//! Measurement harness for the `cargo bench` targets (no criterion in the
//! offline image): warmup + timed samples, mean/std/percentiles, and the
//! paper-shaped table rendering every bench target prints.

use std::time::Instant;

/// Timing statistics over n samples.
#[derive(Clone, Debug)]
pub struct Stats {
    pub samples_ms: Vec<f64>,
}

impl Stats {
    pub fn mean(&self) -> f64 {
        self.samples_ms.iter().sum::<f64>() / self.samples_ms.len().max(1) as f64
    }

    pub fn std(&self) -> f64 {
        let m = self.mean();
        let var = self.samples_ms.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / self.samples_ms.len().max(1) as f64;
        var.sqrt()
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        let mut s = self.samples_ms.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    pub fn min(&self) -> f64 {
        self.samples_ms.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// "12.34 +- 0.56" — the format of the paper's Tables 6–8.
    pub fn pm(&self) -> String {
        format!("{:.2} +- {:.2}", self.mean(), self.std())
    }
}

/// Benchmark a closure: `warmup` unmeasured runs, then `samples` timed runs.
pub fn bench<F: FnMut()>(warmup: usize, samples: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64() * 1000.0);
    }
    Stats { samples_ms: out }
}

/// Convenience wrapper: named measurement printed criterion-style.
pub fn bench_report<F: FnMut()>(name: &str, warmup: usize, samples: usize,
                                f: F) -> Stats {
    let stats = bench(warmup, samples, f);
    println!("{name:<40} {:>12}  (min {:.2} ms, p95 {:.2} ms, n={})",
             stats.pm(), stats.min(), stats.percentile(95.0), samples);
    stats
}

/// Standard bench-output header so all table benches look alike.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Mean-time speedup of `new` over `base` (>1 = faster) — the scaling
/// benches report this per thread count.
pub fn speedup(base: &Stats, new: &Stats) -> f64 {
    let m = new.mean();
    if m <= 0.0 {
        return 0.0;
    }
    base.mean() / m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_math() {
        let s = Stats { samples_ms: vec![1.0, 2.0, 3.0, 4.0] };
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.std() - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 4.0);
    }

    #[test]
    fn bench_runs_closure_expected_times() {
        let mut count = 0;
        let _ = bench(3, 5, || count += 1);
        assert_eq!(count, 8);
    }

    #[test]
    fn pm_format() {
        let s = Stats { samples_ms: vec![10.0, 10.0] };
        assert_eq!(s.pm(), "10.00 +- 0.00");
    }

    #[test]
    fn speedup_ratio() {
        let base = Stats { samples_ms: vec![8.0, 8.0] };
        let faster = Stats { samples_ms: vec![2.0, 2.0] };
        assert!((speedup(&base, &faster) - 4.0).abs() < 1e-12);
        let empty = Stats { samples_ms: vec![] };
        assert_eq!(speedup(&base, &empty), 0.0);
    }
}
