//! Admission-controlled dynamic batching queue: requests accumulate
//! until either the largest bucket fills or the oldest request has
//! waited `max_wait` — the standard trade-off between throughput (full
//! batches) and tail latency (deadline flush).
//!
//! Admission control and load shedding:
//!
//!   * [`Batcher::push`] is the bounded admission point — beyond
//!     `max_queue` it rejects with a **typed** backpressure error
//!     ([`PushError::Full`]) instead of a stringly one, so callers can
//!     tell "back off" from "gone".
//!   * every [`super::Request`] may carry a deadline; requests whose
//!     deadline expires while queued are **shed at dequeue time**:
//!     they come back in [`Drained::expired`] so the worker can deliver
//!     an explicit [`super::Outcome::Shed`] — a client never just loses
//!     its response channel.
//!
//! Consumers run a continuous-batching loop: [`Batcher::next_batch`]
//! blocks (size / max-wait / close triggered) when a worker is idle,
//! and [`Batcher::poll_batch`] refills without blocking while a worker
//! is hot — arrivals during an execute are picked up the moment rows
//! finish instead of waiting out another accumulation barrier.
//!
//! The queue is multi-consumer: any number of engine workers may block
//! in `next_batch` concurrently (the N-worker coordinator does exactly
//! that).  Batches are handed out atomically under the queue lock, so
//! every request is delivered exactly once.  Idle consumers park on the
//! condvar with **no timeout** — `push` and `close` notify — and `push`
//! wakes at most one consumer (the first item of an accumulating batch,
//! or the item completing a full one), never the whole herd;
//! [`Batcher::idle_wakeups`] counts idle-park returns so tests can
//! assert a quiet server stays asleep.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Instant;
#[cfg(test)]
use std::time::Duration;

use super::Request;

#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// flush as soon as this many requests are queued
    pub max_batch: usize,
    /// flush when the oldest queued request has waited this long
    pub max_wait: std::time::Duration,
    /// reject new work beyond this depth (backpressure)
    pub max_queue: usize,
    /// default per-request latency budget applied at submit time
    /// (`None` = no deadline: requests are never shed)
    pub deadline: Option<std::time::Duration>,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: std::time::Duration::from_millis(5),
            max_queue: 1024,
            deadline: None,
        }
    }
}

/// Typed admission failure from [`Batcher::push`] — the backpressure
/// signal clients act on (retry with backoff vs give up).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PushError {
    /// the queue was closed (server shutting down)
    Closed,
    /// the bounded queue is at capacity — shed load upstream
    Full { depth: usize, limit: usize },
}

impl std::fmt::Display for PushError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PushError::Closed => write!(f, "queue closed"),
            PushError::Full { depth, limit } => {
                write!(f, "queue full ({depth}/{limit} requests) — \
                           backpressure")
            }
        }
    }
}

impl std::error::Error for PushError {}

/// What a dequeue hands back: the batch to execute, plus any requests
/// whose deadline expired while they queued.  The caller owes every
/// expired request an explicit shed outcome.
pub struct Drained {
    pub batch: Vec<Request>,
    pub expired: Vec<Request>,
}

struct QueueState {
    items: VecDeque<Request>,
    closed: bool,
}

pub struct Batcher {
    policy: BatchPolicy,
    state: Mutex<QueueState>,
    cv: Condvar,
    /// times a consumer returned from the idle (empty-queue) park; an
    /// idle server with no traffic must not move this
    idle_wakeups: AtomicU64,
}

/// Move every expired request (deadline at or before `now`) out of
/// `items` into `out`, preserving FIFO order of the survivors.
fn prune_expired(items: &mut VecDeque<Request>, now: Instant,
                 out: &mut Vec<Request>) {
    let mut i = 0;
    while i < items.len() {
        let expired =
            items[i].deadline.map(|d| d <= now).unwrap_or(false);
        if expired {
            out.push(items.remove(i).expect("index in bounds"));
        } else {
            i += 1;
        }
    }
    // checked mode: pruning must be complete — an expired request left
    // queued would be re-scored later as if it had met its deadline
    #[cfg(feature = "checked")]
    assert!(
        items.iter().all(|r| r.deadline.map(|d| d > now).unwrap_or(true)),
        "checked: prune_expired left an expired request queued"
    );
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher {
            policy,
            state: Mutex::new(QueueState {
                items: VecDeque::new(),
                closed: false,
            }),
            cv: Condvar::new(),
            idle_wakeups: AtomicU64::new(0),
        }
    }

    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    /// Enqueue a request; typed rejection when closed or over the
    /// backpressure limit.
    pub fn push(&self, req: Request) -> Result<(), PushError> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(PushError::Closed);
        }
        if st.items.len() >= self.policy.max_queue {
            return Err(PushError::Full {
                depth: st.items.len(),
                limit: self.policy.max_queue,
            });
        }
        st.items.push_back(req);
        // checked mode: the admission bound must hold after every push
        // — this is the invariant the typed Full rejection exists for
        #[cfg(feature = "checked")]
        assert!(
            st.items.len() <= self.policy.max_queue,
            "checked: bounded admission breached — {} queued > max_queue {}",
            st.items.len(),
            self.policy.max_queue
        );
        // Wake at most one consumer, and only when this push can
        // unblock one: the first item of an accumulating batch (a
        // consumer must arm the max_wait timer) or the item completing
        // a full batch (flush now).  The old notify_all woke every
        // parked worker for a single request; consumers re-check state
        // under the lock, so a notify that races a faster consumer is a
        // harmless no-op wake.  Consumers holding a partial batch are
        // in a *timed* wait and flush on their own at max_wait.
        let len = st.items.len();
        if len == 1 || len % self.policy.max_batch.max(1) == 0 {
            self.cv.notify_one();
        }
        Ok(())
    }

    /// Blocking dequeue (batch ≤ `cap`): returns once a full batch is
    /// ready, the oldest request has waited `max_wait`, a queued
    /// deadline expired (so sheds reach their clients promptly), or the
    /// queue closed with work remaining.  `None` once closed+empty.
    pub fn next_batch(&self, cap: usize) -> Option<Drained> {
        let cap = cap.min(self.policy.max_batch).max(1);
        let mut st = self.state.lock().unwrap();
        loop {
            let mut expired = Vec::new();
            prune_expired(&mut st.items, Instant::now(), &mut expired);
            if !expired.is_empty() {
                // shed requests must reach their clients now, not after
                // the accumulation wait; take a batch too if one is due
                let due = st.items.len() >= cap
                    || (st.closed && !st.items.is_empty())
                    || st.items.front().map(|r| {
                        r.enqueued.elapsed() >= self.policy.max_wait
                    }).unwrap_or(false);
                let n = if due { st.items.len().min(cap) } else { 0 };
                return Some(Drained {
                    batch: st.items.drain(..n).collect(),
                    expired,
                });
            }
            if st.items.len() >= cap {
                break;
            }
            if !st.items.is_empty() {
                // a closed queue never receives more work: flush the
                // partial batch immediately instead of waiting out the
                // max_wait deadline (close() notifies, so consumers
                // already parked on the deadline wait land here too)
                if st.closed {
                    break;
                }
                // deadline check against the oldest entry
                let oldest = st.items.front().unwrap().enqueued;
                let waited = oldest.elapsed();
                if waited >= self.policy.max_wait {
                    break;
                }
                let remaining = self.policy.max_wait - waited;
                let (guard, _timeout) =
                    self.cv.wait_timeout(st, remaining).unwrap();
                st = guard;
                continue;
            }
            if st.closed {
                return None;
            }
            // empty: park until push/close notifies.  No poll interval —
            // an idle server makes zero wakeups (counted, tested).
            st = self.cv.wait(st).unwrap();
            self.idle_wakeups.fetch_add(1, Ordering::Relaxed);
        }
        let n = st.items.len().min(cap);
        // checked mode: a handed-out batch never exceeds the policy cap
        #[cfg(feature = "checked")]
        assert!(
            n <= self.policy.max_batch,
            "checked: batch of {n} exceeds max_batch {}",
            self.policy.max_batch
        );
        Some(Drained {
            batch: st.items.drain(..n).collect(),
            expired: Vec::new(),
        })
    }

    /// Non-blocking dequeue for hot workers (continuous batching): take
    /// whatever is queued right now, up to `cap`, with no accumulation
    /// barrier — a worker that just finished a batch refills from the
    /// arrivals that landed while it executed.  Both fields may be
    /// empty.
    pub fn poll_batch(&self, cap: usize) -> Drained {
        let cap = cap.min(self.policy.max_batch).max(1);
        let mut st = self.state.lock().unwrap();
        let mut expired = Vec::new();
        prune_expired(&mut st.items, Instant::now(), &mut expired);
        let n = st.items.len().min(cap);
        Drained {
            batch: st.items.drain(..n).collect(),
            expired,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.state.lock().unwrap().items.is_empty()
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    /// Times a consumer woke from the idle (empty-queue) park.  Zero on
    /// a quiet server; one per push-driven hand-off.
    pub fn idle_wakeups(&self) -> u64 {
        self.idle_wakeups.load(Ordering::Relaxed)
    }

    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(id: u64) -> Request {
        let (tx, _rx) = mpsc::channel();
        Request {
            id,
            tokens: vec![0; 4],
            enqueued: Instant::now(),
            deadline: None,
            respond: tx,
        }
    }

    /// A request whose deadline has already passed when it enqueues.
    fn expired_req(id: u64) -> Request {
        let mut r = req(id);
        r.deadline = Some(Instant::now());
        r
    }

    fn live_req(id: u64) -> Request {
        let mut r = req(id);
        r.deadline = Some(Instant::now() + Duration::from_secs(3600));
        r
    }

    fn policy(max_batch: usize, wait_ms: u64, max_queue: usize) -> BatchPolicy {
        BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(wait_ms),
            max_queue,
            deadline: None,
        }
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let b = Batcher::new(policy(4, 10_000, 100));
        for i in 0..4 {
            b.push(req(i)).unwrap();
        }
        let t0 = Instant::now();
        let batch = b.next_batch(4).unwrap().batch;
        assert_eq!(batch.len(), 4);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let b = Batcher::new(policy(8, 20, 100));
        b.push(req(1)).unwrap();
        b.push(req(2)).unwrap();
        let batch = b.next_batch(8).unwrap().batch;
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn fifo_order_preserved() {
        let b = Batcher::new(policy(3, 1, 100));
        for i in 0..7 {
            b.push(req(i)).unwrap();
        }
        let mut seen = Vec::new();
        while seen.len() < 7 {
            for r in b.next_batch(3).unwrap().batch {
                seen.push(r.id);
            }
        }
        assert_eq!(seen, (0..7).collect::<Vec<_>>());
    }

    #[test]
    fn backpressure_rejects_with_typed_error() {
        let b = Batcher::new(policy(4, 1, 2));
        b.push(req(1)).unwrap();
        b.push(req(2)).unwrap();
        match b.push(req(3)) {
            Err(PushError::Full { depth: 2, limit: 2 }) => {}
            other => panic!("expected Full{{2,2}}, got {other:?}"),
        }
    }

    #[test]
    fn closed_queue_rejects_and_drains() {
        let b = Batcher::new(policy(4, 1, 10));
        b.push(req(1)).unwrap();
        b.close();
        assert_eq!(b.push(req(2)), Err(PushError::Closed));
        // drains the remaining request, then returns None
        assert_eq!(b.next_batch(4).unwrap().batch.len(), 1);
        assert!(b.next_batch(4).is_none());
    }

    #[test]
    fn close_flushes_partial_batch_without_waiting_out_deadline() {
        // regression: a closed queue used to sit out the full max_wait
        // before handing a partial batch over; with a long deadline the
        // drain must still be prompt
        let b = Batcher::new(policy(8, 10_000, 100));
        b.push(req(1)).unwrap();
        b.push(req(2)).unwrap();
        b.close();
        let t0 = Instant::now();
        let batch = b.next_batch(8).unwrap().batch;
        assert_eq!(batch.len(), 2);
        assert!(t0.elapsed() < Duration::from_millis(500),
                "partial batch took {:?} after close (max_wait 10s)",
                t0.elapsed());
        assert!(b.next_batch(8).is_none());
    }

    #[test]
    fn close_wakes_consumer_parked_on_deadline_wait() {
        // same bug from the other side: the consumer is already blocked
        // inside next_batch on the 10s deadline when close() lands — the
        // notify must flush the partial batch, not rearm the wait
        let b = std::sync::Arc::new(Batcher::new(policy(8, 10_000, 100)));
        b.push(req(7)).unwrap();
        let bb = b.clone();
        let consumer = std::thread::spawn(move || {
            let t0 = Instant::now();
            (bb.next_batch(8), t0.elapsed())
        });
        // let the consumer reach the deadline wait, then close
        std::thread::sleep(Duration::from_millis(100));
        b.close();
        let (batch, waited) = consumer.join().unwrap();
        assert_eq!(batch.unwrap().batch.len(), 1);
        assert!(waited < Duration::from_secs(5),
                "consumer waited {waited:?} — close() did not flush");
    }

    #[test]
    fn expired_requests_are_shed_at_dequeue() {
        // two expired + one live: the expired pair comes back in
        // `expired` (owed an explicit shed outcome), the live one forms
        // the batch — and the shed return is prompt even though neither
        // the batch-full nor the max_wait trigger fired
        let b = Batcher::new(policy(4, 10_000, 100));
        b.push(expired_req(1)).unwrap();
        b.push(expired_req(2)).unwrap();
        b.push(live_req(3)).unwrap();
        let t0 = Instant::now();
        let d = b.next_batch(4).unwrap();
        assert!(t0.elapsed() < Duration::from_millis(500),
                "sheds waited {:?} for the accumulation barrier",
                t0.elapsed());
        let shed_ids: Vec<u64> = d.expired.iter().map(|r| r.id).collect();
        assert_eq!(shed_ids, vec![1, 2]);
        // no trigger fired, so the live request stays queued...
        assert!(d.batch.is_empty());
        // ...and a hot-path poll picks it up immediately
        let d2 = b.poll_batch(4);
        assert!(d2.expired.is_empty());
        assert_eq!(d2.batch.len(), 1);
        assert_eq!(d2.batch[0].id, 3);
    }

    #[test]
    fn expired_only_queue_sheds_immediately() {
        let b = Batcher::new(policy(8, 10_000, 100));
        b.push(expired_req(1)).unwrap();
        let t0 = Instant::now();
        let d = b.next_batch(8).unwrap();
        assert!(t0.elapsed() < Duration::from_millis(500));
        assert!(d.batch.is_empty());
        assert_eq!(d.expired.len(), 1);
        assert!(b.is_empty());
    }

    #[test]
    fn poll_batch_is_nonblocking_and_bounded() {
        let b = Batcher::new(policy(4, 10_000, 100));
        // empty queue: immediate empty drain, no parking
        let t0 = Instant::now();
        let d = b.poll_batch(4);
        assert!(d.batch.is_empty() && d.expired.is_empty());
        assert!(t0.elapsed() < Duration::from_millis(100));
        // six queued: poll takes cap=4, leaves 2 — no max_wait barrier
        for i in 0..6 {
            b.push(req(i)).unwrap();
        }
        let d = b.poll_batch(4);
        assert_eq!(d.batch.iter().map(|r| r.id).collect::<Vec<_>>(),
                   vec![0, 1, 2, 3]);
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn idle_consumer_makes_no_spurious_wakeups() {
        // regression: idle consumers used to poll every 50 ms even with
        // no traffic; now they park untimed until push/close notifies
        let b = std::sync::Arc::new(Batcher::new(policy(8, 5, 100)));
        let bb = b.clone();
        let consumer = std::thread::spawn(move || bb.next_batch(8));
        std::thread::sleep(Duration::from_millis(300));
        assert_eq!(b.idle_wakeups(), 0,
                   "idle server woke {} times in 300ms of silence",
                   b.idle_wakeups());
        b.close();
        assert!(consumer.join().unwrap().is_none());
    }

    #[test]
    fn push_wakes_one_consumer_not_the_herd() {
        // three consumers parked on an empty queue; one push must not
        // wake all of them (cap 1 ⇒ the woken consumer takes the item
        // and returns immediately)
        let b = std::sync::Arc::new(Batcher::new(policy(1, 5, 100)));
        let consumers: Vec<_> = (0..3).map(|_| {
            let bb = b.clone();
            std::thread::spawn(move || bb.next_batch(1))
        }).collect();
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(b.idle_wakeups(), 0);
        b.push(req(1)).unwrap();
        std::thread::sleep(Duration::from_millis(100));
        assert!(b.idle_wakeups() < 3,
                "one push woke all {} parked consumers",
                b.idle_wakeups());
        b.close();
        let served: usize = consumers.into_iter()
            .map(|c| c.join().unwrap().map(|d| d.batch.len()).unwrap_or(0))
            .sum();
        assert_eq!(served, 1);
    }

    #[test]
    fn multi_consumer_delivers_exactly_once() {
        // N-worker mode: several consumers race on next_batch; every
        // request must come out exactly once across all of them
        let b = std::sync::Arc::new(Batcher::new(policy(4, 1, 10_000)));
        let total = 300u64;
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let bb = b.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(d) = bb.next_batch(4) {
                        got.extend(d.batch.iter().map(|r| r.id));
                    }
                    got // exits when closed + drained
                })
            })
            .collect();
        for i in 0..total {
            b.push(req(i)).unwrap();
            if i % 13 == 0 {
                std::thread::sleep(Duration::from_micros(100));
            }
        }
        b.close();
        let mut got: Vec<u64> =
            consumers.into_iter().flat_map(|c| c.join().unwrap()).collect();
        got.sort();
        assert_eq!(got, (0..total).collect::<Vec<_>>());
    }

    #[test]
    fn no_request_lost_under_concurrency() {
        // property: N producers × M requests all come out exactly once,
        // through a consumer mixing blocking next_batch with hot-path
        // poll_batch refills (the real worker loop's shape)
        let b = std::sync::Arc::new(Batcher::new(policy(8, 2, 10_000)));
        let n_prod = 4;
        let per = 50;
        let mut handles = Vec::new();
        for p in 0..n_prod {
            let bb = b.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    bb.push(req((p * per + i) as u64)).unwrap();
                    if i % 7 == 0 {
                        std::thread::sleep(Duration::from_micros(200));
                    }
                }
            }));
        }
        let consumer = {
            let bb = b.clone();
            std::thread::spawn(move || {
                let mut got = Vec::new();
                while got.len() < n_prod * per {
                    if let Some(d) = bb.next_batch(8) {
                        assert!(d.batch.len() <= 8);
                        got.extend(d.batch.iter().map(|r| r.id));
                        // continuous refill while hot
                        loop {
                            let d = bb.poll_batch(8);
                            if d.batch.is_empty() {
                                break;
                            }
                            got.extend(d.batch.iter().map(|r| r.id));
                        }
                    }
                }
                got
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        let mut got = consumer.join().unwrap();
        got.sort();
        let expect: Vec<u64> = (0..(n_prod * per) as u64).collect();
        assert_eq!(got, expect);
    }
}
