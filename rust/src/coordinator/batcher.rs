//! Dynamic batching queue: requests accumulate until either the largest
//! bucket fills or the oldest request has waited `max_wait` — the standard
//! continuous-batching trade-off between throughput (full batches) and
//! tail latency (deadline flush).
//!
//! The queue is multi-consumer: any number of engine workers may block in
//! [`Batcher::next_batch`] concurrently (the N-worker coordinator does
//! exactly that).  Batches are handed out atomically under the queue
//! lock, so every request is delivered exactly once, and `close()` wakes
//! all parked consumers.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;
#[cfg(test)]
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::Request;

#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// flush as soon as this many requests are queued
    pub max_batch: usize,
    /// flush when the oldest queued request has waited this long
    pub max_wait: Duration,
    /// reject new work beyond this depth (backpressure)
    pub max_queue: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            max_queue: 1024,
        }
    }
}

struct QueueState {
    items: VecDeque<Request>,
    closed: bool,
}

pub struct Batcher {
    policy: BatchPolicy,
    state: Mutex<QueueState>,
    cv: Condvar,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher {
            policy,
            state: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue a request (fails when closed or over the backpressure limit).
    pub fn push(&self, req: Request) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(anyhow!("queue closed"));
        }
        if st.items.len() >= self.policy.max_queue {
            return Err(anyhow!("queue full ({} requests) — backpressure",
                               st.items.len()));
        }
        st.items.push_back(req);
        self.cv.notify_all();
        Ok(())
    }

    /// Blocking pop of the next batch (≤ `cap`); `None` once closed+empty.
    pub fn next_batch(&self, cap: usize) -> Option<Vec<Request>> {
        let cap = cap.min(self.policy.max_batch).max(1);
        let mut st = self.state.lock().unwrap();
        loop {
            if st.items.len() >= cap {
                break;
            }
            if !st.items.is_empty() {
                // a closed queue never receives more work: flush the
                // partial batch immediately instead of waiting out the
                // max_wait deadline (close() notifies, so consumers
                // already parked on the deadline wait land here too)
                if st.closed {
                    break;
                }
                // deadline check against the oldest entry
                let oldest = st.items.front().unwrap().enqueued;
                let waited = oldest.elapsed();
                if waited >= self.policy.max_wait {
                    break;
                }
                let remaining = self.policy.max_wait - waited;
                let (guard, _timeout) =
                    self.cv.wait_timeout(st, remaining).unwrap();
                st = guard;
                continue;
            }
            if st.closed {
                return None;
            }
            // empty: wait for work (with a poll interval so closing is seen)
            let (guard, _timeout) = self
                .cv
                .wait_timeout(st, Duration::from_millis(50))
                .unwrap();
            st = guard;
        }
        let n = st.items.len().min(cap);
        Some(st.items.drain(..n).collect())
    }

    pub fn is_empty(&self) -> bool {
        self.state.lock().unwrap().items.is_empty()
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(id: u64) -> Request {
        let (tx, _rx) = mpsc::channel();
        Request { id, tokens: vec![0; 4], enqueued: Instant::now(), respond: tx }
    }

    fn policy(max_batch: usize, wait_ms: u64, max_queue: usize) -> BatchPolicy {
        BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(wait_ms),
            max_queue,
        }
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let b = Batcher::new(policy(4, 10_000, 100));
        for i in 0..4 {
            b.push(req(i)).unwrap();
        }
        let t0 = Instant::now();
        let batch = b.next_batch(4).unwrap();
        assert_eq!(batch.len(), 4);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let b = Batcher::new(policy(8, 20, 100));
        b.push(req(1)).unwrap();
        b.push(req(2)).unwrap();
        let batch = b.next_batch(8).unwrap();
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn fifo_order_preserved() {
        let b = Batcher::new(policy(3, 1, 100));
        for i in 0..7 {
            b.push(req(i)).unwrap();
        }
        let mut seen = Vec::new();
        while seen.len() < 7 {
            for r in b.next_batch(3).unwrap() {
                seen.push(r.id);
            }
        }
        assert_eq!(seen, (0..7).collect::<Vec<_>>());
    }

    #[test]
    fn backpressure_rejects() {
        let b = Batcher::new(policy(4, 1, 2));
        b.push(req(1)).unwrap();
        b.push(req(2)).unwrap();
        assert!(b.push(req(3)).is_err());
    }

    #[test]
    fn closed_queue_rejects_and_drains() {
        let b = Batcher::new(policy(4, 1, 10));
        b.push(req(1)).unwrap();
        b.close();
        assert!(b.push(req(2)).is_err());
        // drains the remaining request, then returns None
        assert_eq!(b.next_batch(4).unwrap().len(), 1);
        assert!(b.next_batch(4).is_none());
    }

    #[test]
    fn close_flushes_partial_batch_without_waiting_out_deadline() {
        // regression: a closed queue used to sit out the full max_wait
        // before handing a partial batch over; with a long deadline the
        // drain must still be prompt
        let b = Batcher::new(policy(8, 10_000, 100));
        b.push(req(1)).unwrap();
        b.push(req(2)).unwrap();
        b.close();
        let t0 = Instant::now();
        let batch = b.next_batch(8).unwrap();
        assert_eq!(batch.len(), 2);
        assert!(t0.elapsed() < Duration::from_millis(500),
                "partial batch took {:?} after close (max_wait 10s)",
                t0.elapsed());
        assert!(b.next_batch(8).is_none());
    }

    #[test]
    fn close_wakes_consumer_parked_on_deadline_wait() {
        // same bug from the other side: the consumer is already blocked
        // inside next_batch on the 10s deadline when close() lands — the
        // notify must flush the partial batch, not rearm the wait
        let b = std::sync::Arc::new(Batcher::new(policy(8, 10_000, 100)));
        b.push(req(7)).unwrap();
        let bb = b.clone();
        let consumer = std::thread::spawn(move || {
            let t0 = Instant::now();
            (bb.next_batch(8), t0.elapsed())
        });
        // let the consumer reach the deadline wait, then close
        std::thread::sleep(Duration::from_millis(100));
        b.close();
        let (batch, waited) = consumer.join().unwrap();
        assert_eq!(batch.unwrap().len(), 1);
        assert!(waited < Duration::from_secs(5),
                "consumer waited {waited:?} — close() did not flush");
    }

    #[test]
    fn multi_consumer_delivers_exactly_once() {
        // N-worker mode: several consumers race on next_batch; every
        // request must come out exactly once across all of them
        let b = std::sync::Arc::new(Batcher::new(policy(4, 1, 10_000)));
        let total = 300u64;
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let bb = b.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(batch) = bb.next_batch(4) {
                        got.extend(batch.iter().map(|r| r.id));
                    }
                    got // exits when closed + drained
                })
            })
            .collect();
        for i in 0..total {
            b.push(req(i)).unwrap();
            if i % 13 == 0 {
                std::thread::sleep(Duration::from_micros(100));
            }
        }
        b.close();
        let mut got: Vec<u64> =
            consumers.into_iter().flat_map(|c| c.join().unwrap()).collect();
        got.sort();
        assert_eq!(got, (0..total).collect::<Vec<_>>());
    }

    #[test]
    fn no_request_lost_under_concurrency() {
        // property: N producers × M requests all come out exactly once
        let b = std::sync::Arc::new(Batcher::new(policy(8, 2, 10_000)));
        let n_prod = 4;
        let per = 50;
        let mut handles = Vec::new();
        for p in 0..n_prod {
            let bb = b.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    bb.push(req((p * per + i) as u64)).unwrap();
                    if i % 7 == 0 {
                        std::thread::sleep(Duration::from_micros(200));
                    }
                }
            }));
        }
        let consumer = {
            let bb = b.clone();
            std::thread::spawn(move || {
                let mut got = Vec::new();
                while got.len() < n_prod * per {
                    if let Some(batch) = bb.next_batch(8) {
                        assert!(batch.len() <= 8);
                        got.extend(batch.iter().map(|r| r.id));
                    }
                }
                got
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        let mut got = consumer.join().unwrap();
        got.sort();
        let expect: Vec<u64> = (0..(n_prod * per) as u64).collect();
        assert_eq!(got, expect);
    }
}
