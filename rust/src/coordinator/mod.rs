//! L3 serving coordinator — the quantized model is an inference artifact
//! and this is the engine that serves it: a dynamic batcher in front of
//! N worker threads, each owning its own PJRT engine and sessions (PJRT
//! handles are not Send, so every engine lives entirely inside its
//! worker).
//!
//! Request flow:
//!   client → [`ServerHandle::submit`] → shared queue → batcher (size or
//!   deadline trigger, largest-fitting batch bucket, repeat-padding) →
//!   any idle worker → PJRT execute → per-sequence NLL scoring →
//!   response channel.
//!
//! `ServerConfig::workers > 1` scales execute throughput on multi-core
//! hosts: the workers race on the shared [`Batcher`] (work-stealing by
//! construction) and report per-worker metrics so load skew is visible.
//! Each worker compiles its own sessions — startup cost is N× the
//! single-worker compile, which the first-request throughput offset in
//! [`ServerMetrics`] already excludes.
//!
//! The service scores sequences (sum/mean NLL — the serving primitive
//! behind perplexity and multiple-choice evaluation).  Per-row scoring
//! fans out on a per-worker persistent [`crate::par::Pool`] sized to an
//! even split of the process thread budget.  Metrics cover queue wait,
//! execute latency and end-to-end latency.

pub mod batcher;
pub mod metrics;
pub mod soak;

pub use batcher::{Batcher, BatchPolicy, Drained, PushError};
pub use metrics::{Histogram, MetricsSnapshot, ServerMetrics, WorkerSnapshot};

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::runtime::{Engine, ModelArtifacts, NativeModel, TensorBundle};

/// One scoring request: a token sequence of exactly `seq_len`, plus an
/// optional absolute deadline — a request still queued past its
/// deadline is shed with an explicit [`Outcome::Shed`] instead of being
/// executed late.
pub struct Request {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub enqueued: Instant,
    pub deadline: Option<Instant>,
    pub respond: mpsc::Sender<Outcome>,
}

/// The scored result.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// mean next-token NLL over the sequence (exp → per-seq perplexity)
    pub mean_nll: f64,
    /// time spent queued, up to the instant a worker dequeued the batch
    pub queue_us: u64,
    /// backend execute (forward pass) time for the batch
    pub exec_us: u64,
    /// per-batch NLL scoring time (kept out of queue_us and exec_us so
    /// the three phases are attributed honestly)
    pub score_us: u64,
    pub total_us: u64,
}

/// What a client receives on its response channel — exactly one
/// `Outcome` per admitted request, always: scored, shed, or failed.  A
/// client never just loses its channel.
#[derive(Clone, Debug)]
pub enum Outcome {
    /// executed and scored
    Scored(Response),
    /// deadline expired while queued; never executed
    Shed { id: u64, waited_us: u64 },
    /// the execute backend failed; `error` carries the cause (the old
    /// behavior dropped the senders, leaving clients a bare channel
    /// error with no explanation)
    Failed { id: u64, error: String },
}

impl Outcome {
    pub fn id(&self) -> u64 {
        match self {
            Outcome::Scored(r) => r.id,
            Outcome::Shed { id, .. } | Outcome::Failed { id, .. } => *id,
        }
    }

    /// The scored response, or a descriptive error — for clients that
    /// treat anything but success as fatal (`rx.recv()?.scored()?`).
    pub fn scored(self) -> Result<Response> {
        match self {
            Outcome::Scored(r) => Ok(r),
            Outcome::Shed { id, waited_us } => Err(anyhow!(
                "request {id} shed: deadline expired after {waited_us}us \
                 in queue")),
            Outcome::Failed { id, error } => Err(anyhow!(
                "request {id} failed: {error}")),
        }
    }
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub model_dir: PathBuf,
    /// graph prefix, e.g. "fwd_w4a4_r10" or "fwd_fp"; buckets are the
    /// `_b{n}` variants present in graphs.json
    pub graph_prefix: String,
    /// quant bundle dir (None for fp graphs)
    pub quant_dir: Option<PathBuf>,
    pub policy: BatchPolicy,
    /// engine workers pulling from the shared batcher; each owns its own
    /// PJRT engine + sessions (0 is treated as 1)
    pub workers: usize,
    /// force the native (engine-free) execute path: the rotated forward
    /// on the crate's own kernels with quantized layers running the
    /// fused dequant-GEMM ([`crate::runtime::NativeModel`]).  When
    /// false, workers still **fall back** to native if the PJRT engine
    /// fails to initialize (e.g. the vendored stub), so serving works on
    /// engine-less hosts.
    pub native: bool,
}

pub struct ServerHandle {
    queue: Arc<Batcher>,
    next_id: AtomicU64,
    workers: Vec<std::thread::JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
    pub metrics: Arc<ServerMetrics>,
    pub seq_len: usize,
}

impl ServerHandle {
    /// Start the server; blocks until every worker has compiled its
    /// sessions (any worker failing to initialize fails the whole start).
    pub fn start(cfg: ServerConfig) -> Result<ServerHandle> {
        let n_workers = cfg.workers.max(1);
        let queue = Arc::new(Batcher::new(cfg.policy.clone()));
        let metrics = Arc::new(ServerMetrics::with_workers(n_workers));
        let shutdown = Arc::new(AtomicBool::new(false));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<usize, String>>();

        let mut workers = Vec::with_capacity(n_workers);
        for wid in 0..n_workers {
            let cfg = cfg.clone();
            let q2 = queue.clone();
            let m2 = metrics.clone();
            let s2 = shutdown.clone();
            let tx = ready_tx.clone();
            let worker = std::thread::Builder::new()
                .name(format!("lrc-worker-{wid}"))
                .spawn(move || worker_loop(cfg, wid, q2, m2, s2, tx))
                .expect("spawn worker");
            workers.push(worker);
        }
        drop(ready_tx);

        let mut seq_len = None;
        let mut fail: Option<anyhow::Error> = None;
        for _ in 0..n_workers {
            match ready_rx.recv() {
                Err(_) => {
                    fail = Some(anyhow!("worker died during startup"));
                    break;
                }
                Ok(Err(e)) => {
                    fail = Some(anyhow!("worker init: {e}"));
                    break;
                }
                Ok(Ok(got)) => {
                    if let Some(sl) = seq_len {
                        if sl != got {
                            fail = Some(anyhow!(
                                "workers disagree on seq_len: {sl} vs {got}"));
                            break;
                        }
                    }
                    seq_len = Some(got);
                }
            }
        }
        if let Some(e) = fail {
            // tear the healthy workers down before reporting the failure
            shutdown.store(true, Ordering::SeqCst);
            queue.close();
            for w in workers {
                let _ = w.join();
            }
            return Err(e);
        }
        Ok(ServerHandle {
            queue,
            next_id: AtomicU64::new(1),
            workers,
            shutdown,
            metrics,
            seq_len: seq_len.expect("n_workers >= 1"),
        })
    }

    /// Submit a sequence with the policy's default deadline; returns
    /// the channel the [`Outcome`] arrives on.
    pub fn submit(&self, tokens: Vec<i32>) -> Result<mpsc::Receiver<Outcome>> {
        let deadline = self.queue.policy().deadline;
        self.submit_with_deadline(tokens, deadline)
    }

    /// Submit with an explicit latency budget (`None` = never shed).
    /// Admission is bounded: a full queue rejects with the typed
    /// [`PushError::Full`] backpressure error (counted in
    /// `metrics.rejected`) instead of queueing unboundedly.
    pub fn submit_with_deadline(&self, tokens: Vec<i32>,
                                deadline: Option<Duration>)
                                -> Result<mpsc::Receiver<Outcome>> {
        if tokens.len() != self.seq_len {
            return Err(anyhow!("sequence must be seq_len={} tokens, got {}",
                               self.seq_len, tokens.len()));
        }
        let (tx, rx) = mpsc::channel();
        let now = Instant::now();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            tokens,
            enqueued: now,
            deadline: deadline.map(|d| now + d),
            respond: tx,
        };
        if let Err(e) = self.queue.push(req) {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(e.into());
        }
        Ok(rx)
    }

    /// Graceful shutdown: drain the queue, stop every worker.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.shutdown.store(true, Ordering::SeqCst);
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.metrics.snapshot()
    }
}

/// How a worker executes a token block: a per-worker PJRT engine with
/// per-bucket compiled sessions, or the engine-free native forward
/// (fused dequant-GEMM for the quantized layers).  Both expose the same
/// (bucket sizes, run) surface to the batch loop.
enum ExecBackend {
    Engine { buckets: Vec<(usize, crate::runtime::Session)> },
    Native { model: NativeModel, buckets: Vec<usize> },
}

impl ExecBackend {
    fn bucket_sizes(&self) -> Vec<usize> {
        match self {
            ExecBackend::Engine { buckets } =>
                buckets.iter().map(|(b, _)| *b).collect(),
            ExecBackend::Native { buckets, .. } => buckets.clone(),
        }
    }

    /// Execute a `[bsize, seq_len]` token block; flat logits out.
    fn run(&self, flat: &[i32], bsize: usize) -> Result<Vec<f32>> {
        match self {
            ExecBackend::Engine { buckets } => {
                let (_, session) = buckets.iter().find(|(b, _)| *b == bsize)
                    .ok_or_else(|| anyhow!("no session for bucket {bsize}"))?;
                session.run(flat)
            }
            ExecBackend::Native { model, .. } => model.logits(flat, bsize),
        }
    }
}

/// Build the native backend: model + quant bundle on the crate's own
/// kernels.  Bucket sizes come from the graph registry when the prefix
/// matches (so batching behaves exactly like the engine path), else a
/// single max-batch bucket from the policy.
fn native_backend(cfg: &ServerConfig, arts: &ModelArtifacts,
                  quant: Option<&TensorBundle>) -> Result<ExecBackend> {
    let graphs = arts.bucket_graphs(&cfg.graph_prefix);
    let graph = graphs.first().map(|&(_, g)| g);
    let model = NativeModel::new(arts, quant, graph, 4)?;
    let mut buckets: Vec<usize> = graphs.iter().map(|&(b, _)| b).collect();
    if buckets.is_empty() {
        buckets.push(cfg.policy.max_batch.max(1));
    }
    Ok(ExecBackend::Native { model, buckets })
}

fn worker_loop(cfg: ServerConfig, wid: usize, queue: Arc<Batcher>,
               metrics: Arc<ServerMetrics>, shutdown: Arc<AtomicBool>,
               ready: mpsc::Sender<Result<usize, String>>) {
    // All PJRT state is created inside the worker thread (not Send).
    let init = (|| -> Result<_> {
        let arts = ModelArtifacts::load(&cfg.model_dir)?;
        let quant = match &cfg.quant_dir {
            Some(d) => Some(TensorBundle::load(d)?),
            None => None,
        };
        let backend = if cfg.native {
            native_backend(&cfg, &arts, quant.as_ref())?
        } else {
            match Engine::cpu() {
                Ok(engine) => {
                    // discover batch buckets for the prefix (ascending)
                    let mut buckets: Vec<(usize, crate::runtime::Session)> =
                        Vec::new();
                    for (b, g) in arts.bucket_graphs(&cfg.graph_prefix) {
                        let s = engine.session(&arts, &g.name,
                                               quant.as_ref())?;
                        buckets.push((b, s));
                    }
                    if buckets.is_empty() {
                        return Err(anyhow!("no graphs match prefix {}_b*",
                                           cfg.graph_prefix));
                    }
                    ExecBackend::Engine { buckets }
                }
                Err(e) => {
                    // engine-less host (e.g. the vendored PJRT stub):
                    // serve on the native fused path instead of dying
                    if wid == 0 {
                        eprintln!("[coordinator] PJRT engine unavailable \
                                   ({e}); serving on the native fused \
                                   dequant-GEMM path");
                    }
                    native_backend(&cfg, &arts, quant.as_ref())?
                }
            }
        };
        Ok((arts.info.seq_len, arts.info.vocab, backend))
    })();

    let (seq_len, vocab, backend) = match init {
        Ok(v) => {
            let _ = ready.send(Ok(v.0));
            v
        }
        Err(e) => {
            let _ = ready.send(Err(e.to_string()));
            return;
        }
    };
    let bucket_sizes = backend.bucket_sizes();
    let max_bucket = bucket_sizes.last().copied().unwrap_or(1);
    // Per-row NLL scoring (softmax over the vocab per position) is the
    // CPU-side hot loop of a batch; fan it out on a per-worker persistent
    // pool.  The process thread budget is split evenly across the engine
    // workers so N workers never oversubscribe the host, and each row is
    // scored by the same scalar program — responses are bit-identical to
    // the serial loop.
    let score_pool = crate::par::Pool::new(
        (crate::par::threads() / cfg.workers.max(1)).max(1));

    loop {
        // idle: block until work, a queued deadline, or close
        let drained = match queue.next_batch(max_bucket) {
            Some(d) => d,
            None => {
                if shutdown.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
        };
        deliver_shed(drained.expired, &metrics);
        let mut batch = drained.batch;
        // continuous batching: while this worker is hot, execute and
        // then refill from whatever arrived during the execute —
        // poll_batch has no accumulation barrier, so bursty arrivals
        // raise batch fill instead of waiting out another max_wait
        while !batch.is_empty() {
            run_batch(&batch, wid, &backend, &bucket_sizes, seq_len,
                      vocab, &score_pool, &metrics);
            let d = queue.poll_batch(max_bucket);
            deliver_shed(d.expired, &metrics);
            batch = d.batch;
        }
        if shutdown.load(Ordering::SeqCst) && queue.is_empty() {
            return;
        }
    }
}

/// Execute + score + respond for one dequeued batch.  Every request in
/// `batch` receives exactly one [`Outcome`] before this returns.
#[allow(clippy::too_many_arguments)]
fn run_batch(batch: &[Request], wid: usize, backend: &ExecBackend,
             bucket_sizes: &[usize], seq_len: usize, vocab: usize,
             score_pool: &crate::par::Pool, metrics: &ServerMetrics) {
    // the honest phase split (bugfix): queue wait ends at the dequeue
    // instant; execute covers pack + backend.run; scoring is its own
    // phase.  queue_us used to be computed as total − exec, silently
    // folding the scoring time into "queue wait".
    let dequeued = Instant::now();
    // smallest bucket that fits
    let bsize = *bucket_sizes
        .iter()
        .find(|&&b| b >= batch.len())
        .unwrap_or_else(|| bucket_sizes.last().unwrap());
    // pack + repeat-pad
    let mut flat = Vec::with_capacity(bsize * seq_len);
    for r in batch {
        flat.extend_from_slice(&r.tokens);
    }
    for _ in batch.len()..bsize {
        flat.extend_from_slice(&batch.last().unwrap().tokens);
    }
    let logits = match backend.run(&flat, bsize) {
        Ok(l) => l,
        Err(e) => {
            eprintln!("[coordinator] worker {wid}: execute failed: {e}");
            deliver_failure(batch, &format!("execute failed: {e}"), metrics);
            return;
        }
    };
    let exec_us = dequeued.elapsed().as_micros() as u64;
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics.batch_fill.record(
        (batch.len() as f64 / bsize as f64 * 100.0) as u64);
    let wm = &metrics.per_worker[wid];
    wm.batches.fetch_add(1, Ordering::Relaxed);
    wm.requests.fetch_add(batch.len() as u64, Ordering::Relaxed);
    wm.exec_lat_us.record(exec_us);

    // score on the token slices only (the closure must be Sync; the
    // requests' response senders need not be)
    let score_start = Instant::now();
    let token_rows: Vec<&[i32]> =
        batch.iter().map(|r| r.tokens.as_slice()).collect();
    let nlls = score_pool.map(token_rows.len(), |row| {
        let tokens = token_rows[row];
        let mut nll = 0.0_f64;
        for t in 0..seq_len - 1 {
            let target = tokens[t + 1] as usize;
            let off = (row * seq_len + t) * vocab;
            let lrow = &logits[off..off + vocab];
            let max = lrow.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
            let mut sum = 0.0_f64;
            for &v in lrow {
                sum += ((v as f64) - max).exp();
            }
            nll -= (lrow[target] as f64) - max - sum.ln();
        }
        nll
    });
    let score_us = score_start.elapsed().as_micros() as u64;
    metrics.score_lat_us.record(score_us);
    for (req, &nll) in batch.iter().zip(&nlls) {
        let queue_us = dequeued.saturating_duration_since(req.enqueued)
            .as_micros() as u64;
        let total_us = req.enqueued.elapsed().as_micros() as u64;
        let _ = metrics.first_done_us.compare_exchange(
            0, metrics.started.elapsed().as_micros() as u64,
            Ordering::Relaxed, Ordering::Relaxed);
        metrics.requests.fetch_add(1, Ordering::Relaxed);
        metrics.queue_lat_us.record(queue_us);
        metrics.exec_lat_us.record(exec_us);
        metrics.total_lat_us.record(total_us);
        let _ = req.respond.send(Outcome::Scored(Response {
            id: req.id,
            mean_nll: nll / (seq_len - 1) as f64,
            queue_us,
            exec_us,
            score_us,
            total_us,
        }));
    }
}

/// Deliver an explicit [`Outcome::Shed`] to every deadline-expired
/// request the batcher pruned.
fn deliver_shed(expired: Vec<Request>, metrics: &ServerMetrics) {
    for req in expired {
        metrics.shed.fetch_add(1, Ordering::Relaxed);
        let waited_us = req.enqueued.elapsed().as_micros() as u64;
        let _ = req.respond.send(Outcome::Shed { id: req.id, waited_us });
    }
}

/// Bugfix (lost responses on execute failure): every request in a
/// failed batch gets an explicit [`Outcome::Failed`] carrying the
/// cause.  The old path dropped the senders, so clients saw a bare
/// `RecvError` with no explanation.
fn deliver_failure(batch: &[Request], error: &str, metrics: &ServerMetrics) {
    metrics.errors.fetch_add(batch.len() as u64, Ordering::Relaxed);
    for req in batch {
        let _ = req.respond.send(Outcome::Failed {
            id: req.id,
            error: error.to_string(),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req_with_rx(id: u64) -> (Request, mpsc::Receiver<Outcome>) {
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id,
            tokens: vec![0; 4],
            enqueued: Instant::now(),
            deadline: None,
            respond: tx,
        };
        (req, rx)
    }

    #[test]
    fn execute_failure_delivers_explicit_outcome_per_request() {
        // regression: a failed backend.run used to drop the batch's
        // senders silently — clients saw RecvError with no cause
        let metrics = ServerMetrics::new();
        let (reqs, rxs): (Vec<_>, Vec<_>) =
            (0..3).map(req_with_rx).unzip();
        deliver_failure(&reqs, "execute failed: PJRT plugin exploded",
                        &metrics);
        for (i, rx) in rxs.iter().enumerate() {
            match rx.try_recv().expect("no outcome delivered") {
                Outcome::Failed { id, error } => {
                    assert_eq!(id, i as u64);
                    assert!(error.contains("PJRT plugin exploded"),
                            "cause missing from {error:?}");
                }
                other => panic!("expected Failed, got {other:?}"),
            }
        }
        assert_eq!(metrics.errors.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn shed_delivers_explicit_outcome_per_request() {
        let metrics = ServerMetrics::new();
        let (reqs, rxs): (Vec<_>, Vec<_>) =
            (0..2).map(req_with_rx).unzip();
        deliver_shed(reqs, &metrics);
        for (i, rx) in rxs.iter().enumerate() {
            match rx.try_recv().expect("no outcome delivered") {
                Outcome::Shed { id, .. } => assert_eq!(id, i as u64),
                other => panic!("expected Shed, got {other:?}"),
            }
        }
        assert_eq!(metrics.shed.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn outcome_scored_accessor() {
        let ok = Outcome::Scored(Response {
            id: 1, mean_nll: 2.0, queue_us: 1, exec_us: 2, score_us: 3,
            total_us: 6,
        });
        assert_eq!(ok.scored().unwrap().id, 1);
        let shed = Outcome::Shed { id: 2, waited_us: 10 };
        assert_eq!(shed.id(), 2);
        let e = shed.scored().unwrap_err().to_string();
        assert!(e.contains("shed"), "{e}");
        let failed = Outcome::Failed { id: 3, error: "boom".into() };
        let e = failed.scored().unwrap_err().to_string();
        assert!(e.contains("boom"), "{e}");
    }
}
