//! Serving metrics: lock-free counters + log-bucketed latency histograms
//! with percentile estimation (no external metrics crate offline).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Log₂-bucketed histogram of u64 samples (µs, %, ...).  64 buckets cover
/// [1, 2⁶³]; recording and reading are wait-free.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..64).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    pub fn record(&self, v: u64) {
        let idx = 64 - (v.max(1)).leading_zeros() as usize - 1;
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum.load(Ordering::Relaxed) as f64 / c as f64
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Percentile estimate: midpoint of the p-quantile bucket, clamped
    /// to the observed maximum so the estimate can never exceed the
    /// largest recorded sample.
    pub fn percentile(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                // bucket i covers [2^i, 2^(i+1)); midpoint = lo + lo/2.
                // Written without `hi = lo << 1`, which wraps to 0 for
                // bucket 63 and returned an estimate *below* the
                // bucket's lower bound.
                let lo = 1u64 << i;
                let mid = lo + lo / 2;
                return mid.min(self.max());
            }
        }
        self.max()
    }
}

/// Per-worker counters: which of the N engine workers did the work, and
/// how its execute latency compares to its peers (a skewed worker is the
/// first symptom of a bad core pin or a slow session compile).
pub struct WorkerMetrics {
    pub batches: AtomicU64,
    pub requests: AtomicU64,
    pub exec_lat_us: Histogram,
}

impl WorkerMetrics {
    pub fn new() -> WorkerMetrics {
        WorkerMetrics {
            batches: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            exec_lat_us: Histogram::new(),
        }
    }
}

impl Default for WorkerMetrics {
    fn default() -> Self {
        Self::new()
    }
}

/// All server metrics in one shareable struct.
pub struct ServerMetrics {
    pub started: Instant,
    /// µs offset of the first completed request (0 = none yet) so
    /// throughput excludes session-compilation time
    pub first_done_us: AtomicU64,
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub errors: AtomicU64,
    /// requests shed because their deadline expired while queued (each
    /// received an explicit [`super::Outcome::Shed`])
    pub shed: AtomicU64,
    /// submissions rejected at admission (queue full / closed — typed
    /// backpressure, the request never entered the queue)
    pub rejected: AtomicU64,
    pub queue_lat_us: Histogram,
    pub exec_lat_us: Histogram,
    /// per-batch NLL scoring time — kept out of both queue wait and
    /// execute latency so the three phases are reported honestly
    pub score_lat_us: Histogram,
    pub total_lat_us: Histogram,
    /// batch fill ratio in percent
    pub batch_fill: Histogram,
    /// one entry per engine worker (N-worker coordinator mode)
    pub per_worker: Vec<WorkerMetrics>,
}

impl ServerMetrics {
    pub fn new() -> ServerMetrics {
        Self::with_workers(1)
    }

    pub fn with_workers(workers: usize) -> ServerMetrics {
        ServerMetrics {
            started: Instant::now(),
            first_done_us: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            queue_lat_us: Histogram::new(),
            exec_lat_us: Histogram::new(),
            score_lat_us: Histogram::new(),
            total_lat_us: Histogram::new(),
            batch_fill: Histogram::new(),
            per_worker: (0..workers.max(1)).map(|_| WorkerMetrics::new())
                .collect(),
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        // measure serving time from the first completed request so the
        // one-off session compilation does not dilute throughput
        let first = self.first_done_us.load(Ordering::Relaxed) as f64 / 1e6;
        let elapsed = (self.started.elapsed().as_secs_f64() - first).max(1e-9);
        let requests = self.requests.load(Ordering::Relaxed);
        MetricsSnapshot {
            requests,
            batches: self.batches.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            throughput_rps: requests as f64 / elapsed.max(1e-9),
            mean_total_us: self.total_lat_us.mean(),
            p50_total_us: self.total_lat_us.percentile(50.0),
            p95_total_us: self.total_lat_us.percentile(95.0),
            p99_total_us: self.total_lat_us.percentile(99.0),
            mean_exec_us: self.exec_lat_us.mean(),
            mean_queue_us: self.queue_lat_us.mean(),
            mean_score_us: self.score_lat_us.mean(),
            mean_batch_fill_pct: self.batch_fill.mean(),
            per_worker: self.per_worker.iter().map(|w| WorkerSnapshot {
                batches: w.batches.load(Ordering::Relaxed),
                requests: w.requests.load(Ordering::Relaxed),
                mean_exec_us: w.exec_lat_us.mean(),
            }).collect(),
        }
    }
}

impl Default for ServerMetrics {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-worker slice of a [`MetricsSnapshot`].
#[derive(Clone, Debug)]
pub struct WorkerSnapshot {
    pub batches: u64,
    pub requests: u64,
    pub mean_exec_us: f64,
}

#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub errors: u64,
    pub shed: u64,
    pub rejected: u64,
    pub throughput_rps: f64,
    pub mean_total_us: f64,
    pub p50_total_us: u64,
    pub p95_total_us: u64,
    pub p99_total_us: u64,
    pub mean_exec_us: f64,
    pub mean_queue_us: f64,
    pub mean_score_us: f64,
    pub mean_batch_fill_pct: f64,
    pub per_worker: Vec<WorkerSnapshot>,
}

impl MetricsSnapshot {
    pub fn render(&self) -> String {
        let mut out = format!(
            "requests={} batches={} errors={} shed={} rejected={} \
             throughput={:.1} req/s\n\
             latency: mean={:.1}ms p50={:.1}ms p95={:.1}ms p99={:.1}ms\n\
             queue mean={:.1}ms exec mean={:.1}ms score mean={:.1}ms \
             batch-fill={:.0}%",
            self.requests, self.batches, self.errors, self.shed,
            self.rejected, self.throughput_rps,
            self.mean_total_us / 1000.0, self.p50_total_us as f64 / 1000.0,
            self.p95_total_us as f64 / 1000.0,
            self.p99_total_us as f64 / 1000.0,
            self.mean_queue_us / 1000.0, self.mean_exec_us / 1000.0,
            self.mean_score_us / 1000.0, self.mean_batch_fill_pct);
        if self.per_worker.len() > 1 {
            for (i, w) in self.per_worker.iter().enumerate() {
                out.push_str(&format!(
                    "\n  worker {i}: batches={} requests={} exec mean={:.1}ms",
                    w.batches, w.requests, w.mean_exec_us / 1000.0));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_basic() {
        let h = Histogram::new();
        for v in [1u64, 2, 4, 8, 1000, 1000, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 7);
        assert_eq!(h.max(), 1000);
        assert!((h.mean() - (1.0 + 2.0 + 4.0 + 8.0 + 3000.0) / 7.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_monotone() {
        let h = Histogram::new();
        for i in 1..=1000u64 {
            h.record(i);
        }
        let p50 = h.percentile(50.0);
        let p95 = h.percentile(95.0);
        let p99 = h.percentile(99.0);
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // p50 of 1..1000 should land near 512-bucket
        assert!((256..=1024).contains(&p50), "{p50}");
    }

    #[test]
    fn percentile_top_bucket_does_not_wrap() {
        // regression: bucket 63's `hi = lo << 1` wrapped to 0, returning
        // a midpoint *below* the bucket's lower bound
        let h = Histogram::new();
        h.record(u64::MAX);
        h.record(1u64 << 63);
        let p = h.percentile(99.0);
        assert!(p >= 1u64 << 63, "estimate {p} below bucket floor 2^63");
        assert!(p <= h.max(), "estimate {p} above observed max {}",
                h.max());
    }

    #[test]
    fn percentile_clamped_to_observed_max() {
        // bucket [512, 1024) has midpoint 768, but the largest recorded
        // sample is 600 — the estimate must not exceed it
        let h = Histogram::new();
        for _ in 0..8 {
            h.record(600);
        }
        assert_eq!(h.percentile(99.0), 600);
        // and a sample above the midpoint leaves the midpoint in place
        let g = Histogram::new();
        for _ in 0..8 {
            g.record(900);
        }
        assert_eq!(g.percentile(50.0), 768);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = Histogram::new();
        assert_eq!(h.percentile(99.0), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn snapshot_renders() {
        let m = ServerMetrics::new();
        m.requests.fetch_add(10, Ordering::Relaxed);
        m.total_lat_us.record(1500);
        let s = m.snapshot().render();
        assert!(s.contains("requests=10"));
        // single-worker servers do not render the per-worker breakdown
        assert!(!s.contains("worker 0"));
    }

    #[test]
    fn per_worker_breakdown_renders() {
        let m = ServerMetrics::with_workers(3);
        assert_eq!(m.per_worker.len(), 3);
        m.per_worker[1].batches.fetch_add(4, Ordering::Relaxed);
        m.per_worker[1].requests.fetch_add(9, Ordering::Relaxed);
        m.per_worker[1].exec_lat_us.record(2000);
        let snap = m.snapshot();
        assert_eq!(snap.per_worker.len(), 3);
        assert_eq!(snap.per_worker[1].batches, 4);
        let s = snap.render();
        assert!(s.contains("worker 1: batches=4 requests=9"));
        assert!(s.contains("worker 2: batches=0"));
    }
}
