//! Synthetic-traffic soak harness for the serving layer.
//!
//! Two modes share one workload model:
//!
//! * **Trace generation** — open-loop Poisson arrivals with alternating
//!   steady/burst phases and an adversarial tight-deadline request
//!   class, all drawn from the crate's seeded [`Rng`].  The trace is a
//!   pure function of the config (worker count does not influence it),
//!   so a seed reproduces the exact same offered load anywhere.
//!
//! * **Virtual-time simulation** ([`simulate`]) — a single-threaded
//!   discrete-event model of the admission queue, deadline shedding and
//!   continuous batching, advancing a µs clock instead of waiting on
//!   real time.  This is the determinism contract: the report —
//!   per-request served/shed/rejected decisions included — is
//!   **byte-identical** for a given (seed, config) on any host, at any
//!   host thread count.  Regressions caught by the trend gate therefore
//!   reproduce exactly.
//!
//! * **Live mode** ([`run_live`]) — the same trace replayed in real
//!   time against the *real* [`Batcher`] with real worker threads and a
//!   synthetic service function, for wall-clock throughput/tail-latency
//!   numbers.  Wall-clock runs are not byte-deterministic (the OS
//!   scheduler is not); the simulation is the reproducibility anchor,
//!   live mode is the measurement.
//!
//! `lrc soak` drives both; `bench_soak` records the results into the
//! commit-stamped bench JSON the `bench-trend` CI gate consumes.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::rng::Rng;

use super::batcher::{BatchPolicy, Batcher};
use super::{Outcome, Request, Response};

/// Workload + service-model parameters for one soak run.
#[derive(Clone, Debug)]
pub struct SoakConfig {
    pub seed: u64,
    /// total requests in the trace
    pub n_requests: usize,
    /// steady-state offered load (requests/s)
    pub rate_rps: f64,
    /// arrival-rate multiplier inside burst windows (1.0 = no bursts)
    pub burst_mult: f64,
    /// burst phase period: each period opens with `burst_len_us` of
    /// burst-rate arrivals, then steady-rate for the remainder
    pub burst_every_us: u64,
    pub burst_len_us: u64,
    /// fraction of requests in the adversarial class: deadlines so
    /// tight they are expected to shed under any queueing
    pub adversarial_frac: f64,
    /// latency budget for normal requests (None = never shed)
    pub deadline_us: Option<u64>,
    /// latency budget for adversarial requests
    pub tight_deadline_us: u64,
    /// workers: virtual servers in the simulation, real threads live
    pub workers: usize,
    pub max_batch: usize,
    /// admission-queue bound; arrivals beyond it are rejected
    pub max_queue: usize,
    /// synthetic service time for a batch of n rows:
    /// `service_base_us + n * service_per_row_us`
    pub service_base_us: u64,
    pub service_per_row_us: u64,
}

impl Default for SoakConfig {
    fn default() -> Self {
        SoakConfig {
            seed: 42,
            n_requests: 4000,
            rate_rps: 2000.0,
            burst_mult: 6.0,
            burst_every_us: 250_000,
            burst_len_us: 50_000,
            adversarial_frac: 0.05,
            deadline_us: Some(50_000),
            tight_deadline_us: 300,
            workers: 4,
            max_batch: 8,
            max_queue: 64,
            service_base_us: 400,
            service_per_row_us: 150,
        }
    }
}

impl SoakConfig {
    /// Small preset for CI smoke runs and tests (~0.1 s of virtual
    /// time; live replay finishes well under a second).
    pub fn fast() -> Self {
        SoakConfig {
            n_requests: 400,
            burst_every_us: 50_000,
            burst_len_us: 10_000,
            ..Self::default()
        }
    }
}

/// One generated request: arrival instant and latency budget, both in
/// virtual µs from trace start.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Arrival {
    pub id: u64,
    pub at_us: u64,
    /// relative deadline (budget); absolute expiry is `at_us + d`
    pub deadline_us: Option<u64>,
    pub adversarial: bool,
}

/// Generate the arrival trace.  Pure function of (seed, workload
/// fields); notably independent of `workers`, `max_batch`, `max_queue`
/// and the service model, so capacity experiments replay the identical
/// offered load.
pub fn gen_trace(cfg: &SoakConfig) -> Vec<Arrival> {
    let mut rng = Rng::new(cfg.seed);
    let mut t_us = 0.0_f64;
    let mut out = Vec::with_capacity(cfg.n_requests);
    for id in 0..cfg.n_requests as u64 {
        let in_burst = cfg.burst_every_us > 0
            && (t_us as u64) % cfg.burst_every_us < cfg.burst_len_us;
        let rate = if in_burst {
            cfg.rate_rps * cfg.burst_mult
        } else {
            cfg.rate_rps
        };
        // exponential inter-arrival: -ln(1-U)/λ, in µs
        let u = rng.uniform();
        t_us += -(1.0 - u).ln() / rate * 1e6;
        let adversarial = rng.uniform() < cfg.adversarial_frac;
        let deadline_us = if adversarial {
            Some(cfg.tight_deadline_us)
        } else {
            cfg.deadline_us
        };
        out.push(Arrival { id, at_us: t_us as u64, deadline_us, adversarial });
    }
    out
}

/// Per-request decision in canonical id order: `S` served, `X` shed
/// (deadline expired in queue), `R` rejected at admission.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    Served,
    Shed,
    Rejected,
}

impl Decision {
    fn ch(self) -> char {
        match self {
            Decision::Served => 'S',
            Decision::Shed => 'X',
            Decision::Rejected => 'R',
        }
    }
}

/// Simulation output.  `render()` is the byte-identity contract.
#[derive(Clone, Debug, PartialEq)]
pub struct SoakReport {
    pub served: u64,
    pub shed: u64,
    pub rejected: u64,
    /// virtual time the last batch completed
    pub makespan_us: u64,
    /// total (queue + service) latency percentiles over served requests
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    /// integer mean queue wait of served requests (µs)
    pub mean_queue_us: u64,
    /// decision per request, indexed by id ("SXR..." string)
    pub decisions: String,
}

impl SoakReport {
    /// Canonical report text — the determinism test compares this
    /// byte-for-byte across runs.
    pub fn render(&self, cfg: &SoakConfig) -> String {
        format!(
            "soak seed={} n={} workers={} rate={:.0}rps burst=x{:.0} \
             queue={} batch={}\n\
             served={} shed={} rejected={}\n\
             latency_us: p50={} p95={} p99={} mean_queue={}\n\
             makespan_us={}\n\
             decisions={:016x}\n",
            cfg.seed, cfg.n_requests, cfg.workers, cfg.rate_rps,
            cfg.burst_mult, cfg.max_queue, cfg.max_batch,
            self.served, self.shed, self.rejected,
            self.p50_us, self.p95_us, self.p99_us, self.mean_queue_us,
            self.makespan_us, fnv1a(self.decisions.as_bytes()))
    }
}

/// FNV-1a 64-bit — stable digest for trace/decision byte strings.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Exact percentile of a sorted sample (nearest-rank).
pub fn percentile_us(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Virtual-time discrete-event simulation of the serving layer:
/// bounded admission, dequeue-time deadline shedding, greedy
/// continuous batching (a freed worker immediately takes whatever is
/// queued, up to `max_batch` — the no-barrier refill the real
/// `poll_batch` path implements), service time linear in batch rows.
///
/// Single-threaded and integer-clocked, so the result is reproducible
/// byte-for-byte from (seed, config).  Deterministic tie rules:
/// arrivals at or before a batch's start instant are admitted before
/// the batch forms; the free worker with the lowest (free_at, index)
/// takes the batch.
pub fn simulate(cfg: &SoakConfig, trace: &[Arrival]) -> SoakReport {
    let n = trace.len();
    let workers = cfg.workers.max(1);
    let max_batch = cfg.max_batch.max(1);
    let mut decisions = vec![Decision::Rejected; n];
    let mut queue: VecDeque<usize> = VecDeque::new();
    let mut free_at = vec![0u64; workers];
    let mut next_arrival = 0usize; // trace cursor
    let mut clock = 0u64;
    let mut makespan = 0u64;
    let mut total_lat: Vec<u64> = Vec::new();
    let mut queue_wait_sum = 0u64;
    let mut shed = 0u64;
    let mut rejected = 0u64;

    let expiry = |a: &Arrival| a.deadline_us.map(|d| a.at_us + d);
    let admit = |i: usize, queue: &mut VecDeque<usize>,
                     decisions: &mut [Decision], rejected: &mut u64| {
        if queue.len() >= cfg.max_queue {
            decisions[i] = Decision::Rejected;
            *rejected += 1;
        } else {
            queue.push_back(i);
        }
    };

    loop {
        if queue.is_empty() {
            if next_arrival >= n {
                break;
            }
            // idle: jump the clock to the next arrival
            clock = clock.max(trace[next_arrival].at_us);
            admit(next_arrival, &mut queue, &mut decisions, &mut rejected);
            next_arrival += 1;
            continue;
        }
        // earliest-free worker takes the next batch
        let (wid, &w_free) = free_at
            .iter()
            .enumerate()
            .min_by_key(|&(w, &t)| (t, w))
            .expect("workers >= 1");
        let start = w_free.max(clock);
        // tie rule: admit everything that arrived by the start instant
        while next_arrival < n && trace[next_arrival].at_us <= start {
            admit(next_arrival, &mut queue, &mut decisions, &mut rejected);
            next_arrival += 1;
        }
        // form the batch, shedding requests already past their deadline
        let mut batch: Vec<usize> = Vec::with_capacity(max_batch);
        while batch.len() < max_batch {
            let i = match queue.pop_front() {
                Some(i) => i,
                None => break,
            };
            match expiry(&trace[i]) {
                Some(e) if e <= start => {
                    decisions[i] = Decision::Shed;
                    shed += 1;
                }
                _ => batch.push(i),
            }
        }
        clock = start;
        if batch.is_empty() {
            continue; // everything dequeued this round expired
        }
        let service =
            cfg.service_base_us + batch.len() as u64 * cfg.service_per_row_us;
        let done = start + service;
        free_at[wid] = done;
        makespan = makespan.max(done);
        for i in batch {
            decisions[i] = Decision::Served;
            queue_wait_sum += start - trace[i].at_us;
            total_lat.push(done - trace[i].at_us);
        }
    }

    total_lat.sort_unstable();
    let served = total_lat.len() as u64;
    SoakReport {
        served,
        shed,
        rejected,
        makespan_us: makespan,
        p50_us: percentile_us(&total_lat, 50.0),
        p95_us: percentile_us(&total_lat, 95.0),
        p99_us: percentile_us(&total_lat, 99.0),
        mean_queue_us: if served == 0 { 0 } else { queue_wait_sum / served },
        decisions: decisions.iter().map(|d| d.ch()).collect(),
    }
}

/// Wall-clock results from a live replay against the real [`Batcher`].
#[derive(Clone, Debug)]
pub struct LiveStats {
    pub served: u64,
    pub shed: u64,
    pub rejected: u64,
    pub failed: u64,
    pub wall_ms: f64,
    pub throughput_rps: f64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
}

/// Replay the trace in real time against a real [`Batcher`] with
/// `cfg.workers` OS threads and a synthetic (sleep-based) service
/// function — the admission, shedding and continuous-refill code under
/// test is the production code, only the model execute is synthetic.
///
/// Every admitted request receives exactly one [`Outcome`]; the
/// function panics if any response channel is dropped without one
/// (that is precisely the lost-response bug class this PR fixes).
pub fn run_live(cfg: &SoakConfig) -> LiveStats {
    let trace = gen_trace(cfg);
    let policy = BatchPolicy {
        max_batch: cfg.max_batch.max(1),
        max_wait: Duration::from_millis(2),
        max_queue: cfg.max_queue,
        deadline: None, // deadlines are stamped per-request from the trace
    };
    let queue = Arc::new(Batcher::new(policy));
    let max_batch = cfg.max_batch.max(1);
    let base = cfg.service_base_us;
    let per_row = cfg.service_per_row_us;

    let mut workers = Vec::new();
    for _ in 0..cfg.workers.max(1) {
        let q = queue.clone();
        workers.push(std::thread::spawn(move || {
            let deliver = |req: Request, served: bool| {
                let waited_us = req.enqueued.elapsed().as_micros() as u64;
                let out = if served {
                    Outcome::Scored(Response {
                        id: req.id,
                        mean_nll: 0.0,
                        queue_us: waited_us,
                        exec_us: 0,
                        score_us: 0,
                        total_us: req.enqueued.elapsed().as_micros() as u64,
                    })
                } else {
                    Outcome::Shed { id: req.id, waited_us }
                };
                let _ = req.respond.send(out);
            };
            // same shape as the coordinator worker loop: block when
            // idle, then continuous non-blocking refills while hot
            while let Some(drained) = q.next_batch(max_batch) {
                drained.expired.into_iter().for_each(|r| deliver(r, false));
                let mut batch = drained.batch;
                while !batch.is_empty() {
                    std::thread::sleep(Duration::from_micros(
                        base + batch.len() as u64 * per_row));
                    batch.into_iter().for_each(|r| deliver(r, true));
                    let d = q.poll_batch(max_batch);
                    d.expired.into_iter().for_each(|r| deliver(r, false));
                    batch = d.batch;
                }
            }
        }));
    }

    // open-loop producer: arrivals fire at their trace instants whether
    // or not the server keeps up (that is what makes overload real)
    let t0 = Instant::now();
    let mut rxs: Vec<mpsc::Receiver<Outcome>> = Vec::new();
    let mut rejected = 0u64;
    for a in &trace {
        let due = t0 + Duration::from_micros(a.at_us);
        let now = Instant::now();
        if due > now {
            std::thread::sleep(due - now);
        }
        let (tx, rx) = mpsc::channel();
        let enqueued = Instant::now();
        let req = Request {
            id: a.id,
            tokens: Vec::new(),
            enqueued,
            deadline: a.deadline_us
                .map(|d| enqueued + Duration::from_micros(d)),
            respond: tx,
        };
        match queue.push(req) {
            Ok(()) => rxs.push(rx),
            Err(_) => rejected += 1,
        }
    }
    queue.close();

    let (mut served, mut shed, mut failed) = (0u64, 0u64, 0u64);
    let mut lats: Vec<u64> = Vec::new();
    for rx in rxs {
        match rx.recv_timeout(Duration::from_secs(30))
            .expect("admitted request lost its outcome")
        {
            Outcome::Scored(r) => {
                served += 1;
                lats.push(r.total_us);
            }
            Outcome::Shed { .. } => shed += 1,
            Outcome::Failed { .. } => failed += 1,
        }
    }
    for w in workers {
        let _ = w.join();
    }
    let wall = t0.elapsed().as_secs_f64();
    lats.sort_unstable();
    LiveStats {
        served,
        shed,
        rejected,
        failed,
        wall_ms: wall * 1e3,
        throughput_rps: served as f64 / wall.max(1e-9),
        p50_us: percentile_us(&lats, 50.0),
        p95_us: percentile_us(&lats, 95.0),
        p99_us: percentile_us(&lats, 99.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_worker_independent() {
        let cfg = SoakConfig::fast();
        let a = gen_trace(&cfg);
        let b = gen_trace(&cfg);
        assert_eq!(a, b);
        // the trace is offered load — capacity knobs must not move it
        let more_capacity = SoakConfig {
            workers: 16,
            max_batch: 32,
            max_queue: 9999,
            ..cfg
        };
        assert_eq!(a, gen_trace(&more_capacity));
        // arrivals are time-ordered with unique sequential ids
        for (i, arr) in a.iter().enumerate() {
            assert_eq!(arr.id, i as u64);
            if i > 0 {
                assert!(arr.at_us >= a[i - 1].at_us);
            }
        }
    }

    #[test]
    fn different_seeds_differ() {
        let cfg = SoakConfig::fast();
        let other = SoakConfig { seed: 43, ..cfg.clone() };
        assert_ne!(gen_trace(&cfg), gen_trace(&other));
    }

    #[test]
    fn sim_is_byte_identical_and_conserves_requests() {
        let cfg = SoakConfig::fast();
        let trace = gen_trace(&cfg);
        let r1 = simulate(&cfg, &trace);
        let r2 = simulate(&cfg, &trace);
        assert_eq!(r1, r2);
        assert_eq!(r1.render(&cfg), r2.render(&cfg));
        assert_eq!(r1.served + r1.shed + r1.rejected,
                   cfg.n_requests as u64);
        assert_eq!(r1.decisions.len(), cfg.n_requests);
        assert!(r1.p50_us <= r1.p95_us && r1.p95_us <= r1.p99_us);
    }

    #[test]
    fn adversarial_class_sheds() {
        // tight deadlines under bursty load must produce explicit sheds
        let cfg = SoakConfig {
            adversarial_frac: 0.3,
            tight_deadline_us: 1,
            ..SoakConfig::fast()
        };
        let trace = gen_trace(&cfg);
        let report = simulate(&cfg, &trace);
        assert!(report.shed > 0, "expected sheds, got {report:?}");
        // every shed decision is visible, none silently dropped
        let shed_marks =
            report.decisions.chars().filter(|&c| c == 'X').count() as u64;
        assert_eq!(shed_marks, report.shed);
    }

    #[test]
    fn bounded_queue_rejects_under_overload() {
        let cfg = SoakConfig {
            max_queue: 2,
            workers: 1,
            service_base_us: 10_000,
            deadline_us: None,
            adversarial_frac: 0.0,
            ..SoakConfig::fast()
        };
        let trace = gen_trace(&cfg);
        let report = simulate(&cfg, &trace);
        assert!(report.rejected > 0, "expected rejections, got {report:?}");
        assert_eq!(report.served + report.shed + report.rejected,
                   cfg.n_requests as u64);
    }

    #[test]
    fn more_workers_serve_no_fewer() {
        let cfg1 = SoakConfig { workers: 1, ..SoakConfig::fast() };
        let cfg4 = SoakConfig { workers: 4, ..SoakConfig::fast() };
        let trace = gen_trace(&cfg1);
        let r1 = simulate(&cfg1, &trace);
        let r4 = simulate(&cfg4, &trace);
        assert!(r4.served >= r1.served,
                "4 workers served {} < 1 worker's {}", r4.served, r1.served);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile_us(&xs, 50.0), 50);
        assert_eq!(percentile_us(&xs, 99.0), 99);
        assert_eq!(percentile_us(&xs, 100.0), 100);
        assert_eq!(percentile_us(&[], 50.0), 0);
        assert_eq!(percentile_us(&[7], 99.0), 7);
    }
}
