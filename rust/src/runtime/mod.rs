//! PJRT runtime — loads the AOT artifacts (HLO text + tensor bundles) and
//! executes them on the CPU PJRT client.  Python never runs here: this is
//! the production path.
//!
//! * [`TensorBundle`] — the shared f32 bundle format (manifest.json +
//!   flat little-endian bin), written by python *and* by the rust
//!   quantization pipeline.
//! * [`ModelArtifacts`] — one model directory: weights + graph registry.
//! * [`Engine`] — compiles HLO text once per graph, caches executables.
//! * [`Session`] — a compiled graph with its fixed parameters pre-uploaded
//!   as device buffers; per-call uploads are only the variable inputs
//!   (tokens).  This is the hot serving path.
//! * [`native`] — the engine-free serving path: [`NativeModel`] runs the
//!   same rotated forward on the crate's own kernels, with quantized
//!   layers on the fused dequant-GEMM ([`crate::quant::QuantizedLinear`]).
//!   The coordinator falls back to it when no PJRT engine is available.

pub mod native;

pub use native::{NativeModel, NativeProvider};

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::Json;

// ---------------------------------------------------------------------------
// tensor bundles
// ---------------------------------------------------------------------------

/// A named f32 tensor.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Manifest + bin pair (format "lrc-bundle-v1").
#[derive(Clone, Debug, Default)]
pub struct TensorBundle {
    pub tensors: BTreeMap<String, Tensor>,
    /// tensor names in manifest order
    pub order: Vec<String>,
    pub meta: Option<Json>,
}

impl TensorBundle {
    pub fn load(dir: &Path) -> Result<TensorBundle> {
        let man_path = dir.join("manifest.json");
        let man = Json::parse(&std::fs::read_to_string(&man_path)
            .with_context(|| format!("read {man_path:?}"))?)
            .map_err(|e| anyhow!("parse {man_path:?}: {e}"))?;
        let fmt = man.get("format").and_then(|f| f.as_str()).unwrap_or("");
        if fmt != "lrc-bundle-v1" {
            bail!("unsupported bundle format {fmt:?} in {man_path:?}");
        }
        let bin_name = man.get("bin").and_then(|b| b.as_str()).unwrap_or("weights.bin");
        let bytes = std::fs::read(dir.join(bin_name))
            .with_context(|| format!("read {:?}", dir.join(bin_name)))?;
        let mut tensors = BTreeMap::new();
        let mut order = Vec::new();
        for t in man.get("tensors").and_then(|t| t.as_arr()).unwrap_or(&[]) {
            let name = t.get("name").and_then(|n| n.as_str())
                .ok_or_else(|| anyhow!("tensor missing name"))?.to_string();
            let shape: Vec<usize> = t.get("shape").and_then(|s| s.as_arr())
                .ok_or_else(|| anyhow!("tensor {name} missing shape"))?
                .iter().filter_map(|v| v.as_usize()).collect();
            let offset = t.get("offset").and_then(|o| o.as_usize())
                .ok_or_else(|| anyhow!("tensor {name} missing offset"))?;
            let numel: usize = shape.iter().product();
            let start = offset * 4;
            let end = start + numel * 4;
            if end > bytes.len() {
                bail!("tensor {name} out of range in {bin_name}");
            }
            let data: Vec<f32> = bytes[start..end]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            order.push(name.clone());
            tensors.insert(name, Tensor { shape, data });
        }
        Ok(TensorBundle { tensors, order, meta: Some(man) })
    }

    /// Write in the same format python emits (so both sides interchange).
    pub fn write(&self, dir: &Path, extra: &[(&str, Json)]) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut bin: Vec<u8> = Vec::new();
        let mut table = Vec::new();
        let mut offset = 0usize;
        for name in &self.order {
            let t = &self.tensors[name];
            for v in &t.data {
                bin.extend_from_slice(&v.to_le_bytes());
            }
            table.push(Json::obj(vec![
                ("name", Json::str(name.clone())),
                ("shape", Json::Arr(t.shape.iter().map(|&s| Json::num(s as f64)).collect())),
                ("offset", Json::num(offset as f64)),
            ]));
            offset += t.numel();
        }
        std::fs::write(dir.join("weights.bin"), &bin)?;
        let mut pairs = vec![
            ("format", Json::str("lrc-bundle-v1")),
            ("bin", Json::str("weights.bin")),
            ("tensors", Json::Arr(table)),
        ];
        pairs.extend(extra.iter().cloned());
        std::fs::write(dir.join("manifest.json"), Json::obj(pairs).to_string())?;
        Ok(())
    }

    pub fn insert(&mut self, name: &str, shape: Vec<usize>, data: Vec<f32>) {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        if !self.tensors.contains_key(name) {
            self.order.push(name.to_string());
        }
        self.tensors.insert(name.to_string(), Tensor { shape, data });
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).ok_or_else(|| anyhow!("missing tensor {name}"))
    }
}

// ---------------------------------------------------------------------------
// graph registry
// ---------------------------------------------------------------------------

/// Per-activation slice of the `acts` graph output.
#[derive(Clone, Debug)]
pub struct ActSlice {
    pub name: String,
    pub rows: usize,
    pub dim: usize,
    pub offset: usize,
}

#[derive(Clone, Debug)]
pub struct GraphInfo {
    pub name: String,
    pub file: PathBuf,
    pub params: Vec<String>,
    pub batch: usize,
    /// per-layer low-rank sizes (quant graphs only)
    pub ranks: BTreeMap<String, usize>,
    pub rank_pct: f64,
    pub a_group: Option<usize>,
    pub weight_only: bool,
    pub acts: Vec<ActSlice>,
}

/// Model config parsed from the weights manifest.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_experts: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub param_count: usize,
}

/// One model directory under artifacts/models/<name>/.
pub struct ModelArtifacts {
    pub dir: PathBuf,
    pub weights: TensorBundle,
    pub graphs: BTreeMap<String, GraphInfo>,
    pub info: ModelInfo,
}

impl ModelArtifacts {
    pub fn load(dir: &Path) -> Result<ModelArtifacts> {
        let weights = TensorBundle::load(dir)?;
        let meta = weights.meta.clone().unwrap();
        let m = meta.get("model").ok_or_else(|| anyhow!("manifest missing model"))?;
        let gu = |k: &str| m.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
        let info = ModelInfo {
            name: m.get("name").and_then(|v| v.as_str()).unwrap_or("?").into(),
            d_model: gu("d_model"),
            n_layers: gu("n_layers"),
            n_heads: gu("n_heads"),
            d_ff: gu("d_ff"),
            n_experts: gu("n_experts"),
            seq_len: gu("seq_len"),
            vocab: gu("vocab"),
            param_count: gu("param_count"),
        };
        let gpath = dir.join("graphs.json");
        let gjson = Json::parse(&std::fs::read_to_string(&gpath)
            .with_context(|| format!("read {gpath:?}"))?)
            .map_err(|e| anyhow!("parse graphs.json: {e}"))?;
        let mut graphs = BTreeMap::new();
        for (name, g) in gjson.get("graphs").and_then(|g| g.as_obj())
            .ok_or_else(|| anyhow!("graphs.json missing graphs"))? {
            let params = g.get("params").and_then(|p| p.as_arr()).unwrap_or(&[])
                .iter().filter_map(|v| v.as_str().map(String::from)).collect();
            let mut ranks = BTreeMap::new();
            let mut rank_pct = 0.0;
            let mut a_group = None;
            let mut weight_only = false;
            if let Some(q) = g.get("quant") {
                rank_pct = q.get("rank_pct").and_then(|v| v.as_f64()).unwrap_or(0.0);
                a_group = q.get("a_group").and_then(|v| v.as_usize());
                weight_only = matches!(q.get("weight_only"),
                                       Some(Json::Bool(true)));
                if let Some(r) = q.get("ranks").and_then(|r| r.as_obj()) {
                    for (k, v) in r {
                        ranks.insert(k.clone(), v.as_usize().unwrap_or(0));
                    }
                }
            }
            let mut acts = Vec::new();
            if let Some(a) = g.get("acts").and_then(|a| a.as_arr()) {
                for s in a {
                    acts.push(ActSlice {
                        name: s.get("name").and_then(|v| v.as_str()).unwrap_or("").into(),
                        rows: s.get("rows").and_then(|v| v.as_usize()).unwrap_or(0),
                        dim: s.get("dim").and_then(|v| v.as_usize()).unwrap_or(0),
                        offset: s.get("offset").and_then(|v| v.as_usize()).unwrap_or(0),
                    });
                }
            }
            graphs.insert(name.clone(), GraphInfo {
                name: name.clone(),
                file: dir.join(g.get("file").and_then(|f| f.as_str()).unwrap_or("")),
                params,
                batch: g.get("batch").and_then(|b| b.as_usize()).unwrap_or(1),
                ranks, rank_pct, a_group, weight_only, acts,
            });
        }
        Ok(ModelArtifacts { dir: dir.to_path_buf(), weights, graphs, info })
    }

    pub fn graph(&self, name: &str) -> Result<&GraphInfo> {
        self.graphs.get(name)
            .ok_or_else(|| anyhow!("graph {name} not in {:?}", self.dir))
    }

    /// Graphs named `<prefix>_b<N>` as (bucket N, graph), ascending by
    /// bucket — the single place the batch-bucket naming scheme is
    /// parsed (calibration picks the largest, the coordinator compiles
    /// them all).
    pub fn bucket_graphs(&self, prefix: &str) -> Vec<(usize, &GraphInfo)> {
        let pat = format!("{prefix}_b");
        let mut out = Vec::new();
        for (name, g) in &self.graphs {
            if let Some(rest) = name.strip_prefix(&pat) {
                if let Ok(b) = rest.parse::<usize>() {
                    out.push((b, g));
                }
            }
        }
        out.sort_by_key(|(b, _)| *b);
        out
    }
}

// ---------------------------------------------------------------------------
// engine + sessions
// ---------------------------------------------------------------------------

/// The PJRT engine.  NOTE: PJRT handles are not Send — create one Engine
/// per thread (the coordinator does exactly that in its worker).
pub struct Engine {
    pub client: xla::PjRtClient,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        Ok(Engine { client: xla::PjRtClient::cpu()? })
    }

    /// Compile an HLO-text file into an executable.
    pub fn compile_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }

    /// Build a [`Session`]: resolve every fixed parameter of `graph` from
    /// the given bundles and pre-upload them as device buffers.
    ///
    /// Resolution rules (see python/compile/aot.py):
    ///   "fp:<t>"          → weights bundle tensor <t>
    ///   "q:<layer>:<p>"   → quant bundle tensor "<layer>.<p>"
    ///   "tokens"          → per-call variable (i32)
    pub fn session(&self, arts: &ModelArtifacts, graph: &str,
                   quant: Option<&TensorBundle>) -> Result<Session> {
        let g = arts.graph(graph)?;
        let exe = self.compile_file(&g.file)?;
        let mut fixed = Vec::new();
        let mut token_idx = None;
        for (i, p) in g.params.iter().enumerate() {
            if p == "tokens" {
                token_idx = Some(i);
                fixed.push(None);
            } else if let Some(t) = p.strip_prefix("fp:") {
                let tensor = arts.weights.get(t)?;
                fixed.push(Some(self.upload_f32(tensor)?));
            } else if let Some(rest) = p.strip_prefix("q:") {
                let (layer, part) = rest.rsplit_once(':')
                    .ok_or_else(|| anyhow!("bad q param {p}"))?;
                let qb = quant.ok_or_else(|| anyhow!(
                    "graph {graph} needs a quant bundle (param {p})"))?;
                let tensor = qb.get(&format!("{layer}.{part}"))?;
                fixed.push(Some(self.upload_f32(tensor)?));
            } else {
                bail!("unknown param kind {p} in graph {graph}");
            }
        }
        let token_idx = token_idx.ok_or_else(|| anyhow!("graph {graph} has no tokens param"))?;
        Ok(Session {
            exe,
            client: self.client.clone(),
            fixed,
            token_idx,
            batch: g.batch,
            seq_len: arts.info.seq_len,
            vocab: arts.info.vocab,
            acts: g.acts.clone(),
        })
    }

    pub fn upload_f32(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(&t.data, &t.shape, None)?)
    }
}

/// A compiled graph with pre-uploaded fixed parameters.  `run` uploads only
/// the token block — this is the request hot path.
pub struct Session {
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
    fixed: Vec<Option<xla::PjRtBuffer>>,
    token_idx: usize,
    pub batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub acts: Vec<ActSlice>,
}

impl Session {
    /// Execute on a [batch, seq_len] token block; returns the flat f32
    /// output (logits or the concatenated acts vector).
    pub fn run(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        if tokens.len() != self.batch * self.seq_len {
            bail!("token block {} != {}x{}", tokens.len(), self.batch,
                  self.seq_len);
        }
        let tok_buf = self.client.buffer_from_host_buffer(
            tokens, &[self.batch, self.seq_len], None)?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.fixed.len());
        for (i, f) in self.fixed.iter().enumerate() {
            if i == self.token_idx {
                args.push(&tok_buf);
            } else {
                args.push(f.as_ref().expect("fixed param"));
            }
        }
        let out = self.exe.execute_b(&args)?;
        let lit = out[0][0].to_literal_sync()?.to_tuple1()?;
        Ok(lit.to_vec::<f32>()?)
    }
}

/// LogitsProvider over a Session (forward graphs).
pub struct SessionProvider {
    pub session: Session,
}

impl crate::eval::LogitsProvider for SessionProvider {
    fn batch(&self) -> usize {
        self.session.batch
    }
    fn seq_len(&self) -> usize {
        self.session.seq_len
    }
    fn vocab(&self) -> usize {
        self.session.vocab
    }
    fn logits(&mut self, tokens: &[i32]) -> Result<Vec<f32>, String> {
        self.session.run(tokens).map_err(|e| e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bundle_write_load_roundtrip() {
        let dir = std::env::temp_dir().join("lrc_bundle_test");
        let mut b = TensorBundle::default();
        b.insert("a", vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        b.insert("b.c", vec![4], vec![-1.0, 0.5, 0.0, 9.25]);
        b.write(&dir, &[("kind", Json::str("quant"))]).unwrap();
        let back = TensorBundle::load(&dir).unwrap();
        assert_eq!(back.order, vec!["a".to_string(), "b.c".to_string()]);
        assert_eq!(back.get("a").unwrap().shape, vec![2, 3]);
        assert_eq!(back.get("b.c").unwrap().data, vec![-1.0, 0.5, 0.0, 9.25]);
        assert_eq!(back.meta.unwrap().get("kind").unwrap().as_str(), Some("quant"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bundle_missing_tensor_errors() {
        let b = TensorBundle::default();
        assert!(b.get("nope").is_err());
    }

    #[test]
    fn bucket_graphs_filters_and_sorts() {
        let mk = |name: &str, batch: usize| GraphInfo {
            name: name.into(),
            file: PathBuf::new(),
            params: Vec::new(),
            batch,
            ranks: BTreeMap::new(),
            rank_pct: 0.0,
            a_group: None,
            weight_only: false,
            acts: Vec::new(),
        };
        let mut graphs = BTreeMap::new();
        for (n, b) in [("acts_b8", 8), ("acts_b1", 1), ("acts_b32", 32),
                       ("fwd_fp_b8", 8), ("acts_bx", 0)] {
            graphs.insert(n.to_string(), mk(n, b));
        }
        let arts = ModelArtifacts {
            dir: PathBuf::new(),
            weights: TensorBundle::default(),
            graphs,
            info: ModelInfo {
                name: "t".into(), d_model: 0, n_layers: 0, n_heads: 0,
                d_ff: 0, n_experts: 0, seq_len: 0, vocab: 0, param_count: 0,
            },
        };
        let acts = arts.bucket_graphs("acts");
        let got: Vec<(usize, &str)> =
            acts.iter().map(|(b, g)| (*b, g.name.as_str())).collect();
        // malformed "acts_bx" and other prefixes excluded; ascending order
        assert_eq!(got, vec![(1, "acts_b1"), (8, "acts_b8"),
                             (32, "acts_b32")]);
        assert_eq!(arts.bucket_graphs("fwd_fp").len(), 1);
        assert!(arts.bucket_graphs("nope").is_empty());
    }
}
