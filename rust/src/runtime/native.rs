//! Native serving path — the PJRT-free twin of the AOT `fwd_*` graphs.
//!
//! [`NativeModel`] re-implements the rotated model forward
//! (python/compile/model.py, `rotated=True`: RMSNorm pre-LN, causal MHA,
//! SwiGLU — dense or top-2 MoE — with the online FWHT before every
//! down-projection) directly on the crate's own kernels, so scoring
//! works on hosts where the PJRT engine is unavailable and, more
//! importantly, so the quantized layers run the **fused dequant-GEMM**
//! data path: every quantized linear is a
//! [`QuantizedLinear`] executing `Ŵ·Q_a(x) + U·(Vᵀx)` straight from the
//! bit-packed codes — the dense weight matrix is never materialized at
//! serving time.
//!
//! Numerics: fp linears run the canonical f32 GEMM, quantized linears
//! the oracle-locked fused kernel; norms/softmax/SiLU are plain f32 like
//! the HLO.  The native forward is architecture-equivalent to the AOT
//! graphs, not bit-identical to them (XLA fuses and reorders); the
//! bit-level contract lives one layer down, between
//! [`QuantizedLinear::forward`] and its naive reference.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::linalg::{fwht_f32, matmul_nt_f32_into, workspace, Mat};
use crate::quant::dequant::QuantizedLinear;
use crate::quant::pack::PackedInts;
use crate::quant::weight_scales;

use super::{GraphInfo, ModelArtifacts, ModelInfo, TensorBundle};

/// Matches python `ModelConfig.rms_eps` (not exported in the manifest).
const RMS_EPS: f32 = 1e-5;
/// int4 activation grid max (python kernels/ref.py INT4_MAXQ).
const INT4_MAXQ: f32 = 7.0;

/// One linear layer of the native forward: fp weights on the canonical
/// f32 GEMM, or the fused dequant-GEMM over packed codes.
enum Linear {
    Dense { w: Vec<f32>, dout: usize, din: usize },
    Quant { q: QuantizedLinear, clip: f32 },
}

impl Linear {
    fn dout(&self) -> usize {
        match self {
            Linear::Dense { dout, .. } => *dout,
            Linear::Quant { q, .. } => q.dout(),
        }
    }

    fn din(&self) -> usize {
        match self {
            Linear::Dense { din, .. } => *din,
            Linear::Quant { q, .. } => q.din(),
        }
    }

    /// `y = x·Wᵀ` (`[m, din] → [m, dout]`).  On the quantized path the
    /// activations are int4-quantized on the fly (per-token or grouped,
    /// python `_w4a4_kernel` math) while the low-rank correction reads
    /// the unquantized rows — unless `weight_only` (Table 3 mode).
    fn apply(&self, x: &[f32], m: usize, a_group: Option<usize>,
             weight_only: bool, out: &mut Vec<f32>) {
        match self {
            Linear::Dense { w, dout, din } => {
                matmul_nt_f32_into(x, m, *din, w, *dout, out);
            }
            Linear::Quant { q, clip } => {
                if weight_only {
                    q.forward_into(x, m, out);
                } else {
                    let mut xq = workspace::take_zeroed_f32(x.len());
                    act_quantize_rows(x, m, q.din(), *clip, a_group,
                                      &mut xq);
                    q.forward_split_into(&xq, x, m, out);
                    workspace::put_f32(xq);
                }
            }
        }
    }
}

/// On-the-fly int4 activation quantization over row-major `[m, d]`:
/// per-token (or per-group) scale `clip·max|x|/7 + 1e-12`, round, clamp
/// to `[-8, 7]`, back to the grid — f32 like the Pallas kernel.
fn act_quantize_rows(x: &[f32], m: usize, d: usize, clip: f32,
                     group: Option<usize>, out: &mut [f32]) {
    let g = group.unwrap_or(d.max(1));
    for i in 0..m {
        let row = &x[i * d..(i + 1) * d];
        let orow = &mut out[i * d..(i + 1) * d];
        let mut j = 0;
        while j < d {
            let hi = (j + g).min(d);
            let amax = row[j..hi].iter().fold(0.0_f32, |a, &v| a.max(v.abs()));
            let s = clip * amax / INT4_MAXQ + 1e-12;
            for k in j..hi {
                let q = (row[k] / s).round().clamp(-(INT4_MAXQ + 1.0),
                                                  INT4_MAXQ);
                orow[k] = q * s;
            }
            j = hi;
        }
    }
}

struct Expert {
    gate: Linear,
    up: Linear,
    down: Linear,
}

enum Mlp {
    Dense(Expert),
    /// router `[n_experts, d]` + dense-simulated top-2 experts
    Moe { router: Vec<f32>, experts: Vec<Expert> },
}

struct Block {
    ln1: Vec<f32>,
    ln2: Vec<f32>,
    wq: Linear,
    wk: Linear,
    wv: Linear,
    wo: Linear,
    mlp: Mlp,
}

/// The assembled native model: fp tensors from the weights bundle,
/// quantized layers from an optional quant bundle (any layer present as
/// `<name>.wq` there serves fused; the rest stay fp — same override rule
/// as the AOT quantized graphs).
pub struct NativeModel {
    pub info: ModelInfo,
    tok_emb: Vec<f32>,
    pos_emb: Vec<f32>,
    blocks: Vec<Block>,
    ln_f: Vec<f32>,
    head: Linear,
    a_group: Option<usize>,
    weight_only: bool,
}

impl NativeModel {
    /// Build from a model directory's artifacts.  `quant` supplies the
    /// (wq, u, v, clip) tensors per layer; `graph` (when given) carries
    /// the activation-quant setting of the matching AOT graph — its HLO
    /// file is **not** read.  `w_bits` is the packing width for the grid
    /// weights (4 for the paper's W4A4 bundles; any width whose grid
    /// contains the values works — the codes are recovered from the
    /// scales).
    pub fn new(arts: &ModelArtifacts, quant: Option<&TensorBundle>,
               graph: Option<&GraphInfo>, w_bits: u32)
               -> Result<NativeModel> {
        let info = arts.info.clone();
        if info.d_model == 0 || info.n_layers == 0 || info.n_heads == 0 {
            bail!("model {} has no architecture config in its manifest",
                  info.name);
        }
        if !info.d_ff.is_power_of_two() {
            bail!("native forward needs power-of-two d_ff for the online \
                   FWHT, got {}", info.d_ff);
        }
        let dense = |name: &str| -> Result<Linear> {
            let t = arts.weights.get(name)?;
            if t.shape.len() != 2 {
                bail!("tensor {name} is not a matrix: {:?}", t.shape);
            }
            Ok(Linear::Dense {
                w: t.data.clone(),
                dout: t.shape[0],
                din: t.shape[1],
            })
        };
        let linear = |name: &str| -> Result<Linear> {
            if let Some(qb) = quant {
                if let Ok(wq) = qb.get(&format!("{name}.wq")) {
                    let (dout, din) = (wq.shape[0], wq.shape[1]);
                    let wq = Mat::from_f32(dout, din, &wq.data);
                    let scales = weight_scales(&wq, w_bits, None);
                    let packed = PackedInts::pack(&wq, &scales, w_bits, None);
                    let fac = |part: &str| {
                        qb.get(&format!("{name}.{part}")).ok()
                          .map(|t| (t.shape[1], t.data.clone()))
                    };
                    let clip = qb.get(&format!("{name}.clip"))
                                 .map(|t| t.data[0]).unwrap_or(1.0);
                    let q = QuantizedLinear::new(packed, fac("u"), fac("v"));
                    return Ok(Linear::Quant { q, clip });
                }
            }
            dense(name)
        };
        let vecp = |name: &str| -> Result<Vec<f32>> {
            Ok(arts.weights.get(name)?.data.clone())
        };

        let mut blocks = Vec::with_capacity(info.n_layers);
        for i in 0..info.n_layers {
            let mlp = if info.n_experts == 0 {
                Mlp::Dense(Expert {
                    gate: linear(&format!("blk{i}.wgate"))?,
                    up: linear(&format!("blk{i}.wup"))?,
                    down: linear(&format!("blk{i}.wdown"))?,
                })
            } else {
                let mut experts = Vec::with_capacity(info.n_experts);
                for e in 0..info.n_experts {
                    experts.push(Expert {
                        gate: linear(&format!("blk{i}.e{e}.wgate"))?,
                        up: linear(&format!("blk{i}.e{e}.wup"))?,
                        down: linear(&format!("blk{i}.e{e}.wdown"))?,
                    });
                }
                Mlp::Moe { router: vecp(&format!("blk{i}.router"))?, experts }
            };
            blocks.push(Block {
                ln1: vecp(&format!("blk{i}.ln1"))?,
                ln2: vecp(&format!("blk{i}.ln2"))?,
                wq: linear(&format!("blk{i}.wq"))?,
                wk: linear(&format!("blk{i}.wk"))?,
                wv: linear(&format!("blk{i}.wv"))?,
                wo: linear(&format!("blk{i}.wo"))?,
                mlp,
            });
        }
        Ok(NativeModel {
            tok_emb: vecp("tok_emb")?,
            pos_emb: vecp("pos_emb")?,
            blocks,
            ln_f: vecp("ln_f")?,
            head: dense("head")?,
            a_group: graph.and_then(|g| g.a_group),
            weight_only: graph.map(|g| g.weight_only).unwrap_or(false),
            info,
        })
    }

    /// Serving-form bytes of the quantized layers (packed codes + scales
    /// + factors) — what the fused path actually streams.
    pub fn quant_bytes(&self) -> usize {
        let lin = |l: &Linear| match l {
            Linear::Quant { q, .. } => q.size_bytes(),
            Linear::Dense { .. } => 0,
        };
        let exp = |e: &Expert| lin(&e.gate) + lin(&e.up) + lin(&e.down);
        self.blocks.iter().map(|b| {
            lin(&b.wq) + lin(&b.wk) + lin(&b.wv) + lin(&b.wo)
                + match &b.mlp {
                    Mlp::Dense(e) => exp(e),
                    Mlp::Moe { experts, .. } =>
                        experts.iter().map(exp).sum(),
                }
        }).sum()
    }

    /// Full forward on a `[batch, seq_len]` token block; returns flat
    /// `[batch·seq_len, vocab]` logits.
    pub fn logits(&self, tokens: &[i32], batch: usize) -> Result<Vec<f32>> {
        let (t, d) = (self.info.seq_len, self.info.d_model);
        if tokens.len() != batch * t {
            bail!("token block {} != {batch}x{t}", tokens.len());
        }
        let n = batch * t;
        // x = tok_emb[tokens] + pos_emb
        let mut x = vec![0.0_f32; n * d];
        for (i, &tok) in tokens.iter().enumerate() {
            let tok = usize::try_from(tok)
                .ok().filter(|&v| v < self.info.vocab)
                .ok_or_else(|| anyhow!("token {tok} outside vocab {}",
                                       self.info.vocab))?;
            let (e, p) = (&self.tok_emb[tok * d..(tok + 1) * d],
                          &self.pos_emb[(i % t) * d..(i % t + 1) * d]);
            for c in 0..d {
                x[i * d + c] = e[c] + p[c];
            }
        }

        let mut h = vec![0.0_f32; n * d];
        let mut y = Vec::new();
        for blk in &self.blocks {
            // h = rmsnorm(x, ln1);  attn = MHA(q, k, v);  x += wo(attn)
            rmsnorm_rows(&x, d, &blk.ln1, &mut h);
            let (mut q, mut k, mut v) = (Vec::new(), Vec::new(), Vec::new());
            self.lin(&blk.wq, &h, n, &mut q);
            self.lin(&blk.wk, &h, n, &mut k);
            self.lin(&blk.wv, &h, n, &mut v);
            let attn = attention(&q, &k, &v, batch, t, self.info.n_heads, d);
            self.lin(&blk.wo, &attn, n, &mut y);
            add_into(&mut x, &y);

            // h = rmsnorm(x, ln2);  x += mlp(h)
            rmsnorm_rows(&x, d, &blk.ln2, &mut h);
            match &blk.mlp {
                Mlp::Dense(e) => {
                    self.expert_forward(e, &h, n, &mut y);
                    add_into(&mut x, &y);
                }
                Mlp::Moe { router, experts } => {
                    let ne = experts.len();
                    let mut rl = Vec::new();
                    matmul_nt_f32_into(&h, n, d, router, ne, &mut rl);
                    let wts = top2_gates(&rl, n, ne);
                    for (e, exp) in experts.iter().enumerate() {
                        self.expert_forward(exp, &h, n, &mut y);
                        for i in 0..n {
                            let w = wts[i * ne + e];
                            if w != 0.0 {
                                for c in 0..d {
                                    x[i * d + c] += w * y[i * d + c];
                                }
                            }
                        }
                    }
                }
            }
        }

        rmsnorm_rows(&x, d, &self.ln_f, &mut h);
        let mut logits = Vec::new();
        self.lin(&self.head, &h, n, &mut logits);
        Ok(logits)
    }

    fn lin(&self, l: &Linear, x: &[f32], m: usize, out: &mut Vec<f32>) {
        l.apply(x, m, self.a_group, self.weight_only, out);
    }

    /// `down(fwht(silu(gate(h)) · up(h)))` — one SwiGLU branch with the
    /// online Hadamard of the rotated model before the down-projection.
    fn expert_forward(&self, e: &Expert, h: &[f32], n: usize,
                      out: &mut Vec<f32>) {
        let ff = e.gate.dout();
        debug_assert_eq!(e.down.din(), ff);
        let mut gate = Vec::new();
        let mut up = Vec::new();
        self.lin(&e.gate, h, n, &mut gate);
        self.lin(&e.up, h, n, &mut up);
        for (g, u) in gate.iter_mut().zip(&up) {
            let s = *g / (1.0 + (-*g).exp()); // silu
            *g = s * u;
        }
        for row in gate.chunks_exact_mut(ff) {
            fwht_f32(row);
        }
        self.lin(&e.down, &gate, n, out);
    }
}

/// `y[i] = x[i] · rsqrt(mean(x[i]²) + eps) · scale` per length-d row.
fn rmsnorm_rows(x: &[f32], d: usize, scale: &[f32], out: &mut [f32]) {
    for (row, orow) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        let ss: f32 = row.iter().map(|&v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (ss + RMS_EPS).sqrt();
        for c in 0..d {
            orow[c] = row[c] * r * scale[c];
        }
    }
}

fn add_into(x: &mut [f32], y: &[f32]) {
    for (a, b) in x.iter_mut().zip(y) {
        *a += b;
    }
}

/// Causal multi-head attention over flat `[batch·t, d]` q/k/v.
fn attention(q: &[f32], k: &[f32], v: &[f32], batch: usize, t: usize,
             heads: usize, d: usize) -> Vec<f32> {
    let hd = d / heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = vec![0.0_f32; batch * t * d];
    let mut p = vec![0.0_f32; t];
    for b in 0..batch {
        for hh in 0..heads {
            let off = |tt: usize| (b * t + tt) * d + hh * hd;
            for tq in 0..t {
                // causal scores, softmax over tk ≤ tq
                let mut mx = f32::NEG_INFINITY;
                for (tk, pk) in p.iter_mut().enumerate().take(tq + 1) {
                    let (qo, ko) = (off(tq), off(tk));
                    let mut s = 0.0_f32;
                    for i in 0..hd {
                        s += q[qo + i] * k[ko + i];
                    }
                    let s = s * scale;
                    *pk = s;
                    mx = mx.max(s);
                }
                let mut sum = 0.0_f32;
                for pk in p.iter_mut().take(tq + 1) {
                    *pk = (*pk - mx).exp();
                    sum += *pk;
                }
                let oo = off(tq);
                for tk in 0..=tq {
                    let w = p[tk] / sum;
                    let vo = off(tk);
                    for i in 0..hd {
                        out[oo + i] += w * v[vo + i];
                    }
                }
            }
        }
    }
    out
}

/// Top-2 router gates per token (python's argmax+mask formulation):
/// softmax over the two best logits, zero elsewhere.  Returns flat
/// `[n, n_experts]` weights.
fn top2_gates(rl: &[f32], n: usize, ne: usize) -> Vec<f32> {
    let mut wts = vec![0.0_f32; n * ne];
    for i in 0..n {
        let row = &rl[i * ne..(i + 1) * ne];
        let argmax = |skip: Option<usize>| {
            let mut best = usize::MAX;
            let mut bv = f32::NEG_INFINITY;
            for (e, &val) in row.iter().enumerate() {
                if Some(e) != skip && val > bv {
                    best = e;
                    bv = val;
                }
            }
            (best, bv)
        };
        let (e1, v1) = argmax(None);
        let (e2, v2) = argmax(Some(e1));
        let m = v1.max(v2);
        let (a, b) = ((v1 - m).exp(), (v2 - m).exp());
        wts[i * ne + e1] = a / (a + b);
        wts[i * ne + e2] = b / (a + b);
    }
    wts
}

/// [`crate::eval::LogitsProvider`] over a shared [`NativeModel`] — the
/// engine-free counterpart of [`super::SessionProvider`].
pub struct NativeProvider {
    pub model: Arc<NativeModel>,
    pub batch: usize,
}

impl crate::eval::LogitsProvider for NativeProvider {
    fn batch(&self) -> usize {
        self.batch
    }
    fn seq_len(&self) -> usize {
        self.model.info.seq_len
    }
    fn vocab(&self) -> usize {
        self.model.info.vocab
    }
    fn logits(&mut self, tokens: &[i32]) -> Result<Vec<f32>, String> {
        self.model.logits(tokens, self.batch).map_err(|e| e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn_quantize;
    use crate::rng::Rng;
    use std::collections::BTreeMap;
    use std::path::PathBuf;

    fn tiny_info(n_experts: usize) -> ModelInfo {
        ModelInfo {
            name: "tiny".into(),
            d_model: 8,
            n_layers: 1,
            n_heads: 2,
            d_ff: 16,
            n_experts,
            seq_len: 4,
            vocab: 16,
            param_count: 0,
        }
    }

    fn mat(rng: &mut Rng, w: &mut TensorBundle, name: &str, r: usize,
           c: usize, s: f64) {
        let data: Vec<f32> = rng.normal_vec(r * c).iter()
            .map(|&v| (v * s) as f32).collect();
        w.insert(name, vec![r, c], data);
    }

    fn tiny_arts(n_experts: usize, seed: u64) -> ModelArtifacts {
        let info = tiny_info(n_experts);
        let mut rng = Rng::new(seed);
        let mut weights = TensorBundle::default();
        let (d, ff, v, t) = (info.d_model, info.d_ff, info.vocab,
                             info.seq_len);
        mat(&mut rng, &mut weights, "tok_emb", v, d, 0.5);
        mat(&mut rng, &mut weights, "pos_emb", t, d, 0.5);
        for i in 0..info.n_layers {
            weights.insert(&format!("blk{i}.ln1"), vec![d], vec![1.0; d]);
            weights.insert(&format!("blk{i}.ln2"), vec![d], vec![1.0; d]);
            for nm in ["wq", "wk", "wv", "wo"] {
                mat(&mut rng, &mut weights, &format!("blk{i}.{nm}"), d, d,
                    0.35);
            }
            if n_experts == 0 {
                for (nm, r, c) in [("wgate", ff, d), ("wup", ff, d),
                                   ("wdown", d, ff)] {
                    mat(&mut rng, &mut weights, &format!("blk{i}.{nm}"),
                        r, c, 0.35);
                }
            } else {
                mat(&mut rng, &mut weights, &format!("blk{i}.router"),
                    n_experts, d, 0.35);
                for e in 0..n_experts {
                    for (nm, r, c) in [("wgate", ff, d), ("wup", ff, d),
                                       ("wdown", d, ff)] {
                        mat(&mut rng, &mut weights,
                            &format!("blk{i}.e{e}.{nm}"), r, c, 0.35);
                    }
                }
            }
        }
        weights.insert("ln_f", vec![d], vec![1.0; d]);
        mat(&mut rng, &mut weights, "head", v, d, 0.5);
        ModelArtifacts {
            dir: PathBuf::new(),
            weights,
            graphs: BTreeMap::new(),
            info,
        }
    }

    /// Weight-only 8-bit quant bundle: every quantized layer's wq is the
    /// int8 RTN grid of the fp weight, rank 0.
    fn quant_bundle_int8(arts: &ModelArtifacts) -> TensorBundle {
        let mut qb = TensorBundle::default();
        for name in crate::pipeline::quantized_layer_names(&arts.info) {
            let t = arts.weights.get(&name).unwrap();
            let w = Mat::from_f32(t.shape[0], t.shape[1], &t.data);
            let wq = rtn_quantize(&w, 8, None);
            qb.insert(&format!("{name}.wq"), t.shape.clone(), wq.to_f32());
            qb.insert(&format!("{name}.clip"), vec![1], vec![1.0]);
        }
        qb
    }

    fn toks(arts: &ModelArtifacts, batch: usize, seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(seed);
        (0..batch * arts.info.seq_len)
            .map(|_| (rng.normal_vec(1)[0].abs() * 7.0) as i32 % 16)
            .collect()
    }

    #[test]
    fn fp_forward_shapes_and_determinism() {
        for ne in [0usize, 3] {
            let arts = tiny_arts(ne, 5);
            let m = NativeModel::new(&arts, None, None, 4).unwrap();
            let tokens = toks(&arts, 2, 9);
            let l1 = m.logits(&tokens, 2).unwrap();
            assert_eq!(l1.len(), 2 * 4 * 16);
            assert!(l1.iter().all(|v| v.is_finite()));
            assert_eq!(l1, m.logits(&tokens, 2).unwrap(), "experts={ne}");
            assert_eq!(m.quant_bytes(), 0);
        }
    }

    #[test]
    fn int8_weight_only_tracks_fp() {
        let arts = tiny_arts(0, 6);
        let fp = NativeModel::new(&arts, None, None, 4).unwrap();
        let qb = quant_bundle_int8(&arts);
        let g = GraphInfo {
            name: "fwd".into(),
            file: PathBuf::new(),
            params: Vec::new(),
            batch: 2,
            ranks: BTreeMap::new(),
            rank_pct: 0.0,
            a_group: None,
            weight_only: true,
            acts: Vec::new(),
        };
        let qm = NativeModel::new(&arts, Some(&qb), Some(&g), 8).unwrap();
        assert!(qm.quant_bytes() > 0);
        let tokens = toks(&arts, 2, 3);
        let lf = fp.logits(&tokens, 2).unwrap();
        let lq = qm.logits(&tokens, 2).unwrap();
        let scale = lf.iter().fold(0.0_f32, |a, &v| a.max(v.abs()));
        let diff = lf.iter().zip(&lq)
            .fold(0.0_f32, |a, (&x, &y)| a.max((x - y).abs()));
        // int8 weight-only is a fine grid — logits track fp closely
        assert!(diff < 0.05 * (scale + 1.0), "diff {diff} scale {scale}");
    }

    #[test]
    fn w4a4_path_runs_and_is_finite() {
        let arts = tiny_arts(2, 7);
        let qb = quant_bundle_int8(&arts);
        // act-quantized (non weight-only), grouped
        let g = GraphInfo {
            name: "fwd".into(),
            file: PathBuf::new(),
            params: Vec::new(),
            batch: 1,
            ranks: BTreeMap::new(),
            rank_pct: 0.0,
            a_group: Some(4),
            weight_only: false,
            acts: Vec::new(),
        };
        let qm = NativeModel::new(&arts, Some(&qb), Some(&g), 8).unwrap();
        let tokens = toks(&arts, 1, 1);
        let l = qm.logits(&tokens, 1).unwrap();
        assert_eq!(l.len(), 4 * 16);
        assert!(l.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn act_quant_lands_on_the_grid() {
        let mut rng = Rng::new(31);
        let x: Vec<f32> = rng.normal_vec(3 * 20).iter()
            .map(|&v| v as f32).collect();
        for group in [None, Some(5)] {
            let mut out = vec![0.0_f32; x.len()];
            act_quantize_rows(&x, 3, 20, 0.9, group, &mut out);
            let g = group.unwrap_or(20);
            for i in 0..3 {
                let mut j = 0;
                while j < 20 {
                    let hi = (j + g).min(20);
                    let amax = x[i * 20 + j..i * 20 + hi].iter()
                        .fold(0.0_f32, |a, &v| a.max(v.abs()));
                    let s = 0.9 * amax / INT4_MAXQ + 1e-12;
                    for k in j..hi {
                        let q = out[i * 20 + k] / s;
                        assert!((q - q.round()).abs() < 1e-4);
                        assert!((-8.0..=7.0).contains(&q.round()));
                    }
                    j = hi;
                }
            }
        }
    }

    #[test]
    fn top2_gates_sum_to_one_on_the_two_best() {
        let rl = vec![0.1_f32, 2.0, -1.0, 1.5, 9.0, 9.0, 9.0, 9.0];
        let w = top2_gates(&rl, 2, 4);
        for i in 0..2 {
            let row = &w[i * 4..(i + 1) * 4];
            let nz: Vec<_> = row.iter().filter(|&&v| v > 0.0).collect();
            assert_eq!(nz.len(), 2);
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        }
        // token 0: experts 1 (2.0) and 3 (1.5) win
        assert!(w[1] > w[3] && w[3] > 0.0);
        assert_eq!(w[0], 0.0);
        assert_eq!(w[2], 0.0);
    }

    #[test]
    fn token_out_of_vocab_errors() {
        let arts = tiny_arts(0, 8);
        let m = NativeModel::new(&arts, None, None, 4).unwrap();
        let mut tokens = toks(&arts, 1, 2);
        tokens[1] = 99;
        assert!(m.logits(&tokens, 1).is_err());
        tokens[1] = -1;
        assert!(m.logits(&tokens, 1).is_err());
    }

    #[test]
    fn provider_wraps_the_model() {
        use crate::eval::LogitsProvider;
        let arts = tiny_arts(0, 9);
        let m = Arc::new(NativeModel::new(&arts, None, None, 4).unwrap());
        let mut p = NativeProvider { model: m.clone(), batch: 2 };
        assert_eq!(p.batch(), 2);
        assert_eq!(p.seq_len(), 4);
        assert_eq!(p.vocab(), 16);
        let tokens = toks(&arts, 2, 4);
        assert_eq!(p.logits(&tokens).unwrap(),
                   m.logits(&tokens, 2).unwrap());
    }

    #[test]
    fn dff_not_power_of_two_is_rejected() {
        let mut arts = tiny_arts(0, 10);
        arts.info.d_ff = 12;
        let err = NativeModel::new(&arts, None, None, 4)
            .err().unwrap().to_string();
        assert!(err.contains("power-of-two"), "{err}");
    }
}
