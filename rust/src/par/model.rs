//! Exhaustive-interleaving model checker for the pool's job-board
//! protocol.
//!
//! [`super`]'s liveness and exclusivity arguments (`Workers::run`,
//! `worker_loop`) are prose in comments: "a notify_one can only be lost
//! when no worker is parked", "the claim budget is always fully
//! consumed before `active` can reach zero", and so on.  This module
//! turns those arguments into a small state machine and *enumerates
//! every schedule* of it: each Mutex critical section in the real code
//! becomes one atomic transition, condvar waits become waitset
//! membership (no spurious wakeups are modeled, so every wakeup in the
//! model is one the protocol itself caused), and `notify_one` branches
//! nondeterministically over the parked workers.  A memoized DFS then
//! visits every reachable interleaving for ≤3 workers × ≤3 epochs and
//! checks, at each transition:
//!
//! * **termination / no lost wakeup** — every non-terminal state has an
//!   enabled transition (a lost wakeup shows up as a deadlock state);
//! * **exactly-`extra` claimants** — each epoch completes with
//!   `min(items-1, workers)` claims, no more, no fewer;
//! * **claim-budget conservation** — `claims == 0` whenever `active`
//!   reaches zero, and `active` never underflows;
//! * **panic propagation** — a panicking claimant (or submitter body)
//!   is observed by exactly that epoch's completion;
//! * **bounded wakeups** — an epoch notifies at most `extra` parked
//!   workers (surplus workers never leave the condvar), and a woken
//!   worker that re-parks must have found the claim budget already
//!   drained by an unparked "roaming" worker.  The checker *found* that
//!   raced wakeup interleaving (a roaming worker that just finished the
//!   previous epoch re-checks the board before a notified worker wakes,
//!   and steals the claim), which is why the property is stated this
//!   way and not as the naive "zero idle wakeups": the strong form is
//!   falsified by a real, benign schedule — see
//!   `tests/pool_model.rs::raced_wakeup_interleaving_exists`.
//!
//! [`Variant`] knobs re-introduce historical bug shapes (single wakeup
//! per epoch, no claim budget, no re-entrancy guard) so the test suite
//! can prove the checker actually detects protocol violations rather
//! than vacuously passing.
//!
//! The scoped backend (`Pool::scoped` / `scoped_map`) shares no board —
//! fresh threads drain a cursor — so its model ([`explore_scoped`])
//! only has to show every chunk is claimed exactly once and the drain
//! terminates under all schedules.

use std::collections::HashSet;

/// Model capacity: the checker covers pools with up to this many
/// *parked* workers (a pool of `n` threads parks `n - 1`).
pub const MAX_W: usize = 3;

/// Who panics during an epoch, if anyone.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Panicker {
    None,
    /// the submitting thread's own share of the body panics
    Submitter,
    /// the k-th claimant (in claim order) panics; requires `k < extra`
    Claimant(u8),
}

/// One `Workers::run` call: `items` work items, so
/// `extra = min(items - 1, workers)` parked workers participate.
#[derive(Clone, Copy, Debug)]
pub struct EpochSpec {
    pub items: u8,
    pub panicker: Panicker,
    /// claimant bodies perform a nested pool dispatch (exercises the
    /// IN_POOL re-entrancy guard: inline when faithful, deadlock when
    /// the guard is disabled via [`Variant`])
    pub nested: bool,
}

impl EpochSpec {
    pub fn plain(items: u8) -> Self {
        EpochSpec { items, panicker: Panicker::None, nested: false }
    }
}

/// Protocol variant knobs.  `faithful()` models the shipped code; each
/// `false` re-introduces a bug shape the tests prove the checker catches.
#[derive(Clone, Copy, Debug)]
pub struct Variant {
    /// true: publish wakes `extra` workers (notify_all when
    /// `extra == workers`); false: a single notify_one per epoch — the
    /// lost-wakeup bug shape
    pub notify_per_claim: bool,
    /// true: `claims = extra`; false: `claims = workers` — the
    /// over-claim bug shape (surplus claimants underflow `active`)
    pub claim_budget: bool,
    /// true: nested dispatch from a claimant runs inline; false: it
    /// tries to publish on the occupied board and blocks forever
    pub reentry_guard: bool,
}

impl Variant {
    pub fn faithful() -> Self {
        Variant { notify_per_claim: true, claim_budget: true, reentry_guard: true }
    }
}

#[derive(Clone, Debug)]
pub struct Scenario {
    /// parked workers (pool size minus the submitting thread), 1..=3
    pub workers: usize,
    pub epochs: Vec<EpochSpec>,
    pub variant: Variant,
    /// accept the benign claim-steal raced wakeup (see module docs);
    /// single-epoch scenarios that publish before any worker can roam
    /// may set this false to assert the strong zero-idle-wakeup form
    pub allow_raced_wakeups: bool,
}

impl Scenario {
    pub fn faithful(workers: usize, epochs: Vec<EpochSpec>) -> Self {
        Scenario {
            workers,
            epochs,
            variant: Variant::faithful(),
            allow_raced_wakeups: true,
        }
    }
}

/// Where a worker thread is, at critical-section granularity.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
enum Loc {
    /// about to run the board-check critical section
    Check,
    /// in the `work` condvar waitset; runnable only after a notify
    Parked,
    /// claimed the epoch; body + finish section still pending
    Run,
    /// blocked forever (buggy-variant nested dispatch)
    Stuck,
    /// observed shutdown and returned
    Exit,
}

/// Submitter program counter.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
enum SLoc {
    Publish,
    Body,
    Complete,
    Shutdown,
    Join,
    Done,
}

#[derive(Clone, PartialEq, Eq, Hash)]
struct State {
    // job board (mirrors par::JobState)
    epoch: u8,
    job: bool,
    active: u8,
    claims: u8,
    panicked: bool,
    shutdown: bool,
    // workers
    loc: [Loc; MAX_W],
    seen: [u8; MAX_W],
    woken: [bool; MAX_W],
    will_panic: [bool; MAX_W],
    // submitter
    ep_idx: u8,
    sloc: SLoc,
    s_waiting: bool,
    local_panic: bool,
    // per-epoch accounting for the exactly-`extra` property
    claimed: u8,
}

#[derive(Debug, Default, Clone)]
pub struct Stats {
    /// distinct states visited
    pub states: usize,
    /// transitions taken (edges, counting re-entries to visited states)
    pub transitions: usize,
    /// distinct terminal states reached
    pub terminals: usize,
    /// benign raced wakeups observed (claim stolen by a roaming worker)
    pub raced_wakeups: usize,
}

/// A property violation plus the exact schedule that produced it.
#[derive(Debug)]
pub struct Violation {
    pub message: String,
    pub trace: Vec<String>,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "{}", self.message)?;
        for (i, t) in self.trace.iter().enumerate() {
            writeln!(f, "  {i:3}: {t}")?;
        }
        Ok(())
    }
}

fn extra_of(sc: &Scenario, idx: usize) -> u8 {
    (sc.workers as u8).min(sc.epochs[idx].items.saturating_sub(1))
}

fn start_sloc(sc: &Scenario, idx: usize) -> SLoc {
    if idx >= sc.epochs.len() {
        SLoc::Shutdown
    } else if extra_of(sc, idx) == 0 {
        SLoc::Body
    } else {
        SLoc::Publish
    }
}

/// All k-subsets of `items` (the nondeterministic notify_one targets).
fn combinations(items: &[usize], k: usize) -> Vec<Vec<usize>> {
    if k == 0 {
        return vec![Vec::new()];
    }
    if items.len() < k {
        return Vec::new();
    }
    let mut out = Vec::new();
    for (i, &first) in items.iter().enumerate() {
        for mut rest in combinations(&items[i + 1..], k - 1) {
            rest.insert(0, first);
            out.push(rest);
        }
    }
    out
}

/// Enumerate every reachable schedule of `sc` and check all properties.
pub fn explore(sc: &Scenario) -> Result<Stats, Violation> {
    assert!(
        (1..=MAX_W).contains(&sc.workers),
        "model covers 1..={MAX_W} workers"
    );
    for (i, e) in sc.epochs.iter().enumerate() {
        assert!(e.items >= 1, "epoch {i}: items must be >= 1");
        if let Panicker::Claimant(k) = e.panicker {
            assert!(
                k < extra_of(sc, i),
                "epoch {i}: panicking claimant {k} never claims (extra = {})",
                extra_of(sc, i)
            );
        }
        if e.nested {
            assert!(extra_of(sc, i) >= 1, "epoch {i}: nested needs a claimant");
        }
    }

    let mut init = State {
        epoch: 0,
        job: false,
        active: 0,
        claims: 0,
        panicked: false,
        shutdown: false,
        loc: [Loc::Exit; MAX_W],
        seen: [0; MAX_W],
        woken: [false; MAX_W],
        will_panic: [false; MAX_W],
        ep_idx: 0,
        sloc: start_sloc(sc, 0),
        s_waiting: false,
        local_panic: false,
        claimed: 0,
    };
    // live workers start mid-loop (at the board check), which is what
    // exposes the publish-before-first-park startup races
    for w in 0..sc.workers {
        init.loc[w] = Loc::Check;
    }

    let mut stats = Stats::default();
    let mut visited: HashSet<State> = HashSet::new();
    let mut path: Vec<String> = Vec::new();
    visited.insert(init.clone());
    dfs(sc, &init, &mut visited, &mut path, &mut stats)?;
    Ok(stats)
}

fn dfs(
    sc: &Scenario,
    st: &State,
    visited: &mut HashSet<State>,
    path: &mut Vec<String>,
    stats: &mut Stats,
) -> Result<(), Violation> {
    stats.states += 1;
    if st.sloc == SLoc::Done {
        // terminal invariants: clean board, everyone gone
        let clean = !st.job
            && st.active == 0
            && st.claims == 0
            && !st.s_waiting
            && !st.panicked
            && (0..sc.workers).all(|w| st.loc[w] == Loc::Exit);
        if !clean {
            return Err(Violation {
                message: "terminal state with a dirty board".into(),
                trace: path.clone(),
            });
        }
        stats.terminals += 1;
        return Ok(());
    }
    let succs = successors(sc, st, stats).map_err(|message| Violation {
        message,
        trace: path.clone(),
    })?;
    if succs.is_empty() {
        return Err(Violation {
            message: "deadlock: no enabled transition (lost wakeup)".into(),
            trace: path.clone(),
        });
    }
    for (label, s2) in succs {
        stats.transitions += 1;
        if visited.insert(s2.clone()) {
            path.push(label);
            dfs(sc, &s2, visited, path, stats)?;
            path.pop();
        }
    }
    Ok(())
}

/// Enabled transitions from `st`; `Err` is a property violated *by*
/// taking a mandatory step (e.g. an assertion inside a critical
/// section).
#[allow(clippy::too_many_lines)]
fn successors(
    sc: &Scenario,
    st: &State,
    stats: &mut Stats,
) -> Result<Vec<(String, State)>, String> {
    let w_count = sc.workers;
    let mut out: Vec<(String, State)> = Vec::new();

    // ---- submitter ----
    match st.sloc {
        SLoc::Publish => {
            let ex = extra_of(sc, st.ep_idx as usize);
            if st.job || st.active != 0 || st.claims != 0 {
                return Err(format!(
                    "board not clean at publish (job={} active={} claims={})",
                    st.job, st.active, st.claims
                ));
            }
            let mut base = st.clone();
            base.epoch += 1;
            base.job = true;
            base.active = ex;
            base.claims = if sc.variant.claim_budget { ex } else { w_count as u8 };
            base.panicked = false;
            base.claimed = 0;
            base.sloc = SLoc::Body;
            let parked: Vec<usize> =
                (0..w_count).filter(|&w| st.loc[w] == Loc::Parked).collect();
            if sc.variant.notify_per_claim && ex as usize == w_count {
                // full epoch: notify_all
                let mut s2 = base.clone();
                for &w in &parked {
                    s2.loc[w] = Loc::Check;
                    s2.woken[w] = true;
                }
                out.push((format!("S:publish e{} notify_all", base.epoch), s2));
            } else {
                // `extra` targeted notify_ones (1 in the buggy variant):
                // each wakes one *currently parked* worker — extras are
                // lost, which is safe exactly because roaming workers
                // re-check before parking; the checker verifies that.
                let n_notify = if sc.variant.notify_per_claim { ex as usize } else { 1 };
                let k = n_notify.min(parked.len());
                for subset in combinations(&parked, k) {
                    let mut s2 = base.clone();
                    for &w in &subset {
                        s2.loc[w] = Loc::Check;
                        s2.woken[w] = true;
                    }
                    out.push((
                        format!("S:publish e{} wake {subset:?}", base.epoch),
                        s2,
                    ));
                }
            }
        }
        SLoc::Body => {
            let spec = &sc.epochs[st.ep_idx as usize];
            let ex = extra_of(sc, st.ep_idx as usize);
            let mut s2 = st.clone();
            if spec.panicker == Panicker::Submitter {
                s2.local_panic = true;
            }
            if ex == 0 {
                // inline epoch: never touches the board
                let expected = spec.panicker != Panicker::None;
                if s2.local_panic != expected {
                    return Err("panic propagation failed on inline epoch".into());
                }
                s2.local_panic = false;
                s2.ep_idx += 1;
                s2.sloc = start_sloc(sc, s2.ep_idx as usize);
                out.push((format!("S:inline epoch #{}", st.ep_idx), s2));
            } else {
                s2.sloc = SLoc::Complete;
                out.push((format!("S:body done e{}", st.epoch), s2));
            }
        }
        SLoc::Complete if !st.s_waiting => {
            let mut s2 = st.clone();
            if st.active > 0 {
                s2.s_waiting = true;
                out.push((format!("S:wait active={}", st.active), s2));
            } else {
                let ex = extra_of(sc, st.ep_idx as usize);
                let spec = &sc.epochs[st.ep_idx as usize];
                if st.claims != 0 {
                    return Err(format!(
                        "claim budget not conserved: {} claim(s) left at completion",
                        st.claims
                    ));
                }
                if st.claimed != ex {
                    return Err(format!(
                        "expected exactly {ex} claimant(s), saw {}",
                        st.claimed
                    ));
                }
                let observed = st.panicked || st.local_panic;
                let expected = spec.panicker != Panicker::None;
                if observed != expected {
                    return Err(format!(
                        "panic propagation failed (observed={observed}, expected={expected})"
                    ));
                }
                s2.job = false;
                s2.panicked = false;
                s2.local_panic = false;
                s2.claimed = 0;
                s2.ep_idx += 1;
                s2.sloc = start_sloc(sc, s2.ep_idx as usize);
                out.push((format!("S:complete e{}", st.epoch), s2));
            }
        }
        SLoc::Complete => {} // parked in the `done` waitset
        SLoc::Shutdown => {
            let mut s2 = st.clone();
            s2.shutdown = true;
            for w in 0..w_count {
                if s2.loc[w] == Loc::Parked {
                    s2.loc[w] = Loc::Check;
                    s2.woken[w] = true;
                }
            }
            s2.sloc = SLoc::Join;
            out.push(("S:shutdown notify_all".into(), s2));
        }
        SLoc::Join => {
            if (0..w_count).all(|w| st.loc[w] == Loc::Exit) {
                let mut s2 = st.clone();
                s2.sloc = SLoc::Done;
                out.push(("S:join".into(), s2));
            }
        }
        SLoc::Done => {}
    }

    // ---- workers ----
    for w in 0..w_count {
        match st.loc[w] {
            Loc::Check => {
                let mut s2 = st.clone();
                s2.woken[w] = false;
                if st.shutdown {
                    s2.loc[w] = Loc::Exit;
                    out.push((format!("w{w}:exit"), s2));
                } else if st.epoch > st.seen[w] && st.claims > 0 {
                    if !st.job {
                        return Err("claims > 0 with no job on the board".into());
                    }
                    s2.claims -= 1;
                    s2.seen[w] = st.epoch;
                    let ord = st.claimed;
                    s2.claimed += 1;
                    let spec = &sc.epochs[st.ep_idx as usize];
                    if spec.panicker == Panicker::Claimant(ord) {
                        s2.will_panic[w] = true;
                    }
                    s2.loc[w] = Loc::Run;
                    out.push((format!("w{w}:claim #{ord} e{}", st.epoch), s2));
                } else {
                    if st.epoch > st.seen[w] {
                        s2.seen[w] = st.epoch;
                    }
                    s2.loc[w] = Loc::Parked;
                    if st.woken[w] {
                        if st.claims > 0 {
                            return Err(
                                "woken worker parked while claims were available"
                                    .into(),
                            );
                        }
                        if !sc.allow_raced_wakeups {
                            return Err(
                                "idle wakeup: woken worker found the budget \
                                 already drained"
                                    .into(),
                            );
                        }
                        stats.raced_wakeups += 1;
                    }
                    out.push((format!("w{w}:park"), s2));
                }
            }
            Loc::Run => {
                let spec = &sc.epochs[st.ep_idx as usize];
                let mut s2 = st.clone();
                if spec.nested && !sc.variant.reentry_guard {
                    // without the IN_POOL guard the nested run() waits
                    // for the board it is itself occupying
                    s2.loc[w] = Loc::Stuck;
                    out.push((format!("w{w}:nested dispatch blocks on own board"), s2));
                } else {
                    // body (nested part runs inline under the guard,
                    // touching nothing shared) + finish critical section
                    if st.will_panic[w] {
                        s2.panicked = true;
                        s2.will_panic[w] = false;
                    }
                    if st.active == 0 {
                        return Err("active-count underflow in finish section".into());
                    }
                    s2.active -= 1;
                    if s2.active == 0 {
                        // notify_all(done)
                        s2.s_waiting = false;
                    }
                    s2.loc[w] = Loc::Check;
                    out.push((format!("w{w}:finish e{}", st.epoch), s2));
                }
            }
            Loc::Parked | Loc::Stuck | Loc::Exit => {}
        }
    }

    Ok(out)
}

// ---------------------------------------------------------------------
// Scoped backend model: fresh threads drain a shared cursor; no board.
// ---------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
enum SwLoc {
    Fetch,
    Work(u8),
    Done,
}

#[derive(Clone, PartialEq, Eq, Hash)]
struct SState {
    next: u8,
    loc: [SwLoc; MAX_W],
    done_mask: u16,
}

/// Enumerate every schedule of `workers` scoped threads draining
/// `chunks` cursor items; asserts each chunk is claimed exactly once
/// and the drain terminates.
pub fn explore_scoped(workers: usize, chunks: u8) -> Result<Stats, Violation> {
    assert!((1..=MAX_W).contains(&workers));
    assert!(chunks as usize <= 12);
    let mut init = SState { next: 0, loc: [SwLoc::Done; MAX_W], done_mask: 0 };
    for w in 0..workers {
        init.loc[w] = SwLoc::Fetch;
    }
    let mut stats = Stats::default();
    let mut visited = HashSet::new();
    let mut path = Vec::new();
    visited.insert(init.clone());
    scoped_dfs(workers, chunks, &init, &mut visited, &mut path, &mut stats)?;
    Ok(stats)
}

fn scoped_dfs(
    workers: usize,
    chunks: u8,
    st: &SState,
    visited: &mut HashSet<SState>,
    path: &mut Vec<String>,
    stats: &mut Stats,
) -> Result<(), Violation> {
    stats.states += 1;
    if (0..workers).all(|w| st.loc[w] == SwLoc::Done) {
        if st.done_mask != (1u16 << chunks) - 1 {
            return Err(Violation {
                message: format!(
                    "scoped drain terminated with chunks missing (mask {:#b})",
                    st.done_mask
                ),
                trace: path.clone(),
            });
        }
        stats.terminals += 1;
        return Ok(());
    }
    let mut any = false;
    for w in 0..workers {
        let (label, s2) = match st.loc[w] {
            SwLoc::Fetch => {
                let mut s2 = st.clone();
                if st.next < chunks {
                    s2.loc[w] = SwLoc::Work(st.next);
                    s2.next += 1;
                    (format!("w{w}:fetch #{}", st.next), s2)
                } else {
                    s2.loc[w] = SwLoc::Done;
                    (format!("w{w}:drained"), s2)
                }
            }
            SwLoc::Work(c) => {
                if st.done_mask & (1 << c) != 0 {
                    return Err(Violation {
                        message: format!("chunk {c} processed twice"),
                        trace: path.clone(),
                    });
                }
                let mut s2 = st.clone();
                s2.done_mask |= 1 << c;
                s2.loc[w] = SwLoc::Fetch;
                (format!("w{w}:work #{c}"), s2)
            }
            SwLoc::Done => continue,
        };
        any = true;
        stats.transitions += 1;
        if visited.insert(s2.clone()) {
            path.push(label);
            scoped_dfs(workers, chunks, &s2, visited, path, stats)?;
            path.pop();
        }
    }
    if !any {
        return Err(Violation {
            message: "scoped drain deadlocked".into(),
            trace: path.clone(),
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_single_worker_single_epoch() {
        let sc = Scenario::faithful(1, vec![EpochSpec::plain(2)]);
        let stats = explore(&sc).unwrap_or_else(|v| panic!("{v}"));
        assert!(stats.states > 3);
        assert!(stats.terminals >= 1);
    }

    #[test]
    fn smoke_scoped() {
        let stats = explore_scoped(2, 3).unwrap_or_else(|v| panic!("{v}"));
        assert!(stats.terminals >= 1);
    }
}
