//! The parallel compute layer: a zero-dependency **persistent worker
//! pool** with a fixed-index-order reduction contract.
//!
//! Everything hot in this crate (GEMM, Gram updates, Jacobi sweeps, the
//! per-layer quantization loop) is embarrassingly parallel, but PJRT
//! aside, the stack must stay std-only.  This module provides the one
//! primitive all of them share: run N deterministic work items across a
//! bounded set of threads and give the results back in **fixed index
//! order** so every reduction downstream is bit-identical regardless of
//! thread count.
//!
//! # Pool lifecycle
//!
//! [`Pool::new`]`(n)` spawns `n - 1` long-lived worker threads that park
//! on a job board (a `Mutex` + `Condvar` pair) until work arrives.  Each
//! `map`/`for_each` call publishes one **epoch**: a generation-counted
//! job carrying a claim budget of `min(items - 1, workers)` dispatch
//! slots; each claiming worker runs the job once, pulling item indices
//! from an atomic cursor.  Epochs smaller than the pool wake (and run on)
//! only as many workers as there are items — the surplus workers never
//! leave the condvar.  The calling thread participates as the n-th
//! worker, so `Pool::new(1)` holds no threads at all and runs everything
//! inline.  Dropping the last clone of a `Pool` shuts the board down and
//! joins the workers; the [`global`] pool lives for the whole process.
//!
//! Publishing an epoch costs two mutex acquisitions per thread — against
//! the hundreds of microseconds a scoped spawn/join cycle costs, this is
//! what makes *fine-grained* call sites (Jacobi rotation rounds,
//! per-slice Σ updates) worth parallelizing at all.
//!
//! # Nesting and `scoped()`
//!
//! A `map`/`for_each` issued **from inside a pool job** runs inline on
//! the issuing worker (a thread-local guard detects re-entry), so nested
//! library code can never deadlock the board — and the per-layer
//! quantization fan-out automatically suppresses inner GEMM parallelism
//! instead of oversubscribing.  When a call site genuinely wants fresh
//! parallelism in a nested or long-blocking context, [`Pool::scoped`]
//! returns a handle with the same API that falls back to spawn-per-call
//! `std::thread::scope` workers (the pre-persistent-pool behavior).
//! The parallelism of a scoped call comes from those scoped threads
//! alone: they mark themselves in-pool as well, so work running on them
//! never dispatches onto the shared persistent board (whose current
//! epoch may be blocked waiting on this very scope — the guard is what
//! makes `scoped()` deadlock-free by construction).
//!
//! # Determinism contract
//!
//! A [`Pool`] never changes *what* is computed, only *where*.  Work item
//! `i` always produces the same value, and callers always fold results
//! in index order — so `threads ∈ {1, 2, 8}` produce byte-identical
//! outputs (see `tests/par_determinism.rs` and `tests/kernel_oracle.rs`).
//!
//! # Worker-owned scratch arenas
//!
//! Because the workers are persistent threads, each one owns a
//! [`crate::linalg::workspace`] arena (a thread-local free list of
//! scratch buffers) that survives across epochs: the packed GEMM panels
//! and solver temporaries a worker warms up on one layer of the per-layer
//! fan-out are reused verbatim on the next, so steady-state pool work is
//! allocation-free inside the kernels.  [`Pool::for_indices`] completes
//! the picture on the dispatch side — it is the one entry point that
//! publishes an epoch without allocating result slots, which is what the
//! kernel layer uses for disjoint in-place writes.
//!
//! # Sizing
//!
//! Pool sizing, in priority order:
//!   1. an explicit [`set_threads`] call (the CLI's `--threads` flag),
//!   2. the `LRC_THREADS` environment variable — resolved **once** into
//!      a `OnceLock` on first use (re-reading the environment on every
//!      call showed up in profiles of fine-grained sites),
//!   3. `std::thread::available_parallelism()`.
//!
//! `set_threads` keeps working after the env var has been cached: the
//! override is consulted first on every [`threads`] call.

pub mod model;

use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Process-wide override installed by `--threads` (0 = unset).
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// `LRC_THREADS`, parsed once (None = unset or unparsable).
static ENV_THREADS: OnceLock<Option<usize>> = OnceLock::new();

/// Install a process-wide thread-count override (the `--threads` flag).
/// `0` clears the override.
pub fn set_threads(n: usize) {
    THREAD_OVERRIDE.store(n, Ordering::SeqCst);
}

/// Resolve the effective thread count: override > `LRC_THREADS` env >
/// `available_parallelism` (≥ 1 always).  The env var is read exactly
/// once per process; the `set_threads` override stays live throughout.
pub fn threads() -> usize {
    let o = THREAD_OVERRIDE.load(Ordering::SeqCst);
    if o > 0 {
        return o;
    }
    let env = ENV_THREADS.get_or_init(|| {
        std::env::var("LRC_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n > 0)
    });
    if let Some(n) = *env {
        return n;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The shared process pool, built on first use with [`threads`] workers.
/// The CLI installs `--threads` before any compute runs, so the global
/// pool picks the override up; library users who need a different size
/// construct their own [`Pool`].
pub fn global() -> &'static Pool {
    static GLOBAL: OnceLock<Pool> = OnceLock::new();
    GLOBAL.get_or_init(|| Pool::new(threads()))
}

thread_local! {
    /// True while this thread is executing a pool job — nested pool calls
    /// check it and run inline instead of touching a job board.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// True when the current thread is already executing a pool job.  Used
/// by the auto-parallel kernel entry points to decide serial *before*
/// touching (and lazily spawning) the global pool.
pub(crate) fn in_pool() -> bool {
    IN_POOL.with(|f| f.get())
}

/// RAII re-entrancy marker; restores the previous state even on unwind.
struct PoolGuard {
    prev: bool,
}

impl PoolGuard {
    fn enter() -> PoolGuard {
        PoolGuard { prev: IN_POOL.with(|f| f.replace(true)) }
    }
}

impl Drop for PoolGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        IN_POOL.with(|f| f.set(prev));
    }
}

/// A lifetime-erased job body.  Safe to copy into worker threads because
/// [`Workers::run`] never returns until every worker is done with it.
#[derive(Clone, Copy)]
struct SendJob(&'static (dyn Fn() + Sync));

/// The job board all workers of one pool park on.
struct JobState {
    /// generation counter: workers run each epoch at most once
    epoch: u64,
    /// the currently published job (None between epochs)
    job: Option<SendJob>,
    /// workers the current epoch still expects to finish (preset to the
    /// claim budget at publish; decremented as claimed work completes)
    active: usize,
    /// workers that may still join the current epoch — preset to
    /// `min(items - 1, workers)` so an epoch with fewer items than the
    /// pool has workers never dispatches (or wakes) the surplus ones
    claims: usize,
    /// a worker panicked while running the current epoch
    panicked: bool,
    shutdown: bool,
}

struct Board {
    state: Mutex<JobState>,
    /// workers wait here for a new epoch (or shutdown)
    work: Condvar,
    /// submitters wait here for epoch completion / board availability
    done: Condvar,
}

/// Owns the worker threads; dropping the last `Pool` clone drops this,
/// which signals shutdown and joins every worker.
struct Workers {
    board: Arc<Board>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Workers {
    /// Publish one epoch and run it to completion on the calling thread
    /// plus at most `items - 1` parked workers.
    ///
    /// The claim budget is what keeps small epochs cheap: an epoch with
    /// `items` work items can use at most `items` threads (the caller is
    /// one of them), so only `min(items - 1, workers)` parked workers are
    /// woken and run the job — the rest never leave the condvar.  At
    /// `items > workers` this degrades to the old wake-everyone behavior.
    ///
    /// SAFETY: `body` is lifetime-erased before being handed to the
    /// workers; this function does not return (or unwind) until every
    /// worker that claimed the epoch has finished running it — and the
    /// claim budget is always fully consumed before `active` can reach
    /// zero — so the erased borrow never outlives the frame that owns the
    /// captured data.
    fn run(&self, body: &(dyn Fn() + Sync), items: usize) {
        let extra = self.handles.len().min(items.saturating_sub(1));
        if extra == 0 {
            // no workers needed: run inline without occupying the board
            let _guard = PoolGuard::enter();
            body();
            return;
        }
        // SAFETY: the lifetime erasure is sound per the doc above — this
        // frame outlives every worker's use of the borrow because run()
        // only returns after `active` reaches zero, and the claim budget
        // is fully consumed before that can happen.
        let job = SendJob(unsafe {
            std::mem::transmute::<&(dyn Fn() + Sync), &'static (dyn Fn() + Sync)>(body)
        });
        {
            let mut st = self.board.state.lock().unwrap();
            // another thread may be mid-epoch on this shared pool: wait
            // for the board to free up before publishing
            while st.job.is_some() {
                st = self.board.done.wait(st).unwrap();
            }
            #[cfg(feature = "checked")]
            {
                // protocol assertions (mirrored in `par::model`): the
                // board must be clean before a new epoch is published
                assert_eq!(
                    st.active, 0,
                    "checked: publishing over {} live claimant(s)",
                    st.active
                );
                assert_eq!(
                    st.claims, 0,
                    "checked: {} unconsumed claim(s) left on the board",
                    st.claims
                );
                assert!(!st.panicked, "checked: stale panic flag at publish");
            }
            st.epoch += 1;
            st.active = extra;
            st.claims = extra;
            st.job = Some(job);
            st.panicked = false;
            // a notify_one can only be lost when no worker is parked, and
            // an unparked worker re-checks the board (and claims) before
            // parking — so `extra` targeted wakeups always end up with
            // exactly `extra` claimants
            if extra == self.handles.len() {
                self.board.work.notify_all();
            } else {
                for _ in 0..extra {
                    self.board.work.notify_one();
                }
            }
        }
        // the caller is a worker too (pool of n = n-1 threads + caller)
        let local = {
            let _guard = PoolGuard::enter();
            catch_unwind(AssertUnwindSafe(body))
        };
        let worker_panicked = {
            let mut st = self.board.state.lock().unwrap();
            while st.active > 0 {
                st = self.board.done.wait(st).unwrap();
            }
            // claim-budget conservation: every dispatch slot was either
            // claimed (and finished — active hit zero) or the budget
            // math is broken; `claims` must already be zero here
            #[cfg(feature = "checked")]
            assert_eq!(
                st.claims, 0,
                "checked: claim budget not conserved — {} left at completion",
                st.claims
            );
            st.job = None;
            st.claims = 0;
            let p = st.panicked;
            st.panicked = false;
            // wake any submitter waiting for the board to free up
            self.board.done.notify_all();
            p
        };
        if let Err(payload) = local {
            resume_unwind(payload);
        }
        if worker_panicked {
            panic!("pool worker panicked during a parallel job");
        }
    }
}

impl Drop for Workers {
    fn drop(&mut self) {
        {
            let mut st = self.board.state.lock().unwrap();
            st.shutdown = true;
        }
        self.board.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Long-lived worker: park on the board, run each published epoch at most
/// once — and only after claiming one of its dispatch slots (small epochs
/// carry fewer slots than the pool has workers).
fn worker_loop(board: Arc<Board>) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = board.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch > seen {
                    if st.claims > 0 {
                        if let Some(j) = st.job {
                            // no epoch reuse: the `epoch > seen` guard
                            // means this worker never claims the same
                            // generation twice
                            #[cfg(feature = "checked")]
                            assert!(
                                st.epoch > seen,
                                "checked: epoch reuse — re-claiming generation {}",
                                st.epoch
                            );
                            st.claims -= 1;
                            seen = st.epoch;
                            break j;
                        }
                    } else {
                        // epoch fully claimed (or finished) without us —
                        // mark it seen and park again
                        seen = st.epoch;
                    }
                }
                st = board.work.wait(st).unwrap();
            }
        };
        // panics must not kill the worker: catch, record, keep serving
        let res = {
            let _guard = PoolGuard::enter();
            catch_unwind(AssertUnwindSafe(job.0))
        };
        let mut st = board.state.lock().unwrap();
        if res.is_err() {
            st.panicked = true;
        }
        // active-count underflow would mean a claimant the budget never
        // granted (caught in release builds too under `checked`)
        #[cfg(feature = "checked")]
        assert!(
            st.active > 0,
            "checked: active-count underflow in the finish section"
        );
        st.active -= 1;
        if st.active == 0 {
            board.done.notify_all();
        }
    }
}

/// How a [`Pool`] executes work.
enum Backend {
    /// threads = 1: run inline on the caller, suppressing nested
    /// parallelism (a serial pool means *serial*)
    Inline,
    /// spawn-per-call `std::thread::scope` workers (the [`Pool::scoped`]
    /// escape hatch; allows real parallelism from nested contexts)
    Scoped,
    /// parked persistent workers sharing a job board
    Persistent(Arc<Workers>),
}

/// A handle over the compute pool.  Cheap to clone (clones share the
/// same workers); the workers shut down when the last clone drops.
pub struct Pool {
    n: usize,
    backend: Backend,
}

impl Clone for Pool {
    fn clone(&self) -> Pool {
        let backend = match &self.backend {
            Backend::Inline => Backend::Inline,
            Backend::Scoped => Backend::Scoped,
            Backend::Persistent(w) => Backend::Persistent(w.clone()),
        };
        Pool { n: self.n, backend }
    }
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let kind = match &self.backend {
            Backend::Inline => "inline",
            Backend::Scoped => "scoped",
            Backend::Persistent(_) => "persistent",
        };
        write!(f, "Pool({} threads, {kind})", self.n)
    }
}

impl Pool {
    /// A pool of exactly `n` compute threads (clamped to ≥ 1): `n - 1`
    /// parked workers plus the calling thread.  `n = 1` spawns nothing
    /// and runs everything inline.
    pub fn new(n: usize) -> Pool {
        let n = n.max(1);
        if n == 1 {
            return Pool { n, backend: Backend::Inline };
        }
        let board = Arc::new(Board {
            state: Mutex::new(JobState {
                epoch: 0,
                job: None,
                active: 0,
                claims: 0,
                panicked: false,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let mut handles = Vec::with_capacity(n - 1);
        for wid in 0..n - 1 {
            let b = board.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("lrc-par-{wid}"))
                .spawn(move || worker_loop(b));
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // shut down + join the workers already spawned before
                    // propagating, or they would park forever holding
                    // their board Arcs (Workers::drop does exactly that)
                    drop(Workers { board, handles });
                    panic!("spawn pool worker {wid}: {e}");
                }
            }
        }
        Pool { n, backend: Backend::Persistent(Arc::new(Workers { board, handles })) }
    }

    /// A fresh pool sized like the process default (see [`threads`]).
    /// Most callers want the shared [`global`] pool instead.
    pub fn current() -> Pool {
        Pool::new(threads())
    }

    /// A single-threaded pool: runs everything inline on the caller and
    /// suppresses nested parallelism.
    pub fn serial() -> Pool {
        Pool { n: 1, backend: Backend::Inline }
    }

    /// A same-sized handle that dispatches every call through
    /// spawn-per-call scoped threads instead of the persistent board.
    /// Use it for work issued *from inside* a pool job that still wants
    /// real parallelism, or for long-blocking items that should not
    /// occupy the shared workers.  (Also the baseline the `bench_par`
    /// dispatch benchmarks compare the persistent board against.)
    pub fn scoped(&self) -> Pool {
        Pool { n: self.n, backend: Backend::Scoped }
    }

    pub fn threads(&self) -> usize {
        self.n
    }

    /// Apply `f` to every index in `0..n` and return the results in index
    /// order.  Scheduling is dynamic (atomic cursor) so heterogeneous item
    /// costs balance, but the output order — and therefore any fold over
    /// it — is fixed.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        match &self.backend {
            Backend::Inline => {
                let _guard = PoolGuard::enter();
                (0..n).map(f).collect()
            }
            // scoped() deliberately skips the re-entrancy guard: it exists
            // to provide real parallelism from nested contexts
            Backend::Scoped => scoped_map(self.n, n, f),
            _ if n <= 1 || in_pool() => (0..n).map(f).collect(),
            Backend::Persistent(w) => {
                let cursor = AtomicUsize::new(0);
                let slots: Vec<Mutex<Option<T>>> =
                    (0..n).map(|_| Mutex::new(None)).collect();
                let body = || drain_map(&cursor, n, &f, &slots);
                w.run(&body, n);
                collect_slots(slots)
            }
        }
    }

    /// Run `f(i)` for every index in `0..n` with **no result collection
    /// and no per-item allocation**: the serial path is a plain loop, the
    /// pooled path publishes one epoch whose claimants drain the shared
    /// cursor calling `f` directly.  This is the dispatch primitive the
    /// allocation-free kernels use — output goes through caller-managed
    /// disjoint writes (e.g. `linalg::workspace::SharedSlice`), not
    /// through slots.  Same scheduling (dynamic cursor) and same
    /// determinism obligations as [`Pool::map`]: `f` must make item `i`'s
    /// effect independent of which thread runs it.
    pub fn for_indices<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        match &self.backend {
            Backend::Inline => {
                let _guard = PoolGuard::enter();
                for i in 0..n {
                    f(i);
                }
            }
            Backend::Scoped => scoped_for_indices(self.n, n, &f),
            _ if n <= 1 || in_pool() => {
                for i in 0..n {
                    f(i);
                }
            }
            Backend::Persistent(w) => {
                let cursor = AtomicUsize::new(0);
                let body = || drain_indices(&cursor, n, &f);
                w.run(&body, n);
            }
        }
    }

    /// Consume owned work items (e.g. disjoint `&mut` output slices) on
    /// the pool.  Items are handed out dynamically; `f` runs once per
    /// item.  Item payloads must be independent — the pool gives no
    /// ordering guarantee *between* items, only that each runs exactly
    /// once.
    pub fn for_each<T, F>(&self, work: Vec<T>, f: F)
    where
        T: Send,
        F: Fn(T) + Sync,
    {
        let n = work.len();
        match &self.backend {
            Backend::Inline => {
                let _guard = PoolGuard::enter();
                for w in work {
                    f(w);
                }
            }
            Backend::Scoped => scoped_for_each(self.n, work, f),
            _ if n <= 1 || in_pool() => {
                for w in work {
                    f(w);
                }
            }
            Backend::Persistent(wk) => {
                let cursor = AtomicUsize::new(0);
                let slots: Vec<Mutex<Option<T>>> =
                    work.into_iter().map(|w| Mutex::new(Some(w))).collect();
                let body = || drain_for_each(&cursor, n, &f, &slots);
                wk.run(&body, n);
            }
        }
    }
}

/// Pull map items off the shared cursor until exhausted.
fn drain_map<T, F>(cursor: &AtomicUsize, n: usize, f: &F,
                   slots: &[Mutex<Option<T>>])
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    loop {
        let i = cursor.fetch_add(1, Ordering::Relaxed);
        if i >= n {
            break;
        }
        let out = f(i);
        *slots[i].lock().unwrap() = Some(out);
    }
}

/// Pull bare indices off the shared cursor until exhausted (the
/// slot-free [`Pool::for_indices`] path).
fn drain_indices<F>(cursor: &AtomicUsize, n: usize, f: &F)
where
    F: Fn(usize) + Sync,
{
    loop {
        let i = cursor.fetch_add(1, Ordering::Relaxed);
        if i >= n {
            break;
        }
        f(i);
    }
}

/// Spawn-per-call for_indices (the `scoped()` backend).
fn scoped_for_indices<F>(threads: usize, n: usize, f: &F)
where
    F: Fn(usize) + Sync,
{
    let workers = threads.min(n);
    if workers <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..workers {
            // see scoped_map: scoped workers self-mark in-pool
            s.spawn(|| {
                let _guard = PoolGuard::enter();
                drain_indices(&cursor, n, f)
            });
        }
    });
}

/// Pull for_each items off the shared cursor until exhausted.
fn drain_for_each<T, F>(cursor: &AtomicUsize, n: usize, f: &F,
                        slots: &[Mutex<Option<T>>])
where
    T: Send,
    F: Fn(T) + Sync,
{
    loop {
        let i = cursor.fetch_add(1, Ordering::Relaxed);
        if i >= n {
            break;
        }
        let item = slots[i].lock().unwrap().take();
        if let Some(w) = item {
            f(w);
        }
    }
}

fn collect_slots<T>(slots: Vec<Mutex<Option<T>>>) -> Vec<T> {
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("pool worker filled slot"))
        .collect()
}

/// Spawn-per-call map (the `scoped()` backend).
fn scoped_map<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = threads.min(n);
    if workers <= 1 {
        return (0..n).map(f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            // scoped workers mark themselves in-pool too: the parallelism
            // of a scoped() call comes from these threads, and an item
            // that reached for the shared persistent board could deadlock
            // it (the board's current epoch may be the very job that
            // spawned this scope and is blocked waiting on it)
            s.spawn(|| {
                let _guard = PoolGuard::enter();
                drain_map(&cursor, n, &f, &slots)
            });
        }
    });
    collect_slots(slots)
}

/// Spawn-per-call for_each (the `scoped()` backend).
fn scoped_for_each<T, F>(threads: usize, work: Vec<T>, f: F)
where
    T: Send,
    F: Fn(T) + Sync,
{
    let n = work.len();
    let workers = threads.min(n);
    if workers <= 1 {
        for w in work {
            f(w);
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> =
        work.into_iter().map(|w| Mutex::new(Some(w))).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            // see scoped_map: suppress nested board dispatch from items
            s.spawn(|| {
                let _guard = PoolGuard::enter();
                drain_for_each(&cursor, n, &f, &slots)
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn map_preserves_index_order() {
        for t in [1, 2, 3, 8] {
            let pool = Pool::new(t);
            let out = pool.map(100, |i| i * i);
            let expect: Vec<usize> = (0..100).map(|i| i * i).collect();
            assert_eq!(out, expect, "threads={t}");
        }
    }

    #[test]
    fn map_runs_each_index_exactly_once() {
        let hits: Vec<AtomicU64> = (0..64).map(|_| AtomicU64::new(0)).collect();
        let pool = Pool::new(4);
        let _ = pool.map(64, |i| hits[i].fetch_add(1, Ordering::Relaxed));
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn map_handles_edge_sizes() {
        let pool = Pool::new(8);
        assert_eq!(pool.map(0, |i| i), Vec::<usize>::new());
        assert_eq!(pool.map(1, |i| i + 7), vec![7]);
        // more threads than items
        assert_eq!(pool.map(3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn for_indices_runs_each_index_exactly_once_on_every_backend() {
        for t in [1usize, 2, 5] {
            let pool = Pool::new(t);
            for handle in [pool.clone(), pool.scoped()] {
                let hits: Vec<AtomicU64> =
                    (0..41).map(|_| AtomicU64::new(0)).collect();
                handle.for_indices(41, |i| {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                });
                for (i, h) in hits.iter().enumerate() {
                    assert_eq!(h.load(Ordering::Relaxed), 1,
                               "index {i} threads={t}");
                }
                // degenerate sizes
                handle.for_indices(0, |_| panic!("no items"));
                let one = AtomicU64::new(0);
                handle.for_indices(1, |i| {
                    assert_eq!(i, 0);
                    one.fetch_add(1, Ordering::Relaxed);
                });
                assert_eq!(one.load(Ordering::Relaxed), 1);
            }
        }
    }

    #[test]
    fn for_each_consumes_every_item_once() {
        let done: Vec<AtomicU64> = (0..37).map(|_| AtomicU64::new(0)).collect();
        let work: Vec<usize> = (0..37).collect();
        Pool::new(5).for_each(work, |i| {
            done[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, d) in done.iter().enumerate() {
            assert_eq!(d.load(Ordering::Relaxed), 1, "item {i}");
        }
    }

    #[test]
    fn for_each_supports_disjoint_mut_slices() {
        // the exact pattern par_matmul_nt uses: chunked &mut writes
        let mut data = vec![0.0_f64; 100];
        let work: Vec<(usize, &mut [f64])> =
            data.chunks_mut(16).enumerate().collect();
        Pool::new(4).for_each(work, |(ci, chunk)| {
            for (k, v) in chunk.iter_mut().enumerate() {
                *v = (ci * 16 + k) as f64;
            }
        });
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as f64);
        }
    }

    #[test]
    fn pool_sizing_clamps() {
        assert_eq!(Pool::new(0).threads(), 1);
        assert_eq!(Pool::serial().threads(), 1);
        assert!(Pool::current().threads() >= 1);
    }

    #[test]
    fn small_epochs_use_at_most_items_threads() {
        // an epoch with fewer items than the pool has workers must
        // dispatch to (and therefore run on) at most `items` threads —
        // caller + min(items - 1, workers) claimants
        let pool = Pool::new(8);
        for items in [2usize, 3, 5] {
            let tids = Mutex::new(std::collections::BTreeSet::new());
            let out = pool.map(items, |i| {
                tids.lock().unwrap().insert(std::thread::current().id());
                i * 3
            });
            assert_eq!(out, (0..items).map(|i| i * 3).collect::<Vec<_>>());
            let participants = tids.lock().unwrap().len();
            assert!(participants <= items,
                    "items={items}: {participants} threads ran the epoch");
        }
    }

    #[test]
    fn pool_reuse_across_many_epochs() {
        // the persistent board must serve repeated fine-grained calls
        // (the eigh_jacobi_par round pattern) without wedging
        let pool = Pool::new(4);
        for round in 0..200 {
            let out = pool.map(9, |i| i + round);
            let expect: Vec<usize> = (0..9).map(|i| i + round).collect();
            assert_eq!(out, expect, "round {round}");
        }
    }

    #[test]
    fn scoped_matches_persistent() {
        let pool = Pool::new(3);
        let scoped = pool.scoped();
        assert_eq!(scoped.threads(), 3);
        assert_eq!(pool.map(50, |i| 3 * i), scoped.map(50, |i| 3 * i));
    }

    #[test]
    fn nested_map_runs_inline_without_deadlock() {
        let pool = Pool::new(4);
        let out = pool.map(8, |i| {
            // nested call on the same pool: must run inline, not deadlock
            pool.map(5, |j| i * 10 + j).iter().sum::<usize>()
        });
        let expect: Vec<usize> =
            (0..8).map(|i| (0..5).map(|j| i * 10 + j).sum()).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = Pool::new(4);
        let res = catch_unwind(AssertUnwindSafe(|| {
            pool.map(16, |i| {
                assert!(i != 7, "boom");
                i
            })
        }));
        assert!(res.is_err(), "panic must propagate to the submitter");
        // the board must be clean and the workers alive afterwards
        assert_eq!(pool.map(8, |i| i), (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_workers() {
        // repeated build/drop cycles must neither deadlock nor leak;
        // a wedged join would hang this test
        for cycle in 0..5 {
            let pool = Pool::new(4);
            assert_eq!(pool.map(16, |i| i * 2),
                       (0..16).map(|i| i * 2).collect::<Vec<_>>(),
                       "cycle {cycle}");
            drop(pool);
        }
        // out-of-order drops of independent pools
        let p1 = Pool::new(3);
        let p2 = Pool::new(2);
        assert_eq!(p1.map(10, |i| i), (0..10).collect::<Vec<_>>());
        drop(p1);
        assert_eq!(p2.map(10, |i| i + 1), (1..11).collect::<Vec<_>>());
    }

    #[test]
    fn clones_share_workers() {
        let pool = Pool::new(4);
        let c = pool.clone();
        assert_eq!(c.threads(), 4);
        assert_eq!(c.map(20, |i| i), (0..20).collect::<Vec<_>>());
        drop(pool);
        // the clone keeps the workers alive
        assert_eq!(c.map(20, |i| i + 1), (1..21).collect::<Vec<_>>());
    }

    #[test]
    fn global_pool_works() {
        assert!(global().threads() >= 1);
        assert_eq!(global().map(12, |i| i * 7),
                   (0..12).map(|i| i * 7).collect::<Vec<_>>());
    }
}
