//! Zero-dependency scoped thread pool — the parallel compute layer.
//!
//! Everything hot in this crate (GEMM, Gram updates, Jacobi sweeps, the
//! per-layer quantization loop) is embarrassingly parallel, but PJRT
//! aside, the stack must stay std-only.  This module provides the one
//! primitive all of them share: run N deterministic work items across a
//! bounded set of scoped threads (`std::thread::scope`), hand the items
//! out through an atomics-based work queue, and give the results back in
//! **fixed index order** so every reduction downstream is bit-identical
//! regardless of thread count.
//!
//! Determinism contract: a [`Pool`] never changes *what* is computed,
//! only *where*.  Work item `i` always produces the same value, and
//! callers always fold results in index order — so `threads ∈ {1, 2, 8}`
//! produce byte-identical outputs (see `tests/par_determinism.rs`).
//!
//! Pool sizing, in priority order:
//!   1. an explicit [`set_threads`] call (the CLI's `--threads` flag),
//!   2. the `LRC_THREADS` environment variable,
//!   3. `std::thread::available_parallelism()`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Process-wide override installed by `--threads` (0 = unset).
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Install a process-wide thread-count override (the `--threads` flag).
/// `0` clears the override.
pub fn set_threads(n: usize) {
    THREAD_OVERRIDE.store(n, Ordering::SeqCst);
}

/// Resolve the effective thread count: override > `LRC_THREADS` env >
/// `available_parallelism` (≥ 1 always).
pub fn threads() -> usize {
    let o = THREAD_OVERRIDE.load(Ordering::SeqCst);
    if o > 0 {
        return o;
    }
    if let Ok(s) = std::env::var("LRC_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// A sized handle over the scoped pool.  Cheap to copy; owns no threads —
/// threads live only for the duration of each `map`/`for_each` call, so
/// there is nothing to shut down and nested use is safe (inner calls just
/// add their own scoped workers).
#[derive(Clone, Copy, Debug)]
pub struct Pool {
    n: usize,
}

impl Pool {
    /// A pool of exactly `n` worker threads (clamped to ≥ 1).
    pub fn new(n: usize) -> Pool {
        Pool { n: n.max(1) }
    }

    /// The process-default pool (see [`threads`]).
    pub fn current() -> Pool {
        Pool::new(threads())
    }

    /// A single-threaded pool: runs everything inline on the caller.
    pub fn serial() -> Pool {
        Pool::new(1)
    }

    pub fn threads(&self) -> usize {
        self.n
    }

    /// Apply `f` to every index in `0..n` and return the results in index
    /// order.  Scheduling is dynamic (atomic cursor) so heterogeneous item
    /// costs balance, but the output order — and therefore any fold over
    /// it — is fixed.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let workers = self.n.min(n);
        if workers <= 1 {
            return (0..n).map(f).collect();
        }
        let cursor = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<T>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let out = f(i);
                    *slots[i].lock().unwrap() = Some(out);
                });
            }
        });
        slots
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("pool worker filled slot"))
            .collect()
    }

    /// Consume owned work items (e.g. disjoint `&mut` output slices) on
    /// the pool.  Items are handed out dynamically; `f` runs once per
    /// item.  Item payloads must be independent — the pool gives no
    /// ordering guarantee *between* items, only that each runs exactly
    /// once.
    pub fn for_each<T, F>(&self, work: Vec<T>, f: F)
    where
        T: Send,
        F: Fn(T) + Sync,
    {
        let n = work.len();
        let workers = self.n.min(n);
        if workers <= 1 {
            for w in work {
                f(w);
            }
            return;
        }
        let cursor = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<T>>> =
            work.into_iter().map(|w| Mutex::new(Some(w))).collect();
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let item = slots[i].lock().unwrap().take();
                    if let Some(w) = item {
                        f(w);
                    }
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn map_preserves_index_order() {
        for t in [1, 2, 3, 8] {
            let pool = Pool::new(t);
            let out = pool.map(100, |i| i * i);
            let expect: Vec<usize> = (0..100).map(|i| i * i).collect();
            assert_eq!(out, expect, "threads={t}");
        }
    }

    #[test]
    fn map_runs_each_index_exactly_once() {
        let hits: Vec<AtomicU64> = (0..64).map(|_| AtomicU64::new(0)).collect();
        let pool = Pool::new(4);
        let _ = pool.map(64, |i| hits[i].fetch_add(1, Ordering::Relaxed));
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn map_handles_edge_sizes() {
        let pool = Pool::new(8);
        assert_eq!(pool.map(0, |i| i), Vec::<usize>::new());
        assert_eq!(pool.map(1, |i| i + 7), vec![7]);
        // more threads than items
        assert_eq!(pool.map(3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn for_each_consumes_every_item_once() {
        let done: Vec<AtomicU64> = (0..37).map(|_| AtomicU64::new(0)).collect();
        let work: Vec<usize> = (0..37).collect();
        Pool::new(5).for_each(work, |i| {
            done[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, d) in done.iter().enumerate() {
            assert_eq!(d.load(Ordering::Relaxed), 1, "item {i}");
        }
    }

    #[test]
    fn for_each_supports_disjoint_mut_slices() {
        // the exact pattern par_matmul_nt uses: chunked &mut writes
        let mut data = vec![0.0_f64; 100];
        let work: Vec<(usize, &mut [f64])> =
            data.chunks_mut(16).enumerate().collect();
        Pool::new(4).for_each(work, |(ci, chunk)| {
            for (k, v) in chunk.iter_mut().enumerate() {
                *v = (ci * 16 + k) as f64;
            }
        });
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as f64);
        }
    }

    #[test]
    fn pool_sizing_clamps() {
        assert_eq!(Pool::new(0).threads(), 1);
        assert_eq!(Pool::serial().threads(), 1);
        assert!(Pool::current().threads() >= 1);
    }
}
