//! The three lint families behind `lrc analyze`.
//!
//! Everything here is deny-by-default: the allowlists below encode the
//! repo's standing contracts (concurrency primitives live in the pool
//! and the serving engine, wall-clock time never enters deterministic
//! paths, `mul_add` only in the gated FMA kernels, compute layers never
//! depend on serving layers).  A site that must break a rule carries an
//! inline justification marker:
//!
//! ```text
//! // analyze: allow(forbidden-api): checked-mode instrumentation lock,
//! // never taken on the default (unchecked) build.
//! ```
//!
//! The marker must name the rule it silences and carry a non-trivial
//! justification — a bare marker is itself a finding.

use super::lex::{scan, Scan};
use super::Finding;

pub const RULE_SAFETY: &str = "safety-comment";
pub const RULE_API: &str = "forbidden-api";
pub const RULE_LAYERING: &str = "layering";
pub const RULE_MARKER: &str = "allow-marker";

/// Crate modules the layering lint knows about (top-level only).
const KNOWN_MODULES: &[&str] = &[
    "analyze", "bench", "chaos", "coordinator", "data", "eval",
    "experiments", "linalg", "lrc", "par", "pipeline", "quant", "registry",
    "rng", "runtime", "sweep", "util",
];

/// Module-layering contract: which sibling modules each top-level
/// module may reference (`crate::<mod>` in code, comments excluded).
/// The load-bearing edges are the *absent* ones: the compute stack
/// (`linalg`, `quant`, `lrc`, `par`, ...) must never reach into the
/// serving stack (`coordinator`, `runtime`), so quantization math can
/// be desk-verified without dragging the engine in.
fn allowed_deps(module: &str) -> Option<&'static [&'static str]> {
    Some(match module {
        "util" | "rng" | "par" => &[],
        "analyze" | "bench" => &["util"],
        "linalg" => &["par", "rng", "util"],
        "quant" => &["linalg", "lrc", "par", "rng", "util"],
        "lrc" => &["linalg", "par", "quant", "rng", "util"],
        "data" => &["rng", "util"],
        "eval" => &["data", "rng", "util"],
        // the registry is storage + wire protocol only: it may describe
        // artifacts (quant configs, tensor bundles) but the compute
        // stack must never reach *into* it — caching stays an optional
        // layer above the math (`rng` seeds the fault-plan generator and
        // the worker backoff jitter, nothing numerical)
        "registry" => &["quant", "rng", "runtime", "util"],
        // the chaos harness drives fleets end-to-end: sweep grids over
        // the registry wire protocol under injected faults
        "chaos" => &[
            "par", "pipeline", "quant", "registry", "rng", "sweep", "util",
        ],
        "pipeline" => &[
            "data", "eval", "experiments", "linalg", "lrc", "par", "quant",
            "registry", "rng", "runtime", "util",
        ],
        "runtime" => &[
            "data", "eval", "linalg", "lrc", "par", "pipeline", "quant",
            "rng", "util",
        ],
        "experiments" => &[
            "data", "eval", "linalg", "lrc", "par", "pipeline", "quant",
            "rng", "runtime", "util",
        ],
        "sweep" => &[
            "data", "eval", "experiments", "linalg", "lrc", "par",
            "pipeline", "quant", "registry", "rng", "runtime", "util",
        ],
        "coordinator" => &[
            "data", "eval", "linalg", "lrc", "par", "pipeline", "quant",
            "rng", "runtime", "util",
        ],
        _ => return None,
    })
}

struct ApiRule {
    /// token pattern, with `::` as a single token
    pattern: &'static [&'static str],
    /// path prefixes (relative to `src/`) where the API is legitimate
    allowed: &'static [&'static str],
    why: &'static str,
}

const API_RULES: &[ApiRule] = &[
    ApiRule {
        pattern: &["thread", "::", "spawn"],
        allowed: &["par/", "coordinator/", "chaos.rs"],
        why: "thread management belongs to the pool, the serving engine \
              and the in-process chaos fleets",
    },
    ApiRule {
        pattern: &["thread", "::", "Builder"],
        allowed: &["par/", "coordinator/", "chaos.rs"],
        why: "thread management belongs to the pool, the serving engine \
              and the in-process chaos fleets",
    },
    ApiRule {
        pattern: &["Mutex"],
        allowed: &["par/", "coordinator/"],
        why: "locks outside the pool/engine undermine the allocation-free, \
              deterministic hot paths",
    },
    ApiRule {
        pattern: &["Condvar"],
        allowed: &["par/", "coordinator/"],
        why: "blocking coordination belongs to the pool and the serving engine",
    },
    ApiRule {
        pattern: &["Instant", "::", "now"],
        allowed: &["bench/", "coordinator/", "main.rs"],
        why: "wall-clock reads threaten the byte-identical report contract",
    },
    ApiRule {
        pattern: &["SystemTime"],
        allowed: &["bench/", "coordinator/", "main.rs"],
        why: "wall-clock reads threaten the byte-identical report contract",
    },
    ApiRule {
        pattern: &["mul_add"],
        allowed: &["linalg/simd.rs", "linalg/kernels.rs", "quant/dequant.rs"],
        why: "fused multiply-add outside the gated FMA kernels breaks the \
              canonical-scalar-program contract",
    },
];

/// Lint one file.  `rel` is the path relative to the source root
/// (e.g. `par/mod.rs`), used for allowlist matching; fixture files from
/// outside the tree get no allowlist credit, which is exactly what the
/// CI self-test wants.
pub fn lint_file(rel: &str, src: &str) -> Vec<Finding> {
    let sc = scan(src);
    let lines: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    lint_safety(rel, &sc, &lines, &mut out);
    lint_apis(rel, &sc, &lines, &mut out);
    lint_layering(rel, &sc, &lines, &mut out);
    lint_markers(rel, &sc, &mut out);
    out.sort_by_key(|f| f.line);
    out
}

/// Every `unsafe` token must be covered by a `// SAFETY:` comment on the
/// same line or in the contiguous comment block above the statement.
fn lint_safety(rel: &str, sc: &Scan, lines: &[&str], out: &mut Vec<Finding>) {
    let mut done_lines = std::collections::BTreeSet::new();
    for t in &sc.toks {
        if t.text != "unsafe" || !done_lines.insert(t.line) {
            continue;
        }
        if covered(sc, lines, t.line, "SAFETY")
            || marker_at(sc, lines, t.line, RULE_SAFETY)
        {
            continue;
        }
        out.push(Finding {
            file: rel.to_string(),
            line: t.line,
            rule: RULE_SAFETY,
            message: "`unsafe` without a `// SAFETY:` comment on the same \
                      line or immediately above"
                .to_string(),
        });
    }
}

fn lint_apis(rel: &str, sc: &Scan, lines: &[&str], out: &mut Vec<Finding>) {
    for rule in API_RULES {
        if rule.allowed.iter().any(|p| rel.starts_with(p)) {
            continue;
        }
        let mut done_lines = std::collections::BTreeSet::new();
        for i in 0..sc.toks.len() {
            if !match_pattern(sc, i, rule.pattern) {
                continue;
            }
            let line = sc.toks[i].line;
            if !done_lines.insert(line) {
                continue;
            }
            if marker_at(sc, lines, line, RULE_API) {
                continue;
            }
            out.push(Finding {
                file: rel.to_string(),
                line,
                rule: RULE_API,
                message: format!(
                    "`{}` is forbidden here ({}); allowed under: {}",
                    rule.pattern.join(""),
                    rule.why,
                    rule.allowed.join(", ")
                ),
            });
        }
    }
}

fn lint_layering(rel: &str, sc: &Scan, lines: &[&str], out: &mut Vec<Finding>) {
    // lib.rs / main.rs / top-level tests sit above the layering map
    let module = match rel.split('/').next() {
        Some(first) if first.ends_with(".rs") => {
            first.trim_end_matches(".rs").to_string()
        }
        Some(first) => first.to_string(),
        None => return,
    };
    let allowed = match allowed_deps(&module) {
        Some(a) => a,
        None => return,
    };
    let mut done: std::collections::BTreeSet<(usize, String)> =
        std::collections::BTreeSet::new();
    let mut flag = |sc: &Scan, lines: &[&str], line: usize, dep: &str,
                    out: &mut Vec<Finding>,
                    done: &mut std::collections::BTreeSet<(usize, String)>| {
        if dep == module
            || !KNOWN_MODULES.contains(&dep)
            || allowed.contains(&dep)
            || !done.insert((line, dep.to_string()))
        {
            return;
        }
        if marker_at(sc, lines, line, RULE_LAYERING) {
            return;
        }
        out.push(Finding {
            file: rel.to_string(),
            line,
            rule: RULE_LAYERING,
            message: format!(
                "module `{}` must not depend on `crate::{}` (allowed deps: {})",
                module,
                dep,
                if allowed.is_empty() { "none".to_string() } else { allowed.join(", ") }
            ),
        });
    };
    let toks = &sc.toks;
    let mut i = 0;
    while i < toks.len() {
        if toks[i].text == "crate" && i + 1 < toks.len() && toks[i + 1].text == "::" {
            if i + 2 < toks.len() && toks[i + 2].text == "{" {
                // use crate::{a, b::c, ...}; — idents at path-start depth 1
                let mut j = i + 3;
                let mut depth = 1usize;
                let mut at_start = true;
                while j < toks.len() && depth > 0 {
                    match toks[j].text.as_str() {
                        "{" => { depth += 1; at_start = true; }
                        "}" => depth -= 1,
                        "," => at_start = true,
                        "::" => at_start = false,
                        t => {
                            if at_start && depth == 1 {
                                flag(sc, lines, toks[j].line, t, out, &mut done);
                            }
                            at_start = false;
                        }
                    }
                    j += 1;
                }
                i = j;
                continue;
            }
            if i + 2 < toks.len() {
                let dep = toks[i + 2].text.clone();
                flag(sc, lines, toks[i + 2].line, &dep, out, &mut done);
            }
        }
        i += 1;
    }
}

/// A marker that names a rule but carries no real justification is
/// itself a finding — otherwise the allow marker becomes a free mute
/// button.
fn lint_markers(rel: &str, sc: &Scan, out: &mut Vec<Finding>) {
    for (&line, text) in &sc.comments {
        // doc comments are rendered documentation: text *describing*
        // the marker syntax there is not a lint directive
        let t = text.trim_start();
        if t.starts_with("///") || t.starts_with("//!") {
            continue;
        }
        let mut rest = text.as_str();
        while let Some(pos) = rest.find("analyze: allow(") {
            rest = &rest[pos + "analyze: allow(".len()..];
            let close = match rest.find(')') {
                Some(c) => c,
                None => break,
            };
            let rule = &rest[..close];
            rest = &rest[close + 1..];
            let known = [RULE_SAFETY, RULE_API, RULE_LAYERING].contains(&rule);
            // the justification is whatever follows the marker up to the
            // next marker (or end of the comment block on this line)
            let just_end = rest.find("analyze: allow(").unwrap_or(rest.len());
            let just = rest[..just_end]
                .trim_start_matches([':', ' ', '-', '—'])
                .trim();
            if !known {
                out.push(Finding {
                    file: rel.to_string(),
                    line,
                    rule: RULE_MARKER,
                    message: format!("allow marker names unknown rule `{rule}`"),
                });
            } else if just.chars().filter(|c| c.is_alphanumeric()).count() < 8 {
                out.push(Finding {
                    file: rel.to_string(),
                    line,
                    rule: RULE_MARKER,
                    message: format!(
                        "allow({rule}) marker is missing a justification"
                    ),
                });
            }
        }
    }
}

fn match_pattern(sc: &Scan, i: usize, pattern: &[&str]) -> bool {
    if i + pattern.len() > sc.toks.len() {
        return false;
    }
    pattern
        .iter()
        .enumerate()
        .all(|(k, p)| sc.toks[i + k].text == *p)
}

/// Is `needle` present in a comment on `line` or in the contiguous
/// comment/attribute block above the statement containing `line`?
fn covered(sc: &Scan, lines: &[&str], line: usize, needle: &str) -> bool {
    walk_comments(sc, lines, line).any(|c| c.contains(needle))
}

/// Does an `analyze: allow(<rule>)` marker cover `line`?
fn marker_at(sc: &Scan, lines: &[&str], line: usize, rule: &str) -> bool {
    let want = format!("analyze: allow({rule})");
    walk_comments(sc, lines, line).any(|c| c.contains(&want))
}

/// Yield the comment text on `line`, then the comments of the contiguous
/// block above it: the walk skips attribute lines, blank lines, and
/// statement-continuation heads (code lines ending in `=`, `(` or `,` —
/// e.g. `let dst: &mut [f64] =` above an `unsafe { ... }` line), and
/// stops at the first other code line.
fn walk_comments<'a>(
    sc: &'a Scan,
    lines: &'a [&'a str],
    line: usize,
) -> impl Iterator<Item = &'a str> + 'a {
    let mut cur = line;
    let mut same_line_done = false;
    std::iter::from_fn(move || {
        if !same_line_done {
            same_line_done = true;
            if let Some(c) = sc.comment_on(line) {
                return Some(c);
            }
        }
        loop {
            if cur <= 1 {
                return None;
            }
            cur -= 1;
            let raw = lines.get(cur - 1).copied().unwrap_or("").trim();
            if raw.is_empty() || raw.starts_with("#[") || raw.starts_with("#!") {
                continue;
            }
            if raw.starts_with("//") || raw.starts_with("/*") || raw.starts_with('*') {
                // a pure comment line: yield its text
                if let Some(c) = sc.comment_on(cur) {
                    return Some(c);
                }
                continue;
            }
            if raw.ends_with('=') || raw.ends_with('(') || raw.ends_with(',') {
                // continuation head of the same statement: if it carries a
                // trailing comment, yield that too, then keep walking
                if let Some(c) = sc.comment_on(cur) {
                    return Some(c);
                }
                continue;
            }
            // real code above: if it ends with a trailing comment the
            // comment belongs to *that* statement, so stop here
            return None;
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint(rel: &str, src: &str) -> Vec<Finding> {
        lint_file(rel, src)
    }

    fn rules(fs: &[Finding]) -> Vec<&'static str> {
        fs.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn unsafe_without_safety_comment_is_flagged() {
        let fs = lint("quant/mod.rs", "fn f() { unsafe { g() } }\n");
        assert_eq!(rules(&fs), vec![RULE_SAFETY]);
        assert_eq!(fs[0].line, 1);
    }

    #[test]
    fn safety_comment_above_or_trailing_passes() {
        let ok = "// SAFETY: g is fine here.\nfn f() { unsafe { g() } }\n";
        assert!(lint("quant/mod.rs", ok).is_empty());
        let trailing = "fn f() { unsafe { g() } } // SAFETY: g is fine here.\n";
        assert!(lint("quant/mod.rs", trailing).is_empty());
    }

    #[test]
    fn safety_walk_skips_attrs_blanks_and_continuation_heads() {
        let src = "// SAFETY: covered by the partition argument.\n\
                   #[allow(dead_code)]\n\
                   let dst: &mut [f64] =\n\
                   unsafe { shared.range(0, 1) };\n";
        assert!(lint("linalg/x.rs", src).is_empty());
        let blocked = "fn other() {}\n// not a safety note\nlet x = 1;\nunsafe { g() }\n";
        assert_eq!(rules(&lint("linalg/x.rs", blocked)), vec![RULE_SAFETY]);
    }

    #[test]
    fn unsafe_in_comment_or_string_is_ignored() {
        let src = "// unsafe is discussed here\nlet s = \"unsafe\";\n";
        assert!(lint("quant/mod.rs", src).is_empty());
    }

    #[test]
    fn forbidden_api_outside_allowlist() {
        let fs = lint("quant/mod.rs", "let t0 = Instant::now();\n");
        assert_eq!(rules(&fs), vec![RULE_API]);
        assert!(fs[0].message.contains("Instant::now"));
        // same code under an allowlisted module passes
        assert!(lint("coordinator/soak.rs", "let t0 = Instant::now();\n").is_empty());
        assert!(lint("main.rs", "let t0 = Instant::now();\n").is_empty());
    }

    #[test]
    fn mutex_and_spawn_restricted_to_pool_and_engine() {
        assert_eq!(
            rules(&lint("sweep.rs", "static L: Mutex<()> = Mutex::new(());\n")),
            vec![RULE_API]
        );
        assert!(lint("par/mod.rs", "static L: Mutex<()> = Mutex::new(());\n").is_empty());
        assert_eq!(
            rules(&lint("data/mod.rs", "std::thread::spawn(|| {});\n")),
            vec![RULE_API]
        );
    }

    #[test]
    fn mul_add_only_in_gated_kernels() {
        assert_eq!(rules(&lint("lrc/mod.rs", "let y = a.mul_add(b, c);\n")), vec![RULE_API]);
        assert!(lint("linalg/simd.rs", "let y = a.mul_add(b, c);\n").is_empty());
        assert!(lint("quant/dequant.rs", "let y = a.mul_add(b, c);\n").is_empty());
    }

    #[test]
    fn allow_marker_with_justification_suppresses() {
        let src = "// analyze: allow(forbidden-api): wall-clock reporting only, \
                   never folded into deterministic reports.\n\
                   let t0 = Instant::now();\n";
        assert!(lint("pipeline.rs", src).is_empty());
    }

    #[test]
    fn bare_allow_marker_is_a_finding() {
        let src = "// analyze: allow(forbidden-api)\nlet t0 = Instant::now();\n";
        let fs = lint("pipeline.rs", src);
        assert_eq!(rules(&fs), vec![RULE_MARKER]);
        let unknown = "// analyze: allow(nonsense): because I said so, truly.\nlet x = 1;\n";
        assert_eq!(rules(&lint("pipeline.rs", unknown)), vec![RULE_MARKER]);
    }

    #[test]
    fn doc_comments_describing_markers_are_not_markers() {
        let src = "//! marker syntax: `// analyze: allow(<rule>): <why>`\n\
                   /// e.g. `// analyze: allow(nonsense)` would be flagged\n\
                   fn f() {}\n";
        assert!(lint("quant/mod.rs", src).is_empty());
    }

    #[test]
    fn layering_violation_and_allowed_edge() {
        let fs = lint("quant/mod.rs", "use crate::coordinator::Batcher;\n");
        assert_eq!(rules(&fs), vec![RULE_LAYERING]);
        assert!(fs[0].message.contains("coordinator"));
        assert!(lint("quant/mod.rs", "use crate::linalg::Mat;\n").is_empty());
        // doc comments never create edges
        assert!(lint("quant/mod.rs", "/// see [crate::sweep] for the grid\n").is_empty());
        // grouped imports are expanded
        let fs = lint("linalg/mod.rs", "use crate::{par::Pool, runtime::Engine};\n");
        assert_eq!(rules(&fs), vec![RULE_LAYERING]);
        assert!(fs[0].message.contains("runtime"));
    }

    #[test]
    fn layering_ignores_unknown_names_and_self() {
        let src = "use crate::artifacts_dir;\nuse crate::quant::pack;\n";
        assert!(lint("quant/mod.rs", src).is_empty());
    }

    #[test]
    fn fixture_paths_get_no_allowlist_credit() {
        // a fixture outside src/ hits every rule — the CI self-test
        // depends on this
        let fs = lint("fixture.rs", "fn f() { unsafe { g() } }\nlet l = Mutex::new(());\n");
        assert!(rules(&fs).contains(&RULE_SAFETY));
        assert!(rules(&fs).contains(&RULE_API));
    }
}
