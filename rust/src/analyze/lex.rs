//! Minimal Rust source scanner for the `analyze` lints.
//!
//! This is not a parser: the lints only need (a) the identifier/punct
//! token stream with comments and string literals stripped, and (b) the
//! comment text attached to each source line (for `// SAFETY:` and
//! `// analyze: allow(...)` lookups).  The scanner therefore handles
//! exactly the lexical features that can hide a false match: line and
//! (nested) block comments, string / raw-string / byte-string / char
//! literals, and lifetimes vs. char literals.

use std::collections::BTreeMap;

/// One token: an identifier, a number, `::`, or a single punct char.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Tok {
    pub text: String,
    pub line: usize,
}

/// Scan result: tokens plus per-line comment text (all comments that
/// start on or span a line, concatenated).
#[derive(Debug, Default)]
pub struct Scan {
    pub toks: Vec<Tok>,
    pub comments: BTreeMap<usize, String>,
}

impl Scan {
    pub fn comment_on(&self, line: usize) -> Option<&str> {
        self.comments.get(&line).map(|s| s.as_str())
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

pub fn scan(src: &str) -> Scan {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut out = Scan::default();
    let mut i = 0usize;
    let mut line = 1usize;

    fn note(out: &mut Scan, line: usize, text: &str) {
        let e = out.comments.entry(line).or_default();
        e.push_str(text);
        e.push(' ');
    }

    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // line comment (also covers /// and //! doc comments)
        if c == '/' && i + 1 < n && cs[i + 1] == '/' {
            let start = i;
            while i < n && cs[i] != '\n' {
                i += 1;
            }
            let text: String = cs[start..i].iter().collect();
            note(&mut out, line, &text);
            continue;
        }
        // block comment — Rust block comments nest
        if c == '/' && i + 1 < n && cs[i + 1] == '*' {
            let mut depth = 1usize;
            i += 2;
            let mut cur = String::from("/*");
            while i < n && depth > 0 {
                if cs[i] == '/' && i + 1 < n && cs[i + 1] == '*' {
                    depth += 1;
                    cur.push_str("/*");
                    i += 2;
                } else if cs[i] == '*' && i + 1 < n && cs[i + 1] == '/' {
                    depth -= 1;
                    cur.push_str("*/");
                    i += 2;
                } else {
                    if cs[i] == '\n' {
                        note(&mut out, line, &cur);
                        cur.clear();
                        line += 1;
                    } else {
                        cur.push(cs[i]);
                    }
                    i += 1;
                }
            }
            if !cur.is_empty() {
                note(&mut out, line, &cur);
            }
            continue;
        }
        // raw strings r"..." / r#"..."# (and br variants); must be
        // checked before the identifier branch eats the leading r/b
        if (c == 'r' || c == 'b') && raw_string_lookahead(&cs, i).is_some() {
            let (hashes, body_start) = raw_string_lookahead(&cs, i).unwrap();
            i = body_start;
            'raw: while i < n {
                if cs[i] == '\n' {
                    line += 1;
                    i += 1;
                    continue;
                }
                if cs[i] == '"' {
                    let mut k = 0usize;
                    while k < hashes && i + 1 + k < n && cs[i + 1 + k] == '#' {
                        k += 1;
                    }
                    if k == hashes {
                        i += 1 + hashes;
                        break 'raw;
                    }
                }
                i += 1;
            }
            continue;
        }
        // byte string b"..." / byte char b'.'
        if c == 'b' && i + 1 < n && (cs[i + 1] == '"' || cs[i + 1] == '\'') {
            i += 1; // fall through to the "/' branches below via cs[i]
            if cs[i] == '"' {
                i = consume_string(&cs, i, &mut line);
            } else {
                i = consume_char_or_lifetime(&cs, i);
            }
            continue;
        }
        if c == '"' {
            i = consume_string(&cs, i, &mut line);
            continue;
        }
        if c == '\'' {
            i = consume_char_or_lifetime(&cs, i);
            continue;
        }
        if is_ident_start(c) {
            let start = i;
            while i < n && is_ident_cont(cs[i]) {
                i += 1;
            }
            out.toks.push(Tok { text: cs[start..i].iter().collect(), line });
            continue;
        }
        if c.is_ascii_digit() {
            // numbers (incl. float suffixes); stop before `..` ranges
            let start = i;
            while i < n && (is_ident_cont(cs[i]) || cs[i] == '.') {
                if cs[i] == '.' && i + 1 < n && cs[i + 1] == '.' {
                    break;
                }
                i += 1;
            }
            out.toks.push(Tok { text: cs[start..i].iter().collect(), line });
            continue;
        }
        if c == ':' && i + 1 < n && cs[i + 1] == ':' {
            out.toks.push(Tok { text: "::".into(), line });
            i += 2;
            continue;
        }
        out.toks.push(Tok { text: c.to_string(), line });
        i += 1;
    }
    out
}

/// If `cs[i]` starts a raw (byte) string, return (hash count, index of
/// the first body char).
fn raw_string_lookahead(cs: &[char], i: usize) -> Option<(usize, usize)> {
    let n = cs.len();
    let mut j = i;
    if cs[j] == 'b' {
        j += 1;
    }
    if j >= n || cs[j] != 'r' {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while j < n && cs[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j < n && cs[j] == '"' {
        Some((hashes, j + 1))
    } else {
        None
    }
}

/// Consume a normal string literal starting at `cs[i] == '"'`; returns
/// the index just past the closing quote.
fn consume_string(cs: &[char], mut i: usize, line: &mut usize) -> usize {
    let n = cs.len();
    i += 1;
    while i < n {
        match cs[i] {
            // an escape may be a `\<newline>` line continuation — the
            // newline it hides must still advance the line counter or
            // every token after the string is attributed a short line
            '\\' => {
                if i + 1 < n && cs[i + 1] == '\n' {
                    *line += 1;
                }
                i += 2;
            }
            '"' => return i + 1,
            '\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

/// Consume a char literal (`'x'`, `'\n'`, `'\u{1F600}'`) or step past a
/// lifetime tick (`'a` — the following ident is lexed normally, which
/// is harmless for the lint patterns).
fn consume_char_or_lifetime(cs: &[char], i: usize) -> usize {
    let n = cs.len();
    if i + 1 < n && cs[i + 1] == '\\' {
        // escaped char literal: scan to the closing quote
        let mut j = i + 2;
        while j < n && cs[j] != '\'' {
            j += 1;
        }
        return (j + 1).min(n);
    }
    if i + 2 < n && cs[i + 2] == '\'' {
        return i + 3; // plain 'x'
    }
    i + 1 // lifetime tick
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        scan(src).toks.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn strips_line_and_block_comments() {
        let src = "let x = 1; // unsafe Mutex in a comment\n/* Instant::now\n   spans lines */ let y;\n";
        let t = texts(src);
        assert!(!t.iter().any(|s| s == "unsafe" || s == "Mutex" || s == "Instant"));
        assert!(t.iter().any(|s| s == "y"));
        let s = scan(src);
        assert!(s.comment_on(1).unwrap().contains("Mutex"));
        assert!(s.comment_on(2).unwrap().contains("Instant"));
    }

    #[test]
    fn nested_block_comments() {
        let t = texts("/* outer /* inner unsafe */ still comment */ fn f() {}");
        assert_eq!(t[0], "fn");
    }

    #[test]
    fn strips_strings_and_raw_strings() {
        let t = texts(r##"let s = "unsafe \" Mutex"; let r = r#"Instant::now "quoted""#; done"##);
        assert!(!t.iter().any(|s| s == "unsafe" || s == "Mutex" || s == "Instant"));
        assert!(t.iter().any(|s| s == "done"));
    }

    #[test]
    fn lifetimes_and_char_literals() {
        let t = texts("fn f<'a>(x: &'a str) { let c = '\\n'; let q = '\"'; let z = 'Z'; }");
        // the '"' char literal must not open a string that swallows the rest
        assert!(t.iter().any(|s| s == "z"));
        assert!(!t.iter().any(|s| s == "Z"));
    }

    #[test]
    fn line_continuation_in_string_still_counts_the_line() {
        let src = "let s = \"a \\\n         b\";\nInstant::now()\n";
        let s = scan(src);
        assert!(s.toks.iter().any(|t| t.text == "Instant" && t.line == 3));
    }

    #[test]
    fn tracks_lines_and_double_colon() {
        let s = scan("a\nInstant::now()\n");
        let pos: Vec<(String, usize)> =
            s.toks.iter().map(|t| (t.text.clone(), t.line)).collect();
        assert!(pos.contains(&("Instant".into(), 2)));
        assert!(pos.contains(&("::".into(), 2)));
        assert!(pos.contains(&("now".into(), 2)));
    }
}
