//! `lrc analyze` — in-repo correctness tooling: a zero-dependency
//! source lint that mechanically enforces the crate's standing
//! contracts on every CI run instead of trusting desk checks.
//!
//! Three lint families (see [`lints`]):
//!
//! * **safety-comment** — every `unsafe` token must carry a
//!   `// SAFETY:` argument on the same line or immediately above.
//! * **forbidden-api** — concurrency primitives (`thread::spawn`,
//!   `Mutex`, `Condvar`) outside `par/`/`coordinator/`, wall-clock
//!   reads (`Instant::now`, `SystemTime`) outside
//!   `bench`/`coordinator`/`main`, and `mul_add` outside the gated FMA
//!   kernels are findings; justified exceptions carry an inline
//!   `// analyze: allow(<rule>): <why>` marker.
//! * **layering** — `crate::<mod>` references must respect the module
//!   layering map (compute layers never depend on `coordinator` /
//!   `runtime`).
//!
//! Deny-by-default: `lrc analyze --deny-all <paths>` exits non-zero on
//! any finding, which is how CI consumes it.  Findings render as
//! `file:line: [rule] message` lines or as a JSON array (`--json`).

pub mod lex;
pub mod lints;

use std::io::Read;
use std::path::{Path, PathBuf};

use crate::util::Json;

/// One lint finding, machine-readable.
#[derive(Debug, Clone)]
pub struct Finding {
    /// path as given on the command line (display) — allowlist matching
    /// uses the `src/`-relative form computed internally
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

/// Recursively collect `.rs` files under `path` (or `path` itself if it
/// is a file), sorted for deterministic output.
pub fn collect_rs_files(path: &Path) -> std::io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    if path.is_file() {
        out.push(path.to_path_buf());
        return Ok(out);
    }
    let mut stack = vec![path.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir)? {
            let p = entry?.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
                out.push(p);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// The `src/`-relative module path used for allowlist matching: the
/// components after the *last* `src` component, joined with `/`.
/// Paths with no `src` component (CI fixture files) keep their file
/// name only, so they get no allowlist credit.
pub fn module_rel(path: &Path) -> String {
    let comps: Vec<String> = path
        .components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect();
    match comps.iter().rposition(|c| c == "src") {
        Some(i) if i + 1 < comps.len() => comps[i + 1..].join("/"),
        _ => comps.last().cloned().unwrap_or_default(),
    }
}

/// Analyze every `.rs` file under the given paths.  Returns the
/// findings plus the number of files scanned.
pub fn analyze_paths(paths: &[PathBuf]) -> std::io::Result<(Vec<Finding>, usize)> {
    let mut findings = Vec::new();
    let mut nfiles = 0usize;
    for root in paths {
        for file in collect_rs_files(root)? {
            let mut src = String::new();
            std::fs::File::open(&file)?.read_to_string(&mut src)?;
            nfiles += 1;
            let rel = module_rel(&file);
            for mut f in lints::lint_file(&rel, &src) {
                f.file = file.display().to_string();
                findings.push(f);
            }
        }
    }
    findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok((findings, nfiles))
}

/// `file:line: [rule] message` lines plus a summary — grep-friendly.
pub fn render_text(findings: &[Finding], nfiles: usize) -> String {
    let mut out = String::new();
    for f in findings {
        out.push_str(&format!(
            "{}:{}: [{}] {}\n",
            f.file, f.line, f.rule, f.message
        ));
    }
    out.push_str(&format!(
        "analyze: {} finding(s) in {} file(s)\n",
        findings.len(),
        nfiles
    ));
    out
}

/// JSON array of findings (machine-readable CI artifact).
pub fn render_json(findings: &[Finding]) -> String {
    Json::Arr(
        findings
            .iter()
            .map(|f| {
                Json::obj(vec![
                    ("file", Json::str(f.file.clone())),
                    ("line", Json::num(f.line as f64)),
                    ("rule", Json::str(f.rule)),
                    ("message", Json::str(f.message.clone())),
                ])
            })
            .collect(),
    )
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn module_rel_strips_to_last_src() {
        assert_eq!(module_rel(Path::new("rust/src/par/mod.rs")), "par/mod.rs");
        assert_eq!(module_rel(Path::new("/a/b/src/linalg/simd.rs")), "linalg/simd.rs");
        assert_eq!(module_rel(Path::new("src/main.rs")), "main.rs");
        // fixtures keep only the file name → no allowlist credit
        assert_eq!(module_rel(Path::new("/tmp/fixture/bad.rs")), "bad.rs");
    }

    #[test]
    fn render_json_shape() {
        let f = Finding {
            file: "x.rs".into(),
            line: 3,
            rule: lints::RULE_API,
            message: "nope".into(),
        };
        let j = Json::parse(&render_json(&[f])).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("line").unwrap().as_usize().unwrap(), 3);
        assert_eq!(arr[0].get("rule").unwrap().as_str().unwrap(), "forbidden-api");
    }

    #[test]
    fn analyze_paths_scans_a_tree() {
        let dir = std::env::temp_dir().join(format!(
            "lrc_analyze_test_{}",
            std::process::id()
        ));
        let sub = dir.join("src").join("quant");
        std::fs::create_dir_all(&sub).unwrap();
        std::fs::write(sub.join("bad.rs"), "fn f() { unsafe { g() } }\n").unwrap();
        std::fs::write(sub.join("ok.rs"), "fn g() {}\n").unwrap();
        let (findings, nfiles) = analyze_paths(&[dir.clone()]).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert_eq!(nfiles, 2);
        assert_eq!(findings.len(), 1);
        assert_eq!(findings[0].rule, lints::RULE_SAFETY);
        assert!(findings[0].file.ends_with("bad.rs"));
    }
}
