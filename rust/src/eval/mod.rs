//! Evaluation: perplexity + multiple-choice accuracy, generic over a logits
//! provider so the same code scores PJRT-backed models and mock models in
//! tests.  This is the lm-eval substitute producing the numbers in
//! Tables 1–3 / Figures 2–4.

use crate::data::tasks::{scoring_row, Task};
use crate::data::Corpus;

/// Anything that maps a [batch, seq_len] token block to [batch, seq_len,
/// vocab] logits (flat row-major f32).
pub trait LogitsProvider {
    fn batch(&self) -> usize;
    fn seq_len(&self) -> usize;
    fn vocab(&self) -> usize;
    fn logits(&mut self, tokens: &[i32]) -> Result<Vec<f32>, String>;
}

/// log-softmax of one row of logits, returning logprob of `target`.
fn logprob_of(logits_row: &[f32], target: i32) -> f64 {
    let max = logits_row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
    let mut sum = 0.0_f64;
    for &v in logits_row {
        sum += ((v as f64) - max).exp();
    }
    (logits_row[target as usize] as f64) - max - sum.ln()
}

/// Token-level perplexity on the held-out tail of a corpus
/// (paper: WikiText-2 PPL column).
pub fn perplexity<P: LogitsProvider>(p: &mut P, corpus: &Corpus,
                                     max_seqs: usize)
                                     -> Result<f64, String> {
    let seq_len = p.seq_len();
    let vocab = p.vocab();
    let seqs = corpus.eval_sequences(seq_len, max_seqs);
    if seqs.is_empty() {
        return Err("no eval sequences".into());
    }
    let mut nll = 0.0_f64;
    let mut count = 0usize;
    for (flat, used) in crate::data::batch_sequences(&seqs, p.batch()) {
        let logits = p.logits(&flat)?;
        for row in 0..used {
            for t in 0..seq_len - 1 {
                let target = flat[row * seq_len + t + 1];
                let off = (row * seq_len + t) * vocab;
                nll -= logprob_of(&logits[off..off + vocab], target);
                count += 1;
            }
        }
    }
    Ok((nll / count as f64).exp())
}

/// Accuracy on one multiple-choice task: pick the choice with the highest
/// *length-normalised* continuation logprob (lm-eval `acc_norm` protocol).
pub fn task_accuracy<P: LogitsProvider>(p: &mut P, task: &Task)
                                        -> Result<f64, String> {
    let seq_len = p.seq_len();
    let vocab = p.vocab();
    // build all scoring rows
    let mut rows = Vec::new();
    for item in &task.items {
        for choice in &item.choices {
            rows.push(scoring_row(&item.prompt, choice, seq_len));
        }
    }
    let flat_rows: Vec<Vec<i32>> = rows.iter().map(|r| r.tokens.clone()).collect();
    let mut scores = vec![0.0_f64; rows.len()];
    let mut idx = 0usize;
    for (flat, used) in crate::data::batch_sequences(&flat_rows, p.batch()) {
        let logits = p.logits(&flat)?;
        for row in 0..used {
            let sr = &rows[idx];
            let mut lp = 0.0_f64;
            for t in sr.start..sr.end {
                let target = flat[row * seq_len + t + 1];
                let off = (row * seq_len + t) * vocab;
                lp += logprob_of(&logits[off..off + vocab], target);
            }
            scores[idx] = lp / (sr.end - sr.start).max(1) as f64;
            idx += 1;
        }
    }
    // argmax per item
    let mut correct = 0usize;
    let mut cursor = 0usize;
    for item in &task.items {
        let n = item.choices.len();
        let mut best = 0usize;
        for j in 1..n {
            if scores[cursor + j] > scores[cursor + best] {
                best = j;
            }
        }
        if best == item.answer {
            correct += 1;
        }
        cursor += n;
    }
    Ok(correct as f64 / task.items.len() as f64)
}

/// Run every task; returns (per-task accuracy, average).
pub fn all_task_accuracies<P: LogitsProvider>(p: &mut P, tasks: &[Task])
                                              -> Result<(Vec<(String, f64)>, f64), String> {
    let mut out = Vec::new();
    let mut sum = 0.0;
    for t in tasks {
        let acc = task_accuracy(p, t)?;
        sum += acc;
        out.push((t.name.clone(), acc));
    }
    let avg = sum / tasks.len().max(1) as f64;
    Ok((out, avg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::TaskItem;

    /// Mock provider: a bigram model that strongly predicts next = cur + 1.
    struct Mock {
        batch: usize,
        seq: usize,
        vocab: usize,
    }

    impl LogitsProvider for Mock {
        fn batch(&self) -> usize {
            self.batch
        }
        fn seq_len(&self) -> usize {
            self.seq
        }
        fn vocab(&self) -> usize {
            self.vocab
        }
        fn logits(&mut self, tokens: &[i32]) -> Result<Vec<f32>, String> {
            let mut out = vec![0.0f32; self.batch * self.seq * self.vocab];
            for r in 0..self.batch {
                for t in 0..self.seq {
                    let cur = tokens[r * self.seq + t] as usize;
                    let pred = (cur + 1) % self.vocab;
                    out[(r * self.seq + t) * self.vocab + pred] = 8.0;
                }
            }
            Ok(out)
        }
    }

    #[test]
    fn perplexity_low_on_predictable_stream() {
        let mut p = Mock { batch: 2, seq: 8, vocab: 16 };
        // corpus = 0,1,2,...,15,0,1,... exactly the mock's prediction
        let tokens: Vec<i32> = (0..1600).map(|i| (i % 16) as i32).collect();
        let text = crate::data::detokenize(&tokens);
        let corpus = Corpus::from_text("cyc", &text);
        let ppl = perplexity(&mut p, &corpus, 4).unwrap();
        assert!(ppl < 2.0, "ppl {ppl}");
        // and high on a constant stream the mock never predicts
        let tokens2: Vec<i32> = vec![5; 1600];
        let corpus2 = Corpus::from_text("const", &crate::data::detokenize(&tokens2));
        let ppl2 = perplexity(&mut p, &corpus2, 4).unwrap();
        assert!(ppl2 > ppl * 2.0, "{ppl2} vs {ppl}");
    }

    #[test]
    fn task_scoring_picks_predictable_choice() {
        let mut p = Mock { batch: 4, seq: 16, vocab: 256 };
        // prompt "ab" ends at 'b'=98; correct continuation follows the +1
        // chain "cde"; distractors don't.
        let item = TaskItem {
            prompt: "ab".into(),
            choices: vec!["zzz".into(), "cde".into(), "qqq".into(), "mmm".into()],
            answer: 1,
        };
        let task = Task { name: "t".into(), items: vec![item; 5] };
        let acc = task_accuracy(&mut p, &task).unwrap();
        assert_eq!(acc, 1.0);
    }

    #[test]
    fn average_over_tasks() {
        let mut p = Mock { batch: 2, seq: 16, vocab: 256 };
        let good = Task {
            name: "good".into(),
            items: vec![TaskItem {
                prompt: "ab".into(),
                choices: vec!["cd".into(), "xx".into()],
                answer: 0,
            }],
        };
        let bad = Task {
            name: "bad".into(),
            items: vec![TaskItem {
                prompt: "ab".into(),
                choices: vec!["cd".into(), "xx".into()],
                answer: 1, // mock will pick "cd" → wrong
            }],
        };
        let (per, avg) = all_task_accuracies(&mut p, &[good, bad]).unwrap();
        assert_eq!(per[0].1, 1.0);
        assert_eq!(per[1].1, 0.0);
        assert!((avg - 0.5).abs() < 1e-12);
    }
}
