//! `lrc` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   info                          list models/graphs in artifacts/
//!   quantize --model M --method Q quantize natively (calibrate → bundle)
//!   eval --model M --graph G      perplexity + task accuracy of a variant
//!   serve --model M               serving demo with the dynamic batcher
//!
//! Global flags: `--threads N` sizes the compute pool (else the
//! `LRC_THREADS` env var, else every core); `--simd B` pins the GEMM
//! micro-kernel backend (else `LRC_SIMD`, else auto-detection — results
//! are bit-identical on every backend); `serve --workers N` runs N PJRT
//! engine workers against the shared batch queue.
//!
//! Run `lrc <cmd> --help` equivalent: every flag has a default, see below.

use std::time::Duration;

use anyhow::{anyhow, Result};

use lrc::coordinator::{BatchPolicy, ServerConfig, ServerHandle};
use lrc::data::Corpus;
use lrc::experiments::{self, EvalBudget};
use lrc::pipeline::Method;
use lrc::quant::{QuantConfig, Quantizer};
use lrc::runtime::{Engine, ModelArtifacts, TensorBundle};
use lrc::util::{render_table, Args};

fn main() {
    let args = Args::from_env();
    // global parallelism: --threads N > LRC_THREADS env > all cores
    if let Some(s) = args.get("threads") {
        match s.parse::<usize>() {
            Ok(n) if n > 0 => lrc::par::set_threads(n),
            _ => {
                eprintln!("error: --threads expects a positive integer, \
                           got {s:?}");
                std::process::exit(2);
            }
        }
    }
    // SIMD backend: --simd B > LRC_SIMD env > runtime detection
    if let Some(s) = args.get("simd") {
        let sel = match lrc::linalg::simd::Backend::parse(s) {
            Ok(sel) => sel,
            Err(e) => {
                eprintln!("error: --simd: {e}");
                std::process::exit(2);
            }
        };
        if let Err(e) = lrc::linalg::simd::set_backend(sel) {
            eprintln!("error: --simd: {e}");
            std::process::exit(2);
        }
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let res = match cmd {
        "info" => cmd_info(&args),
        "quantize" => cmd_quantize(&args),
        "eval" => cmd_eval(&args),
        "serve" => cmd_serve(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = res {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "lrc — Low-Rank Correction for Quantized LLMs (rust coordinator)\n\
         \n\
         USAGE: lrc <info|quantize|eval|serve> [flags]\n\
         \n\
         quantize --model small --method lrc|svd|quarot --pct 10\n\
         \x20        [--iters 1] [--group 32] [--weight-only] [--rtn]\n\
         \x20        [--calib 128] [--corpus wiki_syn]\n\
         eval     --model small --graph fwd_w4a4_r10_b8 [--quant <dir>]\n\
         \x20        [--fast]\n\
         serve    --model small [--prefix fwd_w4a4_r10] [--quant <dir>]\n\
         \x20        [--requests 64] [--max-wait-ms 5] [--workers 1]\n\
         \n\
         global flags:\n\
         \x20 --threads N   size of the persistent compute pool (parked\n\
         \x20               worker threads) shared by calibration, the\n\
         \x20               per-layer quantization fan-out and the\n\
         \x20               blocked-k GEMM/Gram kernels (default:\n\
         \x20               LRC_THREADS env — read once at startup —\n\
         \x20               else all cores; results are bit-identical\n\
         \x20               at any setting)\n\
         \x20 --simd B      GEMM micro-kernel backend: auto|scalar|sse2|\n\
         \x20               avx2|neon (default: LRC_SIMD env, else the\n\
         \x20               widest the host supports; every backend is\n\
         \x20               bit-identical — this knob is for benches and\n\
         \x20               debugging, errors if B can't run here)\n\
         \x20 --workers N   serve-only: engine workers sharing the batch\n\
         \x20               queue, one PJRT engine + session set each;\n\
         \x20               the thread budget is split across workers\n\
         \x20               for per-row NLL scoring\n"
    );
}

fn load_corpus(name: &str) -> Result<Corpus> {
    let path = lrc::artifacts_dir().join("corpus").join(format!("{name}.txt"));
    Ok(Corpus::load(&path)?)
}

fn cmd_info(_args: &Args) -> Result<()> {
    let art = lrc::artifacts_dir();
    println!("artifacts: {art:?}");
    let models = std::fs::read_dir(art.join("models"))?;
    for m in models.flatten() {
        let arts = ModelArtifacts::load(&m.path())?;
        println!("\nmodel {} — d={} L={} heads={} ff={} experts={} params={}",
                 arts.info.name, arts.info.d_model, arts.info.n_layers,
                 arts.info.n_heads, arts.info.d_ff, arts.info.n_experts,
                 arts.info.param_count);
        for (name, g) in &arts.graphs {
            println!("  graph {name:<24} batch={} params={}",
                     g.batch, g.params.len());
        }
    }
    Ok(())
}

fn quant_config(args: &Args) -> QuantConfig {
    QuantConfig {
        w_bits: 4,
        a_bits: if args.has("weight-only") { None } else { Some(4) },
        a_group: args.get("group").and_then(|g| g.parse().ok()),
        quantizer: if args.has("rtn") { Quantizer::Rtn } else { Quantizer::Gptq },
        rank_pct: args.get_f64("pct", 10.0) / 100.0,
        iters: args.get_usize("iters", 1),
    }
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let model = args.get_or("model", "small");
    let method = Method::parse(&args.get_or("method", "lrc"))?;
    let cfg = quant_config(args);
    let pct = args.get_usize("pct", 10);
    let graph = experiments::quant_graph_name(
        pct, cfg.a_group, args.has("weight-only"), 8);
    let corpus = load_corpus(&args.get_or("corpus", "wiki_syn"))?;
    let engine = Engine::cpu()?;
    let arts = ModelArtifacts::load(&lrc::artifacts_dir().join("models").join(&model))?;
    let n_calib = args.get_usize("calib", 128);
    println!("quantizing {model} with {} against {graph} ({n_calib} calib seqs)",
             method.label(&cfg));
    let (_bundle, report) = lrc::pipeline::quantize_and_save(
        &engine, &arts, &corpus, &graph, method, &cfg, n_calib)?;
    println!("calibration: {:.1}s, quantization: {:.1}s",
             report.calib_seconds, report.quant_seconds);
    println!("mean relative layer error: {:.4}", report.mean_rel_error());
    println!("packed size: {:.2} MB (int4 {:.2} MB + fp16 low-rank {:.2} MB + fp16 rest {:.2} MB)",
             report.size_bytes() as f64 / 1e6,
             report.packed_bytes as f64 / 1e6,
             report.lowrank_params as f64 * 2.0 / 1e6,
             report.fp_params as f64 * 2.0 / 1e6);
    for l in report.layers.iter().take(4) {
        println!("  {:<16} k={:<3} relerr={:.5}", l.layer, l.rank, l.rel_error);
    }
    println!("  ... ({} layers total)", report.layers.len());
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model = args.get_or("model", "small");
    let graph = args.get_or("graph", "fwd_fp_b8");
    let budget = if args.has("fast") { EvalBudget::fast() } else { EvalBudget::full() };
    let engine = Engine::cpu()?;
    let art = lrc::artifacts_dir();
    let arts = ModelArtifacts::load(&art.join("models").join(&model))?;
    let corpus = load_corpus(&args.get_or("corpus", "wiki_syn"))?;
    let tasks = experiments::load_tasks(&art, budget)?;
    let quant = match args.get("quant") {
        Some(d) => Some(TensorBundle::load(std::path::Path::new(d))?),
        None => None,
    };
    let scores = experiments::evaluate_graph(
        &engine, &arts, &graph, quant.as_ref(), &corpus, &tasks, budget,
        &graph)?;
    println!("{}", render_table(&experiments::TABLE_HEADERS,
                                &[scores.cells()]));
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let model = args.get_or("model", "small");
    let prefix = args.get_or("prefix", "fwd_fp");
    let art = lrc::artifacts_dir();
    let model_dir = art.join("models").join(&model);
    let quant_dir = args.get("quant").map(std::path::PathBuf::from);
    let n_requests = args.get_usize("requests", 64);

    let handle = ServerHandle::start(ServerConfig {
        model_dir,
        graph_prefix: prefix.clone(),
        quant_dir,
        policy: BatchPolicy {
            max_batch: args.get_usize("max-batch", 8),
            max_wait: Duration::from_millis(args.get_usize("max-wait-ms", 5) as u64),
            max_queue: 4096,
        },
        workers: args.get_usize("workers", 1),
    })?;
    println!("serving {model}/{prefix} (seq_len={}, workers={})",
             handle.seq_len, handle.metrics.per_worker.len());

    // demo traffic from the held-out corpus
    let corpus = load_corpus("wiki_syn")?;
    let seqs = corpus.eval_sequences(handle.seq_len, n_requests);
    if seqs.is_empty() {
        return Err(anyhow!("no eval sequences available"));
    }
    let mut pending = Vec::new();
    for s in seqs.iter().cycle().take(n_requests) {
        pending.push(handle.submit(s.clone())?);
    }
    let mut mean_nll = 0.0;
    for rx in pending {
        let resp = rx.recv()?;
        mean_nll += resp.mean_nll / n_requests as f64;
    }
    println!("mean per-seq NLL: {mean_nll:.4} (ppl {:.2})", mean_nll.exp());
    let snap = handle.shutdown();
    println!("{}", snap.render());
    Ok(())
}
