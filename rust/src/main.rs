//! `lrc` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   info                          list models/graphs in artifacts/
//!   quantize --model M --method Q quantize natively (calibrate → bundle)
//!   eval --model M --graph G      perplexity + task accuracy of a variant
//!   sweep [--fast] [--model M]    method × bits × rank × group grid
//!                                 driver with shared calibration + resume
//!                                 through the content-addressed registry;
//!                                 --serve ADDR dispatches the grid to
//!                                 sweep-worker processes instead
//!   sweep-worker --connect ADDR   claim/compute/publish cells against a
//!                                 `sweep --serve` dispatcher
//!   bench-trend --current J       compare a bench JSON against baseline
//!                                 artifacts (the CI regression gate)
//!   serve --model M               serving demo with the dynamic batcher
//!   soak [--fast] [--live]        deterministic synthetic-traffic soak:
//!                                 Poisson arrivals, bursts, adversarial
//!                                 deadlines, admission + shedding
//!   chaos [--fast]                deterministic fault-injection harness:
//!                                 in-process sweep fleets run under a
//!                                 seeded FaultPlan (resets, torn writes,
//!                                 crashes, poison cells); the merged
//!                                 report must be byte-identical to the
//!                                 fault-free single-box run
//!   registry ls --root R          inspect a content-addressed registry:
//!                                 list objects with verify status
//!   analyze [paths..] [--deny-all] in-repo source lint: SAFETY-comment,
//!                                 forbidden-API and module-layering
//!                                 checks (what the CI analyze job runs)
//!
//! Global flags: `--threads N` sizes the compute pool (else the
//! `LRC_THREADS` env var, else every core); `--simd B` pins the GEMM
//! micro-kernel backend (else `LRC_SIMD`, else auto-detection — results
//! are bit-identical on every backend); `--fma` opts into the fused
//! multiply-add kernel program (else `LRC_FMA=1`; off by default because
//! it changes the canonical accumulation — still deterministic, with its
//! own lockstep oracle reference); `serve --workers N` runs N PJRT
//! engine workers against the shared batch queue.
//!
//! Run `lrc <cmd> --help` equivalent: every flag has a default, see below.

use std::time::Duration;

use anyhow::{anyhow, Result};

use lrc::coordinator::{BatchPolicy, Outcome, ServerConfig, ServerHandle};
use lrc::data::Corpus;
use lrc::experiments::{self, EvalBudget};
use lrc::pipeline::Method;
use lrc::quant::{QuantConfig, Quantizer};
use lrc::runtime::{Engine, ModelArtifacts, TensorBundle};
use lrc::util::{render_table, Args};

fn main() {
    let args = Args::from_env();
    // global parallelism: --threads N > LRC_THREADS env > all cores
    if let Some(s) = args.get("threads") {
        match s.parse::<usize>() {
            Ok(n) if n > 0 => lrc::par::set_threads(n),
            _ => {
                eprintln!("error: --threads expects a positive integer, \
                           got {s:?}");
                std::process::exit(2);
            }
        }
    }
    // SIMD backend: --simd B > LRC_SIMD env > runtime detection
    if let Some(s) = args.get("simd") {
        let sel = match lrc::linalg::simd::Backend::parse(s) {
            Ok(sel) => sel,
            Err(e) => {
                eprintln!("error: --simd: {e}");
                std::process::exit(2);
            }
        };
        if let Err(e) = lrc::linalg::simd::set_backend(sel) {
            eprintln!("error: --simd: {e}");
            std::process::exit(2);
        }
    }
    // FMA mode: --fma > LRC_FMA env > off.  Opt-in because it changes
    // the canonical accumulation program (fused rounding) — results stay
    // deterministic at every thread count / backend, but differ in the
    // last bits from the default mul-then-add program.
    if args.has("fma") {
        lrc::linalg::simd::set_fma(Some(true));
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let res = match cmd {
        "info" => cmd_info(&args),
        "quantize" => cmd_quantize(&args),
        "eval" => cmd_eval(&args),
        "sweep" => cmd_sweep(&args),
        "sweep-worker" => cmd_sweep_worker(&args),
        "bench-trend" => cmd_bench_trend(&args),
        "serve" => cmd_serve(&args),
        "soak" => cmd_soak(&args),
        "chaos" => cmd_chaos(&args),
        "registry" => cmd_registry(&args),
        "analyze" => cmd_analyze(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = res {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "lrc — Low-Rank Correction for Quantized LLMs (rust coordinator)\n\
         \n\
         USAGE: lrc <info|quantize|eval|sweep|sweep-worker|serve|soak|\n\
         \x20            chaos|registry|analyze> [flags]\n\
         \n\
         quantize --model small --method lrc|svd|quarot --pct 10\n\
         \x20        [--iters 1] [--group 32] [--weight-only] [--rtn]\n\
         \x20        [--calib 128] [--corpus wiki_syn] [--registry <root>]\n\
         \x20        With --registry, the content-addressed artifact store\n\
         \x20        at <root> is consulted first: a hit re-materializes\n\
         \x20        the published bundle with zero quantization compute\n\
         \x20        (no engine, no calibration), a miss computes and then\n\
         \x20        publishes bundle + report under the content digest.\n\
         eval     --model small --graph fwd_w4a4_r10_b8 [--quant <dir>]\n\
         \x20        [--fast] [--native]\n\
         sweep    [--fast] [--model small] [--methods rtn,quarot,svd,lrc]\n\
         \x20        [--bits 2,3,4,8] [--pcts 0,5,10,20,30]\n\
         \x20        [--groups none,32] [--iters 1] [--out <dir>]\n\
         \x20        [--no-resume] [--seed 2024] [--calib 128]\n\
         \x20        [--corpus wiki_syn] [--registry <root>]\n\
         \x20        [--serve <host:port>] [--lease 30000]\n\
         \x20        [--quarantine-after 3]\n\
         \x20        Grid driver over method x w_bits x rank_pct x group:\n\
         \x20        calibration stats are collected once per group value\n\
         \x20        and shared by every cell; independent cells fan out\n\
         \x20        on the compute pool in canonical order, so the grid\n\
         \x20        report (report.json + report.md under --out) is\n\
         \x20        byte-identical at any --threads.  Finished cells\n\
         \x20        persist as content-addressed objects in the registry\n\
         \x20        (--registry <root>, default <out>/registry; legacy\n\
         \x20        <out>/cells/ fragments are migrated in on first read)\n\
         \x20        and are skipped on re-run (--no-resume recomputes).\n\
         \x20        --serve <host:port> turns the driver into a cell\n\
         \x20        dispatcher: sweep-worker processes claim cells over\n\
         \x20        the line protocol, results land in the same registry,\n\
         \x20        and the merged report is byte-identical to a\n\
         \x20        single-box run at any worker count.  A claim held\n\
         \x20        longer than --lease poll iterations (2 ms each;\n\
         \x20        0 = no lease) is requeued, and a cell failed by\n\
         \x20        workers --quarantine-after times (0 = never) is\n\
         \x20        quarantined: pulled from the grid, listed in the\n\
         \x20        summary, exit is non-zero.\n\
         \x20        Without --model the grid runs on a deterministic\n\
         \x20        in-memory synthetic model (no PJRT needed — what CI\n\
         \x20        runs); --fast is the 8-cell CI smoke grid.  Exits\n\
         \x20        non-zero if a built-in sanity assertion fails\n\
         \x20        (gptq<=rtn per cell, error non-increasing in rank,\n\
         \x20        size strictly increasing in bits).\n\
         sweep-worker --connect <host:port> [--name <id>]\n\
         \x20        One distributed sweep worker: claims cells from a\n\
         \x20        `sweep --serve` dispatcher, recomputes them on the\n\
         \x20        local pool (same canonical math as single-box) and\n\
         \x20        publishes the records back over the connection.\n\
         \x20        Runs until the dispatcher reports the grid done.\n\
         \x20        A dropped connection is retried with capped\n\
         \x20        exponential backoff and the fresh welcome is\n\
         \x20        checked against the original run identity; a cell\n\
         \x20        that fails to compute is reported with a `failed`\n\
         \x20        frame instead of killing the process.  --name\n\
         \x20        labels this worker in dispatcher logs (default\n\
         \x20        w<pid>).\n\
         bench-trend --current <bench.json> --baselines <dir>\n\
         \x20        [--threshold 25] [--summary <file>]\n\
         \x20        Compare the current bench JSON's per-measurement\n\
         \x20        medians against the median of the baseline runs in\n\
         \x20        <dir> (searched recursively for bench_par_*.json);\n\
         \x20        writes a markdown table (appended to --summary for\n\
         \x20        $GITHUB_STEP_SUMMARY) and exits non-zero on any\n\
         \x20        regression beyond --threshold percent.  With no\n\
         \x20        baseline artifacts yet it passes with a notice.\n\
         serve    --model small [--prefix fwd_w4a4_r10] [--quant <dir>]\n\
         \x20        [--requests 64] [--max-wait-ms 5] [--workers 1]\n\
         \x20        [--native] [--deadline-ms D] [--max-queue 4096]\n\
         \x20        Admission is bounded: submissions beyond --max-queue\n\
         \x20        are rejected with a typed backpressure error, and a\n\
         \x20        request still queued past its --deadline-ms budget is\n\
         \x20        shed with an explicit Shed outcome (0 = no deadline).\n\
         \x20        Workers batch continuously — the in-flight batch\n\
         \x20        refills as rows finish instead of re-arming the\n\
         \x20        max-wait barrier between batches.\n\
         soak     [--fast] [--seed 42] [--requests 4000] [--rate 2000]\n\
         \x20        [--burst-mult 6] [--adversarial-pct 5]\n\
         \x20        [--deadline-ms 50] [--workers 4] [--max-batch 8]\n\
         \x20        [--max-queue 64] [--live] [--out <report.txt>]\n\
         \x20        Deterministic synthetic-traffic soak of the serving\n\
         \x20        layer: open-loop Poisson arrivals with burst phases\n\
         \x20        and an adversarial tight-deadline class, all drawn\n\
         \x20        from the seeded RNG.  The canonical report comes\n\
         \x20        from a single-threaded virtual-time simulation of\n\
         \x20        admission/shedding/continuous batching and is\n\
         \x20        byte-identical for a (seed, config) on any host —\n\
         \x20        --out writes it for byte-comparison in CI.  --live\n\
         \x20        additionally replays the same trace in real time\n\
         \x20        against the real Batcher with real worker threads\n\
         \x20        (wall-clock throughput + p50/p95/p99; every admitted\n\
         \x20        request must receive exactly one outcome).\n\
         chaos    [--fast] [--seed 2024] [--workers 1,2,3] [--poison 1]\n\
         \x20        [--lease 500] [--quarantine-after 2] [--out <dir>]\n\
         \x20        Deterministic fault-injection harness over the\n\
         \x20        distributed sweep: generates a seeded FaultPlan\n\
         \x20        (connection resets, truncated/delayed frames, torn\n\
         \x20        registry writes, worker crashes, transient + poison\n\
         \x20        compute failures), runs in-process fleets at each\n\
         \x20        --workers count, and asserts the merged report.json\n\
         \x20        is byte-identical to the fault-free single-box run,\n\
         \x20        quarantined cells identical at every worker count,\n\
         \x20        no worker process lost, and torn objects resumed as\n\
         \x20        counted misses.  Exits non-zero on any divergence.\n\
         \x20        --out writes the merged fleet report for CI cmp.\n\
         registry ls --root <dir> [--kind K] [--model M] [--method Q]\n\
         \x20        List a content-addressed registry's objects with\n\
         \x20        digest, key fields, payload size and verify status\n\
         \x20        (ok | corrupt | orphan-blob) — corrupt objects read\n\
         \x20        as counted misses, orphan blobs are a torn write's\n\
         \x20        leftover, invisible to readers.\n\
         analyze  [paths..] [--deny-all] [--json]\n\
         \x20        In-repo source lint over .rs trees (default:\n\
         \x20        rust/src): every `unsafe` needs a SAFETY comment,\n\
         \x20        concurrency/wall-clock/mul_add APIs are fenced to\n\
         \x20        the modules that own them, and cross-module\n\
         \x20        `crate::` references must follow the layering map.\n\
         \x20        Findings can be muted in place with\n\
         \x20        `// analyze: allow(<rule>): <justification>`.\n\
         \x20        --deny-all exits non-zero on any finding (what the\n\
         \x20        CI analyze job runs); --json emits machine-readable\n\
         \x20        findings instead of text.\n\
         \n\
         global flags:\n\
         \x20 --threads N   size of the persistent compute pool (parked\n\
         \x20               worker threads) shared by calibration, the\n\
         \x20               per-layer quantization fan-out and the\n\
         \x20               blocked-k GEMM/Gram kernels (default:\n\
         \x20               LRC_THREADS env — read once at startup —\n\
         \x20               else all cores; results are bit-identical\n\
         \x20               at any setting)\n\
         \x20 --simd B      GEMM micro-kernel backend: auto|scalar|sse2|\n\
         \x20               avx2|neon (default: LRC_SIMD env, else the\n\
         \x20               widest the host supports; every backend is\n\
         \x20               bit-identical — this knob is for benches and\n\
         \x20               debugging, errors if B can't run here)\n\
         \x20 --fma         opt-in fused multiply-add GEMM fast path\n\
         \x20               (default off; LRC_FMA=1 enables via env).\n\
         \x20               Changes the canonical accumulation program\n\
         \x20               to one fused op per step: still deterministic\n\
         \x20               and bit-identical at every --threads/--simd\n\
         \x20               setting, but the last bits differ from the\n\
         \x20               default mul-then-add results\n\
         \x20 --workers N   serve-only: engine workers sharing the batch\n\
         \x20               queue, one PJRT engine + session set each;\n\
         \x20               the thread budget is split across workers\n\
         \x20               for per-row NLL scoring\n\
         \x20 --native      eval/serve: skip the PJRT engine and run the\n\
         \x20               rotated forward on the crate's own kernels;\n\
         \x20               quantized layers execute the fused\n\
         \x20               dequant-GEMM (PackedInts decoded tile-by-tile\n\
         \x20               into the blocked-k micro-kernel, low-rank\n\
         \x20               correction folded into the same pass — the\n\
         \x20               dense f32 weight matrix is never built).\n\
         \x20               serve also falls back to this path\n\
         \x20               automatically when no PJRT plugin loads\n"
    );
}

fn load_corpus(name: &str) -> Result<Corpus> {
    let path = lrc::artifacts_dir().join("corpus").join(format!("{name}.txt"));
    Ok(Corpus::load(&path)?)
}

fn cmd_info(_args: &Args) -> Result<()> {
    let art = lrc::artifacts_dir();
    println!("artifacts: {art:?}");
    let models = std::fs::read_dir(art.join("models"))?;
    for m in models.flatten() {
        let arts = ModelArtifacts::load(&m.path())?;
        println!("\nmodel {} — d={} L={} heads={} ff={} experts={} params={}",
                 arts.info.name, arts.info.d_model, arts.info.n_layers,
                 arts.info.n_heads, arts.info.d_ff, arts.info.n_experts,
                 arts.info.param_count);
        for (name, g) in &arts.graphs {
            println!("  graph {name:<24} batch={} params={}",
                     g.batch, g.params.len());
        }
    }
    Ok(())
}

fn quant_config(args: &Args) -> QuantConfig {
    QuantConfig {
        w_bits: 4,
        a_bits: if args.has("weight-only") { None } else { Some(4) },
        a_group: args.get("group").and_then(|g| g.parse().ok()),
        quantizer: if args.has("rtn") { Quantizer::Rtn } else { Quantizer::Gptq },
        rank_pct: args.get_f64("pct", 10.0) / 100.0,
        iters: args.get_usize("iters", 1),
    }
}

fn print_quant_report(report: &lrc::pipeline::PipelineReport) {
    println!("mean relative layer error: {:.4}", report.mean_rel_error());
    println!("packed size: {:.2} MB (int4 {:.2} MB + fp16 low-rank {:.2} MB + fp16 rest {:.2} MB)",
             report.size_bytes() as f64 / 1e6,
             report.packed_bytes as f64 / 1e6,
             report.lowrank_params as f64 * 2.0 / 1e6,
             report.fp_params as f64 * 2.0 / 1e6);
    for l in report.layers.iter().take(4) {
        println!("  {:<16} k={:<3} relerr={:.5}", l.layer, l.rank, l.rel_error);
    }
    println!("  ... ({} layers total)", report.layers.len());
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let model = args.get_or("model", "small");
    let method = Method::parse(&args.get_or("method", "lrc"))?;
    let cfg = quant_config(args);
    let pct = args.get_usize("pct", 10);
    let graph = experiments::quant_graph_name(
        pct, cfg.a_group, args.has("weight-only"), 8);
    let corpus_name = args.get_or("corpus", "wiki_syn");
    let n_calib = args.get_usize("calib", 128);
    let arts = ModelArtifacts::load(&lrc::artifacts_dir().join("models").join(&model))?;

    // content key: model identity + method + full QuantConfig + the
    // calibration identity (corpus, sequence count, fixed calib seed)
    let registry = args.get("registry").map(|root| {
        let reg = lrc::registry::Registry::local(std::path::Path::new(&root));
        let key = lrc::registry::ObjectKey::new(
            "quant-bundle", &model, method.name(), &cfg, 1234,
            &format!("{corpus_name}-calib{n_calib}"));
        (reg, key)
    });

    // registry hit: re-materialize the published bundle, touch neither
    // the PJRT engine nor the calibration corpus
    if let Some((reg, key)) = &registry {
        if let Some((bundle, report)) =
            lrc::pipeline::load_cached_quant(reg, key)?
        {
            let ginfo = arts.graph(&graph)?.clone();
            let out = lrc::pipeline::save_quant_bundle(
                &arts, &bundle, &ginfo, method, &cfg)?;
            println!("registry hit {} ({}) — zero quantization compute, \
                      bundle re-materialized at {out:?}",
                     key.digest(), reg.describe());
            print_quant_report(&report);
            return Ok(());
        }
    }

    let corpus = load_corpus(&corpus_name)?;
    let engine = Engine::cpu()?;
    println!("quantizing {model} with {} against {graph} ({n_calib} calib seqs)",
             method.label(&cfg));
    let (bundle, report) = lrc::pipeline::quantize_and_save(
        &engine, &arts, &corpus, &graph, method, &cfg, n_calib)?;
    println!("calibration: {:.1}s, quantization: {:.1}s",
             report.calib_seconds, report.quant_seconds);
    print_quant_report(&report);
    if let Some((reg, key)) = &registry {
        let (table, blob) = lrc::registry::bundle_to_blob(&bundle);
        let payload = lrc::util::Json::obj(vec![
            ("kind", lrc::util::Json::str("quant-bundle")),
            ("report", lrc::pipeline::report_to_json(&report)),
            ("tensors", table),
        ]);
        let digest = reg.publish(key, &payload, Some(&blob))?;
        println!("published to registry: {digest}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model = args.get_or("model", "small");
    let graph = args.get_or("graph", "fwd_fp_b8");
    let budget = if args.has("fast") { EvalBudget::fast() } else { EvalBudget::full() };
    let art = lrc::artifacts_dir();
    let arts = ModelArtifacts::load(&art.join("models").join(&model))?;
    let corpus = load_corpus(&args.get_or("corpus", "wiki_syn"))?;
    let tasks = experiments::load_tasks(&art, budget)?;
    let quant = match args.get("quant") {
        Some(d) => Some(TensorBundle::load(std::path::Path::new(d))?),
        None => None,
    };
    if args.has("native") {
        // engine-free scoring: the rotated forward on the crate's own
        // kernels; quantized layers run the fused dequant-GEMM
        let ginfo = arts.graphs.get(&graph);
        let m = lrc::runtime::NativeModel::new(&arts, quant.as_ref(),
                                               ginfo, 4)?;
        let batch = ginfo.map(|g| g.batch).unwrap_or(8);
        let mut provider = lrc::runtime::NativeProvider {
            model: std::sync::Arc::new(m),
            batch,
        };
        let ppl = lrc::eval::perplexity(&mut provider, &corpus,
                                        budget.ppl_seqs)
            .map_err(anyhow::Error::msg)?;
        println!("{model}/{graph} (native fused path): perplexity {ppl:.3}");
        for task in &tasks {
            let acc = lrc::eval::task_accuracy(&mut provider, task)
                .map_err(anyhow::Error::msg)?;
            println!("  task {:<16} acc_norm {acc:.3}", task.name);
        }
        return Ok(());
    }
    let engine = Engine::cpu()?;
    let scores = experiments::evaluate_graph(
        &engine, &arts, &graph, quant.as_ref(), &corpus, &tasks, budget,
        &graph)?;
    println!("{}", render_table(&experiments::TABLE_HEADERS,
                                &[scores.cells()]));
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    use lrc::sweep::{self, SweepAxes, SweepStore};
    let axes = SweepAxes::from_args(args, args.has("fast"))?;
    let resume = !args.has("no-resume");
    let pool = lrc::par::global();
    let seed = args.get_usize("seed", 2024) as u64;
    // --registry overrides where cell objects live; the default keeps
    // them next to the report.  The old <out>/cells/ fragment dir is the
    // migration source: records found there are adopted into the
    // registry on first read.
    let store_for = |out: &std::path::Path| -> SweepStore {
        let root = args.get("registry").map(std::path::PathBuf::from)
            .unwrap_or_else(|| out.join("registry"));
        SweepStore::open(&root, Some(&out.join("cells")), seed)
    };

    let outcome;
    let out_dir;
    let store;
    match args.get("model") {
        None => {
            // engine-free: deterministic synthetic model + calibration
            let arts = sweep::synthetic_artifacts(seed);
            out_dir = args.get("out").map(std::path::PathBuf::from)
                .unwrap_or_else(|| lrc::artifacts_dir().join("sweep")
                                .join(&arts.info.name));
            store = store_for(&out_dir);
            println!("sweep: {} cells on synthetic model (seed {seed}), \
                      out {out_dir:?}", axes.cells().len());
            let run_tag = format!("synthetic-seed{seed}");
            outcome = match args.get("serve") {
                Some(addr) => {
                    // dispatcher mode: workers compute, we merge.  The
                    // canonical CellKey order of the merge keeps the
                    // report byte-identical to a single-box run.
                    let listener = std::net::TcpListener::bind(addr)
                        .map_err(|e| anyhow!("--serve: bind {addr}: {e}"))?;
                    println!("sweep: dispatching on {} — start workers \
                              with `lrc sweep-worker --connect {}`",
                             listener.local_addr()?, listener.local_addr()?);
                    let mut opts = lrc::registry::service::ServeOpts::default();
                    opts.lease_polls = args.get_usize("lease",
                                                      opts.lease_polls);
                    opts.quarantine_after =
                        args.get_usize("quarantine-after",
                                       opts.quarantine_after);
                    sweep::serve_grid_distributed(
                        &arts, &axes, &run_tag, &store, resume, &listener,
                        opts, |s| println!("{s}"))?
                }
                None => {
                    let calib =
                        sweep::synthetic_calib(&arts, seed, &axes.groups);
                    sweep::run_grid(&arts, &calib, &axes, &run_tag,
                                    Some(&store), resume, pool, None)?
                }
            };
        }
        Some(model) => {
            if args.get("serve").is_some() {
                return Err(anyhow!("--serve drives the engine-free \
                    synthetic grid only (workers recompute cells from the \
                    seed; real-model sweeps need the local engine)"));
            }
            // real artifacts: calibrate once per group value via the
            // engine, reuse across every cell; NLL per cell where a
            // matching fwd graph exists (the fwd graphs consume
            // dequantized grid weights, so one graph serves every
            // w_bits at its rank/group coordinate)
            let engine = Engine::cpu()?;
            let arts = ModelArtifacts::load(
                &lrc::artifacts_dir().join("models").join(model))?;
            let corpus_name = args.get_or("corpus", "wiki_syn");
            let corpus = load_corpus(&corpus_name)?;
            let n_calib = args.get_usize("calib", 128);
            let run_tag = format!("{model}-{corpus_name}-calib{n_calib}");
            let mut calib = std::collections::BTreeMap::new();
            for &group in &axes.groups {
                if calib.contains_key(&group) {
                    continue;
                }
                let graph = lrc::pipeline::cell_graph(&arts, 0, group,
                                                      false, 8)?;
                let cfg = lrc::quant::QuantConfig {
                    a_group: group, ..Default::default()
                };
                println!("collecting shared stats (group {group:?}, \
                          {n_calib} seqs)...");
                let stats = lrc::pipeline::collect_stats_for_graph(
                    &engine, &arts, &corpus, &graph, &cfg, n_calib)?;
                calib.insert(group, stats);
            }
            out_dir = args.get("out").map(std::path::PathBuf::from)
                .unwrap_or_else(|| lrc::artifacts_dir().join("sweep")
                                .join(&arts.info.name));
            store = store_for(&out_dir);
            println!("sweep: {} cells on model {model}, out {out_dir:?}",
                     axes.cells().len());
            let mut nll_eval = |key: &lrc::sweep::CellKey,
                                bundle: &TensorBundle|
                               -> Result<Option<f64>> {
                let gname = experiments::quant_graph_name(
                    key.rank_pct, key.a_group, false, 8);
                if !arts.graphs.contains_key(&gname) {
                    return Ok(None);
                }
                let session = engine.session(&arts, &gname, Some(bundle))?;
                let mut provider = lrc::runtime::SessionProvider { session };
                let ppl = lrc::eval::perplexity(&mut provider, &corpus, 8)
                    .map_err(anyhow::Error::msg)?;
                Ok(Some(ppl.ln()))
            };
            outcome = sweep::run_grid(&arts, &calib, &axes, &run_tag,
                                      Some(&store), resume,
                                      pool, Some(&mut nll_eval))?;
        }
    }

    // persist the report before gating on sanity, so a violating run
    // still leaves the full grid behind to debug with
    std::fs::create_dir_all(&out_dir)?;
    std::fs::write(out_dir.join("report.json"), &outcome.report_json)?;
    std::fs::write(out_dir.join("report.md"), &outcome.markdown)?;
    println!("\n{}", outcome.markdown);
    println!("cells: {} computed, {} resumed; report under {out_dir:?}",
             outcome.computed, outcome.resumed);
    let c = store.counters();
    println!("registry {}: {} hit(s), {} published, {} corrupt",
             store.describe(), c.hits, c.published, c.corrupt);
    if outcome.duplicates > 0 {
        println!("distributed: {} duplicate publish(es) absorbed from \
                  requeue races (each verified byte-identical)",
                 outcome.duplicates);
    }
    if !outcome.quarantined.is_empty() {
        for (id, err) in &outcome.quarantined {
            eprintln!("quarantined cell {id}: {err}");
        }
        return Err(anyhow!(
            "{} cell(s) quarantined after repeated worker failures \
             (report written without them under {out_dir:?})",
            outcome.quarantined.len()));
    }
    if !outcome.violations.is_empty() {
        for v in &outcome.violations {
            eprintln!("sanity violation: {v}");
        }
        return Err(anyhow!("{} sweep sanity assertion(s) failed",
                           outcome.violations.len()));
    }
    println!("sanity assertions: all hold (gptq<=rtn, rank monotone, \
              size strictly increasing in bits)");
    Ok(())
}

fn cmd_sweep_worker(args: &Args) -> Result<()> {
    let addr = args.get("connect")
        .ok_or_else(|| anyhow!("--connect <host:port> of a `lrc sweep \
                                --serve` dispatcher is required"))?;
    let name = args.get("name").map(str::to_string)
        .unwrap_or_else(|| format!("w{}", std::process::id()));
    let pool = lrc::par::global();
    println!("sweep-worker {name}: connecting to {addr}");
    let out = lrc::sweep::worker_loop(addr, &name, pool,
                                      |s| println!("{s}"))?;
    println!("sweep-worker {name}: grid done — {} computed, {} failed, \
              {} reconnect(s)", out.computed, out.failed, out.reconnects);
    Ok(())
}

/// Recursively collect `bench_*.json` files under `dir` (covers
/// `bench_par_*` and `bench_soak_*` baselines alike — the trend gate
/// matches entries by (section, name), so mixed files compose).
fn collect_bench_jsons(dir: &std::path::Path,
                       out: &mut Vec<std::path::PathBuf>) {
    if let Ok(rd) = std::fs::read_dir(dir) {
        for e in rd.flatten() {
            let p = e.path();
            if p.is_dir() {
                collect_bench_jsons(&p, out);
            } else if p.file_name().and_then(|n| n.to_str())
                .map(|n| n.starts_with("bench_") && n.ends_with(".json"))
                .unwrap_or(false)
            {
                out.push(p);
            }
        }
    }
}

fn cmd_bench_trend(args: &Args) -> Result<()> {
    use lrc::bench::trend;
    use lrc::util::Json;
    let current_path = args.get("current")
        .ok_or_else(|| anyhow!("--current <bench json> is required"))?;
    let current = Json::parse(&std::fs::read_to_string(current_path)?)
        .map_err(|e| anyhow!("parse {current_path}: {e}"))?;
    let base_dir = args.get("baselines")
        .ok_or_else(|| anyhow!("--baselines <dir> is required"))?;
    let threshold = args.get_f64("threshold", trend::DEFAULT_THRESHOLD_PCT);

    let mut paths = Vec::new();
    collect_bench_jsons(std::path::Path::new(base_dir), &mut paths);
    paths.sort();
    let cur_canon = std::fs::canonicalize(current_path).ok();
    let mut baselines = Vec::new();
    for p in paths {
        if std::fs::canonicalize(&p).ok() == cur_canon && cur_canon.is_some() {
            continue; // don't compare the current run against itself
        }
        match std::fs::read_to_string(&p).map_err(|e| e.to_string())
            .and_then(|t| Json::parse(&t))
        {
            Ok(j) => baselines.push(j),
            Err(e) => eprintln!("warning: skipping baseline {p:?}: {e}"),
        }
    }

    let report = trend::compare(&current, &baselines, threshold);
    let md = report.markdown();
    if let Some(summary) = args.get("summary") {
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new()
            .create(true).append(true).open(summary)?;
        f.write_all(md.as_bytes())?;
    }
    println!("{md}");
    if !report.passed() {
        return Err(anyhow!("bench trend gate failed: {} regression(s) \
                            beyond +{threshold}%: {}",
                           report.regressions.len(),
                           report.regressions.join(", ")));
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let model = args.get_or("model", "small");
    let prefix = args.get_or("prefix", "fwd_fp");
    let art = lrc::artifacts_dir();
    let model_dir = art.join("models").join(&model);
    let quant_dir = args.get("quant").map(std::path::PathBuf::from);
    let n_requests = args.get_usize("requests", 64);

    let handle = ServerHandle::start(ServerConfig {
        model_dir,
        graph_prefix: prefix.clone(),
        quant_dir,
        policy: BatchPolicy {
            max_batch: args.get_usize("max-batch", 8),
            max_wait: Duration::from_millis(args.get_usize("max-wait-ms", 5) as u64),
            max_queue: args.get_usize("max-queue", 4096),
            // 0 (the default) = no deadline: demo requests never shed
            deadline: match args.get_usize("deadline-ms", 0) {
                0 => None,
                ms => Some(Duration::from_millis(ms as u64)),
            },
        },
        workers: args.get_usize("workers", 1),
        native: args.has("native"),
    })?;
    println!("serving {model}/{prefix} (seq_len={}, workers={})",
             handle.seq_len, handle.metrics.per_worker.len());

    // demo traffic from the held-out corpus
    let corpus = load_corpus("wiki_syn")?;
    let seqs = corpus.eval_sequences(handle.seq_len, n_requests);
    if seqs.is_empty() {
        return Err(anyhow!("no eval sequences available"));
    }
    let mut pending = Vec::new();
    for s in seqs.iter().cycle().take(n_requests) {
        pending.push(handle.submit(s.clone())?);
    }
    let (mut mean_nll, mut scored, mut shed, mut failed) = (0.0, 0u64, 0u64, 0u64);
    for rx in pending {
        match rx.recv()? {
            Outcome::Scored(r) => {
                scored += 1;
                mean_nll += r.mean_nll;
            }
            Outcome::Shed { .. } => shed += 1,
            Outcome::Failed { id, error } => {
                failed += 1;
                eprintln!("request {id} failed: {error}");
            }
        }
    }
    if scored > 0 {
        mean_nll /= scored as f64;
        println!("mean per-seq NLL: {mean_nll:.4} (ppl {:.2})", mean_nll.exp());
    }
    if shed + failed > 0 {
        println!("outcomes: scored={scored} shed={shed} failed={failed}");
    }
    let snap = handle.shutdown();
    println!("{}", snap.render());
    Ok(())
}

fn cmd_soak(args: &Args) -> Result<()> {
    use lrc::coordinator::soak::{self, SoakConfig};
    let mut cfg = if args.has("fast") {
        SoakConfig::fast()
    } else {
        SoakConfig::default()
    };
    cfg.seed = args.get_usize("seed", cfg.seed as usize) as u64;
    cfg.n_requests = args.get_usize("requests", cfg.n_requests);
    cfg.rate_rps = args.get_f64("rate", cfg.rate_rps);
    cfg.burst_mult = args.get_f64("burst-mult", cfg.burst_mult);
    cfg.adversarial_frac =
        args.get_f64("adversarial-pct", cfg.adversarial_frac * 100.0) / 100.0;
    if let Some(ms) = args.get("deadline-ms") {
        let ms: f64 = ms.parse()
            .map_err(|_| anyhow!("--deadline-ms expects a number, got {ms:?}"))?;
        cfg.deadline_us = if ms <= 0.0 {
            None
        } else {
            Some((ms * 1000.0) as u64)
        };
    }
    cfg.workers = args.get_usize("workers", cfg.workers);
    cfg.max_batch = args.get_usize("max-batch", cfg.max_batch);
    cfg.max_queue = args.get_usize("max-queue", cfg.max_queue);

    // the canonical, byte-reproducible part: trace + virtual-time sim
    let trace = soak::gen_trace(&cfg);
    let report = soak::simulate(&cfg, &trace);
    let text = report.render(&cfg);
    print!("{text}");
    if report.served + report.shed + report.rejected != cfg.n_requests as u64 {
        return Err(anyhow!(
            "soak conservation violated: served {} + shed {} + rejected {} \
             != {} requests",
            report.served, report.shed, report.rejected, cfg.n_requests));
    }
    if let Some(path) = args.get("out") {
        std::fs::write(path, &text)?;
        println!("report written to {path}");
    }

    // optional wall-clock replay against the real Batcher
    if args.has("live") {
        let live = soak::run_live(&cfg);
        println!(
            "live: served={} shed={} rejected={} failed={} wall={:.1}ms \
             throughput={:.0}rps p50={}us p95={}us p99={}us",
            live.served, live.shed, live.rejected, live.failed, live.wall_ms,
            live.throughput_rps, live.p50_us, live.p95_us, live.p99_us);
        if live.served + live.shed + live.rejected + live.failed
            != cfg.n_requests as u64
        {
            return Err(anyhow!("live soak lost outcomes: {} + {} + {} + {} \
                                != {}", live.served, live.shed, live.rejected,
                               live.failed, cfg.n_requests));
        }
    }
    Ok(())
}

fn cmd_chaos(args: &Args) -> Result<()> {
    use lrc::chaos::{self, ChaosConfig};
    let seed = args.get_usize("seed", 2024) as u64;
    let mut cfg = if args.has("fast") {
        ChaosConfig::fast(seed)
    } else {
        ChaosConfig::full(seed)
    };
    if let Some(w) = args.get("workers") {
        cfg.worker_counts = w.split(',')
            .map(|s| s.trim().parse::<usize>()
                 .map_err(|_| anyhow!("bad --workers entry {s:?}")))
            .collect::<Result<Vec<_>>>()?;
    }
    cfg.poison = args.get_usize("poison", cfg.poison);
    cfg.lease_polls = args.get_usize("lease", cfg.lease_polls);
    cfg.quarantine_after =
        args.get_usize("quarantine-after", cfg.quarantine_after);
    let outcome = chaos::run_chaos(&cfg, lrc::par::global(),
                                   |s| println!("{s}"))?;
    // the merged fleet report (asserted byte-identical to the fault-free
    // single-box run) — what the CI chaos-smoke job cmp's against a
    // plain `lrc sweep` report
    if let Some(out) = args.get("out") {
        let dir = std::path::Path::new(out);
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join("report.json"), &outcome.merged_report)?;
        std::fs::write(dir.join("report.md"), &outcome.merged_markdown)?;
        println!("merged fleet report written under {dir:?}");
    }
    println!(
        "chaos: OK — {} fleet run(s) over {} cells survived {} injected \
         wire/compute fault(s) + {} torn write(s); {} reconnect(s), \
         {} failed frame(s), {} duplicate publish(es), {} quarantined \
         poison cell(s), {} torn object(s) recomputed on resume; every \
         merged report byte-identical to the fault-free run",
        outcome.fleets, outcome.cells, outcome.fired, outcome.torn_fired,
        outcome.reconnects, outcome.failures, outcome.duplicates,
        outcome.quarantined.len(), outcome.torn_recomputed);
    Ok(())
}

fn cmd_registry(args: &Args) -> Result<()> {
    match args.positional.get(1).map(|s| s.as_str()) {
        Some("ls") => {}
        _ => {
            return Err(anyhow!("usage: lrc registry ls --root <dir> \
                                [--kind K] [--model M] [--method Q]"));
        }
    }
    let root = args.get("root")
        .ok_or_else(|| anyhow!("--root <registry dir> is required"))?;
    let rows = lrc::registry::list_objects(std::path::Path::new(root))?;
    let total = rows.len();
    let mut corrupt = 0usize;
    let mut table: Vec<Vec<String>> = Vec::new();
    for r in &rows {
        if args.get("kind").is_some_and(|k| r.kind != k)
            || args.get("model").is_some_and(|m| r.model != m)
            || args.get("method").is_some_and(|q| r.method != q)
        {
            continue;
        }
        if r.status != "ok" {
            corrupt += 1;
        }
        table.push(vec![
            r.digest.clone(),
            r.kind.clone(),
            r.model.clone(),
            r.method.clone(),
            r.blob_len.map(|n| n.to_string())
                .unwrap_or_else(|| "-".into()),
            r.status.to_string(),
        ]);
    }
    print!("{}", render_table(
        &["Digest", "Kind", "Model", "Method", "Blob (B)", "Status"],
        &table));
    println!("{} object(s) shown of {total} in store; {corrupt} \
              non-verifying (read as counted misses)", table.len());
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    // paths after the subcommand; default to the crate source tree
    // whether invoked from the repo root or from rust/
    let mut paths: Vec<std::path::PathBuf> = args
        .positional
        .iter()
        .skip(1)
        .map(std::path::PathBuf::from)
        .collect();
    if paths.is_empty() {
        for cand in ["rust/src", "src"] {
            if std::path::Path::new(cand).is_dir() {
                paths.push(cand.into());
                break;
            }
        }
        if paths.is_empty() {
            return Err(anyhow!(
                "analyze: no paths given and neither rust/src nor src exists"
            ));
        }
    }
    let (findings, nfiles) = lrc::analyze::analyze_paths(&paths)?;
    if args.has("json") {
        println!("{}", lrc::analyze::render_json(&findings));
    } else {
        print!("{}", lrc::analyze::render_text(&findings, nfiles));
    }
    if args.has("deny-all") && !findings.is_empty() {
        return Err(anyhow!(
            "analyze: {} finding(s) with --deny-all",
            findings.len()
        ));
    }
    Ok(())
}
