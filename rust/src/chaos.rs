//! `lrc chaos` — deterministic fault-injection harness for the
//! distributed sweep fleet.
//!
//! The harness generates a seeded [`FaultPlan`], runs in-process fleets
//! (one dispatcher thread + N worker threads per run, real TCP on
//! loopback) under it, and asserts the robustness contract the fleet
//! claims:
//!
//! 1. **Transient faults are invisible.**  Under connection resets,
//!    truncated/delayed frames, worker crashes mid-compute, transient
//!    compute failures and torn registry writes, the merged
//!    `report.json` is byte-identical to the fault-free single-box run,
//!    at every worker count, with nothing quarantined and no worker
//!    process lost.
//! 2. **Poison cells are contained.**  A cell that fails every attempt
//!    is quarantined after `quarantine_after` failures; the remaining
//!    grid completes, the quarantined set is identical at every worker
//!    count, every surviving record matches the fault-free run, and the
//!    poison report itself is byte-identical across worker counts.
//! 3. **Torn writes read as misses.**  Re-running single-box over the
//!    last fleet's registry (clean store, resume on) recomputes exactly
//!    the torn objects — broken metas as *counted* corruptions, missing
//!    metas as plain misses — and reproduces the baseline report.
//!
//! Which faults actually fire depends on how workers interleave (the
//! *plan* is a pure function of the seed; the *claim order* is not), so
//! every assertion here is interleaving-independent: report bytes,
//! quarantine sets, survival.  `run_chaos` returns counts of what fired
//! for operator eyes, and bails on the first broken invariant.

use std::collections::BTreeMap;
use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, bail, Result};

use crate::par::Pool;
use crate::registry::faults::{FaultPlan, TornCounters, TornWriteBackend};
use crate::registry::service::{self, ServeOpts};
use crate::registry::Registry;
use crate::sweep::{self, SweepAxes, SweepOutcome, SweepStore};

/// Everything one chaos run sweeps.  All fields are plain data so a
/// config is trivially reproducible from a CLI invocation.
#[derive(Clone, Debug)]
pub struct ChaosConfig {
    pub seed: u64,
    /// The grid under test (the CI smoke grid by default — chaos stresses
    /// the protocol, not the math, so small cells are the point).
    pub axes: SweepAxes,
    /// Fleet sizes to run; byte-identity is asserted across all of them.
    pub worker_counts: Vec<usize>,
    /// Poison cells (fail on every attempt) in the quarantine phase.
    pub poison: usize,
    /// Dispatcher claim lease in poll iterations (~2 ms each).
    pub lease_polls: usize,
    /// Failed attempts before a cell is quarantined.
    pub quarantine_after: usize,
}

impl ChaosConfig {
    /// The CI smoke shape: 8-cell grid, fleets of 1/2/3, one poison
    /// cell, quarantine on the second failure, ~1 s lease.
    pub fn fast(seed: u64) -> ChaosConfig {
        ChaosConfig {
            seed,
            axes: SweepAxes::fast(),
            worker_counts: vec![1, 2, 3],
            poison: 1,
            lease_polls: 500,
            quarantine_after: 2,
        }
    }

    /// The default (non-`--fast`) shape: same grid, wider fleets, two
    /// poison cells, a longer lease and a higher quarantine bar.
    pub fn full(seed: u64) -> ChaosConfig {
        ChaosConfig {
            seed,
            axes: SweepAxes::fast(),
            worker_counts: vec![1, 2, 4],
            poison: 2,
            lease_polls: 1000,
            quarantine_after: 3,
        }
    }

    fn validate(&self) -> Result<()> {
        self.axes.validate()?;
        if self.worker_counts.is_empty() {
            bail!("chaos needs at least one fleet size");
        }
        if self.worker_counts.contains(&0) {
            bail!("a fleet of 0 workers never drains the grid");
        }
        let cells = self.axes.cells().len();
        if self.poison >= cells {
            bail!("{} poison cells would leave nothing of the {cells}-cell \
                   grid", self.poison);
        }
        if self.poison > 0 && self.quarantine_after == 0 {
            bail!("poison cells with quarantine disabled \
                   (--quarantine-after 0) would retry forever");
        }
        Ok(())
    }
}

/// What the harness observed (all assertions already passed if this is
/// returned at all).
pub struct ChaosOutcome {
    /// grid size
    pub cells: usize,
    /// fleet runs executed (transient + poison phases)
    pub fleets: usize,
    /// per-worker wire/compute faults that actually fired, total
    pub fired: usize,
    /// torn registry writes applied in the transient phase's last fleet
    pub torn_fired: u64,
    /// cells recomputed by the single-box resume over the torn registry
    pub torn_recomputed: usize,
    /// worker sessions re-established after injected transport faults
    pub reconnects: usize,
    /// `failed` frames sent (transient + poison compute failures)
    pub failures: usize,
    /// duplicate publishes absorbed from requeue races
    pub duplicates: usize,
    /// `(cell id, error)` quarantined in the poison phase, canonical
    /// order — identical at every worker count
    pub quarantined: Vec<(String, String)>,
    /// the fault-free single-box report (the oracle)
    pub baseline_report: String,
    /// the last transient fleet's merged report — byte-identical to
    /// `baseline_report`, written by `lrc chaos --out` for CI `cmp`
    pub merged_report: String,
    pub merged_markdown: String,
}

/// Process-unique scratch root (no wall clock in this module — the
/// analyze fences keep `SystemTime` out, and determinism doesn't want
/// it anyway).
fn scratch_dir(tag: &str) -> PathBuf {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    std::env::temp_dir().join(format!(
        "lrc_chaos_{}_{}_{tag}", std::process::id(),
        NEXT.fetch_add(1, Ordering::SeqCst)))
}

/// First byte offset where two reports diverge — failure context only.
fn first_diff(a: &str, b: &str) -> usize {
    a.bytes().zip(b.bytes()).take_while(|(x, y)| x == y).count()
}

fn ensure_identical(what: &str, got: &str, want: &str) -> Result<()> {
    if got != want {
        bail!("{what}: diverged from the fault-free report at byte {} \
               (got {} bytes, want {})",
              first_diff(got, want), got.len(), want.len());
    }
    Ok(())
}

/// Index a report's records by cell id (record bytes, canonical form).
fn records_by_id(out: &SweepOutcome) -> Result<BTreeMap<String, String>> {
    let mut m = BTreeMap::new();
    for rec in &out.records {
        let id = rec.get("key").and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("record without a cell id"))?;
        m.insert(id.to_string(), rec.to_string());
    }
    Ok(m)
}

/// One in-process fleet: a dispatcher thread serving `cells` over
/// loopback TCP through a torn-write registry, plus one worker thread
/// per name, each computing through its slice of the fault plan.
/// Returns the merged outcome, per-worker outcomes, total shim faults
/// fired and the torn-write counters for `registry_root`.
fn run_fleet(cfg: &ChaosConfig, run_tag: &str, plan: &FaultPlan,
             names: &[String], registry_root: &Path)
             -> Result<(SweepOutcome, Vec<service::WorkerOutcome>, usize,
                        TornCounters)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let backend = TornWriteBackend::new(registry_root, plan.torn.clone());
    let torn = backend.counters();
    let store = SweepStore::with_registry(
        Registry::with_backend(Box::new(backend)), cfg.seed);
    let opts = ServeOpts {
        lease_polls: cfg.lease_polls,
        quarantine_after: cfg.quarantine_after,
    };

    let seed = cfg.seed;
    let axes = cfg.axes.clone();
    let tag = run_tag.to_string();
    let dispatcher = std::thread::spawn(move || -> Result<SweepOutcome> {
        let arts = sweep::synthetic_artifacts(seed);
        sweep::serve_grid_distributed(&arts, &axes, &tag, &store,
                                      false, &listener, opts, |_| {})
    });

    let mut handles = Vec::new();
    for name in names {
        let addr = addr.clone();
        let name = name.clone();
        let mut shim = plan.shim_for(&name);
        handles.push(std::thread::spawn(
            move || -> Result<(service::WorkerOutcome, usize)> {
                let pool = Pool::new(1);
                let out = service::run_worker(
                    &addr, &name, Some(&mut shim),
                    sweep::synthetic_cell_compute(&pool), |_| {})?;
                Ok((out, shim.fired))
            }));
    }

    let merged = dispatcher.join()
        .map_err(|_| anyhow!("dispatcher thread panicked"))??;
    let mut workers = Vec::new();
    let mut fired = 0usize;
    for (h, name) in handles.into_iter().zip(names) {
        let (out, f) = h.join()
            .map_err(|_| anyhow!("worker {name} panicked"))?
            .map_err(|e| anyhow!("worker {name} died: {e:#}"))?;
        fired += f;
        workers.push(out);
    }
    Ok((merged, workers, fired, torn))
}

/// Run the whole harness; every invariant violation is an `Err`.
pub fn run_chaos(cfg: &ChaosConfig, pool: &Pool,
                 mut progress: impl FnMut(String)) -> Result<ChaosOutcome> {
    cfg.validate()?;
    let seed = cfg.seed;
    let run_tag = format!("synthetic-seed{seed}");
    let cells: Vec<String> =
        cfg.axes.cells().iter().map(|c| c.id()).collect();
    let scratch = scratch_dir("fleet");

    // ---- phase 1: the oracle — fault-free, single-box, storeless
    progress(format!("chaos: baseline — {} cells single-box, seed {seed}",
                     cells.len()));
    let arts = sweep::synthetic_artifacts(seed);
    let calib = sweep::synthetic_calib(&arts, seed, &cfg.axes.groups);
    let baseline = sweep::run_grid(&arts, &calib, &cfg.axes, &run_tag,
                                   None, false, pool, None)?;
    let base_recs = records_by_id(&baseline)?;

    let mut fleets = 0usize;
    let mut fired = 0usize;
    let mut reconnects = 0usize;
    let mut failures = 0usize;
    let mut duplicates = 0usize;

    // ---- phase 2: transient faults at every fleet size
    let mut merged_report = baseline.report_json.clone();
    let mut merged_markdown = baseline.markdown.clone();
    let mut last_torn: Option<(PathBuf, TornCounters)> = None;
    for &n in &cfg.worker_counts {
        let names: Vec<String> =
            (0..n).map(|i| format!("chaos-w{i}")).collect();
        let plan = FaultPlan::generate(seed, &names, &cells, 0);
        let root = scratch.join(format!("transient{n}"));
        progress(format!(
            "chaos: transient fleet of {n} — {} scheduled fault(s), \
             {} torn write(s)", plan.total_faults(), plan.torn.len()));
        let (out, workers, f, torn) =
            run_fleet(cfg, &run_tag, &plan, &names, &root)?;
        fleets += 1;
        fired += f;
        duplicates += out.duplicates;
        for w in &workers {
            reconnects += w.reconnects;
            failures += w.failed;
        }
        ensure_identical(
            &format!("transient fleet of {n}"),
            &out.report_json, &baseline.report_json)?;
        ensure_identical(
            &format!("transient fleet of {n} (markdown)"),
            &out.markdown, &baseline.markdown)?;
        if !out.quarantined.is_empty() {
            bail!("transient fleet of {n} quarantined {:?} — transient \
                   faults must never quarantine", out.quarantined);
        }
        progress(format!(
            "chaos: transient fleet of {n} OK — report identical, \
             {f} fault(s) fired, {} torn, {} duplicate(s)",
            torn.fired(), out.duplicates));
        merged_report = out.report_json;
        merged_markdown = out.markdown;
        last_torn = Some((root, torn));
    }

    // ---- phase 3: poison cells at every fleet size
    let mut quarantined: Vec<(String, String)> = Vec::new();
    let mut poison_report: Option<String> = None;
    if cfg.poison > 0 {
        for &n in &cfg.worker_counts {
            let names: Vec<String> =
                (0..n).map(|i| format!("chaos-w{i}")).collect();
            // a different seed stream than phase 2, same grid — the
            // plan (and so the poison set) is still pure (seed, cells)
            let plan = FaultPlan::generate(
                seed ^ 0x0DDB_A11_u64, &names, &cells, cfg.poison);
            let root = scratch.join(format!("poison{n}"));
            progress(format!(
                "chaos: poison fleet of {n} — {} poison cell(s), \
                 quarantine after {}", plan.poison.len(),
                cfg.quarantine_after));
            let (out, workers, f, _torn) =
                run_fleet(cfg, &run_tag, &plan, &names, &root)?;
            fleets += 1;
            fired += f;
            duplicates += out.duplicates;
            for w in &workers {
                reconnects += w.reconnects;
                failures += w.failed;
            }
            // the quarantined set is exactly the plan's poison set
            let got: Vec<&String> =
                out.quarantined.iter().map(|(id, _)| id).collect();
            let mut want: Vec<&String> = plan.poison.iter().collect();
            let mut got_sorted = got.clone();
            got_sorted.sort();
            want.sort();
            if got_sorted != want {
                bail!("poison fleet of {n}: quarantined {got:?}, \
                       expected exactly the poison set {want:?}");
            }
            // every surviving record matches the fault-free run, and
            // nothing besides the poison set is missing
            let recs = records_by_id(&out)?;
            for (id, rec) in &recs {
                if plan.poison.contains(id) {
                    bail!("poison fleet of {n}: quarantined cell {id} \
                           still has a record");
                }
                if base_recs.get(id) != Some(rec) {
                    bail!("poison fleet of {n}: record for {id} differs \
                           from the fault-free run");
                }
            }
            if recs.len() + plan.poison.len() != cells.len() {
                bail!("poison fleet of {n}: {} records + {} poison != \
                       {} cells", recs.len(), plan.poison.len(),
                      cells.len());
            }
            // and the whole report is byte-identical across fleet sizes
            match &poison_report {
                None => poison_report = Some(out.report_json.clone()),
                Some(first) => ensure_identical(
                    &format!("poison fleet of {n}"),
                    &out.report_json, first)?,
            }
            quarantined = out.quarantined;
            progress(format!(
                "chaos: poison fleet of {n} OK — {} quarantined, \
                 all workers survived", quarantined.len()));
        }
    }

    // ---- phase 4: the torn registry resumes as misses, nothing worse
    let (torn_root, torn) = last_torn.expect("phase 2 always runs");
    let expected_recompute = torn.fired() as usize;
    progress(format!(
        "chaos: resuming single-box over the torn registry — expecting \
         {expected_recompute} recompute(s), {} counted corruption(s)",
        torn.corrupt()));
    let store = SweepStore::open(&torn_root, None, seed);
    let resumed = sweep::run_grid(&arts, &calib, &cfg.axes, &run_tag,
                                  Some(&store), true, pool, None)?;
    ensure_identical("torn-registry resume", &resumed.report_json,
                     &baseline.report_json)?;
    if resumed.computed != expected_recompute {
        bail!("torn-registry resume recomputed {} cell(s), expected \
               exactly the {expected_recompute} torn object(s)",
              resumed.computed);
    }
    if store.counters().corrupt != torn.corrupt() {
        bail!("torn-registry resume counted {} corruption(s), expected \
               {} (every truncated meta must be a *counted* miss)",
              store.counters().corrupt, torn.corrupt());
    }

    std::fs::remove_dir_all(&scratch).ok();
    Ok(ChaosOutcome {
        cells: cells.len(),
        fleets,
        fired,
        torn_fired: torn.fired(),
        torn_recomputed: resumed.computed,
        reconnects,
        failures,
        duplicates,
        quarantined,
        baseline_report: baseline.report_json,
        merged_report,
        merged_markdown,
    })
}
