//! Data layer: byte tokenizer, corpus loading/splitting, eval batching and
//! the lm-eval-substitute task suites (read from artifacts/tasks/*.json).

pub mod tasks;

pub use tasks::{Task, TaskItem};

use std::path::Path;

use anyhow::{anyhow, Result};

/// Byte-level tokenizer — the vocabulary is exactly 0..=255.
pub const VOCAB_SIZE: usize = 256;

pub fn tokenize(text: &str) -> Vec<i32> {
    text.as_bytes().iter().map(|&b| b as i32).collect()
}

pub fn detokenize(ids: &[i32]) -> String {
    let bytes: Vec<u8> = ids.iter().map(|&i| (i & 0xff) as u8).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// A tokenized corpus with a deterministic train/held-out split.
#[derive(Clone, Debug)]
pub struct Corpus {
    pub name: String,
    pub tokens: Vec<i32>,
    /// index where the held-out tail begins (last 10%)
    pub split: usize,
}

impl Corpus {
    pub fn load(path: &Path) -> std::io::Result<Corpus> {
        let text = std::fs::read_to_string(path)?;
        let tokens = tokenize(&text);
        let split = tokens.len() * 9 / 10;
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default();
        Ok(Corpus { name, tokens, split })
    }

    pub fn from_text(name: &str, text: &str) -> Corpus {
        let tokens = tokenize(text);
        let split = tokens.len() * 9 / 10;
        Corpus { name: name.into(), tokens, split }
    }

    /// Deterministic calibration sequences from the *train* region
    /// (the paper: 128 random sequences of the calibration set).
    ///
    /// Errors when the train region cannot hold even one `seq_len`
    /// window (the old clamp sliced past the token buffer and panicked
    /// on corpora shorter than `seq_len + 1`).
    pub fn calib_sequences(&self, n_seqs: usize, seq_len: usize, seed: u64)
                           -> Result<Vec<Vec<i32>>> {
        if self.split < seq_len + 1 {
            return Err(anyhow!(
                "corpus {:?} is too short for calibration: the train \
                 region holds {} tokens but one sequence needs seq_len + 1 \
                 = {} (corpus has {} tokens total — supply a longer corpus \
                 or a smaller seq_len)",
                self.name, self.split, seq_len + 1, self.tokens.len()));
        }
        let mut rng = crate::rng::Rng::new(seed);
        let max_start = self.split - seq_len; // s + seq_len ≤ split always
        Ok((0..n_seqs)
            .map(|_| {
                let s = rng.below(max_start);
                self.tokens[s..s + seq_len].to_vec()
            })
            .collect())
    }

    /// Non-overlapping eval windows from the held-out tail.
    pub fn eval_sequences(&self, seq_len: usize, max_seqs: usize)
                          -> Vec<Vec<i32>> {
        let tail = &self.tokens[self.split..];
        tail.chunks_exact(seq_len)
            .take(max_seqs)
            .map(|c| c.to_vec())
            .collect()
    }
}

/// Pack sequences into fixed-size batches, padding the final batch by
/// repeating its last row (rows beyond `len` are ignored by the caller).
pub fn batch_sequences(seqs: &[Vec<i32>], batch: usize)
                       -> Vec<(Vec<i32>, usize)> {
    let mut out = Vec::new();
    for chunk in seqs.chunks(batch) {
        let used = chunk.len();
        let seq_len = chunk[0].len();
        let mut flat = Vec::with_capacity(batch * seq_len);
        for s in chunk {
            assert_eq!(s.len(), seq_len);
            flat.extend_from_slice(s);
        }
        for _ in used..batch {
            let last = &chunk[used - 1];
            flat.extend_from_slice(last);
        }
        out.push((flat, used));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenize_roundtrip() {
        let s = "The comet orbits. = Nebula =\n";
        assert_eq!(detokenize(&tokenize(s)), s);
    }

    #[test]
    fn corpus_split_and_calib() {
        let text = "abcdefgh".repeat(200);
        let c = Corpus::from_text("t", &text);
        assert_eq!(c.tokens.len(), 1600);
        assert_eq!(c.split, 1440);
        let seqs = c.calib_sequences(5, 16, 42).unwrap();
        assert_eq!(seqs.len(), 5);
        for s in &seqs {
            assert_eq!(s.len(), 16);
        }
        // determinism
        assert_eq!(seqs, c.calib_sequences(5, 16, 42).unwrap());
    }

    #[test]
    fn calib_windows_stay_inside_the_train_region() {
        // token value == position (the Corpus is built directly, so
        // tokens need not be bytes): every window's start offset is
        // exactly recoverable and the s + seq_len ≤ split bound is
        // observable, not assumed
        let c = Corpus { name: "pos".into(), tokens: (0..500).collect(),
                         split: 450 };
        let seqs = c.calib_sequences(64, 32, 7).unwrap();
        for s in &seqs {
            let start = s[0] as usize;
            assert_eq!(s, &(start as i32..(start + 32) as i32)
                           .collect::<Vec<_>>(),
                       "window is not a contiguous corpus slice");
            assert!(start + 32 <= c.split,
                    "window starting at {start} leaks past split {}",
                    c.split);
        }
    }

    #[test]
    fn short_corpus_errors_instead_of_panicking() {
        // regression: corpora shorter than seq_len + 1 used to clamp
        // max_start to 1 and slice past the token buffer
        for text in ["", "ab", &"x".repeat(16)] {
            let c = Corpus::from_text("tiny", text);
            let err = c.calib_sequences(4, 16, 1).unwrap_err().to_string();
            assert!(err.contains("too short for calibration"),
                    "unexpected error for {} tokens: {err}", text.len());
        }
        // boundary: train region exactly seq_len + 1 tokens must work
        let c = Corpus::from_text("edge", &"y".repeat(20)); // split = 18
        let seqs = c.calib_sequences(3, 17, 1).unwrap();
        assert_eq!(seqs.len(), 3);
        assert!(c.calib_sequences(3, 18, 1).is_err()); // one past the edge
    }

    #[test]
    fn eval_windows_nonoverlapping() {
        let text = "x".repeat(1000);
        let c = Corpus::from_text("t", &text);
        let seqs = c.eval_sequences(16, 100);
        assert_eq!(seqs.len(), (1000 - 900) / 16);
    }

    #[test]
    fn batching_pads() {
        let seqs: Vec<Vec<i32>> = (0..5).map(|i| vec![i; 4]).collect();
        let batches = batch_sequences(&seqs, 2);
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[2].1, 1); // one real row in the last batch
        assert_eq!(batches[2].0.len(), 8); // padded to full batch
        assert_eq!(&batches[2].0[4..], &[4, 4, 4, 4]); // repeat-pad
    }
}
