//! Data layer: byte tokenizer, corpus loading/splitting, eval batching and
//! the lm-eval-substitute task suites (read from artifacts/tasks/*.json).

pub mod tasks;

pub use tasks::{Task, TaskItem};

use std::path::Path;

/// Byte-level tokenizer — the vocabulary is exactly 0..=255.
pub const VOCAB_SIZE: usize = 256;

pub fn tokenize(text: &str) -> Vec<i32> {
    text.as_bytes().iter().map(|&b| b as i32).collect()
}

pub fn detokenize(ids: &[i32]) -> String {
    let bytes: Vec<u8> = ids.iter().map(|&i| (i & 0xff) as u8).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// A tokenized corpus with a deterministic train/held-out split.
#[derive(Clone, Debug)]
pub struct Corpus {
    pub name: String,
    pub tokens: Vec<i32>,
    /// index where the held-out tail begins (last 10%)
    pub split: usize,
}

impl Corpus {
    pub fn load(path: &Path) -> std::io::Result<Corpus> {
        let text = std::fs::read_to_string(path)?;
        let tokens = tokenize(&text);
        let split = tokens.len() * 9 / 10;
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default();
        Ok(Corpus { name, tokens, split })
    }

    pub fn from_text(name: &str, text: &str) -> Corpus {
        let tokens = tokenize(text);
        let split = tokens.len() * 9 / 10;
        Corpus { name: name.into(), tokens, split }
    }

    /// Deterministic calibration sequences from the *train* region
    /// (the paper: 128 random sequences of the calibration set).
    pub fn calib_sequences(&self, n_seqs: usize, seq_len: usize, seed: u64)
                           -> Vec<Vec<i32>> {
        let mut rng = crate::rng::Rng::new(seed);
        let max_start = self.split.saturating_sub(seq_len + 1).max(1);
        (0..n_seqs)
            .map(|_| {
                let s = rng.below(max_start);
                self.tokens[s..s + seq_len].to_vec()
            })
            .collect()
    }

    /// Non-overlapping eval windows from the held-out tail.
    pub fn eval_sequences(&self, seq_len: usize, max_seqs: usize)
                          -> Vec<Vec<i32>> {
        let tail = &self.tokens[self.split..];
        tail.chunks_exact(seq_len)
            .take(max_seqs)
            .map(|c| c.to_vec())
            .collect()
    }
}

/// Pack sequences into fixed-size batches, padding the final batch by
/// repeating its last row (rows beyond `len` are ignored by the caller).
pub fn batch_sequences(seqs: &[Vec<i32>], batch: usize)
                       -> Vec<(Vec<i32>, usize)> {
    let mut out = Vec::new();
    for chunk in seqs.chunks(batch) {
        let used = chunk.len();
        let seq_len = chunk[0].len();
        let mut flat = Vec::with_capacity(batch * seq_len);
        for s in chunk {
            assert_eq!(s.len(), seq_len);
            flat.extend_from_slice(s);
        }
        for _ in used..batch {
            let last = &chunk[used - 1];
            flat.extend_from_slice(last);
        }
        out.push((flat, used));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenize_roundtrip() {
        let s = "The comet orbits. = Nebula =\n";
        assert_eq!(detokenize(&tokenize(s)), s);
    }

    #[test]
    fn corpus_split_and_calib() {
        let text = "abcdefgh".repeat(200);
        let c = Corpus::from_text("t", &text);
        assert_eq!(c.tokens.len(), 1600);
        assert_eq!(c.split, 1440);
        let seqs = c.calib_sequences(5, 16, 42);
        assert_eq!(seqs.len(), 5);
        for s in &seqs {
            assert_eq!(s.len(), 16);
        }
        // determinism
        assert_eq!(seqs, c.calib_sequences(5, 16, 42));
    }

    #[test]
    fn eval_windows_nonoverlapping() {
        let text = "x".repeat(1000);
        let c = Corpus::from_text("t", &text);
        let seqs = c.eval_sequences(16, 100);
        assert_eq!(seqs.len(), (1000 - 900) / 16);
    }

    #[test]
    fn batching_pads() {
        let seqs: Vec<Vec<i32>> = (0..5).map(|i| vec![i; 4]).collect();
        let batches = batch_sequences(&seqs, 2);
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[2].1, 1); // one real row in the last batch
        assert_eq!(batches[2].0.len(), 8); // padded to full batch
        assert_eq!(&batches[2].0[4..], &[4, 4, 4, 4]); // repeat-pad
    }
}
