//! lm-eval-substitute task suites: multiple-choice items scored by
//! length-normalised log-probability of each candidate continuation —
//! the exact protocol lm-eval uses for PIQA/HellaSwag/ARC/Winogrande.
//!
//! Generated at build time by python/compile/data.py into
//! artifacts/tasks/<name>.json; this module only parses + prepares them.

use crate::util::Json;
use std::path::Path;

/// One multiple-choice item.
#[derive(Clone, Debug)]
pub struct TaskItem {
    pub prompt: String,
    pub choices: Vec<String>,
    pub answer: usize,
}

#[derive(Clone, Debug)]
pub struct Task {
    pub name: String,
    pub items: Vec<TaskItem>,
}

/// The six suites mirroring the paper's task spread.
pub const TASK_NAMES: [&str; 6] =
    ["pq_syn", "hs_syn", "ae_syn", "ac_syn", "wg_syn", "la_syn"];

impl Task {
    pub fn load(path: &Path) -> Result<Task, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {path:?}: {e}"))?;
        let v = Json::parse(&text)?;
        let name = v
            .get("name")
            .and_then(|n| n.as_str())
            .ok_or("task missing name")?
            .to_string();
        let mut items = Vec::new();
        for it in v.get("items").and_then(|i| i.as_arr()).ok_or("items")? {
            let prompt = it.get("prompt").and_then(|p| p.as_str())
                .ok_or("prompt")?.to_string();
            let choices = it
                .get("choices")
                .and_then(|c| c.as_arr())
                .ok_or("choices")?
                .iter()
                .map(|c| c.as_str().unwrap_or_default().to_string())
                .collect::<Vec<_>>();
            let answer = it.get("answer").and_then(|a| a.as_usize())
                .ok_or("answer")?;
            if answer >= choices.len() {
                return Err(format!("answer {answer} out of range"));
            }
            items.push(TaskItem { prompt, choices, answer });
        }
        Ok(Task { name, items })
    }

    pub fn load_all(task_dir: &Path, limit: Option<usize>)
                    -> Result<Vec<Task>, String> {
        TASK_NAMES
            .iter()
            .map(|n| {
                let mut t = Task::load(&task_dir.join(format!("{n}.json")))?;
                if let Some(l) = limit {
                    t.items.truncate(l);
                }
                Ok(t)
            })
            .collect()
    }
}

/// A scoring row: tokens of prompt+choice packed to `seq_len`, with the
/// range of positions whose logprob scores the choice.
#[derive(Clone, Debug)]
pub struct ScoringRow {
    pub tokens: Vec<i32>,
    /// predictions at positions [start, end) score the choice: the token
    /// at position p+1 is predicted from position p.
    pub start: usize,
    pub end: usize,
}

/// Build the scoring row for (prompt, choice): left-truncate the prompt so
/// prompt+choice fits `seq_len`, right-pad with zeros (ignored positions).
pub fn scoring_row(prompt: &str, choice: &str, seq_len: usize) -> ScoringRow {
    let p = super::tokenize(prompt);
    let c = super::tokenize(choice);
    let c_len = c.len().min(seq_len.saturating_sub(2));
    let c = &c[..c_len];
    let budget = seq_len - c_len;
    let p_keep = p.len().min(budget).max(1);
    let p = &p[p.len() - p_keep..];
    let mut tokens = Vec::with_capacity(seq_len);
    tokens.extend_from_slice(p);
    tokens.extend_from_slice(c);
    let start = p.len() - 1; // predict first choice token from last prompt tok
    let end = start + c_len;
    tokens.resize(seq_len, 0);
    ScoringRow { tokens, start, end }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_task_json() {
        let text = r#"{"name":"pq_syn","items":[
            {"prompt":"the star ","choices":["a","b","c","d"],"answer":2}
        ]}"#;
        let tmp = std::env::temp_dir().join("lrc_task_test.json");
        std::fs::write(&tmp, text).unwrap();
        let t = Task::load(&tmp).unwrap();
        assert_eq!(t.name, "pq_syn");
        assert_eq!(t.items[0].answer, 2);
        assert_eq!(t.items[0].choices.len(), 4);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn scoring_row_fits() {
        let row = scoring_row("abcdef", "XYZ", 8);
        assert_eq!(row.tokens.len(), 8);
        // choice occupies 3 tokens right after the (possibly truncated) prompt
        assert_eq!(row.end - row.start, 3);
        let txt = super::super::detokenize(&row.tokens[..row.end + 1]);
        assert!(txt.ends_with("XYZ"), "{txt}");
    }

    #[test]
    fn scoring_row_truncates_long_prompt() {
        let long = "p".repeat(100);
        let row = scoring_row(&long, "cc", 16);
        assert_eq!(row.tokens.len(), 16);
        assert_eq!(row.end - row.start, 2);
        assert!(row.end < 16);
    }

    #[test]
    fn scoring_row_truncates_long_choice() {
        let row = scoring_row("p", &"c".repeat(100), 16);
        assert_eq!(row.tokens.len(), 16);
        assert!(row.end <= 15);
    }

    #[test]
    fn bad_answer_rejected() {
        let text = r#"{"name":"x","items":[
            {"prompt":"p","choices":["a"],"answer":3}]}"#;
        let tmp = std::env::temp_dir().join("lrc_task_bad.json");
        std::fs::write(&tmp, text).unwrap();
        assert!(Task::load(&tmp).is_err());
        std::fs::remove_file(&tmp).ok();
    }
}
