//! The sweep subsystem: a declarative **method × w_bits × rank_pct ×
//! group** grid driver over the PTQ pipeline — the paper's Table-3 /
//! Fig.-3 tradeoff *surface* instead of one cell at a time.
//!
//! Design points (see also `tests/sweep_grid.rs`):
//!
//! * **Shared calibration.**  Stats collection dominates wall-clock, and
//!   only the activation-quant config (the group axis) touches Σ — the
//!   method / w_bits / rank axes never do.  The driver therefore takes
//!   one [`CalibStats`] per distinct group value and reuses it across
//!   every cell; with the default single-group axis that is literally
//!   once per model.
//! * **Canonical fold order.**  Cells are materialized in [`CellKey`]
//!   `Ord` order and fanned out on the pool; results are folded back in
//!   that same order, and every cell's math is bit-identical at any
//!   thread count (the [`crate::par`] contract) — so the full grid
//!   report is **byte-identical** at `LRC_THREADS ∈ {1, 4, …}`.
//! * **Resume.**  Each finished cell is persisted as a keyed JSON
//!   fragment under the cells dir and skipped (loaded, not recomputed)
//!   on re-run; a resumed report is byte-identical to a fresh one.
//! * **Built-in sanity assertions.**  The Fig.-3 quantizer ordering
//!   (GPTQ ≤ RTN per cell), error non-increasing in rank_pct at fixed
//!   bits, `size_bytes` strictly increasing in w_bits at fixed rank, and
//!   QuaRot ≡ GPTQ-at-rank-0 as a free cross-check.
//! * **Warm worker arenas.**  Grid cells run on the persistent pool, so
//!   each worker's [`crate::linalg::workspace`] arena — the packed GEMM
//!   panels, GPTQ block scratch and regularized-Σ copies — is warmed by
//!   its first cell and reused verbatim by every subsequent cell of the
//!   same model shape: the steady-state grid does no kernel-scratch
//!   allocation at all.
//!
//! The driver is engine-free: cells quantize against a synthesized
//! rank layout ([`crate::pipeline::cell_graph`]), so the grid runs on
//! real model artifacts *or* on the in-memory synthetic model
//! ([`synthetic_artifacts`]) — which is what CI's `lrc sweep --fast`
//! smoke uses, PJRT stub and all.  NLL is filled in per cell only when
//! the caller supplies an evaluator (a real engine + a matching AOT
//! graph); engine-free runs record it as `null`.

use std::collections::{BTreeMap, BTreeSet};
use std::net::TcpListener;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use crate::linalg::Mat;
use crate::lrc::LayerStats;
use crate::par::Pool;
use crate::pipeline::{activation_source, cell_graph, quantize_model_with_pool,
                      quantized_layer_names, CalibStats, Method,
                      PipelineReport};
use crate::quant::{search_act_clip, QuantConfig, Quantizer};
use crate::registry::{service, FsRegistry, ObjectKey, Registry,
                      RegistryCounters};
use crate::rng::Rng;
use crate::runtime::{ModelArtifacts, ModelInfo, TensorBundle};
use crate::util::{render_table, Json};

/// Slack for the Fig.-3 quantizer ordering (GPTQ ≤ RTN): the alternation's
/// UQ half-steps are approximate, so a strict `<=` can flicker by a few
/// percent at positive rank (see `tests/quant_roundtrip.rs`).
pub const FIG3_SLACK: f64 = 1.02;

/// Slack for rank monotonicity: more correction rank never *materially*
/// hurts, but GPTQ's approximate half-steps allow small inversions (the
/// `higher_rank_never_worse` unit test uses the same bound).
pub const RANK_SLACK: f64 = 1.05;

/// The sweep's method axis.  `Rtn` / `Gptq` are the Fig.-3 quantizer
/// ablation *inside* the LRC alternation (at rank 0 they degrade to the
/// plain RTN / GPTQ baselines); `Quarot` is the paper's named rank-0
/// baseline row (its rank axis collapses to the single rank-0 cell, and
/// it is GPTQ-at-rank-0 by construction — the sanity pass asserts that
/// equality as a free cross-check); `Svd` is the LQER-style weight-residual
/// baseline; `Lrc` is the paper's method (same solver as `Gptq`, kept as
/// the canonical table row).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SweepMethod {
    Rtn,
    Gptq,
    Quarot,
    Svd,
    Lrc,
}

impl SweepMethod {
    pub fn parse(s: &str) -> Result<SweepMethod> {
        match s {
            "rtn" => Ok(SweepMethod::Rtn),
            "gptq" => Ok(SweepMethod::Gptq),
            "quarot" => Ok(SweepMethod::Quarot),
            "svd" => Ok(SweepMethod::Svd),
            "lrc" => Ok(SweepMethod::Lrc),
            _ => Err(anyhow!(
                "unknown sweep method {s} (rtn|gptq|quarot|svd|lrc)")),
        }
    }

    /// Stable lowercase name (cell keys, CLI round-trip).
    pub fn name(&self) -> &'static str {
        match self {
            SweepMethod::Rtn => "rtn",
            SweepMethod::Gptq => "gptq",
            SweepMethod::Quarot => "quarot",
            SweepMethod::Svd => "svd",
            SweepMethod::Lrc => "lrc",
        }
    }

    /// Display label for the report tables.
    pub fn label(&self) -> &'static str {
        match self {
            SweepMethod::Rtn => "RTN",
            SweepMethod::Gptq => "GPTQ",
            SweepMethod::Quarot => "QuaRot",
            SweepMethod::Svd => "SVD",
            SweepMethod::Lrc => "LRC",
        }
    }

    /// The pipeline method a cell of this row runs.
    pub fn pipeline_method(&self) -> Method {
        match self {
            SweepMethod::Quarot => Method::Quarot,
            SweepMethod::Svd => Method::Svd,
            _ => Method::Lrc,
        }
    }

    /// The weight quantizer inside Update-Quant.
    pub fn quantizer(&self) -> Quantizer {
        match self {
            SweepMethod::Rtn => Quantizer::Rtn,
            _ => Quantizer::Gptq,
        }
    }

    /// Whether the rank_pct axis applies (QuaRot always solves at rank 0,
    /// so its rank axis collapses to the single rank-0 cell).
    pub fn uses_rank(&self) -> bool {
        !matches!(self, SweepMethod::Quarot)
    }
}

/// The classic Tables-1/2 variant rows — QuaRot, SVD, LRC(1), LRC(5) —
/// now derived from the grid's method axis instead of the old hardcoded
/// 4-bit-only `standard_method_set` (retired in favor of this driver).
pub fn table_method_rows() -> Vec<(SweepMethod, usize)> {
    vec![(SweepMethod::Quarot, 1), (SweepMethod::Svd, 1),
         (SweepMethod::Lrc, 1), (SweepMethod::Lrc, 5)]
}

/// One grid cell, identified by its swept coordinates.  The derived `Ord`
/// is the canonical fold order of the whole subsystem: reports, fragment
/// scans and pool fan-outs all iterate cells in this order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct CellKey {
    pub method: SweepMethod,
    pub w_bits: u32,
    pub rank_pct: usize,
    pub a_group: Option<usize>,
}

impl CellKey {
    /// Stable cell id: fragment filename and report key,
    /// e.g. `lrc_w4_r10_gnone`.
    pub fn id(&self) -> String {
        let g = match self.a_group {
            None => "none".to_string(),
            Some(g) => g.to_string(),
        };
        format!("{}_w{}_r{}_g{}", self.method.name(), self.w_bits,
                self.rank_pct, g)
    }

    /// The per-cell [`QuantConfig`] (bits × group × quantizer × rank).
    pub fn quant_config(&self, iters: usize) -> QuantConfig {
        QuantConfig::cell(self.w_bits, self.a_group,
                          self.method.quantizer(),
                          self.rank_pct as f64 / 100.0, iters)
    }

    /// Inverse of [`CellKey::id`]: parse `lrc_w4_r10_gnone` back into its
    /// coordinates.  This is how a sweep worker recovers the cell a
    /// dispatcher assigned it — the wire protocol carries ids, not
    /// structs.  Strict: the parsed key must re-render to the input, so
    /// non-canonical spellings (`g0`, leading zeros) are rejected rather
    /// than silently aliased onto another cell.
    pub fn parse(id: &str) -> Result<CellKey> {
        let parts: Vec<&str> = id.split('_').collect();
        let [m, w, r, g] = parts[..] else {
            bail!("malformed cell id {id:?} (want method_wN_rN_gG)");
        };
        let method = SweepMethod::parse(m)?;
        let w_bits = w.strip_prefix('w').and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow!("bad w_bits in cell id {id:?}"))?;
        let rank_pct = r.strip_prefix('r').and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow!("bad rank_pct in cell id {id:?}"))?;
        let a_group = match g.strip_prefix('g')
            .ok_or_else(|| anyhow!("bad group in cell id {id:?}"))? {
            "none" => None,
            t => match t.parse::<usize>() {
                // group 0 is the ungrouped cell and spells "gnone"
                Ok(0) | Err(_) => {
                    bail!("bad group in cell id {id:?}");
                }
                Ok(n) => Some(n),
            },
        };
        let key = CellKey { method, w_bits, rank_pct, a_group };
        if key.id() != id {
            bail!("non-canonical cell id {id:?} (canonical: {})", key.id());
        }
        Ok(key)
    }
}

/// The declarative grid: every axis the driver sweeps.
#[derive(Clone, Debug)]
pub struct SweepAxes {
    pub methods: Vec<SweepMethod>,
    pub w_bits: Vec<u32>,
    pub rank_pcts: Vec<usize>,
    pub groups: Vec<Option<usize>>,
    /// LRC alternating iterations (grid-level: every cell shares it)
    pub iters: usize,
}

impl SweepAxes {
    /// The full paper-shaped grid: RTN/QuaRot/SVD/LRC × {2,3,4,8} bits ×
    /// {0,5,10,20,30}% rank, ungrouped.  (`gptq` stays available on the
    /// method axis but duplicates `lrc` cell-for-cell, so the default
    /// grid carries `rtn` as the Fig.-3 counterpart instead.)
    pub fn full() -> SweepAxes {
        SweepAxes {
            methods: vec![SweepMethod::Rtn, SweepMethod::Quarot,
                          SweepMethod::Svd, SweepMethod::Lrc],
            w_bits: vec![2, 3, 4, 8],
            rank_pcts: vec![0, 5, 10, 20, 30],
            groups: vec![None],
            iters: 1,
        }
    }

    /// The CI smoke grid: 2 methods × {2,4} bits × {0,10}% — 8 cells,
    /// small enough for a workflow job yet exercising every built-in
    /// sanity assertion (quantizer ordering, rank monotonicity, size
    /// growth).
    pub fn fast() -> SweepAxes {
        SweepAxes {
            methods: vec![SweepMethod::Rtn, SweepMethod::Lrc],
            w_bits: vec![2, 4],
            rank_pcts: vec![0, 10],
            groups: vec![None],
            iters: 1,
        }
    }

    /// Apply `--methods/--bits/--pcts/--groups/--iters` CSV overrides.
    pub fn from_args(args: &crate::util::Args, fast: bool)
                     -> Result<SweepAxes> {
        let mut axes = if fast { SweepAxes::fast() } else { SweepAxes::full() };
        if let Some(m) = args.get("methods") {
            axes.methods = m.split(',')
                .map(|s| SweepMethod::parse(s.trim()))
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(b) = args.get("bits") {
            axes.w_bits = b.split(',')
                .map(|s| s.trim().parse::<u32>()
                     .map_err(|_| anyhow!("bad --bits entry {s:?}")))
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(p) = args.get("pcts") {
            axes.rank_pcts = p.split(',')
                .map(|s| s.trim().parse::<usize>()
                     .map_err(|_| anyhow!("bad --pcts entry {s:?}")))
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(g) = args.get("groups") {
            axes.groups = g.split(',')
                .map(|s| match s.trim() {
                    "none" | "0" => Ok(None),
                    t => t.parse::<usize>().map(Some)
                        .map_err(|_| anyhow!("bad --groups entry {t:?}")),
                })
                .collect::<Result<Vec<_>>>()?;
        }
        axes.iters = args.get_usize("iters", axes.iters);
        axes.validate()?;
        Ok(axes)
    }

    pub fn validate(&self) -> Result<()> {
        if self.methods.is_empty() || self.w_bits.is_empty()
            || self.rank_pcts.is_empty() || self.groups.is_empty() {
            bail!("sweep axes must all be non-empty");
        }
        for &b in &self.w_bits {
            if !(2..=8).contains(&b) {
                bail!("w_bits {b} out of the packable 2..=8 range");
            }
        }
        for &p in &self.rank_pcts {
            if p > 100 {
                bail!("rank_pct {p} > 100%");
            }
        }
        if self.iters == 0 {
            bail!("--iters must be >= 1");
        }
        Ok(())
    }

    /// Materialize the cell list in canonical order.  Rank-free methods
    /// collapse their rank axis to the single rank-0 cell, and duplicate
    /// coordinates (from repeated axis values) fold away.
    pub fn cells(&self) -> Vec<CellKey> {
        let mut set = BTreeSet::new();
        for &method in &self.methods {
            for &w_bits in &self.w_bits {
                for &pct in &self.rank_pcts {
                    for &a_group in &self.groups {
                        let rank_pct = if method.uses_rank() { pct } else { 0 };
                        set.insert(CellKey { method, w_bits, rank_pct,
                                             a_group });
                    }
                }
            }
        }
        set.into_iter().collect()
    }
}

// ---------------------------------------------------------------------------
// synthetic model + calibration (engine-free grid source)
// ---------------------------------------------------------------------------

/// Deterministic in-memory model artifacts shaped like a small dense
/// transformer — the engine-free grid source CI's sweep smoke runs on.
/// Weights are gaussian; see [`synthetic_calib`] for the activations.
pub fn synthetic_artifacts(seed: u64) -> ModelArtifacts {
    let (d_model, d_ff, n_layers) = (16usize, 32usize, 2usize);
    let info = ModelInfo {
        name: "synthetic".into(),
        d_model,
        n_layers,
        n_heads: 2,
        d_ff,
        n_experts: 0,
        seq_len: 8,
        vocab: 64,
        param_count: 0,
    };
    let mut rng = Rng::new(seed);
    let mut weights = TensorBundle::default();
    for layer in quantized_layer_names(&info) {
        let (dout, din) = match layer.rsplit_once('.').unwrap().1 {
            "wgate" | "wup" => (d_ff, d_model),
            "wdown" => (d_model, d_ff),
            _ => (d_model, d_model),
        };
        let data: Vec<f32> =
            rng.normal_vec(dout * din).iter().map(|&v| v as f32).collect();
        weights.insert(&layer, vec![dout, din], data);
    }
    // a non-quantized tensor so the fp16 size accounting is exercised
    weights.insert("embed", vec![info.vocab, d_model],
                   vec![0.01; info.vocab * d_model]);
    ModelArtifacts {
        dir: std::path::PathBuf::new(),
        weights,
        graphs: BTreeMap::new(),
        info,
    }
}

/// Correlated, outlier-bearing activations — the same regime as
/// `TestModel::layer_problem` (rank-din/4 mixer + isotropic noise, every
/// 16th channel scaled 8×), which is what makes the GPTQ-vs-RTN and
/// rank-monotonicity sanity orderings hold the way the paper's do.
fn synthetic_activations(seed: u64, din: usize, n: usize) -> Mat {
    let mut rng = Rng::new(seed);
    let base = Mat::random_normal(&mut rng, din / 4, n);
    let mixer = Mat::random_normal(&mut rng, din, din / 4);
    let mut x = mixer.matmul(&base)
        .add(&Mat::random_normal(&mut rng, din, n).scale(0.1));
    for i in (0..din).step_by(16) {
        for j in 0..n {
            x[(i, j)] *= 8.0;
        }
    }
    x
}

/// Shared calibration for a synthetic grid run: one activation batch per
/// activation source (generated once), folded into one [`CalibStats`] per
/// distinct group value — mirroring how a real run shares engine-collected
/// stats across cells.  Clips are searched per (source, group) exactly as
/// `collect_stats` does on its first batch.
pub fn synthetic_calib(arts: &ModelArtifacts, seed: u64,
                       groups: &[Option<usize>])
                       -> BTreeMap<Option<usize>, CalibStats> {
    let sources: BTreeSet<String> = quantized_layer_names(&arts.info)
        .iter().map(|l| activation_source(l)).collect();
    let mut xs: BTreeMap<String, Mat> = BTreeMap::new();
    for (i, src) in sources.iter().enumerate() {
        let din = if src.ends_with("ffn_had") { arts.info.d_ff }
                  else { arts.info.d_model };
        xs.insert(src.clone(),
                  synthetic_activations(seed.wrapping_add(i as u64 + 1),
                                        din, 24 * din));
    }
    let gset: BTreeSet<Option<usize>> = groups.iter().copied().collect();
    let mut out = BTreeMap::new();
    for g in gset {
        let mut stats = BTreeMap::new();
        for (src, x) in &xs {
            let clip = search_act_clip(x, 4, g);
            let mut st = LayerStats::new(x.rows, Some(4), clip, g);
            st.update(x);
            stats.insert(src.clone(), st);
        }
        out.insert(g, CalibStats { stats, seconds: 0.0 });
    }
    out
}

// ---------------------------------------------------------------------------
// cell records
// ---------------------------------------------------------------------------

/// Non-finite values would break both JSON and the sanity ordering —
/// record them as null and let the sanity pass flag the cell.
fn finite_num(v: f64) -> Json {
    if v.is_finite() { Json::num(v) } else { Json::Null }
}

/// The machine record for one finished cell — the unit of the report's
/// `cells` array, of the resume fragments and of the CI artifact schema
/// (`lrc-sweep-v1`).  Everything in it is deterministic; timings stay out
/// (they would break the byte-identity contract).
pub fn cell_record(key: &CellKey, run_tag: &str, iters: usize,
                   report: &PipelineReport, nll: Option<f64>) -> Json {
    let rank_used = report.layers.iter().map(|l| l.rank).max().unwrap_or(0);
    let objective: f64 = report.layers.iter().map(|l| l.objective).sum();
    Json::obj(vec![
        ("key", Json::str(key.id())),
        ("run", Json::str(run_tag)),
        ("method", Json::str(key.method.name())),
        ("w_bits", Json::num(key.w_bits as f64)),
        ("rank_pct", Json::num(key.rank_pct as f64)),
        ("a_group", match key.a_group {
            None => Json::Null,
            Some(g) => Json::num(g as f64),
        }),
        ("iters", Json::num(iters as f64)),
        ("rank_used", Json::num(rank_used as f64)),
        ("mean_rel_error", finite_num(report.mean_rel_error())),
        ("objective", finite_num(objective)),
        ("nll", match nll {
            None => Json::Null,
            Some(v) => finite_num(v),
        }),
        ("size_bytes", Json::num(report.size_bytes() as f64)),
        ("packed_bytes", Json::num(report.packed_bytes as f64)),
        ("lowrank_params", Json::num(report.lowrank_params as f64)),
        ("fp_params", Json::num(report.fp_params as f64)),
    ])
}

/// A parsed view of a cell record (fragment or fresh — same shape).
struct Rec {
    key: String,
    method: SweepMethod,
    w_bits: u32,
    rank_pct: usize,
    a_group: Option<usize>,
    rel: Option<f64>,
    nll: Option<f64>,
    rank_used: usize,
    size_bytes: usize,
}

fn parse_rec(j: &Json) -> Result<Rec> {
    let key = j.get("key").and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("cell record missing key"))?.to_string();
    let method = SweepMethod::parse(
        j.get("method").and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("cell {key} missing method"))?)?;
    let num = |f: &str| -> Result<f64> {
        j.get(f).and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow!("cell {key} missing {f}"))
    };
    let w_bits = num("w_bits")? as u32;
    let rank_pct = num("rank_pct")? as usize;
    let rank_used = num("rank_used")? as usize;
    let size_bytes = num("size_bytes")? as usize;
    Ok(Rec {
        method,
        w_bits,
        rank_pct,
        a_group: j.get("a_group").and_then(|v| v.as_usize()),
        rel: j.get("mean_rel_error").and_then(|v| v.as_f64()),
        nll: j.get("nll").and_then(|v| v.as_f64()),
        rank_used,
        size_bytes,
        key,
    })
}

// ---------------------------------------------------------------------------
// the grid driver
// ---------------------------------------------------------------------------

/// Everything one grid run produces.
pub struct SweepOutcome {
    /// per-cell records in canonical order (the report's `cells` array)
    pub records: Vec<Json>,
    /// the machine report (`lrc-sweep-v1`), byte-identical across thread
    /// counts and across fresh-vs-resumed runs
    pub report_json: String,
    /// the aligned Table-3-style text table
    pub markdown: String,
    pub computed: usize,
    pub resumed: usize,
    /// built-in sanity assertion failures (empty = all hold)
    pub violations: Vec<String>,
    /// duplicate worker publishes absorbed from requeue races (each one
    /// verified byte-identical to the first record); always 0 single-box
    pub duplicates: usize,
    /// `(cell id, error)` for cells quarantined after repeated worker
    /// compute failures, in canonical cell order; always empty single-box
    pub quarantined: Vec<(String, String)>,
}

/// Full validation of a cell record against the identity it is claimed
/// for: parses as a record, and its embedded cell id / iteration count /
/// run tag all match.  A record failing any of it (half-written file,
/// older schema, different run pointed at the same store) is recomputed,
/// never trusted — the same bar for registry objects, legacy fragments
/// and worker-published records alike.
fn valid_cell_record(j: &Json, key: &CellKey, iters: usize, run_tag: &str)
                     -> bool {
    parse_rec(j).is_ok()
        && j.get("key").and_then(|v| v.as_str()) == Some(key.id().as_str())
        && j.get("iters").and_then(|v| v.as_usize()) == Some(iters)
        && j.get("run").and_then(|v| v.as_str()) == Some(run_tag)
}

/// Load a pre-registry resume fragment (`cells/<key>.json`) if it exists
/// and validates.  Kept only as the migration source [`SweepStore::load`]
/// adopts old fragments through — new runs never write fragments.
fn load_fragment(dir: &Path, key: &CellKey, iters: usize, run_tag: &str)
                 -> Option<Json> {
    let text = std::fs::read_to_string(dir.join(format!("{}.json", key.id())))
        .ok()?;
    let j = Json::parse(&text).ok()?;
    valid_cell_record(&j, key, iters, run_tag).then_some(j)
}

/// Where a sweep run persists and resumes its cells: a content-addressed
/// [`Registry`] (kind `"sweep-cell"`, keyed by model × method ×
/// full `QuantConfig` × seed × run tag × code version), plus an optional
/// legacy `cells/` fragment dir that pre-registry runs wrote.  A legacy
/// fragment is adopted **once** — validated, published into the registry
/// under its content key — and the registry serves it from then on.
///
/// Shared freely across pool workers (`&self` everywhere; the registry's
/// counters are atomic and FS publishes are temp-file + rename atomic).
pub struct SweepStore {
    registry: Registry,
    root: PathBuf,
    legacy: Option<PathBuf>,
    seed: u64,
}

impl SweepStore {
    /// Open (creating lazily on first publish) the registry at `root`.
    /// `legacy` points at an old run's `cells/` dir to migrate from;
    /// `seed` is the run's RNG seed — part of every cell's content key.
    pub fn open(root: &Path, legacy: Option<&Path>, seed: u64) -> SweepStore {
        SweepStore {
            registry: Registry::local(root),
            root: root.to_path_buf(),
            legacy: legacy.map(|p| p.to_path_buf()),
            seed,
        }
    }

    /// A store over an already-built [`Registry`] (custom backend — the
    /// chaos harness injects torn writes this way).  No legacy dir, and
    /// [`SweepStore::object_file`] is meaningless for non-FS backends.
    pub fn with_registry(registry: Registry, seed: u64) -> SweepStore {
        SweepStore {
            registry,
            root: PathBuf::new(),
            legacy: None,
            seed,
        }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Hit/miss/corruption counters (operator feedback after a run).
    pub fn counters(&self) -> RegistryCounters {
        self.registry.counters()
    }

    pub fn describe(&self) -> String {
        self.registry.describe()
    }

    /// The content key of one cell of one run.
    pub fn cell_key(&self, model: &str, run_tag: &str, cell: &CellKey,
                    iters: usize) -> ObjectKey {
        ObjectKey::new("sweep-cell", model, cell.method.name(),
                       &cell.quant_config(iters), self.seed, run_tag)
    }

    /// Where a cell's meta document lives on disk (tests poke corruption
    /// in; the store itself never reads objects except through the
    /// verified registry).
    pub fn object_file(&self, model: &str, run_tag: &str, cell: &CellKey,
                       iters: usize) -> PathBuf {
        FsRegistry::new(&self.root)
            .object_file(&self.cell_key(model, run_tag, cell, iters).digest())
    }

    /// Fetch a finished cell record, or `None` for "compute it" (absent,
    /// corrupt, stale code version, or identity mismatch).  Falls back to
    /// the legacy fragment dir on a registry miss, publishing any adopted
    /// fragment so the next lookup hits the registry directly.
    pub fn load(&self, model: &str, run_tag: &str, cell: &CellKey,
                iters: usize) -> Option<Json> {
        let okey = self.cell_key(model, run_tag, cell, iters);
        if let Ok(Some(obj)) = self.registry.get(&okey) {
            if let Ok(payload) = obj.payload() {
                if valid_cell_record(payload, cell, iters, run_tag) {
                    return Some(payload.clone());
                }
            }
        }
        let rec = load_fragment(self.legacy.as_deref()?, cell, iters,
                                run_tag)?;
        // adopted: publish under the content key (best-effort — the
        // record itself is already good even if the write fails)
        let _ = self.registry.publish(&okey, &rec, None);
        Some(rec)
    }

    /// Persist a finished cell record under its content key.
    pub fn publish(&self, model: &str, run_tag: &str, cell: &CellKey,
                   iters: usize, record: &Json) -> Result<()> {
        let okey = self.cell_key(model, run_tag, cell, iters);
        self.registry.publish(&okey, record, None)?;
        Ok(())
    }
}

/// Quantize one cell against the shared stats — pure except for reading
/// the shared calibration, so the pool can fan cells out freely.  When
/// the record is already final (no NLL evaluator pending), it is
/// published to the store here, from the worker — a killed grid run
/// resumes from every cell that finished, not from nothing.
fn run_cell(arts: &ModelArtifacts, calib: &CalibStats, key: &CellKey,
            run_tag: &str, iters: usize, pool: &Pool, keep_bundle: bool,
            store: Option<&SweepStore>)
            -> Result<(Json, Option<TensorBundle>)> {
    let graph = cell_graph(arts, key.rank_pct, key.a_group, false, 8)?;
    let cfg = key.quant_config(iters);
    let (bundle, report) = quantize_model_with_pool(
        arts, calib, &graph, key.method.pipeline_method(), &cfg, pool)?;
    let record = cell_record(key, run_tag, iters, &report, None);
    if !keep_bundle {
        if let Some(store) = store {
            store.publish(&arts.info.name, run_tag, key, iters, &record)?;
        }
    }
    Ok((record, keep_bundle.then_some(bundle)))
}

/// Assemble the canonical `lrc-sweep-v1` report (+ markdown table +
/// sanity verdicts) from a full record set in canonical order.  Shared
/// by the single-box driver and the distributed dispatcher — one
/// assembly path is what makes a distributed `report.json` byte-identical
/// to a single-box one.
///
/// `quarantined` lists `(cell id, error)` pairs for cells pulled from
/// the grid after repeated worker failures, in canonical cell order.  A
/// `quarantined` field is added to the report **only when non-empty**,
/// so a fault-free distributed run's bytes are identical to the
/// single-box run's (which always passes `&[]`).
pub fn assemble_report(model: &str, run_tag: &str, iters: usize,
                       records: &[Json], quarantined: &[(String, String)])
                       -> Result<(String, String, Vec<String>)> {
    let mut pairs = vec![
        ("schema", Json::str("lrc-sweep-v1")),
        ("model", Json::str(model)),
        ("run", Json::str(run_tag)),
        ("iters", Json::num(iters as f64)),
        ("cells", Json::Arr(records.to_vec())),
    ];
    if !quarantined.is_empty() {
        pairs.push(("quarantined", Json::Arr(
            quarantined.iter().map(|(id, err)| Json::obj(vec![
                ("error", Json::str(err.clone())),
                ("key", Json::str(id.clone())),
            ])).collect())));
    }
    let report_json = Json::obj(pairs).to_string();
    let mut markdown = markdown_table(records)?;
    if !quarantined.is_empty() {
        markdown.push_str("\nQuarantined cells (no record; repeated \
                           worker failures):\n");
        for (id, err) in quarantined {
            markdown.push_str(&format!("  {id}: {err}\n"));
        }
    }
    let violations = sanity_violations(records)?;
    Ok((report_json, markdown, violations))
}

/// Run the grid: fan missing cells out on `pool` (finished cells are
/// loaded from the store when `resume`), fold in canonical order,
/// assemble report + markdown, and evaluate the built-in sanity
/// assertions.
///
/// `run_tag` is the run's identity (model + seed / calibration setup) —
/// it is part of every cell's registry content key *and* stamped into
/// the record, so pointing two different runs at one store can never
/// silently mix their numbers.  `calib` maps each group-axis value to
/// the [`CalibStats`] shared by every cell of that group.  `nll_eval`
/// (optional, serial — PJRT sessions are not Sync) fills the per-cell NLL
/// from a real engine; engine-free runs pass `None` and record `null`.
///
/// Persistence is incremental in the engine-free case (each worker
/// publishes its cell as it finishes — a killed run resumes from every
/// finished cell).  With an evaluator, cells are published at the serial
/// fold instead (after NLL lands), and every computed cell's bundle is
/// held until its fold slot — prefer grid subsets over one giant grid
/// when memory matters there.
#[allow(clippy::too_many_arguments)]
pub fn run_grid(arts: &ModelArtifacts,
                calib: &BTreeMap<Option<usize>, CalibStats>,
                axes: &SweepAxes, run_tag: &str, store: Option<&SweepStore>,
                resume: bool, pool: &Pool,
                mut nll_eval: Option<&mut dyn FnMut(&CellKey, &TensorBundle)
                                       -> Result<Option<f64>>>)
                -> Result<SweepOutcome> {
    axes.validate()?;
    let cells = axes.cells();
    for c in &cells {
        if !calib.contains_key(&c.a_group) {
            bail!("no shared CalibStats for group {:?} (cell {})",
                  c.a_group, c.id());
        }
    }
    let model = arts.info.name.clone();

    // resume: adopt valid store records (registry, else migrated legacy
    // fragments), in canonical order
    let existing: Vec<Option<Json>> = cells.iter()
        .map(|c| match (resume, store) {
            (true, Some(s)) => s.load(&model, run_tag, c, axes.iters),
            _ => None,
        })
        .collect();

    // fan the missing cells out; canonical index order in, index order out
    let keep_bundle = nll_eval.is_some();
    let fresh: Vec<Option<Result<(Json, Option<TensorBundle>)>>> =
        pool.map(cells.len(), |i| {
            if existing[i].is_some() {
                return None;
            }
            Some(run_cell(arts, &calib[&cells[i].a_group], &cells[i],
                          run_tag, axes.iters, pool, keep_bundle, store))
        });

    // serial fold: NLL evaluation, evaluator-path persistence, record
    // assembly
    let mut records = Vec::with_capacity(cells.len());
    let (mut computed, mut resumed) = (0usize, 0usize);
    for ((cell, prior), fresh) in cells.iter().zip(existing).zip(fresh) {
        let record = match (prior, fresh) {
            (Some(j), _) => {
                resumed += 1;
                j
            }
            (None, Some(res)) => {
                let (mut record, bundle) = res?;
                if let (Some(eval), Some(b)) = (nll_eval.as_mut(), &bundle) {
                    if let Some(nll) = eval(cell, b)? {
                        if let Json::Obj(m) = &mut record {
                            m.insert("nll".into(), finite_num(nll));
                        }
                    }
                    if let Some(s) = store {
                        s.publish(&model, run_tag, cell, axes.iters,
                                  &record)?;
                    }
                }
                computed += 1;
                record
            }
            (None, None) => unreachable!("cell neither resumed nor computed"),
        };
        records.push(record);
    }

    let (report_json, markdown, violations) =
        assemble_report(&model, run_tag, axes.iters, &records, &[])?;
    Ok(SweepOutcome { records, report_json, markdown, computed, resumed,
                      violations, duplicates: 0, quarantined: Vec::new() })
}

// ---------------------------------------------------------------------------
// distributed sweep: dispatcher + worker entry points
// ---------------------------------------------------------------------------

/// Serve the grid over `listener` instead of computing it locally: cells
/// already in the store are prefilled (never handed out), the rest are
/// claimed and computed by `lrc sweep-worker` processes, and every
/// published record is validated and persisted through the store before
/// it is acknowledged.  The merged outcome folds in canonical
/// [`CellKey`] order, so the distributed `report.json` is byte-identical
/// to the single-box one (every cell's math is bit-identical on any
/// machine — the crate's determinism contract).
///
/// Currently serves synthetic grids: the welcome document carries
/// `(run, model, seed, iters)`, which is everything a worker needs to
/// rebuild synthetic inputs; real-model grids keep the single-box path
/// (their calibration stats live in one process's engine).
pub fn serve_grid_distributed(arts: &ModelArtifacts, axes: &SweepAxes,
                              run_tag: &str, store: &SweepStore,
                              resume: bool, listener: &TcpListener,
                              opts: service::ServeOpts,
                              mut progress: impl FnMut(String))
                              -> Result<SweepOutcome> {
    axes.validate()?;
    let cells = axes.cells();
    let ids: Vec<String> = cells.iter().map(|c| c.id()).collect();
    let model = arts.info.name.clone();

    let mut prefilled: BTreeMap<String, Json> = BTreeMap::new();
    if resume {
        for c in &cells {
            if let Some(rec) = store.load(&model, run_tag, c, axes.iters) {
                prefilled.insert(c.id(), rec);
            }
        }
    }
    let resumed = prefilled.len();
    progress(format!("serving {} cell(s) ({} prefilled) on {}",
                     ids.len(), resumed,
                     listener.local_addr()
                         .map(|a| a.to_string())
                         .unwrap_or_else(|_| "?".into())));

    let welcome = Json::obj(vec![
        ("run", Json::str(run_tag)),
        ("model", Json::str(model.clone())),
        ("seed", Json::num(store.seed() as f64)),
        ("iters", Json::num(axes.iters as f64)),
    ]);
    let outcome = service::serve_grid(
        listener, &welcome, &ids, &prefilled, opts,
        |id, rec| {
            let cell = CellKey::parse(id)?;
            if !valid_cell_record(rec, &cell, axes.iters, run_tag) {
                bail!("worker record for {id} failed validation (wrong \
                       run/iters or malformed — version skew?)");
            }
            store.publish(&model, run_tag, &cell, axes.iters, rec)
        },
        &mut progress)?;

    // fold in canonical order — identical to the single-box fold;
    // quarantined cells have no record and are surfaced separately (in
    // the same canonical order, so the report is deterministic at any
    // worker count)
    let quarantined: Vec<(String, String)> = ids.iter()
        .filter_map(|id| outcome.quarantined.get(id)
                    .map(|q| (id.clone(), q.error.clone())))
        .collect();
    let records: Vec<Json> = ids.iter()
        .filter(|id| !outcome.quarantined.contains_key(id.as_str()))
        .map(|id| outcome.records.get(id).cloned()
             .ok_or_else(|| anyhow!("dispatcher finished without cell {id}")))
        .collect::<Result<Vec<_>>>()?;
    let (report_json, markdown, violations) =
        assemble_report(&model, run_tag, axes.iters, &records,
                        &quarantined)?;
    Ok(SweepOutcome { records, report_json, markdown,
                      computed: outcome.computed, resumed, violations,
                      duplicates: outcome.duplicates, quarantined })
}

/// The per-cell compute a synthetic-grid worker runs: rebuild the run's
/// inputs *only* from the dispatcher's welcome document (run tag, model,
/// seed, iters — never local flags, which could skew the identity),
/// quantize the claimed cell, return its record.  Model artifacts and
/// per-group calibration stats are built lazily on the first cell and
/// cached across cells — exactly the shared-calibration structure of the
/// single-box driver, so a worker's records are bit-identical to locally
/// computed ones.
///
/// Shared by [`worker_loop`] and the chaos harness, which drives
/// [`service::run_worker`] directly with a fault shim wrapped around
/// this same compute.
pub fn synthetic_cell_compute(pool: &Pool)
                              -> impl FnMut(&Json, &str) -> Result<Json>
                                 + '_ {
    let mut arts: Option<ModelArtifacts> = None;
    let mut calib: BTreeMap<Option<usize>, CalibStats> = BTreeMap::new();
    move |welcome, id| {
        let get_str = |f: &str| {
            welcome.get(f).and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("dispatcher welcome missing {f}"))
        };
        let run_tag = get_str("run")?;
        let model = get_str("model")?;
        let seed = welcome.get("seed").and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow!("dispatcher welcome missing seed"))?
            as u64;
        let iters = welcome.get("iters").and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow!("dispatcher welcome missing iters"))?;
        if model != "synthetic" {
            bail!("sweep-worker serves synthetic grids only (the \
                   dispatcher announced model {model:?}); run real-model \
                   grids single-box");
        }
        let cell = CellKey::parse(id)?;
        let arts = arts.get_or_insert_with(|| synthetic_artifacts(seed));
        if !calib.contains_key(&cell.a_group) {
            let built = synthetic_calib(arts, seed, &[cell.a_group])
                .remove(&cell.a_group)
                .ok_or_else(|| anyhow!("no calib for group {:?}",
                                       cell.a_group))?;
            calib.insert(cell.a_group, built);
        }
        let graph = cell_graph(arts, cell.rank_pct, cell.a_group, false, 8)?;
        let cfg = cell.quant_config(iters);
        let (_bundle, report) = quantize_model_with_pool(
            arts, &calib[&cell.a_group], &graph,
            cell.method.pipeline_method(), &cfg, pool)?;
        Ok(cell_record(&cell, run_tag, iters, &report, None))
    }
}

/// The `lrc sweep-worker` loop: connect to a dispatcher as `name`,
/// rebuild the run's inputs from its welcome document, then claim →
/// quantize → publish (or report `failed`) until the grid is done,
/// reconnecting through transport faults.
pub fn worker_loop(addr: &str, name: &str, pool: &Pool,
                   mut progress: impl FnMut(String))
                   -> Result<service::WorkerOutcome> {
    service::run_worker(addr, name, None, synthetic_cell_compute(pool),
                        &mut progress)
}

/// The aligned Table-3-style view of the grid.
fn markdown_table(records: &[Json]) -> Result<String> {
    let headers = ["Cell", "Method", "Bits", "Rank%", "Group", "k",
                   "RelErr", "NLL", "Size (B)"];
    let mut rows = Vec::with_capacity(records.len());
    for j in records {
        let r = parse_rec(j)?;
        rows.push(vec![
            r.key.clone(),
            r.method.label().to_string(),
            r.w_bits.to_string(),
            r.rank_pct.to_string(),
            r.a_group.map_or("-".into(), |g| g.to_string()),
            r.rank_used.to_string(),
            r.rel.map_or("-".into(), |v| format!("{v:.6}")),
            r.nll.map_or("-".into(), |v| format!("{v:.4}")),
            r.size_bytes.to_string(),
        ]);
    }
    Ok(render_table(&headers, &rows))
}

/// Evaluate the built-in sanity assertions over a full record set; every
/// returned string is one violated ordering.  Kept separate from
/// [`run_grid`] so the CLI can persist the report *before* failing on a
/// violation (CI still gets the artifact to debug with).
pub fn sanity_violations(records: &[Json]) -> Result<Vec<String>> {
    let recs: Vec<Rec> = records.iter().map(parse_rec)
        .collect::<Result<Vec<_>>>()?;
    let mut out = Vec::new();

    for r in &recs {
        if r.rel.is_none() {
            out.push(format!("{}: non-finite mean_rel_error", r.key));
        }
    }

    // Fig. 3 quantizer ordering: GPTQ-quantizer cells (gptq / lrc /
    // quarot rows) never do materially worse than the RTN row at the
    // same (bits, rank, group) coordinate.
    for rtn in recs.iter().filter(|r| r.method == SweepMethod::Rtn) {
        for g in recs.iter().filter(|g| {
            g.method.quantizer() == Quantizer::Gptq
                && g.w_bits == rtn.w_bits && g.rank_pct == rtn.rank_pct
                && g.a_group == rtn.a_group
        }) {
            if let (Some(gr), Some(rr)) = (g.rel, rtn.rel) {
                if gr > rr * FIG3_SLACK {
                    out.push(format!(
                        "{}: gptq rel_error {gr:.6} > rtn {rr:.6} × {FIG3_SLACK}",
                        g.key));
                }
            }
        }
    }

    // error non-increasing in rank_pct at fixed (method, bits, group)
    let mut by_rank: BTreeMap<(SweepMethod, u32, Option<usize>),
                              Vec<(usize, String, Option<f64>)>> =
        BTreeMap::new();
    for r in recs.iter().filter(|r| r.method.uses_rank()) {
        by_rank.entry((r.method, r.w_bits, r.a_group)).or_default()
            .push((r.rank_pct, r.key.clone(), r.rel));
    }
    for series in by_rank.values_mut() {
        series.sort_by_key(|(p, _, _)| *p);
        for w in series.windows(2) {
            if let (Some(lo), Some(hi)) = (w[1].2, w[0].2) {
                if lo > hi * RANK_SLACK {
                    out.push(format!(
                        "{}: rel_error {lo:.6} at rank {}% > {hi:.6} at \
                         rank {}% × {RANK_SLACK}",
                        w[1].1, w[1].0, w[0].0));
                }
            }
        }
    }

    // size_bytes strictly increasing in w_bits at fixed (method, rank,
    // group)
    let mut by_bits: BTreeMap<(SweepMethod, usize, Option<usize>),
                              Vec<(u32, String, usize)>> = BTreeMap::new();
    for r in &recs {
        by_bits.entry((r.method, r.rank_pct, r.a_group)).or_default()
            .push((r.w_bits, r.key.clone(), r.size_bytes));
    }
    for series in by_bits.values_mut() {
        series.sort_by_key(|(b, _, _)| *b);
        for w in series.windows(2) {
            if w[1].2 <= w[0].2 {
                out.push(format!(
                    "{}: size {} B at {} bits not > {} B at {} bits",
                    w[1].1, w[1].2, w[1].0, w[0].2, w[0].0));
            }
        }
    }

    // free cross-check: QuaRot ≡ GPTQ-quantizer at rank 0, bit for bit
    for q in recs.iter().filter(|r| r.method == SweepMethod::Quarot) {
        for g in recs.iter().filter(|g| {
            matches!(g.method, SweepMethod::Gptq | SweepMethod::Lrc)
                && g.rank_pct == 0 && g.w_bits == q.w_bits
                && g.a_group == q.a_group
        }) {
            if g.rel != q.rel || g.size_bytes != q.size_bytes {
                out.push(format!(
                    "{} and {} must be identical (QuaRot is GPTQ at rank 0)",
                    q.key, g.key));
            }
        }
    }

    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_axis_roundtrip_and_mapping() {
        for m in [SweepMethod::Rtn, SweepMethod::Gptq, SweepMethod::Quarot,
                  SweepMethod::Svd, SweepMethod::Lrc] {
            assert_eq!(SweepMethod::parse(m.name()).unwrap(), m);
        }
        assert!(SweepMethod::parse("fp16").is_err());
        assert_eq!(SweepMethod::Rtn.quantizer(), Quantizer::Rtn);
        assert_eq!(SweepMethod::Lrc.quantizer(), Quantizer::Gptq);
        assert_eq!(SweepMethod::Quarot.pipeline_method(), Method::Quarot);
        assert!(!SweepMethod::Quarot.uses_rank());
        assert!(SweepMethod::Svd.uses_rank());
    }

    #[test]
    fn cells_are_canonical_deduped_and_rank_collapsed() {
        let axes = SweepAxes {
            methods: vec![SweepMethod::Lrc, SweepMethod::Quarot,
                          SweepMethod::Lrc],
            w_bits: vec![4, 2],
            rank_pcts: vec![10, 0],
            groups: vec![None],
            iters: 1,
        };
        let cells = axes.cells();
        // quarot collapses its rank axis: 2 bits × 1 cell; lrc: 2 × 2
        assert_eq!(cells.len(), 2 + 4);
        // canonical order: method, then bits, then pct
        let ids: Vec<String> = cells.iter().map(|c| c.id()).collect();
        assert_eq!(ids, vec![
            "quarot_w2_r0_gnone", "quarot_w4_r0_gnone",
            "lrc_w2_r0_gnone", "lrc_w2_r10_gnone",
            "lrc_w4_r0_gnone", "lrc_w4_r10_gnone",
        ]);
        let mut sorted = cells.clone();
        sorted.sort();
        assert_eq!(sorted, cells);
    }

    #[test]
    fn fast_axes_are_the_ci_smoke_grid() {
        let axes = SweepAxes::fast();
        assert_eq!(axes.methods.len(), 2);
        assert_eq!(axes.w_bits, vec![2, 4]);
        assert_eq!(axes.rank_pcts, vec![0, 10]);
        assert_eq!(axes.cells().len(), 8);
        axes.validate().unwrap();
    }

    #[test]
    fn axes_validation_rejects_bad_grids() {
        let mut axes = SweepAxes::full();
        axes.w_bits = vec![9];
        assert!(axes.validate().is_err());
        let mut axes = SweepAxes::full();
        axes.methods.clear();
        assert!(axes.validate().is_err());
        let mut axes = SweepAxes::full();
        axes.iters = 0;
        assert!(axes.validate().is_err());
    }

    #[test]
    fn from_args_parses_csv_axes() {
        let args = crate::util::Args::parse(
            ["--methods", "rtn,lrc", "--bits", "3,8", "--pcts", "0,30",
             "--groups", "none,32", "--iters", "2"]
                .iter().map(|s| s.to_string()));
        let axes = SweepAxes::from_args(&args, false).unwrap();
        assert_eq!(axes.methods, vec![SweepMethod::Rtn, SweepMethod::Lrc]);
        assert_eq!(axes.w_bits, vec![3, 8]);
        assert_eq!(axes.rank_pcts, vec![0, 30]);
        assert_eq!(axes.groups, vec![None, Some(32)]);
        assert_eq!(axes.iters, 2);
        let bad = crate::util::Args::parse(
            ["--methods", "fp16"].iter().map(|s| s.to_string()));
        assert!(SweepAxes::from_args(&bad, false).is_err());
    }

    #[test]
    fn cell_key_id_and_config() {
        let key = CellKey { method: SweepMethod::Svd, w_bits: 3,
                            rank_pct: 20, a_group: Some(32) };
        assert_eq!(key.id(), "svd_w3_r20_g32");
        let cfg = key.quant_config(2);
        assert_eq!(cfg.w_bits, 3);
        assert_eq!(cfg.a_group, Some(32));
        assert_eq!(cfg.rank_pct, 0.20);
        assert_eq!(cfg.iters, 2);
        assert_eq!(cfg.quantizer, Quantizer::Gptq);
    }

    #[test]
    fn cell_key_parse_roundtrips_every_grid_cell() {
        let mut axes = SweepAxes::full();
        axes.groups = vec![None, Some(32)];
        for cell in axes.cells() {
            assert_eq!(CellKey::parse(&cell.id()).unwrap(), cell,
                       "id {} must parse back to its key", cell.id());
        }
    }

    #[test]
    fn cell_key_parse_rejects_malformed_and_non_canonical_ids() {
        for bad in ["", "lrc", "lrc_w4_r10", "lrc_w4_r10_gnone_x",
                    "fp16_w4_r10_gnone", "lrc_wx_r10_gnone",
                    "lrc_w4_rx_gnone", "lrc_w4_r10_g",
                    // "g0" aliases Some(0) onto a distinct spelling of
                    // the ungrouped cell — canonical form is "gnone"
                    "lrc_w4_r10_g0",
                    "lrc_w04_r10_gnone"] {
            assert!(CellKey::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn sanity_pass_flags_each_ordering() {
        let mk = |key: &str, method: &str, bits: f64, pct: f64, rel: f64,
                  size: f64| {
            Json::obj(vec![
                ("key", Json::str(key)),
                ("method", Json::str(method)),
                ("w_bits", Json::num(bits)),
                ("rank_pct", Json::num(pct)),
                ("a_group", Json::Null),
                ("iters", Json::num(1.0)),
                ("rank_used", Json::num(1.0)),
                ("mean_rel_error", Json::num(rel)),
                ("objective", Json::num(rel)),
                ("nll", Json::Null),
                ("size_bytes", Json::num(size)),
                ("packed_bytes", Json::num(size)),
                ("lowrank_params", Json::num(0.0)),
                ("fp_params", Json::num(0.0)),
            ])
        };
        // a healthy pair of series: no violations
        let good = vec![
            mk("rtn_w2_r0_gnone", "rtn", 2.0, 0.0, 0.30, 100.0),
            mk("rtn_w2_r10_gnone", "rtn", 2.0, 10.0, 0.20, 120.0),
            mk("lrc_w2_r0_gnone", "lrc", 2.0, 0.0, 0.25, 100.0),
            mk("lrc_w2_r10_gnone", "lrc", 2.0, 10.0, 0.10, 120.0),
            mk("lrc_w4_r0_gnone", "lrc", 4.0, 0.0, 0.05, 150.0),
            mk("lrc_w4_r10_gnone", "lrc", 4.0, 10.0, 0.02, 170.0),
        ];
        assert!(sanity_violations(&good).unwrap().is_empty());

        // gptq (here: lrc row) worse than rtn at the same coordinate
        let fig3 = vec![
            mk("rtn_w4_r0_gnone", "rtn", 4.0, 0.0, 0.10, 100.0),
            mk("lrc_w4_r0_gnone", "lrc", 4.0, 0.0, 0.20, 100.0),
        ];
        let v = sanity_violations(&fig3).unwrap();
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("gptq"), "{v:?}");

        // error increasing in rank
        let rank = vec![
            mk("lrc_w4_r0_gnone", "lrc", 4.0, 0.0, 0.10, 100.0),
            mk("lrc_w4_r10_gnone", "lrc", 4.0, 10.0, 0.50, 120.0),
        ];
        let v = sanity_violations(&rank).unwrap();
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("rank"), "{v:?}");

        // size not increasing in bits
        let size = vec![
            mk("lrc_w2_r0_gnone", "lrc", 2.0, 0.0, 0.30, 100.0),
            mk("lrc_w4_r0_gnone", "lrc", 4.0, 0.0, 0.10, 100.0),
        ];
        let v = sanity_violations(&size).unwrap();
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("size"), "{v:?}");

        // quarot must equal the gptq-quantizer rank-0 row exactly
        let cross = vec![
            mk("quarot_w4_r0_gnone", "quarot", 4.0, 0.0, 0.10, 100.0),
            mk("lrc_w4_r0_gnone", "lrc", 4.0, 0.0, 0.11, 100.0),
        ];
        let v = sanity_violations(&cross).unwrap();
        assert!(v.iter().any(|s| s.contains("identical")), "{v:?}");
    }

    #[test]
    fn table_rows_match_the_papers_variant_set() {
        let rows = table_method_rows();
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0], (SweepMethod::Quarot, 1));
        assert_eq!(rows[3], (SweepMethod::Lrc, 5));
        // every row maps onto a runnable pipeline method
        for (m, iters) in rows {
            assert!(iters >= 1);
            let _ = m.pipeline_method();
        }
    }
}
