//! Measurement harness for the `cargo bench` targets (no criterion in the
//! offline image): warmup + timed samples, mean/std/percentiles, and the
//! paper-shaped table rendering every bench target prints.
//!
//! Measurements can additionally be **persisted**: [`record`] (called
//! automatically by [`bench_report`], and explicitly by the bench
//! targets' custom-printed sites) accumulates every named measurement
//! under the current [`section`], and [`write_json`] dumps them as one
//! commit-stampable JSON document — the CI bench job uploads it as a
//! workflow artifact so perf regressions diff across runs instead of
//! scrolling through job logs.  The [`trend`] submodule closes the loop:
//! it compares the current run's medians against the last N persisted
//! artifacts and gates CI on kernel regressions.

pub mod trend;

// analyze: allow(forbidden-api): the bench harness accumulates records
// behind a lock between timed regions only — never inside a measured
// kernel and never on a deterministic compute path.
use std::sync::Mutex;
use std::time::Instant;

use crate::util::Json;

/// Timing statistics over n samples.
#[derive(Clone, Debug)]
pub struct Stats {
    pub samples_ms: Vec<f64>,
}

impl Stats {
    pub fn mean(&self) -> f64 {
        self.samples_ms.iter().sum::<f64>() / self.samples_ms.len().max(1) as f64
    }

    pub fn std(&self) -> f64 {
        let m = self.mean();
        let var = self.samples_ms.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / self.samples_ms.len().max(1) as f64;
        var.sqrt()
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        let mut s = self.samples_ms.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    pub fn min(&self) -> f64 {
        self.samples_ms.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// "12.34 +- 0.56" — the format of the paper's Tables 6–8.
    pub fn pm(&self) -> String {
        format!("{:.2} +- {:.2}", self.mean(), self.std())
    }
}

/// Benchmark a closure: `warmup` unmeasured runs, then `samples` timed runs.
pub fn bench<F: FnMut()>(warmup: usize, samples: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64() * 1000.0);
    }
    Stats { samples_ms: out }
}

/// Convenience wrapper: named measurement printed criterion-style.
pub fn bench_report<F: FnMut()>(name: &str, warmup: usize, samples: usize,
                                f: F) -> Stats {
    let stats = bench(warmup, samples, f);
    println!("{name:<40} {:>12}  (min {:.2} ms, p95 {:.2} ms, n={})",
             stats.pm(), stats.min(), stats.percentile(95.0), samples);
    record(name, &stats);
    stats
}

/// (section, name, samples_ms) triples accumulated for [`write_json`].
// analyze: allow(forbidden-api): bench-artifact accumulator, locked
// only between timed regions of the single-process bench binary.
static RECORDS: Mutex<Vec<(String, String, Vec<f64>)>> =
    Mutex::new(Vec::new());

/// Section the next [`record`] calls land under (set by [`section`]).
// analyze: allow(forbidden-api): bench-artifact section label, locked
// only between timed regions of the single-process bench binary.
static CURRENT_SECTION: Mutex<String> = Mutex::new(String::new());

/// Standard bench-output header so all table benches look alike; also
/// scopes subsequent [`record`]ed measurements for [`write_json`].
pub fn section(title: &str) {
    println!("\n=== {title} ===");
    *CURRENT_SECTION.lock().unwrap() = title.to_string();
}

/// Persist a named measurement under the current section (bench targets
/// with custom println formatting call this next to their printing;
/// [`bench_report`] does it automatically).
pub fn record(name: &str, stats: &Stats) {
    let sec = CURRENT_SECTION.lock().unwrap().clone();
    RECORDS.lock().unwrap()
        .push((sec, name.to_string(), stats.samples_ms.clone()));
}

/// Dump every recorded measurement as one JSON document:
/// `{meta..., unix_time, entries: [{section, name, mean_ms, std_ms,
/// min_ms, p95_ms, samples_ms}]}`.  `meta` carries bench-target name,
/// commit SHA and anything else the caller wants stamped.
pub fn write_json(path: &std::path::Path, meta: &[(&str, String)])
                  -> std::io::Result<()> {
    let entries: Vec<Json> = RECORDS.lock().unwrap().iter()
        .map(|(sec, name, samples)| {
            let s = Stats { samples_ms: samples.clone() };
            Json::obj(vec![
                ("section", Json::str(sec.clone())),
                ("name", Json::str(name.clone())),
                ("mean_ms", Json::num(s.mean())),
                ("std_ms", Json::num(s.std())),
                ("min_ms", Json::num(s.min())),
                ("p95_ms", Json::num(s.percentile(95.0))),
                ("samples_ms",
                 Json::Arr(samples.iter().map(|&v| Json::num(v)).collect())),
            ])
        })
        .collect();
    let unix_time = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as f64)
        .unwrap_or(0.0);
    let mut pairs: Vec<(&str, Json)> = meta.iter()
        .map(|(k, v)| (*k, Json::str(v.clone())))
        .collect();
    pairs.push(("unix_time", Json::num(unix_time)));
    pairs.push(("entries", Json::Arr(entries)));
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, Json::obj(pairs).to_string())
}

/// Mean-time speedup of `new` over `base` (>1 = faster) — the scaling
/// benches report this per thread count.
pub fn speedup(base: &Stats, new: &Stats) -> f64 {
    let m = new.mean();
    if m <= 0.0 {
        return 0.0;
    }
    base.mean() / m
}

/// Achieved GFLOP/s for a measurement of an operation costing `flops`
/// floating-point operations per run (mean-time based) — the kernel
/// benches print this next to the wall-clock columns so perf reads in
/// hardware units, not just ratios.
pub fn gflops(flops: f64, s: &Stats) -> f64 {
    let ms = s.mean();
    if ms <= 0.0 {
        return 0.0;
    }
    flops / (ms / 1e3) / 1e9
}

/// Achieved tokens/s for a measurement whose run processes `tokens`
/// tokens (mean-time based) — the serving-path benches print this next
/// to GFLOP/s so quantized-vs-dense reads in serving units.
pub fn tokens_per_s(tokens: usize, s: &Stats) -> f64 {
    let ms = s.mean();
    if ms <= 0.0 {
        return 0.0;
    }
    tokens as f64 / (ms / 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_math() {
        let s = Stats { samples_ms: vec![1.0, 2.0, 3.0, 4.0] };
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.std() - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 4.0);
    }

    #[test]
    fn bench_runs_closure_expected_times() {
        let mut count = 0;
        let _ = bench(3, 5, || count += 1);
        assert_eq!(count, 8);
    }

    #[test]
    fn pm_format() {
        let s = Stats { samples_ms: vec![10.0, 10.0] };
        assert_eq!(s.pm(), "10.00 +- 0.00");
    }

    #[test]
    fn record_and_write_json_roundtrip() {
        section("json test section");
        record("alpha", &Stats { samples_ms: vec![1.0, 3.0] });
        let path = std::env::temp_dir().join("lrc_bench_json_test.json");
        write_json(&path, &[("bench", "unit".into()),
                            ("commit", "deadbeef".into())]).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap())
            .unwrap();
        assert_eq!(doc.get("bench").and_then(|j| j.as_str()), Some("unit"));
        assert_eq!(doc.get("commit").and_then(|j| j.as_str()),
                   Some("deadbeef"));
        let entries = doc.get("entries").unwrap().as_arr().unwrap();
        // the global record log is shared across tests in this binary;
        // only assert our own entry landed with the right shape
        let mine = entries.iter().find(|e| {
            e.get("name").and_then(|j| j.as_str()) == Some("alpha")
                && e.get("section").and_then(|j| j.as_str())
                    == Some("json test section")
        }).expect("recorded entry missing from JSON");
        assert_eq!(mine.get("mean_ms").and_then(|j| j.as_f64()), Some(2.0));
        assert_eq!(mine.get("samples_ms").unwrap().as_arr().unwrap().len(),
                   2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn gflops_units() {
        // 2e9 flops in 1000 ms = 2 GFLOP/s
        let s = Stats { samples_ms: vec![1000.0] };
        assert!((gflops(2e9, &s) - 2.0).abs() < 1e-12);
        assert_eq!(gflops(1e9, &Stats { samples_ms: vec![] }), 0.0);
    }

    #[test]
    fn tokens_per_s_units() {
        // 64 tokens in 500 ms = 128 tok/s
        let s = Stats { samples_ms: vec![500.0] };
        assert!((tokens_per_s(64, &s) - 128.0).abs() < 1e-9);
        assert_eq!(tokens_per_s(64, &Stats { samples_ms: vec![] }), 0.0);
    }

    #[test]
    fn speedup_ratio() {
        let base = Stats { samples_ms: vec![8.0, 8.0] };
        let faster = Stats { samples_ms: vec![2.0, 2.0] };
        assert!((speedup(&base, &faster) - 4.0).abs() < 1e-12);
        let empty = Stats { samples_ms: vec![] };
        assert_eq!(speedup(&base, &empty), 0.0);
    }
}
