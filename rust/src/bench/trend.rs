//! Bench-trend comparison — the CI regression gate over the bench JSON
//! artifacts [`super::write_json`] persists.
//!
//! The workflow downloads the last N `bench_par` artifacts, and
//! `lrc bench-trend` compares the current run against them: for every
//! `(section, name)` measurement present on both sides, the **median of
//! the baseline runs' medians** (median-of-medians — robust to one noisy
//! CI run) is compared to the current run's median; any entry slower by
//! more than the threshold fails the gate.  The whole comparison renders
//! as a markdown table for `$GITHUB_STEP_SUMMARY`.  With no baseline
//! artifacts yet (the first run), the gate passes with an explicit
//! notice instead of failing.

use std::collections::BTreeMap;

use crate::util::Json;

/// Default regression threshold: fail at > +25% on any named section.
pub const DEFAULT_THRESHOLD_PCT: f64 = 25.0;

/// One compared measurement.
#[derive(Clone, Debug)]
pub struct TrendPoint {
    pub section: String,
    pub name: String,
    /// median ms of the current run's samples
    pub current_ms: f64,
    /// median across baseline runs of each run's median ms
    /// (`None` = measurement new in this run, nothing to compare)
    pub baseline_ms: Option<f64>,
    /// current / baseline (`None` when there is no baseline)
    pub ratio: Option<f64>,
}

/// The full comparison.
#[derive(Clone, Debug)]
pub struct TrendReport {
    pub points: Vec<TrendPoint>,
    /// "section / name" keys that regressed beyond the threshold
    pub regressions: Vec<String>,
    /// measurements present in baselines but missing from this run
    pub removed: Vec<String>,
    pub baseline_runs: usize,
    pub threshold_pct: f64,
}

fn median(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = s.len();
    if n % 2 == 1 {
        s[n / 2]
    } else {
        0.5 * (s[n / 2 - 1] + s[n / 2])
    }
}

/// Per-run medians keyed by `(section, name)`.  Prefers the raw
/// `samples_ms` array; falls back to the precomputed `mean_ms` when a
/// (hand-trimmed) document carries only aggregates.
fn run_medians(doc: &Json) -> BTreeMap<(String, String), f64> {
    let mut out = BTreeMap::new();
    for e in doc.get("entries").and_then(|e| e.as_arr()).unwrap_or(&[]) {
        let section = e.get("section").and_then(|v| v.as_str())
            .unwrap_or("").to_string();
        let name = match e.get("name").and_then(|v| v.as_str()) {
            Some(n) => n.to_string(),
            None => continue,
        };
        let m = match e.get("samples_ms").and_then(|s| s.as_arr()) {
            Some(samples) if !samples.is_empty() => {
                let vals: Vec<f64> =
                    samples.iter().filter_map(|v| v.as_f64()).collect();
                median(&vals)
            }
            _ => match e.get("mean_ms").and_then(|v| v.as_f64()) {
                Some(m) => m,
                None => continue,
            },
        };
        out.insert((section, name), m);
    }
    out
}

/// Compare the current bench document against N baseline documents.
pub fn compare(current: &Json, baselines: &[Json], threshold_pct: f64)
               -> TrendReport {
    let cur = run_medians(current);
    let mut base: BTreeMap<(String, String), Vec<f64>> = BTreeMap::new();
    for doc in baselines {
        for (k, m) in run_medians(doc) {
            base.entry(k).or_default().push(m);
        }
    }

    let mut points = Vec::new();
    let mut regressions = Vec::new();
    for ((section, name), &current_ms) in &cur {
        let baseline_ms = base.get(&(section.clone(), name.clone()))
            .map(|ms| median(ms));
        let ratio = baseline_ms
            .filter(|&b| b > 0.0)
            .map(|b| current_ms / b);
        if let Some(r) = ratio {
            if r > 1.0 + threshold_pct / 100.0 {
                regressions.push(format!("{section} / {name}"));
            }
        }
        points.push(TrendPoint {
            section: section.clone(),
            name: name.clone(),
            current_ms,
            baseline_ms,
            ratio,
        });
    }
    let removed = base.keys()
        .filter(|k| !cur.contains_key(*k))
        .map(|(s, n)| format!("{s} / {n}"))
        .collect();
    TrendReport {
        points,
        regressions,
        removed,
        baseline_runs: baselines.len(),
        threshold_pct,
    }
}

impl TrendReport {
    /// Gate verdict: a first run (no baselines) passes with a notice;
    /// otherwise any regression fails.
    pub fn passed(&self) -> bool {
        self.baseline_runs == 0 || self.regressions.is_empty()
    }

    /// The `$GITHUB_STEP_SUMMARY` markdown table.
    pub fn markdown(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(out, "### Bench trend (threshold +{:.0}%)\n",
                         self.threshold_pct);
        if self.baseline_runs == 0 {
            let _ = writeln!(
                out,
                "**Notice:** fewer than 2 bench artifacts exist — this is \
                 the first recorded run, nothing to compare against. \
                 Passing; the next run will gate against this one.");
            return out;
        }
        let _ = writeln!(out,
                         "Comparing against the median of the last {} \
                          run(s).\n",
                         self.baseline_runs);
        let _ = writeln!(out,
                         "| Section | Measurement | Baseline (ms) | \
                          Current (ms) | Δ | Status |");
        let _ = writeln!(out, "|---|---|---|---|---|---|");
        for p in &self.points {
            let (base, delta, status) = match (p.baseline_ms, p.ratio) {
                (Some(b), Some(r)) => {
                    let pct = (r - 1.0) * 100.0;
                    let ok = r <= 1.0 + self.threshold_pct / 100.0;
                    (format!("{b:.3}"), format!("{pct:+.1}%"),
                     if ok { "ok" } else { "**REGRESSION**" })
                }
                _ => ("-".to_string(), "-".to_string(), "new"),
            };
            let _ = writeln!(out, "| {} | {} | {} | {:.3} | {} | {} |",
                             p.section, p.name, base, p.current_ms, delta,
                             status);
        }
        if !self.removed.is_empty() {
            let _ = writeln!(out,
                             "\nMeasurements in baselines but not in this \
                              run: {}.",
                             self.removed.join(", "));
        }
        if !self.regressions.is_empty() {
            let _ = writeln!(out,
                             "\n**{} regression(s) beyond +{:.0}%:** {}",
                             self.regressions.len(), self.threshold_pct,
                             self.regressions.join(", "));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(entries: &[(&str, &str, &[f64])]) -> Json {
        Json::obj(vec![
            ("bench", Json::str("bench_par")),
            ("entries", Json::Arr(entries.iter().map(|(s, n, v)| {
                Json::obj(vec![
                    ("section", Json::str(*s)),
                    ("name", Json::str(*n)),
                    ("samples_ms",
                     Json::Arr(v.iter().map(|&x| Json::num(x)).collect())),
                ])
            }).collect())),
        ])
    }

    #[test]
    fn median_odd_even_empty() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn regression_beyond_threshold_fails_the_gate() {
        let base = doc(&[("gemm", "blocked 512", &[10.0, 10.0, 10.0])]);
        let cur = doc(&[("gemm", "blocked 512", &[14.0, 14.0, 14.0])]);
        let rep = compare(&cur, &[base], 25.0);
        assert_eq!(rep.regressions, vec!["gemm / blocked 512"]);
        assert!(!rep.passed());
        assert!(rep.markdown().contains("REGRESSION"));
    }

    #[test]
    fn within_threshold_and_improvements_pass() {
        let b1 = doc(&[("gemm", "blocked 512", &[10.0, 11.0, 12.0])]);
        let b2 = doc(&[("gemm", "blocked 512", &[9.0, 10.0, 11.0])]);
        // current median 11.0 vs baseline median-of-medians 10.5: +4.8%
        let cur = doc(&[("gemm", "blocked 512", &[11.0, 11.0])]);
        let rep = compare(&cur, &[b1, b2], 25.0);
        assert!(rep.passed(), "{:?}", rep.regressions);
        let p = &rep.points[0];
        assert_eq!(p.baseline_ms, Some(10.5));
        assert_eq!(p.current_ms, 11.0);
        // a big improvement is also fine
        let fast = doc(&[("gemm", "blocked 512", &[1.0])]);
        let base = doc(&[("gemm", "blocked 512", &[10.0])]);
        assert!(compare(&fast, &[base], 25.0).passed());
    }

    #[test]
    fn first_run_passes_with_notice() {
        let cur = doc(&[("pool", "epoch dispatch", &[0.5])]);
        let rep = compare(&cur, &[], 25.0);
        assert!(rep.passed());
        assert_eq!(rep.baseline_runs, 0);
        let md = rep.markdown();
        assert!(md.contains("fewer than 2 bench artifacts"), "{md}");
    }

    #[test]
    fn new_and_removed_measurements_do_not_gate() {
        let base = doc(&[("gemm", "old kernel", &[5.0])]);
        let cur = doc(&[("gemm", "new kernel", &[50.0])]);
        let rep = compare(&cur, &[base], 25.0);
        assert!(rep.passed(), "new measurements must not gate");
        assert_eq!(rep.removed, vec!["gemm / old kernel"]);
        assert_eq!(rep.points[0].baseline_ms, None);
        let md = rep.markdown();
        assert!(md.contains("new"), "{md}");
        assert!(md.contains("old kernel"), "{md}");
    }

    #[test]
    fn mean_fallback_when_samples_missing() {
        let trimmed = Json::obj(vec![
            ("entries", Json::Arr(vec![Json::obj(vec![
                ("section", Json::str("gemm")),
                ("name", Json::str("blocked 512")),
                ("mean_ms", Json::num(10.0)),
            ])])),
        ]);
        let cur = doc(&[("gemm", "blocked 512", &[10.5])]);
        let rep = compare(&cur, &[trimmed], 25.0);
        assert_eq!(rep.points[0].baseline_ms, Some(10.0));
        assert!(rep.passed());
    }
}
