//! The sweep-worker wire format: length-prefixed JSON frames.
//!
//! One frame is `<decimal byte length>\n<payload>\n` where the length
//! counts the payload only (not either newline) and the payload is one
//! canonical-JSON document.  The prefix makes framing independent of the
//! payload's contents, the trailing newline keeps a captured stream
//! greppable, and the cap below bounds what a malformed peer can make
//! the other side buffer.  The message vocabulary on top of the framing
//! is specified in `docs/REGISTRY.md` (hello/welcome, claim/cell/wait/
//! done, publish/ok, heartbeat, error).
//!
//! Everything here is pure bytes-in/bytes-out — the loops in
//! [`crate::registry::service`] own the sockets — so the framing rules
//! are unit-testable without any I/O.

use anyhow::{bail, Result};

use crate::util::Json;

/// Upper bound on one frame's payload.  Sweep messages are tiny (cell
/// keys and records); anything near this limit is a corrupted or hostile
/// stream, not a bigger message.
pub const MAX_FRAME: usize = 16 * 1024 * 1024;

/// Encode one message as a frame.
pub fn encode_frame(msg: &Json) -> Vec<u8> {
    let body = msg.to_string();
    let mut out = Vec::with_capacity(body.len() + 16);
    out.extend_from_slice(body.len().to_string().as_bytes());
    out.push(b'\n');
    out.extend_from_slice(body.as_bytes());
    out.push(b'\n');
    out
}

/// Incremental frame decoder: feed it whatever the socket produced,
/// drain complete messages.  Tolerates arbitrary fragmentation (one
/// byte at a time) and coalescing (many frames per read).
#[derive(Default)]
pub struct FrameBuf {
    buf: Vec<u8>,
}

impl FrameBuf {
    pub fn new() -> FrameBuf {
        FrameBuf::default()
    }

    /// Append raw bytes read from the peer.
    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Pop the next complete message, `Ok(None)` while one is still
    /// partial.  Errors are not recoverable — a peer that breaks framing
    /// once can never be resynchronized, so the connection must drop.
    pub fn next(&mut self) -> Result<Option<Json>> {
        let Some(nl) = self.buf.iter().position(|&b| b == b'\n') else {
            if self.buf.len() > 32 {
                bail!("frame length prefix too long (not this protocol?)");
            }
            return Ok(None);
        };
        let len: usize = std::str::from_utf8(&self.buf[..nl])
            .ok()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!(
                "bad frame length prefix {:?}",
                String::from_utf8_lossy(&self.buf[..nl])))?;
        if len > MAX_FRAME {
            bail!("frame of {len} bytes exceeds the {MAX_FRAME} cap");
        }
        // prefix + '\n' + payload + '\n'
        let total = nl + 1 + len + 1;
        if self.buf.len() < total {
            return Ok(None);
        }
        if self.buf[total - 1] != b'\n' {
            bail!("frame missing its trailing newline");
        }
        let body = std::str::from_utf8(&self.buf[nl + 1..total - 1])
            .map_err(|_| anyhow::anyhow!("frame payload is not UTF-8"))?;
        let msg = Json::parse(body)
            .map_err(|e| anyhow::anyhow!("frame payload parse: {e}"))?;
        self.buf.drain(..total);
        Ok(Some(msg))
    }
}

/// The `op` field every message carries.
pub fn op_of(msg: &Json) -> Result<&str> {
    msg.get("op").and_then(|o| o.as_str())
        .ok_or_else(|| anyhow::anyhow!("protocol message missing op: {}",
                                       msg.to_string()))
}

/// `{"op": <op>}` shorthand for the payload-free messages.
pub fn msg(op: &str) -> Json {
    Json::obj(vec![("op", Json::str(op))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_single_frame() {
        let m = Json::obj(vec![("op", Json::str("claim")),
                               ("n", Json::num(3.0))]);
        let mut fb = FrameBuf::new();
        fb.extend(&encode_frame(&m));
        assert_eq!(fb.next().unwrap(), Some(m));
        assert_eq!(fb.next().unwrap(), None);
    }

    #[test]
    fn byte_at_a_time_fragmentation() {
        let m = Json::obj(vec![("op", Json::str("publish")),
                               ("key", Json::str("lrc_w4_r10_gnone"))]);
        let bytes = encode_frame(&m);
        let mut fb = FrameBuf::new();
        for (i, b) in bytes.iter().enumerate() {
            fb.extend(std::slice::from_ref(b));
            let got = fb.next().unwrap();
            if i + 1 < bytes.len() {
                assert!(got.is_none(), "complete frame before byte {i}");
            } else {
                assert_eq!(got, Some(m.clone()));
            }
        }
    }

    #[test]
    fn coalesced_frames_drain_in_order() {
        let a = msg("claim");
        let b = Json::obj(vec![("op", Json::str("heartbeat")),
                               ("key", Json::str("x"))]);
        let mut stream = encode_frame(&a);
        stream.extend_from_slice(&encode_frame(&b));
        let mut fb = FrameBuf::new();
        fb.extend(&stream);
        assert_eq!(fb.next().unwrap(), Some(a));
        assert_eq!(fb.next().unwrap(), Some(b));
        assert_eq!(fb.next().unwrap(), None);
    }

    #[test]
    fn framing_violations_are_fatal() {
        // non-numeric prefix
        let mut fb = FrameBuf::new();
        fb.extend(b"nope\n{}\n");
        assert!(fb.next().is_err());
        // oversize declaration
        let mut fb = FrameBuf::new();
        fb.extend(format!("{}\n", MAX_FRAME + 1).as_bytes());
        assert!(fb.next().is_err());
        // missing trailing newline
        let mut fb = FrameBuf::new();
        fb.extend(b"2\n{}X");
        assert!(fb.next().is_err());
        // endless garbage with no newline trips the prefix guard
        let mut fb = FrameBuf::new();
        fb.extend(&[b'7'; 64]);
        assert!(fb.next().is_err());
        // payload must be one JSON document
        let mut fb = FrameBuf::new();
        fb.extend(b"3\n{],\n");
        assert!(fb.next().is_err());
    }

    #[test]
    fn op_accessor() {
        assert_eq!(op_of(&msg("done")).unwrap(), "done");
        assert!(op_of(&Json::obj(vec![("k", Json::num(1.0))])).is_err());
    }
}
