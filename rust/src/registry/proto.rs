//! The sweep-worker wire format: length-prefixed JSON frames.
//!
//! One frame is `<decimal byte length>\n<payload>\n` where the length
//! counts the payload only (not either newline) and the payload is one
//! canonical-JSON document.  The prefix makes framing independent of the
//! payload's contents, the trailing newline keeps a captured stream
//! greppable, and the cap below bounds what a malformed peer can make
//! the other side buffer.  The message vocabulary on top of the framing
//! is specified in `docs/REGISTRY.md` (hello/welcome, claim/cell/wait/
//! done, publish/ok, failed/ok, heartbeat, error).
//!
//! Everything here is pure bytes-in/bytes-out — the loops in
//! [`crate::registry::service`] own the sockets — so the framing rules
//! are unit-testable without any I/O.

use anyhow::{bail, Result};

use crate::util::Json;

/// Upper bound on one frame's payload.  Sweep messages are tiny (cell
/// keys and records); anything near this limit is a corrupted or hostile
/// stream, not a bigger message.
pub const MAX_FRAME: usize = 16 * 1024 * 1024;

/// Encode one message as a frame.
pub fn encode_frame(msg: &Json) -> Vec<u8> {
    let body = msg.to_string();
    let mut out = Vec::with_capacity(body.len() + 16);
    out.extend_from_slice(body.len().to_string().as_bytes());
    out.push(b'\n');
    out.extend_from_slice(body.as_bytes());
    out.push(b'\n');
    out
}

/// Incremental frame decoder: feed it whatever the socket produced,
/// drain complete messages.  Tolerates arbitrary fragmentation (one
/// byte at a time) and coalescing (many frames per read).
#[derive(Default)]
pub struct FrameBuf {
    buf: Vec<u8>,
}

impl FrameBuf {
    pub fn new() -> FrameBuf {
        FrameBuf::default()
    }

    /// Append raw bytes read from the peer.
    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Pop the next complete message, `Ok(None)` while one is still
    /// partial.  Errors are not recoverable — a peer that breaks framing
    /// once can never be resynchronized, so the connection must drop.
    pub fn next(&mut self) -> Result<Option<Json>> {
        let Some(nl) = self.buf.iter().position(|&b| b == b'\n') else {
            if self.buf.len() > 32 {
                bail!("frame length prefix too long (not this protocol?)");
            }
            return Ok(None);
        };
        let len: usize = std::str::from_utf8(&self.buf[..nl])
            .ok()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!(
                "bad frame length prefix {:?}",
                String::from_utf8_lossy(&self.buf[..nl])))?;
        if len > MAX_FRAME {
            bail!("frame of {len} bytes exceeds the {MAX_FRAME} cap");
        }
        // prefix + '\n' + payload + '\n'
        let total = nl + 1 + len + 1;
        if self.buf.len() < total {
            return Ok(None);
        }
        if self.buf[total - 1] != b'\n' {
            bail!("frame missing its trailing newline");
        }
        let body = std::str::from_utf8(&self.buf[nl + 1..total - 1])
            .map_err(|_| anyhow::anyhow!("frame payload is not UTF-8"))?;
        let msg = Json::parse(body)
            .map_err(|e| anyhow::anyhow!("frame payload parse: {e}"))?;
        self.buf.drain(..total);
        Ok(Some(msg))
    }
}

/// The `op` field every message carries.
pub fn op_of(msg: &Json) -> Result<&str> {
    msg.get("op").and_then(|o| o.as_str())
        .ok_or_else(|| anyhow::anyhow!("protocol message missing op: {}",
                                       msg.to_string()))
}

/// `{"op": <op>}` shorthand for the payload-free messages.
pub fn msg(op: &str) -> Json {
    Json::obj(vec![("op", Json::str(op))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_single_frame() {
        let m = Json::obj(vec![("op", Json::str("claim")),
                               ("n", Json::num(3.0))]);
        let mut fb = FrameBuf::new();
        fb.extend(&encode_frame(&m));
        assert_eq!(fb.next().unwrap(), Some(m));
        assert_eq!(fb.next().unwrap(), None);
    }

    #[test]
    fn byte_at_a_time_fragmentation() {
        let m = Json::obj(vec![("op", Json::str("publish")),
                               ("key", Json::str("lrc_w4_r10_gnone"))]);
        let bytes = encode_frame(&m);
        let mut fb = FrameBuf::new();
        for (i, b) in bytes.iter().enumerate() {
            fb.extend(std::slice::from_ref(b));
            let got = fb.next().unwrap();
            if i + 1 < bytes.len() {
                assert!(got.is_none(), "complete frame before byte {i}");
            } else {
                assert_eq!(got, Some(m.clone()));
            }
        }
    }

    #[test]
    fn coalesced_frames_drain_in_order() {
        let a = msg("claim");
        let b = Json::obj(vec![("op", Json::str("heartbeat")),
                               ("key", Json::str("x"))]);
        let mut stream = encode_frame(&a);
        stream.extend_from_slice(&encode_frame(&b));
        let mut fb = FrameBuf::new();
        fb.extend(&stream);
        assert_eq!(fb.next().unwrap(), Some(a));
        assert_eq!(fb.next().unwrap(), Some(b));
        assert_eq!(fb.next().unwrap(), None);
    }

    #[test]
    fn framing_violations_are_fatal() {
        // non-numeric prefix
        let mut fb = FrameBuf::new();
        fb.extend(b"nope\n{}\n");
        assert!(fb.next().is_err());
        // oversize declaration
        let mut fb = FrameBuf::new();
        fb.extend(format!("{}\n", MAX_FRAME + 1).as_bytes());
        assert!(fb.next().is_err());
        // missing trailing newline
        let mut fb = FrameBuf::new();
        fb.extend(b"2\n{}X");
        assert!(fb.next().is_err());
        // endless garbage with no newline trips the prefix guard
        let mut fb = FrameBuf::new();
        fb.extend(&[b'7'; 64]);
        assert!(fb.next().is_err());
        // payload must be one JSON document
        let mut fb = FrameBuf::new();
        fb.extend(b"3\n{],\n");
        assert!(fb.next().is_err());
    }

    #[test]
    fn every_split_point_across_two_coalesced_frames() {
        // the exact shape the fault injector's frame-split fault
        // produces: one write delivered as two arbitrary chunks.  Every
        // cut point of a two-frame stream must decode to the same two
        // messages, with completeness flipping exactly at frame ends.
        let a = Json::obj(vec![("op", Json::str("cell")),
                               ("key", Json::str("lrc_w4_r10_gnone"))]);
        let b = Json::obj(vec![("op", Json::str("failed")),
                               ("error", Json::str("injected"))]);
        let mut stream = encode_frame(&a);
        let first_len = stream.len();
        stream.extend_from_slice(&encode_frame(&b));
        for cut in 0..=stream.len() {
            let mut fb = FrameBuf::new();
            fb.extend(&stream[..cut]);
            let mut got = Vec::new();
            while let Some(m) = fb.next().unwrap() {
                got.push(m);
            }
            assert_eq!(got.len(),
                       usize::from(cut >= first_len)
                       + usize::from(cut >= stream.len()),
                       "wrong frame count at cut {cut}");
            fb.extend(&stream[cut..]);
            while let Some(m) = fb.next().unwrap() {
                got.push(m);
            }
            assert_eq!(got, vec![a.clone(), b.clone()],
                       "stream split at {cut} decoded differently");
        }
    }

    #[test]
    fn truncated_length_line_stays_incomplete_until_the_newline() {
        // a length prefix cut mid-digit is an incomplete frame, not a
        // framing error — the rest of the digits may still arrive
        let m = msg("claim");
        let frame = encode_frame(&m);
        let mut fb = FrameBuf::new();
        fb.extend(&frame[..1]); // first digit only, no newline yet
        assert_eq!(fb.next().unwrap(), None);
        fb.extend(&frame[1..]);
        assert_eq!(fb.next().unwrap(), Some(m));
    }

    #[test]
    fn declared_length_exactly_at_the_cap_is_not_an_error() {
        // the cap rejects frames *beyond* MAX_FRAME; a declaration of
        // exactly MAX_FRAME is a legal (if absurd) frame still waiting
        // for its payload
        let mut fb = FrameBuf::new();
        fb.extend(format!("{MAX_FRAME}\n").as_bytes());
        assert_eq!(fb.next().unwrap(), None, "at-cap length must wait \
                    for payload, not error");
        // one byte over trips it
        let mut fb = FrameBuf::new();
        fb.extend(format!("{}\n", MAX_FRAME + 1).as_bytes());
        assert!(fb.next().is_err());
    }

    #[test]
    fn resume_after_partial_read_keeps_the_stream_aligned() {
        // a partial payload (what a torn/truncated write delivers before
        // the connection drops) parks in the buffer; when the remainder
        // arrives the frame completes, and the *next* frame on the same
        // buffer still decodes — no desync after the stall
        let a = Json::obj(vec![("op", Json::str("publish")),
                               ("rec", Json::num(7.0))]);
        let b = msg("ok");
        let bytes_a = encode_frame(&a);
        let split = bytes_a.len() - 3; // inside the payload
        let mut fb = FrameBuf::new();
        fb.extend(&bytes_a[..split]);
        assert_eq!(fb.next().unwrap(), None);
        assert_eq!(fb.next().unwrap(), None, "polling again must not \
                    consume the parked partial frame");
        fb.extend(&bytes_a[split..]);
        fb.extend(&encode_frame(&b));
        assert_eq!(fb.next().unwrap(), Some(a));
        assert_eq!(fb.next().unwrap(), Some(b));
        assert_eq!(fb.next().unwrap(), None);
    }

    #[test]
    fn op_accessor() {
        assert_eq!(op_of(&msg("done")).unwrap(), "done");
        assert!(op_of(&Json::obj(vec![("k", Json::num(1.0))])).is_err());
    }
}
