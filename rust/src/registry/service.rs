//! Distributed sweep: the dispatcher and worker halves of the
//! `lrc sweep --serve` / `lrc sweep-worker` pair.
//!
//! The dispatcher owns the canonical cell list and hands cells out over
//! the [`crate::registry::proto`] frame protocol; workers claim a cell,
//! compute it with their own local pool, publish the record back and
//! claim again.  Cells are independent and every cell's math is
//! bit-identical on any machine/thread-count (the crate's determinism
//! contract), so the dispatcher merely *collects* — merging the records
//! in canonical key order afterwards reproduces the single-box report
//! byte for byte.
//!
//! Concurrency model: the dispatcher is a **single-threaded non-blocking
//! poll loop** — no threads, no locks, no wall clock (this module sits
//! outside the `par`/`coordinator` concurrency fences and stays there).
//! Liveness is the TCP connection itself: a worker that dies mid-cell
//! drops its connection and the dispatcher requeues its claimed cells
//! for the next claimant.  `heartbeat` frames are progress markers for
//! the operator log, not a liveness timer.
//!
//! Failure stance: a peer that breaks *framing* or speaks the wrong
//! protocol version is dropped (its cells requeue); a record that fails
//! *validation* on publish is fatal for the whole run — that is a
//! version-skewed or miscomputing worker, and silently dropping its
//! result would hide it.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use super::proto::{encode_frame, msg, op_of, FrameBuf};
use crate::util::Json;

/// Protocol version, exchanged in hello/welcome; either side refuses a
/// mismatch (a skewed worker must never publish into a newer grid).
pub const PROTO_VERSION: &str = "lrc-sweep-worker-v1";

/// Dispatcher poll-loop sleep between idle iterations.
const POLL: Duration = Duration::from_millis(2);

/// After the grid completes, the dispatcher keeps the socket open for at
/// least this many poll iterations so a worker racing in right at the
/// end gets a clean `done` answer instead of a reset connection...
const GRACE_ITERS: usize = 250; // ≈0.5 s of 2 ms polls

/// ...and at most this many, so a peer that connects and then stalls
/// can't pin the dispatcher open forever.
const LINGER_ITERS: usize = 1500; // ≈3 s of 2 ms polls

/// How long a worker keeps retrying its initial connect (the dispatcher
/// may still be collecting prefill when workers start).
const CONNECT_ATTEMPTS: usize = 100;
const CONNECT_BACKOFF: Duration = Duration::from_millis(100);

/// What one `serve_grid` run collected.
pub struct ServeOutcome {
    /// every cell's record, keyed by cell id (prefilled + published)
    pub records: BTreeMap<String, Json>,
    /// cells computed by workers this run (not prefilled)
    pub computed: usize,
    /// distinct worker connections accepted
    pub workers_seen: usize,
}

struct Conn {
    stream: TcpStream,
    fb: FrameBuf,
    greeted: bool,
    claimed: BTreeSet<String>,
    alive: bool,
}

/// Write a frame to a non-blocking socket, absorbing `WouldBlock` with
/// short sleeps — frames are tiny, so this converges immediately in
/// practice and bounds nothing but a pathological peer.
fn write_frame_nb(stream: &mut TcpStream, m: &Json) -> std::io::Result<()> {
    let bytes = encode_frame(m);
    let mut off = 0;
    while off < bytes.len() {
        match stream.write(&bytes[off..]) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero, "peer stopped reading"));
            }
            Ok(n) => off += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(POLL);
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Serve one grid over `listener` until every cell in `cells` has a
/// record.  `welcome` is the run-identity document sent to each worker
/// (run tag, model, seed, iters — everything a worker needs to rebuild
/// the identical inputs); `prefilled` seeds already-known records
/// (registry hits), which are never handed out.  `on_publish` runs for
/// every worker-published record (validation + registry write; an error
/// is fatal for the run).  `progress` receives one line per notable
/// event for the operator log.
pub fn serve_grid(listener: &TcpListener, welcome: &Json, cells: &[String],
                  prefilled: &BTreeMap<String, Json>,
                  mut on_publish: impl FnMut(&str, &Json) -> Result<()>,
                  mut progress: impl FnMut(String)) -> Result<ServeOutcome> {
    listener.set_nonblocking(true)
        .context("set dispatcher listener non-blocking")?;
    let cell_set: BTreeSet<&str> = cells.iter().map(|s| s.as_str()).collect();
    let mut done: BTreeMap<String, Json> = BTreeMap::new();
    let mut pending: VecDeque<String> = VecDeque::new();
    for c in cells {
        match prefilled.get(c) {
            Some(rec) => {
                done.insert(c.clone(), rec.clone());
            }
            None => pending.push_back(c.clone()),
        }
    }
    let mut welcome_msg = welcome.clone();
    if let Json::Obj(m) = &mut welcome_msg {
        m.insert("op".into(), Json::str("welcome"));
        m.insert("proto".into(), Json::str(PROTO_VERSION));
    } else {
        bail!("serve_grid welcome must be a JSON object");
    }

    let mut conns: Vec<Conn> = Vec::new();
    let mut computed = 0usize;
    let mut workers_seen = 0usize;
    let mut linger = 0usize;
    loop {
        let mut activity = false;

        // accept every waiting worker
        loop {
            match listener.accept() {
                Ok((stream, peer)) => {
                    stream.set_nonblocking(true)?;
                    let _ = stream.set_nodelay(true);
                    workers_seen += 1;
                    progress(format!("worker connected from {peer}"));
                    conns.push(Conn {
                        stream,
                        fb: FrameBuf::new(),
                        greeted: false,
                        claimed: BTreeSet::new(),
                        alive: true,
                    });
                    activity = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => return Err(e).context("dispatcher accept"),
            }
        }

        // pump every connection
        for conn in conns.iter_mut() {
            let mut buf = [0u8; 4096];
            loop {
                match conn.stream.read(&mut buf) {
                    Ok(0) => {
                        conn.alive = false;
                        break;
                    }
                    Ok(n) => {
                        conn.fb.extend(&buf[..n]);
                        activity = true;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        break;
                    }
                    Err(e) if e.kind()
                        == std::io::ErrorKind::Interrupted => {}
                    Err(_) => {
                        conn.alive = false;
                        break;
                    }
                }
            }
            while conn.alive {
                let m = match conn.fb.next() {
                    Ok(Some(m)) => m,
                    Ok(None) => break,
                    Err(e) => {
                        progress(format!("dropping worker (bad frame: {e})"));
                        conn.alive = false;
                        break;
                    }
                };
                activity = true;
                let grid_done = done.len() == cells.len();
                // a peer whose message has no `op` falls into the
                // unknown-op arm and is dropped — peer malformation is
                // never fatal for the run
                let reply = match op_of(&m).unwrap_or("<missing>") {
                    "hello" => {
                        let theirs = m.get("proto").and_then(|p| p.as_str())
                            .unwrap_or("?");
                        if theirs != PROTO_VERSION {
                            progress(format!(
                                "dropping worker (protocol {theirs:?}, \
                                 want {PROTO_VERSION:?})"));
                            let _ = write_frame_nb(
                                &mut conn.stream,
                                &Json::obj(vec![
                                    ("op", Json::str("error")),
                                    ("message", Json::str(format!(
                                        "protocol mismatch: dispatcher \
                                         speaks {PROTO_VERSION}"))),
                                ]));
                            conn.alive = false;
                            continue;
                        }
                        conn.greeted = true;
                        welcome_msg.clone()
                    }
                    "claim" if !conn.greeted => {
                        conn.alive = false;
                        continue; // claim before hello: not our worker
                    }
                    "claim" => match pending.pop_front() {
                        Some(key) => {
                            conn.claimed.insert(key.clone());
                            Json::obj(vec![("op", Json::str("cell")),
                                           ("key", Json::str(key))])
                        }
                        None if grid_done => msg("done"),
                        None => msg("wait"),
                    },
                    "heartbeat" => {
                        if let Some(k) = m.get("key").and_then(|k| k.as_str())
                        {
                            progress(format!("worker computing {k}"));
                        }
                        msg("ok")
                    }
                    "publish" => {
                        let key = m.get("key").and_then(|k| k.as_str())
                            .map(str::to_string);
                        let (Some(key), Some(rec)) =
                            (key, m.get("record").cloned())
                        else {
                            progress("dropping worker (publish without \
                                      key/record)".to_string());
                            conn.alive = false;
                            continue;
                        };
                        if !cell_set.contains(key.as_str()) {
                            bail!("worker published unknown cell {key}");
                        }
                        conn.claimed.remove(&key);
                        if done.contains_key(&key) {
                            // duplicate result (requeue race): the math
                            // is deterministic, so it is the same bytes —
                            // acknowledge and move on
                            msg("ok")
                        } else {
                            on_publish(&key, &rec).with_context(
                                || format!("publish of cell {key}"))?;
                            pending.retain(|p| p != &key);
                            done.insert(key.clone(), rec);
                            computed += 1;
                            progress(format!("cell {key} published \
                                              ({}/{})", done.len(),
                                             cells.len()));
                            msg("ok")
                        }
                    }
                    other => {
                        progress(format!(
                            "dropping worker (unknown op {other:?})"));
                        conn.alive = false;
                        continue;
                    }
                };
                if write_frame_nb(&mut conn.stream, &reply).is_err() {
                    conn.alive = false;
                }
            }
        }

        // reap dead connections; their claimed-but-unpublished cells go
        // back to the front of the queue for the next claimant
        for conn in conns.iter_mut().filter(|c| !c.alive) {
            for key in std::mem::take(&mut conn.claimed) {
                if !done.contains_key(&key) {
                    progress(format!("requeueing {key} (worker lost)"));
                    pending.push_front(key);
                }
            }
        }
        conns.retain(|c| c.alive);

        if done.len() == cells.len() {
            // grid complete: hold the socket through a short grace
            // window (answering straggler claims with `done`), then
            // exit once every connection has drained; the hard linger
            // cap bounds a stalled peer
            if (conns.is_empty() && linger >= GRACE_ITERS)
                || linger >= LINGER_ITERS
            {
                break;
            }
            linger += 1;
        }
        if !activity {
            std::thread::sleep(POLL);
        }
    }
    Ok(ServeOutcome { records: done, computed, workers_seen })
}

/// Read one frame from a blocking socket.
fn read_frame(stream: &mut TcpStream, fb: &mut FrameBuf) -> Result<Json> {
    loop {
        if let Some(m) = fb.next()? {
            return Ok(m);
        }
        let mut buf = [0u8; 4096];
        let n = stream.read(&mut buf)
            .context("read from dispatcher")?;
        if n == 0 {
            bail!("dispatcher closed the connection");
        }
        fb.extend(&buf[..n]);
    }
}

/// What one worker process accomplished.
pub struct WorkerOutcome {
    /// cells this worker computed and published
    pub computed: usize,
    /// the dispatcher's welcome document (run identity)
    pub welcome: Json,
}

/// The worker loop: connect (with retries — workers usually start while
/// the dispatcher is still prefilling), handshake, then claim → compute
/// → publish until the dispatcher answers `done`.  `compute` receives
/// the welcome document (run identity: model, seed, iters, run tag) and
/// the claimed cell key, and must return the finished cell record.
pub fn run_worker(addr: &str,
                  mut compute: impl FnMut(&Json, &str) -> Result<Json>,
                  mut progress: impl FnMut(String)) -> Result<WorkerOutcome> {
    let mut stream = None;
    for attempt in 0..CONNECT_ATTEMPTS {
        match TcpStream::connect(addr) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(e) if attempt + 1 == CONNECT_ATTEMPTS => {
                return Err(e).with_context(
                    || format!("connect to dispatcher at {addr} \
                                ({CONNECT_ATTEMPTS} attempts)"));
            }
            Err(_) => std::thread::sleep(CONNECT_BACKOFF),
        }
    }
    // SAFETY of unwrap: the loop either set `stream` or returned
    let mut stream = stream.unwrap();
    let _ = stream.set_nodelay(true);
    let mut fb = FrameBuf::new();

    write_frame_nb(&mut stream, &Json::obj(vec![
        ("op", Json::str("hello")),
        ("proto", Json::str(PROTO_VERSION)),
    ]))?;
    let welcome = read_frame(&mut stream, &mut fb)?;
    match op_of(&welcome)? {
        "welcome" => {}
        "error" => bail!("dispatcher refused: {}",
                         welcome.get("message").and_then(|m| m.as_str())
                         .unwrap_or("?")),
        other => bail!("expected welcome, got {other:?}"),
    }
    progress(format!(
        "connected to {addr}: run {}",
        welcome.get("run").and_then(|r| r.as_str()).unwrap_or("?")));

    let mut computed = 0usize;
    loop {
        write_frame_nb(&mut stream, &msg("claim"))?;
        let reply = read_frame(&mut stream, &mut fb)?;
        match op_of(&reply)? {
            "cell" => {
                let key = reply.get("key").and_then(|k| k.as_str())
                    .ok_or_else(|| anyhow!("cell reply missing key"))?
                    .to_string();
                progress(format!("claimed {key}"));
                // progress marker before the (long) compute; liveness
                // itself is the TCP connection
                write_frame_nb(&mut stream, &Json::obj(vec![
                    ("op", Json::str("heartbeat")),
                    ("key", Json::str(key.clone())),
                ]))?;
                let ack = read_frame(&mut stream, &mut fb)?;
                if op_of(&ack)? != "ok" {
                    bail!("heartbeat not acknowledged: {}", ack.to_string());
                }
                let record = compute(&welcome, &key)?;
                write_frame_nb(&mut stream, &Json::obj(vec![
                    ("op", Json::str("publish")),
                    ("key", Json::str(key.clone())),
                    ("record", record),
                ]))?;
                let ack = read_frame(&mut stream, &mut fb)?;
                if op_of(&ack)? != "ok" {
                    bail!("publish of {key} rejected: {}", ack.to_string());
                }
                computed += 1;
            }
            "wait" => std::thread::sleep(Duration::from_millis(25)),
            "done" => break,
            "error" => bail!("dispatcher error: {}",
                             reply.get("message").and_then(|m| m.as_str())
                             .unwrap_or("?")),
            other => bail!("unexpected dispatcher reply {other:?}"),
        }
    }
    progress(format!("done: {computed} cell(s) computed"));
    Ok(WorkerOutcome { computed, welcome })
}
