//! Distributed sweep: the dispatcher and worker halves of the
//! `lrc sweep --serve` / `lrc sweep-worker` pair.
//!
//! The dispatcher owns the canonical cell list and hands cells out over
//! the [`crate::registry::proto`] frame protocol; workers claim a cell,
//! compute it with their own local pool, publish the record back and
//! claim again.  Cells are independent and every cell's math is
//! bit-identical on any machine/thread-count (the crate's determinism
//! contract), so the dispatcher merely *collects* — merging the records
//! in canonical key order afterwards reproduces the single-box report
//! byte for byte.
//!
//! Concurrency model: the dispatcher is a **single-threaded non-blocking
//! poll loop** — no threads, no locks, no wall clock (this module sits
//! outside the `par`/`coordinator` concurrency fences and stays there).
//! Liveness is layered: a worker that dies mid-cell drops its connection
//! and the dispatcher requeues its claimed cells immediately; a worker
//! that *stalls* while its socket stays open is bounded by the claim
//! **lease**, measured in poll-loop iterations (never `Instant`) — a
//! cell held past [`ServeOpts::lease_polls`] without a `heartbeat`
//! requeues for the next claimant, and the eventual late publish is
//! counted as a verified-identical `duplicate`.
//!
//! Failure stance (`lrc-sweep-worker-v2`):
//!
//! * a peer that breaks *framing* or speaks the wrong protocol version
//!   is dropped (its cells requeue); peer malformation is never fatal
//!   for the run;
//! * a record that fails *validation* on publish is fatal for the whole
//!   run — that is a version-skewed or miscomputing worker, and silently
//!   dropping its result would hide it;
//! * a *compute failure* is a first-class `failed` frame (error string
//!   included), not a dead worker: the cell requeues for another
//!   attempt, and a cell failed [`ServeOpts::quarantine_after`] times is
//!   **quarantined** — pulled from the grid and surfaced in the merged
//!   report instead of stalling the fleet forever;
//! * workers reconnect with capped exponential backoff after any
//!   transport fault and re-validate the run identity from the fresh
//!   welcome before mixing results.
//!
//! Deterministic fault injection for all of the above lives in
//! [`super::faults`]; `run_worker` consults an optional
//! [`WorkerShim`] at every frame write, frame read and cell compute.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use super::faults::{ComputeFault, ReadFault, WorkerShim, WriteFault};
use super::proto::{encode_frame, msg, op_of, FrameBuf};
use crate::rng::Rng;
use crate::util::Json;

/// Protocol version, exchanged in hello/welcome; either side refuses a
/// mismatch (a skewed worker must never publish into a newer grid).
/// v2 over v1: hello carries a `worker` name, workers may report a
/// `failed` op, and both ends survive reconnects.
pub const PROTO_VERSION: &str = "lrc-sweep-worker-v2";

/// Dispatcher poll-loop sleep between idle iterations.
const POLL: Duration = Duration::from_millis(2);

/// After the grid completes, the dispatcher keeps the socket open for at
/// least this many poll iterations so a worker racing in right at the
/// end gets a clean `done` answer instead of a reset connection...
const GRACE_ITERS: usize = 250; // ≈0.5 s of 2 ms polls

/// ...and at most this many, so a peer that connects and then stalls
/// can't pin the dispatcher open forever.
const LINGER_ITERS: usize = 1500; // ≈3 s of 2 ms polls

/// How long a worker keeps retrying its *initial* connect (the
/// dispatcher may still be collecting prefill when workers start).
const CONNECT_ATTEMPTS: usize = 100;
const CONNECT_BACKOFF: Duration = Duration::from_millis(100);

/// Reconnect-after-fault backoff: capped exponential, much tighter than
/// the initial connect — the dispatcher was just there.
const RECONNECT_ATTEMPTS: usize = 12;
const RECONNECT_BACKOFF_START: Duration = Duration::from_millis(10);
const RECONNECT_BACKOFF_CAP: Duration = Duration::from_millis(200);

/// A worker gives up after this many consecutive sessions that die
/// before completing the handshake — that is not a transient.
const MAX_BARREN_SESSIONS: usize = 10;

/// `wait` backoff: capped, jittered, exponential — a near-drained grid
/// with many workers must not hammer the dispatcher in lockstep.
const WAIT_BACKOFF_START_MS: u64 = 5;
const WAIT_BACKOFF_CAP_MS: u64 = 200;

/// Dispatcher robustness knobs.  Both are counted in poll-loop
/// iterations / attempts — pure logical time, reproducible anywhere.
#[derive(Clone, Copy, Debug)]
pub struct ServeOpts {
    /// A claim not refreshed by a `heartbeat` within this many poll
    /// iterations requeues for the next claimant (`0` disables leases —
    /// liveness is then the TCP connection alone, as in v1).
    pub lease_polls: usize,
    /// A cell reported `failed` this many times is quarantined: pulled
    /// from the grid and surfaced in the merged report (`0` disables
    /// quarantine — a poison cell then retries forever).
    pub quarantine_after: usize,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts {
            lease_polls: 30_000, // ≈60 s of 2 ms idle polls
            quarantine_after: 3,
        }
    }
}

/// A cell pulled from the grid after repeated compute failures.
#[derive(Clone, Debug)]
pub struct QuarantinedCell {
    /// Lexicographically smallest error string across the failed
    /// attempts — deterministic even when attempts interleave
    /// differently across runs.
    pub error: String,
    /// Failed attempts recorded when quarantine tripped.
    pub attempts: usize,
    /// Names of the workers that reported failures (operator log
    /// material; interleaving-dependent, so reports must not embed it).
    pub workers: BTreeSet<String>,
}

/// What one `serve_grid` run collected.
pub struct ServeOutcome {
    /// every completed cell's record, keyed by cell id (prefilled +
    /// published; quarantined cells are *absent* here)
    pub records: BTreeMap<String, Json>,
    /// cells computed by workers this run (not prefilled)
    pub computed: usize,
    /// distinct worker connections accepted (reconnects count again)
    pub workers_seen: usize,
    /// duplicate publishes absorbed from requeue races, each verified
    /// byte-identical to the first record
    pub duplicates: usize,
    /// cells requeued (worker lost, lease expired, or compute failed)
    pub requeues: usize,
    /// cells pulled from the grid after repeated compute failures
    pub quarantined: BTreeMap<String, QuarantinedCell>,
}

struct Conn {
    stream: TcpStream,
    fb: FrameBuf,
    greeted: bool,
    claimed: BTreeSet<String>,
    alive: bool,
    /// stable connection id — claim ownership survives `conns` reindexing
    seq: u64,
    /// worker-reported name from `hello` (operator log)
    name: String,
}

/// One cell claim: who holds it and for how many poll iterations.
struct Claim {
    owner: u64,
    age: usize,
}

/// Write raw bytes to a non-blocking socket, absorbing `WouldBlock` with
/// short sleeps — frames are tiny, so this converges immediately in
/// practice and bounds nothing but a pathological peer.
fn write_all_nb(stream: &mut TcpStream, bytes: &[u8]) -> std::io::Result<()> {
    let mut off = 0;
    while off < bytes.len() {
        match stream.write(&bytes[off..]) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero, "peer stopped reading"));
            }
            Ok(n) => off += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(POLL);
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

fn write_frame_nb(stream: &mut TcpStream, m: &Json) -> std::io::Result<()> {
    write_all_nb(stream, &encode_frame(m))
}

/// Serve one grid over `listener` until every cell in `cells` has a
/// record or sits in quarantine.  `welcome` is the run-identity document
/// sent to each worker (run tag, model, seed, iters — everything a
/// worker needs to rebuild the identical inputs); `prefilled` seeds
/// already-known records (registry hits), which are never handed out.
/// `on_publish` runs for every worker-published record (validation +
/// registry write; an error is fatal for the run).  `progress` receives
/// one line per notable event for the operator log.
pub fn serve_grid(listener: &TcpListener, welcome: &Json, cells: &[String],
                  prefilled: &BTreeMap<String, Json>, opts: ServeOpts,
                  mut on_publish: impl FnMut(&str, &Json) -> Result<()>,
                  mut progress: impl FnMut(String)) -> Result<ServeOutcome> {
    listener.set_nonblocking(true)
        .context("set dispatcher listener non-blocking")?;
    let cell_set: BTreeSet<&str> = cells.iter().map(|s| s.as_str()).collect();
    let mut done: BTreeMap<String, Json> = BTreeMap::new();
    let mut pending: VecDeque<String> = VecDeque::new();
    for c in cells {
        match prefilled.get(c) {
            Some(rec) => {
                done.insert(c.clone(), rec.clone());
            }
            None => pending.push_back(c.clone()),
        }
    }
    let mut welcome_msg = welcome.clone();
    if let Json::Obj(m) = &mut welcome_msg {
        m.insert("op".into(), Json::str("welcome"));
        m.insert("proto".into(), Json::str(PROTO_VERSION));
    } else {
        bail!("serve_grid welcome must be a JSON object");
    }

    let mut conns: Vec<Conn> = Vec::new();
    let mut claims: BTreeMap<String, Claim> = BTreeMap::new();
    let mut failures: BTreeMap<String, QuarantinedCell> = BTreeMap::new();
    let mut quarantined: BTreeMap<String, QuarantinedCell> = BTreeMap::new();
    let mut computed = 0usize;
    let mut workers_seen = 0usize;
    let mut duplicates = 0usize;
    let mut requeues = 0usize;
    let mut next_seq = 0u64;
    let mut linger = 0usize;
    loop {
        let mut activity = false;

        // accept every waiting worker
        loop {
            match listener.accept() {
                Ok((stream, peer)) => {
                    stream.set_nonblocking(true)?;
                    let _ = stream.set_nodelay(true);
                    workers_seen += 1;
                    next_seq += 1;
                    progress(format!("worker connected from {peer}"));
                    conns.push(Conn {
                        stream,
                        fb: FrameBuf::new(),
                        greeted: false,
                        claimed: BTreeSet::new(),
                        alive: true,
                        seq: next_seq,
                        name: format!("conn#{next_seq}"),
                    });
                    activity = true;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => return Err(e).context("dispatcher accept"),
            }
        }

        // pump every connection
        for conn in conns.iter_mut() {
            let mut buf = [0u8; 4096];
            loop {
                match conn.stream.read(&mut buf) {
                    Ok(0) => {
                        conn.alive = false;
                        break;
                    }
                    Ok(n) => {
                        conn.fb.extend(&buf[..n]);
                        activity = true;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        break;
                    }
                    Err(e) if e.kind()
                        == std::io::ErrorKind::Interrupted => {}
                    Err(_) => {
                        conn.alive = false;
                        break;
                    }
                }
            }
            while conn.alive {
                let m = match conn.fb.next() {
                    Ok(Some(m)) => m,
                    Ok(None) => break,
                    Err(e) => {
                        progress(format!("dropping worker {} (bad frame: \
                                          {e})", conn.name));
                        conn.alive = false;
                        break;
                    }
                };
                activity = true;
                let grid_done =
                    done.len() + quarantined.len() == cells.len();
                // a peer whose message has no `op` falls into the
                // unknown-op arm and is dropped — peer malformation is
                // never fatal for the run
                let reply = match op_of(&m).unwrap_or("<missing>") {
                    "hello" => {
                        let theirs = m.get("proto").and_then(|p| p.as_str())
                            .unwrap_or("?");
                        if theirs != PROTO_VERSION {
                            progress(format!(
                                "dropping worker (protocol {theirs:?}, \
                                 want {PROTO_VERSION:?})"));
                            let _ = write_frame_nb(
                                &mut conn.stream,
                                &Json::obj(vec![
                                    ("op", Json::str("error")),
                                    ("message", Json::str(format!(
                                        "protocol mismatch: dispatcher \
                                         speaks {PROTO_VERSION}"))),
                                ]));
                            conn.alive = false;
                            continue;
                        }
                        if let Some(n) = m.get("worker")
                            .and_then(|w| w.as_str()) {
                            conn.name = n.to_string();
                        }
                        conn.greeted = true;
                        welcome_msg.clone()
                    }
                    "claim" if !conn.greeted => {
                        conn.alive = false;
                        continue; // claim before hello: not our worker
                    }
                    "claim" => match pending.pop_front() {
                        Some(key) => {
                            conn.claimed.insert(key.clone());
                            claims.insert(key.clone(),
                                          Claim { owner: conn.seq, age: 0 });
                            Json::obj(vec![("op", Json::str("cell")),
                                           ("key", Json::str(key))])
                        }
                        None if grid_done => msg("done"),
                        None => msg("wait"),
                    },
                    "heartbeat" => {
                        if let Some(k) = m.get("key").and_then(|k| k.as_str())
                        {
                            if let Some(claim) = claims.get_mut(k) {
                                if claim.owner == conn.seq {
                                    claim.age = 0; // lease refreshed
                                }
                            }
                            progress(format!("worker {} computing {k}",
                                             conn.name));
                        }
                        msg("ok")
                    }
                    "failed" if !conn.greeted => {
                        conn.alive = false;
                        continue;
                    }
                    "failed" => {
                        let Some(key) = m.get("key").and_then(|k| k.as_str())
                            .map(str::to_string)
                        else {
                            progress(format!("dropping worker {} (failed \
                                              without key)", conn.name));
                            conn.alive = false;
                            continue;
                        };
                        if !cell_set.contains(key.as_str()) {
                            bail!("worker {} reported failure for unknown \
                                   cell {key}", conn.name);
                        }
                        let error = m.get("error").and_then(|e| e.as_str())
                            .unwrap_or("worker reported no error detail")
                            .to_string();
                        conn.claimed.remove(&key);
                        if claims.get(&key).map(|c| c.owner)
                            == Some(conn.seq) {
                            claims.remove(&key);
                        }
                        if done.contains_key(&key)
                            || quarantined.contains_key(&key) {
                            // stale failure from a requeue race: the
                            // cell's fate is already decided
                            msg("ok")
                        } else {
                            let info = failures.entry(key.clone())
                                .or_insert_with(|| QuarantinedCell {
                                    error: error.clone(),
                                    attempts: 0,
                                    workers: BTreeSet::new(),
                                });
                            info.attempts += 1;
                            info.workers.insert(conn.name.clone());
                            if error < info.error {
                                // keep the lexicographically smallest
                                // error so the reported string never
                                // depends on attempt interleaving
                                info.error = error.clone();
                            }
                            progress(format!(
                                "cell {key} failed by {} (attempt {}): \
                                 {error}", conn.name, info.attempts));
                            if opts.quarantine_after > 0
                                && info.attempts >= opts.quarantine_after {
                                pending.retain(|p| p != &key);
                                claims.remove(&key);
                                quarantined.insert(key.clone(),
                                                   info.clone());
                                progress(format!(
                                    "quarantining {key} after {} failed \
                                     attempt(s)", info.attempts));
                            } else if !pending.contains(&key)
                                && !claims.contains_key(&key) {
                                requeues += 1;
                                pending.push_back(key.clone());
                            }
                            msg("ok")
                        }
                    }
                    "publish" => {
                        let key = m.get("key").and_then(|k| k.as_str())
                            .map(str::to_string);
                        let (Some(key), Some(rec)) =
                            (key, m.get("record").cloned())
                        else {
                            progress("dropping worker (publish without \
                                      key/record)".to_string());
                            conn.alive = false;
                            continue;
                        };
                        if !cell_set.contains(key.as_str()) {
                            bail!("worker published unknown cell {key}");
                        }
                        conn.claimed.remove(&key);
                        if claims.get(&key).map(|c| c.owner)
                            == Some(conn.seq) {
                            claims.remove(&key);
                        }
                        if let Some(first) = done.get(&key) {
                            // duplicate result (requeue race): the math
                            // is deterministic, so the bytes must be
                            // identical — observe the race explicitly
                            // and hold the worker to the contract
                            duplicates += 1;
                            if rec.to_string() != first.to_string() {
                                bail!("duplicate publish of {key} by {} \
                                       differs from the first record — \
                                       non-deterministic worker",
                                      conn.name);
                            }
                            progress(format!(
                                "duplicate publish of {key} by {} \
                                 (requeue race; bytes verified \
                                 identical)", conn.name));
                            msg("ok")
                        } else {
                            on_publish(&key, &rec).with_context(
                                || format!("publish of cell {key}"))?;
                            pending.retain(|p| p != &key);
                            if quarantined.remove(&key).is_some() {
                                progress(format!(
                                    "cell {key} recovered after \
                                     quarantine"));
                            }
                            done.insert(key.clone(), rec);
                            computed += 1;
                            progress(format!("cell {key} published \
                                              ({}/{})", done.len(),
                                             cells.len()));
                            msg("ok")
                        }
                    }
                    other => {
                        progress(format!(
                            "dropping worker (unknown op {other:?})"));
                        conn.alive = false;
                        continue;
                    }
                };
                if write_frame_nb(&mut conn.stream, &reply).is_err() {
                    conn.alive = false;
                }
            }
        }

        // age every live claim; a cell held past the lease without a
        // heartbeat requeues at the *back* (its slow holder may yet
        // publish — that publish will be counted as a duplicate)
        if opts.lease_polls > 0 {
            let mut expired: Vec<String> = Vec::new();
            for (key, claim) in claims.iter_mut() {
                claim.age += 1;
                if claim.age > opts.lease_polls {
                    expired.push(key.clone());
                }
            }
            for key in expired {
                let claim = claims.remove(&key)
                    .expect("expired claim must still be present");
                for conn in conns.iter_mut() {
                    if conn.seq == claim.owner {
                        conn.claimed.remove(&key);
                    }
                }
                if !done.contains_key(&key)
                    && !quarantined.contains_key(&key)
                    && !pending.contains(&key) {
                    progress(format!(
                        "requeueing {key} (lease expired after {} \
                         polls)", opts.lease_polls));
                    requeues += 1;
                    pending.push_back(key);
                }
            }
        }

        // reap dead connections; their claimed-but-unpublished cells go
        // back to the front of the queue for the next claimant
        for conn in conns.iter_mut().filter(|c| !c.alive) {
            claims.retain(|_, c| c.owner != conn.seq);
            for key in std::mem::take(&mut conn.claimed) {
                if !done.contains_key(&key)
                    && !quarantined.contains_key(&key)
                    && !pending.contains(&key)
                    && !claims.contains_key(&key) {
                    progress(format!("requeueing {key} (worker {} lost)",
                                     conn.name));
                    requeues += 1;
                    pending.push_front(key);
                }
            }
        }
        conns.retain(|c| c.alive);

        if done.len() + quarantined.len() == cells.len() {
            // grid complete: hold the socket through a short grace
            // window (answering straggler claims with `done`), then
            // exit once every connection has drained; the hard linger
            // cap bounds a stalled peer
            if (conns.is_empty() && linger >= GRACE_ITERS)
                || linger >= LINGER_ITERS
            {
                break;
            }
            linger += 1;
        }
        if !activity {
            std::thread::sleep(POLL);
        }
    }
    if !quarantined.is_empty() {
        progress(format!("{} cell(s) quarantined: {}", quarantined.len(),
                         quarantined.keys().cloned()
                         .collect::<Vec<_>>().join(", ")));
    }
    Ok(ServeOutcome {
        records: done,
        computed,
        workers_seen,
        duplicates,
        requeues,
        quarantined,
    })
}

/// What one worker process accomplished.
pub struct WorkerOutcome {
    /// cells this worker computed and published
    pub computed: usize,
    /// cells whose compute failed (reported via `failed`, worker lived)
    pub failed: usize,
    /// sessions re-established after a transport fault
    pub reconnects: usize,
    /// the dispatcher's welcome document (run identity)
    pub welcome: Json,
}

/// One worker-side I/O step either produced a value or lost the
/// session — the caller reconnects and resumes; only protocol-level
/// breakage (dispatcher framing, rejected frames) is fatal.
enum IoStep<T> {
    Done(T),
    Dropped,
}

/// Send one frame through the (optional) fault schedule.
fn shim_write(stream: &mut TcpStream, shim: &mut Option<&mut WorkerShim>,
              m: &Json) -> IoStep<()> {
    let fault = match shim.as_deref_mut() {
        Some(s) => s.on_write(),
        None => WriteFault::None,
    };
    match fault {
        WriteFault::None => match write_frame_nb(stream, m) {
            Ok(()) => IoStep::Done(()),
            Err(_) => IoStep::Dropped,
        },
        WriteFault::Reset => IoStep::Dropped,
        WriteFault::Truncate(keep) => {
            let bytes = encode_frame(m);
            let keep = keep.min(bytes.len().saturating_sub(1));
            let _ = write_all_nb(stream, &bytes[..keep]);
            IoStep::Dropped
        }
        WriteFault::Split(ms) => {
            let bytes = encode_frame(m);
            let half = bytes.len() / 2;
            if write_all_nb(stream, &bytes[..half]).is_err() {
                return IoStep::Dropped;
            }
            std::thread::sleep(Duration::from_millis(ms));
            match write_all_nb(stream, &bytes[half..]) {
                Ok(()) => IoStep::Done(()),
                Err(_) => IoStep::Dropped,
            }
        }
    }
}

/// Read one frame from a blocking socket through the (optional) fault
/// schedule.  Transport loss is `Dropped` (reconnectable); broken
/// *framing* from the dispatcher is fatal — the stream cannot be
/// resynchronized and the dispatcher is the trusted end.
fn shim_read(stream: &mut TcpStream, fb: &mut FrameBuf,
             shim: &mut Option<&mut WorkerShim>) -> Result<IoStep<Json>> {
    if let Some(s) = shim.as_deref_mut() {
        if s.on_read() == ReadFault::Reset {
            return Ok(IoStep::Dropped);
        }
    }
    loop {
        match fb.next() {
            Ok(Some(m)) => return Ok(IoStep::Done(m)),
            Ok(None) => {}
            Err(e) => {
                return Err(e).context("dispatcher framing broken");
            }
        }
        let mut buf = [0u8; 4096];
        match stream.read(&mut buf) {
            Ok(0) => return Ok(IoStep::Dropped),
            Ok(n) => fb.extend(&buf[..n]),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return Ok(IoStep::Dropped),
        }
    }
}

/// Generous linear retry for the first connect — the dispatcher may
/// still be prefilling its grid when workers start.
fn connect_initial(addr: &str) -> Result<TcpStream> {
    for attempt in 0..CONNECT_ATTEMPTS {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) if attempt + 1 == CONNECT_ATTEMPTS => {
                return Err(e).with_context(
                    || format!("connect to dispatcher at {addr} \
                                ({CONNECT_ATTEMPTS} attempts)"));
            }
            Err(_) => std::thread::sleep(CONNECT_BACKOFF),
        }
    }
    unreachable!("connect loop returns on success or final attempt")
}

/// Capped exponential backoff for reconnects after a transport fault.
fn connect_backoff(addr: &str) -> Result<TcpStream> {
    let mut delay = RECONNECT_BACKOFF_START;
    for attempt in 0..RECONNECT_ATTEMPTS {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) if attempt + 1 == RECONNECT_ATTEMPTS => {
                return Err(e).with_context(
                    || format!("reconnect to dispatcher at {addr} \
                                ({RECONNECT_ATTEMPTS} attempts, capped \
                                 exponential backoff)"));
            }
            Err(_) => {
                std::thread::sleep(delay);
                delay = (delay * 2).min(RECONNECT_BACKOFF_CAP);
            }
        }
    }
    unreachable!("reconnect loop returns on success or final attempt")
}

/// The worker loop: connect (with retries — workers usually start while
/// the dispatcher is still prefilling), handshake, then claim → compute
/// → publish/fail until the dispatcher answers `done`.  `compute`
/// receives the welcome document (run identity: model, seed, iters, run
/// tag) and the claimed cell key, and must return the finished cell
/// record; a compute `Err` is reported to the dispatcher as a `failed`
/// frame and the worker lives on.  Any transport fault (including every
/// fault an optional [`WorkerShim`] injects) drops the session and the
/// worker reconnects with capped exponential backoff, re-validating the
/// run identity from the fresh welcome before continuing.
pub fn run_worker(addr: &str, name: &str,
                  mut shim: Option<&mut WorkerShim>,
                  mut compute: impl FnMut(&Json, &str) -> Result<Json>,
                  mut progress: impl FnMut(String)) -> Result<WorkerOutcome> {
    let mut computed = 0usize;
    let mut failed = 0usize;
    let mut reconnects = 0usize;
    let mut sessions = 0usize;
    let mut barren = 0usize;
    // the run identity from the first welcome, canonical bytes — every
    // later session must present the identical document
    let mut first_welcome: Option<String> = None;
    let mut welcome_doc: Option<Json> = None;
    // per-worker jitter stream (seeded from the name, so a fleet's
    // backoffs decorrelate deterministically)
    let mut jitter = Rng::new(name.bytes().fold(
        0xC0FF_EE00_u64, |h, b| h.wrapping_mul(31).wrapping_add(b as u64)));
    let mut wait_ms = WAIT_BACKOFF_START_MS;

    'session: loop {
        sessions += 1;
        barren += 1;
        if barren > MAX_BARREN_SESSIONS {
            bail!("giving up on {addr}: {MAX_BARREN_SESSIONS} consecutive \
                   sessions died before completing the handshake");
        }
        let mut stream = if sessions == 1 {
            connect_initial(addr)?
        } else {
            reconnects += 1;
            connect_backoff(addr)?
        };
        let _ = stream.set_nodelay(true);
        let mut fb = FrameBuf::new();

        match shim_write(&mut stream, &mut shim, &Json::obj(vec![
            ("op", Json::str("hello")),
            ("proto", Json::str(PROTO_VERSION)),
            ("worker", Json::str(name)),
        ])) {
            IoStep::Done(()) => {}
            IoStep::Dropped => continue 'session,
        }
        let welcome = match shim_read(&mut stream, &mut fb, &mut shim)? {
            IoStep::Done(m) => m,
            IoStep::Dropped => continue 'session,
        };
        match op_of(&welcome)? {
            "welcome" => {}
            "error" => bail!("dispatcher refused: {}",
                             welcome.get("message").and_then(|m| m.as_str())
                             .unwrap_or("?")),
            other => bail!("expected welcome, got {other:?}"),
        }
        let canon = welcome.to_string();
        match &first_welcome {
            None => {
                progress(format!(
                    "connected to {addr}: run {}",
                    welcome.get("run").and_then(|r| r.as_str())
                        .unwrap_or("?")));
                first_welcome = Some(canon);
                welcome_doc = Some(welcome);
            }
            Some(prev) if *prev == canon => {
                progress(format!(
                    "reconnected to {addr} (session {sessions})"));
            }
            Some(_) => bail!("run identity changed across reconnect to \
                              {addr} — refusing to mix results between \
                              different runs"),
        }
        barren = 0;
        // SAFETY of unwrap: `welcome_doc` was set on the first
        // successful handshake, and we only get here through one
        let identity = welcome_doc.clone().unwrap();

        loop {
            match shim_write(&mut stream, &mut shim, &msg("claim")) {
                IoStep::Done(()) => {}
                IoStep::Dropped => continue 'session,
            }
            let reply = match shim_read(&mut stream, &mut fb, &mut shim)? {
                IoStep::Done(m) => m,
                IoStep::Dropped => continue 'session,
            };
            match op_of(&reply)? {
                "cell" => {
                    wait_ms = WAIT_BACKOFF_START_MS; // grid is active
                    let key = reply.get("key").and_then(|k| k.as_str())
                        .ok_or_else(|| anyhow!("cell reply missing key"))?
                        .to_string();
                    progress(format!("claimed {key}"));
                    // progress marker (and lease refresh) before the
                    // (long) compute
                    match shim_write(&mut stream, &mut shim,
                                     &Json::obj(vec![
                        ("op", Json::str("heartbeat")),
                        ("key", Json::str(key.clone())),
                    ])) {
                        IoStep::Done(()) => {}
                        IoStep::Dropped => continue 'session,
                    }
                    let ack =
                        match shim_read(&mut stream, &mut fb, &mut shim)? {
                            IoStep::Done(m) => m,
                            IoStep::Dropped => continue 'session,
                        };
                    if op_of(&ack)? != "ok" {
                        bail!("heartbeat not acknowledged: {}",
                              ack.to_string());
                    }
                    let result = match shim.as_deref_mut()
                        .map(|s| s.on_compute(&key))
                        .unwrap_or(ComputeFault::None)
                    {
                        ComputeFault::Crash => {
                            progress(format!(
                                "injected crash mid-compute on {key}"));
                            continue 'session;
                        }
                        ComputeFault::Fail(e) => Err(anyhow!(e)),
                        ComputeFault::Stall(ms) => {
                            std::thread::sleep(Duration::from_millis(ms));
                            compute(&identity, &key)
                        }
                        ComputeFault::None => compute(&identity, &key),
                    };
                    match result {
                        Ok(record) => {
                            match shim_write(&mut stream, &mut shim,
                                             &Json::obj(vec![
                                ("op", Json::str("publish")),
                                ("key", Json::str(key.clone())),
                                ("record", record),
                            ])) {
                                IoStep::Done(()) => {}
                                IoStep::Dropped => continue 'session,
                            }
                            let ack = match shim_read(&mut stream, &mut fb,
                                                      &mut shim)? {
                                IoStep::Done(m) => m,
                                IoStep::Dropped => continue 'session,
                            };
                            if op_of(&ack)? != "ok" {
                                bail!("publish of {key} rejected: {}",
                                      ack.to_string());
                            }
                            computed += 1;
                        }
                        Err(e) => {
                            failed += 1;
                            progress(format!("cell {key} failed: {e:#}"));
                            match shim_write(&mut stream, &mut shim,
                                             &Json::obj(vec![
                                ("op", Json::str("failed")),
                                ("key", Json::str(key.clone())),
                                ("error", Json::str(format!("{e:#}"))),
                            ])) {
                                IoStep::Done(()) => {}
                                IoStep::Dropped => continue 'session,
                            }
                            let ack = match shim_read(&mut stream, &mut fb,
                                                      &mut shim)? {
                                IoStep::Done(m) => m,
                                IoStep::Dropped => continue 'session,
                            };
                            if op_of(&ack)? != "ok" {
                                bail!("failure report for {key} rejected: \
                                       {}", ack.to_string());
                            }
                        }
                    }
                }
                "wait" => {
                    // capped jittered exponential backoff: a fleet
                    // polling a near-drained grid spreads out instead
                    // of hammering the dispatcher in lockstep
                    let ms = ((wait_ms as f64)
                              * (0.5 + jitter.uniform())) as u64;
                    std::thread::sleep(Duration::from_millis(ms.max(1)));
                    wait_ms = (wait_ms * 2).min(WAIT_BACKOFF_CAP_MS);
                }
                "done" => break 'session,
                "error" => bail!("dispatcher error: {}",
                                 reply.get("message")
                                 .and_then(|m| m.as_str()).unwrap_or("?")),
                other => bail!("unexpected dispatcher reply {other:?}"),
            }
        }
    }
    progress(format!("done: {computed} computed, {failed} failed, \
                      {reconnects} reconnect(s)"));
    // SAFETY of expect: `done` is only reachable after a handshake
    let welcome = welcome_doc.expect("done implies a completed handshake");
    Ok(WorkerOutcome { computed, failed, reconnects, welcome })
}
