//! Content-addressed artifact registry (spec: `docs/REGISTRY.md`).
//!
//! Every expensive artifact the pipeline produces — quant bundles, sweep
//! cell records — is stored under a **content digest of its inputs**:
//! `sha256` over the canonical JSON of `(kind, model-id, method,
//! QuantConfig, seed, calib-identity, code-version)`.  Identical inputs
//! → identical digest → the work is never done twice, on this machine or
//! any machine sharing the store; any input changing (including
//! [`CODE_VERSION`] when the math changes) changes the digest, so stale
//! results can never be served.
//!
//! * [`ObjectKey`] / [`ObjectKey::digest`] — the digest recipe.
//! * [`RegistryBackend`] — pluggable raw byte store (get/put by digest).
//!   [`FsRegistry`] is the local-FS backend: `<root>/objects/<digest>.json`
//!   (+ optional `.bin` blob), published atomically via temp-file +
//!   rename so readers never observe a half-written object.
//! * [`Registry`] — the verified façade: wraps a backend, seals every
//!   object with integrity checksums on publish and re-verifies them on
//!   read (a corrupt or truncated object is a **miss**, never an error,
//!   and never trusted), and counts hits / misses / corruptions.
//! * [`proto`] / [`service`] — the length-prefixed line protocol and the
//!   dispatcher/worker loops that shard a sweep grid across processes
//!   (`lrc sweep --serve` / `lrc sweep-worker`).
//! * [`faults`] — seeded, serializable fault injection (connection
//!   resets, truncated/split frames, compute failures, torn writes) for
//!   the `lrc chaos` harness; [`list_objects`] backs `lrc registry ls`.
//!
//! Layering: the registry sits **above** the compute stack — `pipeline`
//! and `sweep` may consult it, but nothing in `linalg`/`quant`/`lrc`
//! depends on it (enforced by `lrc analyze`'s layering map), so the math
//! stays desk-verifiable without any storage concerns.

pub mod digest;
pub mod faults;
pub mod proto;
pub mod service;

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, bail, Context, Result};

use crate::quant::QuantConfig;
use crate::runtime::TensorBundle;
use crate::util::Json;

pub use digest::sha256_hex;

/// Object schema tag: bump together with any incompatible change to the
/// meta layout below.
pub const SCHEMA: &str = "lrc-registry-v1";

/// Identity of the quantization *code*.  Part of every digest: bump it
/// whenever a change alters what the solvers/packers compute for the
/// same inputs, and every previously published artifact silently becomes
/// a miss instead of a wrong hit.
pub const CODE_VERSION: &str = "lrc-quant-v1";

/// Canonical JSON for a [`QuantConfig`] — the digest's config component.
/// BTreeMap-backed [`Json`] keeps key order (and therefore the digest)
/// stable regardless of construction order.
pub fn quant_config_json(cfg: &QuantConfig) -> Json {
    Json::obj(vec![
        ("w_bits", Json::num(cfg.w_bits as f64)),
        ("a_bits", match cfg.a_bits {
            None => Json::Null,
            Some(b) => Json::num(b as f64),
        }),
        ("a_group", match cfg.a_group {
            None => Json::Null,
            Some(g) => Json::num(g as f64),
        }),
        ("quantizer", Json::str(cfg.quantizer.name())),
        ("rank_pct", Json::num(cfg.rank_pct)),
        ("iters", Json::num(cfg.iters as f64)),
    ])
}

/// The full identity of one registry object — everything that determines
/// the bytes of the artifact.  Two runs producing the same key *must*
/// produce bit-identical artifacts (the crate's determinism contract),
/// which is what makes sharing a registry between machines sound.
#[derive(Clone, Debug, PartialEq)]
pub struct ObjectKey {
    /// artifact kind: `"quant-bundle"` or `"sweep-cell"`
    pub kind: String,
    /// model identity (artifact dir name, or `"synthetic"`)
    pub model: String,
    /// method / sweep-method name (`"lrc"`, `"rtn"`, ...)
    pub method: String,
    /// the cell's full [`QuantConfig`] (canonical JSON)
    pub config: Json,
    /// RNG seed of the run (synthetic model seed or calibration seed)
    pub seed: u64,
    /// calibration identity: corpus + sequence count (or the sweep run
    /// tag, which encodes the same)
    pub calib: String,
    /// [`CODE_VERSION`] at publish time
    pub code: String,
}

impl ObjectKey {
    pub fn new(kind: &str, model: &str, method: &str, cfg: &QuantConfig,
               seed: u64, calib: &str) -> ObjectKey {
        ObjectKey {
            kind: kind.to_string(),
            model: model.to_string(),
            method: method.to_string(),
            config: quant_config_json(cfg),
            seed,
            calib: calib.to_string(),
            code: CODE_VERSION.to_string(),
        }
    }

    /// The canonical key material the digest is computed over.
    pub fn material(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str(self.kind.clone())),
            ("model", Json::str(self.model.clone())),
            ("method", Json::str(self.method.clone())),
            ("config", self.config.clone()),
            ("seed", Json::num(self.seed as f64)),
            ("calib", Json::str(self.calib.clone())),
            ("code", Json::str(self.code.clone())),
        ])
    }

    /// `sha256(material)` — the object's address.
    pub fn digest(&self) -> String {
        sha256_hex(self.material().to_string().as_bytes())
    }
}

/// A verified object read back from the registry.
pub struct RegistryObject {
    /// the full meta document (schema, key material, payload, checksums)
    pub meta: Json,
    /// the optional binary blob (quant bundles store tensor data here)
    pub blob: Option<Vec<u8>>,
}

impl RegistryObject {
    /// The publisher's payload document.
    pub fn payload(&self) -> Result<&Json> {
        self.meta.get("payload")
            .ok_or_else(|| anyhow!("registry object missing payload"))
    }
}

/// A raw byte store addressed by digest.  Implementations only move
/// bytes; all integrity verification lives in [`Registry`], so a remote
/// backend written against `docs/REGISTRY.md` gets the same corruption
/// handling for free.
pub trait RegistryBackend: Send + Sync {
    /// Fetch `(meta bytes, optional blob bytes)`, `None` when absent.
    fn get_raw(&self, digest: &str)
               -> Result<Option<(Vec<u8>, Option<Vec<u8>>)>>;
    /// Publish atomically: a concurrent `get_raw` sees either nothing or
    /// the complete object, never a torn write.
    fn put_raw(&self, digest: &str, meta: &[u8], blob: Option<&[u8]>)
               -> Result<()>;
    /// Human-readable location (log lines).
    fn describe(&self) -> String;
}

/// Local-FS backend: `<root>/objects/<digest>.json` (+ `.bin`), with
/// publishes staged under `<root>/tmp/` and `rename(2)`d into place —
/// rename within one filesystem is atomic, so a reader races only
/// against complete objects.
pub struct FsRegistry {
    root: PathBuf,
}

/// Process-wide staging counter so concurrent publishes (pool workers,
/// several processes sharing a store) never collide on a temp name.
static STAGE_COUNTER: AtomicU64 = AtomicU64::new(0);

impl FsRegistry {
    pub fn new(root: &Path) -> FsRegistry {
        FsRegistry { root: root.to_path_buf() }
    }

    /// Where an object's meta document lives (tests poke corruption in).
    pub fn object_file(&self, digest: &str) -> PathBuf {
        self.root.join("objects").join(format!("{digest}.json"))
    }

    /// Where an object's blob lives.
    pub fn blob_file(&self, digest: &str) -> PathBuf {
        self.root.join("objects").join(format!("{digest}.bin"))
    }

    fn stage(&self, bytes: &[u8], dest: &Path) -> Result<()> {
        let tmp_dir = self.root.join("tmp");
        std::fs::create_dir_all(&tmp_dir)?;
        let tag = STAGE_COUNTER.fetch_add(1, Ordering::Relaxed);
        let tmp = tmp_dir.join(format!(
            "stage-{}-{}-{}", std::process::id(), tag,
            dest.file_name().and_then(|n| n.to_str()).unwrap_or("obj")));
        std::fs::write(&tmp, bytes)
            .with_context(|| format!("stage {tmp:?}"))?;
        std::fs::rename(&tmp, dest)
            .with_context(|| format!("publish {dest:?}"))?;
        Ok(())
    }
}

impl RegistryBackend for FsRegistry {
    fn get_raw(&self, digest: &str)
               -> Result<Option<(Vec<u8>, Option<Vec<u8>>)>> {
        let meta = match std::fs::read(self.object_file(digest)) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(None);
            }
            Err(e) => return Err(e).context("read registry object"),
        };
        let blob = match std::fs::read(self.blob_file(digest)) {
            Ok(b) => Some(b),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => None,
            Err(e) => return Err(e).context("read registry blob"),
        };
        Ok(Some((meta, blob)))
    }

    fn put_raw(&self, digest: &str, meta: &[u8], blob: Option<&[u8]>)
               -> Result<()> {
        std::fs::create_dir_all(self.root.join("objects"))?;
        // blob first: the meta document is the commit point — a reader
        // that sees meta always finds the blob it references
        if let Some(b) = blob {
            self.stage(b, &self.blob_file(digest))?;
        }
        self.stage(meta, &self.object_file(digest))
    }

    fn describe(&self) -> String {
        format!("fs:{}", self.root.display())
    }
}

/// Hit/miss/corruption counters for one registry handle (operator
/// feedback + the "warm re-run did zero compute" acceptance test).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RegistryCounters {
    pub hits: u64,
    pub misses: u64,
    pub corrupt: u64,
    pub published: u64,
}

/// The verified registry façade over a [`RegistryBackend`].
///
/// `get` re-derives every checksum before trusting an object: schema and
/// digest must match the request, the payload checksum must match the
/// payload bytes, and a referenced blob must be present with the right
/// length and checksum.  Any mismatch counts as `corrupt` and reads as a
/// miss — the caller recomputes and republishes, it never errors on
/// somebody else's torn write.
pub struct Registry {
    backend: Box<dyn RegistryBackend>,
    hits: AtomicU64,
    misses: AtomicU64,
    corrupt: AtomicU64,
    published: AtomicU64,
}

impl Registry {
    /// Registry over the local-FS backend rooted at `root`.
    pub fn local(root: &Path) -> Registry {
        Registry::with_backend(Box::new(FsRegistry::new(root)))
    }

    pub fn with_backend(backend: Box<dyn RegistryBackend>) -> Registry {
        Registry {
            backend,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            corrupt: AtomicU64::new(0),
            published: AtomicU64::new(0),
        }
    }

    pub fn describe(&self) -> String {
        self.backend.describe()
    }

    pub fn counters(&self) -> RegistryCounters {
        RegistryCounters {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            corrupt: self.corrupt.load(Ordering::Relaxed),
            published: self.published.load(Ordering::Relaxed),
        }
    }

    /// Verified lookup.  `Ok(None)` covers absent, stale-schema and
    /// corrupt objects alike — all of them mean "compute it".
    pub fn get(&self, key: &ObjectKey) -> Result<Option<RegistryObject>> {
        let digest = key.digest();
        let Some((meta_bytes, blob)) = self.backend.get_raw(&digest)? else {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return Ok(None);
        };
        match verify_object(&digest, &meta_bytes, blob) {
            Some(obj) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Ok(Some(obj))
            }
            None => {
                self.corrupt.fetch_add(1, Ordering::Relaxed);
                self.misses.fetch_add(1, Ordering::Relaxed);
                Ok(None)
            }
        }
    }

    /// Seal and publish `payload` (+ optional blob) under `key`.
    /// Publishing the same key twice is fine — deterministic compute
    /// makes the bytes identical, so the second publish is a no-op
    /// overwrite.  Returns the object digest.
    pub fn publish(&self, key: &ObjectKey, payload: &Json,
                   blob: Option<&[u8]>) -> Result<String> {
        let digest = key.digest();
        let mut pairs = vec![
            ("schema", Json::str(SCHEMA)),
            ("digest", Json::str(digest.clone())),
            ("key", key.material()),
            ("payload", payload.clone()),
            ("check", Json::str(sha256_hex(payload.to_string().as_bytes()))),
        ];
        if let Some(b) = blob {
            pairs.push(("blob_len", Json::num(b.len() as f64)));
            pairs.push(("blob_sha256", Json::str(sha256_hex(b))));
        }
        let meta = Json::obj(pairs).to_string();
        self.backend.put_raw(&digest, meta.as_bytes(), blob)?;
        self.published.fetch_add(1, Ordering::Relaxed);
        Ok(digest)
    }
}

/// Full integrity verification of a raw object; `None` = treat as miss.
fn verify_object(digest: &str, meta_bytes: &[u8], blob: Option<Vec<u8>>)
                 -> Option<RegistryObject> {
    let text = std::str::from_utf8(meta_bytes).ok()?;
    let meta = Json::parse(text).ok()?;
    if meta.get("schema").and_then(|s| s.as_str()) != Some(SCHEMA) {
        return None;
    }
    if meta.get("digest").and_then(|d| d.as_str()) != Some(digest) {
        return None;
    }
    let payload = meta.get("payload")?;
    let check = meta.get("check").and_then(|c| c.as_str())?;
    if sha256_hex(payload.to_string().as_bytes()) != check {
        return None;
    }
    let blob = match meta.get("blob_sha256").and_then(|s| s.as_str()) {
        None => None,
        Some(want) => {
            let b = blob?;
            let len = meta.get("blob_len").and_then(|l| l.as_usize())?;
            if b.len() != len || sha256_hex(&b) != want {
                return None;
            }
            Some(b)
        }
    };
    Some(RegistryObject { meta, blob })
}

// ---------------------------------------------------------------------------
// store introspection (`lrc registry ls`)
// ---------------------------------------------------------------------------

/// One object row for `lrc registry ls`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LsRow {
    /// Object digest (the filename stem under `objects/`).
    pub digest: String,
    /// Key fields from the meta document (`"?"` when unreadable).
    pub kind: String,
    pub model: String,
    pub method: String,
    /// `"ok"` (verifies), `"corrupt"` (fails verification — reads as a
    /// miss) or `"orphan-blob"` (a `.bin` with no meta document: a torn
    /// write's leftover, invisible to readers).
    pub status: &'static str,
    /// Blob byte length when one exists.
    pub blob_len: Option<usize>,
}

/// Walk a local-FS store and classify every object, in digest order —
/// the operator's view of a fleet's shared registry.  Each meta document
/// runs the full read-side verification, so the `status` column reports
/// exactly what a reader would experience.  A missing store is an empty
/// listing, not an error.
pub fn list_objects(root: &Path) -> Result<Vec<LsRow>> {
    let fs = FsRegistry::new(root);
    let mut metas: Vec<String> = Vec::new();
    let mut blobs: BTreeSet<String> = BTreeSet::new();
    let dir = match std::fs::read_dir(root.join("objects")) {
        Ok(dir) => dir,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(Vec::new());
        }
        Err(e) => return Err(e).context("list registry objects"),
    };
    for entry in dir {
        let name = entry?.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some(stem) = name.strip_suffix(".json") {
            metas.push(stem.to_string());
        } else if let Some(stem) = name.strip_suffix(".bin") {
            blobs.insert(stem.to_string());
        }
    }
    metas.sort();
    let mut rows = Vec::new();
    for digest in &metas {
        // tolerate a concurrent writer deleting between listing and read
        let meta_bytes = match std::fs::read(fs.object_file(digest)) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => continue,
            Err(e) => return Err(e).context("read registry object"),
        };
        let blob = std::fs::read(fs.blob_file(digest)).ok();
        let blob_len = blob.as_ref().map(|b| b.len());
        let field = |meta: &Json, name: &str| -> String {
            meta.get("key").and_then(|k| k.get(name))
                .and_then(|v| v.as_str()).unwrap_or("?").to_string()
        };
        let row = match verify_object(digest, &meta_bytes, blob) {
            Some(obj) => LsRow {
                digest: digest.clone(),
                kind: field(&obj.meta, "kind"),
                model: field(&obj.meta, "model"),
                method: field(&obj.meta, "method"),
                status: "ok",
                blob_len,
            },
            None => {
                // best-effort key fields off the (possibly torn) meta
                let meta = std::str::from_utf8(&meta_bytes).ok()
                    .and_then(|t| Json::parse(t).ok())
                    .unwrap_or(Json::Null);
                LsRow {
                    digest: digest.clone(),
                    kind: field(&meta, "kind"),
                    model: field(&meta, "model"),
                    method: field(&meta, "method"),
                    status: "corrupt",
                    blob_len,
                }
            }
        };
        rows.push(row);
    }
    for digest in &blobs {
        if metas.binary_search(digest).is_err() {
            let blob_len = std::fs::metadata(fs.blob_file(digest))
                .map(|m| m.len() as usize).ok();
            rows.push(LsRow {
                digest: digest.clone(),
                kind: "?".to_string(),
                model: "?".to_string(),
                method: "?".to_string(),
                status: "orphan-blob",
                blob_len,
            });
        }
    }
    Ok(rows)
}

// ---------------------------------------------------------------------------
// tensor-bundle <-> registry blob
// ---------------------------------------------------------------------------

/// Serialize a [`TensorBundle`] for registry storage: the tensor table
/// (name/shape/offset, manifest order) goes into the object payload, the
/// flat little-endian f32 stream into the blob — the same layout
/// `TensorBundle::write` puts on disk, so the roundtrip is bit-exact.
pub fn bundle_to_blob(bundle: &TensorBundle) -> (Json, Vec<u8>) {
    let mut bin: Vec<u8> = Vec::new();
    let mut table = Vec::new();
    let mut offset = 0usize;
    for name in &bundle.order {
        let t = &bundle.tensors[name];
        for v in &t.data {
            bin.extend_from_slice(&v.to_le_bytes());
        }
        table.push(Json::obj(vec![
            ("name", Json::str(name.clone())),
            ("shape", Json::Arr(
                t.shape.iter().map(|&s| Json::num(s as f64)).collect())),
            ("offset", Json::num(offset as f64)),
        ]));
        offset += t.numel();
    }
    (Json::Arr(table), bin)
}

/// Rebuild a [`TensorBundle`] from a registry tensor table + blob.
pub fn bundle_from_blob(table: &Json, blob: &[u8]) -> Result<TensorBundle> {
    let mut bundle = TensorBundle::default();
    for t in table.as_arr()
        .ok_or_else(|| anyhow!("registry tensor table is not an array"))? {
        let name = t.get("name").and_then(|n| n.as_str())
            .ok_or_else(|| anyhow!("registry tensor missing name"))?;
        let shape: Vec<usize> = t.get("shape").and_then(|s| s.as_arr())
            .ok_or_else(|| anyhow!("registry tensor {name} missing shape"))?
            .iter().filter_map(|v| v.as_usize()).collect();
        let offset = t.get("offset").and_then(|o| o.as_usize())
            .ok_or_else(|| anyhow!("registry tensor {name} missing offset"))?;
        let numel: usize = shape.iter().product();
        let (start, end) = (offset * 4, (offset + numel) * 4);
        if end > blob.len() {
            bail!("registry tensor {name} out of range ({end} > {} blob \
                   bytes)", blob.len());
        }
        let data: Vec<f32> = blob[start..end]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        bundle.insert(name, shape, data);
    }
    Ok(bundle)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Quantizer;

    fn key(seed: u64) -> ObjectKey {
        ObjectKey::new("sweep-cell", "synthetic", "lrc",
                       &QuantConfig::cell(4, None, Quantizer::Gptq, 0.10, 1),
                       seed, "synthetic-seed2024")
    }

    #[test]
    fn digest_is_stable_and_sensitive_to_every_field() {
        let base = key(7);
        assert_eq!(base.digest(), key(7).digest(),
                   "same key material must digest identically");
        let mut other = key(7);
        other.model = "small".into();
        assert_ne!(base.digest(), other.digest());
        let mut other = key(7);
        other.code = "lrc-quant-v2".into();
        assert_ne!(base.digest(), other.digest(),
                   "a code-version bump must move every digest");
        assert_ne!(base.digest(), key(8).digest());
        let cfg2 = QuantConfig::cell(2, None, Quantizer::Gptq, 0.10, 1);
        let other = ObjectKey::new("sweep-cell", "synthetic", "lrc", &cfg2,
                                   7, "synthetic-seed2024");
        assert_ne!(base.digest(), other.digest());
    }

    #[test]
    fn fs_roundtrip_hit_and_absent_miss() {
        let root = std::env::temp_dir()
            .join(format!("lrc_reg_unit_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let reg = Registry::local(&root);
        let k = key(1);
        assert!(reg.get(&k).unwrap().is_none(), "empty store must miss");
        let payload = Json::obj(vec![("answer", Json::num(42.0))]);
        let digest = reg.publish(&k, &payload, Some(b"blobbytes")).unwrap();
        assert_eq!(digest, k.digest());
        let obj = reg.get(&k).unwrap().expect("hit after publish");
        assert_eq!(obj.payload().unwrap(), &payload);
        assert_eq!(obj.blob.as_deref(), Some(&b"blobbytes"[..]));
        let c = reg.counters();
        assert_eq!((c.hits, c.misses, c.corrupt, c.published), (1, 1, 0, 1));
        // staging area drains: publish leaves nothing behind in tmp/
        let leftovers = std::fs::read_dir(root.join("tmp")).unwrap()
            .flatten().count();
        assert_eq!(leftovers, 0, "atomic publish must not leave temp files");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn corrupt_objects_read_as_misses() {
        let root = std::env::temp_dir()
            .join(format!("lrc_reg_corrupt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let fs = FsRegistry::new(&root);
        let reg = Registry::local(&root);
        let k = key(2);
        let payload = Json::obj(vec![("v", Json::num(1.0))]);
        reg.publish(&k, &payload, Some(b"blob")).unwrap();

        // torn meta
        std::fs::write(fs.object_file(&k.digest()), "{not json").unwrap();
        assert!(reg.get(&k).unwrap().is_none());
        assert_eq!(reg.counters().corrupt, 1);

        // valid JSON, wrong payload checksum
        reg.publish(&k, &payload, Some(b"blob")).unwrap();
        let text = std::fs::read_to_string(fs.object_file(&k.digest()))
            .unwrap();
        std::fs::write(fs.object_file(&k.digest()),
                       text.replace("\"v\":1", "\"v\":2")).unwrap();
        assert!(reg.get(&k).unwrap().is_none(),
                "a tampered payload must fail its checksum");

        // blob truncation
        reg.publish(&k, &payload, Some(b"blob")).unwrap();
        std::fs::write(fs.blob_file(&k.digest()), b"blo").unwrap();
        assert!(reg.get(&k).unwrap().is_none(),
                "a truncated blob must read as a miss");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn bundle_blob_roundtrip_is_bit_exact() {
        let mut b = TensorBundle::default();
        b.insert("blk0.wq", vec![2, 3],
                 vec![1.5, -0.25, 3.0e-8, f32::MIN_POSITIVE, 0.0, -7.0]);
        b.insert("blk0.clip", vec![1], vec![0.97]);
        let (table, blob) = bundle_to_blob(&b);
        let back = bundle_from_blob(&table, &blob).unwrap();
        assert_eq!(back.order, b.order);
        for name in &b.order {
            let (t0, t1) = (&b.tensors[name], &back.tensors[name]);
            assert_eq!(t0.shape, t1.shape);
            assert_eq!(t0.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                       t1.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>());
        }
        // a table pointing past the blob is rejected, not mis-read
        let (table, blob) = bundle_to_blob(&b);
        assert!(bundle_from_blob(&table, &blob[..blob.len() - 4]).is_err());
    }

    #[test]
    fn quant_config_json_is_canonical() {
        let cfg = QuantConfig::cell(3, Some(32), Quantizer::Rtn, 0.20, 2);
        let j = quant_config_json(&cfg);
        assert_eq!(j.to_string(),
                   "{\"a_bits\":4,\"a_group\":32,\"iters\":2,\
                    \"quantizer\":\"rtn\",\"rank_pct\":0.2,\"w_bits\":3}");
    }
}
