//! Deterministic fault injection for the distributed sweep fleet
//! (spec: `docs/REGISTRY.md`, harness: `lrc chaos` in [`crate::chaos`]).
//!
//! A [`FaultPlan`] is a *seeded, serializable schedule* of every fault a
//! run will suffer: connection resets at chosen protocol steps, truncated
//! and delayed (split) frames, worker crashes mid-compute, per-cell
//! compute failures (one-shot transients and always-failing poison
//! cells), and torn registry object writes.  The plan is pure data —
//! generated from a seed via [`crate::rng::Rng`], round-trippable through
//! JSON — so any observed failure reproduces from `(seed, plan)` alone.
//!
//! Injection points:
//!
//! * [`WorkerShim`] sits at the worker's frame-I/O boundary
//!   ([`super::service::run_worker`] consults it before every frame write
//!   / read and before every cell compute) and answers with a
//!   [`WriteFault`] / [`ReadFault`] / [`ComputeFault`].  Schedules are
//!   indexed by monotonic per-worker counters (frames written, frames
//!   read, cells computed), so each scheduled fault fires at most once.
//! * [`TornWriteBackend`] wraps the local-FS [`FsRegistry`] and tears
//!   chosen publishes *after* the atomic rename: deletes the meta
//!   document ([`TornMode::BlobWithoutMeta`]), deletes a referenced blob
//!   ([`TornMode::MetaWithoutBlob`]) or truncates the meta document
//!   ([`TornMode::TruncatedMeta`]).  Read-side verification must turn
//!   every one of these into a counted miss — never an error, never a
//!   wrong answer.
//!
//! Determinism caveat, stated honestly: the *plan* is a pure function of
//! the seed, but **which** scheduled faults fire depends on the claim
//! interleaving (a worker that never reaches frame 17 never suffers the
//! fault scheduled there).  Every assertion the chaos harness makes is
//! therefore interleaving-independent: the merged report bytes, the
//! quarantined cell set, and worker survival do not depend on which
//! subset of the schedule fired.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::rng::Rng;
use crate::util::Json;

use super::{FsRegistry, RegistryBackend};

/// Fault-plan document schema tag.
pub const PLAN_SCHEMA: &str = "lrc-fault-plan-v1";

/// Fault applied to one outgoing frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WriteFault {
    /// Write the frame normally.
    None,
    /// Drop the connection instead of writing (peer sees a reset).
    Reset,
    /// Write only the first `keep` bytes of the frame, then drop the
    /// connection — the peer's decoder is left holding a partial frame.
    Truncate(usize),
    /// Write the first half, sleep `ms`, write the rest — the frame
    /// arrives whole but split across arbitrary read boundaries.
    Split(u64),
}

/// Fault applied to one incoming-frame read.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ReadFault {
    /// Read normally.
    None,
    /// Drop the connection before reading the reply.
    Reset,
}

/// Fault applied to one cell compute.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ComputeFault {
    /// Compute normally.
    None,
    /// Fail the compute with this error (the worker reports a `failed`
    /// frame and lives on).
    Fail(String),
    /// Crash mid-compute: abandon the session without publishing or
    /// reporting — the dispatcher only learns from the dead socket.
    Crash,
    /// Sleep `ms` before computing (exercises claim-lease expiry).
    Stall(u64),
}

/// How one registry publish is torn.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TornMode {
    /// Blob present, meta document missing: the commit point never
    /// landed, so the object must read as a plain miss.
    BlobWithoutMeta,
    /// Meta present, referenced blob missing: verification must fail —
    /// a counted corrupt, read as a miss.
    MetaWithoutBlob,
    /// Meta document cut in half: unparseable — counted corrupt.
    TruncatedMeta,
}

impl TornMode {
    fn name(self) -> &'static str {
        match self {
            TornMode::BlobWithoutMeta => "blob-without-meta",
            TornMode::MetaWithoutBlob => "meta-without-blob",
            TornMode::TruncatedMeta => "truncated-meta",
        }
    }

    fn parse(s: &str) -> Result<TornMode> {
        Ok(match s {
            "blob-without-meta" => TornMode::BlobWithoutMeta,
            "meta-without-blob" => TornMode::MetaWithoutBlob,
            "truncated-meta" => TornMode::TruncatedMeta,
            other => bail!("unknown torn mode {other:?}"),
        })
    }
}

/// The full fault schedule for one chaos run.  Every field is keyed by
/// deterministic identities (worker name, monotonic counter index, cell
/// key), never by wall-clock time, so the plan serializes canonically
/// and replays exactly.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Seed the plan was generated from (recorded for provenance).
    pub seed: u64,
    /// `(worker, frame-write index)` pairs that reset the connection.
    pub write_resets: BTreeSet<(String, usize)>,
    /// `(worker, frame-write index)` → bytes to keep before dropping.
    pub write_truncs: BTreeMap<(String, usize), usize>,
    /// `(worker, frame-write index)` → split delay in milliseconds.
    pub write_splits: BTreeMap<(String, usize), u64>,
    /// `(worker, frame-read index)` pairs that reset the connection.
    pub read_resets: BTreeSet<(String, usize)>,
    /// `(worker, compute index)` pairs that crash mid-compute.
    pub crashes: BTreeSet<(String, usize)>,
    /// `(worker, compute index)` → stall in milliseconds before compute.
    pub stalls: BTreeMap<(String, usize), u64>,
    /// cell key → the one worker that fails it exactly once (a
    /// transient: a retry by anyone, including the same worker, succeeds).
    pub transient: BTreeMap<String, String>,
    /// cell keys every worker fails every time — quarantine fodder.
    pub poison: BTreeSet<String>,
    /// registry publish index → how that publish is torn.
    pub torn: BTreeMap<usize, TornMode>,
}

impl FaultPlan {
    /// An empty plan (no faults) carrying just the seed.
    pub fn empty(seed: u64) -> FaultPlan {
        FaultPlan { seed, ..FaultPlan::default() }
    }

    /// Deterministically generate a plan for `workers` named workers
    /// over `cells`, poisoning `poison_count` of them.  Identical
    /// arguments always yield an identical plan.
    ///
    /// Two invariants the chaos harness leans on:
    ///
    /// * the **cell-level** selections (poison, transient cells, torn
    ///   publishes) are drawn from RNG streams seeded independently of
    ///   the worker list, so they are a pure function of
    ///   `(seed, cells, poison_count)` — quarantine reporting is
    ///   therefore identical at any worker count;
    /// * the schedule is front-loaded (faults land in the first few
    ///   dozen frames / first few computes, so short `--fast` grids
    ///   actually reach them) and every per-connection fault fires at
    ///   most once, so the run always converges.
    pub fn generate(seed: u64, workers: &[String], cells: &[String],
                    poison_count: usize) -> FaultPlan {
        let mut worker_rng = Rng::new(seed);
        let mut cell_rng = Rng::new(seed ^ 0x5EED_CE11_5EED_CE11);
        let mut torn_rng = Rng::new(seed ^ 0x7042_F1A9_0000_0001);
        let mut plan = FaultPlan::empty(seed);
        for w in workers {
            // frame indices 0/1 are the hello/welcome handshake; start
            // injection at 2 so each session usually gets far enough to
            // validate run identity before the wire misbehaves
            for _ in 0..2 {
                plan.write_resets.insert(
                    (w.clone(), 2 + worker_rng.below(40)));
            }
            plan.write_truncs.insert((w.clone(), 2 + worker_rng.below(40)),
                                     1 + worker_rng.below(8));
            for _ in 0..2 {
                plan.write_splits.insert(
                    (w.clone(), 2 + worker_rng.below(40)),
                    1 + worker_rng.below(4) as u64);
            }
            plan.read_resets.insert((w.clone(), 2 + worker_rng.below(40)));
            plan.crashes.insert((w.clone(), worker_rng.below(3)));
            plan.stalls.insert((w.clone(), worker_rng.below(4)),
                               1 + worker_rng.below(5) as u64);
        }
        // poison first, transients from the untouched remainder — a cell
        // is never both; which *worker* fails a transient comes from the
        // worker stream (it may legitimately vary with the fleet shape)
        let mut idx: Vec<usize> = (0..cells.len()).collect();
        cell_rng.shuffle(&mut idx);
        let n_poison = poison_count.min(cells.len());
        for &i in idx.iter().take(n_poison) {
            plan.poison.insert(cells[i].clone());
        }
        if !workers.is_empty() {
            let n_transient = 2.min(cells.len().saturating_sub(n_poison));
            for &i in idx.iter().skip(n_poison).take(n_transient) {
                let w = workers[worker_rng.below(workers.len())].clone();
                plan.transient.insert(cells[i].clone(), w);
            }
        }
        // tear roughly a third of the publishes the run will make (one
        // publish per non-poison cell), alternating tear modes; sweep
        // cells carry no blob, so the meta-side tears are the ones that
        // can actually fire
        let n_puts = cells.len().saturating_sub(n_poison);
        if n_puts > 0 {
            let n_torn = (n_puts / 3).max(1);
            let mut puts: Vec<usize> = (0..n_puts).collect();
            torn_rng.shuffle(&mut puts);
            for (k, &i) in puts.iter().take(n_torn).enumerate() {
                let mode = if k % 2 == 0 { TornMode::BlobWithoutMeta }
                           else { TornMode::TruncatedMeta };
                plan.torn.insert(i, mode);
            }
        }
        plan
    }

    /// Total number of scheduled fault sites (an upper bound on how many
    /// can fire; operator-log material).
    pub fn total_faults(&self) -> usize {
        self.write_resets.len() + self.write_truncs.len()
            + self.write_splits.len() + self.read_resets.len()
            + self.crashes.len() + self.stalls.len()
            + self.transient.len() + self.poison.len() + self.torn.len()
    }

    /// Canonical JSON document (`lrc-fault-plan-v1`).
    pub fn to_json(&self) -> Json {
        let site = |w: &String, i: usize| Json::obj(vec![
            ("frame", Json::num(i as f64)),
            ("worker", Json::str(w.clone())),
        ]);
        let sites = |s: &BTreeSet<(String, usize)>| Json::Arr(
            s.iter().map(|(w, i)| site(w, *i)).collect());
        let sized = |m: &BTreeMap<(String, usize), usize>| Json::Arr(
            m.iter().map(|((w, i), v)| Json::obj(vec![
                ("frame", Json::num(*i as f64)),
                ("value", Json::num(*v as f64)),
                ("worker", Json::str(w.clone())),
            ])).collect());
        let timed = |m: &BTreeMap<(String, usize), u64>| Json::Arr(
            m.iter().map(|((w, i), v)| Json::obj(vec![
                ("frame", Json::num(*i as f64)),
                ("value", Json::num(*v as f64)),
                ("worker", Json::str(w.clone())),
            ])).collect());
        Json::obj(vec![
            ("schema", Json::str(PLAN_SCHEMA)),
            ("seed", Json::num(self.seed as f64)),
            ("write_resets", sites(&self.write_resets)),
            ("write_truncs", sized(&self.write_truncs)),
            ("write_splits", timed(&self.write_splits)),
            ("read_resets", sites(&self.read_resets)),
            ("crashes", sites(&self.crashes)),
            ("stalls", timed(&self.stalls)),
            ("transient", Json::Arr(self.transient.iter().map(|(c, w)|
                Json::obj(vec![
                    ("cell", Json::str(c.clone())),
                    ("worker", Json::str(w.clone())),
                ])).collect())),
            ("poison", Json::Arr(
                self.poison.iter().map(|c| Json::str(c.clone())).collect())),
            ("torn", Json::Arr(self.torn.iter().map(|(i, m)|
                Json::obj(vec![
                    ("mode", Json::str(m.name())),
                    ("put", Json::num(*i as f64)),
                ])).collect())),
        ])
    }

    /// Parse a plan document back; strict about schema and shapes so a
    /// stale plan file fails loudly instead of silently injecting the
    /// wrong faults.
    pub fn from_json(doc: &Json) -> Result<FaultPlan> {
        if doc.get("schema").and_then(|s| s.as_str()) != Some(PLAN_SCHEMA) {
            bail!("not a {PLAN_SCHEMA} document");
        }
        let seed = doc.get("seed").and_then(|s| s.as_f64())
            .ok_or_else(|| anyhow::anyhow!("fault plan missing seed"))?
            as u64;
        let arr = |field: &str| -> Result<&[Json]> {
            doc.get(field).and_then(|a| a.as_arr())
                .ok_or_else(|| anyhow::anyhow!(
                    "fault plan field {field} missing or not an array"))
        };
        let site = |e: &Json, field: &str| -> Result<(String, usize)> {
            let w = e.get("worker").and_then(|w| w.as_str())
                .ok_or_else(|| anyhow::anyhow!("{field}: missing worker"))?;
            let i = e.get("frame").and_then(|f| f.as_usize())
                .ok_or_else(|| anyhow::anyhow!("{field}: missing frame"))?;
            Ok((w.to_string(), i))
        };
        let mut plan = FaultPlan::empty(seed);
        for e in arr("write_resets")? {
            plan.write_resets.insert(site(e, "write_resets")?);
        }
        for e in arr("write_truncs")? {
            let v = e.get("value").and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow::anyhow!("write_truncs: missing \
                                                value"))?;
            plan.write_truncs.insert(site(e, "write_truncs")?, v);
        }
        for e in arr("write_splits")? {
            let v = e.get("value").and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow::anyhow!("write_splits: missing \
                                                value"))?;
            plan.write_splits.insert(site(e, "write_splits")?, v as u64);
        }
        for e in arr("read_resets")? {
            plan.read_resets.insert(site(e, "read_resets")?);
        }
        for e in arr("crashes")? {
            plan.crashes.insert(site(e, "crashes")?);
        }
        for e in arr("stalls")? {
            let v = e.get("value").and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow::anyhow!("stalls: missing value"))?;
            plan.stalls.insert(site(e, "stalls")?, v as u64);
        }
        for e in arr("transient")? {
            let c = e.get("cell").and_then(|c| c.as_str())
                .ok_or_else(|| anyhow::anyhow!("transient: missing cell"))?;
            let w = e.get("worker").and_then(|w| w.as_str())
                .ok_or_else(|| anyhow::anyhow!("transient: missing \
                                                worker"))?;
            plan.transient.insert(c.to_string(), w.to_string());
        }
        for e in arr("poison")? {
            let c = e.as_str()
                .ok_or_else(|| anyhow::anyhow!("poison: not a string"))?;
            plan.poison.insert(c.to_string());
        }
        for e in arr("torn")? {
            let i = e.get("put").and_then(|p| p.as_usize())
                .ok_or_else(|| anyhow::anyhow!("torn: missing put"))?;
            let m = e.get("mode").and_then(|m| m.as_str())
                .ok_or_else(|| anyhow::anyhow!("torn: missing mode"))?;
            plan.torn.insert(i, TornMode::parse(m)?);
        }
        Ok(plan)
    }

    /// The fault schedule projected onto one named worker — what
    /// [`super::service::run_worker`] consults.
    pub fn shim_for(&self, worker: &str) -> WorkerShim {
        let mut shim = WorkerShim {
            write: BTreeMap::new(),
            read: BTreeMap::new(),
            crashes: BTreeSet::new(),
            stalls: BTreeMap::new(),
            transient: BTreeSet::new(),
            transient_fired: BTreeSet::new(),
            poison: self.poison.clone(),
            frames_written: 0,
            frames_read: 0,
            computes: 0,
            fired: 0,
        };
        for (w, i) in &self.write_resets {
            if w == worker {
                shim.write.insert(*i, WriteFault::Reset);
            }
        }
        for ((w, i), keep) in &self.write_truncs {
            if w == worker {
                shim.write.insert(*i, WriteFault::Truncate(*keep));
            }
        }
        for ((w, i), ms) in &self.write_splits {
            if w == worker {
                shim.write.insert(*i, WriteFault::Split(*ms));
            }
        }
        for (w, i) in &self.read_resets {
            if w == worker {
                shim.read.insert(*i, ReadFault::Reset);
            }
        }
        for (w, i) in &self.crashes {
            if w == worker {
                shim.crashes.insert(*i);
            }
        }
        for ((w, i), ms) in &self.stalls {
            if w == worker {
                shim.stalls.insert(*i, *ms);
            }
        }
        for (cell, w) in &self.transient {
            if w == worker {
                shim.transient.insert(cell.clone());
            }
        }
        shim
    }
}

/// One worker's live view of a [`FaultPlan`]: monotonic counters over
/// frame writes, frame reads and cell computes index into the schedule,
/// so every scheduled fault fires at most once and the whole object is
/// deterministic given the sequence of calls.
#[derive(Clone, Debug)]
pub struct WorkerShim {
    write: BTreeMap<usize, WriteFault>,
    read: BTreeMap<usize, ReadFault>,
    crashes: BTreeSet<usize>,
    stalls: BTreeMap<usize, u64>,
    transient: BTreeSet<String>,
    transient_fired: BTreeSet<String>,
    poison: BTreeSet<String>,
    frames_written: usize,
    frames_read: usize,
    computes: usize,
    /// How many scheduled faults this shim has actually fired.
    pub fired: usize,
}

impl WorkerShim {
    /// Consult the schedule for the next outgoing frame.
    pub fn on_write(&mut self) -> WriteFault {
        let i = self.frames_written;
        self.frames_written += 1;
        match self.write.get(&i) {
            Some(f) => {
                self.fired += 1;
                f.clone()
            }
            None => WriteFault::None,
        }
    }

    /// Consult the schedule for the next incoming-frame read.
    pub fn on_read(&mut self) -> ReadFault {
        let i = self.frames_read;
        self.frames_read += 1;
        match self.read.get(&i) {
            Some(f) => {
                self.fired += 1;
                f.clone()
            }
            None => ReadFault::None,
        }
    }

    /// Consult the schedule for the next cell compute.  Poison beats
    /// everything (its error string is a pure function of the cell key,
    /// so quarantine reporting is identical no matter which workers hit
    /// it); transients fire exactly once per shim.
    pub fn on_compute(&mut self, cell: &str) -> ComputeFault {
        let i = self.computes;
        self.computes += 1;
        if self.poison.contains(cell) {
            self.fired += 1;
            return ComputeFault::Fail(
                format!("injected fault: poison cell {cell}"));
        }
        if self.crashes.contains(&i) {
            self.fired += 1;
            return ComputeFault::Crash;
        }
        if self.transient.contains(cell)
            && !self.transient_fired.contains(cell) {
            self.transient_fired.insert(cell.to_string());
            self.fired += 1;
            return ComputeFault::Fail(
                format!("injected fault: transient failure on {cell}"));
        }
        if let Some(&ms) = self.stalls.get(&i) {
            self.fired += 1;
            return ComputeFault::Stall(ms);
        }
        ComputeFault::None
    }
}

/// Shared tear counters, cloned out of a [`TornWriteBackend`] before it
/// disappears into a `Box<dyn RegistryBackend>`.
#[derive(Clone)]
pub struct TornCounters {
    /// Tears that leave the object absent (meta removed): read back as a
    /// plain miss.
    pub missing: Arc<AtomicU64>,
    /// Tears that leave a broken object behind (blob removed, meta
    /// truncated): read back as a counted corrupt.
    pub corrupt: Arc<AtomicU64>,
}

impl TornCounters {
    pub fn missing(&self) -> u64 {
        self.missing.load(Ordering::SeqCst)
    }

    pub fn corrupt(&self) -> u64 {
        self.corrupt.load(Ordering::SeqCst)
    }

    /// Total tears actually applied.
    pub fn fired(&self) -> u64 {
        self.missing() + self.corrupt()
    }
}

/// A [`RegistryBackend`] that publishes through a real [`FsRegistry`]
/// and then tears chosen publishes apart, by monotonic publish index.
/// The tear happens *after* the atomic rename — exactly the artifact a
/// crashed publisher or a lost partial upload leaves behind — and
/// `put_raw` still reports success, so the writer never learns.  Reads
/// pass straight through: the read-side verification above the backend
/// is the thing under test.
pub struct TornWriteBackend {
    inner: FsRegistry,
    torn: BTreeMap<usize, TornMode>,
    puts: AtomicU64,
    counters: TornCounters,
}

impl TornWriteBackend {
    pub fn new(root: &Path, torn: BTreeMap<usize, TornMode>)
               -> TornWriteBackend {
        TornWriteBackend {
            inner: FsRegistry::new(root),
            torn,
            puts: AtomicU64::new(0),
            counters: TornCounters {
                missing: Arc::new(AtomicU64::new(0)),
                corrupt: Arc::new(AtomicU64::new(0)),
            },
        }
    }

    /// Clone the tear counters out (the backend itself is about to be
    /// boxed behind the `RegistryBackend` trait).
    pub fn counters(&self) -> TornCounters {
        self.counters.clone()
    }
}

impl RegistryBackend for TornWriteBackend {
    fn get_raw(&self, digest: &str)
               -> Result<Option<(Vec<u8>, Option<Vec<u8>>)>> {
        self.inner.get_raw(digest)
    }

    fn put_raw(&self, digest: &str, meta: &[u8], blob: Option<&[u8]>)
               -> Result<()> {
        self.inner.put_raw(digest, meta, blob)?;
        let i = self.puts.fetch_add(1, Ordering::SeqCst) as usize;
        if let Some(mode) = self.torn.get(&i) {
            match mode {
                TornMode::BlobWithoutMeta => {
                    let _ = std::fs::remove_file(
                        self.inner.object_file(digest));
                    self.counters.missing.fetch_add(1, Ordering::SeqCst);
                }
                TornMode::MetaWithoutBlob => {
                    // only meaningful when a blob exists to lose
                    if blob.is_some() {
                        let _ = std::fs::remove_file(
                            self.inner.blob_file(digest));
                        self.counters.corrupt.fetch_add(1, Ordering::SeqCst);
                    }
                }
                TornMode::TruncatedMeta => {
                    let path = self.inner.object_file(digest);
                    if let Ok(bytes) = std::fs::read(&path) {
                        let _ = std::fs::write(&path,
                                               &bytes[..bytes.len() / 2]);
                        self.counters.corrupt.fetch_add(1, Ordering::SeqCst);
                    }
                }
            }
        }
        Ok(())
    }

    fn describe(&self) -> String {
        format!("torn({} tears over {})", self.torn.len(),
                self.inner.describe())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn names(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("w{i}")).collect()
    }

    fn cells() -> Vec<String> {
        (0..8).map(|i| format!("cell_{i}")).collect()
    }

    #[test]
    fn generate_is_deterministic_and_seed_sensitive() {
        let a = FaultPlan::generate(7, &names(3), &cells(), 2);
        let b = FaultPlan::generate(7, &names(3), &cells(), 2);
        assert_eq!(a, b, "same seed must yield the same plan");
        let c = FaultPlan::generate(8, &names(3), &cells(), 2);
        assert_ne!(a, c, "a different seed must move the plan");
        assert_eq!(a.poison.len(), 2);
        assert!(a.total_faults() > 0);
        // poison and transient never overlap
        for cell in a.transient.keys() {
            assert!(!a.poison.contains(cell),
                    "{cell} is both poison and transient");
        }
        // cell-level selections are a pure function of (seed, cells,
        // poison_count): changing the fleet shape must not move them,
        // or quarantine reporting would differ across worker counts
        let d = FaultPlan::generate(7, &names(5), &cells(), 2);
        assert_eq!(a.poison, d.poison,
                   "poison set must not depend on worker count");
        assert_eq!(a.torn, d.torn,
                   "torn schedule must not depend on worker count");
        assert_eq!(a.transient.keys().collect::<Vec<_>>(),
                   d.transient.keys().collect::<Vec<_>>(),
                   "transient cells must not depend on worker count");
    }

    #[test]
    fn plan_roundtrips_through_json() {
        let plan = FaultPlan::generate(42, &names(2), &cells(), 1);
        let doc = plan.to_json();
        let text = doc.to_string();
        let back = FaultPlan::from_json(&Json::parse(&text).unwrap())
            .unwrap();
        assert_eq!(plan, back, "plan must survive a JSON roundtrip");
        // and serialization itself is canonical
        assert_eq!(text, back.to_json().to_string());
        // a wrong schema tag is rejected loudly
        let bad = text.replace(PLAN_SCHEMA, "lrc-fault-plan-v0");
        assert!(FaultPlan::from_json(&Json::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn shim_fires_each_scheduled_fault_exactly_once() {
        let mut plan = FaultPlan::empty(0);
        plan.write_resets.insert(("w0".into(), 2));
        plan.write_truncs.insert(("w0".into(), 4), 3);
        plan.read_resets.insert(("w0".into(), 1));
        plan.write_resets.insert(("w1".into(), 0));
        let mut shim = plan.shim_for("w0");
        let writes: Vec<WriteFault> =
            (0..6).map(|_| shim.on_write()).collect();
        assert_eq!(writes, vec![
            WriteFault::None, WriteFault::None, WriteFault::Reset,
            WriteFault::None, WriteFault::Truncate(3), WriteFault::None,
        ]);
        assert_eq!(shim.on_read(), ReadFault::None);
        assert_eq!(shim.on_read(), ReadFault::Reset);
        assert_eq!(shim.on_read(), ReadFault::None);
        assert_eq!(shim.fired, 3, "w1's faults must not leak into w0");
    }

    #[test]
    fn transient_fails_once_poison_fails_always() {
        let mut plan = FaultPlan::empty(0);
        plan.transient.insert("cell_t".into(), "w0".into());
        plan.poison.insert("cell_p".into());
        let mut shim = plan.shim_for("w0");
        match shim.on_compute("cell_t") {
            ComputeFault::Fail(e) => assert!(e.contains("transient")),
            other => panic!("expected transient failure, got {other:?}"),
        }
        assert_eq!(shim.on_compute("cell_t"), ComputeFault::None,
                   "a transient retried by the same worker succeeds");
        for _ in 0..3 {
            match shim.on_compute("cell_p") {
                ComputeFault::Fail(e) => assert_eq!(
                    e, "injected fault: poison cell cell_p",
                    "poison error strings are a pure function of the key"),
                other => panic!("poison must always fail, got {other:?}"),
            }
        }
        // the transient is invisible to other workers
        let mut other = plan.shim_for("w1");
        assert_eq!(other.on_compute("cell_t"), ComputeFault::None);
    }

    #[test]
    fn torn_backend_tears_exactly_the_scheduled_puts() {
        let root = std::env::temp_dir().join(format!(
            "lrc_torn_unit_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        let mut torn = BTreeMap::new();
        torn.insert(0usize, TornMode::BlobWithoutMeta);
        torn.insert(2usize, TornMode::TruncatedMeta);
        let backend = TornWriteBackend::new(&root, torn);
        let counters = backend.counters();
        let fs = FsRegistry::new(&root);
        for i in 0..3 {
            let digest = format!("{i:064}");
            backend.put_raw(&digest, b"{\"meta\":\"document\"}", None)
                .unwrap();
        }
        assert!(!fs.object_file(&format!("{:064}", 0)).exists(),
                "put 0: meta removed");
        assert!(fs.object_file(&format!("{:064}", 1)).exists(),
                "put 1: untouched");
        let truncated =
            std::fs::read(fs.object_file(&format!("{:064}", 2))).unwrap();
        assert_eq!(truncated.len(), b"{\"meta\":\"document\"}".len() / 2,
                   "put 2: meta cut in half");
        assert_eq!((counters.missing(), counters.corrupt()), (1, 1));
        let _ = std::fs::remove_dir_all(&root);
    }
}
