//! Minimal JSON reader/writer (no serde in the offline image).
//!
//! Covers exactly what the artifact manifests, task files and result dumps
//! need: objects, arrays, strings (with escapes), f64 numbers, bool, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != bytes.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Serialize (stable key order via BTreeMap).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad hex")?,
                                16,
                            )
                            .map_err(|_| "bad hex")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = &self.b[self.i..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| "invalid utf8".to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("bad array sep {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("bad object sep {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{"format":"lrc-bundle-v1","tensors":[{"name":"tok_emb","shape":[256,64],"offset":0}],"n":-1.5e3,"flag":true,"none":null}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("format").unwrap().as_str().unwrap(), "lrc-bundle-v1");
        let t = &v.get("tensors").unwrap().as_arr().unwrap()[0];
        assert_eq!(t.get("shape").unwrap().as_arr().unwrap()[1].as_usize(),
                   Some(64));
        assert_eq!(v.get("n").unwrap().as_f64(), Some(-1500.0));
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn strings_with_escapes() {
        let v = Json::parse(r#""a\n\"b\"A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\"b\"A");
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n \"a\" : [ 1 , 2 ] }\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn nested_depth() {
        let v = Json::parse(r#"{"a":{"b":{"c":[{"d":1}]}}}"#).unwrap();
        let d = v.get("a").unwrap().get("b").unwrap().get("c").unwrap()
            .as_arr().unwrap()[0].get("d").unwrap().as_usize();
        assert_eq!(d, Some(1));
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → 世界");
    }
}
