//! Small no-dependency utilities: JSON, CLI args, table printing.

pub mod json;

pub use json::Json;

/// Dead-simple `--key value` / `--flag` argument parser for the CLI and
/// bench targets (no clap offline).
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: std::collections::BTreeMap<String, String>,
    pub flags: std::collections::BTreeSet<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains(key)
    }
}

/// Render an aligned text table (paper-style rows for the bench harness).
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let line = |out: &mut String, cells: &[String]| {
        out.push('|');
        for (i, c) in cells.iter().enumerate().take(ncol) {
            out.push(' ');
            out.push_str(c);
            out.push_str(&" ".repeat(widths[i] - c.len() + 1));
            out.push('|');
        }
        out.push('\n');
    };
    line(&mut out, &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    out.push('|');
    for w in &widths {
        out.push_str(&"-".repeat(w + 2));
        out.push('|');
    }
    out.push('\n');
    for row in rows {
        line(&mut out, row);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn args_forms() {
        let a = Args::parse(
            ["run", "--model", "small", "--fast", "--pct=10"]
                .iter()
                .map(|s| s.to_string()),
        );
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get("model"), Some("small"));
        assert_eq!(a.get("pct"), Some("10"));
        assert!(a.has("fast"));
        assert_eq!(a.get_usize("missing", 7), 7);
    }

    #[test]
    fn negative_option_value() {
        let a = Args::parse(["--x", "-3"].iter().map(|s| s.to_string()));
        assert_eq!(a.get_f64("x", 0.0), -3.0);
    }

    #[test]
    fn table_renders() {
        let t = render_table(&["Method", "PPL"],
                             &[vec!["FP16".into(), "6.01".into()],
                               vec!["LRC (1)".into(), "7.26".into()]]);
        assert!(t.contains("| Method "));
        assert!(t.contains("| LRC (1) "));
    }
}
