//! Symmetric eigensolver — the `eig_k(·)` operator of Algorithms 3 and 4.
//!
//! Two implementations:
//!   * [`eigh`] — Householder tridiagonalization + implicit-shift QL
//!     (tred2/tqli), O(4/3·n³) once + O(n²) per eigenvalue.  The
//!     production path: ~50× faster than Jacobi at n = 256 (see
//!     EXPERIMENTS.md §Perf).
//!   * [`eigh_jacobi`] — cyclic Jacobi: slower but unconditionally
//!     stable and independently derived; kept as the property-test
//!     oracle that cross-checks `eigh`.

use super::Mat;

/// Full symmetric eigendecomposition (Householder + QL path).
/// Returns (eigenvalues ascending, eigenvectors as *columns* of V):
/// A = V · diag(λ) · Vᵀ.
pub fn eigh(a: &Mat) -> (Vec<f64>, Mat) {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    // symmetrize defensively, matching the Jacobi path
    let mut m = a.clone();
    for i in 0..n {
        for j in 0..i {
            let avg = 0.5 * (m[(i, j)] + m[(j, i)]);
            m[(i, j)] = avg;
            m[(j, i)] = avg;
        }
    }
    let (mut d, mut e, z) = tred2(&m);
    // tqli's Givens rotations touch eigenvector *columns*; rotate rows of
    // the transpose instead so the hot loop is contiguous (§Perf: 2.3×)
    let mut zt = z.transpose();
    tqli(&mut d, &mut e, &mut zt);
    let z = zt.transpose();
    // sort ascending
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&x, &y| d[x].partial_cmp(&d[y]).unwrap());
    let vals: Vec<f64> = idx.iter().map(|&i| d[i]).collect();
    let mut vecs = Mat::zeros(n, n);
    for (new_c, &old_c) in idx.iter().enumerate() {
        for r in 0..n {
            vecs[(r, new_c)] = z[(r, old_c)];
        }
    }
    (vals, vecs)
}

/// Householder reduction of a real symmetric matrix to tridiagonal form
/// (Numerical Recipes tred2): returns (diagonal d, sub-diagonal e, and the
/// accumulated orthogonal transform Q with A = Q·T·Qᵀ).
fn tred2(a: &Mat) -> (Vec<f64>, Vec<f64>, Mat) {
    let n = a.rows;
    let mut z = a.clone();
    let mut d = vec![0.0_f64; n];
    let mut e = vec![0.0_f64; n];

    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0_f64;
        if l > 0 {
            let mut scale = 0.0_f64;
            for k in 0..=l {
                scale += z[(i, k)].abs();
            }
            if scale == 0.0 {
                e[i] = z[(i, l)];
            } else {
                for k in 0..=l {
                    z[(i, k)] /= scale;
                    h += z[(i, k)] * z[(i, k)];
                }
                let mut f = z[(i, l)];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                z[(i, l)] = f - g;
                f = 0.0;
                for j in 0..=l {
                    z[(j, i)] = z[(i, j)] / h;
                    let mut g = 0.0_f64;
                    for k in 0..=j {
                        g += z[(j, k)] * z[(i, k)];
                    }
                    for k in j + 1..=l {
                        g += z[(k, j)] * z[(i, k)];
                    }
                    e[j] = g / h;
                    f += e[j] * z[(i, j)];
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let f = z[(i, j)];
                    let g = e[j] - hh * f;
                    e[j] = g;
                    for k in 0..=j {
                        let delta = f * e[k] + g * z[(i, k)];
                        z[(j, k)] -= delta;
                    }
                }
            }
        } else {
            e[i] = z[(i, l)];
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        if d[i] != 0.0 {
            // accumulate the transform
            for j in 0..i {
                let mut g = 0.0_f64;
                for k in 0..i {
                    g += z[(i, k)] * z[(k, j)];
                }
                for k in 0..i {
                    let zkj = z[(k, i)];
                    z[(k, j)] -= g * zkj;
                }
            }
        }
        d[i] = z[(i, i)];
        z[(i, i)] = 1.0;
        for j in 0..i {
            z[(j, i)] = 0.0;
            z[(i, j)] = 0.0;
        }
    }
    (d, e, z)
}

/// Implicit-shift QL on the tridiagonal (d, e), accumulating eigenvectors
/// into the *rows* of zt (transposed layout for contiguous rotations).
fn tqli(d: &mut [f64], e: &mut [f64], zt: &mut Mat) {
    let n = d.len();
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    for l in 0..n {
        let mut iter = 0;
        loop {
            // find a negligible sub-diagonal to split at
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            assert!(iter <= 50, "tqli failed to converge");
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            g = d[m] - d[l] + e[l] / (g + r.copysign(g));
            let (mut s, mut c) = (1.0_f64, 1.0_f64);
            let mut p = 0.0_f64;
            for i in (l..m).rev() {
                let f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // eigenvector rotation on contiguous rows i and i+1
                {
                    let (head, tail) = zt.data.split_at_mut((i + 1) * n);
                    let row_i = &mut head[i * n..];
                    let row_i1 = &mut tail[..n];
                    for k in 0..n {
                        let f = row_i1[k];
                        row_i1[k] = s * row_i[k] + c * f;
                        row_i[k] = c * row_i[k] - s * f;
                    }
                }
            }
            if r == 0.0 && m > l {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
}

/// Cyclic-Jacobi eigensolver — the independently-derived oracle used by
/// the test-suite to cross-check [`eigh`].
pub fn eigh_jacobi(a: &Mat) -> (Vec<f64>, Mat) {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut m = a.clone();
    // symmetrize defensively (callers pass (Σ+Σᵀ)/2-like inputs)
    for i in 0..n {
        for j in 0..i {
            let avg = 0.5 * (m[(i, j)] + m[(j, i)]);
            m[(i, j)] = avg;
            m[(j, i)] = avg;
        }
    }
    let mut v = Mat::eye(n);
    let scale = m.max_abs().max(1e-300);
    let tol = 1e-14 * scale;

    for _sweep in 0..60 {
        // off-diagonal Frobenius mass
        let mut off = 0.0_f64;
        for i in 0..n {
            for j in i + 1..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() <= tol * (n as f64) {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m[(p, q)];
                if apq.abs() <= tol {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = 0.5 * (aqq - app) / apq;
                // stable tan rotation
                let t = theta.signum() / (theta.abs() + (1.0 + theta * theta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;

                // rows/cols p and q of m
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                // accumulate eigenvectors (columns of v)
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    // sort ascending by eigenvalue
    let mut idx: Vec<usize> = (0..n).collect();
    let evals: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    idx.sort_by(|&a, &b| evals[a].partial_cmp(&evals[b]).unwrap());
    let sorted_vals: Vec<f64> = idx.iter().map(|&i| evals[i]).collect();
    let mut sorted_vecs = Mat::zeros(n, n);
    for (new_c, &old_c) in idx.iter().enumerate() {
        for r in 0..n {
            sorted_vecs[(r, new_c)] = v[(r, old_c)];
        }
    }
    (sorted_vals, sorted_vecs)
}

/// Parallel Jacobi eigensolver on a [`crate::par::Pool`].
///
/// Rotation sweeps are reordered into tournament rounds (the circle
/// method): each round holds ⌊n/2⌋ pairwise-disjoint (p, q) pivots, so
/// the rotations of a round commute exactly and can be computed
/// concurrently.  A round is applied in two globally-ordered phases —
/// all column updates (M·G), then all row updates (Gᵀ·M) — with the new
/// columns/rows computed on the pool and written back serially.  Every
/// matrix element is therefore produced by one fixed floating-point
/// program per round, making the result **bit-identical for every pool
/// size** (threads = 1 included); it differs from [`eigh_jacobi`] only
/// by the pivot ordering, which Jacobi convergence does not depend on.
///
/// Intended for large single-matrix workloads; inside the per-layer
/// quantization fan-out the serial QL path stays the right choice (the
/// layers themselves already saturate the pool).
///
/// The two dispatches per round are exactly the fine-grained pattern the
/// persistent pool exists for: a parked-worker epoch costs a couple of
/// mutex hops where a scoped spawn/join cycle costs hundreds of
/// microseconds (see `bench_par`'s persistent-vs-scoped section).  Pass
/// `pool.scoped()` to get the old spawn-per-call behavior.
///
/// Rounds are **allocation-free in steady state**: the per-pair column /
/// row / eigenvector scratch lives in two
/// [`crate::linalg::workspace`]-recycled buffers
/// sized once per call (pairs write disjoint chunks through a
/// `SharedSlice`, applied serially in pair order), and the pair / rotation
/// lists are reused across every round — where each pair used to allocate
/// four fresh `Vec`s per round, a whole call now makes O(1) allocations
/// (`tests/alloc_steady_state.rs` bounds it).
pub fn eigh_jacobi_par(a: &Mat, pool: &crate::par::Pool) -> (Vec<f64>, Mat) {
    use crate::linalg::workspace::{self, SharedSlice};
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    if n == 0 {
        return (Vec::new(), Mat::zeros(0, 0));
    }
    let mut m = a.clone();
    // symmetrize defensively, matching the serial paths
    for i in 0..n {
        for j in 0..i {
            let avg = 0.5 * (m[(i, j)] + m[(j, i)]);
            m[(i, j)] = avg;
            m[(j, i)] = avg;
        }
    }
    let mut v = Mat::eye(n);
    let scale = m.max_abs().max(1e-300);
    let tol = 1e-14 * scale;

    // tournament schedule over np players (pad with a dummy when n is odd):
    // player 0 is fixed, the rest rotate one seat per round — every (p, q)
    // pair occurs exactly once per sweep, each round's pairs are disjoint.
    let np = if n % 2 == 0 { n } else { n + 1 };
    let seat = |j: usize, round: usize| -> usize {
        if j == 0 { 0 } else { (j - 1 + round) % (np - 1) + 1 }
    };

    // round scratch, arena-backed and reused across every round of every
    // sweep: pair pi's phase-1 chunk is colbuf[pi·2n ..] (colp | colq),
    // its phase-2 chunk rowbuf[pi·4n ..] (rowp | rowq | vcolp | vcolq)
    let max_pairs = np / 2;
    let mut colbuf = workspace::take_zeroed(max_pairs * 2 * n);
    let mut rowbuf = workspace::take_zeroed(max_pairs * 4 * n);
    let mut pairs: Vec<(usize, usize)> = Vec::with_capacity(max_pairs);
    // (c, s, live) per pair; live = false for converged pivots
    let mut rots: Vec<(f64, f64, bool)> = Vec::with_capacity(max_pairs);

    for _sweep in 0..60 {
        let mut off = 0.0_f64;
        for i in 0..n {
            for j in i + 1..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() <= tol * (n as f64) {
            break;
        }
        for round in 0..np - 1 {
            pairs.clear();
            for i in 0..np / 2 {
                let a = seat(i, round);
                let b = seat(np - 1 - i, round);
                if a < n && b < n {
                    pairs.push((a.min(b), a.max(b)));
                }
            }
            rots.clear();
            rots.resize(pairs.len(), (0.0, 0.0, false));
            // phase 1 — column updates M ← M·G: each pair computes its
            // rotation angle and its two new columns from the pristine
            // round matrix (pairs are column-disjoint) into its own
            // scratch chunk; applied serially below in fixed pair order
            {
                let col_out = SharedSlice::new(&mut colbuf);
                let rot_out = SharedSlice::new(&mut rots);
                let mm = &m;
                pool.for_indices(pairs.len(), |pi| {
                    let (p, q) = pairs[pi];
                    let apq = mm[(p, q)];
                    if apq.abs() <= tol {
                        return; // rots[pi] stays (_, _, false)
                    }
                    let theta = 0.5 * (mm[(q, q)] - mm[(p, p)]) / apq;
                    let t = theta.signum()
                        / (theta.abs() + (1.0 + theta * theta).sqrt());
                    let c = 1.0 / (1.0 + t * t).sqrt();
                    let s = t * c;
                    // SAFETY: chunk pi is this pair's private span
                    let chunk =
                        unsafe { col_out.range(pi * 2 * n, (pi + 1) * 2 * n) };
                    let (colp, colq) = chunk.split_at_mut(n);
                    for k in 0..n {
                        let mkp = mm[(k, p)];
                        let mkq = mm[(k, q)];
                        colp[k] = c * mkp - s * mkq;
                        colq[k] = s * mkp + c * mkq;
                    }
                    // SAFETY: slot pi is written by this pair alone
                    unsafe { rot_out.range(pi, pi + 1) }[0] = (c, s, true);
                });
            }
            for (pi, &(_, _, live)) in rots.iter().enumerate() {
                if !live {
                    continue;
                }
                let (p, q) = pairs[pi];
                let base = pi * 2 * n;
                for k in 0..n {
                    m[(k, p)] = colbuf[base + k];
                    m[(k, q)] = colbuf[base + n + k];
                }
            }
            // phase 2 — row updates M ← Gᵀ·M and eigenvector columns
            // V ← V·G, from the column-updated matrix (pairs are
            // row-disjoint in M and column-disjoint in V)
            {
                let row_out = SharedSlice::new(&mut rowbuf);
                let mm = &m;
                let vv = &v;
                let rr = &rots;
                pool.for_indices(pairs.len(), |pi| {
                    let (c, s, live) = rr[pi];
                    if !live {
                        return;
                    }
                    let (p, q) = pairs[pi];
                    // SAFETY: chunk pi is this pair's private span
                    let chunk =
                        unsafe { row_out.range(pi * 4 * n, (pi + 1) * 4 * n) };
                    let (rowp, rest) = chunk.split_at_mut(n);
                    let (rowq, rest) = rest.split_at_mut(n);
                    let (vcolp, vcolq) = rest.split_at_mut(n);
                    for k in 0..n {
                        let mpk = mm[(p, k)];
                        let mqk = mm[(q, k)];
                        rowp[k] = c * mpk - s * mqk;
                        rowq[k] = s * mpk + c * mqk;
                        let vkp = vv[(k, p)];
                        let vkq = vv[(k, q)];
                        vcolp[k] = c * vkp - s * vkq;
                        vcolq[k] = s * vkp + c * vkq;
                    }
                });
            }
            for (pi, &(_, _, live)) in rots.iter().enumerate() {
                if !live {
                    continue;
                }
                let (p, q) = pairs[pi];
                let base = pi * 4 * n;
                m.row_mut(p).copy_from_slice(&rowbuf[base..base + n]);
                m.row_mut(q).copy_from_slice(&rowbuf[base + n..base + 2 * n]);
                for k in 0..n {
                    v[(k, p)] = rowbuf[base + 2 * n + k];
                    v[(k, q)] = rowbuf[base + 3 * n + k];
                }
            }
        }
    }
    workspace::put(colbuf);
    workspace::put(rowbuf);

    // sort ascending by eigenvalue, as the serial solvers do
    let mut idx: Vec<usize> = (0..n).collect();
    let evals: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    idx.sort_by(|&a, &b| evals[a].partial_cmp(&evals[b]).unwrap());
    let sorted_vals: Vec<f64> = idx.iter().map(|&i| evals[i]).collect();
    let mut sorted_vecs = Mat::zeros(n, n);
    for (new_c, &old_c) in idx.iter().enumerate() {
        for r in 0..n {
            sorted_vecs[(r, new_c)] = v[(r, old_c)];
        }
    }
    (sorted_vals, sorted_vecs)
}

/// `eig_k`: the k unit eigenvectors with the largest eigenvalues, as the
/// *columns* of a [n, k] matrix (paper's U).
pub fn top_k_eigvecs(a: &Mat, k: usize) -> Mat {
    let n = a.rows;
    assert!(k <= n);
    let (_vals, vecs) = eigh(a);
    let mut u = Mat::zeros(n, k);
    for j in 0..k {
        let src = n - 1 - j; // descending
        for i in 0..n {
            u[(i, j)] = vecs[(i, src)];
        }
    }
    u
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_sym(seed: u64, n: usize) -> Mat {
        let a = Mat::random_normal(&mut Rng::new(seed), n, n);
        a.add(&a.transpose()).scale(0.5)
    }

    #[test]
    fn ql_matches_jacobi_oracle() {
        // the production QL path must agree with the independently
        // derived Jacobi solver: same eigenvalues, same invariant spaces
        for seed in 0..6 {
            let n = 3 + (seed as usize % 4) * 7; // 3, 10, 17, 24
            let a = random_sym(seed + 100, n);
            let (v1, _) = eigh(&a);
            let (v2, _) = eigh_jacobi(&a);
            for (x, y) in v1.iter().zip(&v2) {
                assert!((x - y).abs() < 1e-8 * (1.0 + x.abs()),
                        "seed {seed}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn parallel_jacobi_matches_ql_eigenvalues() {
        use crate::par::Pool;
        for seed in 0..4 {
            let n = 5 + (seed as usize) * 6; // 5, 11, 17, 23 — odd + even
            let a = random_sym(seed + 300, n);
            let (v1, _) = eigh(&a);
            let (v2, _) = eigh_jacobi_par(&a, &Pool::new(4));
            for (x, y) in v1.iter().zip(&v2) {
                assert!((x - y).abs() < 1e-8 * (1.0 + x.abs()),
                        "seed {seed}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn parallel_jacobi_bit_identical_across_pools() {
        use crate::par::Pool;
        for n in [3, 8, 13, 24] {
            let a = random_sym(400 + n as u64, n);
            let (vals1, vecs1) = eigh_jacobi_par(&a, &Pool::new(1));
            for t in [2, 8] {
                let (vals, vecs) = eigh_jacobi_par(&a, &Pool::new(t));
                assert_eq!(vals1, vals, "n={n} threads={t}");
                assert_eq!(vecs1, vecs, "n={n} threads={t}");
            }
        }
    }

    #[test]
    fn parallel_jacobi_reconstructs() {
        use crate::par::Pool;
        let n = 12;
        let a = random_sym(55, n);
        let (vals, v) = eigh_jacobi_par(&a, &Pool::new(3));
        // A V = V diag(vals) and VᵀV = I
        let av = a.matmul(&v);
        let mut vd = v.clone();
        for i in 0..n {
            for j in 0..n {
                vd[(i, j)] *= vals[j];
            }
        }
        assert!(av.sub(&vd).max_abs() < 1e-8);
        let vtv = v.transpose().matmul(&v);
        assert!(vtv.sub(&Mat::eye(n)).max_abs() < 1e-9);
    }

    #[test]
    fn ql_handles_degenerate_spectra() {
        // repeated eigenvalues + zero rows
        let mut a = Mat::zeros(6, 6);
        for i in 0..3 {
            a[(i, i)] = 2.0; // triple eigenvalue
        }
        let (vals, v) = eigh(&a);
        assert!((vals[5] - 2.0).abs() < 1e-12);
        assert!(vals[0].abs() < 1e-12);
        let av = a.matmul(&v);
        let mut vd = v.clone();
        for i in 0..6 {
            for j in 0..6 {
                vd[(i, j)] *= vals[j];
            }
        }
        assert!(av.sub(&vd).max_abs() < 1e-9);
    }

    #[test]
    fn reconstruction() {
        for seed in 0..4 {
            let n = 10;
            let a = random_sym(seed, n);
            let (vals, v) = eigh(&a);
            // A V = V diag(vals)
            let av = a.matmul(&v);
            let mut vd = v.clone();
            for i in 0..n {
                for j in 0..n {
                    vd[(i, j)] *= vals[j];
                }
            }
            assert!(av.sub(&vd).max_abs() < 1e-8, "seed {seed}");
        }
    }

    #[test]
    fn orthonormal_eigvecs() {
        let a = random_sym(7, 12);
        let (_, v) = eigh(&a);
        let vtv = v.transpose().matmul(&v);
        assert!(vtv.sub(&Mat::eye(12)).max_abs() < 1e-9);
    }

    #[test]
    fn known_diagonal() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 3.0;
        a[(1, 1)] = 1.0;
        a[(2, 2)] = 2.0;
        let (vals, _) = eigh(&a);
        assert!((vals[0] - 1.0).abs() < 1e-12);
        assert!((vals[1] - 2.0).abs() < 1e-12);
        assert!((vals[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn trace_and_det_invariants() {
        // property: Σλ = tr(A); eigenvalues of A+cI shift by c
        for seed in 0..5 {
            let a = random_sym(seed + 20, 9);
            let (vals, _) = eigh(&a);
            let sum: f64 = vals.iter().sum();
            assert!((sum - a.trace()).abs() < 1e-8);
            let mut b = a.clone();
            b.add_diag(2.5);
            let (vals_b, _) = eigh(&b);
            for (x, y) in vals.iter().zip(&vals_b) {
                assert!((x + 2.5 - y).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn top_k_maximizes_rayleigh() {
        // property: tr(UᵀAU) for eig_k U beats random orthonormal U
        let a = random_sym(33, 16);
        let u = top_k_eigvecs(&a, 4);
        let utau = u.transpose().matmul(&a).matmul(&u).trace();
        let mut rng = Rng::new(99);
        for _ in 0..10 {
            // random orthonormal via Gram-Schmidt on random matrix
            let r = Mat::random_normal(&mut rng, 16, 4);
            let q = gram_schmidt(&r);
            let t = q.transpose().matmul(&a).matmul(&q).trace();
            assert!(utau >= t - 1e-9, "{utau} < {t}");
        }
    }

    fn gram_schmidt(a: &Mat) -> Mat {
        let (n, k) = (a.rows, a.cols);
        let mut q = a.clone();
        for j in 0..k {
            for p in 0..j {
                let mut d = 0.0;
                for i in 0..n {
                    d += q[(i, j)] * q[(i, p)];
                }
                for i in 0..n {
                    let v = q[(i, p)];
                    q[(i, j)] -= d * v;
                }
            }
            let norm: f64 = (0..n).map(|i| q[(i, j)] * q[(i, j)]).sum::<f64>().sqrt();
            for i in 0..n {
                q[(i, j)] /= norm;
            }
        }
        q
    }
}
