//! Cholesky factorization + triangular solves.
//!
//! The paper's Remark B.1 computes (W−UVᵀ)XYᵀ(YYᵀ)⁻¹ via the Cholesky
//! factor of YYᵀ for numerical stability; these are exactly those
//! primitives.

use super::Mat;

/// Lower-triangular Cholesky factor of a symmetric PD matrix: Σ = L·Lᵀ.
pub fn cholesky(a: &Mat) -> Result<Mat, String> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            // s -= Σ_k L[i,k]·L[j,k]
            s -= super::dot(&l.data[i * n..i * n + j], &l.data[j * n..j * n + j]);
            if i == j {
                if s <= 0.0 {
                    return Err(format!(
                        "cholesky: matrix not PD at pivot {i} (s={s:.3e})"));
                }
                l[(i, i)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Ok(l)
}

/// Solve L·Z = B (forward substitution), B is [n, m], L lower-triangular.
pub fn solve_lower(l: &Mat, b: &Mat) -> Mat {
    let mut z = b.clone();
    solve_lower_in_place(l, &mut z);
    z
}

/// [`solve_lower`] overwriting `z` (the right-hand side) in place — the
/// composed solves reuse one buffer instead of cloning per stage.
pub fn solve_lower_in_place(l: &Mat, z: &mut Mat) {
    let n = l.rows;
    assert_eq!(z.rows, n);
    let m = z.cols;
    for i in 0..n {
        // z[i,:] -= Σ_{k<i} L[i,k] z[k,:]
        for k in 0..i {
            let lik = l[(i, k)];
            if lik != 0.0 {
                let (head, tail) = z.data.split_at_mut(i * m);
                super::axpy(-lik, &head[k * m..k * m + m], &mut tail[..m]);
            }
        }
        let d = l[(i, i)];
        for v in z.row_mut(i) {
            *v /= d;
        }
    }
}

/// Solve Lᵀ·Z = B (back substitution) with L lower-triangular.
pub fn solve_upper(l: &Mat, b: &Mat) -> Mat {
    let mut z = b.clone();
    solve_upper_in_place(l, &mut z);
    z
}

/// [`solve_upper`] overwriting `z` in place.
pub fn solve_upper_in_place(l: &Mat, z: &mut Mat) {
    let n = l.rows;
    assert_eq!(z.rows, n);
    let m = z.cols;
    for i in (0..n).rev() {
        for k in i + 1..n {
            let lki = l[(k, i)]; // (Lᵀ)[i,k]
            if lki != 0.0 {
                let (head, tail) = z.data.split_at_mut(k * m);
                let row_i = &mut head[i * m..i * m + m];
                let row_k = &tail[..m];
                for (a, b) in row_i.iter_mut().zip(row_k) {
                    *a -= lki * b;
                }
            }
        }
        let d = l[(i, i)];
        for v in z.row_mut(i) {
            *v /= d;
        }
    }
}

/// Solve Σ·Z = B for symmetric PD Σ via its Cholesky factor L.  One
/// working copy of B, both substitutions in place (the old composition
/// cloned per stage).
pub fn chol_solve_mat(l: &Mat, b: &Mat) -> Mat {
    let mut z = b.clone();
    solve_lower_in_place(l, &mut z);
    solve_upper_in_place(l, &mut z);
    z
}

/// Σ⁻¹ via Cholesky (used by GPTQ's Hessian inverse).
pub fn chol_inverse(a: &Mat) -> Result<Mat, String> {
    let l = cholesky(a)?;
    Ok(chol_solve_mat(&l, &Mat::eye(a.rows)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_pd(seed: u64, n: usize) -> Mat {
        let a = Mat::random_normal(&mut Rng::new(seed), n, n + 3);
        let mut g = a.gram_n();
        g.add_diag(0.5);
        g
    }

    #[test]
    fn chol_reconstructs() {
        for seed in 0..5 {
            let a = random_pd(seed, 8);
            let l = cholesky(&a).unwrap();
            let rec = l.matmul(&l.transpose());
            assert!(a.sub(&rec).max_abs() < 1e-9, "seed {seed}");
            // lower-triangular check
            for i in 0..8 {
                for j in i + 1..8 {
                    assert_eq!(l[(i, j)], 0.0);
                }
            }
        }
    }

    #[test]
    fn not_pd_detected() {
        let mut a = Mat::eye(4);
        a[(2, 2)] = -1.0;
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn solves_roundtrip() {
        for seed in 0..4 {
            let a = random_pd(seed + 10, 9);
            let l = cholesky(&a).unwrap();
            let b = Mat::random_normal(&mut Rng::new(seed + 99), 9, 5);
            let z = chol_solve_mat(&l, &b);
            let back = a.matmul(&z);
            assert!(back.sub(&b).max_abs() < 1e-8);
        }
    }

    #[test]
    fn triangular_solves() {
        let a = random_pd(3, 7);
        let l = cholesky(&a).unwrap();
        let b = Mat::random_normal(&mut Rng::new(5), 7, 3);
        let z = solve_lower(&l, &b);
        assert!(l.matmul(&z).sub(&b).max_abs() < 1e-9);
        let z2 = solve_upper(&l, &b);
        assert!(l.transpose().matmul(&z2).sub(&b).max_abs() < 1e-9);
    }

    #[test]
    fn inverse_property() {
        let a = random_pd(8, 6);
        let inv = chol_inverse(&a).unwrap();
        let prod = a.matmul(&inv);
        assert!(prod.sub(&Mat::eye(6)).max_abs() < 1e-8);
    }
}
