//! Zero-dependency SIMD backends for the GEMM / Gram micro-kernels,
//! under the **bit-identity contract** of [`super::kernels`].
//!
//! # Why vectorizing here is safe at all
//!
//! The canonical-scalar-program contract says every output element is one
//! accumulator advanced in strictly ascending `k` by `c += a·b` — an IEEE
//! mul followed by an IEEE add.  Vectorization that reassociates *within*
//! an element (horizontal sums, k-striped partial accumulators, FMA)
//! would break it.  Vectorization **across output elements** does not:
//! each SIMD lane carries exactly one element's accumulator, and packed
//! `mul` then packed `add` perform the same two correctly-rounded IEEE
//! operations per lane that the scalar program performs.  So the backends
//! below vectorize across the NR output columns (the `j` lanes of the
//! register tile), broadcast `a[i,k]`, and keep mul and add **separate**
//! — no FMA on any path, because a fused multiply-add rounds once instead
//! of twice and would change the bits.  Serial, blocked, parallel and
//! every SIMD backend therefore agree with the naive triple loop `==` on
//! f64 (`tests/kernel_oracle.rs` enforces this per backend).
//!
//! # Backends and dispatch
//!
//! * `scalar` — portable fallback, the reference program itself.
//! * `sse2`   — x86_64 baseline (always present), 2 f64 lanes, 4×4 tile.
//! * `avx2`   — runtime-detected via `is_x86_feature_detected!`, 4 f64
//!              lanes, widened 4×8 tile (two ymm vectors per output row).
//! * `neon`   — aarch64 baseline (always present), 2 f64 lanes, 4×4 tile.
//!
//! The active backend resolves once per kernel call, in priority order:
//!   1. a [`set_backend`] override (the CLI's `--simd` flag; tests and
//!      benches flip it to sweep backends in-process),
//!   2. the `LRC_SIMD` environment variable (`auto|scalar|sse2|avx2|neon`,
//!      parsed once; unavailable/unparsable values warn and fall back to
//!      auto — the CI matrix runs the tier-1 suite under `scalar` and
//!      `auto`),
//!   3. [`detect`]: the widest backend the host supports.
//!
//! Because every backend produces identical bits, flipping the backend
//! between (or even during) operations can never change a result — which
//! is what makes the process-global override safe for concurrent tests.
//!
//! # The opt-in FMA mode (`--fma` / `LRC_FMA=1`, default **off**)
//!
//! A fused multiply-add rounds once where mul-then-add rounds twice, so
//! turning it on **changes the canonical per-element program** — the one
//! thing the default contract promises never changes.  FMA mode is
//! therefore a *different contract with the same shape*: every output
//! element becomes one accumulator advanced in strictly ascending `k` by
//! `acc = fma(a, b, acc)`, and all paths — serial, blocked, chunked,
//! parallel, every backend — are bit-identical to a **lockstep FMA
//! reference** (the naive triple loop with `f64::mul_add`;
//! `tests/kernel_oracle.rs` carries both references and selects by mode).
//! That works because IEEE-754 `fusedMultiplyAdd` is a single
//! correctly-rounded operation: `f64::mul_add`, `_mm256_fmadd_pd` and
//! `vfmaq_f64` all produce the same bits for the same operands.  Backends
//! without a packed FMA instruction (scalar, SSE2, AVX2 on pre-FMA hosts)
//! run the scalar `mul_add` program at their tile width — same bits by
//! the same argument that makes lane-splitting safe in the default mode.
//!
//! The mode is resolved like the backend — [`set_fma`] override (the
//! CLI's `--fma`) > `LRC_FMA` env (read once) > off — and is **captured
//! at pack time** alongside the backend (see `kernels::PackedRows`), so a
//! mid-product flip can never mix the two programs inside one result.
//! Determinism across thread counts holds in both modes for the same
//! reason it holds at all: chunking never touches the per-element
//! program.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Widest register-tile width any backend uses (AVX2's 4×8 tile); sizes
/// stack accumulator buffers in [`super::kernels`].
pub const MAX_NR: usize = 8;

/// Widest **f32** register-tile width (AVX2's 4×16 tile — f32 lanes are
/// twice as wide as f64 at every vector length); sizes the stack
/// accumulators of the f32 tiles in [`super::kernels`] and
/// [`crate::quant::dequant`].
pub const MAX_NR32: usize = 16;

/// A vector instruction set the micro-kernels can dispatch to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Portable scalar reference program.
    Scalar,
    /// x86_64 baseline: 2×f64 `xmm` lanes.
    Sse2,
    /// x86_64 AVX2: 4×f64 `ymm` lanes, widened 4×8 tile.
    Avx2,
    /// aarch64 baseline: 2×f64 NEON lanes.
    Neon,
}

impl Backend {
    /// Every backend, widest last (detection order).
    pub const ALL: [Backend; 4] =
        [Backend::Scalar, Backend::Sse2, Backend::Avx2, Backend::Neon];

    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Sse2 => "sse2",
            Backend::Avx2 => "avx2",
            Backend::Neon => "neon",
        }
    }

    /// Register-tile width NR: output columns advanced per tile.  AVX2
    /// widens to 8 (two ymm accumulators per row) because the extra four
    /// lanes are free once the `a[i,k]` broadcast is paid for; everything
    /// else keeps the scalar tile's 4.
    pub fn nr(self) -> usize {
        match self {
            Backend::Avx2 => 8,
            _ => 4,
        }
    }

    /// f32 register-tile width NR: twice [`Backend::nr`] on every backend,
    /// because each vector register holds twice as many f32 lanes — the
    /// same two-registers-per-output-row shape as the f64 tiles, at
    /// double the element count.
    pub fn nr32(self) -> usize {
        match self {
            Backend::Avx2 => 16,
            _ => 8,
        }
    }

    /// Parse a `--simd` / `LRC_SIMD` value.  `Ok(None)` means `auto`.
    pub fn parse(s: &str) -> Result<Option<Backend>, String> {
        match s {
            "auto" => Ok(None),
            "scalar" => Ok(Some(Backend::Scalar)),
            "sse2" => Ok(Some(Backend::Sse2)),
            "avx2" => Ok(Some(Backend::Avx2)),
            "neon" => Ok(Some(Backend::Neon)),
            other => Err(format!(
                "unknown SIMD backend {other:?} (auto|scalar|sse2|avx2|neon)")),
        }
    }

    /// Whether this backend can run on the current host.
    pub fn available(self) -> bool {
        match self {
            Backend::Scalar => true,
            Backend::Sse2 => cfg!(target_arch = "x86_64"),
            Backend::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            Backend::Neon => cfg!(target_arch = "aarch64"),
        }
    }
}

/// Every backend the current host can run (always contains `Scalar`) —
/// the sweep axis of the kernel oracle and the SIMD benches.
pub fn available_backends() -> Vec<Backend> {
    Backend::ALL.iter().copied().filter(|b| b.available()).collect()
}

/// The widest backend the host supports.
pub fn detect() -> Backend {
    #[cfg(target_arch = "x86_64")]
    {
        if Backend::Avx2.available() {
            Backend::Avx2
        } else {
            Backend::Sse2
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        Backend::Neon
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        Backend::Scalar
    }
}

/// Process-wide override installed by `--simd` (0 = unset, else
/// `1 + index into Backend::ALL`).
static BACKEND_OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// `LRC_SIMD`, parsed once (`None` = unset, `auto`, or rejected).
static ENV_BACKEND: OnceLock<Option<Backend>> = OnceLock::new();

fn encode(b: Backend) -> u8 {
    1 + Backend::ALL.iter().position(|&x| x == b).unwrap() as u8
}

fn decode(code: u8) -> Option<Backend> {
    match code {
        0 => None,
        n => Some(Backend::ALL[(n - 1) as usize]),
    }
}

/// Install a process-wide backend override (the CLI's `--simd` flag, and
/// the sweep knob of the oracle/bench harnesses).  `None` restores auto
/// resolution (env, then detection).  Fails when the requested backend
/// cannot run on this host — the unsafe dispatch below relies on only
/// available backends ever being selected.
pub fn set_backend(b: Option<Backend>) -> Result<(), String> {
    if let Some(b) = b {
        if !b.available() {
            return Err(format!(
                "SIMD backend '{}' is not available on this host \
                 (available: {})",
                b.name(),
                available_backends()
                    .iter()
                    .map(|b| b.name())
                    .collect::<Vec<_>>()
                    .join(", ")));
        }
    }
    BACKEND_OVERRIDE.store(b.map(encode).unwrap_or(0), Ordering::SeqCst);
    Ok(())
}

/// Process-wide FMA-mode override installed by `--fma` (0 = unset,
/// 1 = forced on, 2 = forced off).
static FMA_OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// `LRC_FMA`, parsed once (`true` only for an explicit enable).
static ENV_FMA: OnceLock<bool> = OnceLock::new();

/// Install a process-wide FMA-mode override (the CLI's `--fma` flag, and
/// the sweep knob of the FMA oracle legs / benches).  `None` restores
/// env-then-default resolution.  Unlike backends there is no availability
/// question: every host runs the fused program (via `f64::mul_add` when
/// no packed FMA instruction exists) with identical bits.
pub fn set_fma(mode: Option<bool>) {
    FMA_OVERRIDE.store(match mode {
        None => 0,
        Some(true) => 1,
        Some(false) => 2,
    }, Ordering::SeqCst);
}

/// Resolve the active accumulation program: [`set_fma`] override >
/// `LRC_FMA` env (`1|true|on|yes` enable; anything else — including
/// unset — keeps the default) > **off**.  Consumers capture this once
/// per packed product (`kernels::pack_rows`), never per tile.
pub fn fma_active() -> bool {
    match FMA_OVERRIDE.load(Ordering::SeqCst) {
        1 => return true,
        2 => return false,
        _ => {}
    }
    *ENV_FMA.get_or_init(|| {
        match std::env::var("LRC_FMA").ok().as_deref() {
            Some("1") | Some("true") | Some("on") | Some("yes") => true,
            Some("0") | Some("false") | Some("off") | Some("no") | None => {
                false
            }
            Some(other) => {
                eprintln!("warning: LRC_FMA={other:?} not understood \
                           (1|0|true|false|on|off|yes|no) — FMA stays off");
                false
            }
        }
    })
}

/// Whether the host has a packed FMA instruction for the AVX2 tile
/// (checked once by the std detection cache; pre-FMA AVX2 hosts fall
/// back to the bit-identical scalar `mul_add` program).
#[cfg(target_arch = "x86_64")]
fn fma_hw() -> bool {
    std::arch::is_x86_feature_detected!("fma")
}

/// Resolve the active backend: override > `LRC_SIMD` env > [`detect`].
/// The env var is read exactly once per process; the [`set_backend`]
/// override stays live throughout (mirrors `par::threads`).
pub fn active() -> Backend {
    if let Some(b) = decode(BACKEND_OVERRIDE.load(Ordering::SeqCst)) {
        return b;
    }
    let env = ENV_BACKEND.get_or_init(|| {
        let raw = std::env::var("LRC_SIMD").ok()?;
        match Backend::parse(&raw) {
            Ok(Some(b)) if b.available() => Some(b),
            Ok(Some(b)) => {
                eprintln!("warning: LRC_SIMD={} is not available on this \
                           host — falling back to auto ({})",
                          b.name(), detect().name());
                None
            }
            Ok(None) => None,
            Err(e) => {
                eprintln!("warning: LRC_SIMD: {e} — falling back to auto");
                None
            }
        }
    });
    env.unwrap_or_else(detect)
}

// ---------------------------------------------------------------------------
// Micro-kernel dispatch.
//
// Both entry points operate on one packed B strip: `bp[kk*nr + l]` holds
// `B[j0+l, k0+kk]` (zero for padded lanes past the matrix edge), so the
// inner loop's B access is a single contiguous vector load per k step.
// `acc[r*nr + l]` is the accumulator of output element (row r, lane l);
// callers preload it from C and store the valid lanes back, which keeps
// every element on one k-panel-spanning ascending-k chain.
// ---------------------------------------------------------------------------

/// Four-row register tile.  With `fma` false (the default contract):
/// `acc[r*nr + l] += a[r][kk] · bp[kk*nr + l]` for `kk` ascending —
/// separate mul then add per lane, never fused.  With `fma` true (the
/// opt-in mode, captured at pack time): the same chain advanced by one
/// fused `mul_add` per step — bit-identical to the lockstep FMA
/// reference on every backend.
pub(crate) fn tile4(be: Backend, fma: bool, a: [&[f64]; 4], bp: &[f64],
                    acc: &mut [f64]) {
    debug_assert_eq!(bp.len(), a[0].len() * be.nr());
    debug_assert_eq!(acc.len(), 4 * be.nr());
    if fma {
        return tile4_fma(be, a, bp, acc);
    }
    match be {
        Backend::Scalar => tile4_scalar(a, bp, acc, 4),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Sse2/Avx2 are only ever selected when `available()`
        // held (set_backend validates; detect/env only yield available
        // backends), so the required target features are present.
        Backend::Sse2 => unsafe { tile4_sse2(a, bp, acc) },
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { tile4_avx2(a, bp, acc) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        Backend::Neon => unsafe { tile4_neon(a, bp, acc) },
        // A backend the current arch doesn't implement (defensive; the
        // selectors never produce one): run the scalar program at the
        // same nr — identical bits by contract.
        other => tile4_scalar(a, bp, acc, other.nr()),
    }
}

/// Single-row tile (ragged row edges, and the Gram row-segment kernel):
/// `acc[l] += a[kk] · bp[kk*nr + l]` for `kk` ascending (one fused
/// `mul_add` per step in FMA mode).
pub(crate) fn tile1(be: Backend, fma: bool, a: &[f64], bp: &[f64],
                    acc: &mut [f64]) {
    debug_assert_eq!(bp.len(), a.len() * be.nr());
    debug_assert_eq!(acc.len(), be.nr());
    if fma {
        return tile1_fma(be, a, bp, acc);
    }
    match be {
        Backend::Scalar => tile1_scalar(a, bp, acc, 4),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see tile4 — only available backends are selectable.
        Backend::Sse2 => unsafe { tile1_sse2(a, bp, acc) },
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { tile1_avx2(a, bp, acc) },
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { tile1_neon(a, bp, acc) },
        other => tile1_scalar(a, bp, acc, other.nr()),
    }
}

/// FMA-mode tile4 dispatch.  Backends without a packed FMA run the
/// scalar `f64::mul_add` program at their own tile width — the same
/// correctly-rounded operation, therefore the same bits.
fn tile4_fma(be: Backend, a: [&[f64]; 4], bp: &[f64], acc: &mut [f64]) {
    match be {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 if fma_hw() =>
            // SAFETY: avx2 selectable ⇒ available; fma_hw() just checked.
            unsafe { tile4_avx2_fma(a, bp, acc) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON (incl. fused vfmaq) is baseline on aarch64.
        Backend::Neon => unsafe { tile4_neon_fma(a, bp, acc) },
        other => tile4_scalar_fma(a, bp, acc, other.nr()),
    }
}

/// FMA-mode tile1 dispatch (see [`tile4_fma`]).
fn tile1_fma(be: Backend, a: &[f64], bp: &[f64], acc: &mut [f64]) {
    match be {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: avx2 selectable ⇒ available; fma_hw() just checked.
        Backend::Avx2 if fma_hw() => unsafe { tile1_avx2_fma(a, bp, acc) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON (incl. fused vfmaq) is baseline on aarch64.
        Backend::Neon => unsafe { tile1_neon_fma(a, bp, acc) },
        other => tile1_scalar_fma(a, bp, acc, other.nr()),
    }
}

// --- scalar reference ------------------------------------------------------

fn tile4_scalar(a: [&[f64]; 4], bp: &[f64], acc: &mut [f64], nr: usize) {
    let kw = a[0].len();
    for kk in 0..kw {
        let y = &bp[kk * nr..(kk + 1) * nr];
        for r in 0..4 {
            let x = a[r][kk];
            let row = &mut acc[r * nr..(r + 1) * nr];
            for l in 0..nr {
                row[l] += x * y[l];
            }
        }
    }
}

fn tile1_scalar(a: &[f64], bp: &[f64], acc: &mut [f64], nr: usize) {
    for (kk, &x) in a.iter().enumerate() {
        let y = &bp[kk * nr..(kk + 1) * nr];
        for l in 0..nr {
            acc[l] += x * y[l];
        }
    }
}

// --- FMA-mode scalar reference (f64::mul_add = IEEE fusedMultiplyAdd,
//     bit-identical to every hardware FMA below) ------------------------------

fn tile4_scalar_fma(a: [&[f64]; 4], bp: &[f64], acc: &mut [f64], nr: usize) {
    let kw = a[0].len();
    for kk in 0..kw {
        let y = &bp[kk * nr..(kk + 1) * nr];
        for r in 0..4 {
            let x = a[r][kk];
            let row = &mut acc[r * nr..(r + 1) * nr];
            for l in 0..nr {
                row[l] = x.mul_add(y[l], row[l]);
            }
        }
    }
}

fn tile1_scalar_fma(a: &[f64], bp: &[f64], acc: &mut [f64], nr: usize) {
    for (kk, &x) in a.iter().enumerate() {
        let y = &bp[kk * nr..(kk + 1) * nr];
        for l in 0..nr {
            acc[l] = x.mul_add(y[l], acc[l]);
        }
    }
}

// --- x86_64: SSE2 (baseline) and AVX2 (runtime-detected) -------------------

// SAFETY (callers): the `sse2` target feature(s) must be enabled, and
// the slice-length contract of the safe dispatch wrapper must hold
// (it debug_asserts `bp`/`acc` against the tile geometry).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
unsafe fn tile4_sse2(a: [&[f64]; 4], bp: &[f64], acc: &mut [f64]) {
    // SAFETY: the dispatcher established the target feature, and all
    // raw loads/stores below stay inside `bp`/`acc` per the length
    // contract debug_asserted by the safe wrapper; the unaligned
    // intrinsics carry no alignment requirement.
    unsafe {
        use core::arch::x86_64::*;
        const NR: usize = 4;
        let kw = a[0].len();
        let (a0, a1, a2, a3) = (a[0], a[1], a[2], a[3]);
        let p = acc.as_mut_ptr();
        let mut c00 = _mm_loadu_pd(p);
        let mut c01 = _mm_loadu_pd(p.add(2));
        let mut c10 = _mm_loadu_pd(p.add(4));
        let mut c11 = _mm_loadu_pd(p.add(6));
        let mut c20 = _mm_loadu_pd(p.add(8));
        let mut c21 = _mm_loadu_pd(p.add(10));
        let mut c30 = _mm_loadu_pd(p.add(12));
        let mut c31 = _mm_loadu_pd(p.add(14));
        let bpp = bp.as_ptr();
        for kk in 0..kw {
            let y0 = _mm_loadu_pd(bpp.add(kk * NR));
            let y1 = _mm_loadu_pd(bpp.add(kk * NR + 2));
            let x0 = _mm_set1_pd(a0[kk]);
            c00 = _mm_add_pd(c00, _mm_mul_pd(x0, y0));
            c01 = _mm_add_pd(c01, _mm_mul_pd(x0, y1));
            let x1 = _mm_set1_pd(a1[kk]);
            c10 = _mm_add_pd(c10, _mm_mul_pd(x1, y0));
            c11 = _mm_add_pd(c11, _mm_mul_pd(x1, y1));
            let x2 = _mm_set1_pd(a2[kk]);
            c20 = _mm_add_pd(c20, _mm_mul_pd(x2, y0));
            c21 = _mm_add_pd(c21, _mm_mul_pd(x2, y1));
            let x3 = _mm_set1_pd(a3[kk]);
            c30 = _mm_add_pd(c30, _mm_mul_pd(x3, y0));
            c31 = _mm_add_pd(c31, _mm_mul_pd(x3, y1));
        }
        _mm_storeu_pd(p, c00);
        _mm_storeu_pd(p.add(2), c01);
        _mm_storeu_pd(p.add(4), c10);
        _mm_storeu_pd(p.add(6), c11);
        _mm_storeu_pd(p.add(8), c20);
        _mm_storeu_pd(p.add(10), c21);
        _mm_storeu_pd(p.add(12), c30);
        _mm_storeu_pd(p.add(14), c31);
    }
}

// SAFETY (callers): the `sse2` target feature(s) must be enabled, and
// the slice-length contract of the safe dispatch wrapper must hold
// (it debug_asserts `bp`/`acc` against the tile geometry).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
unsafe fn tile1_sse2(a: &[f64], bp: &[f64], acc: &mut [f64]) {
    // SAFETY: the dispatcher established the target feature, and all
    // raw loads/stores below stay inside `bp`/`acc` per the length
    // contract debug_asserted by the safe wrapper; the unaligned
    // intrinsics carry no alignment requirement.
    unsafe {
        use core::arch::x86_64::*;
        const NR: usize = 4;
        let p = acc.as_mut_ptr();
        let mut c0 = _mm_loadu_pd(p);
        let mut c1 = _mm_loadu_pd(p.add(2));
        let bpp = bp.as_ptr();
        for (kk, &xv) in a.iter().enumerate() {
            let x = _mm_set1_pd(xv);
            let y0 = _mm_loadu_pd(bpp.add(kk * NR));
            let y1 = _mm_loadu_pd(bpp.add(kk * NR + 2));
            c0 = _mm_add_pd(c0, _mm_mul_pd(x, y0));
            c1 = _mm_add_pd(c1, _mm_mul_pd(x, y1));
        }
        _mm_storeu_pd(p, c0);
        _mm_storeu_pd(p.add(2), c1);
    }
}

// SAFETY (callers): the `avx2` target feature(s) must be enabled, and
// the slice-length contract of the safe dispatch wrapper must hold
// (it debug_asserts `bp`/`acc` against the tile geometry).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn tile4_avx2(a: [&[f64]; 4], bp: &[f64], acc: &mut [f64]) {
    // SAFETY: the dispatcher established the target feature, and all
    // raw loads/stores below stay inside `bp`/`acc` per the length
    // contract debug_asserted by the safe wrapper; the unaligned
    // intrinsics carry no alignment requirement.
    unsafe {
        use core::arch::x86_64::*;
        const NR: usize = 8;
        let kw = a[0].len();
        let (a0, a1, a2, a3) = (a[0], a[1], a[2], a[3]);
        let p = acc.as_mut_ptr();
        let mut c00 = _mm256_loadu_pd(p);
        let mut c01 = _mm256_loadu_pd(p.add(4));
        let mut c10 = _mm256_loadu_pd(p.add(8));
        let mut c11 = _mm256_loadu_pd(p.add(12));
        let mut c20 = _mm256_loadu_pd(p.add(16));
        let mut c21 = _mm256_loadu_pd(p.add(20));
        let mut c30 = _mm256_loadu_pd(p.add(24));
        let mut c31 = _mm256_loadu_pd(p.add(28));
        let bpp = bp.as_ptr();
        for kk in 0..kw {
            let y0 = _mm256_loadu_pd(bpp.add(kk * NR));
            let y1 = _mm256_loadu_pd(bpp.add(kk * NR + 4));
            // mul then add, never _mm256_fmadd_pd: FMA's single rounding
            // would diverge from the canonical scalar program.
            let x0 = _mm256_set1_pd(a0[kk]);
            c00 = _mm256_add_pd(c00, _mm256_mul_pd(x0, y0));
            c01 = _mm256_add_pd(c01, _mm256_mul_pd(x0, y1));
            let x1 = _mm256_set1_pd(a1[kk]);
            c10 = _mm256_add_pd(c10, _mm256_mul_pd(x1, y0));
            c11 = _mm256_add_pd(c11, _mm256_mul_pd(x1, y1));
            let x2 = _mm256_set1_pd(a2[kk]);
            c20 = _mm256_add_pd(c20, _mm256_mul_pd(x2, y0));
            c21 = _mm256_add_pd(c21, _mm256_mul_pd(x2, y1));
            let x3 = _mm256_set1_pd(a3[kk]);
            c30 = _mm256_add_pd(c30, _mm256_mul_pd(x3, y0));
            c31 = _mm256_add_pd(c31, _mm256_mul_pd(x3, y1));
        }
        _mm256_storeu_pd(p, c00);
        _mm256_storeu_pd(p.add(4), c01);
        _mm256_storeu_pd(p.add(8), c10);
        _mm256_storeu_pd(p.add(12), c11);
        _mm256_storeu_pd(p.add(16), c20);
        _mm256_storeu_pd(p.add(20), c21);
        _mm256_storeu_pd(p.add(24), c30);
        _mm256_storeu_pd(p.add(28), c31);
    }
}

// SAFETY (callers): the `avx2` target feature(s) must be enabled, and
// the slice-length contract of the safe dispatch wrapper must hold
// (it debug_asserts `bp`/`acc` against the tile geometry).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn tile1_avx2(a: &[f64], bp: &[f64], acc: &mut [f64]) {
    // SAFETY: the dispatcher established the target feature, and all
    // raw loads/stores below stay inside `bp`/`acc` per the length
    // contract debug_asserted by the safe wrapper; the unaligned
    // intrinsics carry no alignment requirement.
    unsafe {
        use core::arch::x86_64::*;
        const NR: usize = 8;
        let p = acc.as_mut_ptr();
        let mut c0 = _mm256_loadu_pd(p);
        let mut c1 = _mm256_loadu_pd(p.add(4));
        let bpp = bp.as_ptr();
        for (kk, &xv) in a.iter().enumerate() {
            let x = _mm256_set1_pd(xv);
            let y0 = _mm256_loadu_pd(bpp.add(kk * NR));
            let y1 = _mm256_loadu_pd(bpp.add(kk * NR + 4));
            c0 = _mm256_add_pd(c0, _mm256_mul_pd(x, y0));
            c1 = _mm256_add_pd(c1, _mm256_mul_pd(x, y1));
        }
        _mm256_storeu_pd(p, c0);
        _mm256_storeu_pd(p.add(4), c1);
    }
}

// SAFETY (callers): the `avx2` + `fma` target feature(s) must be enabled, and
// the slice-length contract of the safe dispatch wrapper must hold
// (it debug_asserts `bp`/`acc` against the tile geometry).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn tile4_avx2_fma(a: [&[f64]; 4], bp: &[f64], acc: &mut [f64]) {
    // SAFETY: the dispatcher established the target feature, and all
    // raw loads/stores below stay inside `bp`/`acc` per the length
    // contract debug_asserted by the safe wrapper; the unaligned
    // intrinsics carry no alignment requirement.
    unsafe {
        use core::arch::x86_64::*;
        const NR: usize = 8;
        let kw = a[0].len();
        let (a0, a1, a2, a3) = (a[0], a[1], a[2], a[3]);
        let p = acc.as_mut_ptr();
        let mut c00 = _mm256_loadu_pd(p);
        let mut c01 = _mm256_loadu_pd(p.add(4));
        let mut c10 = _mm256_loadu_pd(p.add(8));
        let mut c11 = _mm256_loadu_pd(p.add(12));
        let mut c20 = _mm256_loadu_pd(p.add(16));
        let mut c21 = _mm256_loadu_pd(p.add(20));
        let mut c30 = _mm256_loadu_pd(p.add(24));
        let mut c31 = _mm256_loadu_pd(p.add(28));
        let bpp = bp.as_ptr();
        for kk in 0..kw {
            let y0 = _mm256_loadu_pd(bpp.add(kk * NR));
            let y1 = _mm256_loadu_pd(bpp.add(kk * NR + 4));
            // the FMA-mode program: one correctly-rounded fused op per step
            let x0 = _mm256_set1_pd(a0[kk]);
            c00 = _mm256_fmadd_pd(x0, y0, c00);
            c01 = _mm256_fmadd_pd(x0, y1, c01);
            let x1 = _mm256_set1_pd(a1[kk]);
            c10 = _mm256_fmadd_pd(x1, y0, c10);
            c11 = _mm256_fmadd_pd(x1, y1, c11);
            let x2 = _mm256_set1_pd(a2[kk]);
            c20 = _mm256_fmadd_pd(x2, y0, c20);
            c21 = _mm256_fmadd_pd(x2, y1, c21);
            let x3 = _mm256_set1_pd(a3[kk]);
            c30 = _mm256_fmadd_pd(x3, y0, c30);
            c31 = _mm256_fmadd_pd(x3, y1, c31);
        }
        _mm256_storeu_pd(p, c00);
        _mm256_storeu_pd(p.add(4), c01);
        _mm256_storeu_pd(p.add(8), c10);
        _mm256_storeu_pd(p.add(12), c11);
        _mm256_storeu_pd(p.add(16), c20);
        _mm256_storeu_pd(p.add(20), c21);
        _mm256_storeu_pd(p.add(24), c30);
        _mm256_storeu_pd(p.add(28), c31);
    }
}

// SAFETY (callers): the `avx2` + `fma` target feature(s) must be enabled, and
// the slice-length contract of the safe dispatch wrapper must hold
// (it debug_asserts `bp`/`acc` against the tile geometry).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn tile1_avx2_fma(a: &[f64], bp: &[f64], acc: &mut [f64]) {
    // SAFETY: the dispatcher established the target feature, and all
    // raw loads/stores below stay inside `bp`/`acc` per the length
    // contract debug_asserted by the safe wrapper; the unaligned
    // intrinsics carry no alignment requirement.
    unsafe {
        use core::arch::x86_64::*;
        const NR: usize = 8;
        let p = acc.as_mut_ptr();
        let mut c0 = _mm256_loadu_pd(p);
        let mut c1 = _mm256_loadu_pd(p.add(4));
        let bpp = bp.as_ptr();
        for (kk, &xv) in a.iter().enumerate() {
            let x = _mm256_set1_pd(xv);
            let y0 = _mm256_loadu_pd(bpp.add(kk * NR));
            let y1 = _mm256_loadu_pd(bpp.add(kk * NR + 4));
            c0 = _mm256_fmadd_pd(x, y0, c0);
            c1 = _mm256_fmadd_pd(x, y1, c1);
        }
        _mm256_storeu_pd(p, c0);
        _mm256_storeu_pd(p.add(4), c1);
    }
}

// --- aarch64: NEON (baseline) ----------------------------------------------

// SAFETY (callers): the `neon` target feature(s) must be enabled, and
// the slice-length contract of the safe dispatch wrapper must hold
// (it debug_asserts `bp`/`acc` against the tile geometry).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn tile4_neon(a: [&[f64]; 4], bp: &[f64], acc: &mut [f64]) {
    // SAFETY: the dispatcher established the target feature, and all
    // raw loads/stores below stay inside `bp`/`acc` per the length
    // contract debug_asserted by the safe wrapper; the unaligned
    // intrinsics carry no alignment requirement.
    unsafe {
        use core::arch::aarch64::*;
        const NR: usize = 4;
        let kw = a[0].len();
        let (a0, a1, a2, a3) = (a[0], a[1], a[2], a[3]);
        let p = acc.as_mut_ptr();
        let mut c00 = vld1q_f64(p);
        let mut c01 = vld1q_f64(p.add(2));
        let mut c10 = vld1q_f64(p.add(4));
        let mut c11 = vld1q_f64(p.add(6));
        let mut c20 = vld1q_f64(p.add(8));
        let mut c21 = vld1q_f64(p.add(10));
        let mut c30 = vld1q_f64(p.add(12));
        let mut c31 = vld1q_f64(p.add(14));
        let bpp = bp.as_ptr();
        for kk in 0..kw {
            let y0 = vld1q_f64(bpp.add(kk * NR));
            let y1 = vld1q_f64(bpp.add(kk * NR + 2));
            // vmulq + vaddq, never vfmaq: keep the two-rounding scalar program
            let x0 = vdupq_n_f64(a0[kk]);
            c00 = vaddq_f64(c00, vmulq_f64(x0, y0));
            c01 = vaddq_f64(c01, vmulq_f64(x0, y1));
            let x1 = vdupq_n_f64(a1[kk]);
            c10 = vaddq_f64(c10, vmulq_f64(x1, y0));
            c11 = vaddq_f64(c11, vmulq_f64(x1, y1));
            let x2 = vdupq_n_f64(a2[kk]);
            c20 = vaddq_f64(c20, vmulq_f64(x2, y0));
            c21 = vaddq_f64(c21, vmulq_f64(x2, y1));
            let x3 = vdupq_n_f64(a3[kk]);
            c30 = vaddq_f64(c30, vmulq_f64(x3, y0));
            c31 = vaddq_f64(c31, vmulq_f64(x3, y1));
        }
        vst1q_f64(p, c00);
        vst1q_f64(p.add(2), c01);
        vst1q_f64(p.add(4), c10);
        vst1q_f64(p.add(6), c11);
        vst1q_f64(p.add(8), c20);
        vst1q_f64(p.add(10), c21);
        vst1q_f64(p.add(12), c30);
        vst1q_f64(p.add(14), c31);
    }
}

// SAFETY (callers): the `neon` target feature(s) must be enabled, and
// the slice-length contract of the safe dispatch wrapper must hold
// (it debug_asserts `bp`/`acc` against the tile geometry).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn tile1_neon(a: &[f64], bp: &[f64], acc: &mut [f64]) {
    // SAFETY: the dispatcher established the target feature, and all
    // raw loads/stores below stay inside `bp`/`acc` per the length
    // contract debug_asserted by the safe wrapper; the unaligned
    // intrinsics carry no alignment requirement.
    unsafe {
        use core::arch::aarch64::*;
        const NR: usize = 4;
        let p = acc.as_mut_ptr();
        let mut c0 = vld1q_f64(p);
        let mut c1 = vld1q_f64(p.add(2));
        let bpp = bp.as_ptr();
        for (kk, &xv) in a.iter().enumerate() {
            let x = vdupq_n_f64(xv);
            let y0 = vld1q_f64(bpp.add(kk * NR));
            let y1 = vld1q_f64(bpp.add(kk * NR + 2));
            c0 = vaddq_f64(c0, vmulq_f64(x, y0));
            c1 = vaddq_f64(c1, vmulq_f64(x, y1));
        }
        vst1q_f64(p, c0);
        vst1q_f64(p.add(2), c1);
    }
}

// SAFETY (callers): the `neon` target feature(s) must be enabled, and
// the slice-length contract of the safe dispatch wrapper must hold
// (it debug_asserts `bp`/`acc` against the tile geometry).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn tile4_neon_fma(a: [&[f64]; 4], bp: &[f64], acc: &mut [f64]) {
    // SAFETY: the dispatcher established the target feature, and all
    // raw loads/stores below stay inside `bp`/`acc` per the length
    // contract debug_asserted by the safe wrapper; the unaligned
    // intrinsics carry no alignment requirement.
    unsafe {
        use core::arch::aarch64::*;
        const NR: usize = 4;
        let kw = a[0].len();
        let (a0, a1, a2, a3) = (a[0], a[1], a[2], a[3]);
        let p = acc.as_mut_ptr();
        let mut c00 = vld1q_f64(p);
        let mut c01 = vld1q_f64(p.add(2));
        let mut c10 = vld1q_f64(p.add(4));
        let mut c11 = vld1q_f64(p.add(6));
        let mut c20 = vld1q_f64(p.add(8));
        let mut c21 = vld1q_f64(p.add(10));
        let mut c30 = vld1q_f64(p.add(12));
        let mut c31 = vld1q_f64(p.add(14));
        let bpp = bp.as_ptr();
        for kk in 0..kw {
            let y0 = vld1q_f64(bpp.add(kk * NR));
            let y1 = vld1q_f64(bpp.add(kk * NR + 2));
            // vfmaq_f64(acc, x, y) = acc + x·y, fused — the FMA-mode program
            let x0 = vdupq_n_f64(a0[kk]);
            c00 = vfmaq_f64(c00, x0, y0);
            c01 = vfmaq_f64(c01, x0, y1);
            let x1 = vdupq_n_f64(a1[kk]);
            c10 = vfmaq_f64(c10, x1, y0);
            c11 = vfmaq_f64(c11, x1, y1);
            let x2 = vdupq_n_f64(a2[kk]);
            c20 = vfmaq_f64(c20, x2, y0);
            c21 = vfmaq_f64(c21, x2, y1);
            let x3 = vdupq_n_f64(a3[kk]);
            c30 = vfmaq_f64(c30, x3, y0);
            c31 = vfmaq_f64(c31, x3, y1);
        }
        vst1q_f64(p, c00);
        vst1q_f64(p.add(2), c01);
        vst1q_f64(p.add(4), c10);
        vst1q_f64(p.add(6), c11);
        vst1q_f64(p.add(8), c20);
        vst1q_f64(p.add(10), c21);
        vst1q_f64(p.add(12), c30);
        vst1q_f64(p.add(14), c31);
    }
}

// SAFETY (callers): the `neon` target feature(s) must be enabled, and
// the slice-length contract of the safe dispatch wrapper must hold
// (it debug_asserts `bp`/`acc` against the tile geometry).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn tile1_neon_fma(a: &[f64], bp: &[f64], acc: &mut [f64]) {
    // SAFETY: the dispatcher established the target feature, and all
    // raw loads/stores below stay inside `bp`/`acc` per the length
    // contract debug_asserted by the safe wrapper; the unaligned
    // intrinsics carry no alignment requirement.
    unsafe {
        use core::arch::aarch64::*;
        const NR: usize = 4;
        let p = acc.as_mut_ptr();
        let mut c0 = vld1q_f64(p);
        let mut c1 = vld1q_f64(p.add(2));
        let bpp = bp.as_ptr();
        for (kk, &xv) in a.iter().enumerate() {
            let x = vdupq_n_f64(xv);
            let y0 = vld1q_f64(bpp.add(kk * NR));
            let y1 = vld1q_f64(bpp.add(kk * NR + 2));
            c0 = vfmaq_f64(c0, x, y0);
            c1 = vfmaq_f64(c1, x, y1);
        }
        vst1q_f64(p, c0);
        vst1q_f64(p.add(2), c1);
    }
}

// ---------------------------------------------------------------------------
// f32 micro-kernels — the same canonical program, twice the lane width.
//
// The bit-identity argument is precision-agnostic: one accumulator per
// output element, strictly ascending k, separate IEEE mul then add per
// step (or one fused `mul_add` per step in FMA mode).  f32 lanes simply
// pack twice as many elements per vector register, so `nr32 = 2·nr` and
// the tile shape (two registers per output row) carries over unchanged.
// These feed the fused dequant-GEMM data path (`quant::dequant`), whose
// reference is the naive unpack-then-matmul f32 triple loop.
// ---------------------------------------------------------------------------

/// Four-row f32 register tile: `acc[r*nr32 + l] += a[r][kk] · bp[kk*nr32
/// + l]` for `kk` ascending (one fused `mul_add` per step in FMA mode).
pub(crate) fn tile4_f32(be: Backend, fma: bool, a: [&[f32]; 4], bp: &[f32],
                        acc: &mut [f32]) {
    debug_assert_eq!(bp.len(), a[0].len() * be.nr32());
    debug_assert_eq!(acc.len(), 4 * be.nr32());
    if fma {
        return tile4_f32_fma(be, a, bp, acc);
    }
    match be {
        Backend::Scalar => tile4_f32_scalar(a, bp, acc, 8),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Sse2/Avx2 are only ever selected when `available()`
        // held (set_backend validates; detect/env only yield available
        // backends), so the required target features are present.
        Backend::Sse2 => unsafe { tile4_f32_sse2(a, bp, acc) },
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { tile4_f32_avx2(a, bp, acc) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        Backend::Neon => unsafe { tile4_f32_neon(a, bp, acc) },
        other => tile4_f32_scalar(a, bp, acc, other.nr32()),
    }
}

/// Single-row f32 tile (ragged row edges): `acc[l] += a[kk] · bp[kk*nr32
/// + l]` for `kk` ascending.
pub(crate) fn tile1_f32(be: Backend, fma: bool, a: &[f32], bp: &[f32],
                        acc: &mut [f32]) {
    debug_assert_eq!(bp.len(), a.len() * be.nr32());
    debug_assert_eq!(acc.len(), be.nr32());
    if fma {
        return tile1_f32_fma(be, a, bp, acc);
    }
    match be {
        Backend::Scalar => tile1_f32_scalar(a, bp, acc, 8),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: see tile4_f32 — only available backends are selectable.
        Backend::Sse2 => unsafe { tile1_f32_sse2(a, bp, acc) },
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { tile1_f32_avx2(a, bp, acc) },
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { tile1_f32_neon(a, bp, acc) },
        other => tile1_f32_scalar(a, bp, acc, other.nr32()),
    }
}

/// FMA-mode f32 tile4 dispatch: backends without a packed f32 FMA run
/// the scalar `f32::mul_add` program at their own tile width (same
/// correctly-rounded operation, same bits).
fn tile4_f32_fma(be: Backend, a: [&[f32]; 4], bp: &[f32], acc: &mut [f32]) {
    match be {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 if fma_hw() =>
            // SAFETY: avx2 selectable ⇒ available; fma_hw() just checked.
            unsafe { tile4_f32_avx2_fma(a, bp, acc) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON (incl. fused vfmaq) is baseline on aarch64.
        Backend::Neon => unsafe { tile4_f32_neon_fma(a, bp, acc) },
        other => tile4_f32_scalar_fma(a, bp, acc, other.nr32()),
    }
}

/// FMA-mode f32 tile1 dispatch (see [`tile4_f32_fma`]).
fn tile1_f32_fma(be: Backend, a: &[f32], bp: &[f32], acc: &mut [f32]) {
    match be {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: avx2 selectable ⇒ available; fma_hw() just checked.
        Backend::Avx2 if fma_hw() => unsafe {
            tile1_f32_avx2_fma(a, bp, acc)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON (incl. fused vfmaq) is baseline on aarch64.
        Backend::Neon => unsafe { tile1_f32_neon_fma(a, bp, acc) },
        other => tile1_f32_scalar_fma(a, bp, acc, other.nr32()),
    }
}

// --- f32 scalar reference ----------------------------------------------------

fn tile4_f32_scalar(a: [&[f32]; 4], bp: &[f32], acc: &mut [f32], nr: usize) {
    let kw = a[0].len();
    for kk in 0..kw {
        let y = &bp[kk * nr..(kk + 1) * nr];
        for r in 0..4 {
            let x = a[r][kk];
            let row = &mut acc[r * nr..(r + 1) * nr];
            for l in 0..nr {
                row[l] += x * y[l];
            }
        }
    }
}

fn tile1_f32_scalar(a: &[f32], bp: &[f32], acc: &mut [f32], nr: usize) {
    for (kk, &x) in a.iter().enumerate() {
        let y = &bp[kk * nr..(kk + 1) * nr];
        for l in 0..nr {
            acc[l] += x * y[l];
        }
    }
}

fn tile4_f32_scalar_fma(a: [&[f32]; 4], bp: &[f32], acc: &mut [f32],
                        nr: usize) {
    let kw = a[0].len();
    for kk in 0..kw {
        let y = &bp[kk * nr..(kk + 1) * nr];
        for r in 0..4 {
            let x = a[r][kk];
            let row = &mut acc[r * nr..(r + 1) * nr];
            for l in 0..nr {
                row[l] = x.mul_add(y[l], row[l]);
            }
        }
    }
}

fn tile1_f32_scalar_fma(a: &[f32], bp: &[f32], acc: &mut [f32], nr: usize) {
    for (kk, &x) in a.iter().enumerate() {
        let y = &bp[kk * nr..(kk + 1) * nr];
        for l in 0..nr {
            acc[l] = x.mul_add(y[l], acc[l]);
        }
    }
}

// --- f32 x86_64: SSE2 (baseline) and AVX2 (runtime-detected) ---------------

// SAFETY (callers): the `sse2` target feature(s) must be enabled, and
// the slice-length contract of the safe dispatch wrapper must hold
// (it debug_asserts `bp`/`acc` against the tile geometry).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
unsafe fn tile4_f32_sse2(a: [&[f32]; 4], bp: &[f32], acc: &mut [f32]) {
    // SAFETY: the dispatcher established the target feature, and all
    // raw loads/stores below stay inside `bp`/`acc` per the length
    // contract debug_asserted by the safe wrapper; the unaligned
    // intrinsics carry no alignment requirement.
    unsafe {
        use core::arch::x86_64::*;
        const NR: usize = 8;
        let kw = a[0].len();
        let (a0, a1, a2, a3) = (a[0], a[1], a[2], a[3]);
        let p = acc.as_mut_ptr();
        let mut c00 = _mm_loadu_ps(p);
        let mut c01 = _mm_loadu_ps(p.add(4));
        let mut c10 = _mm_loadu_ps(p.add(8));
        let mut c11 = _mm_loadu_ps(p.add(12));
        let mut c20 = _mm_loadu_ps(p.add(16));
        let mut c21 = _mm_loadu_ps(p.add(20));
        let mut c30 = _mm_loadu_ps(p.add(24));
        let mut c31 = _mm_loadu_ps(p.add(28));
        let bpp = bp.as_ptr();
        for kk in 0..kw {
            let y0 = _mm_loadu_ps(bpp.add(kk * NR));
            let y1 = _mm_loadu_ps(bpp.add(kk * NR + 4));
            let x0 = _mm_set1_ps(a0[kk]);
            c00 = _mm_add_ps(c00, _mm_mul_ps(x0, y0));
            c01 = _mm_add_ps(c01, _mm_mul_ps(x0, y1));
            let x1 = _mm_set1_ps(a1[kk]);
            c10 = _mm_add_ps(c10, _mm_mul_ps(x1, y0));
            c11 = _mm_add_ps(c11, _mm_mul_ps(x1, y1));
            let x2 = _mm_set1_ps(a2[kk]);
            c20 = _mm_add_ps(c20, _mm_mul_ps(x2, y0));
            c21 = _mm_add_ps(c21, _mm_mul_ps(x2, y1));
            let x3 = _mm_set1_ps(a3[kk]);
            c30 = _mm_add_ps(c30, _mm_mul_ps(x3, y0));
            c31 = _mm_add_ps(c31, _mm_mul_ps(x3, y1));
        }
        _mm_storeu_ps(p, c00);
        _mm_storeu_ps(p.add(4), c01);
        _mm_storeu_ps(p.add(8), c10);
        _mm_storeu_ps(p.add(12), c11);
        _mm_storeu_ps(p.add(16), c20);
        _mm_storeu_ps(p.add(20), c21);
        _mm_storeu_ps(p.add(24), c30);
        _mm_storeu_ps(p.add(28), c31);
    }
}

// SAFETY (callers): the `sse2` target feature(s) must be enabled, and
// the slice-length contract of the safe dispatch wrapper must hold
// (it debug_asserts `bp`/`acc` against the tile geometry).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
unsafe fn tile1_f32_sse2(a: &[f32], bp: &[f32], acc: &mut [f32]) {
    // SAFETY: the dispatcher established the target feature, and all
    // raw loads/stores below stay inside `bp`/`acc` per the length
    // contract debug_asserted by the safe wrapper; the unaligned
    // intrinsics carry no alignment requirement.
    unsafe {
        use core::arch::x86_64::*;
        const NR: usize = 8;
        let p = acc.as_mut_ptr();
        let mut c0 = _mm_loadu_ps(p);
        let mut c1 = _mm_loadu_ps(p.add(4));
        let bpp = bp.as_ptr();
        for (kk, &xv) in a.iter().enumerate() {
            let x = _mm_set1_ps(xv);
            let y0 = _mm_loadu_ps(bpp.add(kk * NR));
            let y1 = _mm_loadu_ps(bpp.add(kk * NR + 4));
            c0 = _mm_add_ps(c0, _mm_mul_ps(x, y0));
            c1 = _mm_add_ps(c1, _mm_mul_ps(x, y1));
        }
        _mm_storeu_ps(p, c0);
        _mm_storeu_ps(p.add(4), c1);
    }
}

// SAFETY (callers): the `avx2` target feature(s) must be enabled, and
// the slice-length contract of the safe dispatch wrapper must hold
// (it debug_asserts `bp`/`acc` against the tile geometry).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn tile4_f32_avx2(a: [&[f32]; 4], bp: &[f32], acc: &mut [f32]) {
    // SAFETY: the dispatcher established the target feature, and all
    // raw loads/stores below stay inside `bp`/`acc` per the length
    // contract debug_asserted by the safe wrapper; the unaligned
    // intrinsics carry no alignment requirement.
    unsafe {
        use core::arch::x86_64::*;
        const NR: usize = 16;
        let kw = a[0].len();
        let (a0, a1, a2, a3) = (a[0], a[1], a[2], a[3]);
        let p = acc.as_mut_ptr();
        let mut c00 = _mm256_loadu_ps(p);
        let mut c01 = _mm256_loadu_ps(p.add(8));
        let mut c10 = _mm256_loadu_ps(p.add(16));
        let mut c11 = _mm256_loadu_ps(p.add(24));
        let mut c20 = _mm256_loadu_ps(p.add(32));
        let mut c21 = _mm256_loadu_ps(p.add(40));
        let mut c30 = _mm256_loadu_ps(p.add(48));
        let mut c31 = _mm256_loadu_ps(p.add(56));
        let bpp = bp.as_ptr();
        for kk in 0..kw {
            let y0 = _mm256_loadu_ps(bpp.add(kk * NR));
            let y1 = _mm256_loadu_ps(bpp.add(kk * NR + 8));
            // mul then add, never _mm256_fmadd_ps: FMA's single rounding
            // would diverge from the canonical scalar program.
            let x0 = _mm256_set1_ps(a0[kk]);
            c00 = _mm256_add_ps(c00, _mm256_mul_ps(x0, y0));
            c01 = _mm256_add_ps(c01, _mm256_mul_ps(x0, y1));
            let x1 = _mm256_set1_ps(a1[kk]);
            c10 = _mm256_add_ps(c10, _mm256_mul_ps(x1, y0));
            c11 = _mm256_add_ps(c11, _mm256_mul_ps(x1, y1));
            let x2 = _mm256_set1_ps(a2[kk]);
            c20 = _mm256_add_ps(c20, _mm256_mul_ps(x2, y0));
            c21 = _mm256_add_ps(c21, _mm256_mul_ps(x2, y1));
            let x3 = _mm256_set1_ps(a3[kk]);
            c30 = _mm256_add_ps(c30, _mm256_mul_ps(x3, y0));
            c31 = _mm256_add_ps(c31, _mm256_mul_ps(x3, y1));
        }
        _mm256_storeu_ps(p, c00);
        _mm256_storeu_ps(p.add(8), c01);
        _mm256_storeu_ps(p.add(16), c10);
        _mm256_storeu_ps(p.add(24), c11);
        _mm256_storeu_ps(p.add(32), c20);
        _mm256_storeu_ps(p.add(40), c21);
        _mm256_storeu_ps(p.add(48), c30);
        _mm256_storeu_ps(p.add(56), c31);
    }
}

// SAFETY (callers): the `avx2` target feature(s) must be enabled, and
// the slice-length contract of the safe dispatch wrapper must hold
// (it debug_asserts `bp`/`acc` against the tile geometry).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn tile1_f32_avx2(a: &[f32], bp: &[f32], acc: &mut [f32]) {
    // SAFETY: the dispatcher established the target feature, and all
    // raw loads/stores below stay inside `bp`/`acc` per the length
    // contract debug_asserted by the safe wrapper; the unaligned
    // intrinsics carry no alignment requirement.
    unsafe {
        use core::arch::x86_64::*;
        const NR: usize = 16;
        let p = acc.as_mut_ptr();
        let mut c0 = _mm256_loadu_ps(p);
        let mut c1 = _mm256_loadu_ps(p.add(8));
        let bpp = bp.as_ptr();
        for (kk, &xv) in a.iter().enumerate() {
            let x = _mm256_set1_ps(xv);
            let y0 = _mm256_loadu_ps(bpp.add(kk * NR));
            let y1 = _mm256_loadu_ps(bpp.add(kk * NR + 8));
            c0 = _mm256_add_ps(c0, _mm256_mul_ps(x, y0));
            c1 = _mm256_add_ps(c1, _mm256_mul_ps(x, y1));
        }
        _mm256_storeu_ps(p, c0);
        _mm256_storeu_ps(p.add(8), c1);
    }
}

// SAFETY (callers): the `avx2` + `fma` target feature(s) must be enabled, and
// the slice-length contract of the safe dispatch wrapper must hold
// (it debug_asserts `bp`/`acc` against the tile geometry).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn tile4_f32_avx2_fma(a: [&[f32]; 4], bp: &[f32], acc: &mut [f32]) {
    // SAFETY: the dispatcher established the target feature, and all
    // raw loads/stores below stay inside `bp`/`acc` per the length
    // contract debug_asserted by the safe wrapper; the unaligned
    // intrinsics carry no alignment requirement.
    unsafe {
        use core::arch::x86_64::*;
        const NR: usize = 16;
        let kw = a[0].len();
        let (a0, a1, a2, a3) = (a[0], a[1], a[2], a[3]);
        let p = acc.as_mut_ptr();
        let mut c00 = _mm256_loadu_ps(p);
        let mut c01 = _mm256_loadu_ps(p.add(8));
        let mut c10 = _mm256_loadu_ps(p.add(16));
        let mut c11 = _mm256_loadu_ps(p.add(24));
        let mut c20 = _mm256_loadu_ps(p.add(32));
        let mut c21 = _mm256_loadu_ps(p.add(40));
        let mut c30 = _mm256_loadu_ps(p.add(48));
        let mut c31 = _mm256_loadu_ps(p.add(56));
        let bpp = bp.as_ptr();
        for kk in 0..kw {
            let y0 = _mm256_loadu_ps(bpp.add(kk * NR));
            let y1 = _mm256_loadu_ps(bpp.add(kk * NR + 8));
            // the FMA-mode program: one correctly-rounded fused op per step
            let x0 = _mm256_set1_ps(a0[kk]);
            c00 = _mm256_fmadd_ps(x0, y0, c00);
            c01 = _mm256_fmadd_ps(x0, y1, c01);
            let x1 = _mm256_set1_ps(a1[kk]);
            c10 = _mm256_fmadd_ps(x1, y0, c10);
            c11 = _mm256_fmadd_ps(x1, y1, c11);
            let x2 = _mm256_set1_ps(a2[kk]);
            c20 = _mm256_fmadd_ps(x2, y0, c20);
            c21 = _mm256_fmadd_ps(x2, y1, c21);
            let x3 = _mm256_set1_ps(a3[kk]);
            c30 = _mm256_fmadd_ps(x3, y0, c30);
            c31 = _mm256_fmadd_ps(x3, y1, c31);
        }
        _mm256_storeu_ps(p, c00);
        _mm256_storeu_ps(p.add(8), c01);
        _mm256_storeu_ps(p.add(16), c10);
        _mm256_storeu_ps(p.add(24), c11);
        _mm256_storeu_ps(p.add(32), c20);
        _mm256_storeu_ps(p.add(40), c21);
        _mm256_storeu_ps(p.add(48), c30);
        _mm256_storeu_ps(p.add(56), c31);
    }
}

// SAFETY (callers): the `avx2` + `fma` target feature(s) must be enabled, and
// the slice-length contract of the safe dispatch wrapper must hold
// (it debug_asserts `bp`/`acc` against the tile geometry).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn tile1_f32_avx2_fma(a: &[f32], bp: &[f32], acc: &mut [f32]) {
    // SAFETY: the dispatcher established the target feature, and all
    // raw loads/stores below stay inside `bp`/`acc` per the length
    // contract debug_asserted by the safe wrapper; the unaligned
    // intrinsics carry no alignment requirement.
    unsafe {
        use core::arch::x86_64::*;
        const NR: usize = 16;
        let p = acc.as_mut_ptr();
        let mut c0 = _mm256_loadu_ps(p);
        let mut c1 = _mm256_loadu_ps(p.add(8));
        let bpp = bp.as_ptr();
        for (kk, &xv) in a.iter().enumerate() {
            let x = _mm256_set1_ps(xv);
            let y0 = _mm256_loadu_ps(bpp.add(kk * NR));
            let y1 = _mm256_loadu_ps(bpp.add(kk * NR + 8));
            c0 = _mm256_fmadd_ps(x, y0, c0);
            c1 = _mm256_fmadd_ps(x, y1, c1);
        }
        _mm256_storeu_ps(p, c0);
        _mm256_storeu_ps(p.add(8), c1);
    }
}

// --- f32 aarch64: NEON (baseline) ------------------------------------------

// SAFETY (callers): the `neon` target feature(s) must be enabled, and
// the slice-length contract of the safe dispatch wrapper must hold
// (it debug_asserts `bp`/`acc` against the tile geometry).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn tile4_f32_neon(a: [&[f32]; 4], bp: &[f32], acc: &mut [f32]) {
    // SAFETY: the dispatcher established the target feature, and all
    // raw loads/stores below stay inside `bp`/`acc` per the length
    // contract debug_asserted by the safe wrapper; the unaligned
    // intrinsics carry no alignment requirement.
    unsafe {
        use core::arch::aarch64::*;
        const NR: usize = 8;
        let kw = a[0].len();
        let (a0, a1, a2, a3) = (a[0], a[1], a[2], a[3]);
        let p = acc.as_mut_ptr();
        let mut c00 = vld1q_f32(p);
        let mut c01 = vld1q_f32(p.add(4));
        let mut c10 = vld1q_f32(p.add(8));
        let mut c11 = vld1q_f32(p.add(12));
        let mut c20 = vld1q_f32(p.add(16));
        let mut c21 = vld1q_f32(p.add(20));
        let mut c30 = vld1q_f32(p.add(24));
        let mut c31 = vld1q_f32(p.add(28));
        let bpp = bp.as_ptr();
        for kk in 0..kw {
            let y0 = vld1q_f32(bpp.add(kk * NR));
            let y1 = vld1q_f32(bpp.add(kk * NR + 4));
            // vmulq + vaddq, never vfmaq: keep the two-rounding scalar program
            let x0 = vdupq_n_f32(a0[kk]);
            c00 = vaddq_f32(c00, vmulq_f32(x0, y0));
            c01 = vaddq_f32(c01, vmulq_f32(x0, y1));
            let x1 = vdupq_n_f32(a1[kk]);
            c10 = vaddq_f32(c10, vmulq_f32(x1, y0));
            c11 = vaddq_f32(c11, vmulq_f32(x1, y1));
            let x2 = vdupq_n_f32(a2[kk]);
            c20 = vaddq_f32(c20, vmulq_f32(x2, y0));
            c21 = vaddq_f32(c21, vmulq_f32(x2, y1));
            let x3 = vdupq_n_f32(a3[kk]);
            c30 = vaddq_f32(c30, vmulq_f32(x3, y0));
            c31 = vaddq_f32(c31, vmulq_f32(x3, y1));
        }
        vst1q_f32(p, c00);
        vst1q_f32(p.add(4), c01);
        vst1q_f32(p.add(8), c10);
        vst1q_f32(p.add(12), c11);
        vst1q_f32(p.add(16), c20);
        vst1q_f32(p.add(20), c21);
        vst1q_f32(p.add(24), c30);
        vst1q_f32(p.add(28), c31);
    }
}

// SAFETY (callers): the `neon` target feature(s) must be enabled, and
// the slice-length contract of the safe dispatch wrapper must hold
// (it debug_asserts `bp`/`acc` against the tile geometry).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn tile1_f32_neon(a: &[f32], bp: &[f32], acc: &mut [f32]) {
    // SAFETY: the dispatcher established the target feature, and all
    // raw loads/stores below stay inside `bp`/`acc` per the length
    // contract debug_asserted by the safe wrapper; the unaligned
    // intrinsics carry no alignment requirement.
    unsafe {
        use core::arch::aarch64::*;
        const NR: usize = 8;
        let p = acc.as_mut_ptr();
        let mut c0 = vld1q_f32(p);
        let mut c1 = vld1q_f32(p.add(4));
        let bpp = bp.as_ptr();
        for (kk, &xv) in a.iter().enumerate() {
            let x = vdupq_n_f32(xv);
            let y0 = vld1q_f32(bpp.add(kk * NR));
            let y1 = vld1q_f32(bpp.add(kk * NR + 4));
            c0 = vaddq_f32(c0, vmulq_f32(x, y0));
            c1 = vaddq_f32(c1, vmulq_f32(x, y1));
        }
        vst1q_f32(p, c0);
        vst1q_f32(p.add(4), c1);
    }
}

// SAFETY (callers): the `neon` target feature(s) must be enabled, and
// the slice-length contract of the safe dispatch wrapper must hold
// (it debug_asserts `bp`/`acc` against the tile geometry).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn tile4_f32_neon_fma(a: [&[f32]; 4], bp: &[f32], acc: &mut [f32]) {
    // SAFETY: the dispatcher established the target feature, and all
    // raw loads/stores below stay inside `bp`/`acc` per the length
    // contract debug_asserted by the safe wrapper; the unaligned
    // intrinsics carry no alignment requirement.
    unsafe {
        use core::arch::aarch64::*;
        const NR: usize = 8;
        let kw = a[0].len();
        let (a0, a1, a2, a3) = (a[0], a[1], a[2], a[3]);
        let p = acc.as_mut_ptr();
        let mut c00 = vld1q_f32(p);
        let mut c01 = vld1q_f32(p.add(4));
        let mut c10 = vld1q_f32(p.add(8));
        let mut c11 = vld1q_f32(p.add(12));
        let mut c20 = vld1q_f32(p.add(16));
        let mut c21 = vld1q_f32(p.add(20));
        let mut c30 = vld1q_f32(p.add(24));
        let mut c31 = vld1q_f32(p.add(28));
        let bpp = bp.as_ptr();
        for kk in 0..kw {
            let y0 = vld1q_f32(bpp.add(kk * NR));
            let y1 = vld1q_f32(bpp.add(kk * NR + 4));
            // vfmaq_f32(acc, x, y) = acc + x·y, fused — the FMA-mode program
            let x0 = vdupq_n_f32(a0[kk]);
            c00 = vfmaq_f32(c00, x0, y0);
            c01 = vfmaq_f32(c01, x0, y1);
            let x1 = vdupq_n_f32(a1[kk]);
            c10 = vfmaq_f32(c10, x1, y0);
            c11 = vfmaq_f32(c11, x1, y1);
            let x2 = vdupq_n_f32(a2[kk]);
            c20 = vfmaq_f32(c20, x2, y0);
            c21 = vfmaq_f32(c21, x2, y1);
            let x3 = vdupq_n_f32(a3[kk]);
            c30 = vfmaq_f32(c30, x3, y0);
            c31 = vfmaq_f32(c31, x3, y1);
        }
        vst1q_f32(p, c00);
        vst1q_f32(p.add(4), c01);
        vst1q_f32(p.add(8), c10);
        vst1q_f32(p.add(12), c11);
        vst1q_f32(p.add(16), c20);
        vst1q_f32(p.add(20), c21);
        vst1q_f32(p.add(24), c30);
        vst1q_f32(p.add(28), c31);
    }
}

// SAFETY (callers): the `neon` target feature(s) must be enabled, and
// the slice-length contract of the safe dispatch wrapper must hold
// (it debug_asserts `bp`/`acc` against the tile geometry).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn tile1_f32_neon_fma(a: &[f32], bp: &[f32], acc: &mut [f32]) {
    // SAFETY: the dispatcher established the target feature, and all
    // raw loads/stores below stay inside `bp`/`acc` per the length
    // contract debug_asserted by the safe wrapper; the unaligned
    // intrinsics carry no alignment requirement.
    unsafe {
        use core::arch::aarch64::*;
        const NR: usize = 8;
        let p = acc.as_mut_ptr();
        let mut c0 = vld1q_f32(p);
        let mut c1 = vld1q_f32(p.add(4));
        let bpp = bp.as_ptr();
        for (kk, &xv) in a.iter().enumerate() {
            let x = vdupq_n_f32(xv);
            let y0 = vld1q_f32(bpp.add(kk * NR));
            let y1 = vld1q_f32(bpp.add(kk * NR + 4));
            c0 = vfmaq_f32(c0, x, y0);
            c1 = vfmaq_f32(c1, x, y1);
        }
        vst1q_f32(p, c0);
        vst1q_f32(p.add(4), c1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_and_rejects() {
        assert_eq!(Backend::parse("auto").unwrap(), None);
        for be in Backend::ALL {
            assert_eq!(Backend::parse(be.name()).unwrap(), Some(be));
        }
        assert!(Backend::parse("avx512").is_err());
    }

    #[test]
    fn scalar_always_available_and_detect_is_available() {
        assert!(Backend::Scalar.available());
        assert!(detect().available());
        assert!(available_backends().contains(&Backend::Scalar));
        assert!(available_backends().contains(&detect()));
    }

    #[test]
    fn set_backend_rejects_unavailable() {
        let unavailable: Vec<Backend> = Backend::ALL
            .iter()
            .copied()
            .filter(|b| !b.available())
            .collect();
        for be in unavailable {
            assert!(set_backend(Some(be)).is_err(), "{}", be.name());
        }
        // the active backend is never left in an unavailable state
        assert!(active().available());
    }

    #[test]
    fn every_available_backend_matches_scalar_bits() {
        // the contract at the microkernel level: same bits as the scalar
        // program for ragged k widths, at this backend's own nr
        let mut rng = crate::rng::Rng::new(99);
        for be in available_backends() {
            let nr = be.nr();
            for kw in [0usize, 1, 2, 3, 7, 64, 129] {
                let rows: Vec<Vec<f64>> =
                    (0..4).map(|_| rng.normal_vec(kw)).collect();
                let bp = rng.normal_vec(kw * nr);
                let init = rng.normal_vec(4 * nr);

                let mut want = init.clone();
                tile4_scalar(
                    [&rows[0], &rows[1], &rows[2], &rows[3]], &bp, &mut want,
                    nr);
                let mut got = init.clone();
                tile4(be, false, [&rows[0], &rows[1], &rows[2], &rows[3]],
                      &bp, &mut got);
                assert_eq!(want, got, "tile4 {} kw={kw}", be.name());

                let mut want1 = init[..nr].to_vec();
                tile1_scalar(&rows[0], &bp, &mut want1, nr);
                let mut got1 = init[..nr].to_vec();
                tile1(be, false, &rows[0], &bp, &mut got1);
                assert_eq!(want1, got1, "tile1 {} kw={kw}", be.name());
            }
        }
    }

    #[test]
    fn fma_tiles_match_the_scalar_mul_add_program_bitwise() {
        // FMA mode's contract at the microkernel level: every backend's
        // fused tile == the scalar f64::mul_add program (both are one
        // correctly-rounded fusedMultiplyAdd per step).  The flag is a
        // per-call parameter here, so this never flips the process-wide
        // mode under concurrently running tests.
        let mut rng = crate::rng::Rng::new(123);
        for be in available_backends() {
            let nr = be.nr();
            for kw in [0usize, 1, 3, 7, 65, 130] {
                let rows: Vec<Vec<f64>> =
                    (0..4).map(|_| rng.normal_vec(kw)).collect();
                let bp = rng.normal_vec(kw * nr);
                let init = rng.normal_vec(4 * nr);

                let mut want = init.clone();
                tile4_scalar_fma(
                    [&rows[0], &rows[1], &rows[2], &rows[3]], &bp, &mut want,
                    nr);
                let mut got = init.clone();
                tile4(be, true, [&rows[0], &rows[1], &rows[2], &rows[3]],
                      &bp, &mut got);
                assert_eq!(want, got, "tile4 fma {} kw={kw}", be.name());

                let mut want1 = init[..nr].to_vec();
                tile1_scalar_fma(&rows[0], &bp, &mut want1, nr);
                let mut got1 = init[..nr].to_vec();
                tile1(be, true, &rows[0], &bp, &mut got1);
                assert_eq!(want1, got1, "tile1 fma {} kw={kw}", be.name());
            }
        }
    }

    #[test]
    fn every_available_backend_matches_scalar_bits_f32() {
        // the f32 contract at the microkernel level: same bits as the
        // scalar f32 program for ragged k widths, at this backend's nr32,
        // in both accumulation modes (the mode is a per-call parameter
        // here — no process-global flips under concurrent tests)
        let mut rng = crate::rng::Rng::new(271);
        let f32s = |rng: &mut crate::rng::Rng, n: usize| -> Vec<f32> {
            rng.normal_vec(n).iter().map(|&v| v as f32).collect()
        };
        for be in available_backends() {
            let nr = be.nr32();
            for kw in [0usize, 1, 2, 3, 7, 64, 129] {
                let rows: Vec<Vec<f32>> =
                    (0..4).map(|_| f32s(&mut rng, kw)).collect();
                let bp = f32s(&mut rng, kw * nr);
                let init = f32s(&mut rng, 4 * nr);
                for fma in [false, true] {
                    let mut want = init.clone();
                    if fma {
                        tile4_f32_scalar_fma(
                            [&rows[0], &rows[1], &rows[2], &rows[3]], &bp,
                            &mut want, nr);
                    } else {
                        tile4_f32_scalar(
                            [&rows[0], &rows[1], &rows[2], &rows[3]], &bp,
                            &mut want, nr);
                    }
                    let mut got = init.clone();
                    tile4_f32(be, fma,
                              [&rows[0], &rows[1], &rows[2], &rows[3]],
                              &bp, &mut got);
                    assert_eq!(want, got, "tile4_f32 {} kw={kw} fma={fma}",
                               be.name());

                    let mut want1 = init[..nr].to_vec();
                    if fma {
                        tile1_f32_scalar_fma(&rows[0], &bp, &mut want1, nr);
                    } else {
                        tile1_f32_scalar(&rows[0], &bp, &mut want1, nr);
                    }
                    let mut got1 = init[..nr].to_vec();
                    tile1_f32(be, fma, &rows[0], &bp, &mut got1);
                    assert_eq!(want1, got1,
                               "tile1_f32 {} kw={kw} fma={fma}", be.name());
                }
            }
        }
    }

    #[test]
    fn nr32_doubles_nr_everywhere() {
        for be in Backend::ALL {
            assert_eq!(be.nr32(), 2 * be.nr(), "{}", be.name());
            assert!(be.nr32() <= MAX_NR32);
        }
    }

    #[test]
    fn fma_mode_differs_from_mul_add_somewhere() {
        // sanity that the fused program is genuinely a different
        // canonical program (not a no-op flag): over many random chains
        // at least one accumulator bit must differ
        let mut rng = crate::rng::Rng::new(7);
        let mut differed = false;
        for _ in 0..64 {
            let a = rng.normal_vec(33);
            let bp = rng.normal_vec(33 * 4);
            let mut plain = vec![0.0_f64; 4];
            tile1_scalar(&a, &bp, &mut plain, 4);
            let mut fused = vec![0.0_f64; 4];
            tile1_scalar_fma(&a, &bp, &mut fused, 4);
            if plain != fused {
                differed = true;
                break;
            }
        }
        assert!(differed, "fused and mul-then-add never diverged");
    }

    // NOTE: no unit test here flips the process-global FMA override —
    // unlike the backend override, the FMA mode *changes bits*, so a
    // mid-test flip could fail concurrently running reference
    // comparisons.  The override/env resolution is exercised by the
    // serialized FMA legs in `tests/kernel_oracle.rs` and by the CI
    // matrix's LRC_FMA=1 job (which runs this whole suite with
    // `fma_active()` = true end to end).
}
