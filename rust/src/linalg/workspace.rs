//! Per-thread scratch arenas: grow-only buffer reuse for the compute hot
//! paths, so steady-state GEMM / Gram / Jacobi loops are **allocation-free**.
//!
//! # Why thread-local is per-worker
//!
//! Every arena lives in a thread-local.  The [`crate::par`] pool's workers
//! are *persistent* threads (parked on the job board between epochs), so a
//! worker's arena survives across epochs and across the whole per-layer
//! quantization fan-out: the packed B/A panels, Σ temporaries and solver
//! scratch a worker touches while quantizing layer 7 are the very buffers
//! it reuses for layer 19.  Serial callers get the same treatment through
//! the calling thread's own arena.  (This is one more reason the
//! persistent pool beats spawn-per-call scoped threads: a fresh thread
//! starts with a cold, empty arena every time.)
//!
//! # Shape of the arena
//!
//! A small free list of `Vec<f64>` buffers, keyed by capacity.
//! [`take_zeroed`] / [`take_copy`] hand out the best-fitting cached
//! buffer (smallest capacity that holds the request); in steady state — same
//! kernel shapes call after call, exactly the per-layer fan-out pattern —
//! every take is a cache hit and performs **zero allocations**
//! (`tests/alloc_steady_state.rs` locks this with a counting global
//! allocator).  [`put`] returns a buffer; the list is capacity-capped
//! ([`MAX_CACHED`]) with a keep-the-biggest eviction policy so the arena
//! stays bounded while the most reusable panels survive.
//!
//! A parallel `Vec<f32>` free list ([`take_zeroed_f32`] / [`put_f32`])
//! serves the f32 data path — the fused dequant-GEMM panel strips and
//! f32 activation scratch — under the same best-fit/eviction policy.
//!
//! Buffers are plain `Vec<f64>`s: forgetting to [`put`] one back is not a
//! leak (it just drops), and a buffer `put` on a different thread than it
//! was taken from simply migrates arenas.  The [`Mat`]-shaped helpers
//! ([`take_mat`], [`take_mat_copy`], [`recycle_mat`]) wrap the same pool
//! for callers that want matrix scratch.
//!
//! The module is `pub` so the integration tests and bench targets can
//! exercise the arena directly; library code outside `linalg`/`quant`
//! should not need it.

use std::cell::RefCell;

use super::Mat;

/// Max buffers one thread's arena caches; overflow evicts the smallest.
pub const MAX_CACHED: usize = 24;

/// Max bytes one thread's arena retains (and max size of any single
/// cached buffer).  Keep-the-biggest eviction would otherwise pin the
/// largest panels a long-lived process ever touched — e.g. one huge
/// model quantized once — in every worker's thread-local forever; the
/// byte cap bounds that retention while still covering this repro's
/// d ≤ 512 working set (a packed 512×512 B panel is ~2 MB) many times
/// over.
pub const MAX_CACHED_BYTES: usize = 64 << 20;

thread_local! {
    /// This thread's free list (capacity-keyed, grow-only).
    static ARENA: RefCell<Vec<Vec<f64>>> = const { RefCell::new(Vec::new()) };

    /// This thread's **f32** free list — a parallel arena for the f32
    /// data path (fused dequant-GEMM strips, decoded weight panels,
    /// activation scratch).  Kept separate from the f64 list so a take
    /// can never reinterpret a buffer of the other width.
    static ARENA32: RefCell<Vec<Vec<f32>>> = const { RefCell::new(Vec::new()) };
}

/// A scratch buffer of exactly `len` zeros, reusing this thread's arena
/// when a cached buffer is large enough (no allocation), growing one
/// otherwise.  Return it with [`put`] when done.
pub fn take_zeroed(len: usize) -> Vec<f64> {
    let mut v = take_raw(len);
    v.clear();
    v.resize(len, 0.0);
    v
}

/// A scratch buffer holding a copy of `src` (length `src.len()`); the
/// copy is into recycled storage, so in steady state this allocates
/// nothing.  Return it with [`put`].
pub fn take_copy(src: &[f64]) -> Vec<f64> {
    let mut v = take_raw(src.len());
    v.clear();
    v.extend_from_slice(src);
    v
}

/// Best-fit take over one free list: smallest capacity that already
/// holds the request; else the largest cached buffer (one realloc, then
/// it serves this shape forever); else a fresh allocation.  Shared by
/// the f64 and f32 arenas — the policy is element-width-agnostic.
fn take_from<T>(free: &mut Vec<Vec<T>>, len: usize) -> Vec<T> {
    let mut best: Option<usize> = None;
    let mut largest: Option<usize> = None;
    for (i, b) in free.iter().enumerate() {
        if b.capacity() >= len {
            if best.map_or(true, |j| b.capacity() < free[j].capacity()) {
                best = Some(i);
            }
        }
        if largest.map_or(true, |j: usize| b.capacity() > free[j].capacity()) {
            largest = Some(i);
        }
    }
    match best.or(largest) {
        Some(i) => free.swap_remove(i),
        None => Vec::with_capacity(len),
    }
}

/// Bounded insert into one free list (see [`put`] for the policy).
fn put_into<T>(free: &mut Vec<Vec<T>>, v: Vec<T>) {
    free.push(v);
    let total = |free: &Vec<Vec<T>>| -> usize {
        free.iter().map(|b| b.capacity()).sum::<usize>()
            * std::mem::size_of::<T>()
    };
    while free.len() > MAX_CACHED
        || (free.len() > 1 && total(free) > MAX_CACHED_BYTES)
    {
        let smallest = (0..free.len())
            .min_by_key(|&i| free[i].capacity())
            .unwrap();
        free.swap_remove(smallest);
    }
}

/// Pull the best-fitting cached buffer (length unspecified — callers
/// clear/resize), or a fresh one with `len` capacity on a cache miss.
/// Zero-length requests never consume a cached buffer (a degenerate
/// request would otherwise best-fit — and pin — the smallest one).
fn take_raw(len: usize) -> Vec<f64> {
    if len == 0 {
        return Vec::new();
    }
    ARENA.with(|a| take_from(&mut a.borrow_mut(), len))
}

/// Return a buffer to this thread's arena.  Bounded two ways: past
/// [`MAX_CACHED`] buffers or [`MAX_CACHED_BYTES`] total, the smallest
/// buffers (incoming included) are dropped — and a single buffer larger
/// than the byte cap is never cached at all — so neither varied-shape
/// workloads nor one giant model can grow a worker's arena without
/// bound.
pub fn put(v: Vec<f64>) {
    let bytes = v.capacity() * std::mem::size_of::<f64>();
    if v.capacity() == 0 || bytes > MAX_CACHED_BYTES {
        return;
    }
    ARENA.with(|a| put_into(&mut a.borrow_mut(), v));
}

/// f32 sibling of [`take_zeroed`]: exactly `len` zeros from this
/// thread's f32 arena.  Return it with [`put_f32`].
pub fn take_zeroed_f32(len: usize) -> Vec<f32> {
    let mut v = take_raw_f32(len);
    v.clear();
    v.resize(len, 0.0);
    v
}

/// f32 sibling of [`take_copy`]: an arena-backed copy of `src`.
pub fn take_copy_f32(src: &[f32]) -> Vec<f32> {
    let mut v = take_raw_f32(src.len());
    v.clear();
    v.extend_from_slice(src);
    v
}

/// f32 sibling of `take_raw`: best-fitting cached f32 buffer with
/// unspecified length/contents — callers clear/resize.  `pub(crate)` so
/// the fused dequant-GEMM path can fill decoded panels without a
/// zeroing pass it would immediately overwrite.
pub(crate) fn take_raw_f32(len: usize) -> Vec<f32> {
    if len == 0 {
        return Vec::new();
    }
    ARENA32.with(|a| take_from(&mut a.borrow_mut(), len))
}

/// f32 sibling of [`put`] (same caps — the byte bound is shared policy,
/// applied per arena).
pub fn put_f32(v: Vec<f32>) {
    let bytes = v.capacity() * std::mem::size_of::<f32>();
    if v.capacity() == 0 || bytes > MAX_CACHED_BYTES {
        return;
    }
    ARENA32.with(|a| put_into(&mut a.borrow_mut(), v));
}

/// A `rows × cols` zeroed [`Mat`] backed by arena storage.  Pass it to
/// [`recycle_mat`] when done (dropping it instead is safe, just a future
/// cache miss).
pub fn take_mat(rows: usize, cols: usize) -> Mat {
    Mat { rows, cols, data: take_zeroed(rows * cols) }
}

/// An arena-backed copy of `src` (same shape, same bits, recycled
/// storage).
pub fn take_mat_copy(src: &Mat) -> Mat {
    Mat { rows: src.rows, cols: src.cols, data: take_copy(&src.data) }
}

/// An empty 0×0 [`Mat`] whose storage already holds capacity for
/// `rows × cols` — for handing to the `*_into` entry points
/// ([`Mat::matmul_nt_into`], [`Mat::gram_n_into`],
/// [`Mat::cols_range_into`], [`Mat::resize_zeroed`]), which reshape and
/// fill the target themselves.  Skips the zero-fill [`take_mat`] would
/// do (the `*_into` call zeroes or overwrites every element anyway), so
/// the scratch is written once, not twice.
pub fn take_mat_for(rows: usize, cols: usize) -> Mat {
    let len = rows * cols;
    let mut data = take_raw(len);
    data.clear();
    data.reserve(len);
    Mat { rows: 0, cols: 0, data }
}

/// Return a [`take_mat`]/[`take_mat_copy`] matrix's storage to the arena.
pub fn recycle_mat(m: Mat) {
    put(m.data);
}

/// Shared mutable slice for **disjoint** parallel writes: the pool's
/// workers write non-overlapping ranges of one output buffer (GEMM row
/// chunks, Gram row segments, Jacobi pair scratch) without per-item
/// allocation or locking.
///
/// SAFETY contract: callers must hand out non-overlapping ranges only —
/// each `range` call conjures `&mut` access to its span, so two live
/// overlapping ranges would be UB.  Every use in this crate derives the
/// ranges from a partition (row chunks, per-pair chunks), which is
/// disjoint by construction.
///
/// Under the `checked` cargo feature this contract is *enforced*, not
/// trusted: every claimed range is recorded in a lock-protected
/// interval set for the lifetime of the wrapper (every call site
/// constructs a fresh `SharedSlice` per parallel phase, so wrapper
/// lifetime == phase lifetime), and any overlapping or out-of-bounds
/// claim panics with both intervals.  With the feature off the field
/// does not exist and `range` compiles to the raw pointer math alone.
pub(crate) struct SharedSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    /// every `[start, end)` handed out so far (checked mode only)
    // analyze: allow(forbidden-api): checked-mode race-detector
    // instrumentation — the lock exists only under the `checked`
    // feature and is never compiled into default builds.
    #[cfg(feature = "checked")]
    claims: std::sync::Mutex<Vec<(usize, usize)>>,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

// SAFETY: access is only through `range`, whose disjointness contract
// makes cross-thread use sound; T: Send because the &mut spans move to
// worker threads.
unsafe impl<T: Send> Sync for SharedSlice<'_, T> {}

impl<'a, T> SharedSlice<'a, T> {
    pub fn new(s: &'a mut [T]) -> Self {
        SharedSlice {
            ptr: s.as_mut_ptr(),
            len: s.len(),
            #[cfg(feature = "checked")]
            // analyze: allow(forbidden-api): checked-mode race-detector
            // instrumentation, compiled out of default builds.
            claims: std::sync::Mutex::new(Vec::new()),
            _marker: std::marker::PhantomData,
        }
    }

    /// The sub-slice `[start, end)`.
    ///
    /// SAFETY: the caller guarantees no other live range overlaps
    /// `[start, end)` for the duration of the returned borrow.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range(&self, start: usize, end: usize) -> &mut [T] {
        debug_assert!(start <= end && end <= self.len);
        #[cfg(feature = "checked")]
        self.record_claim(start, end);
        // SAFETY: `[start, end)` is in bounds of the wrapped slice
        // (debug-asserted above, hard-checked under `checked`) and the
        // caller's disjointness contract makes the `&mut` unique.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(start), end - start) }
    }

    /// Checked-mode race detector: record `[start, end)` and panic on
    /// out-of-bounds or on overlap with any previously claimed range.
    #[cfg(feature = "checked")]
    fn record_claim(&self, start: usize, end: usize) {
        assert!(
            start <= end && end <= self.len,
            "checked: out-of-bounds SharedSlice claim [{start}, {end}) of {}",
            self.len
        );
        let mut claims = self.claims.lock().unwrap();
        for &(s, e) in claims.iter() {
            // empty ranges never overlap anything
            if start < e && s < end {
                panic!(
                    "checked: overlapping SharedSlice claims [{s}, {e}) and \
                     [{start}, {end}) — disjoint-write contract violated"
                );
            }
        }
        claims.push((start, end));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_put_roundtrip_reuses_capacity() {
        let v = take_zeroed(513);
        assert_eq!(v.len(), 513);
        assert!(v.iter().all(|&x| x == 0.0));
        let cap = v.capacity();
        let p = v.as_ptr();
        put(v);
        // same-shape take must come back from the cache (same storage)
        let v2 = take_zeroed(513);
        assert!(v2.capacity() >= 513);
        assert_eq!((v2.as_ptr(), v2.capacity()), (p, cap));
        put(v2);
    }

    #[test]
    fn take_zeroed_clears_previous_contents() {
        let mut v = take_zeroed(8);
        v.iter_mut().for_each(|x| *x = 7.0);
        put(v);
        let v = take_zeroed(8);
        assert!(v.iter().all(|&x| x == 0.0));
        put(v);
    }

    #[test]
    fn take_copy_copies_bits() {
        let src = [1.5, -2.25, 0.0, 1e-300];
        let v = take_copy(&src);
        assert_eq!(&v[..], &src[..]);
        put(v);
    }

    #[test]
    fn f32_arena_roundtrip_and_isolation() {
        // the f32 arena reuses capacity like the f64 one…
        let v = take_zeroed_f32(257);
        assert!(v.iter().all(|&x| x == 0.0));
        let p = v.as_ptr();
        put_f32(v);
        let v2 = take_zeroed_f32(257);
        assert_eq!(v2.as_ptr(), p);
        put_f32(v2);
        // …and take_copy_f32 copies bits
        let src = [1.5f32, -2.25, 0.0, 1e-30];
        let c = take_copy_f32(&src);
        assert_eq!(&c[..], &src[..]);
        put_f32(c);
    }

    #[test]
    fn arena_stays_bounded() {
        for i in 0..3 * MAX_CACHED {
            put(Vec::with_capacity(16 + i));
        }
        ARENA.with(|a| assert!(a.borrow().len() <= MAX_CACHED));
    }

    #[test]
    fn mat_helpers_roundtrip() {
        let m = take_mat(3, 4);
        assert_eq!((m.rows, m.cols), (3, 4));
        assert!(m.data.iter().all(|&x| x == 0.0));
        recycle_mat(m);
        let src = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let c = take_mat_copy(&src);
        assert_eq!(c, src);
        recycle_mat(c);
    }

    #[test]
    fn shared_slice_disjoint_ranges() {
        let mut data = vec![0.0_f64; 10];
        let s = SharedSlice::new(&mut data);
        // SAFETY: disjoint halves written "concurrently" (serial here;
        // the pool tests cover the threaded case)
        unsafe {
            s.range(0, 5).iter_mut().for_each(|x| *x = 1.0);
            s.range(5, 10).iter_mut().for_each(|x| *x = 2.0);
        }
        assert_eq!(&data[..5], &[1.0; 5]);
        assert_eq!(&data[5..], &[2.0; 5]);
    }

    /// Checked-mode race detector (`--features checked`): the
    /// disjoint-write contract is enforced at runtime, so a seeded
    /// overlap must panic and honest partitions must not.
    #[cfg(feature = "checked")]
    mod checked {
        use super::super::SharedSlice;

        #[test]
        fn disjoint_claims_pass_under_checked() {
            let mut buf = vec![0.0_f64; 12];
            {
                let s = SharedSlice::new(&mut buf);
                // SAFETY: [0,4), [4,8), [8,12) partition the buffer.
                unsafe {
                    s.range(0, 4)[0] = 1.0;
                    s.range(4, 8)[0] = 2.0;
                    s.range(8, 12)[0] = 3.0;
                }
            }
            assert_eq!((buf[0], buf[4], buf[8]), (1.0, 2.0, 3.0));
        }

        #[test]
        fn adjacent_and_empty_claims_are_not_overlaps() {
            let mut buf = vec![0.0_f64; 8];
            let s = SharedSlice::new(&mut buf);
            // SAFETY: adjacent ranges and empty ranges never alias.
            unsafe {
                let _ = s.range(0, 4);
                let _ = s.range(4, 4);
                let _ = s.range(4, 8);
            }
        }

        #[test]
        #[should_panic(expected = "overlapping SharedSlice claims")]
        fn seeded_overlap_panics_under_checked() {
            let mut buf = vec![0.0_f64; 8];
            let s = SharedSlice::new(&mut buf);
            // SAFETY: the first borrow is dropped before the second
            // claim; the detector panics before any alias can exist.
            let _ = unsafe { s.range(0, 5) };
            // SAFETY: overlapping on purpose — the detector must
            // panic on this claim before any aliased access exists.
            let _ = unsafe { s.range(4, 8) };
        }

        #[test]
        #[should_panic(expected = "out-of-bounds SharedSlice claim")]
        fn out_of_bounds_claim_panics_under_checked() {
            let mut buf = vec![0.0_f64; 4];
            let s = SharedSlice::new(&mut buf);
            // SAFETY: the detector panics before the slice is formed.
            let _ = unsafe { s.range(2, 6) };
        }
    }
}
