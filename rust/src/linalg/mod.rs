//! Dense f64 linear algebra, built from scratch for the LRC math.
//!
//! The paper's covariance computations "required 64-bit precision for
//! numerical accuracy", so everything here is f64.  Sizes are small
//! (d ≤ 512 in this reproduction) but hot: GEMM is register-blocked with a
//! transposed-B layout, Cholesky and the Jacobi eigensolver are the exact
//! primitives Algorithms 2–4 need.
//!
//! Every O(n³) kernel also has a `par_*` variant on [`crate::par::Pool`]
//! (row-chunked with fixed, thread-count-independent chunking), each
//! **bit-identical** to its serial form at any pool size — the serial
//! path is simply the `threads = 1` case.

mod chol;
mod eigh;
mod hadamard;

pub use chol::{cholesky, solve_lower, solve_upper, chol_solve_mat, chol_inverse};
pub use eigh::{eigh, eigh_jacobi, eigh_jacobi_par, top_k_eigvecs};
pub use hadamard::{fwht, fwht_f32, hadamard_matrix};

/// Row-major dense f64 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        let r = rows.len();
        let c = if r > 0 { rows[0].len() } else { 0 };
        let mut data = Vec::with_capacity(r * c);
        for row in &rows {
            assert_eq!(row.len(), c);
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data: data.iter().map(|&x| x as f64).collect() }
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        // simple blocked transpose for cache friendliness
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    /// C = A · B
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul dims {}x{} · {}x{}",
                   self.rows, self.cols, b.rows, b.cols);
        // transpose B once so the inner loop is two contiguous slices
        let bt = b.transpose();
        self.matmul_nt(&bt)
    }

    /// C = A · B on `pool` (row-chunked; bit-identical to [`Mat::matmul`]).
    pub fn par_matmul(&self, b: &Mat, pool: &crate::par::Pool) -> Mat {
        assert_eq!(self.cols, b.rows, "par_matmul dims {}x{} · {}x{}",
                   self.rows, self.cols, b.rows, b.cols);
        let bt = b.transpose();
        self.par_matmul_nt(&bt, pool)
    }

    /// C = A · Bᵀ  (B given as [n, k]: C[i,j] = Σ A[i,:]·B[j,:])
    ///
    /// 2×2 register-blocked: each inner pass streams two A rows against
    /// two B rows, quartering the loads per MAC (§Perf: 4.4→6.4 GFLOP/s).
    pub fn matmul_nt(&self, bt: &Mat) -> Mat {
        assert_eq!(self.cols, bt.cols, "matmul_nt inner dims");
        let (m, n) = (self.rows, bt.rows);
        let mut out = Mat::zeros(m, n);
        self.matmul_nt_block(bt, 0, m, &mut out.data);
        out
    }

    /// Fixed row-chunk size for parallel kernels.  Even, so the 2×2 row
    /// pairing inside every chunk coincides with the serial pairing, and
    /// independent of thread count — both facts together make the par_*
    /// kernels bit-identical to their serial forms at any pool size.
    pub const PAR_ROW_CHUNK: usize = 64;

    /// C = A · Bᵀ on `pool`: rows are split into fixed [`Mat::PAR_ROW_CHUNK`]
    /// chunks, each computed by the serial 2×2 kernel into its disjoint
    /// slice of C.  Bit-identical to [`Mat::matmul_nt`] for every thread
    /// count (each output element is produced by exactly the same
    /// floating-point program).
    pub fn par_matmul_nt(&self, bt: &Mat, pool: &crate::par::Pool) -> Mat {
        assert_eq!(self.cols, bt.cols, "par_matmul_nt inner dims");
        let (m, n) = (self.rows, bt.rows);
        let mut out = Mat::zeros(m, n);
        if pool.threads() == 1 || m <= Self::PAR_ROW_CHUNK || n == 0 {
            self.matmul_nt_block(bt, 0, m, &mut out.data);
            return out;
        }
        let chunk = Self::PAR_ROW_CHUNK;
        let work: Vec<(usize, &mut [f64])> =
            out.data.chunks_mut(chunk * n).enumerate().collect();
        pool.for_each(work, |(ci, slice)| {
            let r0 = ci * chunk;
            let r1 = (r0 + chunk).min(m);
            self.matmul_nt_block(bt, r0, r1, slice);
        });
        out
    }

    /// The 2×2-blocked kernel over rows [r0, r1), writing into `out`
    /// (row-major, `(r1-r0) × bt.rows`, indexed relative to r0).  Row
    /// pairing starts at r0, so any even-aligned chunking reproduces the
    /// full-matrix result exactly.
    fn matmul_nt_block(&self, bt: &Mat, r0: usize, r1: usize,
                       out: &mut [f64]) {
        let n = bt.rows;
        debug_assert_eq!(out.len(), (r1 - r0) * n);
        let mut i = r0;
        while i + 1 < r1 {
            let (a0, a1) = (self.row(i), self.row(i + 1));
            let (o0, o1) = ((i - r0) * n, (i + 1 - r0) * n);
            let mut j = 0;
            while j + 1 < n {
                let (b0, b1) = (bt.row(j), bt.row(j + 1));
                let (mut s00, mut s01) = (0.0_f64, 0.0_f64);
                let (mut s10, mut s11) = (0.0_f64, 0.0_f64);
                for k in 0..a0.len() {
                    let (x0, x1) = (a0[k], a1[k]);
                    let (y0, y1) = (b0[k], b1[k]);
                    s00 += x0 * y0;
                    s01 += x0 * y1;
                    s10 += x1 * y0;
                    s11 += x1 * y1;
                }
                out[o0 + j] = s00;
                out[o0 + j + 1] = s01;
                out[o1 + j] = s10;
                out[o1 + j + 1] = s11;
                j += 2;
            }
            if j < n {
                out[o0 + j] = dot(a0, bt.row(j));
                out[o1 + j] = dot(a1, bt.row(j));
            }
            i += 2;
        }
        if i < r1 {
            let o = (i - r0) * n;
            for j in 0..n {
                out[o + j] = dot(self.row(i), bt.row(j));
            }
        }
    }

    /// C = Aᵀ · A (symmetric Gram matrix, only upper computed then mirrored)
    pub fn gram_t(&self) -> Mat {
        let n = self.cols;
        let at = self.transpose();
        let mut out = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = dot(at.row(i), at.row(j));
                out.data[i * n + j] = v;
                out.data[j * n + i] = v;
            }
        }
        out
    }

    /// C = Aᵀ · A on `pool`: upper-triangle rows computed in parallel,
    /// assembled + mirrored in fixed order.  Bit-identical to
    /// [`Mat::gram_t`] (every entry is the same single `dot`).
    pub fn par_gram_t(&self, pool: &crate::par::Pool) -> Mat {
        let n = self.cols;
        let at = self.transpose();
        let rows = pool.map(n, |i| {
            let mut seg = Vec::with_capacity(n - i);
            for j in i..n {
                seg.push(dot(at.row(i), at.row(j)));
            }
            seg
        });
        let mut out = Mat::zeros(n, n);
        for (i, seg) in rows.iter().enumerate() {
            for (off, &v) in seg.iter().enumerate() {
                let j = i + off;
                out.data[i * n + j] = v;
                out.data[j * n + i] = v;
            }
        }
        out
    }

    /// C = A · Aᵀ (symmetric, rows as vectors)
    pub fn gram_n(&self) -> Mat {
        let m = self.rows;
        let mut out = Mat::zeros(m, m);
        for i in 0..m {
            for j in i..m {
                let v = dot(self.row(i), self.row(j));
                out.data[i * m + j] = v;
                out.data[j * m + i] = v;
            }
        }
        out
    }

    /// C = A · Aᵀ on `pool` (see [`Mat::par_gram_t`]; bit-identical to
    /// [`Mat::gram_n`]).
    pub fn par_gram_n(&self, pool: &crate::par::Pool) -> Mat {
        let m = self.rows;
        let rows = pool.map(m, |i| {
            let mut seg = Vec::with_capacity(m - i);
            for j in i..m {
                seg.push(dot(self.row(i), self.row(j)));
            }
            seg
        });
        let mut out = Mat::zeros(m, m);
        for (i, seg) in rows.iter().enumerate() {
            for (off, &v) in seg.iter().enumerate() {
                let j = i + off;
                out.data[i * m + j] = v;
                out.data[j * m + i] = v;
            }
        }
        out
    }

    /// y = A · x
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows).map(|i| dot(self.row(i), x)).collect()
    }

    pub fn scale(&self, s: f64) -> Mat {
        Mat { rows: self.rows, cols: self.cols,
              data: self.data.iter().map(|&x| x * s).collect() }
    }

    pub fn add(&self, b: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        let data = self.data.iter().zip(&b.data).map(|(x, y)| x + y).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    pub fn sub(&self, b: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        let data = self.data.iter().zip(&b.data).map(|(x, y)| x - y).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// In-place A += s·I
    pub fn add_diag(&mut self, s: f64) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self.data[i * self.cols + i] += s;
        }
    }

    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self.data[i * self.cols + i]).sum()
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Frobenius inner product ⟨A, B⟩.
    pub fn frob_dot(&self, b: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        dot(&self.data, &b.data)
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0_f64, |a, &x| a.max(x.abs()))
    }

    /// Extract columns [c0, c1) as a new matrix.
    pub fn cols_range(&self, c0: usize, c1: usize) -> Mat {
        assert!(c0 <= c1 && c1 <= self.cols);
        let mut out = Mat::zeros(self.rows, c1 - c0);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    pub fn random_normal(rng: &mut crate::rng::Rng, rows: usize, cols: usize)
                         -> Mat {
        Mat { rows, cols, data: rng.normal_vec(rows * cols) }
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Unrolled dot product — the single hottest scalar loop in the crate.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    let chunks = n / 4;
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// axpy: y += a·x
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_mat(seed: u64, r: usize, c: usize) -> Mat {
        Mat::random_normal(&mut Rng::new(seed), r, c)
    }

    #[test]
    fn matmul_identity() {
        let a = rand_mat(1, 5, 7);
        let i = Mat::eye(7);
        let c = a.matmul(&i);
        for (x, y) in a.data.iter().zip(&c.data) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(vec![vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_associativity_property() {
        // property: (AB)C == A(BC) within fp tolerance, random shapes
        for seed in 0..5 {
            let mut r = Rng::new(seed);
            let (m, k, n, p) = (2 + r.below(6), 2 + r.below(6),
                                2 + r.below(6), 2 + r.below(6));
            let a = rand_mat(seed * 3 + 1, m, k);
            let b = rand_mat(seed * 3 + 2, k, n);
            let c = rand_mat(seed * 3 + 3, n, p);
            let left = a.matmul(&b).matmul(&c);
            let right = a.matmul(&b.matmul(&c));
            assert!(left.sub(&right).max_abs() < 1e-9);
        }
    }

    #[test]
    fn transpose_involution() {
        let a = rand_mat(5, 9, 4);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn gram_matches_matmul() {
        let a = rand_mat(11, 6, 4);
        let g1 = a.gram_t();                  // AᵀA
        let g2 = a.transpose().matmul(&a);
        assert!(g1.sub(&g2).max_abs() < 1e-10);
        let h1 = a.gram_n();                  // AAᵀ
        let h2 = a.matmul(&a.transpose());
        assert!(h1.sub(&h2).max_abs() < 1e-10);
    }

    #[test]
    fn par_matmul_nt_bit_identical_across_pools() {
        // property: the parallel kernel equals the serial one EXACTLY
        // (==, not ≈) for every thread count, including ragged shapes
        // around the chunk boundary and odd row counts
        use crate::par::Pool;
        for (m, k, n) in [(1, 5, 1), (2, 3, 2), (7, 9, 5), (63, 17, 31),
                          (64, 8, 65), (65, 8, 64), (129, 33, 66)] {
            let a = rand_mat(m as u64 * 31 + n as u64, m, k);
            let b = rand_mat(m as u64 * 17 + k as u64, n, k);
            let serial = a.matmul_nt(&b);
            for t in [1, 2, 8] {
                let par = a.par_matmul_nt(&b, &Pool::new(t));
                assert_eq!(serial, par, "{m}x{k}·{n}ᵀ threads={t}");
            }
        }
    }

    #[test]
    fn par_matmul_matches_matmul() {
        use crate::par::Pool;
        let a = rand_mat(81, 70, 33);
        let b = rand_mat(82, 33, 41);
        let serial = a.matmul(&b);
        for t in [1, 3, 8] {
            assert_eq!(serial, a.par_matmul(&b, &Pool::new(t)));
        }
    }

    #[test]
    fn par_gram_bit_identical_across_pools() {
        use crate::par::Pool;
        for (r, c) in [(1, 1), (6, 4), (40, 70), (70, 40)] {
            let a = rand_mat(r as u64 * 7 + c as u64, r, c);
            let gt = a.gram_t();
            let gn = a.gram_n();
            for t in [1, 2, 8] {
                let pool = Pool::new(t);
                assert_eq!(gt, a.par_gram_t(&pool), "gram_t {r}x{c} t={t}");
                assert_eq!(gn, a.par_gram_n(&pool), "gram_n {r}x{c} t={t}");
            }
        }
    }

    #[test]
    fn dot_matches_naive() {
        let mut r = Rng::new(2);
        for n in [0, 1, 3, 4, 7, 64, 129] {
            let a = r.normal_vec(n);
            let b = r.normal_vec(n);
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-9);
        }
    }

    #[test]
    fn frob_dot_is_trace_of_product() {
        let a = rand_mat(21, 5, 6);
        let b = rand_mat(22, 5, 6);
        // ⟨A,B⟩ = tr(A Bᵀ)
        let tr = a.matmul(&b.transpose()).trace();
        assert!((a.frob_dot(&b) - tr).abs() < 1e-9);
    }
}
