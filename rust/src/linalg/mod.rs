//! Dense f64 linear algebra, built from scratch for the LRC math.
//!
//! The paper's covariance computations "required 64-bit precision for
//! numerical accuracy", so everything here is f64.  Sizes are small
//! (d ≤ 512 in this reproduction) but hot: GEMM runs on the blocked-k /
//! register-tiled micro-kernel in [`kernels`] with a transposed-B layout,
//! Cholesky and the Jacobi eigensolver are the exact primitives
//! Algorithms 2–4 need.
//!
//! Every O(n³) product kernel follows the **canonical scalar program**
//! contract (see [`kernels`]): each output element is one accumulator
//! advanced in strictly ascending k.  Serial, blocked, chunked and
//! parallel paths are therefore bit-identical by construction — and
//! `matmul`/`gram_*` auto-parallelize on [`crate::par::global`] once the
//! work crosses [`PAR_MIN_WORK`] (suppressed automatically inside pool
//! jobs, so the per-layer fan-out never oversubscribes).  The explicit
//! `par_*` variants take a caller-supplied [`crate::par::Pool`].
//!
//! Inside the register tile the kernels dispatch to the [`simd`]
//! backends (SSE2/AVX2 on x86_64, NEON on aarch64, scalar fallback):
//! lanes run *across output elements* with separate mul-then-add, so the
//! per-element program — and therefore every bit — is unchanged on every
//! backend (`LRC_SIMD` / `--simd` select one explicitly; see the `simd`
//! module docs).  The opt-in `--fma` / `LRC_FMA` mode swaps the
//! per-element step for one fused multiply-add — a *different* canonical
//! program with its own lockstep oracle reference (see `simd`).
//!
//! Kernel scratch (packed panels, solver temporaries) comes from the
//! per-thread [`workspace`] arenas, so steady-state hot loops allocate
//! nothing; the `*_into` entry points ([`Mat::matmul_nt_into`],
//! [`Mat::gram_n_into`], …) extend that to the outputs by reusing a
//! caller-held matrix across calls (`tests/alloc_steady_state.rs`).

mod chol;
mod eigh;
mod hadamard;
pub mod kernels;
pub mod simd;
pub mod workspace;

pub use chol::{cholesky, solve_lower, solve_upper, chol_solve_mat, chol_inverse};
pub use eigh::{eigh, eigh_jacobi, eigh_jacobi_par, top_k_eigvecs};
pub use hadamard::{fwht, fwht_f32, hadamard_matrix};
pub use kernels::{matmul_nt_f32, matmul_nt_f32_into, pack_rows_f32,
                  PackedRowsF32};

/// Row-major dense f64 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        let r = rows.len();
        let c = if r > 0 { rows[0].len() } else { 0 };
        let mut data = Vec::with_capacity(r * c);
        for row in &rows {
            assert_eq!(row.len(), c);
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data: data.iter().map(|&x| x as f64).collect() }
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    /// Grow-only reshape to `rows × cols`, contents zeroed.  The backing
    /// `Vec` keeps its capacity, so reusing one `Mat` across same-shaped
    /// calls (the `*_into` kernel entry points, solver scratch) is
    /// allocation-free in steady state.
    pub fn resize_zeroed(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    /// In-place A += B (the accumulation the Σ statistics fold with —
    /// same `a + b` per element as [`Mat::add`], no temporary).
    pub fn add_assign(&mut self, b: &Mat) {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        for (a, x) in self.data.iter_mut().zip(&b.data) {
            *a += x;
        }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        // simple blocked transpose for cache friendliness
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    /// C = A · B
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul dims {}x{} · {}x{}",
                   self.rows, self.cols, b.rows, b.cols);
        // transpose B once so the inner loop is contiguous slices
        let bt = b.transpose();
        self.matmul_nt(&bt)
    }

    /// C = A · B on `pool` (row-chunked; bit-identical to [`Mat::matmul`]).
    pub fn par_matmul(&self, b: &Mat, pool: &crate::par::Pool) -> Mat {
        assert_eq!(self.cols, b.rows, "par_matmul dims {}x{} · {}x{}",
                   self.rows, self.cols, b.rows, b.cols);
        let bt = b.transpose();
        self.par_matmul_nt(&bt, pool)
    }

    /// C = A · Bᵀ  (B given as [n, k]: C[i,j] = Σ A[i,:]·B[j,:])
    ///
    /// Runs the blocked-k / register-tiled kernel of [`kernels`], and
    /// auto-parallelizes on [`crate::par::global`] once the work crosses
    /// [`PAR_MIN_WORK`] — bit-identical either way (canonical scalar
    /// program), and suppressed automatically inside pool jobs.
    pub fn matmul_nt(&self, bt: &Mat) -> Mat {
        let mut out = Mat::zeros(0, 0);
        self.matmul_nt_into(bt, &mut out);
        out
    }

    /// [`Mat::matmul_nt`] writing into a caller-held output (grow-only
    /// reshaped to m×n).  Reusing one `out` across same-shaped products
    /// makes the steady-state GEMM loop **allocation-free**: the packed
    /// panels come from the per-thread [`workspace`] arena and `out`
    /// keeps its capacity (`tests/alloc_steady_state.rs`).
    pub fn matmul_nt_into(&self, bt: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, bt.cols, "matmul_nt inner dims");
        let (m, n) = (self.rows, bt.rows);
        // decide serial BEFORE touching the global pool, so small-GEMM
        // and inside-a-pool-job workloads never spawn its workers at all
        if n == 0 || m <= Self::PAR_ROW_CHUNK
            || m * n * self.cols < PAR_MIN_WORK
            || crate::par::in_pool()
        {
            out.resize_zeroed(m, n);
            let packed = kernels::pack_rows(bt);
            kernels::matmul_nt_block(self, &packed, 0, m, &mut out.data);
            return;
        }
        self.par_matmul_nt_into(bt, crate::par::global(), out)
    }

    /// Fixed row-chunk size for parallel GEMM.  A scheduling granularity
    /// only: the canonical per-element program makes *any* chunking
    /// bit-identical, so the constant just balances dispatch overhead
    /// against load-balance (it is never derived from the thread count).
    pub const PAR_ROW_CHUNK: usize = 16;

    /// C = A · Bᵀ on `pool`: rows are split into fixed [`Mat::PAR_ROW_CHUNK`]
    /// chunks, each computed by the blocked kernel into its disjoint
    /// slice of C.  Bit-identical to the serial kernel for every thread
    /// count (each output element is produced by exactly the same
    /// floating-point program).
    pub fn par_matmul_nt(&self, bt: &Mat, pool: &crate::par::Pool) -> Mat {
        let mut out = Mat::zeros(0, 0);
        self.par_matmul_nt_into(bt, pool, &mut out);
        out
    }

    /// [`Mat::par_matmul_nt`] writing into a caller-held output.  Row
    /// chunks go out through the slot-free [`crate::par::Pool::for_indices`]
    /// dispatch with disjoint [`workspace::SharedSlice`] writes, so the
    /// pooled path allocates nothing beyond the (workspace-recycled) pack.
    pub fn par_matmul_nt_into(&self, bt: &Mat, pool: &crate::par::Pool,
                              out: &mut Mat) {
        assert_eq!(self.cols, bt.cols, "par_matmul_nt inner dims");
        let (m, n) = (self.rows, bt.rows);
        out.resize_zeroed(m, n);
        let work = m * n * self.cols;
        if n == 0 {
            return;
        }
        // pack Bᵀ into SIMD lane strips ONCE; every row chunk (and the
        // serial path) reads the same pack — the packing cost is one
        // transpose-sized pass per product, not per chunk
        let packed = kernels::pack_rows(bt);
        if pool.threads() == 1 || m <= Self::PAR_ROW_CHUNK
            || work < PAR_MIN_WORK
        {
            kernels::matmul_nt_block(self, &packed, 0, m, &mut out.data);
            return;
        }
        let chunk = Self::PAR_ROW_CHUNK;
        let n_chunks = m.div_ceil(chunk);
        let shared = workspace::SharedSlice::new(&mut out.data);
        pool.for_indices(n_chunks, |ci| {
            let r0 = ci * chunk;
            let r1 = (r0 + chunk).min(m);
            // SAFETY: row chunks [r0, r1) partition out — disjoint spans
            let slice = unsafe { shared.range(r0 * n, r1 * n) };
            kernels::matmul_nt_block(self, &packed, r0, r1, slice);
        });
    }

    /// C = Aᵀ · A (symmetric Gram matrix, only upper computed then
    /// mirrored; auto-parallel past [`PAR_MIN_WORK`], bit-identical).
    pub fn gram_t(&self) -> Mat {
        let at = self.transpose();
        let mut out = Mat::zeros(0, 0);
        gram_upper_auto_into(&at, &mut out);
        out
    }

    /// C = Aᵀ · A on `pool`: upper-triangle row segments computed in
    /// parallel, assembled + mirrored in fixed order.  Bit-identical to
    /// [`Mat::gram_t`] (every entry runs the same canonical program).
    pub fn par_gram_t(&self, pool: &crate::par::Pool) -> Mat {
        let at = self.transpose();
        let mut out = Mat::zeros(0, 0);
        gram_upper_into(&at, pool, &mut out);
        out
    }

    /// C = A · Aᵀ (symmetric, rows as vectors; auto-parallel past
    /// [`PAR_MIN_WORK`], bit-identical).
    pub fn gram_n(&self) -> Mat {
        let mut out = Mat::zeros(0, 0);
        gram_upper_auto_into(self, &mut out);
        out
    }

    /// [`Mat::gram_n`] writing into a caller-held output — with the pack
    /// workspace-recycled and the row segments written straight into the
    /// output's rows, a reused `out` makes the steady-state Gram loop
    /// allocation-free (`tests/alloc_steady_state.rs`).
    pub fn gram_n_into(&self, out: &mut Mat) {
        gram_upper_auto_into(self, out);
    }

    /// C = A · Aᵀ on `pool` (see [`Mat::par_gram_t`]; bit-identical to
    /// [`Mat::gram_n`]).
    pub fn par_gram_n(&self, pool: &crate::par::Pool) -> Mat {
        let mut out = Mat::zeros(0, 0);
        gram_upper_into(self, pool, &mut out);
        out
    }

    /// y = A · x
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows).map(|i| dot(self.row(i), x)).collect()
    }

    pub fn scale(&self, s: f64) -> Mat {
        Mat { rows: self.rows, cols: self.cols,
              data: self.data.iter().map(|&x| x * s).collect() }
    }

    pub fn add(&self, b: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        let data = self.data.iter().zip(&b.data).map(|(x, y)| x + y).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    pub fn sub(&self, b: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        let data = self.data.iter().zip(&b.data).map(|(x, y)| x - y).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// In-place A += s·I
    pub fn add_diag(&mut self, s: f64) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self.data[i * self.cols + i] += s;
        }
    }

    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self.data[i * self.cols + i]).sum()
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Frobenius inner product ⟨A, B⟩.
    pub fn frob_dot(&self, b: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        dot(&self.data, &b.data)
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0_f64, |a, &x| a.max(x.abs()))
    }

    /// Extract columns [c0, c1) as a new matrix.
    pub fn cols_range(&self, c0: usize, c1: usize) -> Mat {
        let mut out = Mat::zeros(0, 0);
        self.cols_range_into(c0, c1, &mut out);
        out
    }

    /// [`Mat::cols_range`] into a caller-held (e.g. workspace-recycled)
    /// matrix — the Σ-accumulation chunk loop reuses one slice buffer
    /// this way instead of allocating per chunk.
    pub fn cols_range_into(&self, c0: usize, c1: usize, out: &mut Mat) {
        assert!(c0 <= c1 && c1 <= self.cols);
        out.resize_zeroed(self.rows, c1 - c0);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[c0..c1]);
        }
    }

    pub fn random_normal(rng: &mut crate::rng::Rng, rows: usize, cols: usize)
                         -> Mat {
        Mat { rows, cols, data: rng.normal_vec(rows * cols) }
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Auto-parallelization threshold in multiply-adds (≈ 0.5 ms of serial
/// work): below it, epoch dispatch costs more than it buys.  Shape-based
/// and compile-time fixed, so the serial/parallel decision is itself
/// deterministic — and harmless either way, since both paths produce
/// identical bits.
pub const PAR_MIN_WORK: usize = 1 << 20;

/// Auto-parallel gram: pick serial below [`PAR_MIN_WORK`] without ever
/// touching (and therefore initializing) the global pool.
fn gram_upper_auto_into(src: &Mat, out: &mut Mat) {
    let m = src.rows;
    if m <= 1 || m * m * src.cols / 2 < PAR_MIN_WORK || crate::par::in_pool() {
        gram_upper_into(src, &crate::par::Pool::serial(), out)
    } else {
        gram_upper_into(src, crate::par::global(), out)
    }
}

/// Shared body of the gram entry points: upper-triangle row segments
/// (each on the canonical scalar program of
/// [`kernels::gram_row_segment_into`]), written **directly into the
/// output matrix's rows** — row `i`'s segment is the disjoint span
/// `out[i, i..]`, handed to the pool through a
/// [`workspace::SharedSlice`] — then mirrored in fixed order.  The
/// source rows are packed into SIMD lane strips once (workspace-
/// recycled), amortized over every segment; no path allocates a
/// per-row vector.
fn gram_upper_into(src: &Mat, pool: &crate::par::Pool, out: &mut Mat) {
    let m = src.rows;
    let work = m * m * src.cols / 2;
    out.resize_zeroed(m, m);
    let packed = kernels::pack_rows(src);
    {
        let shared = workspace::SharedSlice::new(&mut out.data);
        let seg = |i: usize| {
            // SAFETY: segment i lives in out row i — rows are disjoint
            let row = unsafe { shared.range(i * m + i, (i + 1) * m) };
            kernels::gram_row_segment_into(src, &packed, i, row);
        };
        if pool.threads() == 1 || m <= 1 || work < PAR_MIN_WORK {
            for i in 0..m {
                seg(i);
            }
        } else {
            pool.for_indices(m, seg);
        }
    }
    // mirror the strict upper triangle (fixed order, plain copies)
    for i in 0..m {
        for j in i + 1..m {
            out.data[j * m + i] = out.data[i * m + j];
        }
    }
}

/// Unrolled dot product — the single hottest scalar loop in the crate.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    let chunks = n / 4;
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// axpy: y += a·x
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_mat(seed: u64, r: usize, c: usize) -> Mat {
        Mat::random_normal(&mut Rng::new(seed), r, c)
    }

    #[test]
    fn matmul_identity() {
        let a = rand_mat(1, 5, 7);
        let i = Mat::eye(7);
        let c = a.matmul(&i);
        for (x, y) in a.data.iter().zip(&c.data) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(vec![vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_associativity_property() {
        // property: (AB)C == A(BC) within fp tolerance, random shapes
        for seed in 0..5 {
            let mut r = Rng::new(seed);
            let (m, k, n, p) = (2 + r.below(6), 2 + r.below(6),
                                2 + r.below(6), 2 + r.below(6));
            let a = rand_mat(seed * 3 + 1, m, k);
            let b = rand_mat(seed * 3 + 2, k, n);
            let c = rand_mat(seed * 3 + 3, n, p);
            let left = a.matmul(&b).matmul(&c);
            let right = a.matmul(&b.matmul(&c));
            assert!(left.sub(&right).max_abs() < 1e-9);
        }
    }

    #[test]
    fn transpose_involution() {
        let a = rand_mat(5, 9, 4);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn gram_matches_matmul() {
        let a = rand_mat(11, 6, 4);
        let g1 = a.gram_t();                  // AᵀA
        let g2 = a.transpose().matmul(&a);
        assert!(g1.sub(&g2).max_abs() < 1e-10);
        let h1 = a.gram_n();                  // AAᵀ
        let h2 = a.matmul(&a.transpose());
        assert!(h1.sub(&h2).max_abs() < 1e-10);
    }

    #[test]
    fn par_matmul_nt_bit_identical_across_pools() {
        // property: the parallel kernel equals the serial one EXACTLY
        // (==, not ≈) for every thread count, including ragged shapes
        // around the chunk boundary and odd row counts
        use crate::par::Pool;
        for (m, k, n) in [(1, 5, 1), (2, 3, 2), (7, 9, 5), (63, 17, 31),
                          (64, 8, 65), (65, 8, 64), (129, 33, 66)] {
            let a = rand_mat(m as u64 * 31 + n as u64, m, k);
            let b = rand_mat(m as u64 * 17 + k as u64, n, k);
            let serial = a.matmul_nt(&b);
            for t in [1, 2, 8] {
                let par = a.par_matmul_nt(&b, &Pool::new(t));
                assert_eq!(serial, par, "{m}x{k}·{n}ᵀ threads={t}");
            }
        }
    }

    #[test]
    fn par_matmul_matches_matmul() {
        use crate::par::Pool;
        let a = rand_mat(81, 70, 33);
        let b = rand_mat(82, 33, 41);
        let serial = a.matmul(&b);
        for t in [1, 3, 8] {
            assert_eq!(serial, a.par_matmul(&b, &Pool::new(t)));
        }
    }

    #[test]
    fn par_gram_bit_identical_across_pools() {
        use crate::par::Pool;
        for (r, c) in [(1, 1), (6, 4), (40, 70), (70, 40)] {
            let a = rand_mat(r as u64 * 7 + c as u64, r, c);
            let gt = a.gram_t();
            let gn = a.gram_n();
            for t in [1, 2, 8] {
                let pool = Pool::new(t);
                assert_eq!(gt, a.par_gram_t(&pool), "gram_t {r}x{c} t={t}");
                assert_eq!(gn, a.par_gram_n(&pool), "gram_n {r}x{c} t={t}");
            }
        }
    }

    #[test]
    fn into_variants_match_and_reshape_across_calls() {
        // one reused output must track shape changes and stay bit-equal
        // to the allocating entry points
        let mut out = Mat::zeros(0, 0);
        for (m, k, n) in [(7usize, 5usize, 9usize), (17, 16, 15), (3, 8, 2),
                          (17, 16, 15)] {
            let a = rand_mat(m as u64 * 13 + k as u64, m, k);
            let bt = rand_mat(n as u64 * 11 + k as u64, n, k);
            a.matmul_nt_into(&bt, &mut out);
            assert_eq!(out, a.matmul_nt(&bt), "{m}x{k}·{n}ᵀ");
        }
        for (r, c) in [(6usize, 4usize), (12, 9), (6, 4)] {
            let a = rand_mat(r as u64 * 5 + c as u64, r, c);
            a.gram_n_into(&mut out);
            assert_eq!(out, a.gram_n(), "gram {r}x{c}");
        }
        use crate::par::Pool;
        let a = rand_mat(91, 40, 12);
        let bt = rand_mat(92, 33, 12);
        a.par_matmul_nt_into(&bt, &Pool::new(3), &mut out);
        assert_eq!(out, a.matmul_nt(&bt));
    }

    #[test]
    fn add_assign_matches_add_bitwise() {
        let a = rand_mat(61, 9, 7);
        let b = rand_mat(62, 9, 7);
        let sum = a.add(&b);
        let mut acc = a.clone();
        acc.add_assign(&b);
        assert_eq!(acc, sum);
    }

    #[test]
    fn cols_range_into_matches_cols_range() {
        let a = rand_mat(63, 6, 10);
        let mut out = Mat::zeros(0, 0);
        for (c0, c1) in [(0usize, 10usize), (3, 7), (9, 10), (4, 4)] {
            a.cols_range_into(c0, c1, &mut out);
            assert_eq!(out, a.cols_range(c0, c1), "[{c0}, {c1})");
        }
    }

    #[test]
    fn resize_zeroed_clears_and_reshapes() {
        let mut m = rand_mat(64, 4, 5);
        let cap = m.data.capacity();
        m.resize_zeroed(2, 3);
        assert_eq!((m.rows, m.cols), (2, 3));
        assert!(m.data.iter().all(|&x| x == 0.0));
        assert!(m.data.capacity() >= cap.min(6));
    }

    #[test]
    fn dot_matches_naive() {
        let mut r = Rng::new(2);
        for n in [0, 1, 3, 4, 7, 64, 129] {
            let a = r.normal_vec(n);
            let b = r.normal_vec(n);
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-9);
        }
    }

    #[test]
    fn frob_dot_is_trace_of_product() {
        let a = rand_mat(21, 5, 6);
        let b = rand_mat(22, 5, 6);
        // ⟨A,B⟩ = tr(A Bᵀ)
        let tr = a.matmul(&b.transpose()).trace();
        assert!((a.frob_dot(&b) - tr).abs() < 1e-9);
    }
}
