//! Dense f64 linear algebra, built from scratch for the LRC math.
//!
//! The paper's covariance computations "required 64-bit precision for
//! numerical accuracy", so everything here is f64.  Sizes are small
//! (d ≤ 512 in this reproduction) but hot: GEMM runs on the blocked-k /
//! register-tiled micro-kernel in [`kernels`] with a transposed-B layout,
//! Cholesky and the Jacobi eigensolver are the exact primitives
//! Algorithms 2–4 need.
//!
//! Every O(n³) product kernel follows the **canonical scalar program**
//! contract (see [`kernels`]): each output element is one accumulator
//! advanced in strictly ascending k.  Serial, blocked, chunked and
//! parallel paths are therefore bit-identical by construction — and
//! `matmul`/`gram_*` auto-parallelize on [`crate::par::global`] once the
//! work crosses [`PAR_MIN_WORK`] (suppressed automatically inside pool
//! jobs, so the per-layer fan-out never oversubscribes).  The explicit
//! `par_*` variants take a caller-supplied [`crate::par::Pool`].
//!
//! Inside the register tile the kernels dispatch to the [`simd`]
//! backends (SSE2/AVX2 on x86_64, NEON on aarch64, scalar fallback):
//! lanes run *across output elements* with separate mul-then-add, so the
//! per-element program — and therefore every bit — is unchanged on every
//! backend (`LRC_SIMD` / `--simd` select one explicitly; see the `simd`
//! module docs).

mod chol;
mod eigh;
mod hadamard;
pub mod kernels;
pub mod simd;

pub use chol::{cholesky, solve_lower, solve_upper, chol_solve_mat, chol_inverse};
pub use eigh::{eigh, eigh_jacobi, eigh_jacobi_par, top_k_eigvecs};
pub use hadamard::{fwht, fwht_f32, hadamard_matrix};

/// Row-major dense f64 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        let r = rows.len();
        let c = if r > 0 { rows[0].len() } else { 0 };
        let mut data = Vec::with_capacity(r * c);
        for row in &rows {
            assert_eq!(row.len(), c);
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data: data.iter().map(|&x| x as f64).collect() }
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        // simple blocked transpose for cache friendliness
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    /// C = A · B
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul dims {}x{} · {}x{}",
                   self.rows, self.cols, b.rows, b.cols);
        // transpose B once so the inner loop is contiguous slices
        let bt = b.transpose();
        self.matmul_nt(&bt)
    }

    /// C = A · B on `pool` (row-chunked; bit-identical to [`Mat::matmul`]).
    pub fn par_matmul(&self, b: &Mat, pool: &crate::par::Pool) -> Mat {
        assert_eq!(self.cols, b.rows, "par_matmul dims {}x{} · {}x{}",
                   self.rows, self.cols, b.rows, b.cols);
        let bt = b.transpose();
        self.par_matmul_nt(&bt, pool)
    }

    /// C = A · Bᵀ  (B given as [n, k]: C[i,j] = Σ A[i,:]·B[j,:])
    ///
    /// Runs the blocked-k / register-tiled kernel of [`kernels`], and
    /// auto-parallelizes on [`crate::par::global`] once the work crosses
    /// [`PAR_MIN_WORK`] — bit-identical either way (canonical scalar
    /// program), and suppressed automatically inside pool jobs.
    pub fn matmul_nt(&self, bt: &Mat) -> Mat {
        assert_eq!(self.cols, bt.cols, "matmul_nt inner dims");
        let (m, n) = (self.rows, bt.rows);
        // decide serial BEFORE touching the global pool, so small-GEMM
        // and inside-a-pool-job workloads never spawn its workers at all
        if n == 0 || m <= Self::PAR_ROW_CHUNK
            || m * n * self.cols < PAR_MIN_WORK
            || crate::par::in_pool()
        {
            let mut out = Mat::zeros(m, n);
            let packed = kernels::pack_rows(bt);
            kernels::matmul_nt_block(self, &packed, 0, m, &mut out.data);
            return out;
        }
        self.par_matmul_nt(bt, crate::par::global())
    }

    /// Fixed row-chunk size for parallel GEMM.  A scheduling granularity
    /// only: the canonical per-element program makes *any* chunking
    /// bit-identical, so the constant just balances dispatch overhead
    /// against load-balance (it is never derived from the thread count).
    pub const PAR_ROW_CHUNK: usize = 16;

    /// C = A · Bᵀ on `pool`: rows are split into fixed [`Mat::PAR_ROW_CHUNK`]
    /// chunks, each computed by the blocked kernel into its disjoint
    /// slice of C.  Bit-identical to the serial kernel for every thread
    /// count (each output element is produced by exactly the same
    /// floating-point program).
    pub fn par_matmul_nt(&self, bt: &Mat, pool: &crate::par::Pool) -> Mat {
        assert_eq!(self.cols, bt.cols, "par_matmul_nt inner dims");
        let (m, n) = (self.rows, bt.rows);
        let mut out = Mat::zeros(m, n);
        let work = m * n * self.cols;
        if n == 0 {
            return out;
        }
        // pack Bᵀ into SIMD lane strips ONCE; every row chunk (and the
        // serial path) reads the same pack — the packing cost is one
        // transpose-sized pass per product, not per chunk
        let packed = kernels::pack_rows(bt);
        if pool.threads() == 1 || m <= Self::PAR_ROW_CHUNK
            || work < PAR_MIN_WORK
        {
            kernels::matmul_nt_block(self, &packed, 0, m, &mut out.data);
            return out;
        }
        let chunk = Self::PAR_ROW_CHUNK;
        let slices: Vec<(usize, &mut [f64])> =
            out.data.chunks_mut(chunk * n).enumerate().collect();
        pool.for_each(slices, |(ci, slice)| {
            let r0 = ci * chunk;
            let r1 = (r0 + chunk).min(m);
            kernels::matmul_nt_block(self, &packed, r0, r1, slice);
        });
        out
    }

    /// C = Aᵀ · A (symmetric Gram matrix, only upper computed then
    /// mirrored; auto-parallel past [`PAR_MIN_WORK`], bit-identical).
    pub fn gram_t(&self) -> Mat {
        let at = self.transpose();
        gram_upper_auto(&at)
    }

    /// C = Aᵀ · A on `pool`: upper-triangle row segments computed in
    /// parallel, assembled + mirrored in fixed order.  Bit-identical to
    /// [`Mat::gram_t`] (every entry runs the same canonical program).
    pub fn par_gram_t(&self, pool: &crate::par::Pool) -> Mat {
        let at = self.transpose();
        gram_upper(&at, pool)
    }

    /// C = A · Aᵀ (symmetric, rows as vectors; auto-parallel past
    /// [`PAR_MIN_WORK`], bit-identical).
    pub fn gram_n(&self) -> Mat {
        gram_upper_auto(self)
    }

    /// C = A · Aᵀ on `pool` (see [`Mat::par_gram_t`]; bit-identical to
    /// [`Mat::gram_n`]).
    pub fn par_gram_n(&self, pool: &crate::par::Pool) -> Mat {
        gram_upper(self, pool)
    }

    /// y = A · x
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows).map(|i| dot(self.row(i), x)).collect()
    }

    pub fn scale(&self, s: f64) -> Mat {
        Mat { rows: self.rows, cols: self.cols,
              data: self.data.iter().map(|&x| x * s).collect() }
    }

    pub fn add(&self, b: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        let data = self.data.iter().zip(&b.data).map(|(x, y)| x + y).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    pub fn sub(&self, b: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        let data = self.data.iter().zip(&b.data).map(|(x, y)| x - y).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// In-place A += s·I
    pub fn add_diag(&mut self, s: f64) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self.data[i * self.cols + i] += s;
        }
    }

    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self.data[i * self.cols + i]).sum()
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Frobenius inner product ⟨A, B⟩.
    pub fn frob_dot(&self, b: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        dot(&self.data, &b.data)
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0_f64, |a, &x| a.max(x.abs()))
    }

    /// Extract columns [c0, c1) as a new matrix.
    pub fn cols_range(&self, c0: usize, c1: usize) -> Mat {
        assert!(c0 <= c1 && c1 <= self.cols);
        let mut out = Mat::zeros(self.rows, c1 - c0);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    pub fn random_normal(rng: &mut crate::rng::Rng, rows: usize, cols: usize)
                         -> Mat {
        Mat { rows, cols, data: rng.normal_vec(rows * cols) }
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Auto-parallelization threshold in multiply-adds (≈ 0.5 ms of serial
/// work): below it, epoch dispatch costs more than it buys.  Shape-based
/// and compile-time fixed, so the serial/parallel decision is itself
/// deterministic — and harmless either way, since both paths produce
/// identical bits.
pub const PAR_MIN_WORK: usize = 1 << 20;

/// Auto-parallel gram: pick serial below [`PAR_MIN_WORK`] without ever
/// touching (and therefore initializing) the global pool.
fn gram_upper_auto(src: &Mat) -> Mat {
    let m = src.rows;
    if m <= 1 || m * m * src.cols / 2 < PAR_MIN_WORK || crate::par::in_pool() {
        gram_upper(src, &crate::par::Pool::serial())
    } else {
        gram_upper(src, crate::par::global())
    }
}

/// Shared body of the four gram entry points: upper-triangle row segments
/// (each on the canonical scalar program of
/// [`kernels::gram_row_segment_packed`]), computed serially or on the
/// pool, then assembled + mirrored in fixed row order.  The source rows
/// are packed into SIMD lane strips once, amortized over every segment.
fn gram_upper(src: &Mat, pool: &crate::par::Pool) -> Mat {
    let m = src.rows;
    let work = m * m * src.cols / 2;
    let packed = kernels::pack_rows(src);
    let rows: Vec<Vec<f64>> =
        if pool.threads() == 1 || m <= 1 || work < PAR_MIN_WORK {
            (0..m)
                .map(|i| kernels::gram_row_segment_packed(src, &packed, i))
                .collect()
        } else {
            pool.map(m, |i| kernels::gram_row_segment_packed(src, &packed, i))
        };
    let mut out = Mat::zeros(m, m);
    for (i, seg) in rows.iter().enumerate() {
        for (off, &v) in seg.iter().enumerate() {
            let j = i + off;
            out.data[i * m + j] = v;
            out.data[j * m + i] = v;
        }
    }
    out
}

/// Unrolled dot product — the single hottest scalar loop in the crate.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    let chunks = n / 4;
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// axpy: y += a·x
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_mat(seed: u64, r: usize, c: usize) -> Mat {
        Mat::random_normal(&mut Rng::new(seed), r, c)
    }

    #[test]
    fn matmul_identity() {
        let a = rand_mat(1, 5, 7);
        let i = Mat::eye(7);
        let c = a.matmul(&i);
        for (x, y) in a.data.iter().zip(&c.data) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(vec![vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_associativity_property() {
        // property: (AB)C == A(BC) within fp tolerance, random shapes
        for seed in 0..5 {
            let mut r = Rng::new(seed);
            let (m, k, n, p) = (2 + r.below(6), 2 + r.below(6),
                                2 + r.below(6), 2 + r.below(6));
            let a = rand_mat(seed * 3 + 1, m, k);
            let b = rand_mat(seed * 3 + 2, k, n);
            let c = rand_mat(seed * 3 + 3, n, p);
            let left = a.matmul(&b).matmul(&c);
            let right = a.matmul(&b.matmul(&c));
            assert!(left.sub(&right).max_abs() < 1e-9);
        }
    }

    #[test]
    fn transpose_involution() {
        let a = rand_mat(5, 9, 4);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn gram_matches_matmul() {
        let a = rand_mat(11, 6, 4);
        let g1 = a.gram_t();                  // AᵀA
        let g2 = a.transpose().matmul(&a);
        assert!(g1.sub(&g2).max_abs() < 1e-10);
        let h1 = a.gram_n();                  // AAᵀ
        let h2 = a.matmul(&a.transpose());
        assert!(h1.sub(&h2).max_abs() < 1e-10);
    }

    #[test]
    fn par_matmul_nt_bit_identical_across_pools() {
        // property: the parallel kernel equals the serial one EXACTLY
        // (==, not ≈) for every thread count, including ragged shapes
        // around the chunk boundary and odd row counts
        use crate::par::Pool;
        for (m, k, n) in [(1, 5, 1), (2, 3, 2), (7, 9, 5), (63, 17, 31),
                          (64, 8, 65), (65, 8, 64), (129, 33, 66)] {
            let a = rand_mat(m as u64 * 31 + n as u64, m, k);
            let b = rand_mat(m as u64 * 17 + k as u64, n, k);
            let serial = a.matmul_nt(&b);
            for t in [1, 2, 8] {
                let par = a.par_matmul_nt(&b, &Pool::new(t));
                assert_eq!(serial, par, "{m}x{k}·{n}ᵀ threads={t}");
            }
        }
    }

    #[test]
    fn par_matmul_matches_matmul() {
        use crate::par::Pool;
        let a = rand_mat(81, 70, 33);
        let b = rand_mat(82, 33, 41);
        let serial = a.matmul(&b);
        for t in [1, 3, 8] {
            assert_eq!(serial, a.par_matmul(&b, &Pool::new(t)));
        }
    }

    #[test]
    fn par_gram_bit_identical_across_pools() {
        use crate::par::Pool;
        for (r, c) in [(1, 1), (6, 4), (40, 70), (70, 40)] {
            let a = rand_mat(r as u64 * 7 + c as u64, r, c);
            let gt = a.gram_t();
            let gn = a.gram_n();
            for t in [1, 2, 8] {
                let pool = Pool::new(t);
                assert_eq!(gt, a.par_gram_t(&pool), "gram_t {r}x{c} t={t}");
                assert_eq!(gn, a.par_gram_n(&pool), "gram_n {r}x{c} t={t}");
            }
        }
    }

    #[test]
    fn dot_matches_naive() {
        let mut r = Rng::new(2);
        for n in [0, 1, 3, 4, 7, 64, 129] {
            let a = r.normal_vec(n);
            let b = r.normal_vec(n);
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-9);
        }
    }

    #[test]
    fn frob_dot_is_trace_of_product() {
        let a = rand_mat(21, 5, 6);
        let b = rand_mat(22, 5, 6);
        // ⟨A,B⟩ = tr(A Bᵀ)
        let tr = a.matmul(&b.transpose()).trace();
        assert!((a.frob_dot(&b) - tr).abs() < 1e-9);
    }
}
