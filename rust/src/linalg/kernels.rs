//! Blocked-k / register-tiled GEMM micro-kernels.
//!
//! # The canonical-scalar-program contract
//!
//! Every output element these kernels produce is computed by **one fixed
//! floating-point program**: a single accumulator that adds
//! `a[i,k]·b[j,k]` in strictly ascending `k`.  Blocking and tiling change
//! only the *order in which different elements advance* (cache locality)
//! and how many independent accumulator chains are in flight at once
//! (instruction-level parallelism); they never reassociate the sum inside
//! one element.  Two consequences, both load-bearing:
//!
//!   * the result is **bit-identical to the naive triple loop** — the
//!     randomized oracle in `tests/kernel_oracle.rs` asserts `==` on f64,
//!   * any row chunking is bit-identical too, so the serial and parallel
//!     paths agree at every thread count *by construction* (no careful
//!     chunk-alignment argument needed, unlike the old 2×2 kernel).
//!
//! # Block schedule
//!
//! Compile-time fixed — never derived from the thread count or the host:
//! [`NC`]-row panels of Bᵀ are held hot while [`KC`]-wide k-panels stream
//! through [`MR`]×[`NR`] register tiles.  The MR×NR tile carries 16
//! independent accumulator chains, which is what covers the FP-add
//! latency×throughput product on current cores; KC·(MR+NR) f64 ≈ 16 KB
//! keeps the active slices in L1, and the NC×KC B-panel (128 KB) in L2.

use super::Mat;

/// Register-tile rows (A rows advanced together).
pub const MR: usize = 4;
/// Register-tile columns (Bᵀ rows advanced together).
pub const NR: usize = 4;
/// k-panel width: columns of A/Bᵀ processed per pass.
pub const KC: usize = 256;
/// Output-column panel: Bᵀ rows kept hot across one row sweep.
pub const NC: usize = 64;

/// C[r0..r1, :] = A[r0..r1, :]·Bᵀ, written into `out` (row-major,
/// `(r1-r0) × bt.rows`, rows indexed relative to `r0`).
///
/// `out` must be zero-initialized: the kernel accumulates k-panels into
/// it, which is exactly what keeps every element on the canonical
/// ascending-k program.
pub(crate) fn matmul_nt_block(a: &Mat, bt: &Mat, r0: usize, r1: usize,
                              out: &mut [f64]) {
    let n = bt.rows;
    let kd = a.cols;
    debug_assert_eq!(out.len(), (r1 - r0) * n);
    let mut jc = 0;
    while jc < n {
        let jc_hi = (jc + NC).min(n);
        let mut kc = 0;
        while kc < kd {
            let kc_hi = (kc + KC).min(kd);
            let mut i = r0;
            while i < r1 {
                let i_hi = (i + MR).min(r1);
                let mut j = jc;
                while j < jc_hi {
                    let j_hi = (j + NR).min(jc_hi);
                    if i_hi - i == MR && j_hi - j == NR {
                        tile_full(a, bt, i, j, kc, kc_hi, r0, n, out);
                    } else {
                        tile_edge(a, bt, i, i_hi, j, j_hi, kc, kc_hi, r0, n,
                                  out);
                    }
                    j = j_hi;
                }
                i = i_hi;
            }
            kc = kc_hi;
        }
        jc = jc_hi;
    }
}

/// The MR×NR register tile over one k-panel: 16 accumulator chains, each
/// strictly ascending in k.
#[allow(clippy::too_many_arguments)]
#[inline]
fn tile_full(a: &Mat, bt: &Mat, i: usize, j: usize, k0: usize, k1: usize,
             r0: usize, n: usize, out: &mut [f64]) {
    let a0 = &a.row(i)[k0..k1];
    let a1 = &a.row(i + 1)[k0..k1];
    let a2 = &a.row(i + 2)[k0..k1];
    let a3 = &a.row(i + 3)[k0..k1];
    let b0 = &bt.row(j)[k0..k1];
    let b1 = &bt.row(j + 1)[k0..k1];
    let b2 = &bt.row(j + 2)[k0..k1];
    let b3 = &bt.row(j + 3)[k0..k1];
    let o0 = (i - r0) * n + j;
    let o1 = o0 + n;
    let o2 = o1 + n;
    let o3 = o2 + n;
    let (mut c00, mut c01, mut c02, mut c03) =
        (out[o0], out[o0 + 1], out[o0 + 2], out[o0 + 3]);
    let (mut c10, mut c11, mut c12, mut c13) =
        (out[o1], out[o1 + 1], out[o1 + 2], out[o1 + 3]);
    let (mut c20, mut c21, mut c22, mut c23) =
        (out[o2], out[o2 + 1], out[o2 + 2], out[o2 + 3]);
    let (mut c30, mut c31, mut c32, mut c33) =
        (out[o3], out[o3 + 1], out[o3 + 2], out[o3 + 3]);
    for k in 0..k1 - k0 {
        let (x0, x1, x2, x3) = (a0[k], a1[k], a2[k], a3[k]);
        let (y0, y1, y2, y3) = (b0[k], b1[k], b2[k], b3[k]);
        c00 += x0 * y0;
        c01 += x0 * y1;
        c02 += x0 * y2;
        c03 += x0 * y3;
        c10 += x1 * y0;
        c11 += x1 * y1;
        c12 += x1 * y2;
        c13 += x1 * y3;
        c20 += x2 * y0;
        c21 += x2 * y1;
        c22 += x2 * y2;
        c23 += x2 * y3;
        c30 += x3 * y0;
        c31 += x3 * y1;
        c32 += x3 * y2;
        c33 += x3 * y3;
    }
    out[o0] = c00;
    out[o0 + 1] = c01;
    out[o0 + 2] = c02;
    out[o0 + 3] = c03;
    out[o1] = c10;
    out[o1 + 1] = c11;
    out[o1 + 2] = c12;
    out[o1 + 3] = c13;
    out[o2] = c20;
    out[o2 + 1] = c21;
    out[o2 + 2] = c22;
    out[o2 + 3] = c23;
    out[o3] = c30;
    out[o3 + 1] = c31;
    out[o3 + 2] = c32;
    out[o3 + 3] = c33;
}

/// Ragged tile at the matrix edges — same per-element program, just
/// without the fixed-size register block.
#[allow(clippy::too_many_arguments)]
#[inline]
fn tile_edge(a: &Mat, bt: &Mat, i0: usize, i1: usize, j0: usize, j1: usize,
             k0: usize, k1: usize, r0: usize, n: usize, out: &mut [f64]) {
    for i in i0..i1 {
        let ar = &a.row(i)[k0..k1];
        let orow = (i - r0) * n;
        for j in j0..j1 {
            let br = &bt.row(j)[k0..k1];
            let mut s = out[orow + j];
            for (x, y) in ar.iter().zip(br) {
                s += x * y;
            }
            out[orow + j] = s;
        }
    }
}

/// Row `i` of the upper triangle of `src·srcᵀ`: the segment
/// `[Σ_k src[i,k]·src[j,k] for j in i..src.rows]`.
///
/// Every element follows the same canonical ascending-k program as the
/// GEMM kernel, so serial loops, parallel row maps and any chunking all
/// produce identical bits.  The j-direction is tiled by [`NR`] so the
/// `src.row(i)` loads are amortized over four accumulator chains.
pub(crate) fn gram_row_segment(src: &Mat, i: usize) -> Vec<f64> {
    let m = src.rows;
    let ri = src.row(i);
    let mut seg = Vec::with_capacity(m - i);
    let mut j = i;
    while j + NR <= m {
        let b0 = src.row(j);
        let b1 = src.row(j + 1);
        let b2 = src.row(j + 2);
        let b3 = src.row(j + 3);
        let (mut s0, mut s1, mut s2, mut s3) = (0.0_f64, 0.0, 0.0, 0.0);
        for (k, &x) in ri.iter().enumerate() {
            s0 += x * b0[k];
            s1 += x * b1[k];
            s2 += x * b2[k];
            s3 += x * b3[k];
        }
        seg.push(s0);
        seg.push(s1);
        seg.push(s2);
        seg.push(s3);
        j += NR;
    }
    while j < m {
        let bj = src.row(j);
        let mut s = 0.0_f64;
        for (x, y) in ri.iter().zip(bj) {
            s += x * y;
        }
        seg.push(s);
        j += 1;
    }
    seg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// The independent naive reference: single accumulator, ascending k.
    fn naive_nt(a: &Mat, bt: &Mat) -> Mat {
        let mut out = Mat::zeros(a.rows, bt.rows);
        for i in 0..a.rows {
            for j in 0..bt.rows {
                let mut s = 0.0_f64;
                for k in 0..a.cols {
                    s += a[(i, k)] * bt[(j, k)];
                }
                out[(i, j)] = s;
            }
        }
        out
    }

    #[test]
    fn blocked_kernel_bit_identical_to_naive() {
        // shapes straddling every block boundary: MR/NR (4), NC (64),
        // KC (256), plus degenerate edges
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (1, 9, 1), (3, 4, 5),
                            (4, 4, 4), (5, 5, 5), (8, 300, 8), (7, 257, 9),
                            (12, 64, 65), (4, 256, 4), (13, 255, 66),
                            (65, 17, 63)] {
            let a = Mat::random_normal(&mut Rng::new(m as u64 * 101 + k as u64), m, k);
            let bt = Mat::random_normal(&mut Rng::new(n as u64 * 77 + k as u64), n, k);
            let mut out = vec![0.0_f64; m * n];
            matmul_nt_block(&a, &bt, 0, m, &mut out);
            assert_eq!(out, naive_nt(&a, &bt).data, "{m}x{k}·{n}ᵀ");
        }
    }

    #[test]
    fn row_ranges_compose_exactly() {
        // any split point reproduces the full result bit for bit
        let (m, k, n) = (23, 31, 19);
        let a = Mat::random_normal(&mut Rng::new(1), m, k);
        let bt = Mat::random_normal(&mut Rng::new(2), n, k);
        let mut full = vec![0.0_f64; m * n];
        matmul_nt_block(&a, &bt, 0, m, &mut full);
        for split in [1usize, 4, 7, 16, 22] {
            let mut top = vec![0.0_f64; split * n];
            let mut bot = vec![0.0_f64; (m - split) * n];
            matmul_nt_block(&a, &bt, 0, split, &mut top);
            matmul_nt_block(&a, &bt, split, m, &mut bot);
            top.extend_from_slice(&bot);
            assert_eq!(top, full, "split {split}");
        }
    }

    #[test]
    fn gram_segments_match_naive() {
        for &(m, k) in &[(1usize, 1usize), (5, 3), (9, 300), (12, 7)] {
            let src = Mat::random_normal(&mut Rng::new(m as u64 * 7 + k as u64), m, k);
            for i in 0..m {
                let seg = gram_row_segment(&src, i);
                assert_eq!(seg.len(), m - i);
                for (off, &v) in seg.iter().enumerate() {
                    let j = i + off;
                    let mut s = 0.0_f64;
                    for kk in 0..k {
                        s += src[(i, kk)] * src[(j, kk)];
                    }
                    assert_eq!(v, s, "({i},{j}) of {m}x{k}");
                }
            }
        }
    }
}
