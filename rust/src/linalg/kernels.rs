//! Blocked-k / register-tiled GEMM micro-kernels with packed-lane SIMD
//! dispatch.
//!
//! # The canonical-scalar-program contract
//!
//! Every output element these kernels produce is computed by **one fixed
//! floating-point program**: a single accumulator that adds
//! `a[i,k]·b[j,k]` in strictly ascending `k`, one IEEE mul followed by
//! one IEEE add per step.  Blocking, tiling and vectorization change only
//! the *order in which different elements advance* (cache locality) and
//! how many independent accumulator chains are in flight at once
//! (instruction- and data-level parallelism); they never reassociate the
//! sum inside one element.  Two consequences, both load-bearing:
//!
//!   * the result is **bit-identical to the naive triple loop** — the
//!     randomized oracle in `tests/kernel_oracle.rs` asserts `==` on f64
//!     for every available SIMD backend,
//!   * any row chunking is bit-identical too, so the serial and parallel
//!     paths agree at every thread count *by construction*.
//!
//! # SIMD lane layout
//!
//! The [`super::simd`] backends vectorize **across the NR output
//! columns** of the register tile: each vector lane carries one output
//! element's accumulator, `a[i,k]` is broadcast, and mul/add stay
//! separate (no FMA — its single rounding would change the bits; see the
//! `simd` module docs for why lane-wise mul-then-add cannot).  To make
//! the per-k B access one contiguous vector load, the rows of Bᵀ are
//! **packed** once per product into NR-wide strips laid out k-major
//! ([`PackedRows`]: `strip[kk*nr + l] = B[j0+l, kk]`, zero-padded past
//! the edge; padded lanes are computed and discarded, never stored).
//! The one packing pass — O(n·k), the cost of one extra transpose — is
//! shared by the serial sweep and by every row chunk of the parallel
//! path (the pool workers all read the same pack), and the Gram entry
//! points reuse the same structure.  Tile shape is selected by the
//! backend captured at pack time — 4×8 under AVX2 (two ymm accumulators
//! per row), 4×4 otherwise — via [`simd::Backend::nr`].
//!
//! # Block schedule
//!
//! Compile-time fixed — never derived from the thread count or the host:
//! [`NC`]-row panels of Bᵀ are held hot while [`KC`]-wide k-panels stream
//! through [`MR`]×nr register tiles.  KC·(MR+nr) f64 ≤ 24 KB keeps the
//! active slices in L1, and the packed NC×KC panel (128 KB) in L2.

use super::simd::{self, Backend, MAX_NR};
use super::Mat;

/// Register-tile rows (A rows advanced together).  The tile width (NR
/// lanes) is backend-selected, see [`simd::Backend::nr`].
pub const MR: usize = 4;
/// k-panel width: columns of A/Bᵀ processed per pass.
pub const KC: usize = 256;
/// Output-column panel: Bᵀ rows kept hot (packed) across one row sweep.
pub const NC: usize = 64;

/// C[r0..r1, :] = A[r0..r1, :]·Bᵀ, written into `out` (row-major,
/// `(r1-r0) × bt.rows`, rows indexed relative to `r0`), with Bᵀ given
/// pre-packed ([`pack_rows`] — pack once per product and share it across
/// every row chunk; the pool workers of the parallel path all read the
/// same pack).
///
/// `out` must be zero-initialized: the kernel accumulates k-panels into
/// it, which is exactly what keeps every element on the canonical
/// ascending-k program.
pub(crate) fn matmul_nt_block(a: &Mat, bt: &PackedRows, r0: usize, r1: usize,
                              out: &mut [f64]) {
    let n = bt.rows;
    let kd = a.cols;
    debug_assert_eq!(out.len(), (r1 - r0) * n);
    debug_assert_eq!(bt.cols, kd, "matmul_nt_block packed inner dims");
    if n == 0 || r1 <= r0 || kd == 0 {
        return; // empty product: out stays zero, matching the empty sum
    }
    let be = bt.be;
    let nr = be.nr();
    // NC (64) is a multiple of every backend's nr, so jc panels are
    // strip-aligned by construction
    debug_assert_eq!(NC % nr, 0);
    let mut jc = 0;
    while jc < n {
        let jc_hi = (jc + NC).min(n);
        let mut kc = 0;
        while kc < kd {
            let kc_hi = (kc + KC).min(kd);
            let mut i = r0;
            while i < r1 {
                let i_hi = (i + MR).min(r1);
                for s in jc / nr..jc_hi.div_ceil(nr) {
                    let j = s * nr;
                    let lanes = (jc_hi - j).min(nr);
                    // this strip's k-slice for the current panel
                    let strip = &bt.data[(s * kd + kc) * nr..
                                         (s * kd + kc_hi) * nr];
                    if i_hi - i == MR {
                        tile_full(be, a, i, j, kc, kc_hi, lanes, strip, r0,
                                  n, out);
                    } else {
                        for r in i..i_hi {
                            tile_row(be, a, r, j, kc, kc_hi, lanes, strip,
                                     r0, n, out);
                        }
                    }
                }
                i = i_hi;
            }
            kc = kc_hi;
        }
        jc = jc_hi;
    }
}

/// The full MR-row tile over one packed strip: load the live accumulators
/// from C, advance them through the k-panel on the dispatched backend,
/// store the valid lanes back.  Padded lanes accumulate zeros and are
/// discarded.
#[allow(clippy::too_many_arguments)]
#[inline]
fn tile_full(be: Backend, a: &Mat, i: usize, j: usize, k0: usize, k1: usize,
             lanes: usize, strip: &[f64], r0: usize, n: usize,
             out: &mut [f64]) {
    let nr = be.nr();
    let mut acc = [0.0_f64; MR * MAX_NR];
    let acc = &mut acc[..MR * nr];
    for r in 0..MR {
        let orow = (i + r - r0) * n + j;
        acc[r * nr..r * nr + lanes].copy_from_slice(&out[orow..orow + lanes]);
    }
    simd::tile4(be,
                [&a.row(i)[k0..k1], &a.row(i + 1)[k0..k1],
                 &a.row(i + 2)[k0..k1], &a.row(i + 3)[k0..k1]],
                strip, acc);
    for r in 0..MR {
        let orow = (i + r - r0) * n + j;
        out[orow..orow + lanes].copy_from_slice(&acc[r * nr..r * nr + lanes]);
    }
}

/// Ragged row edge: one output row over one packed strip — same
/// per-element program, one accumulator vector pair instead of four.
#[allow(clippy::too_many_arguments)]
#[inline]
fn tile_row(be: Backend, a: &Mat, i: usize, j: usize, k0: usize, k1: usize,
            lanes: usize, strip: &[f64], r0: usize, n: usize,
            out: &mut [f64]) {
    let nr = be.nr();
    let mut acc = [0.0_f64; MAX_NR];
    let acc = &mut acc[..nr];
    let orow = (i - r0) * n + j;
    acc[..lanes].copy_from_slice(&out[orow..orow + lanes]);
    simd::tile1(be, &a.row(i)[k0..k1], strip, acc);
    out[orow..orow + lanes].copy_from_slice(&acc[..lanes]);
}

/// Rows of `src` packed once into NR-wide k-major lane strips
/// (`data[(s*cols + kk)*nr + l] = src[s*nr + l, kk]`, zero-padded), so
/// the GEMM tiles and every Gram row segment reuse contiguous vector
/// loads.  The strip width is fixed by the backend captured at pack time
/// — the consuming kernels must dispatch on the same backend, so it
/// rides along (flipping the global backend mid-product therefore cannot
/// desynchronize layout and dispatch).
pub(crate) struct PackedRows {
    be: Backend,
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

/// Pack `src` for [`matmul_nt_block`] / [`gram_row_segment_packed`] on
/// the active backend.  O(rows·cols) — one extra transpose-sized pass,
/// amortized over the whole product (every row chunk / row segment).
pub(crate) fn pack_rows(src: &Mat) -> PackedRows {
    let be = simd::active();
    let nr = be.nr();
    let n_strips = src.rows.div_ceil(nr);
    let mut data = vec![0.0_f64; n_strips * src.cols * nr];
    for s in 0..n_strips {
        let strip = &mut data[s * src.cols * nr..(s + 1) * src.cols * nr];
        for l in 0..nr {
            let j = s * nr + l;
            if j < src.rows {
                for (kk, &v) in src.row(j).iter().enumerate() {
                    strip[kk * nr + l] = v;
                }
            }
            // else: buffer is zero-initialized, padded lanes stay 0
        }
    }
    PackedRows { be, rows: src.rows, cols: src.cols, data }
}

/// Row `i` of the upper triangle of `src·srcᵀ`: the segment
/// `[Σ_k src[i,k]·src[j,k] for j in i..src.rows]`.
///
/// Every element follows the same canonical ascending-k program as the
/// GEMM kernel, so serial loops, parallel row maps and any chunking all
/// produce identical bits.  The j-direction runs on the packed lane
/// strips of `packed` (the same lane treatment as the GEMM tile): the
/// leading rows up to the next strip boundary are plain scalar dots,
/// then whole strips advance nr accumulators at once via
/// [`simd::tile1`], trailing padded lanes discarded.
pub(crate) fn gram_row_segment_packed(src: &Mat, packed: &PackedRows,
                                      i: usize) -> Vec<f64> {
    let m = src.rows;
    let nr = packed.be.nr();
    debug_assert_eq!(packed.cols, src.cols);
    let ri = src.row(i);
    let mut seg = Vec::with_capacity(m - i);
    // leading ragged rows up to the strip boundary: canonical scalar dots
    let head_end = (i.div_ceil(nr) * nr).min(m);
    for j in i..head_end {
        let rj = src.row(j);
        let mut s = 0.0_f64;
        for (x, y) in ri.iter().zip(rj) {
            s += x * y;
        }
        seg.push(s);
    }
    // aligned strips (the last one zero-padded past m)
    let mut j = head_end;
    while j < m {
        let s = j / nr;
        let lanes = (m - j).min(nr);
        let strip = &packed.data[s * packed.cols * nr..
                                 (s + 1) * packed.cols * nr];
        let mut acc = [0.0_f64; MAX_NR];
        simd::tile1(packed.be, ri, strip, &mut acc[..nr]);
        seg.extend_from_slice(&acc[..lanes]);
        j += lanes;
    }
    seg
}

/// Single-call convenience for [`gram_row_segment_packed`] (packs the
/// source itself — fine for one row, quadratic if called for every row;
/// the Gram entry points in [`super`] pack once instead).
#[cfg(test)]
pub(crate) fn gram_row_segment(src: &Mat, i: usize) -> Vec<f64> {
    gram_row_segment_packed(src, &pack_rows(src), i)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// The independent naive reference: single accumulator, ascending k.
    fn naive_nt(a: &Mat, bt: &Mat) -> Mat {
        let mut out = Mat::zeros(a.rows, bt.rows);
        for i in 0..a.rows {
            for j in 0..bt.rows {
                let mut s = 0.0_f64;
                for k in 0..a.cols {
                    s += a[(i, k)] * bt[(j, k)];
                }
                out[(i, j)] = s;
            }
        }
        out
    }

    fn shapes() -> Vec<(usize, usize, usize)> {
        // shapes straddling every block boundary: MR (4), the widest
        // lane tile (8), NC (64), KC (256), plus degenerate edges
        vec![(1usize, 1usize, 1usize), (1, 9, 1), (3, 4, 5), (4, 4, 4),
             (5, 5, 5), (7, 8, 9), (8, 300, 8), (7, 257, 9), (12, 64, 65),
             (4, 256, 4), (13, 255, 66), (9, 10, 8), (11, 6, 17),
             (65, 17, 63)]
    }

    /// Backend-forcing tests serialize on this lock so a concurrent
    /// sweep can't flip the process-global override mid-shape (results
    /// would still be bit-identical, but per-backend *coverage* would
    /// silently degrade).
    fn sweep_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn blocked_kernel_bit_identical_to_naive_for_every_backend() {
        let _guard = sweep_lock();
        for be in simd::available_backends() {
            simd::set_backend(Some(be)).unwrap();
            for (m, k, n) in shapes() {
                let a = Mat::random_normal(
                    &mut Rng::new(m as u64 * 101 + k as u64), m, k);
                let bt = Mat::random_normal(
                    &mut Rng::new(n as u64 * 77 + k as u64), n, k);
                let mut out = vec![0.0_f64; m * n];
                matmul_nt_block(&a, &pack_rows(&bt), 0, m, &mut out);
                assert_eq!(out, naive_nt(&a, &bt).data,
                           "{m}x{k}·{n}ᵀ on {}", be.name());
            }
        }
        simd::set_backend(None).unwrap();
    }

    #[test]
    fn row_ranges_compose_exactly() {
        // any split point reproduces the full result bit for bit
        let (m, k, n) = (23, 31, 19);
        let a = Mat::random_normal(&mut Rng::new(1), m, k);
        let bt = Mat::random_normal(&mut Rng::new(2), n, k);
        let packed = pack_rows(&bt);
        let mut full = vec![0.0_f64; m * n];
        matmul_nt_block(&a, &packed, 0, m, &mut full);
        for split in [1usize, 4, 7, 16, 22] {
            let mut top = vec![0.0_f64; split * n];
            let mut bot = vec![0.0_f64; (m - split) * n];
            matmul_nt_block(&a, &packed, 0, split, &mut top);
            matmul_nt_block(&a, &packed, split, m, &mut bot);
            top.extend_from_slice(&bot);
            assert_eq!(top, full, "split {split}");
        }
    }

    #[test]
    fn gram_segments_match_naive_for_every_backend() {
        let _guard = sweep_lock();
        for be in simd::available_backends() {
            simd::set_backend(Some(be)).unwrap();
            for &(m, k) in &[(1usize, 1usize), (5, 3), (8, 8), (9, 300),
                             (12, 7), (17, 33)] {
                let src = Mat::random_normal(
                    &mut Rng::new(m as u64 * 7 + k as u64), m, k);
                let packed = pack_rows(&src);
                for i in 0..m {
                    let seg = gram_row_segment_packed(&src, &packed, i);
                    assert_eq!(seg.len(), m - i);
                    for (off, &v) in seg.iter().enumerate() {
                        let j = i + off;
                        let mut s = 0.0_f64;
                        for kk in 0..k {
                            s += src[(i, kk)] * src[(j, kk)];
                        }
                        assert_eq!(v, s, "({i},{j}) of {m}x{k} on {}",
                                   be.name());
                    }
                }
            }
        }
        simd::set_backend(None).unwrap();
    }

    #[test]
    fn single_call_segment_matches_packed() {
        let src = Mat::random_normal(&mut Rng::new(42), 11, 9);
        let packed = pack_rows(&src);
        for i in 0..src.rows {
            assert_eq!(gram_row_segment(&src, i),
                       gram_row_segment_packed(&src, &packed, i));
        }
    }
}
