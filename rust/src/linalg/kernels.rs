//! Blocked-k / register-tiled GEMM micro-kernels with packed-lane SIMD
//! dispatch.
//!
//! # The canonical-scalar-program contract
//!
//! Every output element these kernels produce is computed by **one fixed
//! floating-point program**: a single accumulator that adds
//! `a[i,k]·b[j,k]` in strictly ascending `k`, one IEEE mul followed by
//! one IEEE add per step.  Blocking, tiling and vectorization change only
//! the *order in which different elements advance* (cache locality) and
//! how many independent accumulator chains are in flight at once
//! (instruction- and data-level parallelism); they never reassociate the
//! sum inside one element.  Two consequences, both load-bearing:
//!
//!   * the result is **bit-identical to the naive triple loop** — the
//!     randomized oracle in `tests/kernel_oracle.rs` asserts `==` on f64
//!     for every available SIMD backend,
//!   * any row chunking is bit-identical too, so the serial and parallel
//!     paths agree at every thread count *by construction*.
//!
//! Under the **opt-in FMA mode** (`--fma` / `LRC_FMA=1`, default off) the
//! per-element step becomes one fused multiply-add instead; the contract
//! keeps its shape but the reference changes with it — see the
//! [`super::simd`] module docs.  The mode is captured at pack time
//! ([`PackedRows`]), so one product can never mix the two programs.
//!
//! # SIMD lane layout and panel packing
//!
//! The [`super::simd`] backends vectorize **across the NR output
//! columns** of the register tile: each vector lane carries one output
//! element's accumulator, `a[i,k]` is broadcast, and mul/add stay
//! separate in the default mode (no FMA — its single rounding would
//! change the bits; see the `simd` module docs for why lane-wise
//! mul-then-add cannot).  To make the per-k B access one contiguous
//! vector load, the rows of Bᵀ are **packed** once per product into
//! NR-wide strips laid out k-major ([`PackedRows`]:
//! `strip[kk*nr + l] = B[j0+l, kk]`, zero-padded past the edge; padded
//! lanes are computed and discarded, never stored).  The one packing
//! pass — O(n·k), the cost of one extra transpose — is shared by the
//! serial sweep and by every row chunk of the parallel path (the pool
//! workers all read the same pack), and the Gram entry points reuse the
//! same structure.  Tile shape is selected by the backend captured at
//! pack time — 4×8 under AVX2 (two ymm accumulators per row), 4×4
//! otherwise — via [`simd::Backend::nr`].
//!
//! The **A panel** is packed too: each MR×kw register-tile slice of A is
//! copied once per (jc, kc, i) block into a small contiguous scratch
//! panel (≤ MR·KC f64 = 8 KB, L1-resident) and reused across every lane
//! strip of the jc panel, so the microkernel's four `a` streams come
//! from one hot buffer instead of four matrix rows `a.cols` apart
//! (tightens L1/TLB behavior for large `k`; the copy amortizes over NC
//! columns of compute).  Packing copies values verbatim, so it is
//! invisible to the bit contract; [`set_pack_a`] can disable it for
//! benches/debugging (`bench_par`'s packed-A section times both sides
//! and asserts equality first).
//!
//! # Workspace reuse
//!
//! All kernel scratch — the packed B strips, the packed A panel — comes
//! from the per-thread [`super::workspace`] arena and is returned on
//! drop, so in steady state (repeated products of the same shapes, i.e.
//! the calibration/quantization inner loops) these kernels perform
//! **zero allocations** (`tests/alloc_steady_state.rs`).  Gram row
//! segments write into caller-provided slices for the same reason.
//!
//! # Block schedule
//!
//! Compile-time fixed — never derived from the thread count or the host:
//! [`NC`]-row panels of Bᵀ are held hot while [`KC`]-wide k-panels stream
//! through [`MR`]×nr register tiles.  KC·(MR+nr) f64 ≤ 24 KB keeps the
//! active slices in L1, and the packed NC×KC panel (128 KB) in L2.
//!
//! # The f32 lane family
//!
//! Every piece above exists a second time at f32 ([`pack_rows_f32`],
//! [`matmul_nt_f32`], the `tile_*_f32` kernels): the same block schedule
//! and the same canonical program, at **twice the lane width**
//! ([`simd::Backend::nr32`] = 2·nr on every backend).  The f32 contract
//! mirrors the f64 one — every backend/thread-count/chunking is
//! bit-identical to the naive ascending-k f32 triple loop (fused
//! `mul_add` steps in FMA mode) — and is what the fused dequant-GEMM
//! path ([`crate::quant::dequant`]) drives its decoded `PackedInts`
//! strips through: there, the lane strips are *decoded* from packed
//! codes × scales tile by tile instead of copied from a dense matrix,
//! so the full f32 weight matrix never exists in memory.

use std::sync::atomic::{AtomicBool, Ordering};

use super::simd::{self, Backend, MAX_NR, MAX_NR32};
use super::{workspace, Mat};

/// Register-tile rows (A rows advanced together).  The tile width (NR
/// lanes) is backend-selected, see [`simd::Backend::nr`].
pub const MR: usize = 4;
/// k-panel width: columns of A/Bᵀ processed per pass.
pub const KC: usize = 256;
/// Output-column panel: Bᵀ rows kept hot (packed) across one row sweep.
pub const NC: usize = 64;

/// A-panel packing switch (default on).  A bench/debug knob only: both
/// settings produce identical bits (packing copies values verbatim), so
/// flipping it mid-run is harmless — `bench_par` uses it to time the
/// packed vs unpacked A streams.
static PACK_A: AtomicBool = AtomicBool::new(true);

/// Enable/disable A-panel packing (see [`PACK_A`]).
pub fn set_pack_a(on: bool) {
    PACK_A.store(on, Ordering::SeqCst);
}

/// Whether A panels are currently packed.
pub fn pack_a_enabled() -> bool {
    PACK_A.load(Ordering::SeqCst)
}

/// C[r0..r1, :] = A[r0..r1, :]·Bᵀ, written into `out` (row-major,
/// `(r1-r0) × bt.rows`, rows indexed relative to `r0`), with Bᵀ given
/// pre-packed ([`pack_rows`] — pack once per product and share it across
/// every row chunk; the pool workers of the parallel path all read the
/// same pack).
///
/// `out` must be zero-initialized: the kernel accumulates k-panels into
/// it, which is exactly what keeps every element on the canonical
/// ascending-k program.
pub(crate) fn matmul_nt_block(a: &Mat, bt: &PackedRows, r0: usize, r1: usize,
                              out: &mut [f64]) {
    let n = bt.rows;
    let kd = a.cols;
    debug_assert_eq!(out.len(), (r1 - r0) * n);
    debug_assert_eq!(bt.cols, kd, "matmul_nt_block packed inner dims");
    if n == 0 || r1 <= r0 || kd == 0 {
        return; // empty product: out stays zero, matching the empty sum
    }
    let be = bt.be;
    let fma = bt.fma;
    let nr = be.nr();
    // NC (64) is a multiple of every backend's nr, so jc panels are
    // strip-aligned by construction
    debug_assert_eq!(NC % nr, 0);
    // the A panel: MR rows × one k-panel, packed contiguous and reused
    // across every strip of the current jc panel.  Taken lazily on first
    // use (workspace-recycled): products that never pack — packing off,
    // narrow jc panels, ragged-only row ranges — pay nothing, and the
    // panel is never pre-zeroed (every slot is overwritten by
    // copy_from_slice before the tiles read it).
    let mut apanel: Option<Vec<f64>> = None;
    let pack_a = pack_a_enabled();
    let mut jc = 0;
    while jc < n {
        let jc_hi = (jc + NC).min(n);
        // packing pays off once the panel has ≥ 2 lane strips to reuse
        // the packed rows across; a single-strip panel reads A directly
        let use_pack = pack_a && jc_hi - jc > nr;
        let mut kc = 0;
        while kc < kd {
            let kc_hi = (kc + KC).min(kd);
            let kw = kc_hi - kc;
            let mut i = r0;
            while i < r1 {
                let i_hi = (i + MR).min(r1);
                let full = i_hi - i == MR;
                if full && use_pack {
                    let ap = apanel
                        .get_or_insert_with(|| workspace::take_zeroed(MR * KC));
                    for r in 0..MR {
                        ap[r * kw..(r + 1) * kw]
                            .copy_from_slice(&a.row(i + r)[kc..kc_hi]);
                    }
                }
                for s in jc / nr..jc_hi.div_ceil(nr) {
                    let j = s * nr;
                    let lanes = (jc_hi - j).min(nr);
                    // this strip's k-slice for the current panel
                    let strip = &bt.data[(s * kd + kc) * nr..
                                         (s * kd + kc_hi) * nr];
                    if full {
                        let rows: [&[f64]; MR] = if use_pack {
                            let ap = apanel.as_deref()
                                .expect("A panel packed above");
                            [&ap[..kw], &ap[kw..2 * kw],
                             &ap[2 * kw..3 * kw], &ap[3 * kw..4 * kw]]
                        } else {
                            [&a.row(i)[kc..kc_hi], &a.row(i + 1)[kc..kc_hi],
                             &a.row(i + 2)[kc..kc_hi],
                             &a.row(i + 3)[kc..kc_hi]]
                        };
                        tile_full(be, fma, rows, lanes, strip,
                                  (i - r0) * n + j, n, out);
                    } else {
                        for r in i..i_hi {
                            tile_row(be, fma, &a.row(r)[kc..kc_hi], lanes,
                                     strip, (r - r0) * n + j, out);
                        }
                    }
                }
                i = i_hi;
            }
            kc = kc_hi;
        }
        jc = jc_hi;
    }
    if let Some(ap) = apanel {
        workspace::put(ap);
    }
}

/// The full MR-row tile over one packed strip: load the live accumulators
/// from C, advance them through the k-panel on the dispatched backend,
/// store the valid lanes back.  Padded lanes accumulate zeros and are
/// discarded.  `o0` is the flat index of element (row `i`, column `j`)
/// in `out`; the MR rows sit `n` apart.
#[allow(clippy::too_many_arguments)]
#[inline]
fn tile_full(be: Backend, fma: bool, rows: [&[f64]; MR], lanes: usize,
             strip: &[f64], o0: usize, n: usize, out: &mut [f64]) {
    let nr = be.nr();
    let mut acc = [0.0_f64; MR * MAX_NR];
    let acc = &mut acc[..MR * nr];
    for r in 0..MR {
        let orow = o0 + r * n;
        acc[r * nr..r * nr + lanes].copy_from_slice(&out[orow..orow + lanes]);
    }
    simd::tile4(be, fma, rows, strip, acc);
    for r in 0..MR {
        let orow = o0 + r * n;
        out[orow..orow + lanes].copy_from_slice(&acc[r * nr..r * nr + lanes]);
    }
}

/// Ragged row edge: one output row over one packed strip — same
/// per-element program, one accumulator vector pair instead of four.
#[inline]
fn tile_row(be: Backend, fma: bool, arow: &[f64], lanes: usize,
            strip: &[f64], orow: usize, out: &mut [f64]) {
    let nr = be.nr();
    let mut acc = [0.0_f64; MAX_NR];
    let acc = &mut acc[..nr];
    acc[..lanes].copy_from_slice(&out[orow..orow + lanes]);
    simd::tile1(be, fma, arow, strip, acc);
    out[orow..orow + lanes].copy_from_slice(&acc[..lanes]);
}

/// Rows of `src` packed once into NR-wide k-major lane strips
/// (`data[(s*cols + kk)*nr + l] = src[s*nr + l, kk]`, zero-padded), so
/// the GEMM tiles and every Gram row segment reuse contiguous vector
/// loads.  The strip width is fixed by the backend captured at pack time
/// — the consuming kernels must dispatch on the same backend, so it
/// rides along, and the FMA mode is captured with it (flipping either
/// global mid-product therefore cannot desynchronize layout, dispatch or
/// the per-element program).  The strip storage comes from the
/// per-thread [`workspace`] arena and returns to it on drop.
pub(crate) struct PackedRows {
    be: Backend,
    pub(crate) fma: bool,
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Drop for PackedRows {
    fn drop(&mut self) {
        workspace::put(std::mem::take(&mut self.data));
    }
}

/// Pack `src` for [`matmul_nt_block`] / [`gram_row_segment_into`] on
/// the active backend + accumulation mode.  O(rows·cols) — one extra
/// transpose-sized pass, amortized over the whole product (every row
/// chunk / row segment); allocation-free in steady state (the strip
/// buffer is workspace-recycled).
pub(crate) fn pack_rows(src: &Mat) -> PackedRows {
    let be = simd::active();
    let fma = simd::fma_active();
    let nr = be.nr();
    let n_strips = src.rows.div_ceil(nr);
    let mut data = workspace::take_zeroed(n_strips * src.cols * nr);
    for s in 0..n_strips {
        let strip = &mut data[s * src.cols * nr..(s + 1) * src.cols * nr];
        for l in 0..nr {
            let j = s * nr + l;
            if j < src.rows {
                for (kk, &v) in src.row(j).iter().enumerate() {
                    strip[kk * nr + l] = v;
                }
            }
            // else: buffer is zeroed by take_zeroed, padded lanes stay 0
        }
    }
    PackedRows { be, fma, rows: src.rows, cols: src.cols, data }
}

/// Row `i` of the upper triangle of `src·srcᵀ`, written into `out`
/// (length `src.rows - i`): `out[j-i] = Σ_k src[i,k]·src[j,k]` for
/// `j in i..src.rows`.
///
/// Every element follows the same canonical ascending-k program as the
/// GEMM kernel (fused in FMA mode, per the pack), so serial loops,
/// parallel row maps and any chunking all produce identical bits.  The
/// j-direction runs on the packed lane strips of `packed` (the same lane
/// treatment as the GEMM tile): the leading rows up to the next strip
/// boundary are plain scalar dots, then whole strips advance nr
/// accumulators at once via [`simd::tile1`], trailing padded lanes
/// discarded.  Writing into the caller's slice (the Gram entry points
/// hand out disjoint rows of the output matrix) keeps the per-row path
/// allocation-free — there is no per-segment `Vec` on any path.
pub(crate) fn gram_row_segment_into(src: &Mat, packed: &PackedRows,
                                    i: usize, out: &mut [f64]) {
    let m = src.rows;
    debug_assert_eq!(out.len(), m - i);
    debug_assert_eq!(packed.cols, src.cols);
    let nr = packed.be.nr();
    let fma = packed.fma;
    let ri = src.row(i);
    // leading ragged rows up to the strip boundary: canonical scalar dots
    let head_end = (i.div_ceil(nr) * nr).min(m);
    for j in i..head_end {
        let rj = src.row(j);
        let mut s = 0.0_f64;
        if fma {
            for (x, y) in ri.iter().zip(rj) {
                s = x.mul_add(*y, s);
            }
        } else {
            for (x, y) in ri.iter().zip(rj) {
                s += x * y;
            }
        }
        out[j - i] = s;
    }
    // aligned strips (the last one zero-padded past m)
    let mut j = head_end;
    while j < m {
        let s = j / nr;
        let lanes = (m - j).min(nr);
        let strip = &packed.data[s * packed.cols * nr..
                                 (s + 1) * packed.cols * nr];
        let mut acc = [0.0_f64; MAX_NR];
        simd::tile1(packed.be, fma, ri, strip, &mut acc[..nr]);
        out[j - i..j - i + lanes].copy_from_slice(&acc[..lanes]);
        j += lanes;
    }
}

/// Single-call convenience for [`gram_row_segment_into`] (packs the
/// source itself — fine for one row, quadratic if called for every row;
/// the Gram entry points in [`super`] pack once instead).  Routed through
/// the same write-into-slice kernel as every other path.
#[cfg(test)]
pub(crate) fn gram_row_segment(src: &Mat, i: usize) -> Vec<f64> {
    let mut out = vec![0.0_f64; src.rows - i];
    gram_row_segment_into(src, &pack_rows(src), i, &mut out);
    out
}

// ---------------------------------------------------------------------------
// f32 blocked GEMM — the same schedule and canonical program at twice
// the lane width ([`simd::Backend::nr32`]).  This is the compute layer
// of the fused dequant-GEMM data path (`quant::dequant`): the fused
// kernel builds its lane strips by *decoding* `PackedInts` tiles instead
// of copying a dense matrix, then drives the very same f32 tiles below.
// The f32 reference program is the naive f32 triple loop (ascending k,
// mul-then-add, or one fused `mul_add` per step in FMA mode) —
// `tests/kernel_oracle.rs` locks every backend against it with `==`.
// ---------------------------------------------------------------------------

/// The full MR-row f32 tile over one packed strip (see [`tile_full`] —
/// identical choreography at nr32 lanes).  `pub(crate)` so the fused
/// dequant driver can run the same tile over *decoded* strips.
#[allow(clippy::too_many_arguments)]
#[inline]
pub(crate) fn tile_full_f32(be: Backend, fma: bool, rows: [&[f32]; MR],
                            lanes: usize, strip: &[f32], o0: usize, n: usize,
                            out: &mut [f32]) {
    let nr = be.nr32();
    let mut acc = [0.0_f32; MR * MAX_NR32];
    let acc = &mut acc[..MR * nr];
    for r in 0..MR {
        let orow = o0 + r * n;
        acc[r * nr..r * nr + lanes].copy_from_slice(&out[orow..orow + lanes]);
    }
    simd::tile4_f32(be, fma, rows, strip, acc);
    for r in 0..MR {
        let orow = o0 + r * n;
        out[orow..orow + lanes].copy_from_slice(&acc[r * nr..r * nr + lanes]);
    }
}

/// Ragged-row f32 edge tile (see [`tile_row`]).
#[inline]
pub(crate) fn tile_row_f32(be: Backend, fma: bool, arow: &[f32], lanes: usize,
                           strip: &[f32], orow: usize, out: &mut [f32]) {
    let nr = be.nr32();
    let mut acc = [0.0_f32; MAX_NR32];
    let acc = &mut acc[..nr];
    acc[..lanes].copy_from_slice(&out[orow..orow + lanes]);
    simd::tile1_f32(be, fma, arow, strip, acc);
    out[orow..orow + lanes].copy_from_slice(&acc[..lanes]);
}

/// f32 sibling of [`PackedRows`]: rows of a flat row-major [n, k] matrix
/// packed into nr32-wide k-major lane strips
/// (`data[(s*cols + kk)*nr32 + l] = src[(s*nr32 + l)*cols + kk]`,
/// zero-padded).  Backend + FMA mode captured at pack time; storage from
/// the f32 workspace arena, returned on drop.
pub struct PackedRowsF32 {
    pub(crate) be: Backend,
    pub(crate) fma: bool,
    pub(crate) rows: usize,
    pub(crate) cols: usize,
    pub(crate) data: Vec<f32>,
}

impl Drop for PackedRowsF32 {
    fn drop(&mut self) {
        workspace::put_f32(std::mem::take(&mut self.data));
    }
}

/// Pack a flat row-major `[rows, cols]` f32 matrix for
/// [`matmul_nt_f32_block`] on the active backend + accumulation mode.
pub fn pack_rows_f32(src: &[f32], rows: usize, cols: usize) -> PackedRowsF32 {
    assert_eq!(src.len(), rows * cols, "pack_rows_f32 shape");
    let be = simd::active();
    let fma = simd::fma_active();
    let nr = be.nr32();
    let n_strips = rows.div_ceil(nr);
    let mut data = workspace::take_zeroed_f32(n_strips * cols * nr);
    for s in 0..n_strips {
        let strip = &mut data[s * cols * nr..(s + 1) * cols * nr];
        for l in 0..nr {
            let j = s * nr + l;
            if j < rows {
                for (kk, &v) in src[j * cols..(j + 1) * cols].iter()
                    .enumerate()
                {
                    strip[kk * nr + l] = v;
                }
            }
            // else: buffer is zeroed by take_zeroed_f32, pads stay 0
        }
    }
    PackedRowsF32 { be, fma, rows, cols, data }
}

/// C[r0..r1, :] = A[r0..r1, :]·Bᵀ on the f32 tiles, A given flat
/// row-major `[m, k]` and Bᵀ pre-packed.  Same contract as
/// [`matmul_nt_block`]: `out` (rows relative to `r0`) must be
/// zero-initialized, k-panels accumulate into it, every element runs the
/// canonical ascending-k f32 program.
pub(crate) fn matmul_nt_f32_block(a: &[f32], kd: usize, bt: &PackedRowsF32,
                                  r0: usize, r1: usize, out: &mut [f32]) {
    let n = bt.rows;
    debug_assert_eq!(out.len(), (r1 - r0) * n);
    debug_assert_eq!(bt.cols, kd, "matmul_nt_f32_block packed inner dims");
    if n == 0 || r1 <= r0 || kd == 0 {
        return; // empty product: out stays zero, matching the empty sum
    }
    let be = bt.be;
    let fma = bt.fma;
    let nr = be.nr32();
    // NC (64) is a multiple of every backend's nr32 (8 or 16)
    debug_assert_eq!(NC % nr, 0);
    let arow = |i: usize| -> &[f32] { &a[i * kd..(i + 1) * kd] };
    let mut apanel: Option<Vec<f32>> = None;
    let pack_a = pack_a_enabled();
    let mut jc = 0;
    while jc < n {
        let jc_hi = (jc + NC).min(n);
        let use_pack = pack_a && jc_hi - jc > nr;
        let mut kc = 0;
        while kc < kd {
            let kc_hi = (kc + KC).min(kd);
            let kw = kc_hi - kc;
            let mut i = r0;
            while i < r1 {
                let i_hi = (i + MR).min(r1);
                let full = i_hi - i == MR;
                if full && use_pack {
                    let ap = apanel.get_or_insert_with(
                        || workspace::take_zeroed_f32(MR * KC));
                    for r in 0..MR {
                        ap[r * kw..(r + 1) * kw]
                            .copy_from_slice(&arow(i + r)[kc..kc_hi]);
                    }
                }
                for s in jc / nr..jc_hi.div_ceil(nr) {
                    let j = s * nr;
                    let lanes = (jc_hi - j).min(nr);
                    let strip = &bt.data[(s * kd + kc) * nr..
                                         (s * kd + kc_hi) * nr];
                    if full {
                        let rows: [&[f32]; MR] = if use_pack {
                            let ap = apanel.as_deref()
                                .expect("A panel packed above");
                            [&ap[..kw], &ap[kw..2 * kw],
                             &ap[2 * kw..3 * kw], &ap[3 * kw..4 * kw]]
                        } else {
                            [&arow(i)[kc..kc_hi], &arow(i + 1)[kc..kc_hi],
                             &arow(i + 2)[kc..kc_hi],
                             &arow(i + 3)[kc..kc_hi]]
                        };
                        tile_full_f32(be, fma, rows, lanes, strip,
                                      (i - r0) * n + j, n, out);
                    } else {
                        for r in i..i_hi {
                            tile_row_f32(be, fma, &arow(r)[kc..kc_hi], lanes,
                                         strip, (r - r0) * n + j, out);
                        }
                    }
                }
                i = i_hi;
            }
            kc = kc_hi;
        }
        jc = jc_hi;
    }
    if let Some(ap) = apanel {
        workspace::put_f32(ap);
    }
}

/// C = A·Bᵀ in f32 (flat row-major slices: A `[m, k]`, B `[n, k]`,
/// C `[m, n]`), written into `out` (cleared + resized).  Packs B once,
/// then auto-parallelizes on [`crate::par::global`] past
/// [`super::PAR_MIN_WORK`] with disjoint row-chunk writes — bit-identical
/// at every thread count and on every backend, same argument as the f64
/// path.
pub fn matmul_nt_f32_into(a: &[f32], m: usize, k: usize, b: &[f32], n: usize,
                          out: &mut Vec<f32>) {
    assert_eq!(a.len(), m * k, "matmul_nt_f32 A shape");
    assert_eq!(b.len(), n * k, "matmul_nt_f32 B shape");
    out.clear();
    out.resize(m * n, 0.0);
    if n == 0 || m == 0 {
        return;
    }
    let packed = pack_rows_f32(b, n, k);
    if m <= Mat::PAR_ROW_CHUNK || m * n * k < super::PAR_MIN_WORK
        || crate::par::in_pool()
    {
        matmul_nt_f32_block(a, k, &packed, 0, m, out);
        return;
    }
    let pool = crate::par::global();
    let chunk = Mat::PAR_ROW_CHUNK;
    let n_chunks = m.div_ceil(chunk);
    let shared = workspace::SharedSlice::new(&mut out[..]);
    pool.for_indices(n_chunks, |ci| {
        let r0 = ci * chunk;
        let r1 = (r0 + chunk).min(m);
        // SAFETY: row chunks [r0, r1) partition out — disjoint spans
        let slice = unsafe { shared.range(r0 * n, r1 * n) };
        matmul_nt_f32_block(a, k, &packed, r0, r1, slice);
    });
}

/// Allocating convenience for [`matmul_nt_f32_into`].
pub fn matmul_nt_f32(a: &[f32], m: usize, k: usize, b: &[f32], n: usize)
                     -> Vec<f32> {
    let mut out = Vec::new();
    matmul_nt_f32_into(a, m, k, b, n, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// The independent naive reference: single accumulator, ascending k —
    /// fused when the process-wide FMA mode is on (lockstep with the
    /// kernels; the CI matrix runs this suite under `LRC_FMA=1`).
    fn naive_nt(a: &Mat, bt: &Mat) -> Mat {
        let fma = simd::fma_active();
        let mut out = Mat::zeros(a.rows, bt.rows);
        for i in 0..a.rows {
            for j in 0..bt.rows {
                let mut s = 0.0_f64;
                for k in 0..a.cols {
                    if fma {
                        s = a[(i, k)].mul_add(bt[(j, k)], s);
                    } else {
                        s += a[(i, k)] * bt[(j, k)];
                    }
                }
                out[(i, j)] = s;
            }
        }
        out
    }

    fn shapes() -> Vec<(usize, usize, usize)> {
        // shapes straddling every block boundary: MR (4), the widest
        // lane tile (8), NC (64), KC (256), plus degenerate edges
        vec![(1usize, 1usize, 1usize), (1, 9, 1), (3, 4, 5), (4, 4, 4),
             (5, 5, 5), (7, 8, 9), (8, 300, 8), (7, 257, 9), (12, 64, 65),
             (4, 256, 4), (13, 255, 66), (9, 10, 8), (11, 6, 17),
             (65, 17, 63)]
    }

    /// Backend-forcing tests serialize on this lock so a concurrent
    /// sweep can't flip the process-global override mid-shape (results
    /// would still be bit-identical, but per-backend *coverage* would
    /// silently degrade).
    fn sweep_lock() -> std::sync::MutexGuard<'static, ()> {
        // analyze: allow(forbidden-api): test-only serialization of the
        // process-global backend override; never compiled into the lib.
        static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn blocked_kernel_bit_identical_to_naive_for_every_backend() {
        let _guard = sweep_lock();
        for be in simd::available_backends() {
            simd::set_backend(Some(be)).unwrap();
            for (m, k, n) in shapes() {
                let a = Mat::random_normal(
                    &mut Rng::new(m as u64 * 101 + k as u64), m, k);
                let bt = Mat::random_normal(
                    &mut Rng::new(n as u64 * 77 + k as u64), n, k);
                let mut out = vec![0.0_f64; m * n];
                matmul_nt_block(&a, &pack_rows(&bt), 0, m, &mut out);
                assert_eq!(out, naive_nt(&a, &bt).data,
                           "{m}x{k}·{n}ᵀ on {}", be.name());
            }
        }
        simd::set_backend(None).unwrap();
    }

    #[test]
    fn a_panel_packing_is_bit_invisible() {
        // the A panel copies values verbatim: packed and unpacked runs
        // must agree == on every shape (incl. ones wide enough to
        // actually trigger packing: jc panels with > 1 strip)
        let _guard = sweep_lock();
        for (m, k, n) in [(5usize, 7usize, 40usize), (16, 300, 64),
                          (13, 31, 65), (8, 256, 128)] {
            let a = Mat::random_normal(&mut Rng::new(900 + m as u64), m, k);
            let bt = Mat::random_normal(&mut Rng::new(901 + n as u64), n, k);
            set_pack_a(false);
            let mut plain = vec![0.0_f64; m * n];
            matmul_nt_block(&a, &pack_rows(&bt), 0, m, &mut plain);
            set_pack_a(true);
            let mut packed = vec![0.0_f64; m * n];
            matmul_nt_block(&a, &pack_rows(&bt), 0, m, &mut packed);
            assert_eq!(plain, packed, "{m}x{k}·{n}ᵀ");
        }
    }

    #[test]
    fn row_ranges_compose_exactly() {
        // any split point reproduces the full result bit for bit
        let (m, k, n) = (23, 31, 19);
        let a = Mat::random_normal(&mut Rng::new(1), m, k);
        let bt = Mat::random_normal(&mut Rng::new(2), n, k);
        let packed = pack_rows(&bt);
        let mut full = vec![0.0_f64; m * n];
        matmul_nt_block(&a, &packed, 0, m, &mut full);
        for split in [1usize, 4, 7, 16, 22] {
            let mut top = vec![0.0_f64; split * n];
            let mut bot = vec![0.0_f64; (m - split) * n];
            matmul_nt_block(&a, &packed, 0, split, &mut top);
            matmul_nt_block(&a, &packed, split, m, &mut bot);
            top.extend_from_slice(&bot);
            assert_eq!(top, full, "split {split}");
        }
    }

    #[test]
    fn gram_segments_match_naive_for_every_backend() {
        let _guard = sweep_lock();
        for be in simd::available_backends() {
            simd::set_backend(Some(be)).unwrap();
            let fma = simd::fma_active();
            for &(m, k) in &[(1usize, 1usize), (5, 3), (8, 8), (9, 300),
                             (12, 7), (17, 33)] {
                let src = Mat::random_normal(
                    &mut Rng::new(m as u64 * 7 + k as u64), m, k);
                let packed = pack_rows(&src);
                let mut seg = vec![0.0_f64; m];
                for i in 0..m {
                    let seg = &mut seg[..m - i];
                    gram_row_segment_into(&src, &packed, i, seg);
                    for (off, &v) in seg.iter().enumerate() {
                        let j = i + off;
                        let mut s = 0.0_f64;
                        for kk in 0..k {
                            if fma {
                                s = src[(i, kk)].mul_add(src[(j, kk)], s);
                            } else {
                                s += src[(i, kk)] * src[(j, kk)];
                            }
                        }
                        assert_eq!(v, s, "({i},{j}) of {m}x{k} on {}",
                                   be.name());
                    }
                }
            }
        }
        simd::set_backend(None).unwrap();
    }

    /// Naive mode-matched f32 reference: one accumulator, ascending k.
    fn naive_nt_f32(a: &[f32], m: usize, k: usize, b: &[f32], n: usize)
                    -> Vec<f32> {
        let fma = simd::fma_active();
        let mut out = vec![0.0_f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0_f32;
                for kk in 0..k {
                    if fma {
                        s = a[i * k + kk].mul_add(b[j * k + kk], s);
                    } else {
                        s += a[i * k + kk] * b[j * k + kk];
                    }
                }
                out[i * n + j] = s;
            }
        }
        out
    }

    fn f32s(rng: &mut Rng, n: usize) -> Vec<f32> {
        rng.normal_vec(n).iter().map(|&v| v as f32).collect()
    }

    #[test]
    fn f32_blocked_kernel_bit_identical_to_naive_for_every_backend() {
        let _guard = sweep_lock();
        for be in simd::available_backends() {
            simd::set_backend(Some(be)).unwrap();
            for (m, k, n) in shapes() {
                let mut rng = Rng::new(m as u64 * 131 + k as u64 * 3
                                       + n as u64);
                let a = f32s(&mut rng, m * k);
                let b = f32s(&mut rng, n * k);
                let got = matmul_nt_f32(&a, m, k, &b, n);
                assert_eq!(got, naive_nt_f32(&a, m, k, &b, n),
                           "f32 {m}x{k}·{n}ᵀ on {}", be.name());
            }
        }
        simd::set_backend(None).unwrap();
    }

    #[test]
    fn f32_row_ranges_compose_exactly() {
        let (m, k, n) = (23usize, 31usize, 19usize);
        let mut rng = Rng::new(5);
        let a = f32s(&mut rng, m * k);
        let b = f32s(&mut rng, n * k);
        let packed = pack_rows_f32(&b, n, k);
        let mut full = vec![0.0_f32; m * n];
        matmul_nt_f32_block(&a, k, &packed, 0, m, &mut full);
        for split in [1usize, 4, 7, 16, 22] {
            let mut top = vec![0.0_f32; split * n];
            let mut bot = vec![0.0_f32; (m - split) * n];
            matmul_nt_f32_block(&a, k, &packed, 0, split, &mut top);
            matmul_nt_f32_block(&a, k, &packed, split, m, &mut bot);
            top.extend_from_slice(&bot);
            assert_eq!(top, full, "split {split}");
        }
    }

    #[test]
    fn single_call_segment_matches_into() {
        let src = Mat::random_normal(&mut Rng::new(42), 11, 9);
        let packed = pack_rows(&src);
        for i in 0..src.rows {
            let mut seg = vec![0.0_f64; src.rows - i];
            gram_row_segment_into(&src, &packed, i, &mut seg);
            assert_eq!(gram_row_segment(&src, i), seg);
        }
    }
}
