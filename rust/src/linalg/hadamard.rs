//! Fast Walsh–Hadamard transform — the rust twin of the L1 Pallas `fwht`
//! kernel (QuaRot's online rotation).  Used by the native pipeline when it
//! needs to reproduce the rotated activations without the PJRT engine, and
//! to build the fusion matrices.

use super::Mat;

/// In-place normalized FWHT along a length-d (power of two) buffer.
pub fn fwht(x: &mut [f64]) {
    let d = x.len();
    assert!(d.is_power_of_two(), "FWHT needs power-of-two length, got {d}");
    let mut h = 1;
    while h < d {
        let step = h * 2;
        let mut i = 0;
        while i < d {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
            i += step;
        }
        h = step;
    }
    let norm = 1.0 / (d as f64).sqrt();
    for v in x.iter_mut() {
        *v *= norm;
    }
}

/// f32 variant for runtime activation buffers.
pub fn fwht_f32(x: &mut [f32]) {
    let d = x.len();
    assert!(d.is_power_of_two());
    let mut h = 1;
    while h < d {
        let step = h * 2;
        let mut i = 0;
        while i < d {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
            i += step;
        }
        h = step;
    }
    let norm = 1.0 / (d as f32).sqrt();
    for v in x.iter_mut() {
        *v *= norm;
    }
}

/// Explicit normalized Hadamard matrix (Sylvester), H = Hᵀ, H·H = I.
pub fn hadamard_matrix(d: usize) -> Mat {
    assert!(d.is_power_of_two());
    let mut m = Mat::zeros(d, d);
    m[(0, 0)] = 1.0;
    let mut h = 1;
    while h < d {
        for i in 0..h {
            for j in 0..h {
                let v = m[(i, j)];
                m[(i, j + h)] = v;
                m[(i + h, j)] = v;
                m[(i + h, j + h)] = -v;
            }
        }
        h *= 2;
    }
    m.scale(1.0 / (d as f64).sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn involution() {
        // property: normalized FWHT is its own inverse
        for seed in 0..5 {
            let mut rng = Rng::new(seed);
            let mut x = rng.normal_vec(64);
            let orig = x.clone();
            fwht(&mut x);
            fwht(&mut x);
            for (a, b) in x.iter().zip(&orig) {
                assert!((a - b).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn preserves_norm() {
        let mut rng = Rng::new(3);
        let mut x = rng.normal_vec(128);
        let n0: f64 = x.iter().map(|v| v * v).sum();
        fwht(&mut x);
        let n1: f64 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() / n0 < 1e-12);
    }

    #[test]
    fn matches_matrix() {
        let d = 16;
        let h = hadamard_matrix(d);
        let mut rng = Rng::new(5);
        let x = rng.normal_vec(d);
        let via_mat = h.matvec(&x);
        let mut via_fwht = x.clone();
        fwht(&mut via_fwht);
        for (a, b) in via_mat.iter().zip(&via_fwht) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn matrix_orthogonal() {
        let h = hadamard_matrix(32);
        let prod = h.matmul(&h.transpose());
        assert!(prod.sub(&Mat::eye(32)).max_abs() < 1e-12);
    }

    #[test]
    fn f32_matches_f64() {
        let mut rng = Rng::new(11);
        let xs = rng.normal_vec(256);
        let mut a: Vec<f32> = xs.iter().map(|&v| v as f32).collect();
        let mut b = xs.clone();
        fwht_f32(&mut a);
        fwht(&mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((*x as f64 - y).abs() < 1e-4);
        }
    }
}
