//! Real sub-byte bit-packing — b-bit two's-complement codes in a dense
//! little-endian bit-stream, for b ∈ 2..=8.
//!
//! The eval HLO consumes *dequantized* grid weights (simulated
//! quantization, as in the paper), but Table 3 reports model sizes in GB;
//! this module is the storage layer those numbers come from, and the
//! round-trip proves the grid representation really fits in b bits.  For
//! b = 4 the layout is byte-for-byte the classic two-nibbles-per-byte
//! packing (low nibble first); 2- and 3-bit codes tile the same stream
//! (Fig. 3 / Table 2 bit-width ablations).

use crate::linalg::Mat;

/// A bit-packed integer tensor with per-row (or per-group) f32 scales.
#[derive(Clone, Debug)]
pub struct PackedInts {
    pub rows: usize,
    pub cols: usize,
    /// code width in bits (2..=8)
    pub bits: u32,
    pub group: Option<usize>,
    /// rows·cols codes, little-endian within the bit-stream
    pub bytes: Vec<u8>,
    /// [rows * n_groups] scales
    pub scales: Vec<f32>,
}

impl PackedInts {
    /// Pack a weight matrix already produced by a b-bit quantizer (values
    /// on the grid q·s).  Recovers the integer codes from the scales.
    pub fn pack(wq: &Mat, scales: &Mat, bits: u32, group: Option<usize>)
                -> PackedInts {
        assert!((2..=8).contains(&bits), "bits {bits} out of 2..=8");
        let (rows, cols) = (wq.rows, wq.cols);
        let g = group.unwrap_or(cols.max(1));
        let b = bits as usize;
        let half = 1i64 << (bits - 1);
        let mask = (1u64 << bits) - 1;
        let mut bytes = vec![0u8; (rows * cols * b).div_ceil(8)];
        let mut bitpos = 0usize;
        for i in 0..rows {
            for j in 0..cols {
                let s = scales[(i, j / g)];
                let q = (wq[(i, j)] / s).round() as i64;
                debug_assert!((-half..half).contains(&q),
                              "code {q} out of int{bits}");
                let code = (q as u64) & mask;
                let byte = bitpos / 8;
                let off = bitpos % 8;
                bytes[byte] |= (code << off) as u8;
                if off + b > 8 {
                    // a code spans at most one byte boundary (b ≤ 8)
                    bytes[byte + 1] |= (code >> (8 - off)) as u8;
                }
                bitpos += b;
            }
        }
        PackedInts {
            rows,
            cols,
            bits,
            group,
            bytes,
            scales: scales.data.iter().map(|&x| x as f32).collect(),
        }
    }

    /// Dequantize back to grid values.
    pub fn unpack(&self) -> Mat {
        let g = self.group.unwrap_or(self.cols.max(1));
        let ng = if self.cols == 0 { 0 } else { self.cols / g };
        let b = self.bits as usize;
        let half = 1i64 << (self.bits - 1);
        let mask = (1u64 << self.bits) - 1;
        let mut out = Mat::zeros(self.rows, self.cols);
        let mut bitpos = 0usize;
        for i in 0..self.rows {
            for j in 0..self.cols {
                let byte = bitpos / 8;
                let off = bitpos % 8;
                let mut raw = (self.bytes[byte] as u64) >> off;
                if off + b > 8 {
                    raw |= (self.bytes[byte + 1] as u64) << (8 - off);
                }
                raw &= mask;
                // sign-extend the b-bit code
                let q = if (raw as i64) >= half {
                    raw as i64 - (half << 1)
                } else {
                    raw as i64
                };
                out[(i, j)] = q as f64 * self.scales[i * ng + j / g] as f64;
                bitpos += b;
            }
        }
        out
    }

    /// Storage bytes: packed codes + f32 scales (Table 3 accounting).
    pub fn size_bytes(&self) -> usize {
        self.bytes.len() + self.scales.len() * 4
    }
}

/// Size accounting for a whole quantized model (Table 3's "Size" column).
/// `fp_params` are kept in fp16 per the paper (2 bytes), the low-rank
/// matrices too (the paper: "we are effectively at 6.08 bits").
pub fn model_size_bytes(packed: usize, lowrank_params: usize,
                        fp_params: usize) -> usize {
    packed + 2 * lowrank_params + 2 * fp_params
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{rtn_quantize, weight_scales};
    use crate::rng::Rng;

    #[test]
    fn pack_roundtrip_exact() {
        for seed in 0..5 {
            let w = Mat::random_normal(&mut Rng::new(seed), 7, 32);
            let s = weight_scales(&w, 4, None);
            let q = rtn_quantize(&w, 4, None);
            let p = PackedInts::pack(&q, &s, 4, None);
            let back = p.unpack();
            // scales are stored as f32, so the roundtrip is f32-exact
            assert!(q.sub(&back).max_abs() < 1e-5, "seed {seed}");
        }
    }

    #[test]
    fn grouped_roundtrip() {
        let w = Mat::random_normal(&mut Rng::new(9), 5, 64);
        let s = weight_scales(&w, 4, Some(16));
        let q = rtn_quantize(&w, 4, Some(16));
        let p = PackedInts::pack(&q, &s, 4, Some(16));
        assert!(q.sub(&p.unpack()).max_abs() < 1e-5);
    }

    #[test]
    fn low_bit_roundtrip() {
        // 2- and 3-bit codes span byte boundaries; the stream must still
        // round-trip against the RTN grid
        for bits in [2u32, 3] {
            let w = Mat::random_normal(&mut Rng::new(bits as u64), 6, 40);
            let s = weight_scales(&w, bits, None);
            let q = rtn_quantize(&w, bits, None);
            let p = PackedInts::pack(&q, &s, bits, None);
            assert!(q.sub(&p.unpack()).max_abs() < 1e-5, "bits {bits}");
        }
    }

    #[test]
    fn bits_per_weight_accounting() {
        let w = Mat::random_normal(&mut Rng::new(1), 64, 64);
        for (bits, code_bytes) in [(4u32, 64 * 64 / 2), (3, 64 * 64 * 3 / 8),
                                   (2, 64 * 64 / 4)] {
            let s = weight_scales(&w, bits, None);
            let q = rtn_quantize(&w, bits, None);
            let p = PackedInts::pack(&q, &s, bits, None);
            assert_eq!(p.bytes.len(), code_bytes, "bits {bits}");
            assert_eq!(p.size_bytes(), code_bytes + 64 * 4, "bits {bits}");
        }
    }

    #[test]
    fn negative_extremes() {
        // exercise the most-negative code (sign extension edge) per width
        for bits in [2u32, 3, 4] {
            let half = (1i64 << (bits - 1)) as f64;
            let mut w = Mat::zeros(1, 2);
            w[(0, 0)] = -half;
            w[(0, 1)] = half - 1.0;
            let mut s = Mat::zeros(1, 1);
            s[(0, 0)] = 1.0;
            let p = PackedInts::pack(&w, &s, bits, None);
            let back = p.unpack();
            assert_eq!(back[(0, 0)], -half, "bits {bits}");
            assert_eq!(back[(0, 1)], half - 1.0, "bits {bits}");
        }
    }
}
