//! Real int4 bit-packing — two signed nibbles per byte.
//!
//! The eval HLO consumes *dequantized* grid weights (simulated quantization,
//! as in the paper), but Table 3 reports model sizes in GB; this module is
//! the storage layer those numbers come from, and the round-trip proves the
//! grid representation really fits in 4 bits.

use crate::linalg::Mat;

/// A bit-packed int4 tensor with per-row (or per-group) f32 scales.
#[derive(Clone, Debug)]
pub struct PackedInt4 {
    pub rows: usize,
    pub cols: usize,
    pub group: Option<usize>,
    /// two values per byte, row-major, low nibble first
    pub nibbles: Vec<u8>,
    /// [rows * n_groups] scales
    pub scales: Vec<f32>,
}

impl PackedInt4 {
    /// Pack a weight matrix already produced by an int4 quantizer (values
    /// on the grid q·s).  Recovers the integer codes from the scales.
    pub fn pack(wq: &Mat, scales: &Mat, group: Option<usize>) -> PackedInt4 {
        let (rows, cols) = (wq.rows, wq.cols);
        let g = group.unwrap_or(cols);
        let mut nibbles = vec![0u8; (rows * cols + 1) / 2];
        for i in 0..rows {
            for j in 0..cols {
                let s = scales[(i, j / g)];
                let q = (wq[(i, j)] / s).round() as i64;
                debug_assert!((-8..=7).contains(&q), "code {q} out of int4");
                let code = (q as i8 & 0x0f) as u8;
                let idx = i * cols + j;
                if idx % 2 == 0 {
                    nibbles[idx / 2] |= code;
                } else {
                    nibbles[idx / 2] |= code << 4;
                }
            }
        }
        PackedInt4 {
            rows,
            cols,
            group,
            nibbles,
            scales: scales.data.iter().map(|&x| x as f32).collect(),
        }
    }

    /// Dequantize back to grid values.
    pub fn unpack(&self) -> Mat {
        let g = self.group.unwrap_or(self.cols);
        let ng = self.cols / g;
        let mut out = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for j in 0..self.cols {
                let idx = i * self.cols + j;
                let byte = self.nibbles[idx / 2];
                let raw = if idx % 2 == 0 { byte & 0x0f } else { byte >> 4 };
                // sign-extend the nibble
                let q = ((raw << 4) as i8 >> 4) as f64;
                out[(i, j)] = q * self.scales[i * ng + j / g] as f64;
            }
        }
        out
    }

    /// Storage bytes: nibbles + f32 scales (Table 3 accounting).
    pub fn size_bytes(&self) -> usize {
        self.nibbles.len() + self.scales.len() * 4
    }
}

/// Size accounting for a whole quantized model (Table 3's "Size" column).
/// `fp_params` are kept in fp16 per the paper (2 bytes), the low-rank
/// matrices too (the paper: "we are effectively at 6.08 bits").
pub fn model_size_bytes(packed: usize, lowrank_params: usize,
                        fp_params: usize) -> usize {
    packed + 2 * lowrank_params + 2 * fp_params
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{rtn_quantize, weight_scales};
    use crate::rng::Rng;

    #[test]
    fn pack_roundtrip_exact() {
        for seed in 0..5 {
            let w = Mat::random_normal(&mut Rng::new(seed), 7, 32);
            let s = weight_scales(&w, 4, None);
            let q = rtn_quantize(&w, 4, None);
            let p = PackedInt4::pack(&q, &s, None);
            let back = p.unpack();
            // scales are stored as f32, so the roundtrip is f32-exact
            assert!(q.sub(&back).max_abs() < 1e-5, "seed {seed}");
        }
    }

    #[test]
    fn grouped_roundtrip() {
        let w = Mat::random_normal(&mut Rng::new(9), 5, 64);
        let s = weight_scales(&w, 4, Some(16));
        let q = rtn_quantize(&w, 4, Some(16));
        let p = PackedInt4::pack(&q, &s, Some(16));
        assert!(q.sub(&p.unpack()).max_abs() < 1e-5);
    }

    #[test]
    fn four_bits_per_weight() {
        let w = Mat::random_normal(&mut Rng::new(1), 64, 64);
        let s = weight_scales(&w, 4, None);
        let q = rtn_quantize(&w, 4, None);
        let p = PackedInt4::pack(&q, &s, None);
        // 64*64/2 bytes of nibbles + 64 scales * 4B
        assert_eq!(p.nibbles.len(), 64 * 64 / 2);
        assert_eq!(p.size_bytes(), 64 * 64 / 2 + 64 * 4);
    }

    #[test]
    fn negative_extremes() {
        // exercise the -8 code (sign extension edge)
        let mut w = Mat::zeros(1, 2);
        w[(0, 0)] = -8.0;
        w[(0, 1)] = 7.0;
        let mut s = Mat::zeros(1, 1);
        s[(0, 0)] = 1.0;
        let p = PackedInt4::pack(&w, &s, None);
        let back = p.unpack();
        assert_eq!(back[(0, 0)], -8.0);
        assert_eq!(back[(0, 1)], 7.0);
    }
}
