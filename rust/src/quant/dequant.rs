//! Fused dequant-GEMM: the paper's inference data path, executed
//! natively — `y = Ŵ·x + U·(Vᵀx)` straight from the bit-packed
//! representation.
//!
//! # What "fused" means here
//!
//! [`QuantizedLinear::forward`] consumes a [`PackedInts`] weight matrix
//! (2..=8-bit two's-complement codes × per-row/per-group f32 scales)
//! **without ever materializing the dense f32 weight matrix**: inside
//! the blocked-k sweep of [`crate::linalg::kernels`], each NC×KC panel
//! of codes is decoded straight into the SIMD lane-strip layout
//! (≤ 64 KB of scratch, L2-resident, recycled via the f32 workspace
//! arena) and immediately consumed by the f32 register tiles.  The
//! decode cost is paid once per panel and amortized over every
//! activation row.
//!
//! The low-rank correction is fused into the same sweep as **extra
//! k-panels**: with `T = X·V` precomputed by the canonical f32 GEMM,
//! the product `[X | T] · [Ŵ | U]ᵀ` runs every output element's
//! accumulator first through the quantized columns (ascending k) and
//! then through the rank columns (ascending l) — one pass over the
//! output, one accumulator chain per element.
//!
//! # The extended canonical-program contract
//!
//! Every output element is produced by exactly the floating-point
//! program of the naive reference ([`QuantizedLinear::reference_forward`]):
//! `unpack()` to f32, matmul with a single ascending-k f32 accumulator,
//! then add the correction term with the same accumulator continuing in
//! ascending l (one IEEE f32 mul + add per step; one fused `mul_add`
//! per step in FMA mode).  Decoding tile-by-tile is bit-invisible
//! because `q·s` computed in f32 *is* the correctly-rounded product
//! (|q| < 2⁸ and an f32 scale fill well under f64's 53-bit mantissa, so
//! `unpack()`'s f64 product is exact and rounds to the identical f32).
//! `tests/kernel_oracle.rs` locks fused == reference with `==` across
//! bits × group × backend × thread-count sweeps.

use crate::linalg::kernels::{self, matmul_nt_f32_into, KC, MR, NC};
use crate::linalg::{simd, workspace, Mat, PAR_MIN_WORK};
use crate::par::Pool;
use crate::quant::pack::PackedInts;
use crate::quant::weight_scales;

/// A quantized linear layer in serving form: bit-packed weights plus the
/// optional low-rank correction factors, with
/// [`forward`](QuantizedLinear::forward) running the fused
/// dequant-GEMM data path.
///
/// Shapes: `packed` is `[dout, din]`, `u` is `[dout, rank]` row-major,
/// and V is held transposed (`vt`, `[rank, din]` row-major) so both the
/// `Vᵀx` pre-pass and the fused sweep stream contiguous rows.
pub struct QuantizedLinear {
    pub packed: PackedInts,
    u: Option<Vec<f32>>,
    vt: Option<Vec<f32>>,
    rank: usize,
}

impl QuantizedLinear {
    /// Assemble from pipeline artifacts: `u`/`v` as `(rank, data)` with
    /// `u` `[dout, rank]` and `v` `[din, rank]` row-major (the
    /// `LayerArtifacts` / bundle-tensor layout).  V is transposed once
    /// here.  Rank 0 (or `None`) yields the pure quantized path.
    pub fn new(packed: PackedInts, u: Option<(usize, Vec<f32>)>,
               v: Option<(usize, Vec<f32>)>) -> QuantizedLinear {
        let (dout, din) = (packed.rows, packed.cols);
        let rank = u.as_ref().map_or(0, |(k, _)| *k);
        assert_eq!(rank, v.as_ref().map_or(0, |(k, _)| *k),
                   "u/v rank mismatch");
        if rank == 0 {
            return QuantizedLinear { packed, u: None, vt: None, rank: 0 };
        }
        let (_, u) = u.unwrap();
        let (_, v) = v.unwrap();
        assert_eq!(u.len(), dout * rank, "u shape");
        assert_eq!(v.len(), din * rank, "v shape");
        let mut vt = vec![0.0_f32; rank * din];
        for kk in 0..din {
            for l in 0..rank {
                vt[l * din + kk] = v[kk * rank + l];
            }
        }
        QuantizedLinear { packed, u: Some(u), vt: Some(vt), rank }
    }

    /// Pack a dense grid-valued weight matrix (output of a b-bit
    /// quantizer) plus optional f64 correction factors `u` `[dout, k]`,
    /// `v` `[din, k]` — the [`crate::lrc::LayerResult`] shapes.
    pub fn from_dense(wq: &Mat, bits: u32, group: Option<usize>,
                      u: Option<&Mat>, v: Option<&Mat>) -> QuantizedLinear {
        let scales = weight_scales(wq, bits, group);
        let packed = PackedInts::pack(wq, &scales, bits, group);
        let to32 = |m: &Mat| -> (usize, Vec<f32>) {
            (m.cols, m.data.iter().map(|&x| x as f32).collect())
        };
        QuantizedLinear::new(packed, u.map(to32), v.map(to32))
    }

    pub fn dout(&self) -> usize {
        self.packed.rows
    }

    pub fn din(&self) -> usize {
        self.packed.cols
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Serving-form storage bytes: packed codes + scales + f32 factors.
    pub fn size_bytes(&self) -> usize {
        self.packed.size_bytes()
            + 4 * (self.u.as_ref().map_or(0, |u| u.len())
                   + self.vt.as_ref().map_or(0, |v| v.len()))
    }

    /// Floating-point ops of one `[m, din]` forward (the tokens/s and
    /// GFLOP/s denominator in the benches): the quantized product plus,
    /// when rank > 0, the `Vᵀx` pre-pass and the fused correction
    /// columns.
    pub fn flops(&self, m: usize) -> f64 {
        let (dout, din, k) = (self.dout(), self.din(), self.rank);
        2.0 * m as f64 * (dout as f64 * din as f64
                          + k as f64 * (din + dout) as f64)
    }

    /// `Y = X·Ŵᵀ + (X·V)·Uᵀ` for row-major `X` `[m, din]`, returning
    /// `[m, dout]`.  Auto-parallel past [`PAR_MIN_WORK`] on
    /// [`crate::par::global`]; bit-identical at every thread count and
    /// on every SIMD backend to [`Self::reference_forward`].
    pub fn forward(&self, x: &[f32], m: usize) -> Vec<f32> {
        let mut out = Vec::new();
        self.forward_into(x, m, &mut out);
        out
    }

    /// [`Self::forward`] into a caller-held buffer (steady-state
    /// allocation-free: decode scratch and the `T = X·V` temporary come
    /// from the f32 workspace arena).
    pub fn forward_into(&self, x: &[f32], m: usize, out: &mut Vec<f32>) {
        self.forward_split_into(x, x, m, out);
    }

    /// The serving-kernel form `Y = Xq·Ŵᵀ + (Xc·V)·Uᵀ` with *different*
    /// A-sides: the packed product consumes the activation-quantized
    /// `xq` while the correction runs on the unquantized `xc` — the
    /// paper's Fig. 1 data flow ([`Self::forward`] is the `xq == xc`
    /// special case).  Both inputs are `[m, din]` row-major.
    pub fn forward_split_into(&self, xq: &[f32], xc: &[f32], m: usize,
                              out: &mut Vec<f32>) {
        let work = m * self.dout() * (self.din() + self.rank);
        if m <= Mat::PAR_ROW_CHUNK || work < PAR_MIN_WORK
            || crate::par::in_pool()
        {
            self.split_serial(xq, xc, m, out);
        } else {
            self.split_pool(xq, xc, m, crate::par::global(), out);
        }
    }

    /// Serial fused forward (no pool touched at all).
    pub fn forward_serial(&self, x: &[f32], m: usize, out: &mut Vec<f32>) {
        self.split_serial(x, x, m, out);
    }

    /// Fused forward on an explicit pool (the kernel-oracle thread-sweep
    /// entry): rows split into [`Mat::PAR_ROW_CHUNK`] chunks with
    /// disjoint output writes — bit-identical at every thread count
    /// because chunking never touches the per-element program.
    pub fn forward_pool(&self, x: &[f32], m: usize, pool: &Pool,
                        out: &mut Vec<f32>) {
        self.split_pool(x, x, m, pool, out);
    }

    fn split_serial(&self, xq: &[f32], xc: &[f32], m: usize,
                    out: &mut Vec<f32>) {
        assert_eq!(xq.len(), m * self.din(), "forward Xq shape");
        let t = self.correction_pre_pass(xc, m);
        self.prep_out(m, out);
        self.fused_rows(xq, t.as_deref(), 0, m, out);
        if let Some(t) = t {
            workspace::put_f32(t);
        }
    }

    fn split_pool(&self, xq: &[f32], xc: &[f32], m: usize, pool: &Pool,
                  out: &mut Vec<f32>) {
        assert_eq!(xq.len(), m * self.din(), "forward Xq shape");
        let t = self.correction_pre_pass(xc, m);
        self.prep_out(m, out);
        let chunk = Mat::PAR_ROW_CHUNK;
        if pool.threads() == 1 || m <= chunk {
            self.fused_rows(xq, t.as_deref(), 0, m, out);
        } else {
            let n = self.dout();
            let shared = workspace::SharedSlice::new(&mut out[..]);
            pool.for_indices(m.div_ceil(chunk), |ci| {
                let r0 = ci * chunk;
                let r1 = (r0 + chunk).min(m);
                // SAFETY: row chunks [r0, r1) partition out — disjoint
                let slice = unsafe { shared.range(r0 * n, r1 * n) };
                self.fused_rows(xq, t.as_deref(), r0, r1, slice);
            });
        }
        if let Some(t) = t {
            workspace::put_f32(t);
        }
    }

    /// `T = X·V` (equivalently `X·vtᵀ`) on the canonical f32 GEMM, into
    /// arena scratch.  `None` when rank = 0.
    fn correction_pre_pass(&self, x: &[f32], m: usize) -> Option<Vec<f32>> {
        assert_eq!(x.len(), m * self.din(), "forward X shape");
        let vt = self.vt.as_ref()?;
        let mut t = workspace::take_raw_f32(m * self.rank);
        matmul_nt_f32_into(x, m, self.din(), vt, self.rank, &mut t);
        Some(t)
    }

    fn prep_out(&self, m: usize, out: &mut Vec<f32>) {
        out.clear();
        out.resize(m * self.dout(), 0.0);
    }

    /// The fused sweep over rows `[r0, r1)` of X, writing `out` (rows
    /// relative to `r0`, zero-initialized by the caller).  Mirrors the
    /// jc → kc → i nest of `kernels::matmul_nt_block`, except each
    /// (jc, kc) panel's lane strips are **decoded** from the packed
    /// codes (or copied from U for the correction panels) instead of
    /// read from a pre-packed dense matrix.
    fn fused_rows(&self, x: &[f32], t: Option<&[f32]>, r0: usize, r1: usize,
                  out: &mut [f32]) {
        let (n, din, rank) = (self.dout(), self.din(), self.rank);
        debug_assert_eq!(out.len(), (r1 - r0) * n);
        if n == 0 || r1 <= r0 {
            return;
        }
        // capture once per sweep: a mid-call flip of the process-global
        // backend/FMA knobs can never mix programs inside one forward
        let be = simd::active();
        let fma = simd::fma_active();
        let nr = be.nr32();
        debug_assert_eq!(NC % nr, 0);
        let mut scratch = workspace::take_zeroed_f32(NC * KC);
        let mut jc = 0;
        while jc < n {
            let jc_hi = (jc + NC).min(n);
            // quantized k-panels: decode codes × scales into lane strips
            let mut kc = 0;
            while kc < din {
                let kc_hi = (kc + KC).min(din);
                decode_strips(&self.packed, jc, jc_hi, kc, kc_hi, nr,
                              &mut scratch);
                sweep_rows(be, fma, x, din, kc, kc_hi, jc, jc_hi, nr,
                           &scratch, r0, r1, n, out);
                kc = kc_hi;
            }
            // correction k-panels: each accumulator continues through
            // the rank columns — T rows × U strips, ascending l
            if rank > 0 {
                let (t, u) = (t.expect("rank > 0 has T"),
                              self.u.as_deref().expect("rank > 0 has U"));
                let mut kc = 0;
                while kc < rank {
                    let kc_hi = (kc + KC).min(rank);
                    pack_u_strips(u, rank, n, jc, jc_hi, kc, kc_hi, nr,
                                  &mut scratch);
                    sweep_rows(be, fma, t, rank, kc, kc_hi, jc, jc_hi, nr,
                               &scratch, r0, r1, n, out);
                    kc = kc_hi;
                }
            }
            jc = jc_hi;
        }
        workspace::put_f32(scratch);
    }

    /// The naive unpack-then-matmul-then-correction f32 reference — the
    /// bit-exact specification of [`Self::forward`] (and the only path
    /// that materializes the dense weight matrix; tests and the
    /// equality-asserting bench sections call it, serving never does).
    /// Mode-matched: fused `mul_add` steps when the FMA mode is active.
    pub fn reference_forward(&self, x: &[f32], m: usize) -> Vec<f32> {
        self.reference_split(x, x, m)
    }

    /// [`Self::reference_forward`] for the split form: the naive
    /// specification of [`Self::forward_split_into`].
    pub fn reference_split(&self, xq: &[f32], xc: &[f32], m: usize)
                           -> Vec<f32> {
        assert_eq!(xq.len(), m * self.din(), "forward Xq shape");
        assert_eq!(xc.len(), m * self.din(), "forward X shape");
        let fma = simd::fma_active();
        let (dout, din, rank) = (self.dout(), self.din(), self.rank);
        let w: Vec<f32> =
            self.packed.unpack().data.iter().map(|&v| v as f32).collect();
        // naive T = Xc·V, one ascending-k chain per element
        let t: Option<Vec<f32>> = self.vt.as_ref().map(|vt| {
            let mut t = vec![0.0_f32; m * rank];
            for i in 0..m {
                for l in 0..rank {
                    let mut s = 0.0_f32;
                    for kk in 0..din {
                        let (a, b) = (xc[i * din + kk], vt[l * din + kk]);
                        s = if fma { a.mul_add(b, s) } else { s + a * b };
                    }
                    t[i * rank + l] = s;
                }
            }
            t
        });
        let mut out = vec![0.0_f32; m * dout];
        for i in 0..m {
            for j in 0..dout {
                let mut s = 0.0_f32;
                for kk in 0..din {
                    let (a, b) = (xq[i * din + kk], w[j * din + kk]);
                    s = if fma { a.mul_add(b, s) } else { s + a * b };
                }
                if let (Some(t), Some(u)) = (&t, &self.u) {
                    // the same accumulator continues in ascending l
                    for l in 0..rank {
                        let (a, b) = (t[i * rank + l], u[j * rank + l]);
                        s = if fma { a.mul_add(b, s) } else { s + a * b };
                    }
                }
                out[i * dout + j] = s;
            }
        }
        out
    }
}

/// Decode the `[j0, j1) × [kc, kc_hi)` block of packed codes into
/// nr-wide k-major lane strips: `strips[s_rel·kw·nr + kk·nr + l] =
/// q[j0 + s_rel·nr + l, kc + kk] · scale` (zero for padded lanes).  The
/// bit extraction is exactly [`PackedInts::unpack`]'s, walked
/// sequentially along each row's bit-stream; `q·s` in f32 is the
/// correctly-rounded product, so the decoded strip is bit-equal to
/// unpacking to f64 and narrowing.
fn decode_strips(p: &PackedInts, j0: usize, j1: usize, kc: usize,
                 kc_hi: usize, nr: usize, strips: &mut [f32]) {
    let kw = kc_hi - kc;
    let b = p.bits as usize;
    let half = 1i64 << (p.bits - 1);
    let mask = (1u64 << p.bits) - 1;
    let g = p.group.unwrap_or(p.cols.max(1));
    let ng = if p.cols == 0 { 0 } else { p.cols / g };
    for s_rel in 0..(j1 - j0).div_ceil(nr) {
        let strip = &mut strips[s_rel * kw * nr..(s_rel + 1) * kw * nr];
        for l in 0..nr {
            let j = j0 + s_rel * nr + l;
            if j >= p.rows {
                for kk in 0..kw {
                    strip[kk * nr + l] = 0.0;
                }
                continue;
            }
            let srow = &p.scales[j * ng..(j + 1) * ng];
            let mut bitpos = (j * p.cols + kc) * b;
            for kk in 0..kw {
                let byte = bitpos / 8;
                let off = bitpos % 8;
                let mut raw = (p.bytes[byte] as u64) >> off;
                if off + b > 8 {
                    // a code spans at most one byte boundary (b ≤ 8)
                    raw |= (p.bytes[byte + 1] as u64) << (8 - off);
                }
                raw &= mask;
                let q = if (raw as i64) >= half {
                    raw as i64 - (half << 1)
                } else {
                    raw as i64
                };
                strip[kk * nr + l] = q as f32 * srow[(kc + kk) / g];
                bitpos += b;
            }
        }
    }
}

/// Copy the `[j0, j1) × [kc, kc_hi)` block of U (`[n_rows, rank]`
/// row-major) into the same lane-strip layout as [`decode_strips`].
#[allow(clippy::too_many_arguments)]
fn pack_u_strips(u: &[f32], rank: usize, n_rows: usize, j0: usize, j1: usize,
                 kc: usize, kc_hi: usize, nr: usize, strips: &mut [f32]) {
    let kw = kc_hi - kc;
    for s_rel in 0..(j1 - j0).div_ceil(nr) {
        let strip = &mut strips[s_rel * kw * nr..(s_rel + 1) * kw * nr];
        for l in 0..nr {
            let j = j0 + s_rel * nr + l;
            if j >= n_rows {
                for kk in 0..kw {
                    strip[kk * nr + l] = 0.0;
                }
                continue;
            }
            let urow = &u[j * rank + kc..j * rank + kc_hi];
            for kk in 0..kw {
                strip[kk * nr + l] = urow[kk];
            }
        }
    }
}

/// One (kc, jc) panel sweep over rows `[r0, r1)`: the MR-row register
/// tiles of `kernels` driven over block-local strips.  `a` is the flat
/// `[*, kd]` row-major A-side (X for the quantized panels, T for the
/// correction panels); `out` rows are relative to `r0` and `n` wide.
#[allow(clippy::too_many_arguments)]
fn sweep_rows(be: simd::Backend, fma: bool, a: &[f32], kd: usize, kc: usize,
              kc_hi: usize, jc: usize, jc_hi: usize, nr: usize,
              strips: &[f32], r0: usize, r1: usize, n: usize,
              out: &mut [f32]) {
    let kw = kc_hi - kc;
    let arow = |i: usize| -> &[f32] { &a[i * kd..(i + 1) * kd] };
    let mut i = r0;
    while i < r1 {
        let i_hi = (i + MR).min(r1);
        let full = i_hi - i == MR;
        for s_rel in 0..(jc_hi - jc).div_ceil(nr) {
            let j = jc + s_rel * nr;
            let lanes = (jc_hi - j).min(nr);
            let strip = &strips[s_rel * kw * nr..(s_rel + 1) * kw * nr];
            if full {
                let rows: [&[f32]; MR] =
                    [&arow(i)[kc..kc_hi], &arow(i + 1)[kc..kc_hi],
                     &arow(i + 2)[kc..kc_hi], &arow(i + 3)[kc..kc_hi]];
                kernels::tile_full_f32(be, fma, rows, lanes, strip,
                                       (i - r0) * n + j, n, out);
            } else {
                for r in i..i_hi {
                    kernels::tile_row_f32(be, fma, &arow(r)[kc..kc_hi],
                                          lanes, strip, (r - r0) * n + j,
                                          out);
                }
            }
        }
        i = i_hi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn_quantize;
    use crate::rng::Rng;

    fn f32s(rng: &mut Rng, n: usize) -> Vec<f32> {
        rng.normal_vec(n).iter().map(|&v| v as f32).collect()
    }

    /// A random grid-valued layer at the given shape/bits/group/rank.
    fn layer(seed: u64, dout: usize, din: usize, bits: u32,
             group: Option<usize>, rank: usize) -> QuantizedLinear {
        let mut rng = Rng::new(seed);
        let w = Mat::random_normal(&mut rng, dout, din);
        let wq = rtn_quantize(&w, bits, group);
        let (u, v) = if rank > 0 {
            (Some(Mat::random_normal(&mut rng, dout, rank).scale(0.05)),
             Some(Mat::random_normal(&mut rng, din, rank).scale(0.05)))
        } else {
            (None, None)
        };
        QuantizedLinear::from_dense(&wq, bits, group, u.as_ref(), v.as_ref())
    }

    #[test]
    fn fused_matches_reference_bitwise() {
        // shapes straddling MR, nr32 (8/16), NC and KC boundaries; the
        // full bits × group × backend × threads sweep lives in
        // tests/kernel_oracle.rs
        for &(dout, din, m, rank) in &[(1usize, 1usize, 1usize, 0usize),
                                       (7, 9, 3, 2), (17, 33, 5, 4),
                                       (65, 70, 9, 3), (64, 256, 8, 0),
                                       (96, 300, 13, 8)] {
            let q = layer(dout as u64 * 7 + din as u64, dout, din, 4, None,
                          rank);
            let x = f32s(&mut Rng::new(99), m * din);
            let got = q.forward(&x, m);
            let want = q.reference_forward(&x, m);
            assert_eq!(got, want, "{dout}x{din} m={m} rank={rank}");
        }
    }

    #[test]
    fn split_inputs_match_reference_bitwise() {
        // distinct quantized / correction A-sides (the W4A4 data flow)
        let q = layer(5, 33, 40, 4, Some(8), 6);
        let xq = f32s(&mut Rng::new(7), 9 * 40);
        let xc = f32s(&mut Rng::new(8), 9 * 40);
        let mut got = Vec::new();
        q.forward_split_into(&xq, &xc, 9, &mut got);
        assert_eq!(got, q.reference_split(&xq, &xc, 9));
    }

    #[test]
    fn forward_into_is_steady_state_reusable() {
        let q = layer(3, 40, 48, 4, Some(16), 5);
        let x = f32s(&mut Rng::new(4), 6 * 48);
        let want = q.forward(&x, 6);
        let mut out = Vec::new();
        for _ in 0..3 {
            q.forward_into(&x, 6, &mut out);
            assert_eq!(out, want);
        }
    }

    #[test]
    fn pool_chunking_is_bit_identical() {
        let q = layer(11, 48, 64, 3, None, 4);
        let x = f32s(&mut Rng::new(12), 37 * 64);
        let mut serial = Vec::new();
        q.forward_serial(&x, 37, &mut serial);
        for threads in [1usize, 2, 4] {
            let pool = Pool::new(threads);
            let mut out = Vec::new();
            q.forward_pool(&x, 37, &pool, &mut out);
            assert_eq!(out, serial, "threads={threads}");
        }
    }

    #[test]
    fn size_and_flops_accounting() {
        let q = layer(21, 64, 64, 4, None, 3);
        // codes (64·64/2) + scales (64·4) + u/v (2·64·3·4)
        assert_eq!(q.size_bytes(), 64 * 64 / 2 + 64 * 4 + 2 * 64 * 3 * 4);
        assert_eq!(q.flops(2) as usize,
                   2 * 2 * (64 * 64 + 3 * (64 + 64)));
        let q0 = layer(22, 16, 16, 2, None, 0);
        assert_eq!(q0.rank(), 0);
        assert_eq!(q0.size_bytes(), 16 * 16 / 4 + 16 * 4);
    }
}
