//! Quantizers: the C(b) constraint set machinery.
//!
//! * [`rtn`]  — round-to-nearest (weights per-channel / activations
//!             per-token, optional groupsize) + the paper's clip search
//! * [`gptq`] — the GPTQ solver used inside Update-Quant (Alg. 2 line 5)
//! * [`pack`] — real 2/3/4…8-bit bit-packing (storage sizes for Table 3;
//!              roundtrips locked by `tests/quant_roundtrip.rs`)
//! * [`dequant`] — the fused dequant-GEMM serving path:
//!              [`QuantizedLinear`] runs `Ŵ·x + U·(Vᵀx)` straight from
//!              the packed codes, tile-by-tile, never materializing the
//!              dense weight matrix (oracle-locked bit-identical to the
//!              naive unpack-then-matmul reference)

pub mod dequant;
pub mod gptq;
pub mod pack;
pub mod rtn;

pub use dequant::QuantizedLinear;
pub use gptq::gptq;
pub use rtn::{act_quantize, act_quantize_into, rtn_quantize, search_act_clip,
              weight_scales};

/// A quantization configuration for one PTQ run.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantConfig {
    /// weight bits (paper: 4)
    pub w_bits: u32,
    /// activation bits (paper: 4); `None` = weight-only (Table 3)
    pub a_bits: Option<u32>,
    /// activation groupsize (paper's Table 2 uses 128; scaled here)
    pub a_group: Option<usize>,
    /// weight quantizer for Update-Quant ("gptq" | "rtn", Fig. 3 ablation)
    pub quantizer: Quantizer,
    /// low-rank budget as a fraction of each matrix's size (0.10 = 10%)
    pub rank_pct: f64,
    /// LRC alternating iterations (paper: 1 and 5)
    pub iters: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Quantizer {
    Gptq,
    Rtn,
}

impl Quantizer {
    /// Stable lowercase name — registry digests and CLI round-trips key
    /// on this, so it must never change for an existing variant.
    pub fn name(&self) -> &'static str {
        match self {
            Quantizer::Gptq => "gptq",
            Quantizer::Rtn => "rtn",
        }
    }
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig {
            w_bits: 4,
            a_bits: Some(4),
            a_group: None,
            quantizer: Quantizer::Gptq,
            rank_pct: 0.10,
            iters: 1,
        }
    }
}

impl QuantConfig {
    /// Config for one sweep grid cell: the swept axes (`w_bits` ×
    /// activation `group` × `quantizer` × `rank_pct`) over the paper's
    /// W4A4 defaults for everything the grid does not sweep.  Activation
    /// bits stay at 4 — the grid varies *weight* width, so one shared
    /// calibration pass per group value covers every cell (see
    /// [`crate::sweep`]).
    pub fn cell(w_bits: u32, a_group: Option<usize>, quantizer: Quantizer,
                rank_pct: f64, iters: usize) -> QuantConfig {
        QuantConfig {
            w_bits,
            a_bits: Some(4),
            a_group,
            quantizer,
            rank_pct,
            iters,
        }
    }
}

/// Rank giving ≈`pct` memory overhead for a [dout, din] matrix:
/// k·(dout+din) = pct·dout·din.  Must match python `lrc.rank_for_pct`.
pub fn rank_for_pct(dout: usize, din: usize, pct: f64) -> usize {
    if pct <= 0.0 {
        return 0;
    }
    let k = (pct * dout as f64 * din as f64 / (dout + din) as f64).round();
    (k as usize).max(1)
}

/// Symmetric grid max for b bits: e.g. 7 for int4 ([-8, 7], clip to ±7).
pub fn maxq(bits: u32) -> f64 {
    (1u64 << (bits - 1)) as f64 - 1.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_formula_matches_python() {
        // spot values mirrored in python/tests/test_lrc.py
        assert_eq!(rank_for_pct(64, 64, 0.10), 3);
        assert_eq!(rank_for_pct(128, 256, 0.10), 9);
        assert_eq!(rank_for_pct(256, 128, 0.30), 26);
        assert_eq!(rank_for_pct(64, 64, 0.0), 0);
    }

    #[test]
    fn cell_config_sweeps_only_the_grid_axes() {
        let c = QuantConfig::cell(3, Some(32), Quantizer::Rtn, 0.30, 5);
        assert_eq!(c.w_bits, 3);
        assert_eq!(c.a_group, Some(32));
        assert_eq!(c.quantizer, Quantizer::Rtn);
        assert_eq!(c.rank_pct, 0.30);
        assert_eq!(c.iters, 5);
        // the un-swept axes keep the W4A4 defaults
        assert_eq!(c.a_bits, QuantConfig::default().a_bits);
    }

    #[test]
    fn maxq_values() {
        assert_eq!(maxq(4), 7.0);
        assert_eq!(maxq(8), 127.0);
        assert_eq!(maxq(2), 1.0);
    }
}
