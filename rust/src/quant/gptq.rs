//! GPTQ (Frantar et al., 2022) — the layer-wise quantization solver used by
//! Update-Quant (Algorithm 2, line 5).
//!
//! Approximates  min_{Ŵ ∈ C(b)} ‖Ŵ·Y − W̃·Y‖²  given only W̃ and the
//! Hessian H = YYᵀ: quantize columns left→right, propagating each column's
//! error through the upper-Cholesky factor of H⁻¹ (the OBS update), with
//! lazy block batching so the trailing update is a GEMM.

use super::{maxq, weight_scales};
use crate::linalg::{cholesky, chol_solve_mat, workspace, Mat};

/// GPTQ with Cholesky error feedback.
///
/// * `w0`   — [dout, din] target weights (already W̃ from Prop. 3.1)
/// * `hess` — [din, din] = YYᵀ (caller may pre-regularize; damping is added
///            here too, as in the reference implementation)
/// * returns dequantized (on-grid) Ŵ
///
/// The working copies of W and H, the per-block error matrix and the
/// trailing-update GEMM operands all live in workspace-recycled storage,
/// so the per-layer fan-out's repeated GPTQ solves stop hammering the
/// allocator (each solve used to clone both inputs and allocate three
/// fresh matrices per block).
pub fn gptq(w0: &Mat, hess: &Mat, bits: u32, group: Option<usize>,
            damp: f64, block: usize) -> Result<Mat, String> {
    let (dout, din) = (w0.rows, w0.cols);
    assert_eq!(hess.rows, din);
    let mut w = workspace::take_mat_copy(w0);
    let mut h = workspace::take_mat_copy(hess);

    // dead-column guard + damping
    for j in 0..din {
        if h[(j, j)] == 0.0 {
            h[(j, j)] = 1.0;
            for i in 0..dout {
                w[(i, j)] = 0.0;
            }
        }
    }
    let mean_diag = h.trace() / din as f64;
    h.add_diag(damp * mean_diag);

    // upper-Cholesky factor of H⁻¹ via the reverse-ordering trick:
    // chol(P·H⁻¹·P)ᵀ reversed again gives U with H⁻¹ = Uᵀ·U, U upper.
    // (error paths below drop the workspace mats instead of recycling —
    // harmless, just a future cache miss on a cold path)
    let hinv = chol_solve_mat(&cholesky(&h)?, &Mat::eye(din));
    let hinv_u = upper_cholesky(&hinv)?;

    let scale = weight_scales(&w, bits, group);
    let g = group.unwrap_or(din);
    let mq = maxq(bits);
    let mut q_out = Mat::zeros(dout, din);

    // block scratch, taken at the first block's sizes — the largest any
    // block needs, so best-fit lands on the right cached buffer
    // immediately and later blocks only shrink within capacity — and
    // recycled at the end: the error matrix, the transposed trailing
    // slice of U, and the trailing-update product
    let bw0 = block.min(din);
    let mut werr = workspace::take_mat_for(dout, bw0);
    let mut hu_t = workspace::take_mat_for(din - bw0, bw0);
    let mut delta = workspace::take_mat_for(dout, din - bw0);

    let mut j1 = 0;
    while j1 < din {
        let j2 = (j1 + block).min(din);
        let bw = j2 - j1;
        // per-block error matrix [dout, bw]
        werr.resize_zeroed(dout, bw);
        for j in j1..j2 {
            let d = hinv_u[(j, j)];
            for i in 0..dout {
                let wj = w[(i, j)];
                let s = scale[(i, j / g)];
                let q = (wj / s).round().clamp(-(mq + 1.0), mq) * s;
                q_out[(i, j)] = q;
                let err = (wj - q) / d;
                werr[(i, j - j1)] = err;
                // propagate inside the block
                for jj in j..j2 {
                    w[(i, jj)] -= err * hinv_u[(j, jj)];
                }
            }
        }
        // propagate to the remaining columns in one GEMM:
        // W[:, j2:] -= werr · hinv_u[j1:j2, j2:]
        if j2 < din {
            let rest = din - j2;
            // the [rest, bw] transposed slice of hinv_u, built directly
            // in the layout matmul_nt consumes (what `matmul` would have
            // produced by transposing a [bw, rest] copy — same bits,
            // one fewer matrix)
            hu_t.resize_zeroed(rest, bw);
            for c in 0..rest {
                for r in 0..bw {
                    hu_t[(c, r)] = hinv_u[(j1 + r, j2 + c)];
                }
            }
            werr.matmul_nt_into(&hu_t, &mut delta);
            for i in 0..dout {
                let drow = delta.row(i);
                let wrow = &mut w.row_mut(i)[j2..];
                for (wv, dv) in wrow.iter_mut().zip(drow) {
                    *wv -= dv;
                }
            }
        }
        j1 = j2;
    }
    workspace::recycle_mat(werr);
    workspace::recycle_mat(hu_t);
    workspace::recycle_mat(delta);
    workspace::recycle_mat(w);
    workspace::recycle_mat(h);
    Ok(q_out)
}

/// Upper-triangular U with A = Uᵀ·U for symmetric PD A: exactly the
/// transpose of the lower Cholesky factor (A = L·Lᵀ = (Lᵀ)ᵀ·Lᵀ) —
/// the `torch.linalg.cholesky(·, upper=True)` the GPTQ reference uses.
fn upper_cholesky(a: &Mat) -> Result<Mat, String> {
    Ok(cholesky(a)?.transpose())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn_quantize;
    use crate::rng::Rng;

    fn layer_problem(seed: u64, dout: usize, din: usize, n: usize)
                     -> (Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        let w = Mat::random_normal(&mut rng, dout, din);
        // correlated activations
        let base = Mat::random_normal(&mut rng, din / 2, n);
        let mixer = Mat::random_normal(&mut rng, din, din / 2);
        let mut x = mixer.matmul(&base);
        let noise = Mat::random_normal(&mut rng, din, n).scale(0.1);
        x = x.add(&noise);
        let h = x.gram_n(); // XXᵀ
        (w, x, h)
    }

    fn recon_err(w: &Mat, q: &Mat, x: &Mat) -> f64 {
        w.sub(q).matmul(x).frob_norm()
    }

    #[test]
    fn upper_cholesky_factorizes() {
        for seed in 0..4 {
            let a = {
                let m = Mat::random_normal(&mut Rng::new(seed), 9, 12);
                let mut g = m.gram_n();
                g.add_diag(0.3);
                g
            };
            let u = upper_cholesky(&a).unwrap();
            // upper triangular
            for i in 0..9 {
                for j in 0..i {
                    assert!(u[(i, j)].abs() < 1e-12);
                }
            }
            let rec = u.transpose().matmul(&u);
            assert!(rec.sub(&a).max_abs() < 1e-8);
        }
    }

    #[test]
    fn gptq_beats_rtn_on_correlated_data() {
        // the whole point of GPTQ: error feedback helps when X correlated
        for seed in 0..3 {
            let (w, x, h) = layer_problem(seed, 16, 32, 256);
            let q_rtn = rtn_quantize(&w, 4, None);
            let q_gptq = gptq(&w, &h, 4, None, 0.01, 16).unwrap();
            let e_rtn = recon_err(&w, &q_rtn, &x);
            let e_gptq = recon_err(&w, &q_gptq, &x);
            assert!(e_gptq < e_rtn, "seed {seed}: gptq {e_gptq} rtn {e_rtn}");
        }
    }

    #[test]
    fn gptq_output_on_grid() {
        let (w, _x, h) = layer_problem(7, 8, 16, 128);
        let q = gptq(&w, &h, 4, None, 0.01, 8).unwrap();
        let s = weight_scales(&w, 4, None);
        // note: gptq scales are computed from the *original* w rows
        for i in 0..8 {
            for j in 0..16 {
                let steps = q[(i, j)] / s[(i, 0)];
                assert!((steps - steps.round()).abs() < 1e-6,
                        "off grid at ({i},{j})");
                assert!(steps.round().abs() <= 8.0);
            }
        }
    }

    #[test]
    fn block_size_invariance() {
        // property: lazy-batch block size must not change the result
        let (w, _x, h) = layer_problem(11, 6, 24, 200);
        let q1 = gptq(&w, &h, 4, None, 0.01, 1).unwrap();
        let q8 = gptq(&w, &h, 4, None, 0.01, 8).unwrap();
        let q24 = gptq(&w, &h, 4, None, 0.01, 24).unwrap();
        assert!(q1.sub(&q8).max_abs() < 1e-8);
        assert!(q1.sub(&q24).max_abs() < 1e-8);
    }

    #[test]
    fn grouped_gptq_runs() {
        let (w, x, h) = layer_problem(13, 8, 32, 256);
        let q = gptq(&w, &h, 4, Some(8), 0.01, 16).unwrap();
        let q_rtn = rtn_quantize(&w, 4, Some(8));
        assert!(recon_err(&w, &q, &x) <= recon_err(&w, &q_rtn, &x) * 1.01);
    }

    #[test]
    fn identity_hessian_equals_rtn() {
        // with H = I there is no correlation to exploit: GPTQ == RTN
        let w = Mat::random_normal(&mut Rng::new(5), 8, 16);
        let h = Mat::eye(16);
        let q = gptq(&w, &h, 4, None, 0.0, 4).unwrap();
        let q_rtn = rtn_quantize(&w, 4, None);
        assert!(q.sub(&q_rtn).max_abs() < 1e-9);
    }
}
