//! Round-to-nearest quantization — weights (per-output-channel or grouped)
//! and the paper's on-the-fly activation quantizer Q_a with its clip
//! hyper-parameter search.
//!
//! Mirrors python/compile/lrc.py exactly (same grid, same ε guards) so the
//! two pipelines produce interchangeable bundles.

use super::maxq;
use crate::linalg::{workspace, Mat};

/// Per-output-channel (group=None) or per-group symmetric scales.
/// Returns a [dout, n_groups] matrix (n_groups = 1 when ungrouped).
pub fn weight_scales(w: &Mat, bits: u32, group: Option<usize>) -> Mat {
    let mq = maxq(bits);
    match group {
        None => {
            let mut s = Mat::zeros(w.rows, 1);
            for i in 0..w.rows {
                let amax = w.row(i).iter().fold(0.0_f64, |a, &x| a.max(x.abs()));
                s[(i, 0)] = amax / mq + 1e-12;
            }
            s
        }
        Some(g) => {
            assert_eq!(w.cols % g, 0, "cols {} % group {g}", w.cols);
            let ng = w.cols / g;
            let mut s = Mat::zeros(w.rows, ng);
            for i in 0..w.rows {
                let row = w.row(i);
                for gi in 0..ng {
                    let amax = row[gi * g..(gi + 1) * g]
                        .iter()
                        .fold(0.0_f64, |a, &x| a.max(x.abs()));
                    s[(i, gi)] = amax / mq + 1e-12;
                }
            }
            s
        }
    }
}

/// RTN weight quantization; returns dequantized (on-grid) weights.
pub fn rtn_quantize(w: &Mat, bits: u32, group: Option<usize>) -> Mat {
    let mq = maxq(bits);
    let s = weight_scales(w, bits, group);
    let g = group.unwrap_or(w.cols);
    let mut out = Mat::zeros(w.rows, w.cols);
    for i in 0..w.rows {
        for j in 0..w.cols {
            let sc = s[(i, j / g)];
            let q = (w[(i, j)] / sc).round().clamp(-(mq + 1.0), mq);
            out[(i, j)] = q * sc;
        }
    }
    out
}

/// Activation quantizer Q_a on X [din, n] (tokens are *columns*):
/// per-token scale = clip · max|x| / maxq (optionally per group of input
/// channels).  Returns the dequantized Y = Q_a(X).
pub fn act_quantize(x: &Mat, bits: u32, clip: f64, group: Option<usize>) -> Mat {
    let mut out = Mat::zeros(0, 0);
    act_quantize_into(x, bits, clip, group, &mut out);
    out
}

/// [`act_quantize`] writing into a caller-held matrix (reshaped to
/// [din, n]).  The per-token amax/scale scratch comes from the
/// [`workspace`] arena and `out` is typically arena-recycled storage
/// (e.g. [`workspace::take_mat_for`]), so a steady-state calibration
/// loop quantizes with **zero** allocations
/// (`tests/alloc_steady_state.rs` locks this through
/// `LayerStats::update`).  Same grid, same clamp, same ε as the
/// allocating entry point — the ungrouped case is the `g = din` special
/// case of the grouped walk, element for element.
pub fn act_quantize_into(x: &Mat, bits: u32, clip: f64,
                         group: Option<usize>, out: &mut Mat) {
    let mq = maxq(bits);
    let (din, n) = (x.rows, x.cols);
    out.resize_zeroed(din, n);
    let g = group.unwrap_or(din.max(1));
    assert_eq!(din % g, 0);
    // one arena buffer serves as the per-token amax and then — rewritten
    // in place — as the per-token scale
    let mut s = workspace::take_zeroed(n);
    for gi in 0..din / g {
        let rows = gi * g..(gi + 1) * g;
        s.iter_mut().for_each(|v| *v = 0.0);
        for i in rows.clone() {
            for (j, &v) in x.row(i).iter().enumerate() {
                let a = v.abs();
                if a > s[j] {
                    s[j] = a;
                }
            }
        }
        s.iter_mut().for_each(|a| *a = clip * *a / mq + 1e-12);
        for i in rows {
            for j in 0..n {
                let q = (x[(i, j)] / s[j]).round().clamp(-(mq + 1.0), mq);
                out[(i, j)] = q * s[j];
            }
        }
    }
    workspace::put(s);
}

/// Paper §2: grid search for the activation clip factor c, minimizing the
/// quantization error ‖X − Q_a(X)‖_F.
pub fn search_act_clip(x: &Mat, bits: u32, group: Option<usize>) -> f64 {
    let grid = [1.0, 0.95, 0.9, 0.85, 0.8, 0.75, 0.7];
    let mut best = f64::INFINITY;
    let mut best_c = 1.0;
    let mut y = workspace::take_mat_for(x.rows, x.cols);
    for &c in &grid {
        act_quantize_into(x, bits, c, group, &mut y);
        let err = x.sub(&y).frob_norm();
        if err < best {
            best = err;
            best_c = c;
        }
    }
    workspace::recycle_mat(y);
    best_c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn act_quantize_into_overwrites_dirty_scratch_bitwise() {
        // the into-variant must fully overwrite whatever a recycled
        // buffer held, matching the allocating entry point bit for bit
        let x = Mat::random_normal(&mut Rng::new(77), 8, 30);
        let dirty = Mat::random_normal(&mut Rng::new(78), 8, 30);
        for group in [None, Some(4)] {
            let fresh = act_quantize(&x, 4, 0.9, group);
            let mut out = workspace::take_mat_for(8, 30);
            act_quantize_into(&dirty, 4, 1.0, None, &mut out);
            act_quantize_into(&x, 4, 0.9, group, &mut out);
            assert_eq!(fresh, out, "group {group:?}");
            workspace::recycle_mat(out);
        }
    }

    #[test]
    fn rtn_on_grid_and_bounded_error() {
        // property: |w - q| <= scale/2 for in-range values; q on the grid
        for seed in 0..5 {
            let w = Mat::random_normal(&mut Rng::new(seed), 8, 32);
            let s = weight_scales(&w, 4, None);
            let q = rtn_quantize(&w, 4, None);
            for i in 0..w.rows {
                for j in 0..w.cols {
                    let err = (w[(i, j)] - q[(i, j)]).abs();
                    assert!(err <= s[(i, 0)] * 0.5 + 1e-9,
                            "err {err} scale {}", s[(i, 0)]);
                    let steps = q[(i, j)] / s[(i, 0)];
                    assert!((steps - steps.round()).abs() < 1e-6);
                }
            }
        }
    }

    #[test]
    fn grouped_tighter_than_ungrouped() {
        // property: group scales never increase quantization error
        let mut rng = Rng::new(42);
        let mut w = Mat::random_normal(&mut rng, 4, 64);
        // plant an outlier to make the difference visible
        w[(0, 0)] = 40.0;
        let e_full = w.sub(&rtn_quantize(&w, 4, None)).frob_norm();
        let e_grp = w.sub(&rtn_quantize(&w, 4, Some(16))).frob_norm();
        assert!(e_grp <= e_full + 1e-12, "{e_grp} > {e_full}");
    }

    #[test]
    fn more_bits_less_error() {
        let w = Mat::random_normal(&mut Rng::new(3), 6, 48);
        let e4 = w.sub(&rtn_quantize(&w, 4, None)).frob_norm();
        let e8 = w.sub(&rtn_quantize(&w, 8, None)).frob_norm();
        assert!(e8 < e4);
    }

    #[test]
    fn act_quant_per_token() {
        let x = Mat::random_normal(&mut Rng::new(9), 16, 40);
        let y = act_quantize(&x, 4, 1.0, None);
        // each column has <= 16 distinct magnitudes implied by the grid
        assert_eq!(y.rows, 16);
        // error bounded by scale/2 per token (clip=1 → no clipping)
        for j in 0..40 {
            let amax = (0..16).map(|i| x[(i, j)].abs()).fold(0.0_f64, f64::max);
            let s = amax / 7.0 + 1e-12;
            for i in 0..16 {
                assert!((x[(i, j)] - y[(i, j)]).abs() <= s * 0.5 + 1e-9);
            }
        }
    }

    #[test]
    fn clip_search_prefers_small_on_outliers() {
        // heavy-tailed (Laplace) activations: clipping the rare extreme
        // buys resolution for the bulk — the paper's motivation for c
        let mut rng = Rng::new(11);
        let mut x = Mat::zeros(256, 64);
        for i in 0..256 {
            for j in 0..64 {
                let u = rng.uniform().max(1e-12);
                let sign = if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
                x[(i, j)] = sign * (-u.ln()); // Laplace(0,1)
            }
        }
        let c = search_act_clip(&x, 4, None);
        assert!(c < 1.0, "clip {c}");
        // and the returned c is the grid argmin (definition check)
        let err_c = x.sub(&act_quantize(&x, 4, c, None)).frob_norm();
        let err_1 = x.sub(&act_quantize(&x, 4, 1.0, None)).frob_norm();
        assert!(err_c <= err_1);
    }

    #[test]
    fn identity_when_high_bits() {
        let x = Mat::random_normal(&mut Rng::new(2), 8, 8);
        let y = act_quantize(&x, 16, 1.0, None);
        assert!(x.sub(&y).max_abs() < 1e-3);
    }
}
