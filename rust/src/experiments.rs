//! Shared experiment harness: everything the CLI, examples and bench
//! targets need to produce a paper-shaped row — quantize a model variant,
//! evaluate PPL + the six task suites, format the row.

use std::path::Path;

use anyhow::Result;

use crate::data::{tasks::Task, Corpus};
use crate::eval::{all_task_accuracies, perplexity};
use crate::pipeline::{quantize_and_save, Method, PipelineReport};
use crate::quant::QuantConfig;
use crate::runtime::{Engine, ModelArtifacts, SessionProvider, TensorBundle};

/// One table row: PPL + per-task accuracy + average.
#[derive(Clone, Debug)]
pub struct VariantScores {
    pub label: String,
    pub ppl: f64,
    pub tasks: Vec<(String, f64)>,
    pub avg: f64,
}

impl VariantScores {
    /// Cells in the paper's column order: PPL PQ HS A-e A-c WG LA Avg.
    pub fn cells(&self) -> Vec<String> {
        let mut out = vec![self.label.clone(), format!("{:.2}", self.ppl)];
        for (_, acc) in &self.tasks {
            out.push(format!("{:.3}", acc));
        }
        out.push(format!("{:.3}", self.avg));
        out
    }
}

pub const TABLE_HEADERS: [&str; 9] =
    ["Method", "PPL", "PQ", "HS", "A-e", "A-c", "WG", "LA", "Avg."];

/// Evaluation budget (trade evaluation time for statistical noise).
#[derive(Clone, Copy, Debug)]
pub struct EvalBudget {
    pub ppl_seqs: usize,
    pub task_items: usize,
}

impl EvalBudget {
    pub fn full() -> Self {
        EvalBudget { ppl_seqs: 48, task_items: 96 }
    }
    pub fn fast() -> Self {
        EvalBudget { ppl_seqs: 8, task_items: 16 }
    }
    /// fast when `--fast` was passed OR `LRC_BENCH_FAST=1` is set
    /// (`make bench` sets it so the full suite fits a CI budget).
    pub fn from_args(args: &crate::util::Args) -> Self {
        if args.has("fast") || std::env::var("LRC_BENCH_FAST").ok().as_deref() == Some("1") {
            Self::fast()
        } else {
            Self::full()
        }
    }
}

/// Model list: `--models` flag, else `LRC_BENCH_MODELS`, else the default.
pub fn models_from_args(args: &crate::util::Args, default: &str) -> String {
    if let Some(m) = args.get("models") {
        return m.to_string();
    }
    std::env::var("LRC_BENCH_MODELS").unwrap_or_else(|_| default.to_string())
}

/// Evaluate one graph (+optional quant bundle): PPL + all tasks.
pub fn evaluate_graph(engine: &Engine, arts: &ModelArtifacts,
                      graph: &str, quant: Option<&TensorBundle>,
                      corpus: &Corpus, tasks: &[Task], budget: EvalBudget,
                      label: &str) -> Result<VariantScores> {
    let session = engine.session(arts, graph, quant)?;
    let mut provider = SessionProvider { session };
    let ppl = perplexity(&mut provider, corpus, budget.ppl_seqs)
        .map_err(anyhow::Error::msg)?;
    let (task_scores, avg) = all_task_accuracies(&mut provider, tasks)
        .map_err(anyhow::Error::msg)?;
    Ok(VariantScores { label: label.into(), ppl, tasks: task_scores, avg })
}

/// Load the task suites truncated to the budget.
pub fn load_tasks(artifacts: &Path, budget: EvalBudget) -> Result<Vec<Task>> {
    Task::load_all(&artifacts.join("tasks"), Some(budget.task_items))
        .map_err(anyhow::Error::msg)
}

/// Quantize with `method` against `graph` and evaluate — one table row.
#[allow(clippy::too_many_arguments)]
pub fn quantize_and_evaluate(engine: &Engine, arts: &ModelArtifacts,
                             corpus: &Corpus, tasks: &[Task], graph: &str,
                             method: Method, cfg: &QuantConfig,
                             n_calib: usize, budget: EvalBudget)
                             -> Result<(VariantScores, PipelineReport)> {
    let (bundle, report) =
        quantize_and_save(engine, arts, corpus, graph, method, cfg, n_calib)?;
    let scores = evaluate_graph(engine, arts, graph, Some(&bundle), corpus,
                                tasks, budget, &method.label(cfg))?;
    Ok((scores, report))
}

/// Graph name helper matching aot.py's naming.
pub fn quant_graph_name(pct: usize, group: Option<usize>, weight_only: bool,
                        batch: usize) -> String {
    if weight_only {
        format!("fwd_w4_r{pct}_b{batch}")
    } else {
        match group {
            Some(g) => format!("fwd_w4a4_r{pct}_g{g}_b{batch}"),
            None => format!("fwd_w4a4_r{pct}_b{batch}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn graph_names() {
        assert_eq!(quant_graph_name(10, None, false, 8), "fwd_w4a4_r10_b8");
        assert_eq!(quant_graph_name(0, Some(32), false, 8),
                   "fwd_w4a4_r0_g32_b8");
        assert_eq!(quant_graph_name(10, None, true, 8), "fwd_w4_r10_b8");
    }

    #[test]
    fn cells_shape() {
        let v = VariantScores {
            label: "LRC (1)".into(),
            ppl: 7.26,
            tasks: vec![("pq".into(), 0.786); 6],
            avg: 0.697,
        };
        let c = v.cells();
        assert_eq!(c.len(), TABLE_HEADERS.len());
        assert_eq!(c[0], "LRC (1)");
        assert_eq!(c[1], "7.26");
        assert_eq!(c[8], "0.697");
    }
}
