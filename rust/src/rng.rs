//! Deterministic RNG (SplitMix64) — no external `rand` in the offline image.
//!
//! Every stochastic choice in the crate (workload generation, property
//! tests, serving traffic) flows through this so runs are reproducible from
//! a single seed.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork a child RNG (stable: derived from the current stream).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let xs = r.normal_vec(20_000);
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
