//! # LRC — Low-Rank Correction for Quantized LLMs
//!
//! A full-system reproduction of *"Low-Rank Correction for Quantized LLMs"*
//! (Scetbon & Hensman, 2024) as a three-layer Rust + JAX + Pallas stack.
//! This crate is layer 3: the self-contained production binary that
//! quantizes, evaluates and serves W4A4 models whose compute graphs were
//! AOT-lowered from JAX (layer 2) and whose hot loop is a fused Pallas
//! kernel (layer 1), executed through the PJRT C API.
//!
//! Module map:
//!
//! * [`par`]         — zero-dependency **persistent** worker pool (parked
//!                     std threads on a Mutex/Condvar job board, epoch
//!                     generations, `Pool::scoped()` spawn-per-call escape
//!                     hatch; `LRC_THREADS` / `--threads` sizing) with a
//!                     fixed-order reduction contract: results are
//!                     bit-identical at every thread count
//! * [`linalg`]      — dense f64 linear algebra built from scratch
//!                     (blocked-k / register-tiled GEMM micro-kernels with
//!                     a canonical per-element accumulation order — serial,
//!                     blocked, parallel AND every SIMD backend agree
//!                     bit-for-bit, see `tests/kernel_oracle.rs`; the
//!                     `linalg::simd` layer dispatches SSE2/AVX2/NEON
//!                     lane kernels at runtime — f64 AND double-width
//!                     **f32 lanes** under the same contract —
//!                     `LRC_SIMD` / `--simd` pins one, and the opt-in
//!                     `--fma` / `LRC_FMA` mode swaps in fused
//!                     multiply-add kernels with their own lockstep
//!                     oracle reference; `linalg::workspace` provides
//!                     the per-thread grow-only scratch arenas (f64 and
//!                     f32) — packed A/B panels, solver temporaries and
//!                     Σ scratch are recycled so steady-state hot loops
//!                     are allocation-free
//!                     (`tests/alloc_steady_state.rs`); Cholesky, Jacobi
//!                     eigensolver, FWHT; `par_*` and `*_into` variants
//!                     plus automatic parallelism past a fixed work
//!                     threshold)
//! * [`rng`]         — deterministic SplitMix64 RNG
//! * [`quant`]       — RTN / GPTQ quantizers + 2..=8-bit packing; the
//!                     `quant::dequant` **fused dequant-GEMM** serving
//!                     kernel: `QuantizedLinear` consumes `PackedInts`
//!                     directly (codes × scales decoded tile-by-tile
//!                     into the blocked-k microkernel, never
//!                     materializing the f32 weight matrix) with the
//!                     low-rank correction `U·(Vᵀx)` fused into the same
//!                     pass, bit-identical to the naive unpack reference
//!                     on every backend × thread count
//! * [`lrc`]         — the paper's Algorithms 1–4 + SVD baseline + oracle
//! * [`data`]        — byte tokenizer, corpora, lm-eval-style task suites
//! * [`eval`]        — perplexity + multiple-choice accuracy scoring
//! * [`runtime`]     — PJRT engine: HLO-text artifacts → executables;
//!                     plus the engine-free `NativeModel` /
//!                     `NativeProvider` serving path (`--native`): the
//!                     rotated forward on the crate's own kernels with
//!                     quantized layers on the fused dequant-GEMM
//! * [`pipeline`]    — end-to-end PTQ driver (calibrate → quantize →
//!                     bundle); the per-layer loop fans out on [`par`];
//!                     split entry points let calibration be collected
//!                     once and reused across many quantization runs
//! * [`registry`]    — content-addressed artifact store: every quant
//!                     bundle / sweep cell is keyed by
//!                     sha256(model, method, QuantConfig, seed,
//!                     calibration identity, code version) — hand-rolled
//!                     SHA-256, canonical-JSON key material, atomic
//!                     temp-file + rename publish, corruption-checked
//!                     reads (a torn object is a counted miss, never a
//!                     wrong answer), pluggable `RegistryBackend`;
//!                     `registry::proto` + `registry::service` add the
//!                     length-prefixed line protocol and the
//!                     single-threaded non-blocking dispatcher / worker
//!                     loops behind `lrc sweep --serve` /
//!                     `lrc sweep-worker` — `lrc-sweep-worker-v2`:
//!                     worker reconnect with run-identity re-validation,
//!                     `failed` frames, claim leases, poison-cell
//!                     quarantine (spec: `docs/REGISTRY.md`);
//!                     `registry::faults` is the seeded deterministic
//!                     fault-injection layer (wire shims + torn-write
//!                     backend) behind `lrc chaos`
//! * [`sweep`]       — declarative method × w_bits × rank_pct × group
//!                     grid driver: shared calibration across cells,
//!                     canonical fold order (byte-identical reports at
//!                     any thread count), resume through the
//!                     content-addressed [`registry`] (legacy fragment
//!                     dirs migrate in on first read), distributed
//!                     claim/compute/publish workers whose merged report
//!                     is byte-identical to a single-box run, built-in
//!                     sanity assertions; runs on real artifacts or an
//!                     engine-free synthetic model
//! * [`chaos`]       — `lrc chaos`: deterministic fault-injection
//!                     harness for the distributed sweep — in-process
//!                     fleets run under a seeded `FaultPlan`; merged
//!                     reports must be byte-identical to the fault-free
//!                     single-box run, poison-cell quarantine identical
//!                     at every worker count, torn registries resume as
//!                     counted misses
//! * [`coordinator`] — serving engine: bounded admission queue with
//!                     typed backpressure (`PushError::Full`),
//!                     deadline-aware load shedding (every request gets
//!                     exactly one `Outcome` — scored, shed or failed;
//!                     response channels are never silently dropped),
//!                     **continuous batching** (hot workers refill the
//!                     in-flight batch via `poll_batch` instead of
//!                     re-arming the max-wait barrier), N engine
//!                     workers, per-worker metrics with honest
//!                     queue/exec/score phase attribution; falls back
//!                     to the native fused path when no PJRT plugin
//!                     loads.  `coordinator::soak` is the synthetic
//!                     traffic harness (`lrc soak`): seeded Poisson /
//!                     burst / adversarial-deadline trace, a
//!                     byte-deterministic virtual-time simulation, and
//!                     a wall-clock replay against the real batcher
//! * [`bench`]       — measurement harness used by `cargo bench` targets
//!                     + the `bench-trend` regression comparison the CI
//!                     gate runs over bench JSON artifacts
//! * [`analyze`]     — in-repo correctness tooling (`lrc analyze`): a
//!                     zero-dependency source lint that mechanically
//!                     enforces the crate's standing contracts —
//!                     `// SAFETY:` comments on every `unsafe`,
//!                     concurrency/wall-clock/`mul_add` API fences, and
//!                     the module-layering map; deny-by-default in CI.
//!                     Its runtime siblings: the `checked` cargo feature
//!                     arms `SharedSlice` with an overlap/bounds race
//!                     detector and the pool with protocol assertions,
//!                     and `par::model` + `tests/pool_model.rs`
//!                     exhaustively model-check the job-board protocol
//! * [`util`]        — no-deps JSON + CLI parsing

// Every `unsafe` operation must sit in an explicit `unsafe {}` block with
// its own justification, even inside `unsafe fn` — `lrc analyze` then
// checks every such block carries a `// SAFETY:` argument.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod analyze;
pub mod bench;
pub mod chaos;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod linalg;
pub mod lrc;
pub mod par;
pub mod pipeline;
pub mod quant;
pub mod registry;
pub mod rng;
pub mod runtime;
pub mod sweep;
pub mod util;

/// Repo-relative artifacts directory (respects `LRC_ARTIFACTS` env var).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("LRC_ARTIFACTS") {
        return p.into();
    }
    // walk up from cwd until we find artifacts/ (works from target/ too)
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}
