//! The paper's contribution, natively: Algorithms 1–4 + the SVD baseline
//! and the Prop.-3.4 perfect-quantizer oracle.
//!
//! All math in f64 on [`crate::linalg::Mat`]; mirrors
//! `python/compile/lrc.py` (the two are cross-checked by an objective-value
//! golden test — exact matrices may differ by fp association, the achieved
//! ℒ_qlr must not).

pub mod stats;
pub mod svd;

pub use stats::LayerStats;

use crate::linalg::{cholesky, chol_solve_mat, solve_lower, solve_upper,
                    top_k_eigvecs, Mat};
use crate::quant::{gptq::gptq, rtn_quantize, QuantConfig, Quantizer};

/// Deterministic synthetic layer problems — the correlated,
/// outlier-bearing regime W4A4 struggles in and the paper targets.
/// Shared by the unit tests, the integration suites
/// (`tests/quant_roundtrip.rs`), the bench targets and the quickstart
/// example, so they all exercise the same distribution.
pub struct TestModel;

impl TestModel {
    /// (W [dout, din], X [din, n]): W gaussian, X low-rank-correlated
    /// (rank din/4 mixer) plus small isotropic noise, with every 16th
    /// input channel scaled 8× (the outliers QuaRot rotates away).
    pub fn layer_problem(seed: u64, dout: usize, din: usize, n: usize)
                         -> (Mat, Mat) {
        let mut rng = crate::rng::Rng::new(seed);
        let w = Mat::random_normal(&mut rng, dout, din);
        let base = Mat::random_normal(&mut rng, din / 4, n);
        let mixer = Mat::random_normal(&mut rng, din, din / 4);
        let mut x = mixer.matmul(&base)
            .add(&Mat::random_normal(&mut rng, din, n).scale(0.1));
        for i in (0..din).step_by(16) {
            for j in 0..n {
                x[(i, j)] *= 8.0; // outlier channels
            }
        }
        (w, x)
    }

    /// [`LayerStats`] accumulated over X in two half-batches (4-bit Q_a,
    /// the given clip) — the standard Σ setup the tests share.
    pub fn stats(x: &Mat, clip: f64) -> LayerStats {
        let mut st = LayerStats::new(x.rows, Some(4), clip, None);
        let n = x.cols;
        let half = n / 2;
        st.update(&x.cols_range(0, half));
        st.update(&x.cols_range(half, n));
        st
    }
}

/// Result of quantizing one layer.
#[derive(Clone, Debug)]
pub struct LayerResult {
    /// dequantized quantized weights Ŵ (on the int4 grid)
    pub w_hat: Mat,
    /// low-rank correction U [dout, k] (empty when rank 0)
    pub u: Option<Mat>,
    /// low-rank correction V [din, k]
    pub v: Option<Mat>,
    /// final ℒ_qlr value
    pub objective: f64,
    /// ℒ_qlr after every half step (UQ, ULR, UQ, ULR, ...)
    pub history: Vec<f64>,
}

/// Algorithm 4 / Prop. 3.4 — closed-form init:
/// Σinit = W·Σx·Wᵀ − SᵀS with S = Ly⁻¹·Σxyᵀ·Wᵀ;  U = eig_k, V = Wᵀ·U.
pub fn init_lr(w: &Mat, sx: &Mat, sy: &Mat, sxy: &Mat, k: usize)
               -> Result<(Mat, Mat), String> {
    let sigma1 = w.matmul(sx).matmul_nt(w);
    let ly = cholesky(sy)?;
    let s = solve_lower(&ly, &sxy.transpose().matmul_nt(w));
    let sigma2 = s.gram_t();
    let u = top_k_eigvecs(&sigma1.sub(&sigma2), k);
    let v = w.transpose().matmul(&u);
    Ok((u, v))
}

/// Algorithm 2 / Prop. 3.1 — W̃ = (W − U·Vᵀ)·Σxy·Σy⁻¹ (via Cholesky,
/// Remark B.1), then solve the layer-wise problem against Hessian Σy.
pub fn update_quant(w: &Mat, u: &Mat, v: &Mat, sy: &Mat, sxy: &Mat,
                    cfg: &QuantConfig) -> Result<Mat, String> {
    let r = w.sub(&u.matmul_nt(v));
    let rhs = r.matmul(sxy);
    // W̃ᵀ = Σy⁻¹ · rhsᵀ
    let ly = cholesky(sy)?;
    let wt = chol_solve_mat(&ly, &rhs.transpose()).transpose();
    match cfg.quantizer {
        Quantizer::Gptq => gptq(&wt, sy, cfg.w_bits, None, 0.01, 64),
        Quantizer::Rtn => Ok(rtn_quantize(&wt, cfg.w_bits, None)),
    }
}

/// Algorithm 3 / Prop. 3.3 — closed-form (U, V) update given Ŵ:
/// Σ = W·Σx·Wᵀ + SᵀS − (Ŵ·Σxyᵀ·Wᵀ + W·Σxy·Ŵᵀ), S = Lx⁻¹·Σxy·Ŵᵀ;
/// U = eig_k(Σ), V = [Wᵀ − Σx⁻¹·Σxy·Ŵᵀ]·U.
pub fn update_lr(w: &Mat, w_hat: &Mat, sx: &Mat, sxy: &Mat, k: usize)
                 -> Result<(Mat, Mat), String> {
    let sigma1 = w.matmul(sx).matmul_nt(w);
    let a = w_hat.matmul(&sxy.transpose()).matmul_nt(w); // Ŵ·Σxyᵀ·Wᵀ
    let sigma3 = a.add(&a.transpose());
    let lx = cholesky(sx)?;
    let s = solve_lower(&lx, &sxy.matmul_nt(w_hat)); // Lx⁻¹·Σxy·Ŵᵀ
    let sigma2 = s.gram_t();
    let sigma = sigma1.add(&sigma2).sub(&sigma3);
    let u = top_k_eigvecs(&sigma, k);
    let tmp = solve_upper(&lx, &s); // Σx⁻¹·Σxy·Ŵᵀ
    let v = w.transpose().sub(&tmp).matmul(&u);
    Ok((u, v))
}

/// Prop. 3.4's unconstrained W̃ — the perfect-quantizer oracle bound.
pub fn oracle_wtilde(w: &Mat, u: &Mat, v: &Mat, sy: &Mat, sxy: &Mat)
                     -> Result<Mat, String> {
    let r = w.sub(&u.matmul_nt(v));
    let rhs = r.matmul(sxy);
    let ly = cholesky(sy)?;
    Ok(chol_solve_mat(&ly, &rhs.transpose()).transpose())
}

/// ℒ_qlr(Ŵ,U,V) = ‖WX − ŴY − UVᵀX‖² expanded through the *raw*
/// (unregularized) Σ matrices:
/// with R = W − UVᵀ:  tr(R·Σx·Rᵀ) − 2·tr(R·Σxy·Ŵᵀ) + tr(Ŵ·Σy·Ŵᵀ).
pub fn qlr_objective(w: &Mat, w_hat: &Mat, u: &Mat, v: &Mat,
                     st: &LayerStats) -> f64 {
    let r = w.sub(&u.matmul_nt(v));
    let t1 = r.matmul(&st.sx).frob_dot(&r);
    let t2 = r.matmul(&st.sxy).frob_dot(w_hat);
    let t3 = w_hat.matmul(&st.sy).frob_dot(w_hat);
    t1 - 2.0 * t2 + t3
}

/// Algorithm 1 — the full LRC driver for one layer.
/// `k = 0` degrades exactly to QuaRot-style quantization (no correction).
pub fn lrc(w: &Mat, st: &LayerStats, k: usize, cfg: &QuantConfig)
           -> Result<LayerResult, String> {
    // Σxy is borrowed from the accumulator; the regularized Σx/Σy copies
    // live in workspace-recycled storage returned below, so repeated
    // per-layer solves reuse the same scratch
    let (sx, sy, sxy) = st.regularized();
    let recycle = |sx: Mat, sy: Mat| {
        crate::linalg::workspace::recycle_mat(sx);
        crate::linalg::workspace::recycle_mat(sy);
    };
    let zero_u = Mat::zeros(w.rows, 1);
    let zero_v = Mat::zeros(w.cols, 1);
    if k == 0 {
        let w_hat = update_quant(w, &zero_u, &zero_v, &sy, sxy, cfg)?;
        recycle(sx, sy);
        let obj = qlr_objective(w, &w_hat, &zero_u, &zero_v, st);
        return Ok(LayerResult {
            w_hat, u: None, v: None, objective: obj, history: vec![obj],
        });
    }
    let (mut u, mut v) = init_lr(w, &sx, &sy, sxy, k)?;
    let mut w_hat = Mat::zeros(w.rows, w.cols);
    let mut history = Vec::new();
    for _ in 0..cfg.iters.max(1) {
        w_hat = update_quant(w, &u, &v, &sy, sxy, cfg)?;
        history.push(qlr_objective(w, &w_hat, &u, &v, st));
        let (nu, nv) = update_lr(w, &w_hat, &sx, sxy, k)?;
        u = nu;
        v = nv;
        history.push(qlr_objective(w, &w_hat, &u, &v, st));
    }
    recycle(sx, sy);
    Ok(LayerResult {
        objective: *history.last().unwrap(),
        w_hat, u: Some(u), v: Some(v), history,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::act_quantize;
    use crate::rng::Rng;

    fn layer_problem(seed: u64, dout: usize, din: usize, n: usize)
                     -> (Mat, Mat) {
        TestModel::layer_problem(seed, dout, din, n)
    }

    fn stats_for(x: &Mat, clip: f64) -> LayerStats {
        TestModel::stats(x, clip)
    }

    #[test]
    fn objective_matches_direct_residual() {
        let (w, x) = layer_problem(0, 12, 16, 512);
        let st = stats_for(&x, 0.9);
        let cfg = QuantConfig { iters: 1, ..Default::default() };
        let res = lrc(&w, &st, 4, &cfg).unwrap();
        let y = act_quantize(&x, 4, 0.9, None);
        let direct = w.matmul(&x)
            .sub(&res.w_hat.matmul(&y))
            .sub(&res.u.as_ref().unwrap()
                 .matmul_nt(res.v.as_ref().unwrap()).matmul(&x))
            .frob_norm()
            .powi(2);
        let rel = (direct - res.objective).abs() / direct;
        assert!(rel < 1e-8, "direct {direct} vs obj {}", res.objective);
    }

    #[test]
    fn lrc_beats_quarot_and_svd() {
        // the paper's headline ordering at the layer level
        for seed in [1, 2] {
            let (w, x) = layer_problem(seed, 24, 32, 1024);
            let st = stats_for(&x, 0.9);
            let cfg = QuantConfig::default();
            let k = 6;
            let quarot = lrc(&w, &st, 0, &cfg).unwrap();
            let svd = svd::svd_baseline(&w, &st, k, &cfg).unwrap();
            let ours = lrc(&w, &st, k, &cfg).unwrap();
            assert!(ours.objective < quarot.objective,
                    "seed {seed}: lrc {} quarot {}", ours.objective,
                    quarot.objective);
            assert!(ours.objective < svd.objective,
                    "seed {seed}: lrc {} svd {}", ours.objective,
                    svd.objective);
        }
    }

    #[test]
    fn update_lr_never_increases_objective() {
        // Update-LR is exact (Prop. 3.3): each ULR half-step must not
        // increase ℒ_qlr (GPTQ half-steps are approximate and may).
        let (w, x) = layer_problem(3, 16, 16, 512);
        let st = stats_for(&x, 0.9);
        let cfg = QuantConfig { iters: 4, ..Default::default() };
        let res = lrc(&w, &st, 4, &cfg).unwrap();
        // Update-LR minimizes the ε-regularized objective (numerical
        // stability, §3.2), so the *raw* objective may drift by O(ε)=1e-2
        // relative — allow that slack, reject anything larger.
        for step in res.history.chunks(2) {
            if step.len() == 2 {
                assert!(step[1] <= step[0] * 1.005,
                        "ULR increased: {} -> {}", step[0], step[1]);
            }
        }
    }

    #[test]
    fn oracle_bounds_update_quant() {
        // unconstrained W̃ (perfect quantizer) ≤ any quantized Ŵ, same U,V
        let (w, x) = layer_problem(4, 12, 16, 512);
        let st = stats_for(&x, 0.9);
        let (sx, sy, sxy) = st.regularized();
        let (u, v) = init_lr(&w, &sx, &sy, sxy, 4).unwrap();
        let cfg = QuantConfig::default();
        let w_hat = update_quant(&w, &u, &v, &sy, sxy, &cfg).unwrap();
        let wt = oracle_wtilde(&w, &u, &v, &sy, sxy).unwrap();
        let obj_q = qlr_objective(&w, &w_hat, &u, &v, &st);
        let obj_o = qlr_objective(&w, &wt, &u, &v, &st);
        assert!(obj_o <= obj_q, "oracle {obj_o} > quantized {obj_q}");
    }

    #[test]
    fn update_lr_is_argmin_over_perturbations() {
        // Prop. 3.3 optimality: the closed-form (U,V) beats perturbed pairs
        let (w, x) = layer_problem(5, 10, 16, 512);
        let st = stats_for(&x, 0.9);
        let (sx, sy, sxy) = st.regularized();
        let cfg = QuantConfig::default();
        let (u0, v0) = init_lr(&w, &sx, &sy, sxy, 3).unwrap();
        let w_hat = update_quant(&w, &u0, &v0, &sy, sxy, &cfg).unwrap();
        let (u, v) = update_lr(&w, &w_hat, &sx, sxy, 3).unwrap();
        let best = qlr_objective(&w, &w_hat, &u, &v, &st);
        let mut rng = Rng::new(77);
        for _ in 0..8 {
            let du = Mat::random_normal(&mut rng, u.rows, u.cols).scale(0.05);
            let dv = Mat::random_normal(&mut rng, v.rows, v.cols).scale(0.05);
            let obj = qlr_objective(&w, &w_hat, &u.add(&du), &v.add(&dv), &st);
            assert!(best <= obj + 1e-9, "perturbation beat closed form");
        }
    }

    #[test]
    fn higher_rank_never_worse() {
        let (w, x) = layer_problem(6, 16, 16, 512);
        let st = stats_for(&x, 0.9);
        let cfg = QuantConfig::default();
        let o2 = lrc(&w, &st, 2, &cfg).unwrap().objective;
        let o6 = lrc(&w, &st, 6, &cfg).unwrap().objective;
        // not a theorem under approximate GPTQ, but holds robustly here
        assert!(o6 <= o2 * 1.05, "rank 6 {o6} vs rank 2 {o2}");
    }

    #[test]
    fn weight_only_mode_near_lossless() {
        // Table 3 regime: Qa = identity → quantization error is tiny and
        // the low-rank term adds nearly nothing (paper's point)
        let (w, x) = layer_problem(7, 16, 16, 512);
        let mut st = LayerStats::new(16, None, 1.0, None);
        st.update(&x);
        let cfg = QuantConfig { a_bits: None, ..Default::default() };
        let r0 = lrc(&w, &st, 0, &cfg).unwrap();
        let r4 = lrc(&w, &st, 4, &cfg).unwrap();
        let wx = w.matmul(&x).frob_norm().powi(2);
        assert!(r0.objective / wx < 0.01, "w4-only err too big");
        // low-rank improvement exists but is a small fraction of fp norm
        assert!(r4.objective <= r0.objective);
    }
}
