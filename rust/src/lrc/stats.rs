//! Online Σ accumulation (Algorithm 1, lines 3–5).
//!
//! The paper: "we accumulate batches of activations X to avoid running out
//! of memory, and update Σx, Σy, Σxy in an online fashion" — and "we found
//! that computation of these matrices required 64-bit precision".  X holds
//! tokens as *columns* ([din, n]), matching the paper's notation.

use crate::linalg::{workspace, Mat};
use crate::par::Pool;
use crate::quant::act_quantize_into;

/// Fixed token-chunk width for parallel Σ accumulation.  Chunk boundaries
/// are a property of the *math*, not of the pool: partial Grams are
/// computed per chunk (concurrently) and merged in chunk order, so the
/// accumulated Σ are bit-identical at every thread count.  The per-chunk
/// Grams run on the blocked kernels of [`crate::linalg::kernels`] — and
/// therefore on whatever [`crate::linalg::simd`] backend is active, which
/// by the lane-wise mul-then-add contract cannot change a single bit of
/// Σx/Σy/Σxy — whose own nested parallelism suppresses itself inside pool
/// jobs; on a persistent pool these fine-grained chunk updates are cheap
/// enough to dispatch even for small batches.
pub const STATS_TOKEN_CHUNK: usize = 256;

/// Square tile edge for the blocked f32→f64 activation transpose: 64
/// output rows × 64 input columns is ≤ 32 KB of f64 destination + 16 KB
/// of f32 source — both sides of a tile stay L1-resident.
const TRANSPOSE_TILE: usize = 64;

/// acc += p elementwise in ascending index order — the merge step of
/// [`LayerStats::update_par`], same program as [`Mat::add_assign`] ran
/// on the old per-chunk partial matrices (bit for bit).
fn add_slice(acc: &mut [f64], p: &[f64]) {
    debug_assert_eq!(acc.len(), p.len());
    for (a, &v) in acc.iter_mut().zip(p) {
        *a += v;
    }
}

/// Accumulates Σx = XXᵀ, Σy = YYᵀ, Σxy = XYᵀ over calibration batches,
/// where Y = Q_a(X) (or Y = X in weight-only mode).
#[derive(Clone, Debug)]
pub struct LayerStats {
    pub din: usize,
    /// activation bits; `None` = weight-only (Q_a = identity, Table 3)
    pub a_bits: Option<u32>,
    pub clip: f64,
    pub a_group: Option<usize>,
    pub sx: Mat,
    pub sy: Mat,
    pub sxy: Mat,
    pub n: usize,
}

impl LayerStats {
    pub fn new(din: usize, a_bits: Option<u32>, clip: f64,
               a_group: Option<usize>) -> Self {
        LayerStats {
            din,
            a_bits,
            clip,
            a_group,
            sx: Mat::zeros(din, din),
            sy: Mat::zeros(din, din),
            sxy: Mat::zeros(din, din),
            n: 0,
        }
    }

    /// Fold in one batch of activation columns X [din, b].  The partial
    /// Grams land in one workspace-recycled temporary, the Q_a output
    /// lands in another ([`act_quantize_into`]), and both accumulate
    /// into Σ in place — the steady-state calibration loop is fully
    /// **allocation-free** (`tests/alloc_steady_state.rs` asserts 0).
    /// In weight-only mode (Q_a = identity) Σx = Σy = Σxy element for
    /// element — `gram_n` and `matmul_nt(x, x)` run the same canonical
    /// ascending-k program — so the Gram is computed **once** and folded
    /// three ways (the old path cloned X and computed it three times).
    pub fn update(&mut self, x: &Mat) {
        assert_eq!(x.rows, self.din);
        let mut tmp = workspace::take_mat_for(self.din, self.din);
        match self.a_bits {
            Some(bits) => {
                let mut y = workspace::take_mat_for(x.rows, x.cols);
                act_quantize_into(x, bits, self.clip, self.a_group, &mut y);
                x.gram_n_into(&mut tmp);
                self.sx.add_assign(&tmp);
                y.gram_n_into(&mut tmp);
                self.sy.add_assign(&tmp);
                x.matmul_nt_into(&y, &mut tmp);
                self.sxy.add_assign(&tmp);
                workspace::recycle_mat(y);
            }
            None => {
                x.gram_n_into(&mut tmp);
                self.sx.add_assign(&tmp);
                self.sy.add_assign(&tmp);
                self.sxy.add_assign(&tmp);
            }
        }
        workspace::recycle_mat(tmp);
        self.n += x.cols;
    }

    /// Fold in one batch of activation columns X [din, b], accumulating
    /// per-thread partial Σ over fixed [`STATS_TOKEN_CHUNK`] token chunks
    /// and merging them in chunk order.  Bit-identical at every pool
    /// size (the serial [`LayerStats::update`] differs only by Gram
    /// association across chunk boundaries, within fp round-off).
    ///
    /// Dispatch is **slot-free**: chunks go through
    /// [`Pool::for_indices`] and each writes its partial block
    /// `[Σx | Σy | Σxy]` (just `[Σx]` in weight-only mode, where all
    /// three Σ share the same bits) into a disjoint range of one
    /// arena-recycled buffer, so the fan-out performs no per-chunk
    /// slot/result allocation — the old [`Pool::map`] path boxed three
    /// fresh Grams per chunk.  Chunk-local scratch (the column slice,
    /// the Q_a output and the Gram temporary) comes from (and returns
    /// to) the executing worker's own arena — persistent workers reuse
    /// it across chunks, epochs and the whole per-layer fan-out.
    pub fn update_par(&mut self, x: &Mat, pool: &Pool) {
        assert_eq!(x.rows, self.din);
        let n = x.cols;
        let d2 = self.din * self.din;
        let n_chunks = n.div_ceil(STATS_TOKEN_CHUNK).max(1);
        let (a_bits, clip, a_group) = (self.a_bits, self.clip, self.a_group);
        let per = if a_bits.is_some() { 3 * d2 } else { d2 };
        let mut buf = workspace::take_zeroed(n_chunks * per);
        {
            let shared = workspace::SharedSlice::new(&mut buf[..]);
            pool.for_indices(n_chunks, |ci| {
                let c0 = ci * STATS_TOKEN_CHUNK;
                let c1 = (c0 + STATS_TOKEN_CHUNK).min(n);
                // SAFETY: per-chunk blocks partition the buffer
                let out = unsafe { shared.range(ci * per, (ci + 1) * per) };
                let mut xs = workspace::take_mat_for(x.rows, c1 - c0);
                x.cols_range_into(c0, c1, &mut xs);
                let mut g = workspace::take_mat_for(x.rows, x.rows);
                xs.gram_n_into(&mut g);
                out[..d2].copy_from_slice(&g.data);
                if let Some(bits) = a_bits {
                    // Q_a is per-token, so quantizing a chunk equals
                    // quantizing the full batch and slicing
                    let mut ys = workspace::take_mat_for(xs.rows, xs.cols);
                    act_quantize_into(&xs, bits, clip, a_group, &mut ys);
                    ys.gram_n_into(&mut g);
                    out[d2..2 * d2].copy_from_slice(&g.data);
                    xs.matmul_nt_into(&ys, &mut g);
                    out[2 * d2..].copy_from_slice(&g.data);
                    workspace::recycle_mat(ys);
                }
                workspace::recycle_mat(g);
                workspace::recycle_mat(xs);
            });
        }
        // merge in ascending chunk order: chunk boundaries are a
        // property of the math, so Σ is invariant to which worker ran
        // which chunk
        for ci in 0..n_chunks {
            let p = &buf[ci * per..(ci + 1) * per];
            add_slice(&mut self.sx.data, &p[..d2]);
            if a_bits.is_some() {
                add_slice(&mut self.sy.data, &p[d2..2 * d2]);
                add_slice(&mut self.sxy.data, &p[2 * d2..]);
            } else {
                add_slice(&mut self.sy.data, &p[..d2]);
                add_slice(&mut self.sxy.data, &p[..d2]);
            }
        }
        workspace::put(buf);
        self.n += n;
    }

    /// Fold in a batch given in *row-major token rows* ([b, din] f32),
    /// the layout the PJRT acts graph produces.  The transposed f64
    /// batch lives in a workspace-recycled matrix, so the per-batch
    /// calibration loop reuses one transpose buffer.
    pub fn update_rows_f32(&mut self, rows: &[f32], n_rows: usize) {
        assert_eq!(rows.len(), n_rows * self.din);
        let x = Self::transpose_rows_f32(rows, n_rows, self.din);
        self.update(&x);
        workspace::recycle_mat(x);
    }

    /// [`LayerStats::update_rows_f32`] on a pool: transpose once, then
    /// accumulate the partial Grams concurrently via [`LayerStats::update_par`].
    pub fn update_rows_f32_par(&mut self, rows: &[f32], n_rows: usize,
                               pool: &Pool) {
        assert_eq!(rows.len(), n_rows * self.din);
        let x = Self::transpose_rows_f32(rows, n_rows, self.din);
        self.update_par(&x, pool);
        workspace::recycle_mat(x);
    }

    /// Transpose row-major f32 token rows into column-token f64 X
    /// (workspace-backed; callers recycle).  The walk is cache-blocked:
    /// [`TRANSPOSE_TILE`]² tiles keep both streams resident in L1, the
    /// inner copy reads the f32 source contiguously — a straight widen
    /// the compiler keeps in vector lanes, loading at the f32 data
    /// path's 2× lane width — and the strided f64 writes stay inside
    /// the tile's working set.  The naive column-major walk this
    /// replaces touched `n_rows` distinct cache lines per output row.
    fn transpose_rows_f32(rows: &[f32], n_rows: usize, din: usize) -> Mat {
        let mut x = workspace::take_mat(din, n_rows);
        for r0 in (0..n_rows).step_by(TRANSPOSE_TILE) {
            let r1 = (r0 + TRANSPOSE_TILE).min(n_rows);
            for c0 in (0..din).step_by(TRANSPOSE_TILE) {
                let c1 = (c0 + TRANSPOSE_TILE).min(din);
                for r in r0..r1 {
                    let src = &rows[r * din + c0..r * din + c1];
                    for (dc, &v) in src.iter().enumerate() {
                        x[(c0 + dc, r)] = v as f64;
                    }
                }
            }
        }
        x
    }

    /// (Σx + εx·I, Σy + εy·I, Σxy) with ε = 1e-2·tr(Σ)/d, as in the
    /// paper.  Finalization is copy-minimal: Σxy — which the ε shift
    /// never touches — is **borrowed** straight from the accumulator
    /// (it used to be cloned per solve), and the two shifted copies land
    /// in workspace-recycled storage (pass them back via
    /// [`crate::linalg::workspace::recycle_mat`] when done, as
    /// [`crate::lrc::lrc`] does).  To finalize with no copies at all,
    /// use [`LayerStats::into_regularized`].
    pub fn regularized(&self) -> (Mat, Mat, &Mat) {
        let d = self.din as f64;
        let mut sx = workspace::take_mat_copy(&self.sx);
        sx.add_diag(1e-2 * self.sx.trace() / d);
        let mut sy = workspace::take_mat_copy(&self.sy);
        sy.add_diag(1e-2 * self.sy.trace() / d);
        (sx, sy, &self.sxy)
    }

    /// [`LayerStats::regularized`] consuming the accumulator: the ε
    /// shift is applied to Σx/Σy **in place** and all three matrices
    /// move out — zero copies, for callers done accumulating.
    pub fn into_regularized(self) -> (Mat, Mat, Mat) {
        let d = self.din as f64;
        let LayerStats { mut sx, mut sy, sxy, .. } = self;
        let tx = sx.trace();
        sx.add_diag(1e-2 * tx / d);
        let ty = sy.trace();
        sy.add_diag(1e-2 * ty / d);
        (sx, sy, sxy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn online_equals_batch() {
        // property: accumulating in chunks == one shot
        let x = Mat::random_normal(&mut Rng::new(1), 8, 200);
        let mut st_once = LayerStats::new(8, Some(4), 0.9, None);
        st_once.update(&x);
        let mut st_chunks = LayerStats::new(8, Some(4), 0.9, None);
        for c in (0..200).step_by(37) {
            st_chunks.update(&x.cols_range(c, (c + 37).min(200)));
        }
        assert!(st_once.sx.sub(&st_chunks.sx).max_abs() < 1e-8);
        assert!(st_once.sy.sub(&st_chunks.sy).max_abs() < 1e-8);
        assert!(st_once.sxy.sub(&st_chunks.sxy).max_abs() < 1e-8);
        assert_eq!(st_once.n, st_chunks.n);
    }

    #[test]
    fn identity_qa_gives_equal_sigmas() {
        let x = Mat::random_normal(&mut Rng::new(2), 6, 100);
        let mut st = LayerStats::new(6, None, 1.0, None);
        st.update(&x);
        assert!(st.sx.sub(&st.sy).max_abs() < 1e-10);
        assert!(st.sx.sub(&st.sxy).max_abs() < 1e-10);
    }

    #[test]
    fn regularization_strength() {
        let x = Mat::random_normal(&mut Rng::new(3), 4, 50);
        let mut st = LayerStats::new(4, Some(4), 1.0, None);
        st.update(&x);
        let (sx, _, _) = st.regularized();
        let eps = 1e-2 * st.sx.trace() / 4.0;
        for i in 0..4 {
            assert!((sx[(i, i)] - st.sx[(i, i)] - eps).abs() < 1e-9);
        }
    }

    #[test]
    fn rows_f32_matches_update() {
        let mut rng = Rng::new(4);
        let n_rows = 10;
        let din = 5;
        let rows: Vec<f32> =
            rng.normal_vec(n_rows * din).iter().map(|&v| v as f32).collect();
        let mut st1 = LayerStats::new(din, Some(4), 1.0, None);
        st1.update_rows_f32(&rows, n_rows);
        // manual transpose path
        let mut x = Mat::zeros(din, n_rows);
        for r in 0..n_rows {
            for c in 0..din {
                x[(c, r)] = rows[r * din + c] as f64;
            }
        }
        let mut st2 = LayerStats::new(din, Some(4), 1.0, None);
        st2.update(&x);
        assert!(st1.sx.sub(&st2.sx).max_abs() < 1e-9);
    }

    #[test]
    fn update_par_bit_identical_across_pools() {
        // spans several STATS_TOKEN_CHUNK boundaries plus a ragged tail
        let x = Mat::random_normal(&mut Rng::new(10), 6, 3 * 256 + 97);
        let mut base = LayerStats::new(6, Some(4), 0.9, None);
        base.update_par(&x, &Pool::new(1));
        for t in [2, 8] {
            let mut st = LayerStats::new(6, Some(4), 0.9, None);
            st.update_par(&x, &Pool::new(t));
            assert_eq!(base.sx, st.sx, "threads={t}");
            assert_eq!(base.sy, st.sy, "threads={t}");
            assert_eq!(base.sxy, st.sxy, "threads={t}");
            assert_eq!(base.n, st.n);
        }
    }

    #[test]
    fn update_par_matches_serial_update() {
        // same Σ up to fp association across chunk boundaries
        let x = Mat::random_normal(&mut Rng::new(11), 8, 700);
        let mut serial = LayerStats::new(8, Some(4), 0.9, None);
        serial.update(&x);
        let mut par = LayerStats::new(8, Some(4), 0.9, None);
        par.update_par(&x, &Pool::new(4));
        assert!(serial.sx.sub(&par.sx).max_abs() < 1e-8);
        assert!(serial.sy.sub(&par.sy).max_abs() < 1e-8);
        assert!(serial.sxy.sub(&par.sxy).max_abs() < 1e-8);
        assert_eq!(serial.n, par.n);
    }

    #[test]
    fn rows_f32_par_matches_rows_f32() {
        let mut rng = Rng::new(12);
        let (n_rows, din) = (530, 5);
        let rows: Vec<f32> =
            rng.normal_vec(n_rows * din).iter().map(|&v| v as f32).collect();
        let mut serial = LayerStats::new(din, Some(4), 1.0, None);
        serial.update_rows_f32(&rows, n_rows);
        let mut par = LayerStats::new(din, Some(4), 1.0, None);
        par.update_rows_f32_par(&rows, n_rows, &Pool::new(4));
        assert!(serial.sx.sub(&par.sx).max_abs() < 1e-8);
        assert!(serial.sxy.sub(&par.sxy).max_abs() < 1e-8);
        assert_eq!(serial.n, par.n);
    }

    #[test]
    fn regularized_borrows_sxy_and_into_matches() {
        // finalize must hand Σxy out without copying (same allocation)
        // and the consuming path must produce identical bits
        let x = Mat::random_normal(&mut Rng::new(9), 5, 60);
        let mut st = LayerStats::new(5, Some(4), 0.9, None);
        st.update(&x);
        let (sx, sy, sxy) = st.regularized();
        assert!(std::ptr::eq(sxy, &st.sxy), "sxy must be a borrow");
        let (ix, iy, ixy) = st.clone().into_regularized();
        assert_eq!(sx, ix);
        assert_eq!(sy, iy);
        assert_eq!(*sxy, ixy);
        crate::linalg::workspace::recycle_mat(sx);
        crate::linalg::workspace::recycle_mat(sy);
    }

    #[test]
    fn sigmas_are_symmetric_psd() {
        let x = Mat::random_normal(&mut Rng::new(5), 6, 80);
        let mut st = LayerStats::new(6, Some(4), 0.9, None);
        st.update(&x);
        let (sx, sy, _) = st.regularized();
        for m in [&sx, &sy] {
            assert!(m.sub(&m.transpose()).max_abs() < 1e-9);
            // PD check via cholesky
            assert!(crate::linalg::cholesky(m).is_ok());
        }
    }
}
