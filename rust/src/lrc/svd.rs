//! The paper's "SVD" baseline (Tables 1–3): QuaRot-quantize with GPTQ, then
//! a rank-k SVD of the *weight* residual W − Ŵ — no activation statistics
//! in the low-rank term.  (LQER-style; the paper shows this is not enough.)
//!
//! Also provides the truncated SVD itself, built on the Jacobi eigensolver:
//! for A [m, n] with m ≤ n we eigendecompose A·Aᵀ and recover V = Aᵀ·U/σ.

use super::{lrc, qlr_objective, LayerResult, LayerStats};
use crate::linalg::{top_k_eigvecs, Mat};
use crate::quant::QuantConfig;

/// Truncated SVD: returns (U·diag(σ) [m,k], V [n,k]) with A ≈ (Uσ)·Vᵀ.
pub fn truncated_svd(a: &Mat, k: usize) -> (Mat, Mat) {
    let (m, n) = (a.rows, a.cols);
    if m <= n {
        let g = a.gram_n();                       // A·Aᵀ [m,m]
        let u = top_k_eigvecs(&g, k);             // [m,k]
        // σ_j² = u_jᵀ G u_j ; V = Aᵀ·U·diag(1/σ) ; return (U·σ, V)
        let atu = a.transpose().matmul(&u);       // [n,k] = Aᵀ U = V·σ
        let mut us = u.clone();
        let mut v = atu.clone();
        for j in 0..k {
            let sigma = (0..n)
                .map(|i| atu[(i, j)] * atu[(i, j)])
                .sum::<f64>()
                .sqrt()
                .max(1e-300);
            for i in 0..m {
                us[(i, j)] *= sigma;
            }
            for i in 0..n {
                v[(i, j)] /= sigma;
            }
        }
        (us, v)
    } else {
        let (v, us) = truncated_svd(&a.transpose(), k);
        // aᵀ ≈ v·usᵀ → a ≈ us·vᵀ ... careful: recursive call returns
        // (U'σ, V') for Aᵀ, i.e. Aᵀ ≈ (U'σ)V'ᵀ → A ≈ V'(U'σ)ᵀ.
        (us, v)
    }
}

/// The SVD baseline for one layer.
pub fn svd_baseline(w: &Mat, st: &LayerStats, k: usize, cfg: &QuantConfig)
                    -> Result<LayerResult, String> {
    // quantize with no correction (QuaRot-style)
    let base = lrc(w, st, 0, cfg)?;
    let resid = w.sub(&base.w_hat);
    let (u, v) = truncated_svd(&resid, k);
    let obj = qlr_objective(w, &base.w_hat, &u, &v, st);
    Ok(LayerResult {
        w_hat: base.w_hat,
        u: Some(u),
        v: Some(v),
        objective: obj,
        history: vec![obj],
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn svd_reconstructs_low_rank_exactly() {
        // A = U₀·V₀ᵀ with rank 3 → rank-3 truncated SVD is exact
        let mut rng = Rng::new(1);
        let u0 = Mat::random_normal(&mut rng, 10, 3);
        let v0 = Mat::random_normal(&mut rng, 14, 3);
        let a = u0.matmul(&v0.transpose());
        let (us, v) = truncated_svd(&a, 3);
        let rec = us.matmul(&v.transpose());
        assert!(a.sub(&rec).max_abs() < 1e-8);
    }

    #[test]
    fn svd_best_rank_k_property() {
        // Eckart–Young: truncated SVD beats random rank-k approximations
        let a = Mat::random_normal(&mut Rng::new(2), 12, 12);
        let (us, v) = truncated_svd(&a, 4);
        let err_svd = a.sub(&us.matmul(&v.transpose())).frob_norm();
        let mut rng = Rng::new(3);
        for _ in 0..10 {
            let ur = Mat::random_normal(&mut rng, 12, 4);
            let vr = Mat::random_normal(&mut rng, 12, 4);
            // best scale for the random pair (least squares on vec space)
            let approx = ur.matmul(&vr.transpose());
            let alpha = a.frob_dot(&approx) / approx.frob_dot(&approx);
            let err_r = a.sub(&approx.scale(alpha)).frob_norm();
            assert!(err_svd <= err_r + 1e-9);
        }
    }

    #[test]
    fn tall_and_wide_agree() {
        let a = Mat::random_normal(&mut Rng::new(4), 6, 17);
        let (us1, v1) = truncated_svd(&a, 2);
        let (us2, v2) = truncated_svd(&a.transpose(), 2);
        let r1 = us1.matmul(&v1.transpose());
        let r2 = us2.matmul(&v2.transpose()).transpose();
        assert!(r1.sub(&r2).max_abs() < 1e-7);
    }

    #[test]
    fn singular_values_descending() {
        let a = Mat::random_normal(&mut Rng::new(5), 9, 9);
        let (us, _) = truncated_svd(&a, 5);
        let norms: Vec<f64> = (0..5)
            .map(|j| (0..9).map(|i| us[(i, j)] * us[(i, j)]).sum::<f64>().sqrt())
            .collect();
        for w in norms.windows(2) {
            assert!(w[0] >= w[1] - 1e-9, "{norms:?}");
        }
    }
}
