//! The end-to-end PTQ pipeline, natively in rust (python never runs):
//!
//!   1. stream calibration batches through the AOT `acts` graph (PJRT),
//!      accumulating per-activation Σ statistics in f64,
//!   2. per quantized layer, run the selected method (QuaRot / SVD / LRC)
//!      from [`crate::lrc`],
//!   3. emit a quant [`TensorBundle`] whose (wq, u, v, clip) tensors slot
//!      into the matching `fwd_w4a4_*` graph parameters,
//!   4. account real int4 + fp16 storage (Table 3 sizes).
//!
//! This mirrors the paper's application procedure: "LRC works sequentially
//! through the weight matrices of the model, computing activations for
//! each weight matrix, obtaining the covariance and cross-covariances
//! matrices needed" — except the activations come from the rotated model's
//! AOT graph so layers are calibrated against the *original* (fp) forward.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::data::Corpus;
use crate::linalg::Mat;
use crate::lrc::{lrc, svd::svd_baseline, LayerStats};
use crate::par::Pool;
use crate::quant::pack::{model_size_bytes, PackedInts};
use crate::quant::{search_act_clip, weight_scales, QuantConfig};
use crate::registry::{ObjectKey, Registry};
use crate::runtime::{Engine, GraphInfo, ModelArtifacts, ModelInfo, TensorBundle};
use crate::util::Json;

/// Quantization method (the rows of Tables 1–3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// QuaRot baseline: GPTQ only, no correction (rank 0)
    Quarot,
    /// QuaRot + SVD of the weight residual (LQER-style)
    Svd,
    /// the paper's method
    Lrc,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        match s {
            "quarot" => Ok(Method::Quarot),
            "svd" => Ok(Method::Svd),
            "lrc" => Ok(Method::Lrc),
            _ => Err(anyhow!("unknown method {s} (quarot|svd|lrc)")),
        }
    }
    pub fn label(&self, cfg: &QuantConfig) -> String {
        match self {
            Method::Quarot => "QuaRot".into(),
            Method::Svd => "SVD".into(),
            Method::Lrc => format!("LRC ({})", cfg.iters),
        }
    }
    /// Stable lowercase name — registry digests key on this, so it must
    /// never change for an existing variant (round-trips `Method::parse`).
    pub fn name(&self) -> &'static str {
        match self {
            Method::Quarot => "quarot",
            Method::Svd => "svd",
            Method::Lrc => "lrc",
        }
    }
}

/// Names of the quantized linear layers, forward order — must mirror
/// python/compile/model.py::quantized_layer_names.
pub fn quantized_layer_names(info: &ModelInfo) -> Vec<String> {
    let mut out = Vec::new();
    for i in 0..info.n_layers {
        for nm in ["wq", "wk", "wv", "wo"] {
            out.push(format!("blk{i}.{nm}"));
        }
        if info.n_experts == 0 {
            for nm in ["wgate", "wup", "wdown"] {
                out.push(format!("blk{i}.{nm}"));
            }
        } else {
            for e in 0..info.n_experts {
                for nm in ["wgate", "wup", "wdown"] {
                    out.push(format!("blk{i}.e{e}.{nm}"));
                }
            }
        }
    }
    out
}

/// Which collected activation feeds a layer — mirrors
/// python/compile/model.py::activation_source.
pub fn activation_source(layer: &str) -> String {
    let (blk, leaf) = layer.split_once('.').expect("layer name");
    match leaf {
        "wq" | "wk" | "wv" => format!("{blk}.ln1_out"),
        "wo" => format!("{blk}.attn_out"),
        "wgate" | "wup" => format!("{blk}.ln2_out"),
        "wdown" => format!("{blk}.ffn_had"),
        other => {
            let (exp, leaf2) = other.split_once('.').expect("expert leaf");
            match leaf2 {
                "wgate" | "wup" => format!("{blk}.ln2_out"),
                "wdown" => format!("{blk}.{exp}.ffn_had"),
                _ => panic!("unknown layer {layer}"),
            }
        }
    }
}

/// Per-layer outcome for the report.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub layer: String,
    /// Correction rank the solve actually used (0 for QuaRot and any
    /// other factor-free solve), not the graph's rank layout.
    pub rank: usize,
    pub objective: f64,
    pub rel_error: f64,
    pub clip: f64,
}

/// Result of quantizing a whole model.
pub struct PipelineReport {
    pub method: Method,
    pub layers: Vec<LayerReport>,
    pub calib_seconds: f64,
    pub quant_seconds: f64,
    /// Table-3 storage accounting
    pub packed_bytes: usize,
    pub lowrank_params: usize,
    pub fp_params: usize,
}

impl PipelineReport {
    pub fn size_bytes(&self) -> usize {
        model_size_bytes(self.packed_bytes, self.lowrank_params, self.fp_params)
    }
    /// Mean relative layer reconstruction error (diagnostic).
    pub fn mean_rel_error(&self) -> f64 {
        let s: f64 = self.layers.iter().map(|l| l.rel_error).sum();
        s / self.layers.len().max(1) as f64
    }
}

/// Collected calibration statistics for every activation of a model.
pub struct CalibStats {
    pub stats: BTreeMap<String, LayerStats>,
    pub seconds: f64,
}

/// Plan how `n_seqs` calibration sequences spread over the exported
/// `acts_b*` batch buckets: greedily fill the largest bucket while a full
/// batch remains, then hand the tail to the smallest bucket that still
/// holds it — so a 41-sequence run over buckets {1, 8, 32} calibrates as
/// 32 + 8 + 1 with **zero** padded rows, where the old largest-only
/// policy padded 23 dead sequences into a second batch of 32.  Returns
/// `(bucket, used)` entries in execution order (deterministic: largest
/// first); `used < bucket` only ever in the final entry.
pub fn plan_calib_buckets(n_seqs: usize, buckets: &[usize])
                          -> Result<Vec<(usize, usize)>> {
    if buckets.is_empty() {
        return Err(anyhow!(
            "no acts_b* batch buckets to plan calibration over"));
    }
    if buckets.contains(&0) {
        return Err(anyhow!("zero-size acts bucket"));
    }
    let mut desc: Vec<usize> = buckets.to_vec();
    desc.sort_unstable_by(|a, b| b.cmp(a));
    desc.dedup();
    let mut plan = Vec::new();
    let mut remaining = n_seqs;
    for &b in &desc {
        while remaining >= b {
            plan.push((b, b));
            remaining -= b;
        }
    }
    if remaining > 0 {
        // after the descending pass, remaining < the smallest bucket, so
        // every bucket can hold the tail; the smallest pads least
        let b = *desc.last().expect("non-empty bucket list");
        plan.push((b, remaining));
    }
    Ok(plan)
}

/// Build the calibration batch list for `collect_stats`, validating the
/// inputs where the problem actually is: zero requested sequences or a
/// corpus too short to cut even one window both used to slip through as
/// an empty batch list, silently producing empty stats that only failed
/// much later as "no stats for activation".  Engine-free, so the edge
/// cases are unit-testable without PJRT.
pub fn calib_batches(corpus: &Corpus, n_seqs: usize, seq_len: usize,
                     seed: u64, batch: usize)
                     -> Result<Vec<(Vec<i32>, usize)>> {
    if n_seqs == 0 {
        return Err(anyhow!(
            "0 calibration sequences requested — calibration needs at \
             least one (pass --calib N with N > 0; the paper uses 128)"));
    }
    let seqs = corpus.calib_sequences(n_seqs, seq_len, seed)?;
    Ok(crate::data::batch_sequences(&seqs, batch))
}

/// Stream `n_seqs` calibration sequences through the acts graphs and
/// accumulate Σ per activation (paper: 128 sequences).  Σ partials are
/// folded on the process pool (see [`LayerStats::update_rows_f32_par`]).
///
/// Batches follow [`plan_calib_buckets`] over **every** exported
/// `acts_b*` bucket — the old policy ran only the largest bucket and
/// padded the tail up to it, silently burning forward passes on dead
/// rows whenever `n_seqs` was not a multiple of the largest batch.  One
/// session is compiled per distinct bucket the plan touches; the plan's
/// order is fixed (largest bucket first), so the Σ accumulation order —
/// and therefore every downstream bit — is deterministic.
pub fn collect_stats(engine: &Engine, arts: &ModelArtifacts, corpus: &Corpus,
                     n_seqs: usize, seed: u64, a_bits: Option<u32>,
                     a_group: Option<usize>) -> Result<CalibStats> {
    // analyze: allow(forbidden-api): wall-clock timing metadata for
    // operator feedback only; the deterministic report surfaces are
    // computed from model outputs, never from these seconds.
    let t0 = Instant::now();
    let pool = crate::par::global();
    if n_seqs == 0 {
        return Err(anyhow!(
            "0 calibration sequences requested — calibration needs at \
             least one (pass --calib N with N > 0; the paper uses 128)"));
    }
    let buckets: Vec<usize> = arts.bucket_graphs("acts")
        .iter().map(|(b, _)| *b).collect();
    if buckets.is_empty() {
        return Err(anyhow!(
            "model {} exports no acts_b* graph (have: {:?})",
            arts.info.name, arts.graphs.keys().collect::<Vec<_>>()));
    }
    let plan = plan_calib_buckets(n_seqs, &buckets)?;
    let seqs = corpus.calib_sequences(n_seqs, arts.info.seq_len, seed)?;

    let mut sessions: BTreeMap<usize, crate::runtime::Session> =
        BTreeMap::new();
    let mut stats: BTreeMap<String, LayerStats> = BTreeMap::new();
    let mut first = true;
    let mut cursor = 0usize;
    for (bucket, used) in plan {
        if !sessions.contains_key(&bucket) {
            let gname = format!("acts_b{bucket}");
            sessions.insert(bucket, engine.session(arts, &gname, None)?);
        }
        let session = &sessions[&bucket];
        let chunk = &seqs[cursor..cursor + used];
        cursor += used;
        for (flat, used) in &crate::data::batch_sequences(chunk, bucket) {
            let out = session.run(flat)?;
            for slice in &session.acts {
                let rows_per_seq = slice.rows / session.batch;
                let n_rows = used * rows_per_seq;
                let seg =
                    &out[slice.offset..slice.offset + slice.rows * slice.dim];
                if first {
                    // clip search on the first batch (per-activation c);
                    // the transposed batch is workspace scratch shared
                    // with the Σ-update transposes that follow
                    let mut x = crate::linalg::workspace::take_mat(
                        slice.dim, n_rows);
                    for r in 0..n_rows {
                        for c in 0..slice.dim {
                            x[(c, r)] = seg[r * slice.dim + c] as f64;
                        }
                    }
                    let clip = match a_bits {
                        Some(bits) => search_act_clip(&x, bits, a_group),
                        None => 1.0,
                    };
                    crate::linalg::workspace::recycle_mat(x);
                    stats.insert(slice.name.clone(),
                                 LayerStats::new(slice.dim, a_bits, clip,
                                                 a_group));
                }
                let st = stats.get_mut(&slice.name).ok_or_else(|| {
                    anyhow!("activation slice {:?} first appeared after the \
                             first calibration batch — the acts graph \
                             output set must be stable across batches and \
                             buckets", slice.name)
                })?;
                st.update_rows_f32_par(&seg[..n_rows * slice.dim], n_rows,
                                       pool);
            }
            first = false;
        }
    }
    Ok(CalibStats { stats, seconds: t0.elapsed().as_secs_f64() })
}

/// Everything one layer's worker produces; folded into the bundle and the
/// report serially, in `quantized_layer_names` order.
struct LayerArtifacts {
    layer: String,
    dout: usize,
    din: usize,
    wq: Vec<f32>,
    u: Option<(usize, Vec<f32>)>,
    v: Option<(usize, Vec<f32>)>,
    clip: f64,
    packed_bytes: usize,
    report: LayerReport,
}

/// Quantize one layer — the unit of work the pool fans out.  Pure: reads
/// shared calibration state, touches nothing mutable.
fn quantize_layer(arts: &ModelArtifacts, calib: &CalibStats,
                  graph: &GraphInfo, method: Method, cfg: &QuantConfig,
                  layer: &str) -> Result<LayerArtifacts> {
    let wt = arts.weights.get(layer)?;
    let (dout, din) = (wt.shape[0], wt.shape[1]);
    let w = Mat::from_f32(dout, din, &wt.data);
    let src = activation_source(layer);
    let st = calib.stats.get(&src)
        .ok_or_else(|| anyhow!("no stats for activation {src}"))?;
    let k = *graph.ranks.get(layer).unwrap_or(&0);

    let res = match method {
        Method::Quarot => lrc(&w, st, 0, cfg).map_err(|e| anyhow!(e))?,
        Method::Svd => svd_baseline(&w, st, k, cfg).map_err(|e| anyhow!(e))?,
        Method::Lrc => lrc(&w, st, k, cfg).map_err(|e| anyhow!(e))?,
    };

    // relative error vs the fp output energy: ℒ/‖WX‖²  (tr(WΣxWᵀ))
    let wx = w.matmul(&st.sx).frob_dot(&w);
    let rel = if wx > 0.0 { res.objective / wx } else { 0.0 };

    // real storage accounting (honors the configured weight bit-width)
    let scales = weight_scales(&res.w_hat, cfg.w_bits, None);
    let packed = PackedInts::pack(&res.w_hat, &scales, cfg.w_bits, None);

    // the rank actually used by the solve, not the graph's rank layout:
    // QuaRot always solves at rank 0 regardless of k, and a rank-0 solve
    // carries no factors — reporting k here mislabeled Table-1 baseline
    // rows
    let used_rank = res.u.as_ref().map_or(0, |u| u.cols);

    Ok(LayerArtifacts {
        layer: layer.to_string(),
        dout,
        din,
        wq: res.w_hat.to_f32(),
        u: res.u.as_ref().map(|u| (u.cols, u.to_f32())),
        v: res.v.as_ref().map(|v| (v.cols, v.to_f32())),
        clip: st.clip,
        packed_bytes: packed.size_bytes(),
        report: LayerReport {
            layer: layer.to_string(),
            rank: used_rank,
            objective: res.objective,
            rel_error: rel,
            clip: st.clip,
        },
    })
}

/// Quantize every layer of `arts` with `method`, matching the rank layout
/// of `graph` (the fwd graph the bundle will be fed into).  Uses the
/// shared process pool (`--threads` / `LRC_THREADS`; see
/// [`crate::par::global`]).
pub fn quantize_model(arts: &ModelArtifacts, calib: &CalibStats,
                      graph: &GraphInfo, method: Method, cfg: &QuantConfig)
                      -> Result<(TensorBundle, PipelineReport)> {
    quantize_model_with_pool(arts, calib, graph, method, cfg,
                             crate::par::global())
}

/// [`quantize_model`] on an explicit pool.
///
/// The per-layer solves depend only on the shared calibration statistics,
/// so the layer loop is embarrassingly parallel; workers pull layers from
/// the pool's queue and results are folded back in
/// [`quantized_layer_names`] order — bundles and reports are therefore
/// byte-identical for every thread count.  Inside each worker the GEMM /
/// Gram auto-parallelism suppresses itself (pool re-entrancy guard), so
/// the fan-out never oversubscribes.  (Single-layer workloads that call
/// the solvers directly — quickstart, rank sweeps — get the inner
/// parallelism instead; the bits are identical either way.)
pub fn quantize_model_with_pool(arts: &ModelArtifacts, calib: &CalibStats,
                                graph: &GraphInfo, method: Method,
                                cfg: &QuantConfig, pool: &Pool)
                                -> Result<(TensorBundle, PipelineReport)> {
    // analyze: allow(forbidden-api): wall-clock timing metadata for
    // operator feedback only; the deterministic report surfaces are
    // computed from model outputs, never from these seconds.
    let t0 = Instant::now();
    let names = quantized_layer_names(&arts.info);
    let results = pool.map(names.len(), |i| {
        quantize_layer(arts, calib, graph, method, cfg, &names[i])
    });

    let mut bundle = TensorBundle::default();
    let mut layers = Vec::new();
    let mut packed_bytes = 0usize;
    let mut lowrank_params = 0usize;
    for res in results {
        let la = res?;
        let layer = &la.layer;
        bundle.insert(&format!("{layer}.wq"), vec![la.dout, la.din], la.wq);
        if let (Some((uk, u)), Some((vk, v))) = (la.u, la.v) {
            lowrank_params += la.dout * uk + la.din * vk;
            bundle.insert(&format!("{layer}.u"), vec![la.dout, uk], u);
            bundle.insert(&format!("{layer}.v"), vec![la.din, vk], v);
        }
        bundle.insert(&format!("{layer}.clip"), vec![1],
                      vec![la.clip as f32]);
        packed_bytes += la.packed_bytes;
        layers.push(la.report);
    }

    // fp params = everything not quantized (embeddings, norms, head, router)
    let qset: std::collections::BTreeSet<String> =
        quantized_layer_names(&arts.info).into_iter().collect();
    let fp_params: usize = arts.weights.order.iter()
        .filter(|n| !qset.contains(*n))
        .map(|n| arts.weights.tensors[n].numel())
        .sum();

    let report = PipelineReport {
        method,
        layers,
        calib_seconds: calib.seconds,
        quant_seconds: t0.elapsed().as_secs_f64(),
        packed_bytes,
        lowrank_params,
        fp_params,
    };
    Ok((bundle, report))
}

/// Finite numbers serialize as themselves; NaN/Inf (pathological solves)
/// as `null` — JSON has no spelling for them, and a registry object must
/// always parse back.
fn finite_or_null(v: f64) -> Json {
    if v.is_finite() { Json::num(v) } else { Json::Null }
}

/// Canonical JSON for a [`PipelineReport`] — the registry payload form.
/// Wall-clock seconds are deliberately **excluded**: registry objects
/// are keyed by content and must be bit-identical across runs, and the
/// timings are the one non-deterministic field a report carries.
pub fn report_to_json(report: &PipelineReport) -> Json {
    Json::obj(vec![
        ("method", Json::str(report.method.name())),
        ("layers", Json::Arr(report.layers.iter().map(|l| Json::obj(vec![
            ("layer", Json::str(l.layer.clone())),
            ("rank", Json::num(l.rank as f64)),
            ("objective", finite_or_null(l.objective)),
            ("rel_error", finite_or_null(l.rel_error)),
            ("clip", finite_or_null(l.clip)),
        ])).collect())),
        ("packed_bytes", Json::num(report.packed_bytes as f64)),
        ("lowrank_params", Json::num(report.lowrank_params as f64)),
        ("fp_params", Json::num(report.fp_params as f64)),
    ])
}

/// Rebuild a [`PipelineReport`] from its registry payload form.  The
/// timing fields come back as zero (they were never stored — a cached
/// artifact did no work).
pub fn report_from_json(j: &Json) -> Result<PipelineReport> {
    let method = Method::parse(j.get("method").and_then(|m| m.as_str())
        .ok_or_else(|| anyhow!("cached report missing method"))?)?;
    let fnum = |t: &Json, f: &str| {
        t.get(f).and_then(|v| v.as_f64()).unwrap_or(f64::NAN)
    };
    let mut layers = Vec::new();
    for l in j.get("layers").and_then(|l| l.as_arr())
        .ok_or_else(|| anyhow!("cached report missing layers"))? {
        layers.push(LayerReport {
            layer: l.get("layer").and_then(|n| n.as_str())
                .ok_or_else(|| anyhow!("cached layer report missing name"))?
                .to_string(),
            rank: l.get("rank").and_then(|r| r.as_usize())
                .ok_or_else(|| anyhow!("cached layer report missing rank"))?,
            objective: fnum(l, "objective"),
            rel_error: fnum(l, "rel_error"),
            clip: fnum(l, "clip"),
        });
    }
    let unum = |f: &str| {
        j.get(f).and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow!("cached report missing {f}"))
    };
    Ok(PipelineReport {
        method,
        layers,
        calib_seconds: 0.0,
        quant_seconds: 0.0,
        packed_bytes: unum("packed_bytes")?,
        lowrank_params: unum("lowrank_params")?,
        fp_params: unum("fp_params")?,
    })
}

/// Verified registry lookup for a quant artifact: `Ok(None)` is a miss
/// (absent / corrupt / stale code version — compute it), `Ok(Some)` is
/// the bit-exact bundle + report previously published under this key.
/// This is checked **before** any engine or calibration work exists, so
/// a warm hit skips stats collection entirely (see `cmd_quantize`).
pub fn load_cached_quant(reg: &Registry, key: &ObjectKey)
                         -> Result<Option<(TensorBundle, PipelineReport)>> {
    let Some(obj) = reg.get(key)? else { return Ok(None) };
    let payload = obj.payload()?;
    let report = report_from_json(payload.get("report")
        .ok_or_else(|| anyhow!("quant registry object missing report"))?)?;
    let table = payload.get("tensors")
        .ok_or_else(|| anyhow!("quant registry object missing tensors"))?;
    let blob = obj.blob.as_deref()
        .ok_or_else(|| anyhow!("quant registry object missing blob"))?;
    let bundle = crate::registry::bundle_from_blob(table, blob)?;
    Ok(Some((bundle, report)))
}

/// [`quantize_model_with_pool`] behind the registry: a hit returns the
/// published bundle/report **without touching** `calib`, `graph` or the
/// pool (zero quantization compute — the warm-re-run acceptance test in
/// `tests/registry.rs` passes empty stats to prove it); a miss computes,
/// publishes and returns.  The `bool` is `true` on a hit.
pub fn quantize_model_cached(arts: &ModelArtifacts, calib: &CalibStats,
                             graph: &GraphInfo, method: Method,
                             cfg: &QuantConfig, pool: &Pool, reg: &Registry,
                             key: &ObjectKey)
                             -> Result<(TensorBundle, PipelineReport, bool)> {
    if let Some((bundle, report)) = load_cached_quant(reg, key)? {
        return Ok((bundle, report, true));
    }
    let (bundle, report) =
        quantize_model_with_pool(arts, calib, graph, method, cfg, pool)?;
    let (table, blob) = crate::registry::bundle_to_blob(&bundle);
    let payload = Json::obj(vec![
        ("kind", Json::str("quant-bundle")),
        ("report", report_to_json(&report)),
        ("tensors", table),
    ]);
    reg.publish(key, &payload, Some(&blob))?;
    Ok((bundle, report, false))
}

/// [`collect_stats`] for the activation-quant config `graph` implies:
/// weight-only graphs calibrate with Q_a = identity, everything else with
/// the configured activation bits and the graph's group size.  This is
/// the **stats-reuse entry point**: collect once, then run any number of
/// grid cells against the same [`CalibStats`] (stats collection dominates
/// wall-clock, so the sweep driver shares one per activation config).
pub fn collect_stats_for_graph(engine: &Engine, arts: &ModelArtifacts,
                               corpus: &Corpus, graph: &GraphInfo,
                               cfg: &QuantConfig, n_calib: usize)
                               -> Result<CalibStats> {
    let a_bits = if graph.weight_only { None } else { cfg.a_bits };
    collect_stats(engine, arts, corpus, n_calib, 1234, a_bits, graph.a_group)
}

/// Persist a quant bundle under `<model_dir>/quant/<method>_<graph>/` —
/// the **cell-execution half** of the old monolithic `quantize_and_save`.
pub fn save_quant_bundle(arts: &ModelArtifacts, bundle: &TensorBundle,
                         graph: &GraphInfo, method: Method,
                         cfg: &QuantConfig) -> Result<std::path::PathBuf> {
    let tag = format!("{}_{}", method.label(cfg).replace([' ', '(', ')'], ""),
                      graph.name);
    let out = arts.dir.join("quant").join(tag);
    bundle.write(&out, &[
        ("kind", Json::str("quant")),
        ("graph", Json::str(graph.name.clone())),
        ("rank_pct", Json::num(graph.rank_pct)),
    ])?;
    Ok(out)
}

/// Synthesize the [`GraphInfo`] a `fwd_*_r{pct}` AOT graph would carry
/// for one sweep cell: per-layer low-rank sizes from
/// [`crate::quant::rank_for_pct`] on the weight shapes (the same formula
/// python's AOT lowering uses, so a synthesized layout matches the
/// on-disk graph of the same pct wherever one exists).  Grid cells
/// quantize against this, so a sweep needs no matching AOT graph on disk
/// — only NLL evaluation does.
pub fn cell_graph(arts: &ModelArtifacts, rank_pct: usize,
                  a_group: Option<usize>, weight_only: bool, batch: usize)
                  -> Result<GraphInfo> {
    let pct = rank_pct as f64 / 100.0;
    let mut ranks = BTreeMap::new();
    for layer in quantized_layer_names(&arts.info) {
        let wt = arts.weights.get(&layer)?;
        ranks.insert(layer,
                     crate::quant::rank_for_pct(wt.shape[0], wt.shape[1],
                                                pct));
    }
    Ok(GraphInfo {
        name: crate::experiments::quant_graph_name(rank_pct, a_group,
                                                   weight_only, batch),
        file: std::path::PathBuf::new(),
        params: Vec::new(),
        batch,
        ranks,
        rank_pct: pct,
        a_group,
        weight_only,
        acts: Vec::new(),
    })
}

/// Convenience: quantize and persist under
/// `<model_dir>/quant/<method>_<graph>/` — now a thin composition of the
/// split entry points ([`collect_stats_for_graph`] → [`quantize_model`]
/// → [`save_quant_bundle`]).
pub fn quantize_and_save(engine: &Engine, arts: &ModelArtifacts,
                         corpus: &Corpus, graph_name: &str, method: Method,
                         cfg: &QuantConfig, n_calib: usize)
                         -> Result<(TensorBundle, PipelineReport)> {
    let graph = arts.graph(graph_name)?.clone();
    let calib = collect_stats_for_graph(engine, arts, corpus, &graph, cfg,
                                        n_calib)?;
    let (bundle, report) = quantize_model(arts, &calib, &graph, method, cfg)?;
    save_quant_bundle(arts, &bundle, &graph, method, cfg)?;
    Ok((bundle, report))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_names_dense() {
        let info = ModelInfo {
            name: "t".into(), d_model: 8, n_layers: 2, n_heads: 2, d_ff: 16,
            n_experts: 0, seq_len: 4, vocab: 256, param_count: 0,
        };
        let names = quantized_layer_names(&info);
        assert_eq!(names.len(), 14);
        assert_eq!(names[0], "blk0.wq");
        assert_eq!(names[6], "blk0.wdown");
        assert_eq!(names[13], "blk1.wdown");
    }

    #[test]
    fn layer_names_moe() {
        let info = ModelInfo {
            name: "t".into(), d_model: 8, n_layers: 1, n_heads: 2, d_ff: 16,
            n_experts: 3, seq_len: 4, vocab: 256, param_count: 0,
        };
        let names = quantized_layer_names(&info);
        assert_eq!(names.len(), 4 + 9);
        assert!(names.contains(&"blk0.e2.wdown".to_string()));
    }

    #[test]
    fn zero_calib_sequences_error_at_the_source() {
        // regression: n_seqs = 0 used to produce an empty batch list and
        // empty stats, failing much later as "no stats for activation"
        let corpus = crate::data::Corpus::from_text("t", &"ab".repeat(200));
        let err = calib_batches(&corpus, 0, 16, 1, 8).unwrap_err()
            .to_string();
        assert!(err.contains("calibration"), "{err}");
        assert!(err.contains("--calib"), "not actionable: {err}");
    }

    #[test]
    fn empty_corpus_error_at_the_source() {
        let corpus = crate::data::Corpus::from_text("empty", "");
        let err = calib_batches(&corpus, 8, 16, 1, 8).unwrap_err()
            .to_string();
        assert!(err.contains("too short for calibration"), "{err}");
    }

    #[test]
    fn calib_batches_round_up_to_full_batches() {
        let corpus = crate::data::Corpus::from_text("t", &"ab".repeat(400));
        let batches = calib_batches(&corpus, 10, 16, 1, 4).unwrap();
        assert_eq!(batches.len(), 3); // 4 + 4 + 2(padded)
        assert_eq!(batches[2].1, 2);
        for (flat, _) in &batches {
            assert_eq!(flat.len(), 4 * 16);
        }
    }

    #[test]
    fn cell_graph_ranks_follow_the_weight_shapes() {
        let info = ModelInfo {
            name: "t".into(), d_model: 16, n_layers: 1, n_heads: 2,
            d_ff: 32, n_experts: 0, seq_len: 4, vocab: 64, param_count: 0,
        };
        let mut weights = TensorBundle::default();
        for layer in quantized_layer_names(&info) {
            let (dout, din) = match layer.rsplit_once('.').unwrap().1 {
                "wgate" | "wup" => (32usize, 16usize),
                "wdown" => (16, 32),
                _ => (16, 16),
            };
            weights.insert(&layer, vec![dout, din], vec![0.0; dout * din]);
        }
        let arts = ModelArtifacts {
            dir: std::path::PathBuf::new(),
            weights,
            graphs: BTreeMap::new(),
            info,
        };
        let g = cell_graph(&arts, 10, Some(32), false, 8).unwrap();
        assert_eq!(g.name, "fwd_w4a4_r10_g32_b8");
        assert_eq!(g.rank_pct, 0.10);
        assert_eq!(g.ranks["blk0.wq"],
                   crate::quant::rank_for_pct(16, 16, 0.10));
        assert_eq!(g.ranks["blk0.wup"],
                   crate::quant::rank_for_pct(32, 16, 0.10));
        // rank 0 layout for the baseline cells
        let g0 = cell_graph(&arts, 0, None, false, 8).unwrap();
        assert!(g0.ranks.values().all(|&k| k == 0));
        assert_eq!(g0.name, "fwd_w4a4_r0_b8");
    }

    #[test]
    fn activation_sources() {
        assert_eq!(activation_source("blk0.wq"), "blk0.ln1_out");
        assert_eq!(activation_source("blk1.wo"), "blk1.attn_out");
        assert_eq!(activation_source("blk0.wup"), "blk0.ln2_out");
        assert_eq!(activation_source("blk1.wdown"), "blk1.ffn_had");
        assert_eq!(activation_source("blk0.e1.wgate"), "blk0.ln2_out");
        assert_eq!(activation_source("blk0.e1.wdown"), "blk0.e1.ffn_had");
    }

    #[test]
    fn method_name_roundtrips() {
        for m in [Method::Quarot, Method::Svd, Method::Lrc] {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
    }

    #[test]
    fn calib_plan_spreads_over_every_bucket() {
        // regression: the old policy calibrated on the largest bucket
        // only — 41 sequences over {1, 8, 32} ran 2×32 batches with 23
        // padded dead rows; the plan covers all 41 with zero padding
        let plan = plan_calib_buckets(41, &[1, 8, 32]).unwrap();
        assert_eq!(plan, vec![(32, 32), (8, 8), (1, 1)]);
        assert!(plan.iter().all(|(b, u)| u == b), "no padded entries");

        // tail smaller than every bucket lands in the smallest (least
        // padding), partially filled
        assert_eq!(plan_calib_buckets(7, &[8, 32]).unwrap(), vec![(8, 7)]);
        // a single bucket repeats until the sequences are consumed
        assert_eq!(plan_calib_buckets(64, &[32]).unwrap(),
                   vec![(32, 32), (32, 32)]);
        // duplicates on the bucket axis fold away; order in is irrelevant
        assert_eq!(plan_calib_buckets(9, &[8, 1, 8]).unwrap(),
                   vec![(8, 8), (1, 1)]);
        assert!(plan_calib_buckets(5, &[]).is_err());
        assert!(plan_calib_buckets(5, &[0, 8]).is_err());
    }

    #[test]
    fn calib_plan_on_a_multi_bucket_fixture() {
        // drive the plan from a fixture's exported graphs, exactly as
        // collect_stats does
        let mk = |name: &str, batch: usize| GraphInfo {
            name: name.into(),
            file: std::path::PathBuf::new(),
            params: Vec::new(),
            batch,
            ranks: BTreeMap::new(),
            rank_pct: 0.0,
            a_group: None,
            weight_only: false,
            acts: Vec::new(),
        };
        let mut graphs = BTreeMap::new();
        for (n, b) in [("acts_b1", 1), ("acts_b8", 8), ("acts_b32", 32),
                       ("fwd_fp_b8", 8)] {
            graphs.insert(n.to_string(), mk(n, b));
        }
        let arts = ModelArtifacts {
            dir: std::path::PathBuf::new(),
            weights: TensorBundle::default(),
            graphs,
            info: ModelInfo {
                name: "t".into(), d_model: 8, n_layers: 1, n_heads: 2,
                d_ff: 16, n_experts: 0, seq_len: 4, vocab: 64,
                param_count: 0,
            },
        };
        let buckets: Vec<usize> = arts.bucket_graphs("acts")
            .iter().map(|(b, _)| *b).collect();
        assert_eq!(buckets, vec![1, 8, 32]);
        let plan = plan_calib_buckets(128, &buckets).unwrap();
        // the paper's 128 sequences: four full batches of 32, no padding
        assert_eq!(plan, vec![(32, 32); 4]);
        let covered: usize = plan.iter().map(|(_, u)| u).sum();
        assert_eq!(covered, 128);
    }

    #[test]
    fn report_json_roundtrip_drops_only_the_timings() {
        let report = PipelineReport {
            method: Method::Lrc,
            layers: vec![
                LayerReport { layer: "blk0.wq".into(), rank: 3,
                              objective: 0.125, rel_error: 0.03125,
                              clip: 0.97 },
                LayerReport { layer: "blk0.wdown".into(), rank: 0,
                              objective: f64::NAN, rel_error: 0.5,
                              clip: 1.0 },
            ],
            calib_seconds: 12.5,
            quant_seconds: 3.25,
            packed_bytes: 4096,
            lowrank_params: 128,
            fp_params: 777,
        };
        let j = report_to_json(&report);
        let text = j.to_string();
        assert!(!text.contains("seconds"),
                "wall-clock must not enter registry payloads: {text}");
        let back = report_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.method, report.method);
        assert_eq!(back.layers.len(), 2);
        assert_eq!(back.layers[0].layer, "blk0.wq");
        assert_eq!(back.layers[0].rank, 3);
        // exact f64 round-trip (shortest-roundtrip formatting)
        assert_eq!(back.layers[0].objective, 0.125);
        assert_eq!(back.layers[0].rel_error, 0.03125);
        // the NaN objective serialized as null and came back NaN
        assert!(back.layers[1].objective.is_nan());
        assert_eq!(back.packed_bytes, 4096);
        assert_eq!(back.lowrank_params, 128);
        assert_eq!(back.fp_params, 777);
        assert_eq!(back.calib_seconds, 0.0);
        assert_eq!(back.quant_seconds, 0.0);
        assert_eq!(back.size_bytes(), report.size_bytes());
    }
}
