//! Compile-time stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The build image ships neither the XLA shared library nor a crates.io
//! registry, so this path dependency provides the *type surface* the
//! `lrc` runtime layer compiles against — `PjRtClient`, `PjRtBuffer`,
//! `PjRtLoadedExecutable`, `HloModuleProto`, `XlaComputation`,
//! `Literal` — with every runtime entry point returning a descriptive
//! [`Error`].
//!
//! Everything that does not touch PJRT (the whole PTQ math stack, the
//! batcher, the metrics, the par pool, all unit tests) builds and runs
//! unchanged; integration tests that need real execution already skip
//! when `make artifacts` has not produced artifacts.  To execute AOT
//! graphs, point the `xla` dependency in `rust/Cargo.toml` at the real
//! binding — the API below matches the subset `lrc` uses.

use std::fmt;
use std::path::Path;

/// Error type mirroring xla-rs's: displayable, `std::error::Error`, so
/// `?` converts it into `anyhow::Error` at the call sites.
#[derive(Debug, Clone)]
pub struct Error {
    message: String,
}

impl Error {
    fn unavailable(entry: &str) -> Error {
        Error {
            message: format!(
                "{entry}: PJRT runtime unavailable — this build uses the \
                 offline `xla` stub crate (rust/vendor/xla). Point the \
                 `xla` dependency at the real xla-rs binding to execute \
                 compiled graphs."),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types transferable to device buffers.
pub trait Element: Copy + Send + Sync + 'static {}

impl Element for f32 {}
impl Element for f64 {}
impl Element for i32 {}
impl Element for i64 {}
impl Element for u8 {}
impl Element for u32 {}

/// PJRT client handle (stub: construction fails).
#[derive(Clone)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn buffer_from_host_buffer<T: Element>(
        &self, _data: &[T], _dims: &[usize], _device: Option<usize>)
        -> Result<PjRtBuffer> {
        Err(Error::unavailable("PjRtClient::buffer_from_host_buffer"))
    }

    pub fn compile(&self, _computation: &XlaComputation)
                   -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module proto (stub: parsing fails).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(Error {
            message: format!(
                "HloModuleProto::from_text_file({:?}): PJRT runtime \
                 unavailable — offline `xla` stub crate in use",
                path.as_ref()),
        })
    }
}

/// An XLA computation wrapping a module proto.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// A compiled executable (stub: execution fails).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute over borrowed argument buffers; one output list per device.
    pub fn execute_b(&self, _args: &[&PjRtBuffer])
                     -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// A device buffer (stub: never constructed).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A host literal (stub: never constructed).
pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_stub() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        let msg = err.to_string();
        assert!(msg.contains("stub"), "{msg}");
        assert!(msg.contains("PjRtClient::cpu"), "{msg}");
    }

    #[test]
    fn hlo_parse_reports_path() {
        let err = HloModuleProto::from_text_file("/tmp/fwd.hlo")
            .err().expect("stub must fail");
        assert!(err.to_string().contains("fwd.hlo"));
    }

    #[test]
    fn error_converts_via_std_error() {
        fn takes_std(_: &dyn std::error::Error) {}
        let err = PjRtClient::cpu().err().unwrap();
        takes_std(&err);
    }
}
