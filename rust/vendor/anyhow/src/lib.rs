//! Offline, dependency-free shim of the `anyhow` error-handling crate.
//!
//! The build image ships no crates.io registry, so this path dependency
//! provides the exact API subset the `lrc` crate uses — drop-in
//! compatible with the real `anyhow` for:
//!
//!   * [`Error`] with [`Error::msg`] and source-chain collection,
//!   * [`Result<T>`] (defaulted error parameter),
//!   * the [`anyhow!`], [`bail!`] and [`ensure!`] macros,
//!   * the [`Context`] extension trait (`context` / `with_context`) on
//!     `Result` and `Option`,
//!   * `From<E: std::error::Error>` so `?` converts std errors,
//!   * `{e}` / `{e:#}` Display (head message / full `a: b: c` chain) and
//!     an anyhow-style Debug ("Caused by:" list).
//!
//! Swapping back to the real crate is a one-line change in
//! `rust/Cargo.toml`; nothing in the consuming code needs to move.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the same defaulted shape as anyhow.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-chained error: `chain[0]` is the outermost message, the rest
/// are successively deeper causes.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (anyhow-compatible).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The outermost message (what `{e}` prints).
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }

    fn from_std<E: StdError + ?Sized>(e: &E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{e:#}` — the full chain, anyhow-style
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// `?` on any std error.  Coherent because `Error` itself deliberately
// does NOT implement `std::error::Error` (same trick as real anyhow).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::from_std(&e)
    }
}

/// `.context(...)` / `.with_context(|| ...)` on fallible values.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: StdError + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::from_std(&e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from_std(&e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("fmt {x}")`, `anyhow!(displayable)`, `anyhow!("{} {}", a, b)`.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// `ensure!(cond, ...)` — bail unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn macro_forms() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let name = "x";
        let e = anyhow!("tensor {name} bad");
        assert_eq!(e.to_string(), "tensor x bad");
        let e = anyhow!("{} of {}", 1, 2);
        assert_eq!(e.to_string(), "1 of 2");
        let s: String = "owned".into();
        let e = anyhow!(s);
        assert_eq!(e.to_string(), "owned");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(f(true).unwrap(), 7);
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            let _n: usize = "nope".parse()?;
            Ok(())
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing file");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(e.to_string(), "empty");
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }

    #[test]
    fn error_msg_as_fn_pointer() {
        // the `.map_err(anyhow::Error::msg)` pattern used by the crate
        let r: std::result::Result<(), String> = Err("boom".into());
        let e = r.map_err(Error::msg).unwrap_err();
        assert_eq!(e.to_string(), "boom");
    }
}
