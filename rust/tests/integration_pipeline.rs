//! Integration: the full native PTQ pipeline (calibrate → quantize →
//! bundle → evaluate) and the serving coordinator, against real artifacts.
//! Skips loudly when `make artifacts` hasn't run.

use std::time::Duration;

use lrc::coordinator::{BatchPolicy, ServerConfig, ServerHandle};
use lrc::data::Corpus;
use lrc::experiments::{self, EvalBudget};
use lrc::pipeline::{collect_stats, quantize_model, Method};
use lrc::quant::QuantConfig;
use lrc::runtime::{Engine, ModelArtifacts};

fn setup() -> Option<(Engine, ModelArtifacts, Corpus)> {
    let art = lrc::artifacts_dir();
    let mdir = art.join("models/nano");
    if !mdir.is_dir() {
        eprintln!("SKIP: run `make artifacts` first");
        return None;
    }
    let engine = Engine::cpu().unwrap();
    let arts = ModelArtifacts::load(&mdir).unwrap();
    let corpus = Corpus::load(&art.join("corpus/wiki_syn.txt")).unwrap();
    Some((engine, arts, corpus))
}

#[test]
fn calibration_stats_cover_all_layers() {
    let Some((engine, arts, corpus)) = setup() else { return };
    let calib = collect_stats(&engine, &arts, &corpus, 16, 1, Some(4), None)
        .unwrap();
    for layer in lrc::pipeline::quantized_layer_names(&arts.info) {
        let src = lrc::pipeline::activation_source(&layer);
        let st = calib.stats.get(&src).unwrap_or_else(
            || panic!("no stats for {src}"));
        assert!(st.n >= 16 * arts.info.seq_len);
        // Σx must be PD after regularization
        let (sx, sy, _) = st.regularized();
        assert!(lrc::linalg::cholesky(&sx).is_ok(), "{src} Σx not PD");
        assert!(lrc::linalg::cholesky(&sy).is_ok(), "{src} Σy not PD");
    }
}

#[test]
fn lrc_pipeline_beats_quarot_end_to_end() {
    // the headline claim, as an automated integration test on nano:
    // PPL(fp) < PPL(lrc@10%) < PPL(quarot)
    let Some((engine, arts, corpus)) = setup() else { return };
    let budget = EvalBudget { ppl_seqs: 16, task_items: 8 };
    let tasks = experiments::load_tasks(&lrc::artifacts_dir(), budget).unwrap();

    let calib = collect_stats(&engine, &arts, &corpus, 64, 1234, Some(4),
                              None).unwrap();
    let cfg = QuantConfig::default();

    let g_lrc = arts.graph("fwd_w4a4_r10_b8").unwrap().clone();
    let (b_lrc, _) = quantize_model(&arts, &calib, &g_lrc, Method::Lrc, &cfg)
        .unwrap();
    let g_q = arts.graph("fwd_w4a4_r0_b8").unwrap().clone();
    let (b_q, _) = quantize_model(&arts, &calib, &g_q, Method::Quarot, &cfg)
        .unwrap();

    let fp = experiments::evaluate_graph(&engine, &arts, "fwd_fp_b8", None,
                                         &corpus, &tasks, budget, "fp")
        .unwrap();
    let lrc_s = experiments::evaluate_graph(&engine, &arts, "fwd_w4a4_r10_b8",
                                            Some(&b_lrc), &corpus, &tasks,
                                            budget, "lrc").unwrap();
    let q_s = experiments::evaluate_graph(&engine, &arts, "fwd_w4a4_r0_b8",
                                          Some(&b_q), &corpus, &tasks,
                                          budget, "quarot").unwrap();
    assert!(fp.ppl < lrc_s.ppl, "fp {} !< lrc {}", fp.ppl, lrc_s.ppl);
    assert!(lrc_s.ppl < q_s.ppl, "lrc {} !< quarot {}", lrc_s.ppl, q_s.ppl);
}

#[test]
fn quant_bundle_shapes_match_graph() {
    let Some((engine, arts, corpus)) = setup() else { return };
    let calib = collect_stats(&engine, &arts, &corpus, 8, 7, Some(4), None)
        .unwrap();
    let g = arts.graph("fwd_w4a4_r10_b8").unwrap().clone();
    let (bundle, report) =
        quantize_model(&arts, &calib, &g, Method::Svd, &QuantConfig::default())
            .unwrap();
    for layer in lrc::pipeline::quantized_layer_names(&arts.info) {
        let w = arts.weights.get(&layer).unwrap();
        let wq = bundle.get(&format!("{layer}.wq")).unwrap();
        assert_eq!(w.shape, wq.shape);
        let k = g.ranks[&layer];
        let u = bundle.get(&format!("{layer}.u")).unwrap();
        assert_eq!(u.shape, vec![w.shape[0], k]);
        let v = bundle.get(&format!("{layer}.v")).unwrap();
        assert_eq!(v.shape, vec![w.shape[1], k]);
        let clip = bundle.get(&format!("{layer}.clip")).unwrap();
        assert_eq!(clip.shape, vec![1]);
        assert!(clip.data[0] > 0.0 && clip.data[0] <= 1.0);
    }
    assert!(report.packed_bytes > 0);
    assert!(report.lowrank_params > 0);
}

#[test]
fn weight_only_pipeline_near_lossless() {
    // Table-3 regime: W4, Qa = id — PPL within a whisker of fp
    let Some((engine, arts, corpus)) = setup() else { return };
    let budget = EvalBudget { ppl_seqs: 16, task_items: 8 };
    let tasks = experiments::load_tasks(&lrc::artifacts_dir(), budget).unwrap();
    let calib = collect_stats(&engine, &arts, &corpus, 32, 5, None, None)
        .unwrap();
    let cfg = QuantConfig { a_bits: None, ..Default::default() };
    let g = arts.graph("fwd_w4_r0_b8").unwrap().clone();
    let (bundle, _) = quantize_model(&arts, &calib, &g, Method::Quarot, &cfg)
        .unwrap();
    let fp = experiments::evaluate_graph(&engine, &arts, "fwd_fp_b8", None,
                                         &corpus, &tasks, budget, "fp")
        .unwrap();
    let w4 = experiments::evaluate_graph(&engine, &arts, "fwd_w4_r0_b8",
                                         Some(&bundle), &corpus, &tasks,
                                         budget, "w4").unwrap();
    assert!(w4.ppl < fp.ppl * 1.10,
            "weight-only not near-lossless: {} vs {}", w4.ppl, fp.ppl);
}

#[test]
fn coordinator_serves_fp_graph() {
    let Some((_, _, corpus)) = setup() else { return };
    let handle = ServerHandle::start(ServerConfig {
        model_dir: lrc::artifacts_dir().join("models/nano"),
        graph_prefix: "fwd_fp".into(),
        quant_dir: None,
        policy: BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            max_queue: 256,
            deadline: None,
        },
        workers: 2,
        native: false,
    })
    .unwrap();
    let seqs = corpus.eval_sequences(handle.seq_len, 24);
    let mut rxs = Vec::new();
    for s in &seqs {
        rxs.push(handle.submit(s.clone()).unwrap());
    }
    let mut ids = Vec::new();
    for rx in rxs {
        // no deadline configured, so every outcome must be Scored
        let resp = rx.recv().unwrap().scored().unwrap();
        assert!(resp.mean_nll.is_finite() && resp.mean_nll > 0.0);
        ids.push(resp.id);
    }
    assert_eq!(ids.len(), seqs.len());
    let snap = handle.shutdown();
    assert_eq!(snap.requests, seqs.len() as u64);
    assert_eq!(snap.errors, 0);
    // per-seq NLL from the server should be near corpus-level quality
    assert!(snap.batches >= (seqs.len() as u64) / 8);
}

#[test]
fn coordinator_rejects_bad_seq_len() {
    let Some(_) = setup() else { return };
    let handle = ServerHandle::start(ServerConfig {
        model_dir: lrc::artifacts_dir().join("models/nano"),
        graph_prefix: "fwd_fp".into(),
        quant_dir: None,
        policy: BatchPolicy::default(),
        workers: 1,
        native: false,
    })
    .unwrap();
    assert!(handle.submit(vec![1, 2, 3]).is_err());
    handle.shutdown();
}
