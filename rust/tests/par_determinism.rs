//! The determinism contract of the parallel compute layer, end to end:
//! `quantize_model` fans the per-layer solves out across the pool, and the
//! resulting bundle + report must be **byte-identical** for every thread
//! count.  Runs on synthetic in-memory artifacts — no PJRT, no `make
//! artifacts` — so it is always exercised.

use std::collections::BTreeMap;

use lrc::linalg::Mat;
use lrc::lrc::LayerStats;
use lrc::par::Pool;
use lrc::pipeline::{activation_source, quantize_model_with_pool,
                    quantized_layer_names, Method};
use lrc::quant::QuantConfig;
use lrc::rng::Rng;
use lrc::runtime::{GraphInfo, ModelArtifacts, ModelInfo, TensorBundle};

/// Serializes the FMA-forcing test against every test in this binary
/// that quantizes more than once and compares the results: the FMA mode
/// changes bits (by design, with its own determinism contract), so a
/// mid-test flip would turn a cross-run comparison into a false failure.
/// Backend flips never need this — they are bit-invisible.
fn mode_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn synthetic_model() -> (ModelArtifacts, lrc::pipeline::CalibStats, GraphInfo) {
    let (d_model, d_ff) = (8usize, 16usize);
    let info = ModelInfo {
        name: "synthetic".into(),
        d_model,
        n_layers: 1,
        n_heads: 2,
        d_ff,
        n_experts: 0,
        seq_len: 4,
        vocab: 32,
        param_count: 0,
    };

    let mut rng = Rng::new(2024);
    let mut weights = TensorBundle::default();
    let mut ranks = BTreeMap::new();
    for layer in quantized_layer_names(&info) {
        let (dout, din) = match layer.rsplit_once('.').unwrap().1 {
            "wgate" | "wup" => (d_ff, d_model),
            "wdown" => (d_model, d_ff),
            _ => (d_model, d_model),
        };
        let data: Vec<f32> =
            rng.normal_vec(dout * din).iter().map(|&v| v as f32).collect();
        weights.insert(&layer, vec![dout, din], data);
        ranks.insert(layer, 2usize);
    }
    // a non-quantized tensor so fp_params accounting is exercised
    weights.insert("embed", vec![info.vocab, d_model],
                   vec![0.01; info.vocab * d_model]);

    let arts = ModelArtifacts {
        dir: std::env::temp_dir().join("lrc_par_determinism"),
        weights,
        graphs: BTreeMap::new(),
        info,
    };

    // calibration statistics per activation source, correlated activations
    let mut stats = BTreeMap::new();
    for layer in quantized_layer_names(&arts.info) {
        let src = activation_source(&layer);
        if stats.contains_key(&src) {
            continue;
        }
        let din = if src.ends_with("ffn_had") { d_ff } else { d_model };
        let x = Mat::random_normal(&mut rng, din, 64 * din);
        let mut st = LayerStats::new(din, Some(4), 0.9, None);
        st.update(&x);
        stats.insert(src, st);
    }
    let calib = lrc::pipeline::CalibStats { stats, seconds: 0.0 };

    let graph = GraphInfo {
        name: "fwd_w4a4_r10_b8".into(),
        file: std::path::PathBuf::new(),
        params: Vec::new(),
        batch: 8,
        ranks,
        rank_pct: 0.10,
        a_group: None,
        weight_only: false,
        acts: Vec::new(),
    };
    (arts, calib, graph)
}

#[test]
fn small_epochs_dispatch_to_a_worker_subset_and_stay_correct() {
    // regression (ROADMAP open item): epochs used to wake every parked
    // worker even when the item count was smaller than the pool — the
    // board now hands out min(items - 1, workers) claims per epoch.  The
    // contract under test: (1) a small epoch runs on at most `items`
    // threads, (2) interleaving small and full-width epochs on one board
    // never leaks stale claims (every epoch still computes exactly its
    // own items, in order).
    use std::collections::BTreeSet;
    use std::sync::Mutex;
    let pool = Pool::new(8);
    for items in [2usize, 3, 6] {
        let tids = Mutex::new(BTreeSet::new());
        let out = pool.map(items, |i| {
            tids.lock().unwrap().insert(std::thread::current().id());
            i + 100
        });
        assert_eq!(out, (100..100 + items).collect::<Vec<_>>());
        let participants = tids.lock().unwrap().len();
        assert!(participants <= items,
                "items={items}: {participants} threads participated");
    }
    for round in 0..100 {
        assert_eq!(pool.map(2, |i| i + round), vec![round, round + 1],
                   "small epoch, round {round}");
        assert_eq!(pool.map(32, |i| i * i),
                   (0..32).map(|i| i * i).collect::<Vec<_>>(),
                   "full-width epoch, round {round}");
    }
}

#[test]
fn quantize_model_bit_identical_across_thread_counts() {
    let _guard = mode_lock();
    let (arts, calib, graph) = synthetic_model();
    let cfg = QuantConfig::default();
    for method in [Method::Lrc, Method::Svd, Method::Quarot] {
        let (b1, r1) = quantize_model_with_pool(
            &arts, &calib, &graph, method, &cfg, &Pool::new(1)).unwrap();
        for t in [2usize, 8] {
            let (bt, rt) = quantize_model_with_pool(
                &arts, &calib, &graph, method, &cfg, &Pool::new(t)).unwrap();
            // bundle: same tensors, same order, same bytes
            assert_eq!(b1.order, bt.order, "{method:?} threads={t}");
            for name in &b1.order {
                let x = b1.get(name).unwrap();
                let y = bt.get(name).unwrap();
                assert_eq!(x.shape, y.shape, "{method:?} {name} t={t}");
                assert_eq!(x.data, y.data, "{method:?} {name} t={t}");
            }
            // report: objectives (the acceptance criterion) + accounting
            assert_eq!(r1.layers.len(), rt.layers.len());
            for (a, b) in r1.layers.iter().zip(&rt.layers) {
                assert_eq!(a.layer, b.layer, "{method:?} t={t}");
                assert_eq!(a.rank, b.rank);
                assert_eq!(a.objective.to_bits(), b.objective.to_bits(),
                           "{method:?} {}: objective differs at t={t}",
                           a.layer);
                assert_eq!(a.rel_error.to_bits(), b.rel_error.to_bits());
            }
            assert_eq!(r1.packed_bytes, rt.packed_bytes);
            assert_eq!(r1.lowrank_params, rt.lowrank_params);
            assert_eq!(r1.fp_params, rt.fp_params);
        }
    }
}

#[test]
fn fanout_matches_direct_per_layer_solve() {
    let _guard = mode_lock();
    // the pool must not change the math: a layer solved directly equals
    // the same layer pulled out of the fan-out, bit for bit
    let (arts, calib, graph) = synthetic_model();
    let cfg = QuantConfig::default();
    let (bundle, report) = quantize_model_with_pool(
        &arts, &calib, &graph, Method::Lrc, &cfg, &Pool::new(4)).unwrap();

    let layer = "blk0.wq";
    let wt = arts.weights.get(layer).unwrap();
    let w = Mat::from_f32(wt.shape[0], wt.shape[1], &wt.data);
    let st = &calib.stats[&activation_source(layer)];
    let direct = lrc::lrc::lrc(&w, st, graph.ranks[layer], &cfg).unwrap();

    let rep = report.layers.iter().find(|l| l.layer == layer).unwrap();
    assert_eq!(rep.objective.to_bits(), direct.objective.to_bits());
    let wq = bundle.get(&format!("{layer}.wq")).unwrap();
    assert_eq!(wq.data, direct.w_hat.to_f32());
}

#[test]
fn persistent_pool_reused_across_runs_stays_byte_identical() {
    let _guard = mode_lock();
    // the persistent board carries state (epoch counter, parked workers)
    // between calls — reusing ONE pool for repeated quantize_model runs
    // must keep producing byte-identical bundles, and must match a pool
    // built fresh for each run
    let (arts, calib, graph) = synthetic_model();
    let cfg = QuantConfig::default();
    let pool = Pool::new(4);
    let (b0, r0) = quantize_model_with_pool(
        &arts, &calib, &graph, Method::Lrc, &cfg, &pool).unwrap();
    for run in 0..3 {
        let (b, r) = quantize_model_with_pool(
            &arts, &calib, &graph, Method::Lrc, &cfg, &pool).unwrap();
        assert_eq!(b0.order, b.order, "run {run}");
        for name in &b0.order {
            assert_eq!(b0.get(name).unwrap().data, b.get(name).unwrap().data,
                       "{name} differs on reused pool, run {run}");
        }
        for (a, b) in r0.layers.iter().zip(&r.layers) {
            assert_eq!(a.objective.to_bits(), b.objective.to_bits(),
                       "{} run {run}", a.layer);
        }
    }
    let fresh = Pool::new(4);
    let (bf, _) = quantize_model_with_pool(
        &arts, &calib, &graph, Method::Lrc, &cfg, &fresh).unwrap();
    for name in &b0.order {
        assert_eq!(b0.get(name).unwrap().data, bf.get(name).unwrap().data,
                   "{name}: reused pool differs from fresh pool");
    }
}

#[test]
fn pool_drop_and_rebuild_cycles_do_not_wedge() {
    let _guard = mode_lock();
    // build → use → drop must join the parked workers every cycle; a
    // leaked worker or wedged join would hang this test (the harness
    // timeout is the assertion), and each rebuilt pool must still
    // produce the reference results
    let (arts, calib, graph) = synthetic_model();
    let cfg = QuantConfig::default();
    let (b0, _) = quantize_model_with_pool(
        &arts, &calib, &graph, Method::Quarot, &cfg, &Pool::new(1)).unwrap();
    for cycle in 0..4 {
        let pool = Pool::new(3);
        let (b, _) = quantize_model_with_pool(
            &arts, &calib, &graph, Method::Quarot, &cfg, &pool).unwrap();
        for name in &b0.order {
            assert_eq!(b0.get(name).unwrap().data, b.get(name).unwrap().data,
                       "{name} cycle {cycle}");
        }
        drop(pool);
    }
    // scoped handles share no workers and may outlive their parent
    let parent = Pool::new(4);
    let scoped = parent.scoped();
    drop(parent);
    let (bs, _) = quantize_model_with_pool(
        &arts, &calib, &graph, Method::Quarot, &cfg, &scoped).unwrap();
    for name in &b0.order {
        assert_eq!(b0.get(name).unwrap().data, bs.get(name).unwrap().data,
                   "{name} via scoped handle");
    }
}

#[test]
fn quantize_model_byte_identical_across_simd_backends() {
    let _guard = mode_lock();
    // the SIMD dispatch must be observationally invisible end to end:
    // the same model quantized under every available backend produces
    // byte-identical bundles and reports.  (The backend override is
    // process-global; concurrent tests flipping it are harmless for
    // exactly the property asserted here.)
    use lrc::linalg::simd;
    let (arts, calib, graph) = synthetic_model();
    let cfg = QuantConfig::default();
    simd::set_backend(Some(simd::Backend::Scalar)).unwrap();
    let (b0, r0) = quantize_model_with_pool(
        &arts, &calib, &graph, Method::Lrc, &cfg, &Pool::new(4)).unwrap();
    for be in simd::available_backends() {
        simd::set_backend(Some(be)).unwrap();
        let (b, r) = quantize_model_with_pool(
            &arts, &calib, &graph, Method::Lrc, &cfg, &Pool::new(4)).unwrap();
        assert_eq!(b0.order, b.order, "backend {}", be.name());
        for name in &b0.order {
            assert_eq!(b0.get(name).unwrap().data, b.get(name).unwrap().data,
                       "{name} differs on backend {}", be.name());
        }
        for (x, y) in r0.layers.iter().zip(&r.layers) {
            assert_eq!(x.objective.to_bits(), y.objective.to_bits(),
                       "{} objective differs on backend {}", x.layer,
                       be.name());
        }
    }
    simd::set_backend(None).unwrap();
}

#[test]
fn quarot_reports_the_rank_actually_used() {
    // regression: QuaRot solves at rank 0 whatever the graph's rank
    // layout says, and its Table-1 rows were labeled with the graph rank
    let (arts, calib, graph) = synthetic_model();
    let cfg = QuantConfig::default();
    let (bundle, report) = quantize_model_with_pool(
        &arts, &calib, &graph, Method::Quarot, &cfg, &Pool::new(2)).unwrap();
    for l in &report.layers {
        assert_eq!(l.rank, 0,
                   "{}: QuaRot row labeled rank {} (graph says {})",
                   l.layer, l.rank, graph.ranks[&l.layer]);
        // and indeed no low-rank factors were emitted
        assert!(bundle.get(&format!("{}.u", l.layer)).is_err());
    }
    assert_eq!(report.lowrank_params, 0);
    // the corrected methods still report the graph rank they solved at
    let (_, lrc_report) = quantize_model_with_pool(
        &arts, &calib, &graph, Method::Lrc, &cfg, &Pool::new(2)).unwrap();
    for l in &lrc_report.layers {
        assert_eq!(l.rank, graph.ranks[&l.layer], "{}", l.layer);
    }
}

#[test]
fn report_layer_order_is_canonical() {
    // results come back in quantized_layer_names order regardless of
    // which worker finished first
    let (arts, calib, graph) = synthetic_model();
    let (_, report) = quantize_model_with_pool(
        &arts, &calib, &graph, Method::Quarot, &QuantConfig::default(),
        &Pool::new(8)).unwrap();
    let expect = quantized_layer_names(&arts.info);
    let got: Vec<String> =
        report.layers.iter().map(|l| l.layer.clone()).collect();
    assert_eq!(got, expect);
}

#[test]
fn fma_mode_bundles_byte_identical_across_thread_counts() {
    // the FMA fast path keeps the end-to-end determinism contract: with
    // LRC_FMA forced on, quantize_model produces byte-identical bundles
    // at threads {1, 4} — and those bundles genuinely differ from the
    // default mul-then-add mode's (the fused program is really running).
    use lrc::linalg::simd;
    let _guard = mode_lock();
    let (arts, calib, graph) = synthetic_model();
    let cfg = QuantConfig::default();

    simd::set_fma(Some(false));
    let (_, r_plain) = quantize_model_with_pool(
        &arts, &calib, &graph, Method::Lrc, &cfg, &Pool::new(4)).unwrap();

    simd::set_fma(Some(true));
    let (b1, r1) = quantize_model_with_pool(
        &arts, &calib, &graph, Method::Lrc, &cfg, &Pool::new(1)).unwrap();
    let (b4, r4) = quantize_model_with_pool(
        &arts, &calib, &graph, Method::Lrc, &cfg, &Pool::new(4)).unwrap();
    simd::set_fma(None);

    assert_eq!(b1.order, b4.order);
    for name in &b1.order {
        let x = b1.get(name).unwrap();
        let y = b4.get(name).unwrap();
        assert_eq!(x.shape, y.shape, "{name}");
        assert_eq!(x.data, y.data, "{name}: FMA bundle differs at t=4");
    }
    for (a, b) in r1.layers.iter().zip(&r4.layers) {
        assert_eq!(a.objective.to_bits(), b.objective.to_bits(),
                   "{}: FMA objective differs across pools", a.layer);
    }
    // the fused program must actually be reaching the solvers: observe
    // the mode difference on the f64 objectives (bundle tensors are f32,
    // whose ~6e-8 relative resolution would absorb the ulp-level f64
    // divergence on this tiny model and make a bundle-bytes comparison
    // vacuous)
    let any_diff = r_plain.layers.iter().zip(&r1.layers)
        .any(|(a, b)| a.objective.to_bits() != b.objective.to_bits());
    assert!(any_diff,
            "FMA-mode objectives are bit-identical to the default mode's \
             on every layer — the fused program is not reaching the \
             solvers");
}
