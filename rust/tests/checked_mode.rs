//! Exercises the `checked` feature's runtime race detector and pool
//! protocol assertions through the public API.  Compiled only with
//! `--features checked` — the whole file is a no-op otherwise, so the
//! default tier-1 run is untouched.
#![cfg(feature = "checked")]

use lrc::linalg::workspace::SharedSlice;
use lrc::par::Pool;

/// The pool's protocol assertions (claim budget, epoch generations,
/// active-count) must all hold across many epochs at both a serial and
/// a contended thread count — this drives the exact paths the checked
/// assertions instrument.
#[test]
fn pool_protocol_assertions_hold_under_checked() {
    for threads in [1usize, 4] {
        let pool = Pool::new(threads);
        for round in 0..50usize {
            // items spans inline (1), partial (2) and full (> threads)
            for items in [1usize, 2, 7] {
                let got = pool.map(items, |i| i * 31 + round);
                let want: Vec<usize> = (0..items).map(|i| i * 31 + round).collect();
                assert_eq!(got, want);
            }
        }
        // nested dispatch runs inline under the re-entrancy guard and
        // must not trip the board assertions either
        let got = pool.map(4, |i| {
            let inner = Pool::current().map(3, move |j| i * 10 + j);
            inner.iter().sum::<usize>()
        });
        assert_eq!(got.len(), 4);
    }
}

/// A panicking work item must propagate without corrupting the board:
/// the same pool keeps serving afterwards with all checked assertions
/// still armed.
#[test]
fn pool_survives_panics_with_assertions_armed() {
    for threads in [1usize, 4] {
        let pool = Pool::new(threads);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.for_indices(6, |i| {
                if i == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "worker panic must propagate");
        // the board must be clean: the next epoch behaves normally
        assert_eq!(pool.map(5, |i| i + 1), vec![1, 2, 3, 4, 5]);
    }
}

/// Disjoint SharedSlice claims from real pool workers pass under the
/// detector; this is the legitimate parallel-write pattern the arena
/// code uses.
#[test]
fn disjoint_parallel_writes_pass_the_detector() {
    let mut buf = vec![0.0f64; 64];
    let n = buf.len();
    let shared = SharedSlice::new(&mut buf);
    let pool = Pool::new(4);
    pool.for_indices(4, |i| {
        let chunk = n / 4;
        // SAFETY: quarter `i` is written only by worker `i` — the ranges
        // are pairwise disjoint by construction (checked mode verifies).
        let dst = unsafe { shared.range(i * chunk, (i + 1) * chunk) };
        for (k, v) in dst.iter_mut().enumerate() {
            *v = (i * chunk + k) as f64;
        }
    });
    for (k, v) in buf.iter().enumerate() {
        assert_eq!(*v, k as f64);
    }
}

/// A seeded overlap must panic with the detector's message — this is
/// the bug class the checked build exists to catch.
#[test]
#[should_panic(expected = "overlapping SharedSlice claims")]
fn seeded_overlap_is_caught() {
    let mut buf = vec![0.0f64; 16];
    let shared = SharedSlice::new(&mut buf);
    // SAFETY: intentionally overlapping to drive the detector; the
    // second claim must panic before any aliased write happens.
    let _a = unsafe { shared.range(0, 10) };
    let _b = unsafe { shared.range(8, 12) };
}
