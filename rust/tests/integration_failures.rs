//! Failure injection: corrupted artifacts must fail loudly and precisely,
//! never silently misalign (the positional param contract makes silent
//! corruption the worst failure mode of this architecture).

use lrc::runtime::{Engine, ModelArtifacts, TensorBundle};
use lrc::util::Json;
use std::path::PathBuf;

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("lrc_fail_{name}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn truncated_bin_rejected() {
    let d = tmpdir("trunc");
    let mut b = TensorBundle::default();
    b.insert("w", vec![4, 4], vec![0.5; 16]);
    b.write(&d, &[]).unwrap();
    // truncate the bin
    let bin = d.join("weights.bin");
    let bytes = std::fs::read(&bin).unwrap();
    std::fs::write(&bin, &bytes[..bytes.len() - 8]).unwrap();
    let err = TensorBundle::load(&d).unwrap_err().to_string();
    assert!(err.contains("out of range"), "{err}");
}

#[test]
fn wrong_format_rejected() {
    let d = tmpdir("fmt");
    std::fs::write(d.join("manifest.json"),
                   r#"{"format":"other-v9","tensors":[]}"#).unwrap();
    std::fs::write(d.join("weights.bin"), b"").unwrap();
    let err = TensorBundle::load(&d).unwrap_err().to_string();
    assert!(err.contains("unsupported bundle format"), "{err}");
}

#[test]
fn malformed_manifest_rejected() {
    let d = tmpdir("json");
    std::fs::write(d.join("manifest.json"), "{not json").unwrap();
    assert!(TensorBundle::load(&d).is_err());
}

#[test]
fn missing_quant_bundle_is_explicit() {
    // a quant graph session without a quant bundle must explain itself
    let art = lrc::artifacts_dir();
    let mdir = art.join("models/nano");
    if !mdir.is_dir() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let engine = Engine::cpu().unwrap();
    let arts = ModelArtifacts::load(&mdir).unwrap();
    let err = match engine.session(&arts, "fwd_w4a4_r10_b8", None) {
        Ok(_) => panic!("session should have failed"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("needs a quant bundle"), "{err}");
}

#[test]
fn unknown_graph_is_explicit() {
    let art = lrc::artifacts_dir();
    let mdir = art.join("models/nano");
    if !mdir.is_dir() {
        return;
    }
    let arts = ModelArtifacts::load(&mdir).unwrap();
    let err = arts.graph("fwd_nonexistent").unwrap_err().to_string();
    assert!(err.contains("fwd_nonexistent"), "{err}");
}

#[test]
fn quant_bundle_with_missing_tensor_is_explicit() {
    let art = lrc::artifacts_dir();
    let mdir = art.join("models/nano");
    if !mdir.is_dir() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let arts = ModelArtifacts::load(&mdir).unwrap();
    // bundle with only one tensor: session must name the missing one
    let mut b = TensorBundle::default();
    b.insert("blk0.wq.wq", vec![1], vec![0.0]);
    let err = match engine.session(&arts, "fwd_w4a4_r10_b8", Some(&b)) {
        Ok(_) => panic!("session should have failed"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("missing tensor"), "{err}");
}

#[test]
fn wrong_token_count_rejected() {
    let art = lrc::artifacts_dir();
    let mdir = art.join("models/nano");
    if !mdir.is_dir() {
        return;
    }
    let engine = Engine::cpu().unwrap();
    let arts = ModelArtifacts::load(&mdir).unwrap();
    let session = engine.session(&arts, "fwd_fp_b1", None).unwrap();
    let err = session.run(&[1, 2, 3]).unwrap_err().to_string();
    assert!(err.contains("token block"), "{err}");
}

#[test]
fn json_parser_fuzz_does_not_panic() {
    // byte-mutation fuzz over a valid manifest: parser must return
    // Ok or Err, never panic
    let base = r#"{"format":"lrc-bundle-v1","tensors":[{"name":"a","shape":[2,3],"offset":0}],"x":[1,2.5,-3e4,true,null,"s\n"]}"#;
    let mut rng = lrc::rng::Rng::new(99);
    for _ in 0..2000 {
        let mut bytes = base.as_bytes().to_vec();
        let n_mut = 1 + rng.below(4);
        for _ in 0..n_mut {
            let i = rng.below(bytes.len());
            bytes[i] = (rng.next_u64() & 0x7f) as u8;
        }
        if let Ok(s) = String::from_utf8(bytes) {
            let _ = Json::parse(&s); // must not panic
        }
    }
}
