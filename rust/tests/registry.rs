//! The content-addressed artifact registry, end to end (engine-free):
//!
//!   * verified get/publish: absent, corrupt and stale-code-version
//!     objects all read as misses (never as errors, never as answers);
//!   * a warm `quantize_model_cached` re-run is a registry hit with
//!     **zero** quantization compute — proven by handing the warm call
//!     empty calibration stats, which any compute path would trip over;
//!   * a sweep grid dispatched to {1, 2, 3} `sweep-worker` loops over
//!     the wire protocol produces a report **byte-identical** to the
//!     single-box run;
//!   * pre-registry `cells/<key>.json` fragment dirs migrate into the
//!     registry on first read and are served from it afterwards.
//!
//! Threads are used freely here: this tree is not under the
//! `lrc analyze` concurrency fences, which bind `rust/src` only.

use std::net::TcpListener;
use std::path::PathBuf;

use lrc::par::Pool;
use lrc::pipeline::{cell_graph, quantize_model_cached, report_to_json,
                    CalibStats, Method};
use lrc::quant::{QuantConfig, Quantizer};
use lrc::registry::service::ServeOpts;
use lrc::registry::{list_objects, FsRegistry, ObjectKey, Registry};
use lrc::sweep::{run_grid, serve_grid_distributed, synthetic_artifacts,
                 synthetic_calib, worker_loop, SweepAxes, SweepStore};
use lrc::util::Json;

const SEED: u64 = 2024;
const TAG: &str = "synthetic-seed2024";

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("lrc_registry_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn test_cfg() -> QuantConfig {
    QuantConfig {
        w_bits: 4,
        a_bits: Some(4),
        a_group: None,
        quantizer: Quantizer::Gptq,
        rank_pct: 0.10,
        iters: 1,
    }
}

#[test]
fn get_publish_corrupt_and_stale_code_version() {
    let dir = tmp_dir("basics");
    let reg = Registry::local(&dir);
    let key = ObjectKey::new("sweep-cell", "synthetic", "lrc", &test_cfg(),
                             7, "test-run");

    // absent object: a plain miss
    assert!(reg.get(&key).unwrap().is_none());
    assert_eq!(reg.counters().misses, 1);

    // publish + verified read-back, payload and blob bit-exact
    let payload = Json::obj(vec![("answer", Json::num(42.0))]);
    let digest = reg.publish(&key, &payload, Some(b"\x00\x01\xfe")).unwrap();
    let obj = reg.get(&key).unwrap().expect("published object must read");
    assert_eq!(obj.payload().unwrap(), &payload);
    assert_eq!(obj.blob.as_deref(), Some(&b"\x00\x01\xfe"[..]));
    assert_eq!(reg.counters().hits, 1);

    // a flipped bit in the blob fails the checksum: counted corrupt,
    // read as a miss
    let blob_file = FsRegistry::new(&dir).blob_file(&digest);
    let mut blob = std::fs::read(&blob_file).unwrap();
    blob[1] ^= 0x80;
    std::fs::write(&blob_file, &blob).unwrap();
    assert!(reg.get(&key).unwrap().is_none());
    assert_eq!(reg.counters().corrupt, 1);

    // garbage over the meta document: the same
    std::fs::write(FsRegistry::new(&dir).object_file(&digest),
                   "not a registry object").unwrap();
    assert!(reg.get(&key).unwrap().is_none());
    assert_eq!(reg.counters().corrupt, 2);

    // republish heals both files
    reg.publish(&key, &payload, Some(b"\x00\x01\xfe")).unwrap();
    assert!(reg.get(&key).unwrap().is_some());

    // a stale code version is a *different address*: bumping the code
    // field orphans every old object instead of serving it
    let mut stale = key.clone();
    stale.code = "lrc-quant-v0".to_string();
    assert_ne!(stale.digest(), key.digest());
    assert!(reg.get(&stale).unwrap().is_none());

    // so is any other key component
    let other_seed = ObjectKey::new("sweep-cell", "synthetic", "lrc",
                                    &test_cfg(), 8, "test-run");
    assert_ne!(other_seed.digest(), key.digest());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn warm_quantize_rerun_is_a_hit_with_zero_compute() {
    let arts = synthetic_artifacts(SEED);
    let calib = synthetic_calib(&arts, SEED, &[None]);
    let graph = cell_graph(&arts, 10, None, false, 8).unwrap();
    let cfg = test_cfg();
    let pool = Pool::new(2);
    let dir = tmp_dir("warm");
    let reg = Registry::local(&dir);
    let key = ObjectKey::new("quant-bundle", "synthetic", "lrc", &cfg, SEED,
                             "synthetic-calib");

    // cold: computes and publishes
    let (bundle, report, hit) = quantize_model_cached(
        &arts, &calib[&None], &graph, Method::Lrc, &cfg, &pool, &reg, &key)
        .unwrap();
    assert!(!hit);
    assert_eq!(reg.counters().published, 1);
    assert_eq!(reg.counters().misses, 1);

    // warm: the stats are EMPTY — any code path that tried to quantize
    // would fail on the first layer lookup, so a clean return here *is*
    // the zero-compute proof
    let empty = CalibStats { stats: Default::default(), seconds: 0.0 };
    let (cached, cached_report, hit) = quantize_model_cached(
        &arts, &empty, &graph, Method::Lrc, &cfg, &pool, &reg, &key)
        .unwrap();
    assert!(hit, "second run must be served from the registry");
    assert_eq!(reg.counters().hits, 1);
    assert_eq!(reg.counters().published, 1, "a hit publishes nothing");

    // and the cached artifact is bit-exact
    assert_eq!(bundle.order, cached.order);
    for name in &bundle.order {
        let (a, b) = (&bundle.tensors[name], &cached.tensors[name]);
        assert_eq!(a.shape, b.shape, "{name}");
        let bits = |t: &[f32]| t.iter().map(|v| v.to_bits())
            .collect::<Vec<u32>>();
        assert_eq!(bits(&a.data), bits(&b.data), "tensor {name} not \
                    bit-exact through the registry");
    }
    assert_eq!(report_to_json(&report).to_string(),
               report_to_json(&cached_report).to_string());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn distributed_sweep_report_is_byte_identical_to_single_box() {
    let axes = SweepAxes::fast();
    let arts = synthetic_artifacts(SEED);
    let calib = synthetic_calib(&arts, SEED, &axes.groups);
    let single = run_grid(&arts, &calib, &axes, TAG, None, false,
                          &Pool::new(2), None).unwrap();

    for n_workers in [1usize, 2, 3] {
        let dir = tmp_dir(&format!("dist{n_workers}"));
        let store = SweepStore::open(&dir.join("registry"), None, SEED);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();

        let d_arts = synthetic_artifacts(SEED);
        let d_axes = axes.clone();
        let dispatcher = std::thread::spawn(move || {
            serve_grid_distributed(&d_arts, &d_axes, TAG, &store, false,
                                   &listener, ServeOpts::default(), |_| {})
        });
        let workers: Vec<_> = (0..n_workers).map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let pool = Pool::new(1);
                worker_loop(&addr, &format!("w{i}"), &pool, |_| {})
            })
        }).collect();

        let outcome = dispatcher.join().unwrap().unwrap();
        let computed_by_workers: usize = workers.into_iter()
            .map(|w| w.join().unwrap().unwrap().computed)
            .sum();
        assert_eq!(outcome.report_json, single.report_json,
                   "distributed report differs at {n_workers} worker(s)");
        assert_eq!(outcome.markdown, single.markdown);
        assert_eq!(outcome.computed, axes.cells().len());
        assert_eq!(outcome.resumed, 0);
        assert_eq!(computed_by_workers, axes.cells().len(),
                   "every cell is computed exactly once across workers");
        assert!(outcome.violations.is_empty(), "{:?}", outcome.violations);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn distributed_resume_serves_finished_cells_without_recompute() {
    let axes = SweepAxes::fast();
    let arts = synthetic_artifacts(SEED);
    let calib = synthetic_calib(&arts, SEED, &axes.groups);
    let dir = tmp_dir("dist_resume");

    // single-box run fills the registry...
    let store = SweepStore::open(&dir.join("registry"), None, SEED);
    let full = run_grid(&arts, &calib, &axes, TAG, Some(&store), false,
                        &Pool::new(2), None).unwrap();

    // ...then a dispatcher over the same registry has nothing left to
    // hand out: the worker is told "done" and computes zero cells
    let store = SweepStore::open(&dir.join("registry"), None, SEED);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let d_arts = synthetic_artifacts(SEED);
    let d_axes = axes.clone();
    let dispatcher = std::thread::spawn(move || {
        serve_grid_distributed(&d_arts, &d_axes, TAG, &store, true,
                               &listener, ServeOpts::default(), |_| {})
    });
    let worker = std::thread::spawn(move || {
        let pool = Pool::new(1);
        worker_loop(&addr, "w0", &pool, |_| {})
    });
    let outcome = dispatcher.join().unwrap().unwrap();
    assert_eq!(worker.join().unwrap().unwrap().computed, 0,
               "a fully-resumed grid must not recompute on workers");
    assert_eq!(outcome.computed, 0);
    assert_eq!(outcome.resumed, axes.cells().len());
    assert_eq!(outcome.report_json, full.report_json);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn legacy_fragment_dirs_migrate_into_the_registry() {
    let axes = SweepAxes::fast();
    let arts = synthetic_artifacts(SEED);
    let calib = synthetic_calib(&arts, SEED, &axes.groups);
    let fresh = run_grid(&arts, &calib, &axes, TAG, None, false,
                         &Pool::new(2), None).unwrap();

    // handcraft a pre-registry layout: one <cells>/<key>.json per record
    let dir = tmp_dir("migrate");
    let cells_dir = dir.join("cells");
    std::fs::create_dir_all(&cells_dir).unwrap();
    for rec in &fresh.records {
        let id = rec.get("key").unwrap().as_str().unwrap();
        std::fs::write(cells_dir.join(format!("{id}.json")),
                       rec.to_string()).unwrap();
    }

    // a store pointed at the legacy dir resumes every cell and adopts
    // each fragment into the registry as it reads it
    let store = SweepStore::open(&dir.join("registry"), Some(&cells_dir),
                                 SEED);
    let resumed = run_grid(&arts, &calib, &axes, TAG, Some(&store), true,
                           &Pool::new(2), None).unwrap();
    assert_eq!(resumed.computed, 0, "fragments must satisfy every cell");
    assert_eq!(resumed.resumed, axes.cells().len());
    assert_eq!(resumed.report_json, fresh.report_json);
    assert_eq!(store.counters().published as usize, axes.cells().len(),
               "every adopted fragment is published under its content key");

    // after migration the registry alone (no legacy dir) serves the grid
    std::fs::remove_dir_all(&cells_dir).unwrap();
    let store = SweepStore::open(&dir.join("registry"), None, SEED);
    let again = run_grid(&arts, &calib, &axes, TAG, Some(&store), true,
                         &Pool::new(2), None).unwrap();
    assert_eq!(again.computed, 0);
    assert_eq!(again.resumed, axes.cells().len());
    assert_eq!(again.report_json, fresh.report_json);
    assert_eq!(store.counters().hits as usize, axes.cells().len());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn torn_objects_in_both_orderings_read_as_counted_misses() {
    let dir = tmp_dir("torn");
    let reg = Registry::local(&dir);
    let fs = FsRegistry::new(&dir);
    let key = ObjectKey::new("sweep-cell", "synthetic", "lrc", &test_cfg(),
                             11, "torn-run");
    let payload = Json::obj(vec![("v", Json::num(1.0))]);

    // ordering 1: blob present, meta missing — the commit point (the
    // meta rename) never happened, so the orphan blob is invisible and
    // reads as a *plain* miss, not a corruption
    let digest = reg.publish(&key, &payload, Some(b"blobdata")).unwrap();
    std::fs::remove_file(fs.object_file(&digest)).unwrap();
    assert!(reg.get(&key).unwrap().is_none(),
            "an orphan blob must never surface");
    assert_eq!(reg.counters().misses, 1);
    assert_eq!(reg.counters().corrupt, 0,
               "a missing meta is absence, not corruption");

    // ordering 2: meta present, blob missing — the meta promises a blob
    // that isn't there, which is a *counted* corruption (and still a
    // miss, never an error or a blobless answer)
    reg.publish(&key, &payload, Some(b"blobdata")).unwrap();
    std::fs::remove_file(fs.blob_file(&digest)).unwrap();
    assert!(reg.get(&key).unwrap().is_none(),
            "a meta without its blob must read as a miss");
    assert_eq!(reg.counters().corrupt, 1,
               "a dangling meta is a counted corruption");
    assert_eq!(reg.counters().misses, 2);

    // a republish over either tear heals the object completely
    reg.publish(&key, &payload, Some(b"blobdata")).unwrap();
    let obj = reg.get(&key).unwrap().expect("healed object must read");
    assert_eq!(obj.payload().unwrap(), &payload);
    assert_eq!(obj.blob.as_deref(), Some(&b"blobdata"[..]));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn registry_ls_classifies_ok_corrupt_and_orphan_objects() {
    let dir = tmp_dir("ls");
    let reg = Registry::local(&dir);
    let fs = FsRegistry::new(&dir);
    let payload = Json::obj(vec![("v", Json::num(3.0))]);

    // an empty (even absent) store lists cleanly
    assert!(list_objects(&dir).unwrap().is_empty());

    let k_ok = ObjectKey::new("sweep-cell", "synthetic", "lrc", &test_cfg(),
                              1, "ls-run");
    let k_bad = ObjectKey::new("sweep-cell", "synthetic", "rtn", &test_cfg(),
                               2, "ls-run");
    let k_orphan = ObjectKey::new("quant-bundle", "synthetic", "svd",
                                  &test_cfg(), 3, "ls-run");
    let d_ok = reg.publish(&k_ok, &payload, Some(b"good")).unwrap();
    let d_bad = reg.publish(&k_bad, &payload, None).unwrap();
    let d_orphan = reg.publish(&k_orphan, &payload, Some(b"orphan")).unwrap();
    // corrupt the second meta, orphan the third's blob
    std::fs::write(fs.object_file(&d_bad), "garbage").unwrap();
    std::fs::remove_file(fs.object_file(&d_orphan)).unwrap();

    let rows = list_objects(&dir).unwrap();
    assert_eq!(rows.len(), 3);
    let by_digest = |d: &str| rows.iter().find(|r| r.digest == d).unwrap();
    let ok = by_digest(&d_ok);
    assert_eq!((ok.status, ok.kind.as_str(), ok.method.as_str(),
                ok.blob_len),
               ("ok", "sweep-cell", "lrc", Some(4)));
    assert_eq!(by_digest(&d_bad).status, "corrupt");
    let orphan = by_digest(&d_orphan);
    assert_eq!((orphan.status, orphan.blob_len), ("orphan-blob", Some(6)));
    let _ = std::fs::remove_dir_all(&dir);
}
