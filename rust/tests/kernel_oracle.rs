//! The kernel oracle: a randomized property harness locking the blocked-k
//! GEMM / Gram kernels and their parallel dispatch to an independently
//! written naive reference — **bit-identical** (`==` on f64, never an
//! epsilon), at every pool size × every SIMD backend the host can run.
//!
//! This is the enforcement arm of the canonical-scalar-program contract
//! (`linalg::kernels`): every output element is a single accumulator
//! advanced in strictly ascending k, so blocking, register tiling, SIMD
//! lanes, row chunking and thread count must all be observationally
//! invisible.  The sweep covers ~50 shape/seed combos including the
//! degenerate and ragged cases (1×1, 1×k, odd rows greater than the
//! thread count, rows not a multiple of the chunk/tile/lane sizes, dims
//! straddling the KC/NC panels).  The **widened** legs extend the same
//! contract to the f32 data path: the f32 GEMM against its naive f32
//! reference, and the fused dequant-GEMM ([`lrc::quant::QuantizedLinear`])
//! against the unpack-then-matmul-then-correction reference across
//! bits × scale-group × backend × thread-count.
//!
//! Backends are forced through the same override the CLI's `--simd` flag
//! installs (the process-wide knob `LRC_SIMD` seeds; the CI matrix also
//! runs this whole suite under `LRC_SIMD ∈ {scalar, auto}`).  The
//! override is process-global and tests in this binary run concurrently,
//! which is *safe by the very contract under test*: every backend
//! produces identical bits, so a mid-test backend flip can never change
//! an assertion's outcome.
//!
//! The **FMA mode** (`--fma` / `LRC_FMA=1`) is different: it changes the
//! canonical program, so its oracle is a **lockstep FMA reference** (the
//! same naive loops with `f64::mul_add`).  The naive references below
//! select fused vs mul-then-add by the *live* mode, which keeps every
//! test here valid under the CI matrix's `LRC_FMA=1` leg; tests that
//! *force* the mode serialize on [`sweep_lock`] with every other test
//! in this binary that computes a reference and a kernel result in two
//! steps (unlike backend flips, a mid-test FMA flip WOULD change bits).

use lrc::linalg::{simd, Mat};
use lrc::par::Pool;
use lrc::rng::Rng;

/// Naive C = A·Bᵀ: the textbook triple loop, single accumulator,
/// ascending k — fused when the FMA mode is live (the lockstep
/// reference), mul-then-add otherwise.  Written against `Mat` indexing
/// only — it shares no code with the production kernel.
fn naive_matmul_nt(a: &Mat, bt: &Mat) -> Mat {
    let fma = simd::fma_active();
    assert_eq!(a.cols, bt.cols);
    let mut out = Mat::zeros(a.rows, bt.rows);
    for i in 0..a.rows {
        for j in 0..bt.rows {
            let mut s = 0.0_f64;
            for k in 0..a.cols {
                if fma {
                    s = a[(i, k)].mul_add(bt[(j, k)], s);
                } else {
                    s += a[(i, k)] * bt[(j, k)];
                }
            }
            out[(i, j)] = s;
        }
    }
    out
}

/// Naive AᵀA (sum over rows of A, ascending; mode-matched like
/// [`naive_matmul_nt`]).
fn naive_gram_t(a: &Mat) -> Mat {
    let fma = simd::fma_active();
    let n = a.cols;
    let mut out = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0_f64;
            for r in 0..a.rows {
                if fma {
                    s = a[(r, i)].mul_add(a[(r, j)], s);
                } else {
                    s += a[(r, i)] * a[(r, j)];
                }
            }
            out[(i, j)] = s;
        }
    }
    out
}

/// Naive AAᵀ (sum over columns of A, ascending; mode-matched).
fn naive_gram_n(a: &Mat) -> Mat {
    let fma = simd::fma_active();
    let m = a.rows;
    let mut out = Mat::zeros(m, m);
    for i in 0..m {
        for j in 0..m {
            let mut s = 0.0_f64;
            for k in 0..a.cols {
                if fma {
                    s = a[(i, k)].mul_add(a[(j, k)], s);
                } else {
                    s += a[(i, k)] * a[(j, k)];
                }
            }
            out[(i, j)] = s;
        }
    }
    out
}

/// The thread counts the contract is checked at (1 < chunk, prime,
/// power-of-two > typical CI core count).
fn pools() -> Vec<Pool> {
    [1usize, 2, 3, 8].into_iter().map(Pool::new).collect()
}

/// The binary-wide serialization lock.  Backend sweeps hold it so a
/// concurrent sweep can't silently degrade per-backend *coverage*; the
/// FMA-forcing test and every reference-then-kernel two-step test hold
/// it because a mid-test FMA flip would change bits, not just coverage.
fn sweep_lock() -> std::sync::MutexGuard<'static, ()> {
    static SWEEP: std::sync::Mutex<()> = std::sync::Mutex::new(());
    SWEEP.lock().unwrap_or_else(|e| e.into_inner())
}

/// Run `body` once per SIMD backend this host supports, forcing each via
/// the process-wide backend override, then restore auto resolution.
fn for_each_backend(body: impl Fn(simd::Backend)) {
    let _guard = sweep_lock();
    for be in simd::available_backends() {
        simd::set_backend(Some(be)).unwrap();
        body(be);
    }
    simd::set_backend(None).unwrap();
}

/// Deterministic (m, k, n) sweep: hand-picked boundary shapes + seeded
/// random fill-in, ≥ 50 combos total.
fn gemm_shapes() -> Vec<(usize, usize, usize)> {
    let mut shapes = vec![
        // degenerate
        (1, 1, 1),
        (1, 9, 1),
        (1, 1, 7),
        (2, 1, 2),
        // odd rows > threads, tiny cols
        (11, 3, 2),
        (13, 5, 3),
        // around the MR/NR register tile (4)
        (3, 6, 5),
        (4, 4, 4),
        (5, 5, 5),
        // around PAR_ROW_CHUNK (16): rows % chunk != 0 on both sides
        (15, 12, 11),
        (16, 8, 16),
        (17, 9, 10),
        (33, 7, 31),
        // around the KC k-panel (256)
        (6, 255, 5),
        (5, 256, 6),
        (7, 257, 4),
        // around the NC column panel (64)
        (9, 10, 63),
        (8, 12, 64),
        (10, 11, 65),
        // bigger ragged shape crossing several chunks
        (65, 33, 66),
        // large enough to cross the PAR_MIN_WORK auto-parallel threshold
        // (the small shapes above take the serial path by design), with
        // ragged row counts so the last for_each chunk is partial
        (128, 128, 128),
        (65, 256, 65),
        (33, 300, 129),
        (17, 1024, 61),
        (100, 110, 101),
    ];
    let mut rng = Rng::new(0xC0FFEE);
    while shapes.len() < 50 {
        shapes.push((1 + rng.below(70), 1 + rng.below(70), 1 + rng.below(70)));
    }
    shapes
}

#[test]
fn matmul_nt_bit_identical_to_naive_at_every_thread_count_and_backend() {
    let pools = pools();
    for_each_backend(|be| {
        for (si, &(m, k, n)) in gemm_shapes().iter().enumerate() {
            let a = Mat::random_normal(&mut Rng::new(1_000 + si as u64), m, k);
            let bt = Mat::random_normal(&mut Rng::new(2_000 + si as u64), n, k);
            let reference = naive_matmul_nt(&a, &bt);
            assert_eq!(reference, a.matmul_nt(&bt),
                       "serial {m}x{k}·{n}ᵀ [{}]", be.name());
            for pool in &pools {
                let t = pool.threads();
                assert_eq!(reference, a.par_matmul_nt(&bt, pool),
                           "{m}x{k}·{n}ᵀ threads={t} [{}]", be.name());
                assert_eq!(reference, a.par_matmul_nt(&bt, &pool.scoped()),
                           "{m}x{k}·{n}ᵀ scoped threads={t} [{}]", be.name());
            }
        }
    });
}

#[test]
fn matmul_bit_identical_to_naive_at_every_thread_count_and_backend() {
    let pools = pools();
    for_each_backend(|be| {
        for (si, &(m, k, n)) in [(1usize, 1usize, 1usize), (1, 8, 3),
                                 (7, 5, 9), (17, 16, 15), (40, 70, 33),
                                 (65, 17, 64)]
            .iter()
            .enumerate()
        {
            let a = Mat::random_normal(&mut Rng::new(3_000 + si as u64), m, k);
            let b = Mat::random_normal(&mut Rng::new(4_000 + si as u64), k, n);
            let reference = naive_matmul_nt(&a, &b.transpose());
            assert_eq!(reference, a.matmul(&b),
                       "serial {m}x{k}·{k}x{n} [{}]", be.name());
            for pool in &pools {
                assert_eq!(reference, a.par_matmul(&b, pool),
                           "{m}x{k}·{k}x{n} threads={} [{}]",
                           pool.threads(), be.name());
            }
        }
    });
}

#[test]
fn gram_bit_identical_to_naive_at_every_thread_count() {
    let pools = pools();
    let mut shapes = vec![
        (1usize, 1usize),
        (1, 6),
        (6, 1),
        (3, 4),
        (4, 4),
        (5, 3),
        (15, 7),
        (16, 9),
        (17, 11),
        (63, 5),
        (64, 6),
        (65, 7),
        (40, 70),
        (70, 40),
        (9, 257),
        // past PAR_MIN_WORK so the pooled row-segment path really runs
        (65, 500),
        (129, 130),
    ];
    let mut rng = Rng::new(0xBEEF);
    while shapes.len() < 25 {
        shapes.push((1 + rng.below(60), 1 + rng.below(60)));
    }
    for_each_backend(|be| {
        for (si, &(r, c)) in shapes.iter().enumerate() {
            let a = Mat::random_normal(&mut Rng::new(5_000 + si as u64), r, c);
            let ref_t = naive_gram_t(&a);
            let ref_n = naive_gram_n(&a);
            assert_eq!(ref_t, a.gram_t(),
                       "serial gram_t {r}x{c} [{}]", be.name());
            assert_eq!(ref_n, a.gram_n(),
                       "serial gram_n {r}x{c} [{}]", be.name());
            for pool in &pools {
                let t = pool.threads();
                assert_eq!(ref_t, a.par_gram_t(pool),
                           "gram_t {r}x{c} t={t} [{}]", be.name());
                assert_eq!(ref_n, a.par_gram_n(pool),
                           "gram_n {r}x{c} t={t} [{}]", be.name());
                assert_eq!(ref_t, a.par_gram_t(&pool.scoped()),
                           "gram_t scoped {r}x{c} t={t} [{}]", be.name());
            }
        }
    });
}

#[test]
fn kernels_are_deterministic_across_repeated_dispatch() {
    // same pool object, repeated calls: dynamic scheduling must never
    // leak into the results (the slots are keyed by index, not arrival);
    // shape chosen past PAR_MIN_WORK so the board really dispatches.
    // Holds the sweep lock: the first result is the reference for the
    // repeats, so an FMA flip in between would falsely fail it.
    let _guard = sweep_lock();
    let a = Mat::random_normal(&mut Rng::new(77), 65, 256);
    let bt = Mat::random_normal(&mut Rng::new(78), 66, 256);
    let pool = Pool::new(8);
    let first = a.par_matmul_nt(&bt, &pool);
    for rep in 0..10 {
        assert_eq!(first, a.par_matmul_nt(&bt, &pool), "rep {rep}");
    }
}

/// Naive C = A·Bᵀ in **f32** (flat row-major slices): the independent
/// reference for the widened canonical program — single f32
/// accumulator, ascending k, mode-matched like [`naive_matmul_nt`].
fn naive_matmul_nt_f32(a: &[f32], m: usize, k: usize, bt: &[f32], n: usize)
                       -> Vec<f32> {
    let fma = simd::fma_active();
    let mut out = vec![0.0_f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0_f32;
            for kk in 0..k {
                let (x, y) = (a[i * k + kk], bt[j * k + kk]);
                s = if fma { x.mul_add(y, s) } else { s + x * y };
            }
            out[i * n + j] = s;
        }
    }
    out
}

#[test]
fn matmul_nt_f32_bit_identical_to_naive_on_every_backend() {
    // the widened (f32) canonical program: 2× lane width makes this a
    // distinct dispatch path (nr32 = 16 on AVX2) from the f64 legs
    use lrc::linalg::matmul_nt_f32;
    for_each_backend(|be| {
        for (si, &(m, k, n)) in [(1usize, 1usize, 1usize), (3, 9, 5),
                                 (4, 16, 8), (17, 33, 18), (5, 256, 65),
                                 (19, 257, 15), (40, 300, 97),
                                 // past PAR_MIN_WORK → pooled row chunks
                                 (128, 128, 128)]
            .iter()
            .enumerate()
        {
            let mut rng = Rng::new(40_000 + si as u64);
            let a: Vec<f32> =
                rng.normal_vec(m * k).iter().map(|&v| v as f32).collect();
            let bt: Vec<f32> =
                rng.normal_vec(n * k).iter().map(|&v| v as f32).collect();
            let reference = naive_matmul_nt_f32(&a, m, k, &bt, n);
            assert_eq!(reference, matmul_nt_f32(&a, m, k, &bt, n),
                       "{m}x{k}·{n}ᵀ f32 [{}]", be.name());
        }
    });
}

/// The fused dequant-GEMM oracle (the tentpole's enforcement arm):
/// executing `X·Ŵᵀ + (X·V)·Uᵀ` straight from the bit-packed codes with
/// tile-by-tile decoding must equal the naive
/// unpack-then-matmul-then-add-correction f32 reference **bit for
/// bit**, across bits × scale-group × backend × thread count, plus the
/// rank-0 edge (pure quantized path — no correction panels at all).
#[test]
fn fused_dequant_gemm_bit_identical_to_unpack_reference() {
    use lrc::quant::{rtn_quantize, QuantizedLinear};
    // m = 19 crosses a PAR_ROW_CHUNK boundary; dout = 33 straddles the
    // 8- and 16-lane strip widths; din = 64 divides both group sizes
    let (dout, m) = (33usize, 19usize);
    for_each_backend(|be| {
        for &bits in &[2u32, 3, 4, 8] {
            for &group in &[None, Some(16), Some(64)] {
                for &(din, rank) in &[(64usize, 5usize), (128, 0)] {
                    let seed = 60_000
                        + bits as u64 * 100
                        + group.unwrap_or(0) as u64 * 7
                        + din as u64;
                    let mut rng = Rng::new(seed);
                    let w = Mat::random_normal(&mut rng, dout, din);
                    let wq = rtn_quantize(&w, bits, group);
                    let (u, v) = if rank > 0 {
                        (Some(Mat::random_normal(&mut rng, dout, rank)
                                  .scale(0.05)),
                         Some(Mat::random_normal(&mut rng, din, rank)
                                  .scale(0.05)))
                    } else {
                        (None, None)
                    };
                    let q = QuantizedLinear::from_dense(
                        &wq, bits, group, u.as_ref(), v.as_ref());
                    let x: Vec<f32> = rng.normal_vec(m * din)
                        .iter().map(|&v| v as f32).collect();
                    let reference = q.reference_forward(&x, m);
                    let mut out = Vec::new();
                    q.forward_serial(&x, m, &mut out);
                    assert_eq!(out, reference,
                               "serial bits={bits} group={group:?} \
                                rank={rank} [{}]", be.name());
                    for t in [1usize, 4] {
                        let pool = Pool::new(t);
                        q.forward_pool(&x, m, &pool, &mut out);
                        assert_eq!(out, reference,
                                   "bits={bits} group={group:?} \
                                    rank={rank} t={t} [{}]", be.name());
                    }
                }
            }
        }
    });
}

/// The FMA legs: force each mode and hold the kernels to the matching
/// lockstep reference — fused naive loop under FMA, mul-then-add naive
/// loop otherwise — across every backend, the serial path, pooled row
/// chunks at several thread counts, and the Gram segments.  Also pins
/// the programs apart: on at least one shape the two modes must differ
/// (otherwise the "mode" would be a no-op and the oracle vacuous).
#[test]
fn fma_mode_bit_identical_to_its_lockstep_reference() {
    let _guard = sweep_lock();
    let shapes =
        [(1usize, 1usize, 1usize), (7, 9, 5), (17, 16, 15), (12, 257, 9),
         (33, 65, 31), (65, 256, 65)];
    let mut modes_differed = false;
    for fma in [false, true] {
        simd::set_fma(Some(fma));
        for be in simd::available_backends() {
            simd::set_backend(Some(be)).unwrap();
            for (si, &(m, k, n)) in shapes.iter().enumerate() {
                let a = Mat::random_normal(
                    &mut Rng::new(9_000 + si as u64), m, k);
                let bt = Mat::random_normal(
                    &mut Rng::new(9_500 + si as u64), n, k);
                let reference = naive_matmul_nt(&a, &bt);
                assert_eq!(reference, a.matmul_nt(&bt),
                           "serial {m}x{k}·{n}ᵀ fma={fma} [{}]", be.name());
                for t in [1usize, 4] {
                    let pool = Pool::new(t);
                    assert_eq!(reference, a.par_matmul_nt(&bt, &pool),
                               "{m}x{k}·{n}ᵀ fma={fma} t={t} [{}]",
                               be.name());
                }
                let g = Mat::random_normal(
                    &mut Rng::new(9_900 + si as u64), m, k);
                assert_eq!(naive_gram_n(&g), g.gram_n(),
                           "gram_n {m}x{k} fma={fma} [{}]", be.name());
                assert_eq!(naive_gram_t(&g), g.gram_t(),
                           "gram_t {m}x{k} fma={fma} [{}]", be.name());
            }
        }
        simd::set_backend(None).unwrap();
    }
    // the two canonical programs are genuinely different
    simd::set_fma(Some(false));
    let a = Mat::random_normal(&mut Rng::new(31_337), 23, 129);
    let bt = Mat::random_normal(&mut Rng::new(31_338), 19, 129);
    let plain = a.matmul_nt(&bt);
    simd::set_fma(Some(true));
    let fused = a.matmul_nt(&bt);
    if plain != fused {
        modes_differed = true;
    }
    simd::set_fma(None);
    assert!(modes_differed,
            "FMA mode produced identical bits to mul-then-add — the \
             fused program is not being dispatched");
}
